//! Networked serving demo (E15 companion): the full TCP path in one
//! process, fully offline — synthetic Table-III weights, loopback
//! server, client calls, graceful drain.
//!
//!     cargo run --release --example net_serve
//!
//! Pipeline per request:
//!   client `attribute_batch` → framed wire protocol (JSON header +
//!   raw LE f32 payload) → TCP server (bounded pool, deadlines) →
//!   coordinator micro-batching → shared-plan simulator FP+BP →
//!   heatmap f32s back over the wire, bit-exact.

use std::time::Duration;

use attrax::attribution::Method;
use attrax::coordinator::{Config, Coordinator};
use attrax::fpga::{self, Board};
use attrax::model::{Network, Params};
use attrax::sched::Simulator;
use attrax::serve::{Client, Server, ServerConfig};

fn main() -> anyhow::Result<()> {
    let net = Network::table3();
    let params = Params::synthetic(&net, 42);
    let board = Board::PynqZ2;
    let hw = fpga::choose_config(board, &net, Method::Guided);
    let sim = Simulator::new(net, &params, hw)?;

    let coord = Coordinator::start(
        sim,
        Config { workers: 2, queue_depth: 64, max_batch: 4, max_wait_ms: 2, ..Default::default() },
        None,
    )?;
    let srv = Server::start("127.0.0.1:0", coord, ServerConfig::default())?;
    let addr = srv.local_addr();
    println!("== net_serve: {board} behind {addr} (synthetic weights) ==");

    let mut client = Client::connect(addr)?;
    client.set_timeout(Some(Duration::from_secs(10)))?;

    // one image
    let mut rng = attrax::util::rng::Pcg32::seeded(7);
    let sample = attrax::data::make_sample(3, &mut rng);
    let one = client.attribute(&sample.image, Method::Guided)?;
    println!(
        "single: pred={} device={:.2}ms heatmap[{}] logits[{}]",
        one.pred,
        one.device_cycles as f64 / (fpga::TARGET_FREQ_MHZ * 1e3),
        one.relevance.len(),
        one.logits.len()
    );

    // a batched frame: one wire round-trip, one micro-batched device pass
    let imgs: Vec<Vec<f32>> =
        (0..4).map(|i| attrax::data::make_sample(i, &mut rng).image).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let batch = client.attribute_batch(&refs, Method::Saliency)?;
    let preds: Vec<usize> = batch.iter().map(|a| a.pred).collect();
    println!("batch of {}: preds {:?}", batch.len(), preds);

    let snap = srv.shutdown()?;
    println!("\n== serving metrics ==\n{}", snap.report());
    anyhow::ensure!(snap.completed == 5, "expected 5 completed, saw {}", snap.completed);
    Ok(())
}
