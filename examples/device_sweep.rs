//! Table IV reproduction as a runnable example: per-board hardware
//! configuration, resource utilization (FP vs FP+BP), and modeled
//! latency, plus the pipelined variant and the paper's overhead rows.
//!
//!     make artifacts && cargo run --release --example device_sweep

use attrax::attribution::Method;
use attrax::data;
use attrax::fpga::{self, Board, ALL_BOARDS};
use attrax::model::{artifacts_dir, load_artifacts, Network};
use attrax::sched::{pipeline, AttrOptions, Simulator};
use attrax::util::rng::Pcg32;

/// Paper Table IV reference values: (board, fp_ms, fpbp_ms).
const PAPER_LATENCY: [(&str, f64, f64); 3] = [
    ("Pynq-Z2", 43.53, 66.75),
    ("Ultra96-V2", 24.56, 39.96),
    ("ZCU104", 15.32, 26.37),
];

fn main() -> anyhow::Result<()> {
    let (_, params) = load_artifacts(&artifacts_dir())?;
    let net = Network::table3();
    let method = Method::Guided;
    let mut rng = Pcg32::seeded(4);
    let sample = data::make_sample(1, &mut rng);

    println!("== Table IV: per-board configuration, resources, latency ==\n");
    println!(
        "{:<12} {:>5} {:>5} {:>5} | {:>5} {:>4} {:>8} {:>8} | {:>8} {:>8} {:>9} | {:>8}",
        "board", "N_oh", "N_ow", "VMM", "BRAM", "DSP", "FF", "LUT", "FP(ms)", "+BP(ms)", "ovhd(%)", "pipe(x)"
    );
    for (bi, b) in ALL_BOARDS.iter().enumerate() {
        let cfg = fpga::choose_config(*b, &net, method);
        let sim = Simulator::new(net.clone(), &params, cfg)?;
        let r = sim.attribute(&sample.image, method, AttrOptions::default());
        let fp = r.fp_cost.latency_ms(fpga::TARGET_FREQ_MHZ);
        let bp = r.bp_cost.latency_ms(fpga::TARGET_FREQ_MHZ);
        let rep = pipeline::analyze(&r.fp_cost, &r.bp_cost, fpga::TARGET_FREQ_MHZ);
        let u = fpga::estimate_fp_bp(&cfg, &net, method);
        let pct = b.percent(&u);
        println!(
            "{:<12} {:>5} {:>5} {:>5} | {:>5} {:>4} {:>8} {:>8} | {:>8.2} {:>8.2} {:>9.1} | {:>8.2}",
            b.name(),
            cfg.n_oh,
            cfg.n_ow,
            cfg.vmm_tile,
            u.bram_18k,
            u.dsp,
            u.ff,
            u.lut,
            fp,
            fp + bp,
            100.0 * bp / fp,
            rep.speedup,
        );
        println!(
            "{:<12} {:>27} | {:>4.0}% {:>4.0}% {:>7.0}% {:>7.0}% | paper: {:>6.2} {:>8.2}",
            "",
            "utilization / paper ref",
            pct[0],
            pct[1],
            pct[2],
            pct[3],
            PAPER_LATENCY[bi].1,
            PAPER_LATENCY[bi].2,
        );
    }

    println!("\n== per-layer latency breakdown (ZCU104, guided) ==\n");
    let cfg = fpga::choose_config(Board::Zcu104, &net, method);
    let sim = Simulator::new(net.clone(), &params, cfg)?;
    let r = sim.attribute(&sample.image, method, AttrOptions::default());
    println!("{:<10} {:>12} {:>10}", "layer", "cycles", "ms@100MHz");
    for (name, cycles) in r.fp_cost.layer_breakdown() {
        println!("{:<10} {:>12} {:>10.3}", name, cycles, cycles as f64 / 1e5);
    }
    println!("-- backward --");
    for (name, cycles) in r.bp_cost.layer_breakdown() {
        println!("{:<10} {:>12} {:>10.3}", name, cycles, cycles as f64 / 1e5);
    }
    Ok(())
}
