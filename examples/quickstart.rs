//! Quickstart: load the trained artifacts, build the accelerator
//! simulator for a board, and attribute one image.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Prints the prediction, the modeled device latency (the paper's
//! Table-IV quantity), and writes `out/quickstart_heatmap.ppm`.

use attrax::attribution::Method;
use attrax::data;
use attrax::fpga::{self, Board};
use attrax::model::{artifacts_dir, load_artifacts, Network};
use attrax::sched::{AttrOptions, Simulator};
use attrax::util::{ppm, rng::Pcg32};

fn main() -> anyhow::Result<()> {
    // 1. artifacts: weights trained + AOT-compiled by `make artifacts`
    let (manifest, params) = load_artifacts(&artifacts_dir())?;
    println!(
        "loaded {} ({} params, trained to {:.1}% test accuracy)",
        manifest.network,
        manifest.param_count,
        manifest.test_accuracy * 100.0
    );

    // 2. pick a board; the library chooses the paper's Table-IV config
    let board = Board::PynqZ2;
    let net = Network::table3();
    let cfg = fpga::choose_config(board, &net, Method::Guided);
    println!(
        "{board}: N_oh={} N_ow={} VMM={} ({} parallel conv MACs)",
        cfg.n_oh,
        cfg.n_ow,
        cfg.vmm_tile,
        cfg.conv_macs_parallel()
    );
    let sim = Simulator::new(net, &params, cfg)?;

    // 3. one shapes-32 sample through FP+BP on the 16-bit datapath
    let mut rng = Pcg32::seeded(7);
    let sample = data::make_sample(2, &mut rng); // a triangle
    let r = sim.attribute(&sample.image, Method::Guided, AttrOptions::default());
    let fp = r.fp_cost.latency_ms(fpga::TARGET_FREQ_MHZ);
    let bp = r.bp_cost.latency_ms(fpga::TARGET_FREQ_MHZ);
    println!(
        "pred = {} ({}), device latency = {fp:.2} + {bp:.2} = {:.2} ms @100MHz",
        r.pred,
        data::CLASS_NAMES[r.pred],
        fp + bp
    );
    println!(
        "localization (relevance mass on the shape) = {:.3}",
        data::localization_score(&r.relevance, &sample.mask)
    );

    // 4. render the heatmap
    std::fs::create_dir_all("out")?;
    let mut heat = vec![0f32; 32 * 32];
    for c in 0..3 {
        for i in 0..1024 {
            heat[i] += r.relevance[c * 1024 + i];
        }
    }
    let path = std::path::Path::new("out/quickstart_heatmap.ppm");
    ppm::write_ppm(path, &ppm::relevance_to_rgb(&heat), 32, 32)?;
    println!("wrote {}", path.display());
    Ok(())
}
