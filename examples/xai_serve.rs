//! End-to-end serving driver (E12): the full system on a real small
//! workload, proving all layers compose.
//!
//!     make artifacts && cargo run --release --example xai_serve -- \
//!         [requests] [workers] [verify_fraction] [max_batch] [max_wait_ms]
//!
//! Pipeline exercised per request:
//!   shapes-32 generator (rust)  →  bounded queue + worker pool (L3)
//!   →  16-bit tiled accelerator simulator FP+BP (L3, modeling the
//!      paper's Table-IV hardware)  →  heatmap + metrics
//!   and, for a sampled fraction  →  PJRT golden float path (the AOT
//!      HLO compiled from the L2 JAX model calling the L1 Pallas
//!      kernels), with fixed-vs-float correlation tracked.
//!
//! Reports: accuracy, localization, host latency percentiles, modeled
//! device latency, throughput, verification agreement. Recorded in
//! EXPERIMENTS.md §E12.

use attrax::attribution::Method;
use attrax::coordinator::{server, Config, Coordinator};
use attrax::fpga::{self, Board};
use attrax::model::{artifacts_dir, load_artifacts, Network};
use attrax::sched::Simulator;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(60);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let verify: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.2);
    let max_batch: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(8);
    let max_wait_ms: u64 = args.get(4).and_then(|s| s.parse().ok()).unwrap_or(3);

    let (manifest, params) = load_artifacts(&artifacts_dir())?;
    let net = Network::table3();
    let board = Board::Zcu104;
    let cfg = fpga::choose_config(board, &net, Method::Guided);
    let sim = Simulator::new(net.clone(), &params, cfg)?;
    println!(
        "== xai_serve: {requests} requests, {workers} workers, verify {:.0}%, board {board}, \
         micro-batch ≤{max_batch} (wait {max_wait_ms}ms) ==",
        verify * 100.0
    );
    println!(
        "model: {} params, trained test acc {:.1}%",
        manifest.param_count,
        manifest.test_accuracy * 100.0
    );

    let coord = Coordinator::start(
        sim,
        Config {
            workers,
            queue_depth: 256,
            verify_fraction: verify,
            freq_mhz: fpga::TARGET_FREQ_MHZ,
            max_batch,
            max_wait_ms,
            ..Default::default()
        },
        Some((manifest, params)),
    )?;

    let report = server::run_load(
        &coord,
        server::LoadSpec { requests, rate: 0.0, seed: 2026, method: None },
    );

    // per-method localization breakdown
    let mut by_method: std::collections::BTreeMap<Method, (f64, usize)> = Default::default();
    let mut device_ms = attrax::util::stats::Samples::new();
    for item in &report.items {
        if let Some(r) = &item.response {
            let e = by_method.entry(r.method).or_insert((0.0, 0));
            e.0 += item.localization;
            e.1 += 1;
            device_ms.push(r.device_ms);
        }
    }

    println!("\n== workload results ==");
    println!(
        "served {} requests in {:.2}s ({:.1} img/s host), rejected {}",
        report.items.len(),
        report.wall_s,
        report.items.len() as f64 / report.wall_s,
        report.rejected
    );
    println!("classification accuracy on generated samples: {:.1}%", report.accuracy * 100.0);
    println!(
        "modeled device latency (FP+BP @100MHz): mean {:.2} ms -> {:.1} img/s on-device",
        device_ms.mean(),
        1e3 / device_ms.mean()
    );
    for (m, (sum, n)) in &by_method {
        println!("  {m:<10} mean localization {:.3} over {n} requests", sum / *n as f64);
    }

    // give the verifier a moment to drain, then report
    std::thread::sleep(std::time::Duration::from_millis(1500));
    let snap = coord.shutdown();
    println!("\n== coordinator metrics ==\n{}", snap.report());

    anyhow::ensure!(report.accuracy > 0.9, "end-to-end accuracy regressed");
    if snap.verified > 0 {
        anyhow::ensure!(
            snap.mean_verify_corr > 0.95,
            "fixed-point vs golden correlation too low: {}",
            snap.mean_verify_corr
        );
        println!(
            "\nOK: 16-bit device heatmaps match the float golden path (corr {:.4})",
            snap.mean_verify_corr
        );
    }
    Ok(())
}
