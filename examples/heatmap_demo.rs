//! Fig. 3 reproduction: attribution heatmaps for the three methods,
//! rendered side by side with the input, on both the fixed-point device
//! simulator and the PJRT float golden path.
//!
//!     make artifacts && cargo run --release --example heatmap_demo
//!
//! Writes per-sample panels to out/fig3/:
//!   sample<k>_input.ppm
//!   sample<k>_<method>_device.ppm   (16-bit accelerator simulator)
//!   sample<k>_<method>_golden.ppm   (PJRT float path)
//! and prints the device-vs-golden correlation + localization table.

use attrax::attribution::{Method, ALL_METHODS};
use attrax::data;
use attrax::fpga::{self, Board};
use attrax::model::{artifacts_dir, load_artifacts, Network};
use attrax::runtime::Runtime;
use attrax::sched::{AttrOptions, Simulator};
use attrax::util::ppm;
use attrax::util::rng::Pcg32;
use attrax::util::stats::{pearson, spearman};
use std::path::PathBuf;

fn channel_sum(rel: &[f32]) -> Vec<f32> {
    let mut heat = vec![0f32; 1024];
    for c in 0..3 {
        for i in 0..1024 {
            heat[i] += rel[c * 1024 + i];
        }
    }
    heat
}

fn main() -> anyhow::Result<()> {
    let (manifest, params) = load_artifacts(&artifacts_dir())?;
    let net = Network::table3();
    let cfg = fpga::choose_config(Board::Zcu104, &net, Method::Guided);
    let sim = Simulator::new(net, &params, cfg)?;

    let runtime = Runtime::cpu()?;
    let mut golden = std::collections::BTreeMap::new();
    for m in ALL_METHODS {
        golden.insert(
            m,
            runtime.load_artifact(&manifest, &params, &format!("attr_{}", m.name()), 2)?,
        );
    }

    let out_dir = PathBuf::from("out/fig3");
    std::fs::create_dir_all(&out_dir)?;
    let mut rng = Pcg32::seeded(11);

    println!(
        "{:<8} {:<10} {:>6} {:>10} {:>10} {:>8} {:>8}",
        "sample", "method", "pred", "pearson", "spearman", "loc-dev", "loc-gold"
    );
    for (k, cls) in [0usize, 2, 6, 7].iter().enumerate() {
        let sample = data::make_sample(*cls, &mut rng);
        // input panel
        ppm::write_ppm(
            &out_dir.join(format!("sample{k}_input.ppm")),
            &ppm::chw_to_rgb(&sample.image, 32, 32),
            32,
            32,
        )?;
        for m in ALL_METHODS {
            let dev = sim.attribute(&sample.image, m, AttrOptions::default());
            let outs = golden[&m].run(&sample.image, &manifest.img_shape)?;
            let gold_rel = &outs[1];

            let dev_heat = channel_sum(&dev.relevance);
            let gold_heat = channel_sum(gold_rel);
            ppm::write_ppm(
                &out_dir.join(format!("sample{k}_{}_device.ppm", m.name())),
                &ppm::relevance_to_rgb(&dev_heat),
                32,
                32,
            )?;
            ppm::write_ppm(
                &out_dir.join(format!("sample{k}_{}_golden.ppm", m.name())),
                &ppm::relevance_to_rgb(&gold_heat),
                32,
                32,
            )?;
            println!(
                "{:<8} {:<10} {:>6} {:>10.4} {:>10.4} {:>8.3} {:>8.3}",
                format!("{k}:{}", data::CLASS_NAMES[*cls]),
                m.name(),
                dev.pred,
                pearson(&dev.relevance, gold_rel),
                spearman(&dev.relevance, gold_rel),
                data::localization_score(&dev.relevance, &sample.mask),
                data::localization_score(gold_rel, &sample.mask),
            );
        }
    }
    println!("\nwrote panels to {}", out_dir.display());
    println!("(view .ppm files with any image viewer; red = positive relevance, blue = negative)");
    Ok(())
}
