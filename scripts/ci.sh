#!/usr/bin/env bash
# Canonical pre-merge check (referenced from ROADMAP.md).
#
# Tier-1 gate first (must stay green), then style/lint gates. The build
# gate uses --all-targets so the harness=false bench binaries are
# compiled in the tier-1 step too (previously they were only reached by
# clippy, letting bench-only breakage slip past the build gate).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== perf gate: allocation-count regression (release) =="
# The zero-allocation steady-state guarantee is a release-mode property
# the serving path depends on; run its regression test under the same
# profile the binaries ship with.
cargo test --release -q --test alloc_regression

echo "== style: cargo fmt --check =="
cargo fmt --check

echo "== lint: cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== ci.sh: all gates passed =="
