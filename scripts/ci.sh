#!/usr/bin/env bash
# Canonical pre-merge check (referenced from ROADMAP.md).
#
# Tier-1 gate first (must stay green), then style/lint gates. The lint
# gates cover all targets including the harness=false bench binaries.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== style: cargo fmt --check =="
cargo fmt --check

echo "== lint: cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== ci.sh: all gates passed =="
