#!/usr/bin/env bash
# Canonical pre-merge check (referenced from ROADMAP.md).
#
# Tier-1 gate first (must stay green), then style/lint gates. The build
# gate uses --all-targets so the harness=false bench binaries are
# compiled in the tier-1 step too (previously they were only reached by
# clippy, letting bench-only breakage slip past the build gate).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release --all-targets =="
cargo build --release --all-targets

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== perf gate: allocation-count regression (release) =="
# The zero-allocation steady-state guarantee is a release-mode property
# the serving path depends on; run its regression test under the same
# profile the binaries ship with.
cargo test --release -q --test alloc_regression

echo "== serve gate: loopback e2e + protocol robustness =="
# The networked serving subsystem's dedicated suites (also part of the
# plain `cargo test` run above; repeated by name so a serve regression
# is called out explicitly in CI output).
cargo test -q --test e2e_net
cargo test -q --test proto_robustness

echo "== serve gate: loadgen smoke (2s in-process loopback) =="
# Keeps the binary path green: spins a TCP server on an ephemeral
# loopback port with synthetic weights and hammers it for ~2 seconds.
# Fails if zero requests complete.
cargo run --release -q -- loadgen --smoke --secs 2 --out BENCH_serve_smoke.json

echo "== dse gate: tune --smoke emits an artifact that serve --config accepts =="
# Tiny exhaustive space on synthetic Table-III weights, fully offline.
# The tuned-config artifact must be parseable (schema-tagged JSON) and
# must boot the serving coordinator via --config.
cargo run --release -q -- tune --smoke --out BENCH_dse_smoke.json --tuned tuned_smoke.json
grep -q '"schema":"attrax-tuned/v1"' tuned_smoke.json
grep -q '"bench":"dse"' BENCH_dse_smoke.json
cargo run --release -q -- serve --config tuned_smoke.json --requests 4 --workers 1 --verify 0
rm -f tuned_smoke.json BENCH_dse_smoke.json

echo "== xeval gate: eval --smoke + tune --smoke --quality =="
# Attribution-quality smoke: fully offline on synthetic Table-III
# weights. The binary exits nonzero unless the identity self-check is
# exact and the parameter-randomization sanity check passes for all
# three methods; the artifact must carry the schema tag. Then the
# quality-objective tuner must still emit an artifact that boots
# `attrax serve --config`.
cargo run --release -q -- eval --smoke --out BENCH_xeval_smoke.json
grep -q '"schema":"attrax-xeval/v1"' BENCH_xeval_smoke.json
cargo run --release -q -- tune --smoke --quality --out BENCH_dse_q_smoke.json --tuned tuned_q_smoke.json
grep -q '"schema":"attrax-tuned/v1"' tuned_q_smoke.json
grep -q '"quality":true' BENCH_dse_q_smoke.json
cargo run --release -q -- serve --config tuned_q_smoke.json --requests 4 --workers 1 --verify 0
rm -f BENCH_xeval_smoke.json BENCH_dse_q_smoke.json tuned_q_smoke.json

echo "== graph gate: model --dry-run + bad-corpus messages + residual eval smoke =="
# The graph-IR path end to end, fully offline. Every good manifest must
# validate (load -> schedule -> plan compile); every known-bad manifest
# must be rejected with its documented error message (the messages are
# part of the validator's contract — DESIGN.md §graph IR); and the
# residual topology must survive the full attribution-quality smoke,
# which exercises the skip fork/join through FP, BP and the oracle.
cargo run --release -q -- model --dry-run \
    examples/graphs/table3.graph.json \
    examples/graphs/vgg11_32.graph.json \
    examples/graphs/residual16.graph.json
check_bad_manifest() {
    # $1 = manifest path, $2 = expected error substring
    if out=$(cargo run --release -q -- model --dry-run "$1" 2>&1); then
        echo "ERROR: $1 validated but must be rejected"
        exit 1
    fi
    if ! echo "$out" | grep -qF "$2"; then
        echo "ERROR: $1 rejection message missing \"$2\":"
        echo "$out"
        exit 1
    fi
}
check_bad_manifest examples/graphs/bad/cycle.graph.json          "cycle through"
check_bad_manifest examples/graphs/bad/unknown_input.graph.json  "unknown input"
check_bad_manifest examples/graphs/bad/duplicate.graph.json      "duplicate node name"
check_bad_manifest examples/graphs/bad/odd_pool.graph.json       "maxpool needs even dims"
check_bad_manifest examples/graphs/bad/bad_fanin.graph.json      "expects 2 input"
check_bad_manifest examples/graphs/bad/shape_mismatch.graph.json "input channels, got"
cargo run --release -q -- eval --smoke --model examples/graphs/residual16.graph.json \
    --out BENCH_graph_smoke.json
grep -q '"schema":"attrax-xeval/v1"' BENCH_graph_smoke.json
rm -f BENCH_graph_smoke.json

echo "== chaos gate: deterministic fault campaign, zero escaped faults =="
# Seeded fault-injection smoke through the whole serving stack (wire
# proxy + admission + device + memory sites). The binary exits nonzero
# if any injected fault escapes as a wrong answer; two runs must be
# byte-identical (the report carries no wall-clock fields) and the
# artifact must be schema-tagged with an explicit escaped:0.
cargo run --release -q -- chaos --smoke --out BENCH_chaos_a.json
cargo run --release -q -- chaos --smoke --out BENCH_chaos_b.json
cmp BENCH_chaos_a.json BENCH_chaos_b.json
grep -q '"schema":"attrax-chaos/v1"' BENCH_chaos_a.json
grep -q '"escaped":0' BENCH_chaos_a.json
rm -f BENCH_chaos_a.json BENCH_chaos_b.json

echo "== obs gate: capture -> bit-exact replay -> deterministic doctor =="
# Capture a short traced loopback run, then (1) replay it in-process:
# the binary exits nonzero unless every recorded heatmap reconciles
# bitwise; (2) doctor it: schema-tagged BENCH_doctor.json, and two runs
# must be byte-identical (no wall-clock fields in the report).
cargo run --release -q -- loadgen --smoke --secs 2 --trace-out smoke.trace \
    --out BENCH_serve_smoke.json
cargo run --release -q -- replay smoke.trace
cargo run --release -q -- doctor smoke.trace --out BENCH_doctor.json
grep -q '"schema":"attrax-doctor/v1"' BENCH_doctor.json
cargo run --release -q -- doctor smoke.trace --out BENCH_doctor_b.json
cmp BENCH_doctor.json BENCH_doctor_b.json
rm -f smoke.trace BENCH_serve_smoke.json BENCH_doctor.json BENCH_doctor_b.json

echo "== telemetry gate: live scrape reconciles with the final snapshot =="
# Loadgen smoke with the stats endpoint bound on an ephemeral loopback
# port: the report must carry the server-side stage/unit breakdown,
# counters may only grow between the two scrapes (monotone:true), and
# the final scrape's dual-written counters must equal the in-process
# coordinator Snapshot exactly (reconciled:true; the binary also exits
# nonzero on a reconciliation failure).
cargo run --release -q -- loadgen --smoke --secs 2 --stats-addr 127.0.0.1:0 \
    --out BENCH_serve_stats.json
grep -q '"server_stats":' BENCH_serve_stats.json
grep -q '"monotone":true' BENCH_serve_stats.json
grep -q '"reconciled":true' BENCH_serve_stats.json
rm -f BENCH_serve_stats.json

echo "== slo gate: classed loadgen reconciles + deterministic monitor smoke =="
# (1) Classed traffic: every Ok frame must land in exactly one per-class
# slot, so the classed scrape counters times the batch size must equal
# the final Snapshot's completed count (reconciled:true covers it; the
# binary exits nonzero otherwise). (2) monitor --smoke drives a fixed
# classed workload against a loopback server under the committed spec:
# exit 0 on the compliant spec with a byte-identical rerun (the report
# is counter arithmetic only — no wall clock, no latencies), nonzero on
# the impossible one (1 ns threshold, zero budget => exhausted).
cargo run --release -q -- loadgen --smoke --secs 2 --stats-addr 127.0.0.1:0 \
    --class-mix gold:1,silver:2,bronze:5 --out BENCH_serve_classed.json
grep -q '"reconciled":true' BENCH_serve_classed.json
grep -q '"classes":' BENCH_serve_classed.json
grep -q '"schema":"attrax-slo/v1"' examples/slo/default.slo.json
cargo run --release -q -- monitor examples/slo/default.slo.json --smoke --out BENCH_slo_a.json
cargo run --release -q -- monitor examples/slo/default.slo.json --smoke --out BENCH_slo_b.json
cmp BENCH_slo_a.json BENCH_slo_b.json
grep -q '"schema":"attrax-slo-report/v1"' BENCH_slo_a.json
grep -q '"exhausted":false' BENCH_slo_a.json
if cargo run --release -q -- monitor examples/slo/impossible.slo.json --smoke \
    --out BENCH_slo_bad.json; then
    echo "ERROR: the impossible spec must exhaust its budget (nonzero exit)"
    exit 1
fi
grep -q '"exhausted":true' BENCH_slo_bad.json
rm -f BENCH_serve_classed.json BENCH_slo_a.json BENCH_slo_b.json BENCH_slo_bad.json

echo "== style: cargo fmt --check =="
cargo fmt --check

echo "== lint: cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== ci.sh: all gates passed =="
