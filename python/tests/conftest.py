"""Make `compile.*` importable whether pytest runs from python/ or the
repository root (the Makefile uses python/, CI logs use the root)."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
