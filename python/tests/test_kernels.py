"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes and value ranges. This is the CORE
correctness signal for the kernel library."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as kconv
from compile.kernels import pool as kpool
from compile.kernels import quant as kquant
from compile.kernels import ref
from compile.kernels import relu as krelu
from compile.kernels import vmm as kvmm

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    ic=st.sampled_from([1, 3, 8, 16]),
    oc=st.sampled_from([4, 16, 32]),
    hw=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(ic, oc, hw, seed):
    x = rand(seed, (ic, hw, hw))
    w = rand(seed + 1, (oc, ic, 3, 3), -0.5, 0.5)
    got = kconv.conv2d(x, w)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    ic=st.sampled_from([3, 8]),
    oc=st.sampled_from([4, 32]),
    hw=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_input_grad_matches_ref(ic, oc, hw, seed):
    g = rand(seed, (oc, hw, hw))
    w = rand(seed + 1, (oc, ic, 3, 3), -0.5, 0.5)
    got = kconv.conv2d_input_grad(g, w)
    want = ref.conv2d_input_grad(g, w)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_conv2d_input_grad_is_true_vjp():
    """The flipped-transpose conv equals jax.vjp of the forward conv."""
    x = rand(0, (3, 16, 16))
    w = rand(1, (8, 3, 3, 3), -0.5, 0.5)
    g = rand(2, (8, 16, 16))
    _, vjp = jax.vjp(lambda xx: ref.conv2d(xx, w), x)
    want = vjp(g)[0]
    got = kconv.conv2d_input_grad(g, w)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_conv2d_block_size_invariance():
    x = rand(3, (16, 16, 16))
    w = rand(4, (32, 16, 3, 3), -0.3, 0.3)
    a = kconv.conv2d(x, w, co_blk=8, ci_blk=4)
    b = kconv.conv2d(x, w, co_blk=32, ci_blk=16)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_flip_transpose_involution():
    w = rand(5, (6, 4, 3, 3))
    np.testing.assert_array_equal(
        ref.flip_transpose_weights(ref.flip_transpose_weights(w)), w
    )


# ---------------------------------------------------------------------------
# vmm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    out_n=st.sampled_from([10, 128, 100]),
    in_n=st.sampled_from([128, 1000, 4096]),
    seed=st.integers(0, 2**16),
)
def test_vmm_matches_ref(out_n, in_n, seed):
    w = rand(seed, (out_n, in_n), -0.2, 0.2)
    x = rand(seed + 1, (in_n,))
    np.testing.assert_allclose(kvmm.vmm(w, x), ref.vmm(w, x), atol=2e-3, rtol=1e-3)


@settings(**SETTINGS)
@given(
    out_n=st.sampled_from([10, 128]),
    in_n=st.sampled_from([128, 4096]),
    seed=st.integers(0, 2**16),
)
def test_vmm_t_matches_ref(out_n, in_n, seed):
    w = rand(seed, (out_n, in_n), -0.2, 0.2)
    g = rand(seed + 1, (out_n,))
    np.testing.assert_allclose(kvmm.vmm_t(w, g), ref.vmm_t(w, g), atol=2e-3, rtol=1e-3)


def test_vmm_t_is_transpose_of_vmm():
    """<y, Wx> == <WᵀY, x> — the reuse the paper exploits (§III-E)."""
    w = rand(6, (32, 64), -0.5, 0.5)
    x = rand(7, (64,))
    y = rand(8, (32,))
    lhs = jnp.dot(y, kvmm.vmm(w, x))
    rhs = jnp.dot(kvmm.vmm_t(w, y), x)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


# ---------------------------------------------------------------------------
# relu (Fig. 4 dataflows)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    c=st.sampled_from([1, 3, 32]),
    hw=st.sampled_from([4, 16]),
    method=st.sampled_from(["saliency", "deconvnet", "guided"]),
    seed=st.integers(0, 2**16),
)
def test_relu_fwd_bwd_matches_ref(c, hw, method, seed):
    x = rand(seed, (c, hw, hw), -2.0, 2.0)
    g = rand(seed + 1, (c, hw, hw), -2.0, 2.0)
    y1, m1 = krelu.relu_fwd(x)
    y2, m2 = ref.relu_fwd(x)
    np.testing.assert_array_equal(y1, y2)
    np.testing.assert_array_equal(m1, m2)
    got = krelu.relu_bwd(m1, g, method=method)
    want = ref.RELU_BWD[method](m2, g)
    np.testing.assert_array_equal(got, want)


def test_relu_bwd_rejects_unknown_method():
    m = jnp.ones((4, 4, 4), jnp.int8)
    g = jnp.ones((4, 4, 4), jnp.float32)
    with pytest.raises(ValueError):
        krelu.relu_bwd(m, g, method="lime")


def test_guided_equals_saliency_compose_deconvnet():
    x = rand(9, (8, 8, 8), -1.0, 1.0)
    g = rand(10, (8, 8, 8), -1.0, 1.0)
    _, m = ref.relu_fwd(x)
    a = krelu.relu_bwd(m, g, method="guided")
    b = krelu.relu_bwd(m, krelu.relu_bwd(m, g, method="deconvnet"), method="saliency")
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# pool / unpool (Fig. 5)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    c=st.sampled_from([1, 4, 32]),
    hw=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**16),
)
def test_maxpool_matches_ref(c, hw, seed):
    x = rand(seed, (c, hw, hw))
    p1, i1 = kpool.maxpool2x2(x)
    p2, i2 = ref.maxpool2x2(x)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(i1, i2)


@settings(**SETTINGS)
@given(
    c=st.sampled_from([1, 4, 16]),
    hw=st.sampled_from([2, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_unpool_matches_ref(c, hw, seed):
    g = rand(seed, (c, hw, hw))
    idx = jnp.asarray(
        np.random.default_rng(seed).integers(0, 4, (c, hw, hw)), jnp.int8
    )
    np.testing.assert_array_equal(kpool.unpool2x2(g, idx), ref.unpool2x2(g, idx))


def test_pool_unpool_gradient_routing():
    """unpool(g, idx) places each g exactly at the argmax position."""
    x = rand(11, (4, 8, 8))
    _, idx = kpool.maxpool2x2(x)
    g = rand(12, (4, 4, 4), 0.5, 1.0)
    up = np.asarray(kpool.unpool2x2(g, idx))
    # one nonzero per window, equal to g
    win = up.reshape(4, 4, 2, 4, 2).transpose(0, 1, 3, 2, 4).reshape(4, 4, 4, 4)
    assert (np.count_nonzero(win, axis=-1) == 1).all()
    np.testing.assert_allclose(win.sum(-1), g, rtol=1e-6)


def test_maxpool_is_vjp_consistent():
    """unpool == vjp of maxpool (for distinct window values)."""
    x = rand(13, (2, 8, 8))
    p, idx = ref.maxpool2x2(x)
    g = rand(14, (2, 4, 4))
    _, vjp = jax.vjp(lambda xx: ref.maxpool2x2(xx)[0], x)
    want = vjp(g)[0]
    got = kpool.unpool2x2(g, idx)
    np.testing.assert_allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    word=st.sampled_from([8, 12, 16, 24]),
    frac=st.sampled_from([4, 7, 9]),
    seed=st.integers(0, 2**16),
)
def test_quantize_matches_ref(word, frac, seed):
    if frac >= word:
        return
    x = rand(seed, (8, 8, 8), -40.0, 40.0)
    got = kquant.quantize_fx(x, word_bits=word, frac_bits=frac)
    want = ref.quantize_fx(x, word_bits=word, frac_bits=frac)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_quantize_error_bound():
    x = rand(15, (4, 16, 16), -10.0, 10.0)
    q = kquant.quantize_fx(x, word_bits=16, frac_bits=9)
    assert float(jnp.max(jnp.abs(q - x))) <= 0.5 / 512 + 1e-6


def test_quantize_saturates():
    x = jnp.full((1, 2, 2), 1e6, jnp.float32)
    q = kquant.quantize_fx(x, word_bits=16, frac_bits=9)
    np.testing.assert_allclose(q, np.full((1, 2, 2), 32767 / 512), rtol=1e-6)
