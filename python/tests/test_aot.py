"""AOT path checks: lowering produces valid HLO text; the emitted
artifacts (when present) are internally consistent with the manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lowering_produces_hlo_text():
    lowered = aot._lower_forward(use_ref=True)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "parameter" in text.lower()
    # 12 params + image = 13 inputs
    assert text.count("parameter(") >= 13 or text.count("Parameter") >= 13


def test_attr_lowering_has_two_outputs():
    lowered = aot._lower_attr("guided", use_ref=True)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # root is a 2-tuple: (logits, relevance)
    assert "(f32[10]" in text.replace(" ", "") and "f32[3,32,32]" in text.replace(" ", "")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")), reason="run make artifacts")
def test_manifest_consistent():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    assert m["param_count"] == model.param_count()
    assert m["weight_bytes"] == model.param_count() * 4
    # param table offsets are contiguous and ordered like PARAM_SPEC
    offset = 0
    for entry, (name, kind, shape) in zip(m["params"], model.PARAM_SPEC):
        assert entry["name"] == name
        assert entry["kind"] == kind
        assert tuple(entry["shape"]) == tuple(shape)
        assert entry["offset_bytes"] == offset
        offset += entry["size_bytes"]
    assert offset == m["weight_bytes"]
    assert set(m["methods"]) == set(model.METHODS)
    for art in m["artifacts"].values():
        assert os.path.exists(os.path.join(ART, art)), art
    # §V accounting embedded for the rust side
    assert m["mask_bits_onchip"]["saliency"] == 24_704
    assert m["autodiff_cache_bits"] == 3_543_040


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "weights.bin")), reason="run make artifacts")
def test_weights_roundtrip_through_forward():
    """Load weights.bin the way rust does; the reconstructed params must
    reproduce the golden logits."""
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    raw = np.fromfile(os.path.join(ART, "weights.bin"), dtype="<f4")
    params = {}
    for entry in m["params"]:
        n = int(np.prod(entry["shape"]))
        start = entry["offset_bytes"] // 4
        params[entry["name"]] = jnp.asarray(
            raw[start : start + n].reshape(entry["shape"])
        )
    with open(os.path.join(ART, "golden.json")) as f:
        g = json.load(f)
    gb = np.fromfile(os.path.join(ART, "golden.bin"), dtype="<f4")
    rec_len = 3072 + 10 + len(g["methods"]) * 3072
    img = jnp.asarray(gb[:3072].reshape(3, 32, 32))
    want_logits = gb[3072 : 3072 + 10]
    logits, _ = model.forward_ref(params, img)
    np.testing.assert_allclose(logits, want_logits, atol=1e-4, rtol=1e-4)
    assert gb.size == g["count"] * rec_len


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")), reason="run make artifacts")
def test_trained_model_classifies_fresh_data():
    """The shipped weights generalize to freshly drawn shapes-32 samples."""
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    raw = np.fromfile(os.path.join(ART, "weights.bin"), dtype="<f4")
    params = {}
    for entry in m["params"]:
        n = int(np.prod(entry["shape"]))
        start = entry["offset_bytes"] // 4
        params[entry["name"]] = jnp.asarray(raw[start : start + n].reshape(entry["shape"]))
    rng = np.random.default_rng(99)
    correct = 0
    total = 40
    for i in range(total):
        img, _ = data.make_sample(i % 10, rng)
        logits, _ = model.forward_ref(params, jnp.asarray(img))
        correct += int(jnp.argmax(logits)) == i % 10
    assert correct / total > 0.85, f"accuracy {correct}/{total}"
