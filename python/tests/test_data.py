"""shapes-32 generator sanity: the synthetic CIFAR-10 stand-in."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data


@settings(max_examples=20, deadline=None)
@given(cls=st.integers(0, 9), seed=st.integers(0, 2**16))
def test_sample_well_formed(cls, seed):
    img, mask = data.make_sample(cls, np.random.default_rng(seed))
    assert img.shape == (3, 32, 32)
    assert img.dtype == np.float32
    assert mask.shape == (32, 32)
    assert (img >= 0).all() and (img <= 1).all()
    area = int(mask.sum())
    assert 8 < area < 600, f"class {cls}: {area} shape pixels"


def test_dataset_balanced_and_shuffled():
    xs, ys, masks = data.make_dataset(100, seed=1)
    assert xs.shape == (100, 3, 32, 32)
    assert masks.shape == (100, 32, 32)
    counts = np.bincount(ys, minlength=10)
    assert (counts == 10).all()
    # shuffled: not sorted by class
    assert not (np.diff(ys) >= 0).all()


def test_determinism():
    a = data.make_dataset(20, seed=7)[0]
    b = data.make_dataset(20, seed=7)[0]
    np.testing.assert_array_equal(a, b)
    c = data.make_dataset(20, seed=8)[0]
    assert not np.array_equal(a, c)


def test_classes_distinguishable():
    """Mean per-class mask patterns must differ — else training is moot."""
    rng = np.random.default_rng(3)
    protos = []
    for cls in range(10):
        acc = np.zeros((32, 32))
        for _ in range(20):
            _, m = data.make_sample(cls, rng)
            acc += m
        protos.append(acc / 20)
    # pairwise L1 distance between class prototypes is nonzero
    for i in range(10):
        for j in range(i + 1, 10):
            d = np.abs(protos[i] - protos[j]).mean()
            assert d > 0.005, f"classes {i} and {j} look identical"


def test_shape_contrast():
    rng = np.random.default_rng(11)
    ok = 0
    for i in range(30):
        img, mask = data.make_sample(i % 10, rng)
        fg = img[:, mask].mean()
        bg = img[:, ~mask].mean()
        ok += fg > bg + 0.15
    assert ok >= 27
