"""L2 correctness: the Table-III CNN, its attribution BP, and the
paper's memory accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def image():
    img, _ = data.make_sample(3, np.random.default_rng(0))
    return jnp.asarray(img)


def test_param_count_matches_paper(params):
    assert model.param_count() == 591_274
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == 591_274
    # per-layer counts from Table III
    counts = {
        "conv1": 896, "conv2": 9248, "conv3": 18496, "conv4": 36928,
        "fc1": 524416, "fc2": 1290,
    }
    for name, want in counts.items():
        w = params[f"{name}_w"]
        b = params[f"{name}_b"]
        assert int(np.prod(w.shape)) + int(np.prod(b.shape)) == want, name


def test_model_size_2_26_mib():
    mib = model.param_count() * 4 / (1024 * 1024)
    assert abs(mib - 2.2555) < 0.01


def test_forward_pallas_equals_ref(params, image):
    l1, c1 = model.forward(params, image)
    l2, c2 = model.forward_ref(params, image)
    np.testing.assert_allclose(l1, l2, atol=1e-4, rtol=1e-4)
    for k in c1:
        np.testing.assert_array_equal(np.asarray(c1[k]), np.asarray(c2[k]), err_msg=k)


@pytest.mark.parametrize("method", model.METHODS)
def test_attribute_pallas_equals_ref(params, image, method):
    _, r1 = model.attribute(params, image, method)
    _, r2 = model.attribute_ref(params, image, method)
    np.testing.assert_allclose(r1, r2, atol=2e-3, rtol=2e-3)


def test_saliency_equals_autodiff(params, image):
    """Eq. 3's analytic BP must equal jax.grad exactly — the strongest
    end-to-end oracle for the backward dataflow."""
    want = model.saliency_autodiff(params, image)
    _, got = model.attribute_ref(params, image, "saliency")
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_saliency_autodiff_any_target(params, image):
    for target in [0, 4, 9]:
        want = model.saliency_autodiff(params, image, target=target)
        _, got = model.attribute_ref(params, image, "saliency", target=target)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_deconvnet_guided_nonnegative_final_grad(params, image):
    """Deconvnet/guided heatmaps highlight positive contributions: the
    gradient leaving the last ReLU is non-negative (eq. 4/5); the conv
    BP may still produce signed relevance (negative kernel weights)."""
    for method in ("deconvnet", "guided"):
        _, rel = model.attribute_ref(params, image, method)
        assert np.isfinite(np.asarray(rel)).all()


def test_masks_shapes(params, image):
    _, caches = model.forward_ref(params, image)
    assert caches["m1"].shape == (32, 32, 32)
    assert caches["m2"].shape == (32, 32, 32)
    assert caches["m3"].shape == (64, 16, 16)
    assert caches["m4"].shape == (64, 16, 16)
    assert caches["m5"].shape == (128,)
    assert caches["i1"].shape == (32, 16, 16)
    assert caches["i2"].shape == (64, 8, 8)
    # pool indices are 2-bit values
    assert int(jnp.max(caches["i1"])) <= 3 and int(jnp.min(caches["i1"])) >= 0


def test_mask_accounting_matches_paper():
    # §V: 24.7 Kb on-chip vs 3.4 Mb framework cache
    assert model.mask_bits_onchip("saliency") == 24_704
    assert model.mask_bits_onchip("guided") == 24_704
    assert model.mask_bits_onchip("deconvnet") == 24_576
    assert model.autodiff_cache_bits() == 3_543_040
    ratio = model.autodiff_cache_bits() / model.mask_bits_onchip("saliency")
    assert 130 < ratio < 150  # paper rounds to 137x
    # Table II conceptual ordering
    assert model.mask_bits_conceptual("deconvnet") < model.mask_bits_conceptual("guided")


def test_attribution_shape_and_start_class(params, image):
    logits, rel = model.attribute_ref(params, image, "guided")
    assert rel.shape == (3, 32, 32)
    assert logits.shape == (10,)
    # explicit target changes the heatmap
    _, rel0 = model.attribute_ref(params, image, "guided", target=0)
    _, rel9 = model.attribute_ref(params, image, "guided", target=9)
    assert not np.allclose(rel0, rel9)
