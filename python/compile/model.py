"""Layer-2: the paper's Table-III CNN and its attribution backward pass.

Forward (FP) and attribution-backward (BP) are composed from the Layer-1
Pallas kernels so that the AOT-lowered HLO contains the same tiled
compute the paper's accelerator executes. A pure-jnp twin built from
`kernels.ref` is provided for every entry point; pytest asserts the two
agree, and the trainer uses the (vmap-friendly, faster) ref twin.

Network (paper Table III — parameter counts reproduced in test_model.py):

    [3,32,32]  Conv2d 3x3/p1 +ReLU   [32,32,32]     896
    [32,32,32] Conv2d 3x3/p1 +ReLU   [32,32,32]   9,248
    [32,32,32] MaxPool2d 2x2         [32,16,16]
    [32,16,16] Conv2d 3x3/p1 +ReLU   [64,16,16]  18,496
    [64,16,16] Conv2d 3x3/p1 +ReLU   [64,16,16]  36,928
    [64,16,16] MaxPool2d 2x2         [64,8,8]
    [4096]     FC +ReLU              [128]      524,416
    [128]      FC                    [10]         1,290
                                     total      591,274 (2.26 MiB fp32)

The BP pass is *analytic* (paper §V "Software"): no autodiff, no cached
activations — only the 1-bit ReLU masks and 2-bit pool argmax indices
captured during FP are consumed, exactly the memory optimization the
paper claims (3.4 Mb -> 24.7 Kb).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import conv2d as kconv
from .kernels import pool as kpool
from .kernels import ref
from .kernels import relu as krelu
from .kernels import vmm as kvmm

METHODS = ("saliency", "deconvnet", "guided")

# (name, kind, shape) in DRAM/weights.bin order. Kind is used by the
# rust loader to distinguish conv kernels from fc matrices.
PARAM_SPEC = (
    ("conv1_w", "conv", (32, 3, 3, 3)),
    ("conv1_b", "bias", (32,)),
    ("conv2_w", "conv", (32, 32, 3, 3)),
    ("conv2_b", "bias", (32,)),
    ("conv3_w", "conv", (64, 32, 3, 3)),
    ("conv3_b", "bias", (64,)),
    ("conv4_w", "conv", (64, 64, 3, 3)),
    ("conv4_b", "bias", (64,)),
    ("fc1_w", "fc", (128, 4096)),
    ("fc1_b", "bias", (128,)),
    ("fc2_w", "fc", (10, 128)),
    ("fc2_b", "bias", (10,)),
)


def param_count():
    n = 0
    for _, _, shape in PARAM_SPEC:
        k = 1
        for d in shape:
            k *= d
        n += k
    return n


def init_params(key):
    """He-normal init, dict keyed per PARAM_SPEC."""
    params = {}
    for name, kind, shape in PARAM_SPEC:
        key, sub = jax.random.split(key)
        if kind == "bias":
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = 1
            for d in shape[1:]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Forward pass. Returns logits + the BP caches (masks only — paper §V).
# ---------------------------------------------------------------------------


def _forward(p, x, ops):
    """Shared FP graph; `ops` selects pallas kernels or the jnp oracle."""
    conv, rl, mp, mm = ops["conv"], ops["relu_fwd"], ops["pool"], ops["vmm"]

    a1 = conv(x, p["conv1_w"]) + p["conv1_b"][:, None, None]
    a1, m1 = rl(a1)
    a2 = conv(a1, p["conv2_w"]) + p["conv2_b"][:, None, None]
    a2, m2 = rl(a2)
    a2, i1 = mp(a2)

    a3 = conv(a2, p["conv3_w"]) + p["conv3_b"][:, None, None]
    a3, m3 = rl(a3)
    a4 = conv(a3, p["conv4_w"]) + p["conv4_b"][:, None, None]
    a4, m4 = rl(a4)
    a4, i2 = mp(a4)

    flat = a4.reshape(-1)
    h = mm(p["fc1_w"], flat) + p["fc1_b"]
    h, m5 = rl(h)
    logits = mm(p["fc2_w"], h) + p["fc2_b"]

    caches = {"m1": m1, "m2": m2, "m3": m3, "m4": m4, "m5": m5, "i1": i1, "i2": i2}
    return logits, caches


_PALLAS_OPS = {
    "conv": kconv.conv2d,
    "relu_fwd": krelu.relu_fwd,
    "pool": kpool.maxpool2x2,
    "vmm": kvmm.vmm,
}
_REF_OPS = {
    "conv": ref.conv2d,
    "relu_fwd": ref.relu_fwd,
    "pool": ref.maxpool2x2,
    "vmm": ref.vmm,
}


def forward(params, x):
    """FP via Pallas kernels. x:[3,32,32] -> (logits[10], caches)."""
    return _forward(params, x, _PALLAS_OPS)


def forward_ref(params, x):
    """FP via the jnp oracle (vmap/grad-friendly; used by the trainer)."""
    return _forward(params, x, _REF_OPS)


# ---------------------------------------------------------------------------
# Attribution backward pass (analytic, mask-only — eqs. 3/4/5 at ReLUs).
# ---------------------------------------------------------------------------


def _backward(p, caches, g_logits, method, ops):
    convT, rb, up, mvt = ops["convT"], ops["relu_bwd"], ops["unpool"], ops["vmm_t"]

    g = mvt(p["fc2_w"], g_logits)  # [128]
    g = rb(caches["m5"], g, method)
    g = mvt(p["fc1_w"], g)  # [4096]
    g = g.reshape(64, 8, 8)

    g = up(g, caches["i2"])  # [64,16,16]
    g = rb(caches["m4"], g, method)
    g = convT(g, p["conv4_w"])  # [64,16,16]
    g = rb(caches["m3"], g, method)
    g = convT(g, p["conv3_w"])  # [32,16,16]

    g = up(g, caches["i1"])  # [32,32,32]
    g = rb(caches["m2"], g, method)
    g = convT(g, p["conv2_w"])  # [32,32,32]
    g = rb(caches["m1"], g, method)
    g = convT(g, p["conv1_w"])  # [3,32,32]
    return g


_PALLAS_BWD = {
    "convT": kconv.conv2d_input_grad,
    "relu_bwd": lambda m, g, meth: krelu.relu_bwd(m, g, method=meth),
    "unpool": kpool.unpool2x2,
    "vmm_t": kvmm.vmm_t,
}
_REF_BWD = {
    "convT": ref.conv2d_input_grad,
    "relu_bwd": lambda m, g, meth: ref.RELU_BWD[meth](m, g),
    "unpool": ref.unpool2x2,
    "vmm_t": ref.vmm_t,
}


def _attribute(p, x, method, fwd, bwd_ops, target=None):
    logits, caches = fwd(p, x)
    # Paper §III-F: BP starts from the max output value (predicted class)
    # unless an explicit target class is requested.
    cls = jnp.argmax(logits) if target is None else target
    g_logits = jax.nn.one_hot(cls, logits.shape[0], dtype=logits.dtype)
    rel = _backward(p, caches, g_logits, method, bwd_ops)
    return logits, rel


def attribute(params, x, method, target=None):
    """FP + BP via Pallas kernels -> (logits[10], relevance[3,32,32])."""
    assert method in METHODS, method
    return _attribute(params, x, method, forward, _PALLAS_BWD, target)


def attribute_ref(params, x, method, target=None):
    """FP + BP via the jnp oracle."""
    assert method in METHODS, method
    return _attribute(params, x, method, forward_ref, _REF_BWD, target)


def saliency_autodiff(params, x, target=None):
    """Autodiff ground truth for the *saliency* method: R = ∂f_c/∂x.

    Eq. 3's analytic BP must equal jax.grad exactly (up to float assoc.);
    this is the strongest end-to-end correctness oracle we have and is
    asserted in pytest. (deconvnet/guided are *not* gradients of any
    scalar — no autodiff twin exists for them by construction.)
    """

    def f(xx):
        logits, _ = forward_ref(params, xx)
        cls = jnp.argmax(logits) if target is None else target
        return logits[cls]

    return jax.grad(f)(x)


# ---------------------------------------------------------------------------
# Mask memory accounting (paper Table II + §V) — mirrored in rust
# (rust/src/attribution/memory.rs; the two are cross-checked in tests).
#
# §V's 24.7 Kb counts what must be *stored on-chip*: the 2-bit pool
# argmax masks (24,576 b) and the 128-entry FC ReLU mask (128 b) =
# 24,704 b ≈ 24.7 Kb. Conv-layer ReLU masks are FREE: the post-ReLU
# activation is written to DRAM anyway (it is the next layer's input),
# and mask == (activation > 0); for the pre-pool ReLUs the pooled max
# value in DRAM recovers the mask at the only positions unpooling can
# route gradient to. The 3.4 Mb framework figure is every intermediate
# activation cached at 32-bit (110,720 elems × 32 b = 3.54e6 b ≈
# 3.38 Mib), giving the ≈137× reduction.
# ---------------------------------------------------------------------------

CONV_RELU_MASK_BITS = 32 * 32 * 32 + 32 * 32 * 32 + 64 * 16 * 16 + 64 * 16 * 16
FC_RELU_MASK_BITS = 128
POOL_MASK_BITS = 2 * (32 * 16 * 16) + 2 * (64 * 8 * 8)


def mask_bits_onchip(method):
    """Bits of on-chip mask storage (paper §V accounting)."""
    bits = POOL_MASK_BITS  # every method routes gradients through unpool
    if method in ("saliency", "guided"):
        bits += FC_RELU_MASK_BITS  # conv ReLU masks recomputed from DRAM
    return bits


def mask_bits_conceptual(method):
    """Bits if every mask were materialized (Table II's yes/no rows)."""
    bits = POOL_MASK_BITS
    if method in ("saliency", "guided"):
        bits += CONV_RELU_MASK_BITS + FC_RELU_MASK_BITS
    return bits


def autodiff_cache_bits(precision_bits=32):
    """What a framework would cache: every intermediate activation (§V)."""
    elems = (
        32 * 32 * 32  # conv1 out
        + 32 * 32 * 32  # conv2 out
        + 32 * 16 * 16  # pool1 out
        + 64 * 16 * 16  # conv3 out
        + 64 * 16 * 16  # conv4 out
        + 64 * 8 * 8  # pool2 out
        + 128  # fc1 out
    )
    return elems * precision_bits
