"""Max-pool 2x2/stride-2 with argmax capture, and unpool gradient routing
(paper §III-D, Fig. 5).

FP: the pooling is "absorbed into the output store" of the preceding
layer — we model that as a fused kernel producing both the pooled tile
and the 2-bit argmax index mask kept on-chip.

BP: the unpool kernel routes each gradient value to the cached argmax
position within its 2x2 window, zeros elsewhere.

Tiled over channels; each kernel invocation handles one channel block's
full spatial extent (spatial dims are small on 32x32-class inputs).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _windows(x):
    c, h, w = x.shape
    return x.reshape(c, h // 2, 2, w // 2, 2).transpose(0, 1, 3, 2, 4).reshape(
        c, h // 2, w // 2, 4
    )


def _maxpool_kernel(x_ref, y_ref, i_ref):
    win = _windows(x_ref[...])
    y_ref[...] = jnp.max(win, axis=-1)
    i_ref[...] = jnp.argmax(win, axis=-1).astype(jnp.int8)


def _unpool_kernel(g_ref, i_ref, o_ref):
    g = g_ref[...]
    c, ho, wo = g.shape
    onehot = (i_ref[...][..., None] == jnp.arange(4, dtype=jnp.int8)).astype(g.dtype)
    win = onehot * g[..., None]
    o_ref[...] = win.reshape(c, ho, wo, 2, 2).transpose(0, 1, 3, 2, 4).reshape(
        c, 2 * ho, 2 * wo
    )


def _blk(n, want=8):
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


@jax.jit
def maxpool2x2(x):
    """[C,H,W] -> ([C,H/2,W/2] pooled, [C,H/2,W/2] int8 argmax index)."""
    c, h, w = x.shape
    assert h % 2 == 0 and w % 2 == 0, "max-pool needs even spatial dims"
    blk = _blk(c)
    out_shape = (c, h // 2, w // 2)
    return pl.pallas_call(
        _maxpool_kernel,
        grid=(c // blk,),
        in_specs=[pl.BlockSpec((blk, h, w), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((blk, h // 2, w // 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((blk, h // 2, w // 2), lambda i: (i, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(out_shape, x.dtype),
            jax.ShapeDtypeStruct(out_shape, jnp.int8),
        ),
        interpret=True,
    )(x)


@jax.jit
def unpool2x2(g, idx):
    """Route [C,Ho,Wo] gradients to [C,2Ho,2Wo] via the 2-bit index mask."""
    c, ho, wo = g.shape
    blk = _blk(c)
    return pl.pallas_call(
        _unpool_kernel,
        grid=(c // blk,),
        in_specs=[
            pl.BlockSpec((blk, ho, wo), lambda i: (i, 0, 0)),
            pl.BlockSpec((blk, ho, wo), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, 2 * ho, 2 * wo), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 2 * ho, 2 * wo), g.dtype),
        interpret=True,
    )(g, idx)
