"""Fixed-point quantization kernel (paper §IV: 16-bit fixed-point datapath).

Models one pass through the Q-format datapath: scale by 2^frac, round to
nearest, saturate to the signed word range, descale. The golden float
path inserts this after every layer when emulating the accelerator's
numerics; the bit-exact integer path lives in the rust simulator
(rust/src/fx/).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, o_ref, *, scale, lo, hi):
    x = x_ref[...]
    o_ref[...] = jnp.clip(jnp.round(x * scale), lo, hi) * (1.0 / scale)


def _blk(n, want=8):
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("word_bits", "frac_bits"))
def quantize_fx(x, *, word_bits=16, frac_bits=9):
    """Quantize-dequantize through a signed Q(word-frac-1).frac format."""
    scale = float(2**frac_bits)
    lo = float(-(2 ** (word_bits - 1)))
    hi = float(2 ** (word_bits - 1) - 1)
    c = x.shape[0]
    blk = _blk(c)
    rest = x.shape[1:]
    spec = pl.BlockSpec((blk, *rest), lambda i: (i,) + (0,) * len(rest))
    return pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale, lo=lo, hi=hi),
        grid=(c // blk,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
