"""Layer-1 Pallas kernel library (all interpret=True, CPU-PJRT runnable).

Kernels mirror the paper's HLS compute blocks:
  conv2d   — tiled output-stationary convolution + flipped-transpose BP
  vmm      — tiled vector-matrix product + transpose-load BP
  relu     — fused ReLU + 1-bit mask; 3 attribution backward dataflows
  pool     — max-pool 2x2 with 2-bit argmax mask; unpool gradient routing
  quant    — Q-format quantize/dequantize emulation

`ref` holds the pure-jnp oracles each kernel is tested against.
"""

from . import conv2d, pool, quant, ref, relu, vmm  # noqa: F401
