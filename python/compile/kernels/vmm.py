"""Tiled vector-matrix product as a Pallas kernel (paper §III-C).

The FC layers are a VMM during FP and a matrix-vector product (Wᵀ·g)
during BP. The paper reuses one compute block for both by loading the
weight buffer "in a transpose manner" from DRAM (§III-E); here the same
kernel body serves both phases and only the weight ``BlockSpec``
``index_map`` (plus an in-tile transpose) changes — the load pattern,
not the datapath.

Output-stationary accumulation over input blocks, as in the conv kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmm_kernel(w_ref, x_ref, o_ref, *, transpose):
    """One (out-block, in-block) grid step: o += W_blk · x_blk.

    transpose=False : w_ref is [OUT_BLK, IN_BLK]      (FP load)
    transpose=True  : w_ref is [IN_BLK, OUT_BLK]      (BP transpose load)
    """
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...]
    if transpose:
        w = w.T
    o_ref[...] += jnp.dot(w, x_ref[...], preferred_element_type=o_ref.dtype)


def _pick_block(n, want):
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("out_blk", "in_blk"))
def vmm(w, x, *, out_blk=32, in_blk=256):
    """FC forward: y = W·x. w:[OUT,IN], x:[IN] -> [OUT]."""
    out_n, in_n = w.shape
    out_blk = _pick_block(out_n, out_blk)
    in_blk = _pick_block(in_n, in_blk)
    grid = (out_n // out_blk, in_n // in_blk)
    return pl.pallas_call(
        functools.partial(_vmm_kernel, transpose=False),
        grid=grid,
        in_specs=[
            pl.BlockSpec((out_blk, in_blk), lambda o, i: (o, i)),
            pl.BlockSpec((in_blk,), lambda o, i: (i,)),
        ],
        out_specs=pl.BlockSpec((out_blk,), lambda o, i: (o,)),
        out_shape=jax.ShapeDtypeStruct((out_n,), x.dtype),
        interpret=True,
    )(w, x)


@functools.partial(jax.jit, static_argnames=("out_blk", "in_blk"))
def vmm_t(w, g, *, out_blk=256, in_blk=32):
    """FC backward: gx = Wᵀ·g. w:[OUT,IN], g:[OUT] -> [IN].

    Same kernel body; the weight BlockSpec walks the matrix transposed
    (index_map swaps block coordinates), reproducing the paper's
    transpose-manner DRAM load into the same on-chip buffer.
    """
    out_n, in_n = w.shape
    # 'out' of this product is IN of the layer; reduction runs over OUT.
    o_blk = _pick_block(in_n, out_blk)
    r_blk = _pick_block(out_n, in_blk)
    grid = (in_n // o_blk, out_n // r_blk)
    return pl.pallas_call(
        functools.partial(_vmm_kernel, transpose=True),
        grid=grid,
        in_specs=[
            # block shape [r_blk, o_blk] read at (reduction, output) —
            # the transposed walk of w
            pl.BlockSpec((r_blk, o_blk), lambda o, r: (r, o)),
            pl.BlockSpec((r_blk,), lambda o, r: (r,)),
        ],
        out_specs=pl.BlockSpec((o_blk,), lambda o, r: (o,)),
        out_shape=jax.ShapeDtypeStruct((in_n,), g.dtype),
        interpret=True,
    )(w, g)
