"""Pure-jnp correctness oracles for the Pallas kernel library.

Every kernel in this package has a reference implementation here written
with stock jax.numpy / lax ops. pytest (python/tests/) sweeps shapes and
dtypes with hypothesis and asserts allclose between kernel and oracle —
this is the CORE correctness signal for Layer 1.

Conventions (paper §III, batch size = 1 throughout):
  activations  : [C, H, W]   (channel-major, like the paper's DRAM layout)
  conv weights : [O, I, KH, KW]
  fc weights   : [OUT, IN]
  relu mask    : same shape as activation, {0,1}  (paper: 1-bit BRAM mask)
  pool index   : [C, H/2, W/2], values in {0,1,2,3} (paper: 2-bit mask)
"""

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Convolution (paper §III-B) and its backprop (paper §III-E, Fig. 6)
# ---------------------------------------------------------------------------


def conv2d(x, w, *, padding=1):
    """Feedforward convolution, stride 1. x:[I,H,W] w:[O,I,KH,KW] -> [O,H',W']."""
    out = jax.lax.conv_general_dilated(
        x[None],  # [1,I,H,W]
        w,
        window_strides=(1, 1),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def flip_transpose_weights(w):
    """Paper Fig. 6: swap in/out channel dims and rotate each kernel 180°."""
    return jnp.flip(w, axis=(-2, -1)).transpose(1, 0, 2, 3)


def conv2d_input_grad(g, w, *, padding=1):
    """Gradient of conv2d w.r.t. its input: a convolution of the upstream
    gradient with the flipped-transposed kernels (paper §III-E). Valid for
    stride-1 convs as used by the paper's CNN."""
    kh = w.shape[2]
    return conv2d(g, flip_transpose_weights(w), padding=kh - 1 - padding)


# ---------------------------------------------------------------------------
# Fully connected / VMM (paper §III-C) and its backprop
# ---------------------------------------------------------------------------


def vmm(w, x, b=None):
    """FC forward: y = W·x (+ b). w:[OUT,IN] x:[IN] -> [OUT]."""
    y = w @ x
    if b is not None:
        y = y + b
    return y


def vmm_t(w, g):
    """FC input-gradient: gx = Wᵀ·g — the 'transpose-manner DRAM load'
    reuse of the VMM block (paper §III-E)."""
    return w.T @ g


# ---------------------------------------------------------------------------
# ReLU (paper §II, Fig. 4) — forward + the three attribution dataflows
# ---------------------------------------------------------------------------


def relu_fwd(x):
    """Forward ReLU and the 1-bit positivity mask stored in BRAM."""
    mask = (x > 0).astype(jnp.int8)
    return jnp.maximum(x, 0.0), mask


def relu_bwd_saliency(mask, g):
    """Eq. 3: R^L = (f^L > 0) ⊙ R^{L+1} — vanilla gradient."""
    return g * mask.astype(g.dtype)


def relu_bwd_deconvnet(mask, g):
    """Eq. 4: R^L = (R^{L+1} > 0) ⊙ R^{L+1} — ReLU applied to the gradient
    itself; the FP mask is unused (the method's memory saving)."""
    del mask
    return jnp.maximum(g, 0.0)


def relu_bwd_guided(mask, g):
    """Eq. 5: R^L = (f^L > 0) ⊙ (R^{L+1} > 0) ⊙ R^{L+1}."""
    return jnp.maximum(g, 0.0) * mask.astype(g.dtype)


RELU_BWD = {
    "saliency": relu_bwd_saliency,
    "deconvnet": relu_bwd_deconvnet,
    "guided": relu_bwd_guided,
}


# ---------------------------------------------------------------------------
# Max-pool 2x2 stride 2 (paper §III-D, Fig. 5) and unpooling
# ---------------------------------------------------------------------------


def _pool_windows(x):
    """[C,H,W] -> [C,H/2,W/2,4] window-major view (row-major within window)."""
    c, h, w = x.shape
    return x.reshape(c, h // 2, 2, w // 2, 2).transpose(0, 1, 3, 2, 4).reshape(
        c, h // 2, w // 2, 4
    )


def maxpool2x2(x):
    """Forward max-pool; returns (pooled, idx) with idx the 2-bit argmax
    position inside each 2x2 window (paper Fig. 5a)."""
    win = _pool_windows(x)
    idx = jnp.argmax(win, axis=-1).astype(jnp.int8)
    return jnp.max(win, axis=-1), idx


def unpool2x2(g, idx):
    """Backward gradient routing: place g at the cached argmax position,
    zeros elsewhere (paper Fig. 5b)."""
    c, ho, wo = g.shape
    onehot = jax.nn.one_hot(idx, 4, dtype=g.dtype)  # [C,Ho,Wo,4]
    win = onehot * g[..., None]
    return win.reshape(c, ho, wo, 2, 2).transpose(0, 1, 3, 2, 4).reshape(
        c, 2 * ho, 2 * wo
    )


# ---------------------------------------------------------------------------
# Fixed-point quantization (paper §IV: 16-bit fixed point datapath)
# ---------------------------------------------------------------------------


def quantize_fx(x, *, word_bits=16, frac_bits=9):
    """Round-to-nearest, saturate to the signed word range, return the
    dequantized float value — models one pass through the Q-format
    datapath. Default Q6.9 (+sign) matches the rust simulator."""
    scale = jnp.float32(2**frac_bits)
    lo = jnp.float32(-(2 ** (word_bits - 1)))
    hi = jnp.float32(2 ** (word_bits - 1) - 1)
    q = jnp.clip(jnp.round(x * scale), lo, hi)
    return q / scale


# ---------------------------------------------------------------------------
# Whole-layer compositions used by L2 tests
# ---------------------------------------------------------------------------


def conv_relu_fwd(x, w, b, *, padding=1):
    """Conv + bias + ReLU, returning activation and mask — the fused unit
    the scheduler treats as one 'layer' (ReLU absorbed into output store,
    paper §III-D)."""
    y = conv2d(x, w, padding=padding) + b[:, None, None]
    return relu_fwd(y)


def fc_relu_fwd(x, w, b):
    y = vmm(w, x, b)
    return relu_fwd(y)
