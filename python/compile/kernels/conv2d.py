"""Tiled output-stationary conv2d as a Pallas kernel (paper §III-B).

Hardware-adaptation notes (DESIGN.md §Hardware-Adaptation):

* The paper's on-chip input / weight / output buffers become VMEM tiles
  described by ``BlockSpec``s.
* The paper's output-stationary dataflow — accumulate an output tile in
  place while streaming input-channel tiles from DRAM — becomes a grid
  axis over input-channel blocks with ``o_ref[...] +=`` accumulation and
  a ``pl.when(ci == 0)`` zero-init, the canonical Pallas reduction idiom.
* The paper's ``N_oh × N_ow`` DSP unroll becomes the vectorized
  ``jnp.einsum`` over the whole spatial tile, which the MXU executes.
* The BP phase reuses this exact kernel: the *caller* presents the
  flipped-transposed weight view (paper Fig. 6 / Table I) — same compute
  block, different load pattern, exactly the paper's reuse story.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that
both pytest and the rust runtime can run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _conv_kernel(x_ref, w_ref, o_ref, *, kh, kw):
    """One (co-block, ci-block) grid step of the output-stationary conv.

    x_ref : [CI_BLK, H + kh - 1, W + kw - 1]  padded input tile (halo included)
    w_ref : [CO_BLK, CI_BLK, kh, kw]
    o_ref : [CO_BLK, H, W]                    accumulated in place
    """
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = o_ref.shape[1]
    w = o_ref.shape[2]
    x = x_ref[...]
    wt = w_ref[...]
    acc = jnp.zeros(o_ref.shape, dtype=o_ref.dtype)
    # The kh*kw shifted-window MACs — the loop the paper unrolls onto
    # DSP slices; here each term is a full-tile einsum onto the MXU.
    for i in range(kh):
        for j in range(kw):
            acc += jnp.einsum(
                "oc,chw->ohw",
                wt[:, :, i, j],
                jax.lax.dynamic_slice(x, (0, i, j), (x.shape[0], h, w)),
                preferred_element_type=o_ref.dtype,
            )
    o_ref[...] += acc


def _pick_block(n, want):
    """Largest divisor of n that is <= want (block sizes must tile exactly)."""
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("padding", "co_blk", "ci_blk"))
def conv2d(x, w, *, padding=1, co_blk=16, ci_blk=16):
    """Stride-1 'same'-style convolution. x:[I,H,W], w:[O,I,KH,KW].

    Grid = (O/co_blk, I/ci_blk); ci is the innermost (reduction) axis so
    revisits of each output block are consecutive — required for the
    in-place accumulation to be well-defined.
    """
    i_ch, h, wd = x.shape
    o_ch, i_ch2, kh, kw = w.shape
    assert i_ch == i_ch2, f"channel mismatch {i_ch} vs {i_ch2}"
    co_blk = _pick_block(o_ch, co_blk)
    ci_blk = _pick_block(i_ch, ci_blk)

    # Halo handling: pad once at the DRAM->VMEM boundary (the paper's
    # line-buffer load does the same job on the FPGA).
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding)))
    oh = h + 2 * padding - kh + 1
    ow = wd + 2 * padding - kw + 1

    grid = (o_ch // co_blk, i_ch // ci_blk)
    return pl.pallas_call(
        functools.partial(_conv_kernel, kh=kh, kw=kw),
        grid=grid,
        in_specs=[
            # input tile: all spatial rows, one ci block (spatial dims are
            # small at 32x32; channel tiling is where VMEM pressure lives)
            pl.BlockSpec(
                (ci_blk, oh + kh - 1, ow + kw - 1), lambda co, ci: (ci, 0, 0)
            ),
            pl.BlockSpec((co_blk, ci_blk, kh, kw), lambda co, ci: (co, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((co_blk, oh, ow), lambda co, ci: (co, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((o_ch, oh, ow), x.dtype),
        interpret=True,
    )(xp, w)


def conv2d_input_grad(g, w, *, padding=1, co_blk=16, ci_blk=16):
    """BP conv: same kernel, flipped-transposed weight view (paper Fig. 6).

    The transform happens at load time (index manipulation), not in the
    compute block — mirroring the paper's modified DRAM access pattern.
    """
    kh = w.shape[2]
    wt = ref.flip_transpose_weights(w)
    return conv2d(g, wt, padding=kh - 1 - padding, co_blk=co_blk, ci_blk=ci_blk)
