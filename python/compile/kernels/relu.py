"""ReLU forward + the three attribution backward dataflows (paper Fig. 4).

The forward kernel produces the activation AND the 1-bit positivity mask
in one pass — the paper stores this mask in BRAM during FP (§III-D) so
that BP never needs the full activation tensor. The backward kernel is
*configured at trace time* with the attribution method, mirroring the
paper's design-time configurability (§III-G):

  saliency  (eq. 3):  g · (f > 0)            — needs the FP mask
  deconvnet (eq. 4):  max(g, 0)              — mask-free
  guided    (eq. 5):  max(g, 0) · (f > 0)    — needs the FP mask

Element-wise kernels tiled over the leading (channel) axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

METHODS = ("saliency", "deconvnet", "guided")


def _relu_fwd_kernel(x_ref, y_ref, m_ref):
    x = x_ref[...]
    y_ref[...] = jnp.maximum(x, 0.0)
    m_ref[...] = (x > 0).astype(jnp.int8)


def _relu_bwd_kernel(m_ref, g_ref, o_ref, *, method):
    g = g_ref[...]
    if method == "saliency":
        o_ref[...] = g * m_ref[...].astype(g.dtype)
    elif method == "deconvnet":
        o_ref[...] = jnp.maximum(g, 0.0)
    elif method == "guided":
        o_ref[...] = jnp.maximum(g, 0.0) * m_ref[...].astype(g.dtype)
    else:  # pragma: no cover - guarded by METHODS check in wrappers
        raise ValueError(method)


def _blk(n, want=8):
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


@jax.jit
def relu_fwd(x):
    """y = max(x,0) plus the 1-bit mask, single fused pass."""
    c = x.shape[0]
    blk = _blk(c)
    rest = x.shape[1:]
    spec = pl.BlockSpec((blk, *rest), lambda i: (i,) + (0,) * len(rest))
    return pl.pallas_call(
        _relu_fwd_kernel,
        grid=(c // blk,),
        in_specs=[spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(x.shape, jnp.int8),
        ),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("method",))
def relu_bwd(mask, g, *, method):
    """Route the gradient through the ReLU per the configured method.

    ``mask`` is always passed (fixed kernel signature = fixed buffer
    allocation, as in the HLS library); deconvnet simply never reads it.
    """
    if method not in METHODS:
        raise ValueError(f"unknown attribution method {method!r}")
    c = g.shape[0]
    blk = _blk(c)
    rest = g.shape[1:]
    spec = pl.BlockSpec((blk, *rest), lambda i: (i,) + (0,) * len(rest))
    return pl.pallas_call(
        functools.partial(_relu_bwd_kernel, method=method),
        grid=(c // blk,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=True,
    )(mask, g)
