"""AOT compile path: train once, serialize weights, lower FP/BP graphs
to HLO *text* for the rust PJRT runtime.

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits into the output directory:

  weights.bin          f32-LE params, concatenated in model.PARAM_SPEC order
  manifest.json        param table (name/kind/shape/offset), network meta,
                       mask accounting, training stats, artifact list
  forward.hlo.txt      (params..., x) -> (logits,)                [pallas]
  attr_saliency.hlo.txt / attr_deconvnet.hlo.txt / attr_guided.hlo.txt
                       (params..., x) -> (logits, relevance)      [pallas]
  attr_*_ref.hlo.txt   same graphs built from the jnp oracle — the
                       XLA-fusion baseline for the kernel-vs-fused
                       ablation bench
  golden.bin           sample images + expected logits/relevance for the
                       rust integration tests (golden.json describes it)

HLO **text** is the interchange format, not `.serialize()`: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, train


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_list(params):
    return [params[name] for name, _, _ in model.PARAM_SPEC]


def _unflatten(flat):
    return {name: p for (name, _, _), p in zip(model.PARAM_SPEC, flat)}


def _lower_forward(use_ref):
    fwd = model.forward_ref if use_ref else model.forward

    def fn(*args):
        params, x = _unflatten(args[:-1]), args[-1]
        logits, _ = fwd(params, x)
        return (logits,)

    specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, _, shape in model.PARAM_SPEC
    ]
    specs.append(jax.ShapeDtypeStruct(data.IMG_SHAPE, jnp.float32))
    return jax.jit(fn).lower(*specs)


def _lower_attr(method, use_ref):
    attr = model.attribute_ref if use_ref else model.attribute

    def fn(*args):
        params, x = _unflatten(args[:-1]), args[-1]
        return attr(params, x, method)

    specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, _, shape in model.PARAM_SPEC
    ]
    specs.append(jax.ShapeDtypeStruct(data.IMG_SHAPE, jnp.float32))
    return jax.jit(fn).lower(*specs)


def write_weights(params, out_dir):
    """weights.bin + the param table for manifest.json."""
    table = []
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name, kind, shape in model.PARAM_SPEC:
            arr = np.asarray(params[name], dtype="<f4")
            assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
            f.write(arr.tobytes())
            table.append(
                {
                    "name": name,
                    "kind": kind,
                    "shape": list(shape),
                    "offset_bytes": offset,
                    "size_bytes": arr.nbytes,
                }
            )
            offset += arr.nbytes
    return table, offset


def write_golden(params, out_dir, n=6, seed=1234):
    """Sample images + ref-path expected outputs for rust integration tests."""
    rng = np.random.default_rng(seed)
    records = []
    with open(os.path.join(out_dir, "golden.bin"), "wb") as f:
        for i in range(n):
            cls = i % data.NUM_CLASSES
            img, _ = data.make_sample(cls, rng)
            x = jnp.asarray(img)
            rec = {"label": cls}
            f.write(img.astype("<f4").tobytes())
            logits = None
            for method in model.METHODS:
                lg, rel = model.attribute_ref(params, x, method)
                if logits is None:
                    logits = np.asarray(lg, dtype="<f4")
                    f.write(logits.tobytes())
                    rec["pred"] = int(np.argmax(logits))
                f.write(np.asarray(rel, dtype="<f4").tobytes())
            records.append(rec)
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(
            {
                "count": n,
                "layout": "per-record: image[3*32*32] f32le, logits[10], "
                "relevance[3*32*32] per method in manifest order",
                "methods": list(model.METHODS),
                "records": records,
            },
            f,
            indent=1,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--skip-train", action="store_true", help="random init (CI)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    if args.skip_train:
        params, test_acc, log = model.init_params(jax.random.PRNGKey(0)), 0.0, []
    else:
        params, test_acc, log = train.train(steps=args.steps)

    param_table, weight_bytes = write_weights(params, args.out)

    artifacts = {}
    jobs = [("forward", None, False)]
    for m in model.METHODS:
        jobs.append((f"attr_{m}", m, False))
        jobs.append((f"attr_{m}_ref", m, True))
    for name, method, use_ref in jobs:
        t = time.time()
        lowered = (
            _lower_forward(use_ref)
            if method is None
            else _lower_attr(method, use_ref)
        )
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        artifacts[name] = fname
        print(f"[aot] {fname}: {len(text)} chars ({time.time() - t:.1f}s)")

    write_golden(params, args.out)

    manifest = {
        "name": "attrax",
        "network": "table3-cnn",
        "num_classes": data.NUM_CLASSES,
        "img_shape": list(data.IMG_SHAPE),
        "class_names": list(data.CLASS_NAMES),
        "methods": list(model.METHODS),
        "param_count": model.param_count(),
        "weight_bytes": weight_bytes,
        "params": param_table,
        "artifacts": artifacts,
        "test_accuracy": round(float(test_acc), 4),
        "train_log": [[int(s), float(l), float(a)] for s, l, a in log],
        "mask_bits_onchip": {m: model.mask_bits_onchip(m) for m in model.METHODS},
        "mask_bits_conceptual": {
            m: model.mask_bits_conceptual(m) for m in model.METHODS
        },
        "autodiff_cache_bits": model.autodiff_cache_bits(),
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"[aot] done in {time.time() - t0:.1f}s — test_acc={test_acc:.4f}, "
        f"{len(jobs)} HLO artifacts, {weight_bytes} weight bytes"
    )


if __name__ == "__main__":
    main()
