"""shapes-32: the synthetic stand-in for CIFAR-10 (DESIGN.md §1).

The sandbox has no network access, so the paper's CIFAR-10 workload is
replaced by a procedurally generated 10-class dataset of 32x32 RGB
images. Tensor shapes, layer dims and the Table-III network are
untouched. Beyond availability, shapes-32 has a property CIFAR lacks:
every sample carries a ground-truth *salient-region mask* (the drawn
shape's pixels), so attribution heatmaps can be scored quantitatively
(localization mass, EXPERIMENTS.md E12) instead of only eyeballed.

Classes:
  0 circle      1 square      2 triangle    3 h-stripes   4 v-stripes
  5 diagonal    6 cross       7 ring        8 checker     9 dot-grid

Each image: noisy background + one shape drawn in a random saturated
color at a random position/scale. The same spec is implemented in rust
(rust/src/data/) for serving-side request generation; the two need not
be bit-identical (no cross-language exactness is ever compared).
"""

import numpy as np

NUM_CLASSES = 10
IMG_SHAPE = (3, 32, 32)
CLASS_NAMES = (
    "circle",
    "square",
    "triangle",
    "h-stripes",
    "v-stripes",
    "diagonal",
    "cross",
    "ring",
    "checker",
    "dot-grid",
)


def _shape_mask(cls, rng):
    """Boolean [32,32] mask of the shape's pixels."""
    yy, xx = np.mgrid[0:32, 0:32].astype(np.float32)
    cy = rng.uniform(10, 22)
    cx = rng.uniform(10, 22)
    r = rng.uniform(5, 9)
    if cls == 0:  # circle
        return (yy - cy) ** 2 + (xx - cx) ** 2 <= r * r
    if cls == 1:  # square
        return (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
    if cls == 2:  # triangle (axis-aligned, apex up)
        h = (yy - (cy - r)) / (2 * r)  # 0 at apex .. 1 at base
        return (h >= 0) & (h <= 1) & (np.abs(xx - cx) <= h * r)
    if cls == 3:  # horizontal stripes (band-limited region)
        period = max(2, int(r) // 2)
        region = (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
        return region & ((yy.astype(np.int32) // period) % 2 == 0)
    if cls == 4:  # vertical stripes
        period = max(2, int(r) // 2)
        region = (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
        return region & ((xx.astype(np.int32) // period) % 2 == 0)
    if cls == 5:  # diagonal bar
        return (np.abs((yy - cy) - (xx - cx)) <= 2) & (np.abs(yy - cy) <= r)
    if cls == 6:  # cross
        return ((np.abs(yy - cy) <= 2) | (np.abs(xx - cx) <= 2)) & (
            (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
        )
    if cls == 7:  # ring
        d2 = (yy - cy) ** 2 + (xx - cx) ** 2
        return (d2 <= r * r) & (d2 >= (r - 2.5) ** 2)
    if cls == 8:  # checkerboard
        period = max(2, int(r) // 2)
        region = (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
        return region & (
            ((yy.astype(np.int32) // period) + (xx.astype(np.int32) // period)) % 2
            == 0
        )
    if cls == 9:  # dot grid
        period = max(3, int(r) // 2 + 1)
        region = (np.abs(yy - cy) <= r) & (np.abs(xx - cx) <= r)
        return region & (
            (yy.astype(np.int32) % period < 2) & (xx.astype(np.int32) % period < 2)
        )
    raise ValueError(cls)


def make_sample(cls, rng):
    """One (image [3,32,32] float32 in [0,1], mask [32,32] bool) pair."""
    img = rng.uniform(0.0, 0.35, size=(3, 32, 32)).astype(np.float32)
    mask = _shape_mask(cls, rng)
    color = rng.uniform(0.6, 1.0, size=3).astype(np.float32)
    color[rng.integers(0, 3)] *= rng.uniform(0.1, 0.4)  # saturate a hue
    img[:, mask] = color[:, None] + rng.normal(
        0, 0.05, size=(3, int(mask.sum()))
    ).astype(np.float32)
    return np.clip(img, 0.0, 1.0), mask


def make_dataset(n, seed=0):
    """Balanced dataset: (images [N,3,32,32], labels [N], masks [N,32,32])."""
    rng = np.random.default_rng(seed)
    images = np.empty((n, *IMG_SHAPE), np.float32)
    labels = np.empty(n, np.int32)
    masks = np.empty((n, 32, 32), bool)
    for i in range(n):
        cls = i % NUM_CLASSES
        img, m = make_sample(cls, rng)
        images[i], labels[i], masks[i] = img, cls, m
    perm = rng.permutation(n)
    return images[perm], labels[perm], masks[perm]
