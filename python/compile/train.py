"""Build-time trainer for the Table-III CNN on shapes-32.

The paper trains its CNN with PyTorch to 88% on CIFAR-10; we train the
identical architecture with JAX (hand-rolled Adam — the sandbox has no
optax) on shapes-32. Runs once inside `make artifacts`; the resulting
weights are serialized for the rust runtime and baked into nothing —
they are passed to the AOT graphs as runtime parameters so the HLO text
stays small.

Training uses the jnp-oracle forward (`model.forward_ref`) because it is
vmap-able and ~50x faster than interpret-mode Pallas; pytest separately
proves oracle == Pallas, so the trained weights are valid for both.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def _loss_fn(params, xb, yb):
    logits = jax.vmap(lambda x: model.forward_ref(params, x)[0])(xb)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, yb[:, None], axis=1).mean()
    acc = (jnp.argmax(logits, axis=1) == yb).mean()
    return nll, acc


def _adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


@functools.partial(jax.jit, static_argnames=("lr",))
def _train_step(params, opt, xb, yb, lr=1e-3):
    (loss, acc), grads = jax.value_and_grad(_loss_fn, has_aux=True)(params, xb, yb)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads
    )
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}, loss, acc


def train(
    n_train=4000,
    n_test=1000,
    batch=64,
    steps=400,
    seed=0,
    log_every=150,
    verbose=True,
):
    """Train and return (params, test_accuracy, loss_log)."""
    xs, ys, _ = data.make_dataset(n_train, seed=seed)
    xt, yt, _ = data.make_dataset(n_test, seed=seed + 1)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)

    params = model.init_params(jax.random.PRNGKey(seed))
    opt = _adam_init(params)
    rng = np.random.default_rng(seed)
    log = []
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, n_train, size=batch)
        params, opt, loss, acc = _train_step(params, opt, xs[idx], ys[idx])
        if verbose and (step % log_every == 0 or step == steps - 1):
            log.append((step, float(loss), float(acc)))
            print(
                f"[train] step {step:5d}  loss {float(loss):.4f}  "
                f"batch-acc {float(acc):.3f}  ({time.time() - t0:.1f}s)"
            )

    # test accuracy in batches
    correct = 0
    for i in range(0, n_test, 250):
        logits = jax.vmap(lambda x: model.forward_ref(params, x)[0])(
            xt[i : i + 250]
        )
        correct += int((jnp.argmax(logits, axis=1) == yt[i : i + 250]).sum())
    test_acc = correct / n_test
    if verbose:
        print(f"[train] test accuracy {test_acc:.4f} on {n_test} samples")
    return params, test_acc, log
