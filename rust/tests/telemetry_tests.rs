//! End-to-end telemetry tests: a live TCP server publishing into a
//! shared [`Registry`], scraped over the one-shot stats endpoint.
//!
//! The acceptance contract under test (ISSUE 9): after the client
//! quiesces, a scrape's counters reconcile EXACTLY with the final
//! coordinator `Snapshot`; the per-unit engine profiler attributes
//! forward and backward passes to every fused plan unit; span
//! sampling is a pure hash of sequence (reruns identical); and a
//! rotated capture audits segment-by-segment like a single file.
//!
//! Artifact-free: everything runs the deterministic tiny model from
//! `sched::tests_support`.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use attrax::attribution::Method;
use attrax::coordinator::{Config, Coordinator};
use attrax::hls::HwConfig;
use attrax::obs::doctor::{self, DoctorSpec};
use attrax::obs::export;
use attrax::obs::span::{CountingRecorder, Recorder};
use attrax::obs::telemetry::{splitmix64, Registry, SampledRecorder};
use attrax::obs::trace::{TraceMeta, TraceWriter};
use attrax::sched::tests_support::tiny_sim;
use attrax::serve::{loadgen, Client, Server, ServerConfig};
use attrax::util::rng::Pcg32;

/// The tiny test model's input size ([2,8,8]).
const ELEMS: usize = 128;

fn image(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..ELEMS).map(|_| rng.f32()).collect()
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("attrax_telem_{}_{name}.trace", std::process::id()))
}

/// Start a loopback server whose coordinator and serving layer share
/// one registry, with the stats endpoint on an ephemeral port.
fn start_telemetry_server(seed: u64) -> (Server, Arc<Registry>) {
    let reg = Arc::new(Registry::new());
    let coord = Coordinator::start(
        tiny_sim(seed, HwConfig::pynq_z2()),
        Config {
            workers: 1,
            max_batch: 4,
            max_wait_ms: 2,
            telemetry: Some(reg.clone()),
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let cfg = ServerConfig {
        telemetry: Some(reg.clone()),
        stats_addr: Some("127.0.0.1:0".into()),
        ..Default::default()
    };
    let server = Server::start("127.0.0.1:0", coord, cfg).unwrap();
    (server, reg)
}

fn scrape_summary(addr: &str) -> export::StatsSummary {
    let body = export::scrape(addr, Duration::from_secs(5)).unwrap();
    export::summarize(&export::parse(&body).unwrap())
}

#[test]
fn live_scrape_reconciles_with_snapshot_and_profiles_every_unit() {
    let (server, _reg) = start_telemetry_server(7);
    let stats = server.stats_addr().expect("stats endpoint bound").to_string();

    let mut c = Client::connect(server.local_addr()).unwrap();
    for (i, m) in [Method::Saliency, Method::Guided, Method::Deconvnet].into_iter().enumerate() {
        c.attribute(&image(100 + i as u64), m).unwrap();
    }
    let (a, b) = (image(110), image(111));
    assert_eq!(c.attribute_batch(&[&a, &b], Method::Guided).unwrap().len(), 2);
    drop(c); // quiesce: counters are final before the last reply byte

    let sum = scrape_summary(&stats);

    // per-unit engine profile: forward AND backward passes attributed
    // to every fused unit of the tiny plan, modeled cycles alongside
    // measured host wall time (the live Table III counterpart)
    assert!(!sum.units.is_empty(), "profiler rows must be exposed");
    for phase in ["fwd", "bwd"] {
        let rows: Vec<_> = sum.units.iter().filter(|u| u.phase == phase).collect();
        assert!(!rows.is_empty(), "missing {phase} rows");
        for u in rows {
            assert!(u.passes > 0, "unit {} {phase} never ran", u.unit);
            assert!(u.cycles > 0, "unit {} {phase} has no modeled cycles", u.unit);
        }
    }
    assert!(sum.units.iter().map(|u| u.wall_ns).sum::<u64>() > 0, "no wall time attributed");

    // span histograms landed, the scrape carries the live gauges and
    // the per-device fleet rows, and the snapshot mirror is present
    assert!(sum.stages.iter().any(|s| s.count > 0), "no stage/request histograms");
    assert!(sum.gauges.contains_key("attrax_queue_depth"));
    assert!(sum.gauges.contains_key("attrax_snapshot_completed"));
    assert!(!sum.devices.is_empty(), "fleet rows missing");
    assert!(sum.devices.iter().map(|d| d.completed).sum::<u64>() > 0);

    // quiesced reconciliation: every dual-written counter equals the
    // final Snapshot exactly — not approximately
    let snap = server.shutdown().unwrap();
    let pairs = [
        ("attrax_completed_total", snap.completed),
        ("attrax_rejected_total", snap.rejected),
        ("attrax_rejected_busy_total", snap.rejected_busy),
        ("attrax_deadline_exceeded_total", snap.deadline_exceeded),
        ("attrax_errors_total", snap.errors),
        ("attrax_retries_total", snap.retries),
        ("attrax_breaker_trips_total", snap.breaker_trips),
        ("attrax_integrity_failures_total", snap.integrity_failures),
        ("attrax_reconnects_total", snap.reconnects),
        ("attrax_conns_total", snap.total_conns),
        ("attrax_verified_total", snap.verified),
    ];
    for (name, v) in pairs {
        assert_eq!(
            sum.counters.get(name).copied(),
            Some(v as f64),
            "{name} does not reconcile with the snapshot"
        );
    }
    assert!(snap.completed >= 4, "all driven requests completed");
}

#[test]
fn stats_endpoint_dies_with_the_server() {
    let (server, _reg) = start_telemetry_server(11);
    let stats = server.stats_addr().unwrap().to_string();
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.attribute(&image(1), Method::Saliency).unwrap();
    drop(c);
    assert!(export::scrape(&stats, Duration::from_secs(5)).is_ok());
    server.shutdown().unwrap();
    assert!(
        export::scrape(&stats, Duration::from_millis(200)).is_err(),
        "endpoint must not outlive the server"
    );
}

#[test]
fn live_sampling_is_deterministic_and_registry_counts_the_rest() {
    let n = 8u64;
    // one client, serial requests: the recorder sees sequence 0..n in
    // order, so the keep set is a pure function of splitmix64
    let expected_kept = (0..n).filter(|&i| splitmix64(i) % 2 == 0).count() as u64;
    let run = || {
        let reg = Arc::new(Registry::new());
        let inner = Arc::new(CountingRecorder::default());
        let coord = Coordinator::start(
            tiny_sim(3, HwConfig::pynq_z2()),
            Config { workers: 1, ..Default::default() },
            None,
        )
        .unwrap();
        let cfg = ServerConfig {
            recorder: Some(Arc::new(SampledRecorder::new(
                inner.clone() as Arc<dyn Recorder>,
                2,
                Some(reg.clone()),
            )) as Arc<dyn Recorder>),
            ..Default::default()
        };
        let server = Server::start("127.0.0.1:0", coord, cfg).unwrap();
        let mut c = Client::connect(server.local_addr()).unwrap();
        for i in 0..n {
            c.attribute(&image(i), Method::Saliency).unwrap();
        }
        drop(c);
        server.shutdown().unwrap();
        (inner.seen.load(Ordering::Relaxed) as u64, reg.spans_sampled_out.get())
    };
    let (kept, dropped) = run();
    assert_eq!(kept, expected_kept);
    assert_eq!(kept + dropped, n, "every span kept or counted out");
    assert_eq!(run(), (kept, dropped), "reruns sample identically");
}

#[test]
fn rotated_live_capture_audits_segment_by_segment() {
    let base = tmp("rotating");
    let meta = TraceMeta {
        board: "pynq-z2".into(),
        model: "tiny-test".into(),
        weights: "synthetic:5".into(),
        config: "custom".into(),
        elems: ELEMS,
        out_n: 4,
        workers: 1,
        max_batch: 4,
        max_wait_ms: 2,
    };
    // tiny cap: every record (frames + span, ~KB) exceeds it, so each
    // span lands in its own self-contained segment
    let writer = Arc::new(TraceWriter::create_rotating(&base, &meta, 512).unwrap());
    let coord = Coordinator::start(
        tiny_sim(5, HwConfig::pynq_z2()),
        Config { workers: 1, max_batch: 4, max_wait_ms: 2, ..Default::default() },
        None,
    )
    .unwrap();
    let cfg =
        ServerConfig { recorder: Some(writer.clone() as Arc<dyn Recorder>), ..Default::default() };
    let server = Server::start("127.0.0.1:0", coord, cfg).unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    for (i, m) in [Method::Saliency, Method::Guided, Method::Deconvnet, Method::Saliency]
        .into_iter()
        .enumerate()
    {
        c.attribute(&image(200 + i as u64), m).unwrap();
    }
    drop(c);
    server.shutdown().unwrap();
    assert_eq!(writer.finish(), Ok(4));
    assert!(writer.segments() > 1, "cap of 512 B must force rotation");
    let paths = writer.segment_paths();

    // the segment list audits as one capture, byte-identically on rerun
    let a = doctor::diagnose_segments(&paths, &DoctorSpec::default()).unwrap();
    let b = doctor::diagnose_segments(&paths, &DoctorSpec::default()).unwrap();
    assert_eq!(a.frames, 4, "doctor sees every frame across segments");
    assert_eq!(a.outcomes.get("ok"), Some(&4));
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());

    for p in &paths {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn loadgen_scrape_attaches_monotone_server_stats() {
    let (server, _reg) = start_telemetry_server(13);
    let spec = loadgen::Spec {
        addr: server.local_addr().to_string(),
        conns: 1,
        requests: 6,
        secs: 30.0,
        rps: 0.0,
        batch: 1,
        elems: ELEMS,
        method: None,
        timeout_ms: 5000,
        seed: 1,
        trace: None,
        stats_addr: server.stats_addr().map(|a| a.to_string()),
        class_mix: Vec::new(),
    };
    let report = loadgen::run(&spec).unwrap();
    assert_eq!(report.ok, 6);
    let ss = report.server_stats.as_ref().expect("--stats-addr attaches server stats");
    assert!(ss.monotone, "counters can only grow between the two scrapes");
    assert!(ss.reconciled.is_none(), "reconciliation is the CLI's job (needs the snapshot)");
    assert!(ss.summary.counters.get("attrax_completed_total").copied().unwrap_or(0.0) >= 6.0);
    assert!(!ss.summary.units.is_empty(), "server-side unit breakdown rides in the report");
    let json = report.to_json(&spec).to_string();
    assert!(json.contains("\"monotone\":true"), "{json}");
    assert!(json.contains("\"server_stats\":"), "{json}");
    server.shutdown().unwrap();
}
