//! End-to-end networked serving tests, fully offline: synthetic
//! `sched::tests_support::tiny_sim` weights, loopback TCP, no
//! artifacts. Cover: wire-level numeric equality with the in-process
//! path, concurrent connections, Busy shedding (connection pool and
//! queue), per-request deadlines, graceful drain, and bad-request
//! handling.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use attrax::attribution::Method;
use attrax::coordinator::{Config, Coordinator};
use attrax::hls::HwConfig;
use attrax::sched::tests_support::tiny_sim;
use attrax::sched::AttrOptions;
use attrax::serve::{Client, ClientError, ErrCode, Server, ServerConfig};
use attrax::util::rng::Pcg32;

const ELEMS: usize = 2 * 8 * 8;

fn image(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..ELEMS).map(|_| rng.f32()).collect()
}

fn start_server(sim_seed: u64, cfg: Config, scfg: ServerConfig) -> Server {
    let sim = tiny_sim(sim_seed, HwConfig::pynq_z2());
    let coord = Coordinator::start(sim, cfg, None).unwrap();
    Server::start("127.0.0.1:0", coord, scfg).unwrap()
}

#[test]
fn single_request_matches_in_process_bit_exact() {
    let srv = start_server(1, Config::default(), ServerConfig::default());
    let reference = tiny_sim(1, HwConfig::pynq_z2());
    let mut client = Client::connect(srv.local_addr()).unwrap();
    let img = image(10);
    let got = client.attribute(&img, Method::Guided).unwrap();
    let want = reference.attribute(&img, Method::Guided, AttrOptions::default());
    assert_eq!(got.pred, want.pred);
    assert_eq!(got.logits, want.logits, "logits must cross the wire bit-exactly");
    assert_eq!(got.relevance, want.relevance, "heatmap must cross the wire bit-exactly");
    assert!(got.device_cycles > 0);
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.total_conns, 1);
    assert_eq!(snap.open_conns, 0);
}

#[test]
fn batch_request_matches_in_process_bit_exact() {
    let srv = start_server(
        2,
        Config { workers: 1, max_batch: 8, max_wait_ms: 20, ..Default::default() },
        ServerConfig::default(),
    );
    let reference = tiny_sim(2, HwConfig::pynq_z2());
    let mut client = Client::connect(srv.local_addr()).unwrap();
    let imgs: Vec<Vec<f32>> = (0..6).map(|i| image(100 + i)).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let got = client.attribute_batch(&refs, Method::Saliency).unwrap();
    assert_eq!(got.len(), 6);
    for (i, (g, img)) in got.iter().zip(&imgs).enumerate() {
        let want = reference.attribute(img, Method::Saliency, AttrOptions::default());
        assert_eq!(g.pred, want.pred, "image {i}");
        assert_eq!(g.relevance, want.relevance, "image {i}: networked batch diverged");
    }
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.completed, 6);
}

#[test]
fn concurrent_connections_all_complete() {
    let srv = start_server(
        3,
        Config { workers: 4, queue_depth: 128, max_batch: 4, ..Default::default() },
        ServerConfig::default(),
    );
    let addr = srv.local_addr();
    let per_conn = 8u64;
    let conns = 6u64;
    std::thread::scope(|sc| {
        for c in 0..conns {
            sc.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for r in 0..per_conn {
                    let img = image(c * 1000 + r);
                    let method = attrax::attribution::ALL_METHODS[(r % 3) as usize];
                    let a = client.attribute(&img, method).unwrap();
                    assert_eq!(a.relevance.len(), ELEMS);
                }
            });
        }
    });
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.completed, conns * per_conn);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.total_conns, conns);
    assert_eq!(snap.open_conns, 0);
}

#[test]
fn connection_pool_sheds_busy() {
    let srv = start_server(
        4,
        Config::default(),
        ServerConfig { max_conns: 1, ..Default::default() },
    );
    // first connection occupies the only slot (a completed request
    // proves its handler thread is running)
    let mut first = Client::connect(srv.local_addr()).unwrap();
    first.attribute(&image(1), Method::Guided).unwrap();
    // the second connection must be shed — as a typed Busy frame when
    // the timing lets it through, as a reset when the kernel races us
    let mut second = Client::connect(srv.local_addr()).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // let the acceptor shed it
    match second.attribute(&image(2), Method::Guided) {
        Err(ClientError::Rejected { code: ErrCode::Busy, .. }) | Err(ClientError::Io(_)) => {}
        Err(ClientError::Proto(_)) => {}
        other => panic!("expected the second connection to be shed, got {other:?}"),
    }
    // the slot-holder still works
    first.attribute(&image(3), Method::Guided).unwrap();
    let snap = srv.shutdown().unwrap();
    assert!(snap.rejected_busy >= 1, "pool shed must be counted");
    assert_eq!(snap.completed, 2);
}

#[test]
fn queue_overload_sheds_busy_without_hanging() {
    // 1 worker that lingers 50ms filling its batch + a depth-1 queue:
    // concurrent batch-4 frames must overflow admission and get Busy
    let srv = start_server(
        5,
        Config { workers: 1, queue_depth: 1, max_batch: 4, max_wait_ms: 50, ..Default::default() },
        ServerConfig { max_conns: 16, ..Default::default() },
    );
    let addr = srv.local_addr();
    let busy = AtomicU64::new(0);
    let ok = AtomicU64::new(0);
    let deadline = Instant::now() + Duration::from_secs(20);
    std::thread::scope(|sc| {
        for c in 0..4u64 {
            let busy = &busy;
            let ok = &ok;
            sc.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let imgs: Vec<Vec<f32>> = (0..4).map(|i| image(c * 100 + i)).collect();
                let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
                for _ in 0..200 {
                    if busy.load(Ordering::Relaxed) > 0 || Instant::now() > deadline {
                        break;
                    }
                    match client.attribute_batch(&refs, Method::Deconvnet) {
                        Ok(_) => {
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ClientError::Rejected { code: ErrCode::Busy, .. }) => {
                            busy.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("unexpected failure under overload: {e}"),
                    }
                }
            });
        }
    });
    assert!(busy.load(Ordering::Relaxed) > 0, "overload never shed Busy");
    let snap = srv.shutdown().unwrap();
    assert!(snap.rejected_busy >= 1);
    assert_eq!(snap.errors, 0);
}

#[test]
fn deadline_exceeded_is_typed_and_counted() {
    // the worker lingers 500ms filling a batch, so a 100ms deadline
    // deterministically expires while the request is in flight
    let srv = start_server(
        6,
        Config { workers: 1, max_batch: 8, max_wait_ms: 500, ..Default::default() },
        ServerConfig::default(),
    );
    let mut client = Client::connect(srv.local_addr()).unwrap();
    client.set_timeout(Some(Duration::from_millis(100))).unwrap();
    match client.attribute(&image(7), Method::Guided) {
        Err(ClientError::Rejected { code: ErrCode::DeadlineExceeded, .. }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    // the connection survives a deadline miss
    client.set_timeout(None).unwrap();
    client.attribute(&image(8), Method::Guided).unwrap();
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.deadline_exceeded, 1);
}

#[test]
fn graceful_drain_answers_then_closes() {
    let srv = start_server(8, Config::default(), ServerConfig::default());
    let addr = srv.local_addr();
    let mut client = Client::connect(addr).unwrap();
    client.attribute(&image(20), Method::Saliency).unwrap();
    // drain with the client idle: the handler sends Closed and exits
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.open_conns, 0);
    // the drained connection is dead: typed Closed when the frame wins
    // the race with the socket teardown, an i/o error otherwise
    match client.attribute(&image(21), Method::Saliency) {
        Err(ClientError::Rejected { code: ErrCode::Closed, .. }) => {}
        Err(_) => {}
        Ok(_) => panic!("request served after graceful drain"),
    }
    // and the listener is gone
    assert!(Client::connect(addr).is_err(), "listener must be closed after shutdown");
}

#[test]
fn client_marks_stream_broken_and_reconnects_after_mid_frame_break() {
    use attrax::serve::proto::{read_frame, ResponseFrame};
    use attrax::serve::Frame;
    use std::io::Write;

    // hand-rolled server: the first connection answers with HALF a
    // response frame then dies mid-frame; the second serves properly.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let respond_to = |req: &Frame| -> Vec<u8> {
            let Frame::Request(q) = req else { panic!("expected a request, got {req:?}") };
            attrax::serve::proto::encode(&Frame::Response(ResponseFrame {
                id: q.id,
                n: q.n,
                elems: q.elems,
                out_n: 2,
                preds: vec![0; q.n],
                device_cycles: vec![1; q.n],
                with_crc: false,
                logits: vec![0.5; q.n * 2],
                relevance: vec![1.0; q.n * q.elems],
            }))
            .unwrap()
        };
        // conn 1: stall the response mid-frame, then kill the socket
        let (mut s, _) = listener.accept().unwrap();
        let req1 = read_frame(&mut s).unwrap().unwrap();
        let bytes = respond_to(&req1);
        s.write_all(&bytes[..bytes.len() / 2]).unwrap();
        drop(s);
        // conn 2: the client reconnected and resubmitted — same frame id
        let (mut s, _) = listener.accept().unwrap();
        let req2 = read_frame(&mut s).unwrap().unwrap();
        assert_eq!(req2, req1, "resubmit must be the identical (idempotent) frame");
        s.write_all(&respond_to(&req2)).unwrap();
    });

    let mut client = Client::connect(addr).unwrap();
    client.set_recovery(1, Duration::from_millis(1), 5);
    let a = client.attribute(&image(40), Method::Guided).unwrap();
    assert_eq!(a.relevance.len(), ELEMS);
    assert_eq!(client.reconnects(), 1, "the broken stream must trigger exactly one reconnect");
    assert!(!client.is_broken(), "the reconnected stream is live");
    server.join().unwrap();
}

#[test]
fn mid_frame_break_without_retries_fails_typed_then_next_call_reconnects() {
    use attrax::serve::proto::{read_frame, write_frame, ResponseFrame};
    use attrax::serve::Frame;
    use std::io::Write;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        // conn 1: half a frame, then die
        let (mut s, _) = listener.accept().unwrap();
        let req = read_frame(&mut s).unwrap().unwrap();
        let Frame::Request(q) = &req else { panic!() };
        let bytes = attrax::serve::proto::encode(&Frame::Response(ResponseFrame {
            id: q.id,
            n: q.n,
            elems: q.elems,
            out_n: 2,
            preds: vec![0; q.n],
            device_cycles: vec![1; q.n],
            with_crc: false,
            logits: vec![0.5; q.n * 2],
            relevance: vec![1.0; q.n * q.elems],
        }))
        .unwrap();
        s.write_all(&bytes[..bytes.len() - 3]).unwrap();
        drop(s);
        // conn 2: echo back a proper error frame so the client's second
        // call proves it reconnected (writing into the dead first
        // stream would never reach us)
        let (mut s, _) = listener.accept().unwrap();
        let req = read_frame(&mut s).unwrap().unwrap();
        let Frame::Request(q) = &req else { panic!() };
        write_frame(
            &mut s,
            &Frame::Error(attrax::serve::proto::ErrorFrame {
                id: q.id,
                code: ErrCode::Busy,
                msg: "probe".into(),
            }),
        )
        .unwrap();
    });

    let mut client = Client::connect(addr).unwrap();
    // no recovery configured: the torn stream is a hard (typed) error
    match client.attribute(&image(41), Method::Guided) {
        Err(ClientError::Proto(_)) | Err(ClientError::Io(_)) => {}
        other => panic!("expected a transport error, got {other:?}"),
    }
    assert!(client.is_broken(), "mid-frame failure must mark the stream broken");
    // next call transparently reconnects instead of writing into the
    // desynced stream
    match client.attribute(&image(42), Method::Guided) {
        Err(ClientError::Rejected { code: ErrCode::Busy, .. }) => {}
        other => panic!("expected the second connection's Busy probe, got {other:?}"),
    }
    assert_eq!(client.reconnects(), 1);
    server.join().unwrap();
}

#[test]
fn drain_under_load_answers_in_flight_and_reconciles_counts() {
    // depth-1 queue + 1 worker: at any instant at most one request is
    // executing and at most one is queued, so the drain decision for
    // every other request is deterministic (Busy before drain, Closed
    // after). Every client thread counts what it saw; the metrics
    // snapshot must reconcile exactly.
    let srv = start_server(
        12,
        Config { workers: 1, queue_depth: 1, max_batch: 1, ..Default::default() },
        ServerConfig::default(),
    );
    let addr = srv.local_addr();
    let (mut ok_total, mut busy_total) = (0u64, 0u64);
    let mut refused_total = 0u64;
    let snap = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..3u64)
            .map(|c| {
                sc.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let (mut ok, mut busy, mut refused) = (0u64, 0u64, 0u64);
                    loop {
                        match client.attribute(&image(500 + c), Method::Saliency) {
                            Ok(a) => {
                                assert_eq!(a.relevance.len(), ELEMS, "drained reply is complete");
                                ok += 1;
                            }
                            Err(ClientError::Rejected { code: ErrCode::Busy, .. }) => busy += 1,
                            Err(ClientError::Rejected { code: ErrCode::Closed, .. }) => {
                                refused += 1;
                                break;
                            }
                            // socket torn down mid-drain: also a clean end
                            Err(_) => break,
                        }
                    }
                    (ok, busy, refused)
                })
            })
            .collect();
        // shut down while all three connections are mid-burst
        std::thread::sleep(Duration::from_millis(150));
        let snap = srv.shutdown().unwrap();
        for h in handles {
            let (ok, busy, refused) = h.join().unwrap();
            ok_total += ok;
            busy_total += busy;
            refused_total += refused;
        }
        snap
    });
    assert!(ok_total > 0, "the burst must complete some requests before the drain");
    assert_eq!(
        snap.completed, ok_total,
        "every response the clients saw is counted exactly once — nothing in flight was dropped"
    );
    assert_eq!(
        snap.rejected_busy, busy_total,
        "the shed/answered split must reconcile with the snapshot"
    );
    assert_eq!(snap.open_conns, 0);
    let _ = refused_total; // Closed refusals race socket teardown; either end is clean
}

#[test]
fn bad_request_keeps_connection_alive() {
    let srv = start_server(9, Config::default(), ServerConfig::default());
    let mut client = Client::connect(srv.local_addr()).unwrap();
    // wrong image size: typed BadRequest, stream stays framed
    let small = vec![0.5f32; 64];
    match client.attribute(&small, Method::Guided) {
        Err(ClientError::Rejected { code: ErrCode::BadRequest, .. }) => {}
        other => panic!("expected BadRequest, got {other:?}"),
    }
    // the same connection still serves well-formed requests
    let a = client.attribute(&image(30), Method::Guided).unwrap();
    assert_eq!(a.relevance.len(), ELEMS);
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.completed, 1);
}

#[test]
fn garbage_bytes_get_typed_error_then_disconnect() {
    use std::io::Write;
    let srv = start_server(11, Config::default(), ServerConfig::default());
    let mut raw = std::net::TcpStream::connect(srv.local_addr()).unwrap();
    // exactly one preamble's worth of garbage, so the server has no
    // unread bytes when it drops the connection (clean FIN, no RST)
    raw.write_all(&[0xffu8; 12]).unwrap();
    raw.flush().unwrap();
    // server answers BadRequest (bad magic), then drops the connection
    match attrax::serve::proto::read_frame(&mut raw) {
        Ok(Some(attrax::serve::Frame::Error(e))) => {
            assert_eq!(e.code, ErrCode::BadRequest);
        }
        other => panic!("expected a BadRequest frame, got {other:?}"),
    }
    match attrax::serve::proto::read_frame(&mut raw) {
        Ok(None) | Err(_) => {} // disconnected
        Ok(Some(f)) => panic!("expected EOF after a framing error, got {f:?}"),
    }
    srv.shutdown().unwrap();
}
