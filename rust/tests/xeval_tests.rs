//! End-to-end tests of the xeval subsystem (ISSUE-5): artifact
//! determinism, the identity/self-check and sanity acceptance gates on
//! a structured mid-size model, and the fidelity-vs-precision ordering
//! the whole subsystem exists to measure.
//!
//! (The CLI twin of these assertions — `attrax eval --smoke` on the
//! full Table-III network — runs in release mode from `scripts/ci.sh`;
//! here a 3×16×16 model keeps the debug-mode suite fast.)

use attrax::fx::QFormat;
use attrax::model::{Network, NetworkBuilder, Params, Shape};
use attrax::util::json::Json;
use attrax::xeval::{self, EvalSpec, XEVAL_SCHEMA};

/// A structured mid-size model: 768 input features — big enough that
/// two unrelated heatmaps decorrelate far below the sanity threshold
/// (|ρ| ~ 1/√768 ≈ 0.04), small enough for debug-mode tests.
fn mid_model(seed: u64) -> (Network, Params) {
    let net = NetworkBuilder::new(Shape::Chw(3, 16, 16))
        .conv("c1", 8, 3, 1)
        .relu()
        .conv("c2", 8, 3, 1)
        .relu()
        .maxpool2()
        .flatten()
        .fc("f1", 16)
        .relu()
        .fc("f2", 4)
        .build()
        .unwrap();
    let params = Params::synthetic(&net, seed);
    (net, params)
}

fn spec() -> EvalSpec {
    EvalSpec {
        qformats: vec![QFormat::paper16(), QFormat::new(8, 4), QFormat::new(16, 2)],
        images: 3,
        seed: 42,
        topk_frac: 0.1,
        steps: 5,
    }
}

#[test]
fn eval_is_deterministic_and_passes_its_own_gates() {
    let (net, params) = mid_model(81);
    let a = xeval::run_eval(&net, &params, &spec()).unwrap();
    let b = xeval::run_eval(&net, &params, &spec()).unwrap();
    // consecutive runs emit byte-identical artifacts
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(a.methods.len(), 3);
    for m in &a.methods {
        // ISSUE-5 acceptance: the identity comparison is exact, and
        // the raw-arithmetic identity pass (which a correlation bug
        // WOULD break, unlike the shortcut) lands within round-off
        assert_eq!(m.self_check.pearson, 1.0, "{}", m.method);
        assert_eq!(m.self_check.spearman, 1.0, "{}", m.method);
        assert_eq!(m.self_check.topk, 1.0, "{}", m.method);
        assert!((m.self_check_raw.0 - 1.0).abs() < 1e-9, "{}", m.method);
        assert!((m.self_check_raw.1 - 1.0).abs() < 1e-9, "{}", m.method);
        // ISSUE-5 acceptance: reshuffled weights decorrelate the
        // attribution below the documented threshold, for every method
        assert!(
            m.sanity.pass,
            "{}: sanity |rho| pearson={} spearman={} (threshold {})",
            m.method,
            m.sanity.mean_abs_pearson,
            m.sanity.mean_abs_spearman,
            xeval::SANITY_RHO_MAX
        );
        // curves exist and are finite
        assert_eq!(m.curves.fractions.len(), 5);
        assert!(m.curves.deletion_auc.is_finite());
        assert!(m.curves.insertion_auc.is_finite());
    }
    assert!(a.all_checks_pass());
}

#[test]
fn fidelity_orders_formats_by_precision() {
    // the subsystem's raison d'être: Q16.9 tracks the oracle, a
    // 2-fraction-bit format of the same width cannot
    let (net, params) = mid_model(83);
    let r = xeval::run_eval(&net, &params, &spec()).unwrap();
    for m in &r.methods {
        let paper = &m.fidelity[0].mean;
        let coarse = &m.fidelity[2].mean;
        assert!(
            paper.pearson > coarse.pearson,
            "{}: Q16.9 rho={} vs Q16.2 rho={}",
            m.method,
            paper.pearson,
            coarse.pearson
        );
        assert!(paper.pearson > 0.8, "{}: paper-format fidelity {}", m.method, paper.pearson);
        assert!(paper.snr_db > coarse.snr_db, "{}", m.method);
        assert!(
            paper.topk >= coarse.topk,
            "{}: top-k {} vs {}",
            m.method,
            paper.topk,
            coarse.topk
        );
    }
}

#[test]
fn artifact_carries_the_schema_and_structure() {
    let (net, params) = mid_model(85);
    let text = xeval::run_eval(&net, &params, &spec()).unwrap().to_json().to_string();
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("schema").and_then(Json::as_str), Some(XEVAL_SCHEMA));
    assert_eq!(j.get("bench").and_then(Json::as_str), Some("xeval"));
    assert_eq!(j.get("images").and_then(Json::as_usize), Some(3));
    assert_eq!(j.get("qformats").and_then(Json::as_arr).map(|a| a.len()), Some(3));
    for method in ["saliency", "deconvnet", "guided"] {
        for leaf in [
            ["methods", method, "fidelity", "Q16.9"].as_slice(),
            ["methods", method, "faithfulness", "deletion_auc"].as_slice(),
            ["methods", method, "sanity", "pass"].as_slice(),
            ["methods", method, "self_check", "pearson"].as_slice(),
        ] {
            assert!(j.path(leaf).is_some(), "missing {leaf:?}");
        }
    }
    // the raw string carries the grep-able tag ci.sh checks for
    assert!(text.contains("\"schema\":\"attrax-xeval/v1\""), "schema tag not greppable");
}
