//! End-to-end serving test: trained artifacts → coordinator → workers
//! → fixed-point accelerator sim → responses, with shadow verification
//! against the PJRT golden path. The CI version of examples/xai_serve.

use attrax::attribution::Method;
use attrax::coordinator::{server, Config, Coordinator};
use attrax::fpga::{self, Board};
use attrax::model::{artifacts_dir, load_artifacts, Network};
use attrax::sched::Simulator;

fn build() -> (Simulator, attrax::model::Manifest, attrax::model::Params) {
    let (manifest, params) = load_artifacts(&artifacts_dir()).expect("make artifacts first");
    let net = Network::table3();
    let cfg = fpga::choose_config(Board::Zcu104, &net, Method::Guided);
    (Simulator::new(net, &params, cfg).unwrap(), manifest, params)
}

#[test]
fn serve_trained_model_with_verification() {
    let (sim, manifest, params) = build();
    let coord = Coordinator::start(
        sim,
        Config { workers: 4, queue_depth: 128, verify_fraction: 0.34, freq_mhz: 100.0 },
        Some((manifest, params)),
    )
    .unwrap();
    let report = server::run_load(
        &coord,
        server::LoadSpec { requests: 15, rate: 0.0, seed: 77, method: None },
    );
    assert_eq!(report.rejected, 0);
    assert_eq!(report.items.len(), 15);
    assert!(report.items.iter().all(|i| i.response.is_some()));
    // trained model should classify its own distribution near-perfectly
    assert!(report.accuracy >= 0.85, "accuracy {}", report.accuracy);
    // localization: relevance should concentrate on the drawn shape well
    // above the ~19% area baseline on average
    assert!(
        report.mean_localization > 0.10,
        "mean localization {}",
        report.mean_localization
    );
    // let the verifier drain before shutdown
    std::thread::sleep(std::time::Duration::from_millis(2000));
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 15);
    assert!(snap.verified > 0, "shadow verifier never ran");
    assert!(
        snap.mean_verify_corr > 0.97,
        "fixed-vs-golden correlation {}",
        snap.mean_verify_corr
    );
}

#[test]
fn open_loop_arrivals_respect_backpressure() {
    let (sim, _, _) = build();
    // tiny queue + 1 worker: the closed-loop flood must trip rejections
    // yet every accepted request completes
    let coord = Coordinator::start(
        sim,
        Config { workers: 1, queue_depth: 2, verify_fraction: 0.0, freq_mhz: 100.0 },
        None,
    )
    .unwrap();
    let report = server::run_load(
        &coord,
        server::LoadSpec { requests: 20, rate: 0.0, seed: 5, method: Some(Method::Deconvnet) },
    );
    let snap = coord.shutdown();
    assert_eq!(snap.completed as usize + report.rejected, 20);
    assert!(report.rejected > 0, "expected backpressure with queue_depth=2");
}
