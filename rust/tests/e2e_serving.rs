//! End-to-end serving tests.
//!
//! Two tiers:
//! * artifact-free tests (always run): coordinator lifecycle — the
//!   shutdown/queue race regression, and the micro-batched drain
//!   against the single-request path;
//! * trained-artifact tests (skip with a message when `make artifacts`
//!   hasn't been run — the offline CI environment): the full system,
//!   with shadow verification against the PJRT golden path when the
//!   `pjrt` feature is enabled.

use attrax::attribution::Method;
use attrax::coordinator::{server, Config, Coordinator, FailKind};
use attrax::fpga::{self, Board};
use attrax::hls::HwConfig;
use attrax::model::{artifacts_dir, load_artifacts, Network, NetworkBuilder, Params, Shape, Tensor};
use attrax::sched::{AttrOptions, Simulator};
use attrax::util::rng::Pcg32;
use std::collections::BTreeMap;

// -- artifact-free harness ------------------------------------------------

/// Small random full-input-size model (no trained artifacts needed).
fn tiny_sim(seed: u64) -> Simulator {
    let net = NetworkBuilder::new(Shape::Chw(3, 32, 32))
        .conv("c1", 4, 3, 1)
        .relu()
        .maxpool2()
        .flatten()
        .fc("f1", 10)
        .build()
        .unwrap();
    let mut rng = Pcg32::seeded(seed);
    let mut tensors = BTreeMap::new();
    let mut add = |name: &str, shape: Vec<usize>, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
        tensors.insert(name.to_string(), Tensor { shape, data });
    };
    add("c1_w", vec![4, 3, 3, 3], &mut rng);
    add("c1_b", vec![4], &mut rng);
    add("f1_w", vec![10, 1024], &mut rng);
    add("f1_b", vec![10], &mut rng);
    Simulator::new(net, &Params { tensors }, HwConfig::pynq_z2()).unwrap()
}

fn image(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..3 * 32 * 32).map(|_| rng.f32()).collect()
}

/// Heavier model (~6M MACs/attribution) so each request takes real
/// compute time — used by the shutdown-race test to guarantee requests
/// are still queued when `shutdown_now` fires.
fn chunky_sim(seed: u64) -> Simulator {
    let net = NetworkBuilder::new(Shape::Chw(3, 32, 32))
        .conv("c1", 16, 3, 1)
        .relu()
        .conv("c2", 16, 3, 1)
        .relu()
        .maxpool2()
        .flatten()
        .fc("f1", 10)
        .build()
        .unwrap();
    let mut rng = Pcg32::seeded(seed);
    let mut tensors = BTreeMap::new();
    let mut add = |name: &str, shape: Vec<usize>, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
        tensors.insert(name.to_string(), Tensor { shape, data });
    };
    add("c1_w", vec![16, 3, 3, 3], &mut rng);
    add("c1_b", vec![16], &mut rng);
    add("c2_w", vec![16, 16, 3, 3], &mut rng);
    add("c2_b", vec![16], &mut rng);
    add("f1_w", vec![10, 4096], &mut rng);
    add("f1_b", vec![10], &mut rng);
    Simulator::new(net, &Params { tensors }, HwConfig::pynq_z2()).unwrap()
}

/// Regression (seed bug): `Bounded::close` + worker join used to leave
/// in-flight requests with a dropped `mpsc::Sender` — a client blocked
/// on `recv()` saw a bare channel error indistinguishable from a worker
/// crash. `shutdown_now` must hand every still-queued request an
/// explicit `Closed` reply, while already-running requests complete.
#[test]
fn shutdown_with_requests_in_flight_replies_to_everyone() {
    // chunky_sim: each attribution takes milliseconds even in release,
    // and shutdown_now fires microseconds after the last submit, so the
    // single worker can have started at most a couple of the 32 requests
    // — the Closed path is exercised deterministically
    let coord = Coordinator::start(
        chunky_sim(1),
        Config { workers: 1, queue_depth: 128, ..Default::default() },
        None,
    )
    .unwrap();
    let mut rxs = Vec::new();
    for i in 0..32u64 {
        rxs.push(coord.submit_traced(image(100 + i), Method::Guided).unwrap());
    }
    let snap = coord.shutdown_now();
    let (mut completed, mut closed) = (0u64, 0u64);
    for (id, rx) in rxs {
        match rx.recv() {
            Ok(Ok(resp)) => {
                assert_eq!(resp.id, id);
                completed += 1;
            }
            Ok(Err(f)) => {
                assert_eq!(f.id, id);
                assert_eq!(f.kind, FailKind::Closed, "abortive shutdown sends Closed");
                closed += 1;
            }
            Err(e) => panic!("request {id}: reply channel dropped ({e}) — the seed race"),
        }
    }
    assert_eq!(completed + closed, 32, "every accepted request gets exactly one reply");
    assert_eq!(snap.completed, completed);
    assert!(closed > 0, "expected some pending requests at abortive shutdown");
}

/// Graceful shutdown still drains everything (no Closed replies).
#[test]
fn graceful_shutdown_drains_everything() {
    let coord = Coordinator::start(
        tiny_sim(2),
        Config { workers: 2, queue_depth: 128, ..Default::default() },
        None,
    )
    .unwrap();
    let mut rxs = Vec::new();
    for i in 0..12u64 {
        rxs.push(coord.submit_traced(image(200 + i), Method::Saliency).unwrap());
    }
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 12);
    for (_, rx) in rxs {
        assert!(rx.recv().unwrap().is_ok(), "graceful shutdown never sends Closed");
    }
}

/// Tentpole e2e: the micro-batched drain produces bit-identical
/// responses to an unbatched coordinator over the same request stream,
/// and the batch path really amortizes weight traffic (checked at the
/// simulator level).
#[test]
fn micro_batched_serving_is_bit_exact() {
    let imgs: Vec<Vec<f32>> = (0..10).map(|i| image(300 + i)).collect();

    // batched coordinator: single worker so the queue actually batches
    let coord = Coordinator::start(
        tiny_sim(3),
        Config { workers: 1, queue_depth: 64, max_batch: 4, max_wait_ms: 10, ..Default::default() },
        None,
    )
    .unwrap();
    let mut rxs = Vec::new();
    for img in &imgs {
        rxs.push(coord.submit_traced(img.clone(), Method::Deconvnet).unwrap());
    }
    let batched: Vec<_> = rxs
        .into_iter()
        .map(|(_, rx)| rx.recv().unwrap().expect("completed"))
        .collect();
    coord.shutdown();

    // reference: same model, plain single-image attribution
    let reference = tiny_sim(3);
    for (i, resp) in batched.iter().enumerate() {
        let want = reference.attribute(&imgs[i], Method::Deconvnet, AttrOptions::default());
        assert_eq!(resp.pred, want.pred, "request {i}");
        assert_eq!(resp.logits, want.logits, "request {i}");
        assert_eq!(resp.relevance, want.relevance, "request {i}: batched serving diverged");
    }

    // traffic: a batch of 4 pays the weight bytes of ONE pass
    let refs: Vec<&[f32]> = imgs[..4].iter().map(|v| v.as_slice()).collect();
    let batch = reference.attribute_batch(&refs, Method::Deconvnet, AttrOptions::default());
    let single = reference.attribute(&imgs[0], Method::Deconvnet, AttrOptions::default());
    assert_eq!(batch.fp_cost.dram_weight_bytes, single.fp_cost.dram_weight_bytes);
    assert_eq!(batch.bp_cost.dram_weight_bytes, single.bp_cost.dram_weight_bytes);
}

// -- trained-artifact tier ------------------------------------------------

fn build() -> Option<(Simulator, attrax::model::Manifest, attrax::model::Params)> {
    let (manifest, params) = match load_artifacts(&artifacts_dir()) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("SKIP: artifacts not available ({e}); run `make artifacts` to enable");
            return None;
        }
    };
    let net = Network::table3();
    let cfg = fpga::choose_config(Board::Zcu104, &net, Method::Guided);
    Some((Simulator::new(net, &params, cfg).unwrap(), manifest, params))
}

#[test]
fn serve_trained_model_with_verification() {
    let Some((sim, manifest, params)) = build() else { return };
    let coord = Coordinator::start(
        sim,
        Config {
            workers: 4,
            queue_depth: 128,
            verify_fraction: 0.34,
            freq_mhz: 100.0,
            ..Default::default()
        },
        Some((manifest, params)),
    )
    .unwrap();
    let report = server::run_load(
        &coord,
        server::LoadSpec { requests: 15, rate: 0.0, seed: 77, method: None },
    );
    assert_eq!(report.rejected, 0);
    assert_eq!(report.items.len(), 15);
    assert!(report.items.iter().all(|i| i.response.is_some()));
    // trained model should classify its own distribution near-perfectly
    assert!(report.accuracy >= 0.85, "accuracy {}", report.accuracy);
    // localization: relevance should concentrate on the drawn shape well
    // above the ~19% area baseline on average
    assert!(
        report.mean_localization > 0.10,
        "mean localization {}",
        report.mean_localization
    );
    // let the verifier drain before shutdown
    std::thread::sleep(std::time::Duration::from_millis(2000));
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 15);
    // golden-path shadow verification needs the PJRT runtime
    if cfg!(feature = "pjrt") {
        assert!(snap.verified > 0, "shadow verifier never ran");
        assert!(
            snap.mean_verify_corr > 0.97,
            "fixed-vs-golden correlation {}",
            snap.mean_verify_corr
        );
    }
}

#[test]
fn open_loop_arrivals_respect_backpressure() {
    let Some((sim, _, _)) = build() else { return };
    // tiny queue + 1 worker: the closed-loop flood must trip rejections
    // yet every accepted request completes
    let coord = Coordinator::start(
        sim,
        Config {
            workers: 1,
            queue_depth: 2,
            verify_fraction: 0.0,
            freq_mhz: 100.0,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let report = server::run_load(
        &coord,
        server::LoadSpec { requests: 20, rate: 0.0, seed: 5, method: Some(Method::Deconvnet) },
    );
    let snap = coord.shutdown();
    assert_eq!(snap.completed as usize + report.rejected, 20);
    assert!(report.rejected > 0, "expected backpressure with queue_depth=2");
}

#[test]
fn micro_batched_serving_on_trained_model() {
    let Some((sim, _, _)) = build() else { return };
    let Some((reference, _, _)) = build() else { return };
    let coord = Coordinator::start(
        sim,
        Config { workers: 2, queue_depth: 128, max_batch: 8, max_wait_ms: 5, ..Default::default() },
        None,
    )
    .unwrap();
    let samples = attrax::data::make_dataset(8, 99);
    let mut rxs = Vec::new();
    for s in &samples {
        rxs.push(coord.submit_traced(s.image.clone(), Method::Guided).unwrap());
    }
    for (i, (_, rx)) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().expect("completed");
        let want = reference.attribute(&samples[i].image, Method::Guided, AttrOptions::default());
        assert_eq!(resp.relevance, want.relevance, "request {i}");
    }
    coord.shutdown();
}
