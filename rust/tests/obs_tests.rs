//! End-to-end observability tests: capture spans from a live TCP
//! server into an `attrax-trace/v1` artifact, then (a) replay the
//! trace against a freshly built coordinator and reconcile every
//! response bitwise, and (b) audit it offline with the doctor.
//!
//! These are artifact-free: the server runs the deterministic tiny
//! model from `sched::tests_support`, so replay uses the
//! `replay_with_sim` seam rather than rebuilding from the trace meta
//! (which only knows the built-in table3 model).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use attrax::attribution::Method;
use attrax::coordinator::{Config, Coordinator};
use attrax::hls::HwConfig;
use attrax::obs::doctor::{self, DoctorSpec, DOCTOR_SCHEMA};
use attrax::obs::replay::{replay_with_sim, Timing};
use attrax::obs::span::{CountingRecorder, Recorder};
use attrax::obs::trace::{TraceMeta, TraceReader, TraceWriter};
use attrax::sched::tests_support::tiny_sim;
use attrax::serve::{Client, Server, ServerConfig};
use attrax::util::rng::Pcg32;

/// The tiny test model's input size ([2,8,8]).
const ELEMS: usize = 128;

fn image(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..ELEMS).map(|_| rng.f32()).collect()
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("attrax_obs_{}_{name}.trace", std::process::id()))
}

/// Capture meta for a tiny-model run. `model`/`config` mark the trace
/// as not-rebuildable-from-meta, which is true: replay must go through
/// the `replay_with_sim` seam.
fn meta(seed: u64) -> TraceMeta {
    TraceMeta {
        board: "pynq-z2".into(),
        model: "tiny-test".into(),
        weights: format!("synthetic:{seed}"),
        config: "custom".into(),
        elems: ELEMS,
        out_n: 4,
        workers: 1,
        max_batch: 4,
        max_wait_ms: 2,
    }
}

/// Serve `frames` request frames on a traced loopback server and
/// return the trace path.
fn capture(name: &str, seed: u64) -> std::path::PathBuf {
    let path = tmp(name);
    let writer = Arc::new(TraceWriter::create(&path, &meta(seed)).unwrap());
    let coord = Coordinator::start(
        tiny_sim(seed, HwConfig::pynq_z2()),
        Config { workers: 1, max_batch: 4, max_wait_ms: 2, ..Default::default() },
        None,
    )
    .unwrap();
    let cfg =
        ServerConfig { recorder: Some(writer.clone() as Arc<dyn Recorder>), ..Default::default() };
    let server = Server::start("127.0.0.1:0", coord, cfg).unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    // single-image frames across methods, plus one multi-image frame —
    // the replay must preserve this method/batch mix
    for (i, m) in [Method::Saliency, Method::Guided, Method::Deconvnet].into_iter().enumerate() {
        c.attribute(&image(100 + i as u64), m).unwrap();
    }
    let (a, b) = (image(110), image(111));
    let batch = c.attribute_batch(&[&a, &b], Method::Guided).unwrap();
    assert_eq!(batch.len(), 2);
    server.shutdown().unwrap();
    assert_eq!(writer.finish(), Ok(4), "one trace record per answered frame");
    path
}

#[test]
fn captured_trace_replays_bitwise_and_catches_divergence() {
    let path = capture("replay", 7);
    let p = path.to_str().unwrap();

    // spans carry real pipeline stamps end to end
    let (_, recs) = TraceReader::open(p).unwrap().read_all().unwrap();
    assert_eq!(recs.len(), 4);
    for rec in &recs {
        assert!(rec.span.total_ns() > 0);
        assert!(rec.span.batch_size >= 1, "served spans carry batch facts");
        assert_ne!(rec.span.device_index, u32::MAX);
    }

    // same seed → same weights → every response reconciles bitwise
    let report = replay_with_sim(p, tiny_sim(7, HwConfig::pynq_z2()), Timing::Asap).unwrap();
    assert_eq!(report.frames, 4);
    assert_eq!(report.matched, 4);
    assert_eq!(report.diverged, 0);
    assert!(report.ok());

    // recorded pacing replays the same frames (gaps here are tiny)
    let report = replay_with_sim(p, tiny_sim(7, HwConfig::pynq_z2()), Timing::Recorded).unwrap();
    assert!(report.ok());

    // a different seed is a different model: replay must flag it
    let report = replay_with_sim(p, tiny_sim(8, HwConfig::pynq_z2()), Timing::Asap).unwrap();
    assert!(report.diverged > 0);
    assert!(!report.ok());

    // a flipped trace byte surfaces as a typed error, not a clean pass
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 5;
    bytes[last] ^= 0x20;
    std::fs::write(&path, &bytes).unwrap();
    assert!(replay_with_sim(p, tiny_sim(7, HwConfig::pynq_z2()), Timing::Asap).is_err());

    std::fs::remove_file(&path).ok();
}

#[test]
fn doctor_audit_is_deterministic_and_schema_tagged() {
    let path = capture("doctor", 13);
    let p = path.to_str().unwrap();

    let a = doctor::diagnose(p, &DoctorSpec::default()).unwrap();
    let b = doctor::diagnose(p, &DoctorSpec::default()).unwrap();
    // byte-identical reruns: the report carries no wall-clock fields
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());

    let json = a.to_json().to_string();
    assert!(json.contains(&format!("\"schema\":\"{DOCTOR_SCHEMA}\"")), "{json}");
    assert_eq!(a.frames, 4);
    assert_eq!(a.outcomes.get("ok"), Some(&4));
    assert_eq!(a.violations(), 0, "default thresholds are report-only: {:?}", a.findings);
    assert!(a.summary().contains("4 frames audited"));

    std::fs::remove_file(&path).ok();
}

#[test]
fn recorder_sees_every_answered_frame_including_errors() {
    let rec = Arc::new(CountingRecorder::default());
    let coord = Coordinator::start(
        tiny_sim(9, HwConfig::pynq_z2()),
        Config { workers: 1, ..Default::default() },
        None,
    )
    .unwrap();
    let cfg =
        ServerConfig { recorder: Some(rec.clone() as Arc<dyn Recorder>), ..Default::default() };
    let server = Server::start("127.0.0.1:0", coord, cfg).unwrap();
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.attribute(&image(1), Method::Saliency).unwrap();
    // wrong image size: a typed BadRequest — still exactly one record
    let short = vec![0.5f32; ELEMS / 2];
    assert!(c.attribute(&short, Method::Saliency).is_err());
    server.shutdown().unwrap();
    assert_eq!(rec.seen.load(Ordering::Relaxed), 2);
}
