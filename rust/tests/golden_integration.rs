//! Integration tests against the jax-computed golden vectors
//! (artifacts/golden.bin): the cross-layer contract L1/L2 ⇄ L3.
//!
//! Requires `make artifacts`. When the artifacts directory is absent
//! (the offline CI environment — the python side cannot run there) each
//! test SKIPS by returning early, printing why; they assert for real on
//! a machine where the artifacts have been built. The PJRT legs
//! additionally require the `pjrt` cargo feature (the xla crate).
//!
//! Each test checks one leg of the triangle:
//!
//!   jax ref (golden.bin) ── PJRT executables ── rust fixed-point sim

use attrax::attribution::{Method, ALL_METHODS};
use attrax::fpga::{self, Board};
use attrax::model::{artifacts_dir, golden, load_artifacts, Network};
use attrax::runtime::Runtime;
use attrax::sched::{AttrOptions, Simulator};
use attrax::util::stats::pearson;

type Setup = (attrax::model::Manifest, attrax::model::Params, Vec<golden::GoldenRecord>);

/// Load artifacts + golden vectors, or None (skip) when not built.
fn try_setup() -> Option<Setup> {
    let dir = artifacts_dir();
    let (manifest, params) = match load_artifacts(&dir) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("SKIP: artifacts not available ({e}); run `make artifacts` to enable");
            return None;
        }
    };
    let recs = match golden::load_golden(&dir) {
        Ok(r) if !r.is_empty() => r,
        Ok(_) => {
            eprintln!("SKIP: golden.bin has no records");
            return None;
        }
        Err(e) => {
            eprintln!("SKIP: golden vectors not available ({e})");
            return None;
        }
    };
    Some((manifest, params, recs))
}

/// PJRT runtime, or None (skip) when built without the `pjrt` feature.
fn try_runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: {e}");
            None
        }
    }
}

fn table3_sim(params: &attrax::model::Params, board: Board) -> Simulator {
    let net = Network::table3();
    let cfg = fpga::choose_config(board, &net, Method::Guided);
    Simulator::new(net, params, cfg).unwrap()
}

#[test]
fn manifest_consistent_with_table3() {
    let Some((manifest, params, _)) = try_setup() else { return };
    let net = Network::table3();
    assert_eq!(manifest.param_count, net.param_count());
    assert_eq!(params.total_elems(), net.param_count());
    assert_eq!(manifest.num_classes, 10);
    assert_eq!(manifest.img_shape, vec![3, 32, 32]);
    assert_eq!(manifest.methods.len(), 3);
    // §V numbers embedded by the python side match the rust accounting
    let budget = attrax::attribution::memory::mask_budget(&net);
    for m in ALL_METHODS {
        assert_eq!(
            manifest.mask_bits_onchip[m.name()],
            budget.onchip_bits(m),
            "python/rust mask accounting diverged for {m}"
        );
    }
    assert_eq!(
        manifest.autodiff_cache_bits,
        attrax::attribution::memory::autodiff_cache_bits(&net, 32)
    );
    assert!(manifest.test_accuracy > 0.9, "trained model accuracy {}", manifest.test_accuracy);
}

#[test]
fn simulator_predictions_match_jax() {
    let Some((_, params, recs)) = try_setup() else { return };
    let sim = table3_sim(&params, Board::PynqZ2);
    for (i, rec) in recs.iter().enumerate() {
        let fp = sim.forward(&rec.image);
        assert_eq!(fp.pred, rec.pred, "record {i}: sim pred {} vs jax {}", fp.pred, rec.pred);
        // logits agree within the accumulated Q6.9 error budget of six
        // quantized layers (empirically ~0.3 worst-case on trained nets)
        for (a, b) in fp.logits.iter().zip(&rec.logits) {
            assert!((a - b).abs() < 0.8, "record {i}: logit {a} vs {b}");
        }
    }
}

#[test]
fn simulator_relevance_correlates_with_jax() {
    let Some((_, params, recs)) = try_setup() else { return };
    let sim = table3_sim(&params, Board::Zcu104);
    for rec in recs.iter().take(3) {
        for (mname, jax_rel) in &rec.relevance {
            let m = Method::parse(mname).unwrap();
            let r = sim.attribute(&rec.image, m, AttrOptions::default());
            let corr = pearson(&r.relevance, jax_rel);
            assert!(
                corr > 0.97,
                "method {m}: fixed-point vs jax correlation {corr}"
            );
        }
    }
}

#[test]
fn batched_simulator_matches_jax_and_single() {
    // the batch-N serving path against the same golden contract
    let Some((_, params, recs)) = try_setup() else { return };
    let sim = table3_sim(&params, Board::Zcu104);
    let imgs: Vec<&[f32]> = recs.iter().take(4).map(|r| r.image.as_slice()).collect();
    let batch = sim.attribute_batch(&imgs, Method::Guided, AttrOptions::default());
    for (i, (item, rec)) in batch.items.iter().zip(recs.iter()).enumerate() {
        assert_eq!(item.pred, rec.pred, "record {i}");
        let single = sim.attribute(&rec.image, Method::Guided, AttrOptions::default());
        assert_eq!(item.relevance, single.relevance, "record {i}: batch != single");
    }
}

#[test]
fn pjrt_pallas_executables_reproduce_golden() {
    let Some((manifest, params, recs)) = try_setup() else { return };
    let Some(runtime) = try_runtime() else { return };
    for m in ALL_METHODS {
        // the *pallas* artifact (tiled kernels lowered through interpret
        // mode), not the jnp ref — proves the L1 kernels themselves run
        // under the rust runtime
        let exe = runtime
            .load_artifact(&manifest, &params, &format!("attr_{}", m.name()), 2)
            .unwrap();
        for rec in recs.iter().take(2) {
            let outs = exe.run(&rec.image, &manifest.img_shape).unwrap();
            let (logits, rel) = (&outs[0], &outs[1]);
            for (a, b) in logits.iter().zip(&rec.logits) {
                assert!((a - b).abs() < 1e-3, "{m}: logit {a} vs golden {b}");
            }
            let jax_rel = &rec.relevance.iter().find(|(n, _)| n == m.name()).unwrap().1;
            for (a, b) in rel.iter().zip(jax_rel.iter()) {
                assert!((a - b).abs() < 1e-3, "{m}: relevance {a} vs golden {b}");
            }
        }
    }
}

#[test]
fn pjrt_ref_and_pallas_artifacts_agree() {
    let Some((manifest, params, recs)) = try_setup() else { return };
    let Some(runtime) = try_runtime() else { return };
    let pallas = runtime.load_artifact(&manifest, &params, "attr_guided", 2).unwrap();
    let reference = runtime.load_artifact(&manifest, &params, "attr_guided_ref", 2).unwrap();
    let rec = &recs[0];
    let a = pallas.run(&rec.image, &manifest.img_shape).unwrap();
    let b = reference.run(&rec.image, &manifest.img_shape).unwrap();
    for (x, y) in a[1].iter().zip(b[1].iter()) {
        assert!((x - y).abs() < 1e-3, "pallas {x} vs ref {y}");
    }
}

#[test]
fn forward_artifact_matches_attribution_logits() {
    let Some((manifest, params, recs)) = try_setup() else { return };
    let Some(runtime) = try_runtime() else { return };
    let fwd = runtime.load_artifact(&manifest, &params, "forward", 1).unwrap();
    let rec = &recs[0];
    let outs = fwd.run(&rec.image, &manifest.img_shape).unwrap();
    for (a, b) in outs[0].iter().zip(&rec.logits) {
        assert!((a - b).abs() < 1e-3);
    }
}

#[test]
fn all_boards_agree_functionally() {
    // hardware config changes tiling/latency, never numerics
    let Some((_, params, recs)) = try_setup() else { return };
    let rec = &recs[0];
    let base = table3_sim(&params, Board::PynqZ2)
        .attribute(&rec.image, Method::Guided, AttrOptions::default());
    for board in [Board::Ultra96V2, Board::Zcu104] {
        let r = table3_sim(&params, board)
            .attribute(&rec.image, Method::Guided, AttrOptions::default());
        assert_eq!(r.relevance, base.relevance, "board {board} diverged numerically");
        assert_eq!(r.logits, base.logits);
    }
}

#[test]
fn fused_unpool_exact_on_real_model() {
    let Some((_, params, recs)) = try_setup() else { return };
    let sim = table3_sim(&params, Board::Ultra96V2);
    let rec = &recs[1];
    let fused = sim.attribute(&rec.image, Method::Saliency, AttrOptions::default());
    let unfused = sim.attribute(
        &rec.image,
        Method::Saliency,
        AttrOptions { fused_unpool: false, ..Default::default() },
    );
    assert_eq!(fused.relevance, unfused.relevance);
    assert!(fused.bp_cost.total_cycles() < unfused.bp_cost.total_cycles());
}
