//! Adversarial-input tests for `serve::proto` decode: truncated,
//! oversized, and garbage frames must produce typed [`ProtoError`]s —
//! never a panic, and never an allocation beyond the frame caps.

use std::io::Cursor;

use attrax::attribution::Method;
use attrax::serve::proto::{
    self, encode, read_frame, ErrCode, ErrorFrame, Frame, ProtoError, RequestFrame,
    ResponseFrame, MAGIC, MAX_HEADER_BYTES, MAX_IMAGES_PER_FRAME, MAX_PAYLOAD_BYTES,
    PREAMBLE_LEN,
};
use attrax::util::prop::{run_prop, PropConfig};

fn sample_request() -> Frame {
    Frame::Request(RequestFrame {
        id: 42,
        method: Method::Saliency,
        target: None,
        n: 2,
        elems: 4,
        deadline_ms: Some(250),
        with_crc: false,
        trace_seq: None,
        slo_class: None,
        images: vec![0.0, 1.5, -2.25, 3.5, -0.125, 0.75, 8.0, -9.5],
    })
}

fn sample_response() -> Frame {
    Frame::Response(ResponseFrame {
        id: 42,
        n: 1,
        elems: 3,
        out_n: 2,
        preds: vec![1],
        device_cycles: vec![987_654],
        with_crc: false,
        logits: vec![0.25, -0.5],
        relevance: vec![1.0, 2.0, 3.0],
    })
}

fn crc_request() -> Frame {
    match sample_request() {
        Frame::Request(mut q) => {
            q.with_crc = true;
            Frame::Request(q)
        }
        _ => unreachable!(),
    }
}

#[test]
fn every_truncation_of_every_frame_kind_is_a_typed_error() {
    let frames = [
        sample_request(),
        sample_response(),
        Frame::Error(ErrorFrame { id: 1, code: ErrCode::Busy, msg: "full".into() }),
    ];
    for f in &frames {
        let bytes = encode(f).unwrap();
        // the full stream decodes back to the original
        assert_eq!(read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap(), *f);
        // zero bytes is a clean EOF, any proper prefix a typed error
        assert!(matches!(read_frame(&mut Cursor::new(&bytes[..0])), Ok(None)));
        for cut in 1..bytes.len() {
            match read_frame(&mut Cursor::new(&bytes[..cut])) {
                Err(_) => {}
                ok => panic!("{cut}-byte prefix decoded as {ok:?}"),
            }
        }
    }
}

#[test]
fn oversized_length_fields_are_capped_before_allocation() {
    // a preamble claiming a 4 GiB header/payload must be rejected from
    // the 12 fixed bytes alone — no body needed, nothing allocated
    let mut pre = [0u8; PREAMBLE_LEN];
    pre[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    pre[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
    pre[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    match read_frame(&mut Cursor::new(&pre)) {
        Err(ProtoError::TooLarge { header_len, payload_len }) => {
            assert!(header_len > MAX_HEADER_BYTES);
            assert!(payload_len > MAX_PAYLOAD_BYTES);
        }
        other => panic!("expected TooLarge, got {other:?}"),
    }
    // just-over-cap values too
    let mut pre = [0u8; PREAMBLE_LEN];
    pre[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    pre[4..8].copy_from_slice(&((MAX_HEADER_BYTES as u32) + 1).to_le_bytes());
    assert!(matches!(read_frame(&mut Cursor::new(&pre)), Err(ProtoError::TooLarge { .. })));
}

#[test]
fn oversized_request_batch_rejected() {
    let n = MAX_IMAGES_PER_FRAME + 1;
    let header = format!(r#"{{"t":"req","id":1,"method":"guided","n":{n},"elems":1}}"#);
    let payload = vec![0u8; n * 4];
    assert!(matches!(proto::decode(header.as_bytes(), &payload), Err(ProtoError::Malformed(_))));
}

#[test]
fn bad_magic_and_garbage_headers_are_typed() {
    let mut bytes = encode(&sample_request()).unwrap();
    bytes[1] = b'Q';
    assert!(matches!(read_frame(&mut Cursor::new(&bytes)), Err(ProtoError::BadMagic(_))));

    for bad_header in [
        "not json at all",
        "{}",
        r#"{"t":"nope"}"#,
        r#"{"t":"req"}"#,
        r#"{"t":"req","id":1,"method":"sorcery","n":1,"elems":4}"#,
        r#"{"t":"req","id":-3,"method":"guided","n":1,"elems":4}"#,
        r#"{"t":"req","id":1,"method":"guided","n":0,"elems":4}"#,
        r#"{"t":"req","id":1,"method":"guided","n":1,"elems":0}"#,
        r#"{"t":"err","id":1,"code":"not_a_code"}"#,
        r#"{"t":"resp","id":1,"n":1,"elems":2,"out_n":1,"preds":[0,1],"device_cycles":[1]}"#,
    ] {
        match proto::decode(bad_header.as_bytes(), &[]) {
            Err(ProtoError::Malformed(_)) => {}
            other => panic!("header {bad_header:?} decoded as {other:?}"),
        }
    }
}

#[test]
fn payload_length_must_match_header_arithmetic() {
    let header = br#"{"t":"req","id":1,"method":"guided","n":2,"elems":4}"#;
    // 2 images * 4 elems = 32 bytes; everything else is malformed
    for bad_len in [0usize, 4, 31, 33, 64] {
        let payload = vec![0u8; bad_len];
        assert!(
            matches!(proto::decode(header, &payload), Err(ProtoError::Malformed(_))),
            "payload of {bad_len} B must be rejected"
        );
    }
    let payload = vec![0u8; 32];
    assert!(proto::decode(header, &payload).is_ok());
}

#[test]
fn trailing_garbage_after_a_valid_frame_is_a_typed_error_not_a_panic() {
    // a stream with one good frame then junk: the first read succeeds,
    // the next must surface a typed error (BadMagic/Eof/Truncated),
    // never a panic or a phantom frame
    for junk in [
        &b"\x00"[..],
        &b"garbage bytes here"[..],
        &[0xff; PREAMBLE_LEN][..],
        &MAGIC.to_le_bytes()[..2], // half a preamble, then EOF
    ] {
        let mut bytes = encode(&sample_request()).unwrap();
        bytes.extend_from_slice(junk);
        let mut cur = Cursor::new(&bytes);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), sample_request());
        assert!(
            read_frame(&mut cur).is_err(),
            "trailing {junk:?} must yield a typed error, not a frame"
        );
    }
}

#[test]
fn zero_length_preamble_fields_are_rejected() {
    // header_len == 0 can never carry a valid frame type; a preamble
    // claiming it (with or without trailing payload bytes) is typed
    for payload_len in [0u32, 8] {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&payload_len.to_le_bytes());
        bytes.extend_from_slice(&[0xAB; 8]);
        assert!(
            read_frame(&mut Cursor::new(&bytes)).is_err(),
            "empty header with payload_len {payload_len} must be rejected"
        );
    }
}

#[test]
fn trace_seq_is_version_negotiated_like_crc() {
    // a tagged request round-trips through encode/decode
    let tagged = match sample_request() {
        Frame::Request(mut q) => {
            q.trace_seq = Some(777);
            Frame::Request(q)
        }
        _ => unreachable!(),
    };
    let bytes = encode(&tagged).unwrap();
    assert_eq!(read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap(), tagged);

    // an old client's frame (no trace_seq header field) decodes to None
    let plain = encode(&sample_request()).unwrap();
    match read_frame(&mut Cursor::new(&plain)).unwrap().unwrap() {
        Frame::Request(q) => assert_eq!(q.trace_seq, None),
        other => panic!("decoded as {other:?}"),
    }
    // and a tagged frame is strictly longer on the wire — the field
    // costs nothing when absent
    assert!(bytes.len() > plain.len());

    // an old *server* (this decoder, standing in for one that predates
    // the field) skips unknown header fields, so a future tag spelling
    // still decodes; explicit null means absent, like deadline_ms
    for (extra, want) in [
        (r#","trace_seq":9"#, Some(9u64)),
        (r#","trace_seq":null"#, None),
        (r#","trace_seq_v2":{"x":1}"#, None),
    ] {
        let header = format!(
            r#"{{"t":"req","id":1,"method":"guided","n":1,"elems":2{extra}}}"#
        );
        let payload = [0u8; 8];
        match proto::decode(header.as_bytes(), &payload) {
            Ok(Frame::Request(q)) => assert_eq!(q.trace_seq, want, "header {header}"),
            other => panic!("header {header} decoded as {other:?}"),
        }
    }

    // a malformed trace_seq (negative / fractional) is typed, not UB
    for bad in [r#","trace_seq":-1"#, r#","trace_seq":1.5"#, r#","trace_seq":"x""#] {
        let header = format!(r#"{{"t":"req","id":1,"method":"guided","n":1,"elems":2{bad}}}"#);
        assert!(
            matches!(proto::decode(header.as_bytes(), &[0u8; 8]), Err(ProtoError::Malformed(_))),
            "header {header} must be rejected"
        );
    }
}

#[test]
fn slo_class_is_version_negotiated_like_crc_and_trace_seq() {
    use attrax::serve::proto::MAX_SLO_CLASS_BYTES;

    // a classed request round-trips through encode/decode
    let classed = match sample_request() {
        Frame::Request(mut q) => {
            q.slo_class = Some("gold".to_string());
            Frame::Request(q)
        }
        _ => unreachable!(),
    };
    let bytes = encode(&classed).unwrap();
    assert_eq!(read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap(), classed);

    // an old client's frame (no slo_class header field) decodes to
    // None, and the field costs nothing on the wire when absent
    let plain = encode(&sample_request()).unwrap();
    match read_frame(&mut Cursor::new(&plain)).unwrap().unwrap() {
        Frame::Request(q) => assert_eq!(q.slo_class, None),
        other => panic!("decoded as {other:?}"),
    }
    assert!(bytes.len() > plain.len());

    // an old server skips unknown spellings; explicit null is absent
    for (extra, want) in [
        (r#","slo_class":"gold""#.to_string(), Some("gold".to_string())),
        (r#","slo_class":null"#.to_string(), None),
        (r#","slo_class_v2":{"x":1}"#.to_string(), None),
        // names up to the cap are carried verbatim
        (
            format!(r#","slo_class":"{}""#, "c".repeat(MAX_SLO_CLASS_BYTES)),
            Some("c".repeat(MAX_SLO_CLASS_BYTES)),
        ),
    ] {
        let header = format!(r#"{{"t":"req","id":1,"method":"guided","n":1,"elems":2{extra}}}"#);
        let payload = [0u8; 8];
        match proto::decode(header.as_bytes(), &payload) {
            Ok(Frame::Request(q)) => assert_eq!(q.slo_class, want, "header {header}"),
            other => panic!("header {header} decoded as {other:?}"),
        }
    }

    // a malformed slo_class (non-string / empty / over the cap) is
    // typed, not UB and not a silent admit
    for bad in [
        r#","slo_class":7"#.to_string(),
        r#","slo_class":[]"#.to_string(),
        r#","slo_class":"""#.to_string(),
        format!(r#","slo_class":"{}""#, "x".repeat(MAX_SLO_CLASS_BYTES + 1)),
    ] {
        let header = format!(r#"{{"t":"req","id":1,"method":"guided","n":1,"elems":2{bad}}}"#);
        assert!(
            matches!(proto::decode(header.as_bytes(), &[0u8; 8]), Err(ProtoError::Malformed(_))),
            "header {header} must be rejected"
        );
    }
}

#[test]
fn crc_protected_stream_catches_every_payload_byte_flip() {
    let clean = encode(&crc_request()).unwrap();
    assert_eq!(read_frame(&mut Cursor::new(&clean)).unwrap().unwrap(), crc_request());
    // flip each payload byte in turn: every one must surface as the
    // typed Integrity error (the payload is the trailing 32 bytes)
    let payload_start = clean.len() - 32;
    for pos in payload_start..clean.len() {
        let mut corrupt = clean.clone();
        corrupt[pos] ^= 0x10;
        match read_frame(&mut Cursor::new(&corrupt)) {
            Err(ProtoError::Integrity { expected, got }) => assert_ne!(expected, got),
            other => panic!("flipped byte {pos} decoded as {other:?}"),
        }
    }
}

#[test]
fn prop_random_bytes_never_panic_decoder() {
    // pure fuzz: random byte strings through the frame reader
    run_prop(
        PropConfig { cases: 512, ..Default::default() },
        |rng| {
            let len = rng.below(96) as usize;
            (0..len).map(|_| (rng.next_u32() & 0xff) as u8).collect::<Vec<u8>>()
        },
        |bytes| {
            // any outcome but a panic is acceptable; decoded frames can
            // only come from a valid encoding
            let _ = read_frame(&mut Cursor::new(bytes));
            Ok(())
        },
    );
}

#[test]
fn prop_valid_frame_with_flipped_byte_never_panics() {
    // mutate one byte of a valid frame: decode must stay total
    let bytes = encode(&sample_request()).unwrap();
    let blen = bytes.len();
    run_prop(
        PropConfig { cases: 512, ..Default::default() },
        |rng| {
            let pos = rng.below(blen as u32) as usize;
            let val = (rng.next_u32() & 0xff) as u8;
            (pos, val)
        },
        |&(pos, val)| {
            let mut mutated = bytes.clone();
            mutated[pos] = val;
            let _ = read_frame(&mut Cursor::new(&mutated));
            Ok(())
        },
    );
}
