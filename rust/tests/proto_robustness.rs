//! Adversarial-input tests for `serve::proto` decode: truncated,
//! oversized, and garbage frames must produce typed [`ProtoError`]s —
//! never a panic, and never an allocation beyond the frame caps.

use std::io::Cursor;

use attrax::attribution::Method;
use attrax::serve::proto::{
    self, encode, read_frame, ErrCode, ErrorFrame, Frame, ProtoError, RequestFrame,
    ResponseFrame, MAGIC, MAX_HEADER_BYTES, MAX_IMAGES_PER_FRAME, MAX_PAYLOAD_BYTES,
    PREAMBLE_LEN,
};
use attrax::util::prop::{run_prop, PropConfig};

fn sample_request() -> Frame {
    Frame::Request(RequestFrame {
        id: 42,
        method: Method::Saliency,
        target: None,
        n: 2,
        elems: 4,
        deadline_ms: Some(250),
        images: vec![0.0, 1.5, -2.25, 3.5, -0.125, 0.75, 8.0, -9.5],
    })
}

fn sample_response() -> Frame {
    Frame::Response(ResponseFrame {
        id: 42,
        n: 1,
        elems: 3,
        out_n: 2,
        preds: vec![1],
        device_cycles: vec![987_654],
        logits: vec![0.25, -0.5],
        relevance: vec![1.0, 2.0, 3.0],
    })
}

#[test]
fn every_truncation_of_every_frame_kind_is_a_typed_error() {
    let frames = [
        sample_request(),
        sample_response(),
        Frame::Error(ErrorFrame { id: 1, code: ErrCode::Busy, msg: "full".into() }),
    ];
    for f in &frames {
        let bytes = encode(f).unwrap();
        // the full stream decodes back to the original
        assert_eq!(read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap(), *f);
        // zero bytes is a clean EOF, any proper prefix a typed error
        assert!(matches!(read_frame(&mut Cursor::new(&bytes[..0])), Ok(None)));
        for cut in 1..bytes.len() {
            match read_frame(&mut Cursor::new(&bytes[..cut])) {
                Err(_) => {}
                ok => panic!("{cut}-byte prefix decoded as {ok:?}"),
            }
        }
    }
}

#[test]
fn oversized_length_fields_are_capped_before_allocation() {
    // a preamble claiming a 4 GiB header/payload must be rejected from
    // the 12 fixed bytes alone — no body needed, nothing allocated
    let mut pre = [0u8; PREAMBLE_LEN];
    pre[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    pre[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
    pre[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    match read_frame(&mut Cursor::new(&pre)) {
        Err(ProtoError::TooLarge { header_len, payload_len }) => {
            assert!(header_len > MAX_HEADER_BYTES);
            assert!(payload_len > MAX_PAYLOAD_BYTES);
        }
        other => panic!("expected TooLarge, got {other:?}"),
    }
    // just-over-cap values too
    let mut pre = [0u8; PREAMBLE_LEN];
    pre[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    pre[4..8].copy_from_slice(&((MAX_HEADER_BYTES as u32) + 1).to_le_bytes());
    assert!(matches!(read_frame(&mut Cursor::new(&pre)), Err(ProtoError::TooLarge { .. })));
}

#[test]
fn oversized_request_batch_rejected() {
    let n = MAX_IMAGES_PER_FRAME + 1;
    let header = format!(r#"{{"t":"req","id":1,"method":"guided","n":{n},"elems":1}}"#);
    let payload = vec![0u8; n * 4];
    assert!(matches!(proto::decode(header.as_bytes(), &payload), Err(ProtoError::Malformed(_))));
}

#[test]
fn bad_magic_and_garbage_headers_are_typed() {
    let mut bytes = encode(&sample_request()).unwrap();
    bytes[1] = b'Q';
    assert!(matches!(read_frame(&mut Cursor::new(&bytes)), Err(ProtoError::BadMagic(_))));

    for bad_header in [
        "not json at all",
        "{}",
        r#"{"t":"nope"}"#,
        r#"{"t":"req"}"#,
        r#"{"t":"req","id":1,"method":"sorcery","n":1,"elems":4}"#,
        r#"{"t":"req","id":-3,"method":"guided","n":1,"elems":4}"#,
        r#"{"t":"req","id":1,"method":"guided","n":0,"elems":4}"#,
        r#"{"t":"req","id":1,"method":"guided","n":1,"elems":0}"#,
        r#"{"t":"err","id":1,"code":"not_a_code"}"#,
        r#"{"t":"resp","id":1,"n":1,"elems":2,"out_n":1,"preds":[0,1],"device_cycles":[1]}"#,
    ] {
        match proto::decode(bad_header.as_bytes(), &[]) {
            Err(ProtoError::Malformed(_)) => {}
            other => panic!("header {bad_header:?} decoded as {other:?}"),
        }
    }
}

#[test]
fn payload_length_must_match_header_arithmetic() {
    let header = br#"{"t":"req","id":1,"method":"guided","n":2,"elems":4}"#;
    // 2 images * 4 elems = 32 bytes; everything else is malformed
    for bad_len in [0usize, 4, 31, 33, 64] {
        let payload = vec![0u8; bad_len];
        assert!(
            matches!(proto::decode(header, &payload), Err(ProtoError::Malformed(_))),
            "payload of {bad_len} B must be rejected"
        );
    }
    let payload = vec![0u8; 32];
    assert!(proto::decode(header, &payload).is_ok());
}

#[test]
fn prop_random_bytes_never_panic_decoder() {
    // pure fuzz: random byte strings through the frame reader
    run_prop(
        PropConfig { cases: 512, ..Default::default() },
        |rng| {
            let len = rng.below(96) as usize;
            (0..len).map(|_| (rng.next_u32() & 0xff) as u8).collect::<Vec<u8>>()
        },
        |bytes| {
            // any outcome but a panic is acceptable; decoded frames can
            // only come from a valid encoding
            let _ = read_frame(&mut Cursor::new(bytes));
            Ok(())
        },
    );
}

#[test]
fn prop_valid_frame_with_flipped_byte_never_panics() {
    // mutate one byte of a valid frame: decode must stay total
    let bytes = encode(&sample_request()).unwrap();
    let blen = bytes.len();
    run_prop(
        PropConfig { cases: 512, ..Default::default() },
        |rng| {
            let pos = rng.below(blen as u32) as usize;
            let val = (rng.next_u32() & 0xff) as u8;
            (pos, val)
        },
        |&(pos, val)| {
            let mut mutated = bytes.clone();
            mutated[pos] = val;
            let _ = read_frame(&mut Cursor::new(&mutated));
            Ok(())
        },
    );
}
