//! Allocation-count regression for the workspace-arena execution core
//! (ISSUE 2 acceptance): once a [`Workspace`]/[`BatchOutput`] pair is
//! warm, `Simulator::attribute_batch_into` must perform **zero heap
//! allocations** — every intermediate lives in a reused slab. A
//! counting global allocator (thread-local counter, so the harness's
//! other test threads don't pollute the measurement) proves it.
//!
//! The guarantee is stated for `shards = 1`: sharded runs are
//! bit-identical but pay a handful of scoped-thread spawns, which
//! allocate by nature (OS thread stacks), not per element.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::BTreeMap;

use std::sync::Arc;

use attrax::attribution::Method;
use attrax::hls::{HwConfig, Phase};
use attrax::model::{Network, NetworkBuilder, Params, Shape, Tensor};
use attrax::obs::span::{self, Span, Stage, ALL_STAGES};
use attrax::obs::telemetry::{Registry, UnitProfiler};
use attrax::sched::{AttrOptions, BatchOutput, Simulator, Workspace};
use attrax::util::rng::Pcg32;

thread_local! {
    static ALLOCS: Cell<u64> = Cell::new(0);
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

/// Tiny conv/relu/conv/relu+pool/fc/relu/fc model with random params.
fn tiny_sim(seed: u64) -> Simulator {
    let net: Network = NetworkBuilder::new(Shape::Chw(2, 8, 8))
        .conv("c1", 4, 3, 1)
        .relu()
        .conv("c2", 4, 3, 1)
        .relu()
        .maxpool2()
        .flatten()
        .fc("f1", 8)
        .relu()
        .fc("f2", 3)
        .build()
        .unwrap();
    let mut rng = Pcg32::seeded(seed);
    let mut tensors = BTreeMap::new();
    let mut add = |name: &str, shape: Vec<usize>, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let scale = (2.0 / n as f32).sqrt().max(0.05);
        let data: Vec<f32> = (0..n).map(|_| rng.normal() * scale).collect();
        tensors.insert(name.to_string(), Tensor { shape, data });
    };
    add("c1_w", vec![4, 2, 3, 3], &mut rng);
    add("c1_b", vec![4], &mut rng);
    add("c2_w", vec![4, 4, 3, 3], &mut rng);
    add("c2_b", vec![4], &mut rng);
    add("f1_w", vec![8, 64], &mut rng);
    add("f1_b", vec![8], &mut rng);
    add("f2_w", vec![3, 8], &mut rng);
    add("f2_b", vec![3], &mut rng);
    Simulator::new(net, &Params { tensors }, HwConfig::pynq_z2()).unwrap()
}

fn images(n: usize, len: usize) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(99);
    (0..n).map(|_| (0..len).map(|_| rng.f32()).collect()).collect()
}

#[test]
fn steady_state_attribute_batch_is_allocation_free() {
    let sim = tiny_sim(42);
    let imgs = images(4, 2 * 8 * 8);
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let mut ws = Workspace::with_shards(1);
    let mut out = BatchOutput::new();
    // warm-up: slabs grow to their steady-state capacities
    for _ in 0..3 {
        for m in attrax::attribution::ALL_METHODS {
            sim.attribute_batch_into(&mut ws, &refs, m, AttrOptions::default(), false, &mut out);
        }
    }
    let before = allocs_now();
    for _ in 0..5 {
        for m in attrax::attribution::ALL_METHODS {
            sim.attribute_batch_into(&mut ws, &refs, m, AttrOptions::default(), false, &mut out);
        }
    }
    let n = allocs_now() - before;
    assert_eq!(
        n, 0,
        "steady-state attribute_batch_into allocated {n} times (workspace reuse regressed)"
    );
    // sanity: the counter itself works — a cold workspace must allocate
    let before = allocs_now();
    let mut cold_ws = Workspace::with_shards(1);
    let mut cold_out = BatchOutput::new();
    sim.attribute_batch_into(
        &mut cold_ws,
        &refs,
        Method::Guided,
        AttrOptions::default(),
        false,
        &mut cold_out,
    );
    assert!(allocs_now() - before > 0, "counting allocator is not counting");
    assert_eq!(cold_out.relevance, out.relevance, "cold and warm runs must agree");
}

#[test]
fn steady_state_survives_batch_shrink_and_single_image() {
    // a smaller batch than the warmed one must not allocate either
    // (shrinking resizes never grow capacity), and neither must the
    // batch-of-one serving case
    let sim = tiny_sim(7);
    let imgs = images(4, 2 * 8 * 8);
    let refs4: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let refs2: Vec<&[f32]> = imgs[..2].iter().map(|v| v.as_slice()).collect();
    let refs1: Vec<&[f32]> = imgs[..1].iter().map(|v| v.as_slice()).collect();
    let mut ws = Workspace::with_shards(1);
    let mut out = BatchOutput::new();
    let opts = AttrOptions::default();
    for _ in 0..3 {
        sim.attribute_batch_into(&mut ws, &refs4, Method::Guided, opts, false, &mut out);
    }
    let before = allocs_now();
    sim.attribute_batch_into(&mut ws, &refs2, Method::Guided, opts, false, &mut out);
    sim.attribute_batch_into(&mut ws, &refs1, Method::Guided, opts, false, &mut out);
    sim.attribute_batch_into(&mut ws, &refs4, Method::Guided, opts, false, &mut out);
    let n = allocs_now() - before;
    assert_eq!(n, 0, "shrunken/single batches allocated {n} times on a warm workspace");
    // the unfused-ablation path has its own scratch (tmp slab): it must
    // also reach zero after its own warm-up
    let unfused = AttrOptions { fused_unpool: false, ..Default::default() };
    for _ in 0..3 {
        sim.attribute_batch_into(&mut ws, &refs4, Method::Guided, unfused, false, &mut out);
    }
    let before = allocs_now();
    sim.attribute_batch_into(&mut ws, &refs4, Method::Guided, unfused, false, &mut out);
    assert_eq!(allocs_now() - before, 0, "unfused ablation allocated on a warm workspace");
}

#[test]
fn span_ledger_with_tracing_disabled_is_allocation_free() {
    // the obs contract (ISSUE 8 acceptance): with no recorder
    // configured the server still stamps a full span per request —
    // create, every stage stamp, all batch/device facts, segment
    // queries — and none of it may touch the heap
    span::epoch(); // pin outside the measured window
    let before = allocs_now();
    for i in 0..100u64 {
        let mut sp = Span::start(i, 1, 4, Method::Guided);
        for st in ALL_STAGES {
            sp.stamp_now(st);
        }
        sp.stamp(Stage::DeviceComplete, 12_345 + i);
        sp.batch_id = i;
        sp.batch_size = 4;
        sp.device_index = 0;
        sp.attempts = 1;
        sp.breaker_tripped = i % 2 == 0;
        sp.device_cycles += 999;
        sp.deadline_ms = 50;
        sp.trace_seq = Some(i);
        let _ = sp.segment_ns(Stage::Flush);
        let _ = sp.total_ns();
        std::hint::black_box(&sp);
    }
    let n = allocs_now() - before;
    assert_eq!(n, 0, "span stamping allocated {n} times with tracing disabled");
}

#[test]
fn telemetry_publication_is_allocation_free() {
    // the ISSUE 9 hot-path contract: publishing into the lock-free
    // registry — counters, gauges, histogram observes, folding a full
    // span, profiler slot updates — is atomics only, zero heap
    let reg = Registry::new();
    // classed publication rides the same contract: the slots are
    // preallocated at install time, so observe_class is atomics only
    reg.install_classes(vec!["gold".into(), "bronze".into()]);
    let prof = UnitProfiler::new(vec![
        ("c1".into(), attrax::hls::EngineKind::Conv),
        ("f1".into(), attrax::hls::EngineKind::Vmm),
    ]);
    let mut sp = Span::start(1, 1, 4, Method::Guided);
    for st in ALL_STAGES {
        sp.stamp(st, 1_000 * (st as u64 + 1));
    }
    let before = allocs_now();
    for i in 0..100u64 {
        reg.completed.inc();
        reg.retries.add(2);
        reg.conns_open.inc();
        reg.queue_depth.set(i);
        reg.conns_open.dec();
        reg.request_ns.observe(10_000 + i);
        reg.observe_span(&sp);
        reg.observe_class((i % 2) as usize, 10_000 + i, i % 3 != 0);
        prof.record((i % 2) as usize, Phase::Forward, 500, 80);
        prof.record((i % 2) as usize, Phase::Backward, 700, 90);
    }
    let n = allocs_now() - before;
    assert_eq!(n, 0, "telemetry publication allocated {n} times");
    assert_eq!(reg.completed.get(), 100);
    assert_eq!(reg.request_ns.count(), 200, "direct observes + observe_span folds");
    let classed: u64 = (0..2).map(|c| reg.class_good[c].get() + reg.class_bad[c].get()).sum();
    assert_eq!(classed, 100, "every classed observation landed in a slot");
}

#[test]
fn profiled_attribute_batch_is_allocation_free_when_warm() {
    // attaching the per-unit profiler must not reopen the zero-alloc
    // pin: the hooks around each unit dispatch are cycle-ledger reads,
    // clock reads, and relaxed atomic adds into preallocated slots
    let sim = tiny_sim(21);
    let imgs = images(4, 2 * 8 * 8);
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let mut ws = Workspace::with_shards(1);
    let mut out = BatchOutput::new();
    let prof = Arc::new(UnitProfiler::for_plan(&sim));
    ws.profiler = Some(prof.clone());
    for _ in 0..3 {
        for m in attrax::attribution::ALL_METHODS {
            sim.attribute_batch_into(&mut ws, &refs, m, AttrOptions::default(), false, &mut out);
        }
    }
    let passes_warm = prof.rows().iter().map(|r| r.passes).sum::<u64>();
    assert!(passes_warm > 0, "profiler never saw a unit dispatch");
    let before = allocs_now();
    for _ in 0..5 {
        for m in attrax::attribution::ALL_METHODS {
            sim.attribute_batch_into(&mut ws, &refs, m, AttrOptions::default(), false, &mut out);
        }
    }
    let n = allocs_now() - before;
    assert_eq!(n, 0, "profiled steady-state attribute_batch_into allocated {n} times");
    let rows = prof.rows();
    assert!(rows.iter().map(|r| r.passes).sum::<u64>() > passes_warm);
    for r in &rows {
        assert!(r.passes > 0, "unit {} {:?} never profiled", r.unit, r.phase);
        assert!(r.cycles > 0, "unit {} {:?} has no modeled cycles", r.unit, r.phase);
    }
}
