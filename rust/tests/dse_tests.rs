//! End-to-end tests of the DSE subsystem (ISSUE-4): determinism,
//! legality/capacity of everything the tuner emits, artifact
//! round-trips through the serving entry points, and the
//! tuned-vs-default bit-exactness contract.

use attrax::attribution::Method;
use attrax::dse::{self, Space, TuneSpec};
use attrax::fpga::{Board, ALL_BOARDS};
use attrax::sched::tests_support::tiny_net_params;
use attrax::sched::{AttrOptions, Plan, Simulator};
use attrax::util::rng::Pcg32;
use std::sync::Arc;

fn smoke_spec(seed: u64) -> TuneSpec {
    TuneSpec {
        space: Space::smoke(),
        boards: ALL_BOARDS.to_vec(),
        method: Method::Guided,
        seed,
        budget: 32,
        beam: 4,
        threads: 2,
    }
}

#[test]
fn frontier_and_winner_are_byte_identical_across_reruns() {
    let (net, params) = tiny_net_params(21);
    let spec = smoke_spec(3);
    let a = dse::tune(&net, &params, &spec).unwrap();
    let b = dse::tune(&net, &params, &spec).unwrap();
    assert_eq!(a.to_json(&spec).to_string(), b.to_json(&spec).to_string());
    assert_eq!(a.tuned_json().to_string(), b.tuned_json().to_string());
    // a different seed still converges to the same result on an
    // exhaustively-searched space (the seed only matters for sampling)
    let c = dse::tune(&net, &params, &smoke_spec(4)).unwrap();
    let a_reseeded = a.tuned_json().to_string().replace("\"seed\":\"3\"", "\"seed\":\"4\"");
    assert_eq!(a_reseeded, c.tuned_json().to_string());
}

#[test]
fn everything_emitted_validates_and_fits() {
    let (net, params) = tiny_net_params(23);
    let r = dse::tune(&net, &params, &smoke_spec(5)).unwrap();
    assert_eq!(r.outcomes.len(), 3);
    for o in &r.outcomes {
        for p in o.frontier.entries() {
            p.cfg.validate().unwrap();
            assert!(o.board.fits(&p.util), "{}: frontier point over capacity", o.board);
            assert!(p.cycles() > 0);
        }
        o.best.cfg.validate().unwrap();
        assert!(o.board.fits(&o.best.util));
        assert!(o.speedup >= 1.0);
    }
}

#[test]
fn tune_beats_default_on_at_least_two_boards() {
    // the ISSUE-4 acceptance bar, on the offline tiny model: a
    // capacity-feasible tuned config with strictly fewer modeled
    // attribution cycles than the board's default HwConfig (or the
    // default proven Pareto-optimal) — and the strict win must land on
    // at least two boards.
    let (net, params) = tiny_net_params(25);
    let r = dse::tune(&net, &params, &smoke_spec(6)).unwrap();
    let mut strict_wins = 0;
    for o in &r.outcomes {
        if o.best.cycles() < o.default_point.cycles() {
            strict_wins += 1;
        } else {
            assert!(o.default_on_frontier, "{}: no win and default off-frontier", o.board);
        }
    }
    assert!(strict_wins >= 2, "tuner beat the default on only {strict_wins} board(s)");
}

#[test]
fn tuned_config_is_bit_exact_with_default_heatmaps() {
    // a tuned config changes the cycle/resource model, never the
    // arithmetic: running the emitted winner through attribute() must
    // reproduce the default config's heatmap bit for bit (P2 config
    // invariance, here asserted on the tuner's actual output).
    let (net, params) = tiny_net_params(27);
    let r = dse::tune(&net, &params, &smoke_spec(7)).unwrap();
    let text = r.tuned_json().to_string();
    let tuned = dse::tune::parse_tuned(&text).unwrap();
    let mut rng = Pcg32::seeded(31);
    let img: Vec<f32> = (0..net.input.elems()).map(|_| rng.f32()).collect();
    for o in &r.outcomes {
        let tuned_cfg = tuned.for_board(o.board).expect("artifact covers every tuned board");
        assert_eq!(tuned_cfg, o.best.cfg);
        let plan = Arc::new(Plan::new(net.clone(), &params, o.default_point.cfg).unwrap());
        let default_sim = Simulator::from_plan(plan.clone());
        let tuned_sim = Simulator::with_config(plan.clone(), tuned_cfg).unwrap();
        for method in attrax::attribution::ALL_METHODS {
            let d = default_sim.attribute(&img, method, AttrOptions::default());
            let t = tuned_sim.attribute(&img, method, AttrOptions::default());
            assert_eq!(d.logits, t.logits, "{}/{method}: logits drifted", o.board);
            assert_eq!(d.pred, t.pred, "{}/{method}", o.board);
            assert_eq!(d.relevance, t.relevance, "{}/{method}: heatmap drifted", o.board);
            assert_eq!(d.relevance.len(), net.input.elems(), "heatmap shape contract");
        }
    }
}

#[test]
fn large_space_beam_search_is_deterministic_and_budgeted() {
    let (net, params) = tiny_net_params(29);
    let spec = TuneSpec {
        space: Space::paper(),
        boards: vec![Board::PynqZ2, Board::Zcu104],
        method: Method::Saliency,
        seed: 11,
        budget: 20,
        beam: 4,
        threads: 3,
    };
    let a = dse::tune(&net, &params, &spec).unwrap();
    for o in &a.outcomes {
        assert!(o.scored <= spec.budget, "{}: {} scored", o.board, o.scored);
        assert!(o.visited >= o.scored);
        for p in o.frontier.entries() {
            p.cfg.validate().unwrap();
            assert!(o.board.fits(&p.util));
        }
    }
    let mut spec2 = spec.clone();
    spec2.threads = 1;
    let b = dse::tune(&net, &params, &spec2).unwrap();
    assert_eq!(a.to_json(&spec).to_string(), b.to_json(&spec2).to_string());
}
