//! End-to-end tests of the DSE subsystem (ISSUE-4): determinism,
//! legality/capacity of everything the tuner emits, artifact
//! round-trips through the serving entry points, and the
//! tuned-vs-default bit-exactness contract.

use attrax::attribution::Method;
use attrax::dse::{self, Space, TuneSpec};
use attrax::fpga::{Board, ALL_BOARDS};
use attrax::sched::tests_support::tiny_net_params;
use attrax::sched::{AttrOptions, Plan, Simulator};
use attrax::util::rng::Pcg32;
use std::sync::Arc;

fn smoke_spec(seed: u64) -> TuneSpec {
    TuneSpec {
        space: Space::smoke(),
        boards: ALL_BOARDS.to_vec(),
        method: Method::Guided,
        seed,
        budget: 32,
        beam: 4,
        threads: 2,
        quality: false,
    }
}

#[test]
fn frontier_and_winner_are_byte_identical_across_reruns() {
    let (net, params) = tiny_net_params(21);
    let spec = smoke_spec(3);
    let a = dse::tune(&net, &params, &spec).unwrap();
    let b = dse::tune(&net, &params, &spec).unwrap();
    assert_eq!(a.to_json(&spec).to_string(), b.to_json(&spec).to_string());
    assert_eq!(a.tuned_json().to_string(), b.tuned_json().to_string());
    // a different seed still converges to the same result on an
    // exhaustively-searched space (the seed only matters for sampling)
    let c = dse::tune(&net, &params, &smoke_spec(4)).unwrap();
    let a_reseeded = a.tuned_json().to_string().replace("\"seed\":\"3\"", "\"seed\":\"4\"");
    assert_eq!(a_reseeded, c.tuned_json().to_string());
}

#[test]
fn everything_emitted_validates_and_fits() {
    let (net, params) = tiny_net_params(23);
    let r = dse::tune(&net, &params, &smoke_spec(5)).unwrap();
    assert_eq!(r.outcomes.len(), 3);
    for o in &r.outcomes {
        for p in o.frontier.entries() {
            p.cfg.validate().unwrap();
            assert!(o.board.fits(&p.util), "{}: frontier point over capacity", o.board);
            assert!(p.cycles() > 0);
        }
        o.best.cfg.validate().unwrap();
        assert!(o.board.fits(&o.best.util));
        assert!(o.speedup >= 1.0);
    }
}

#[test]
fn tune_beats_default_on_at_least_two_boards() {
    // the ISSUE-4 acceptance bar, on the offline tiny model: a
    // capacity-feasible tuned config with strictly fewer modeled
    // attribution cycles than the board's default HwConfig (or the
    // default proven Pareto-optimal) — and the strict win must land on
    // at least two boards.
    let (net, params) = tiny_net_params(25);
    let r = dse::tune(&net, &params, &smoke_spec(6)).unwrap();
    let mut strict_wins = 0;
    for o in &r.outcomes {
        if o.best.cycles() < o.default_point.cycles() {
            strict_wins += 1;
        } else {
            assert!(o.default_on_frontier, "{}: no win and default off-frontier", o.board);
        }
    }
    assert!(strict_wins >= 2, "tuner beat the default on only {strict_wins} board(s)");
}

#[test]
fn tuned_config_is_bit_exact_with_default_heatmaps() {
    // a tuned config changes the cycle/resource model, never the
    // arithmetic: running the emitted winner through attribute() must
    // reproduce the default config's heatmap bit for bit (P2 config
    // invariance, here asserted on the tuner's actual output).
    let (net, params) = tiny_net_params(27);
    let r = dse::tune(&net, &params, &smoke_spec(7)).unwrap();
    let text = r.tuned_json().to_string();
    let tuned = dse::tune::parse_tuned(&text).unwrap();
    let mut rng = Pcg32::seeded(31);
    let img: Vec<f32> = (0..net.input.elems()).map(|_| rng.f32()).collect();
    for o in &r.outcomes {
        let tuned_cfg = tuned.for_board(o.board).expect("artifact covers every tuned board");
        assert_eq!(tuned_cfg, o.best.cfg);
        let plan = Arc::new(Plan::new(net.clone(), &params, o.default_point.cfg).unwrap());
        let default_sim = Simulator::from_plan(plan.clone());
        let tuned_sim = Simulator::with_config(plan.clone(), tuned_cfg).unwrap();
        for method in attrax::attribution::ALL_METHODS {
            let d = default_sim.attribute(&img, method, AttrOptions::default());
            let t = tuned_sim.attribute(&img, method, AttrOptions::default());
            assert_eq!(d.logits, t.logits, "{}/{method}: logits drifted", o.board);
            assert_eq!(d.pred, t.pred, "{}/{method}", o.board);
            assert_eq!(d.relevance, t.relevance, "{}/{method}: heatmap drifted", o.board);
            assert_eq!(d.relevance.len(), net.input.elems(), "heatmap shape contract");
        }
    }
}

#[test]
fn quality_tuner_dominates_the_format_a_blind_tuner_accepts() {
    // ISSUE-5 acceptance: on the smoke_quality space the Q16.2 twins
    // cost exactly the same cycles/BRAM/DSP as their Q16.9 siblings —
    // a quality-blind tuner cannot tell them apart and (by the config
    // tie-break, which orders frac_bits ascending) actually KEEPS the
    // garbage format on its frontier. With --quality the sibling
    // dominates it (worse fidelity, no latency/resource win) and every
    // frontier survivor carries the faithful format.
    let (net, params) = tiny_net_params(33);
    let blind_spec = TuneSpec {
        space: Space::smoke_quality(),
        boards: vec![Board::PynqZ2, Board::Zcu104],
        method: Method::Guided,
        seed: 13,
        budget: 32,
        beam: 4,
        threads: 2,
        quality: false,
    };
    let quality_spec = TuneSpec { quality: true, ..blind_spec.clone() };
    let q16_2 = attrax::fx::QFormat::new(16, 2);
    let blind = dse::tune(&net, &params, &blind_spec).unwrap();
    let qual = dse::tune(&net, &params, &quality_spec).unwrap();
    // the blind tuner accepted low-fidelity design points somewhere
    let blind_accepts = blind
        .outcomes
        .iter()
        .flat_map(|o| o.frontier.entries())
        .filter(|p| p.cfg.q == q16_2)
        .count();
    assert!(blind_accepts > 0, "blind frontier never picked the low-fidelity format");
    for (b, q) in blind.outcomes.iter().zip(&qual.outcomes) {
        // the quality tuner demonstrably dominates them all: its
        // frontier is pure Q16.9, and for every blind Q16.2 entry the
        // same-knob Q16.9 sibling sits on the quality frontier with
        // identical cycles and resources but strictly better fidelity
        for p in q.frontier.entries() {
            assert_eq!(
                p.cfg.q,
                attrax::fx::QFormat::paper16(),
                "{}: low-fidelity format survived the quality frontier",
                q.board
            );
        }
        for bp in b.frontier.entries().iter().filter(|p| p.cfg.q == q16_2) {
            let mut sibling = bp.cfg;
            sibling.q = attrax::fx::QFormat::paper16();
            let twin = q
                .frontier
                .entries()
                .into_iter()
                .find(|p| p.cfg == sibling)
                .unwrap_or_else(|| panic!("{}: faithful sibling missing", q.board))
                .clone();
            assert_eq!(twin.cycles(), bp.cycles(), "same cycle model");
            assert_eq!(twin.util, bp.util, "same resource build");
            assert!(twin.infidelity_ppm < 500_000, "sibling should track the oracle");
        }
        // winner runs the faithful format and never lost latency
        assert_eq!(q.best.cfg.q, attrax::fx::QFormat::paper16());
        assert_eq!(q.best.cycles(), b.best.cycles(), "quality never costs latency here");
    }
    // determinism holds with the quality objective on: rerun and
    // thread-count invariance, byte for byte
    let rerun = dse::tune(&net, &params, &quality_spec).unwrap();
    assert_eq!(
        qual.to_json(&quality_spec).to_string(),
        rerun.to_json(&quality_spec).to_string()
    );
    let mut spec_mt = quality_spec.clone();
    spec_mt.threads = 4;
    let mt = dse::tune(&net, &params, &spec_mt).unwrap();
    assert_eq!(qual.to_json(&quality_spec).to_string(), mt.to_json(&spec_mt).to_string());
}

#[test]
fn large_space_beam_search_is_deterministic_and_budgeted() {
    let (net, params) = tiny_net_params(29);
    let spec = TuneSpec {
        space: Space::paper(),
        boards: vec![Board::PynqZ2, Board::Zcu104],
        method: Method::Saliency,
        seed: 11,
        budget: 20,
        beam: 4,
        threads: 3,
        quality: false,
    };
    let a = dse::tune(&net, &params, &spec).unwrap();
    for o in &a.outcomes {
        assert!(o.scored <= spec.budget, "{}: {} scored", o.board, o.scored);
        assert!(o.visited >= o.scored);
        for p in o.frontier.entries() {
            p.cfg.validate().unwrap();
            assert!(o.board.fits(&p.util));
        }
    }
    let mut spec2 = spec.clone();
    spec2.threads = 1;
    let b = dse::tune(&net, &params, &spec2).unwrap();
    assert_eq!(a.to_json(&spec).to_string(), b.to_json(&spec2).to_string());
}
