//! Property-based tests (hand-rolled harness, util::prop) over the
//! coordinator, scheduler, engines and accounting invariants.

use attrax::attribution::{Method, ALL_METHODS};
use attrax::coordinator::{Config, Coordinator};
use attrax::fpga::{self, Board};
use attrax::fx::QFormat;
use attrax::hls::{Cost, HwConfig};
use attrax::model::{Network, NetworkBuilder, Params, Shape, Tensor};
use attrax::sched::{AttrOptions, BatchOutput, Simulator, Workspace};
use attrax::util::prop::{run_prop, PropConfig};
use attrax::util::rng::Pcg32;
use std::collections::BTreeMap;

/// Random small CNN (conv[+relu][+pool]* then fc+) with matching params.
fn random_model(rng: &mut Pcg32) -> (Network, Params) {
    let ch0 = 1 + rng.below(3) as usize;
    let mut side = 8 * (1 + rng.below(2) as usize); // 8 or 16
    let mut b = NetworkBuilder::new(Shape::Chw(ch0, side, side));
    let mut tensors = BTreeMap::new();
    let mut add = |name: String, shape: Vec<usize>, rng: &mut Pcg32| {
        let n: usize = shape.iter().product();
        let scale = (2.0 / n as f32).sqrt().max(0.05);
        let data: Vec<f32> = (0..n).map(|_| rng.normal() * scale).collect();
        tensors.insert(name, Tensor { shape, data });
    };
    let mut ch = ch0;
    let n_conv = 1 + rng.below(3) as usize;
    for i in 0..n_conv {
        let out_ch = [2usize, 4, 8][rng.below(3) as usize];
        let name = format!("c{i}");
        b = b.conv(&name, out_ch, 3, 1).relu();
        add(format!("{name}_w"), vec![out_ch, ch, 3, 3], rng);
        add(format!("{name}_b"), vec![out_ch], rng);
        ch = out_ch;
        if side >= 8 && rng.below(2) == 1 {
            b = b.maxpool2();
            side /= 2;
        }
    }
    b = b.flatten();
    let flat = ch * side * side;
    let hidden = 4 + rng.below(8) as usize;
    b = b.fc("f0", hidden).relu().fc("f1", 3);
    add("f0_w".into(), vec![hidden, flat], rng);
    add("f0_b".into(), vec![hidden], rng);
    add("f1_w".into(), vec![3, hidden], rng);
    add("f1_b".into(), vec![3], rng);
    (b.build().unwrap(), Params { tensors })
}

fn random_config(rng: &mut Pcg32) -> HwConfig {
    let unrolls = [(1usize, 1usize), (2, 2), (2, 4), (4, 4), (4, 8), (8, 8)];
    let (noh, now) = unrolls[rng.below(unrolls.len() as u32) as usize];
    HwConfig::with_unroll(noh, now, [16, 32][rng.below(2) as usize])
}

#[derive(Clone, Debug)]
struct Scenario {
    seed: u64,
    cfg: HwConfig,
}

fn scenario(rng: &mut Pcg32) -> Scenario {
    Scenario { seed: rng.next_u64(), cfg: random_config(rng) }
}

/// P1: fused and unfused BP produce identical relevance on arbitrary
/// models/configs, and fusion never costs more cycles.
#[test]
fn prop_fusion_exactness_and_economy() {
    run_prop(
        PropConfig { cases: 24, ..Default::default() },
        scenario,
        |s| {
            let mut rng = Pcg32::seeded(s.seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let sim = Simulator::new(net, &params, s.cfg).map_err(|e| e.to_string())?;
            let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
            for m in ALL_METHODS {
                let a = sim.attribute(&img, m, AttrOptions::default());
                let b = sim.attribute(
                    &img,
                    m,
                    AttrOptions { fused_unpool: false, ..Default::default() },
                );
                if a.relevance != b.relevance {
                    return Err(format!("{m}: fused != unfused"));
                }
                if a.bp_cost.total_cycles() > b.bp_cost.total_cycles() {
                    return Err(format!("{m}: fusion more expensive"));
                }
            }
            Ok(())
        },
    );
}

/// P2: hardware config is performance-only — relevance and logits are
/// bit-identical across all tilings/unrolls.
#[test]
fn prop_config_invariance() {
    run_prop(
        PropConfig { cases: 16, ..Default::default() },
        |r| (r.next_u64(), random_config(r), random_config(r)),
        |(seed, cfg_a, cfg_b)| {
            let mut rng = Pcg32::seeded(*seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
            let sa = Simulator::new(net.clone(), &params, *cfg_a).map_err(|e| e.to_string())?;
            let sb = Simulator::new(net, &params, *cfg_b).map_err(|e| e.to_string())?;
            let a = sa.attribute(&img, Method::Guided, AttrOptions::default());
            let b = sb.attribute(&img, Method::Guided, AttrOptions::default());
            if a.logits != b.logits {
                return Err("logits differ across configs".into());
            }
            if a.relevance != b.relevance {
                return Err("relevance differs across configs".into());
            }
            Ok(())
        },
    );
}

/// P3: guided relevance is "sparser or equal" — its nonzero support is
/// contained in saliency's support union deconvnet's support at the
/// input (both gates applied). Checked via: guided nonzero count <=
/// min over the other two is NOT generally true at the input conv
/// (conv mixes), but guided's last-ReLU gradient sparsity is. Instead
/// we check the robust invariant: all three methods agree on logits
/// and the FP cost is method-independent.
#[test]
fn prop_fp_method_independence() {
    run_prop(
        PropConfig { cases: 16, ..Default::default() },
        scenario,
        |s| {
            let mut rng = Pcg32::seeded(s.seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let sim = Simulator::new(net, &params, s.cfg).map_err(|e| e.to_string())?;
            let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
            let rs: Vec<_> = ALL_METHODS
                .iter()
                .map(|&m| sim.attribute(&img, m, AttrOptions::default()))
                .collect();
            if rs[0].logits != rs[1].logits || rs[1].logits != rs[2].logits {
                return Err("FP logits depend on BP method".into());
            }
            if rs[0].fp_cost.total_cycles() != rs[1].fp_cost.total_cycles()
                || rs[1].fp_cost.total_cycles() != rs[2].fp_cost.total_cycles()
            {
                return Err("FP cost depends on BP method".into());
            }
            Ok(())
        },
    );
}

/// P4: more unroll never increases compute cycles; MACs are invariant.
#[test]
fn prop_unroll_monotonicity() {
    run_prop(
        PropConfig { cases: 12, ..Default::default() },
        |r| r.next_u64(),
        |&seed| {
            let mut rng = Pcg32::seeded(seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
            let mut prev_cycles = u64::MAX;
            let mut macs = None;
            for (noh, now) in [(1, 1), (2, 2), (4, 4), (8, 8)] {
                let cfg = HwConfig::with_unroll(noh, now, 16);
                let sim = Simulator::new(net.clone(), &params, cfg).map_err(|e| e.to_string())?;
                let r = sim.attribute(&img, Method::Saliency, AttrOptions::default());
                let cycles = r.fp_cost.compute_cycles + r.bp_cost.compute_cycles;
                let m = r.fp_cost.macs + r.bp_cost.macs;
                if cycles > prev_cycles {
                    return Err(format!("unroll ({noh},{now}) increased cycles"));
                }
                if let Some(m0) = macs {
                    if m != m0 {
                        return Err("MAC count changed with unroll".into());
                    }
                }
                macs = Some(m);
                prev_cycles = cycles;
            }
            Ok(())
        },
    );
}

/// P5: the coordinator under random load completes every accepted
/// request exactly once; completed + rejected == submitted.
#[test]
fn prop_coordinator_conservation() {
    run_prop(
        PropConfig { cases: 10, ..Default::default() },
        |r| {
            (
                r.next_u64(),
                1 + r.below(4) as usize,      // workers
                1 + r.below(16) as usize,     // queue depth
                5 + r.below(40) as usize,     // requests
            )
        },
        |&(seed, workers, depth, requests)| {
            let mut rng = Pcg32::seeded(seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let sim = Simulator::new(net, &params, HwConfig::with_unroll(4, 4, 16))
                .map_err(|e| e.to_string())?;
            let coord = Coordinator::start(
                sim,
                Config {
                    workers,
                    queue_depth: depth,
                    verify_fraction: 0.0,
                    freq_mhz: 100.0,
                    ..Default::default()
                },
                None,
            )
            .map_err(|e| e.to_string())?;
            let mut rxs = Vec::new();
            let mut rejected = 0u64;
            for i in 0..requests {
                let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
                let m = ALL_METHODS[i % 3];
                match coord.submit_traced(img, m) {
                    Ok((_, rx)) => rxs.push(rx),
                    Err(_) => rejected += 1,
                }
            }
            let accepted = rxs.len();
            for rx in rxs {
                rx.recv().map_err(|_| "response channel dropped".to_string())?;
            }
            let snap = coord.shutdown();
            if snap.completed != accepted as u64 {
                return Err(format!("completed {} != accepted {accepted}", snap.completed));
            }
            if snap.rejected != rejected {
                return Err(format!("rejected {} != {rejected}", snap.rejected));
            }
            Ok(())
        },
    );
}

/// P10 (tentpole): batched and single-image execution are bit-exact —
/// for random tiny networks, configs, batch sizes and all three
/// methods, `attribute_batch(imgs)[i] == attribute(imgs[i])` on logits,
/// prediction and relevance.
#[test]
fn prop_batch_bit_exact() {
    run_prop(
        PropConfig { cases: 10, ..Default::default() },
        scenario,
        |s| {
            let mut rng = Pcg32::seeded(s.seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let sim = Simulator::new(net, &params, s.cfg).map_err(|e| e.to_string())?;
            let nb = 1 + rng.below(4) as usize; // 1..=4 images
            let imgs: Vec<Vec<f32>> = (0..nb)
                .map(|_| (0..n_in).map(|_| rng.f32()).collect())
                .collect();
            let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
            for m in ALL_METHODS {
                for fused in [true, false] {
                    let opts = AttrOptions { fused_unpool: fused, ..Default::default() };
                    let batch = sim.attribute_batch(&refs, m, opts);
                    if batch.items.len() != nb {
                        return Err(format!("{m}: wrong batch arity"));
                    }
                    for (i, item) in batch.items.iter().enumerate() {
                        let single = sim.attribute(&imgs[i], m, opts);
                        if item.logits != single.logits || item.pred != single.pred {
                            return Err(format!("{m} fused={fused}: image {i} FP diverged"));
                        }
                        if item.relevance != single.relevance {
                            return Err(format!(
                                "{m} fused={fused}: image {i} relevance diverged"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// P12 (tentpole): concurrency determinism — the same batch attributed
/// with 1/2/4 shard threads on a reused workspace, and via two OS
/// threads sharing one `Arc<Plan>`, is bit-identical to the
/// single-threaded single-image path. (Sharding splits the batch into
/// disjoint accumulator regions and the cost ledger is charged by a
/// shard-independent pass, so this must hold for ANY thread count.)
#[test]
fn prop_shard_and_shared_plan_determinism() {
    run_prop(
        PropConfig { cases: 8, ..Default::default() },
        scenario,
        |s| {
            let mut rng = Pcg32::seeded(s.seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let sim = Simulator::new(net, &params, s.cfg).map_err(|e| e.to_string())?;
            let nb = 1 + rng.below(4) as usize; // 1..=4 images
            let imgs: Vec<Vec<f32>> = (0..nb)
                .map(|_| (0..n_in).map(|_| rng.f32()).collect())
                .collect();
            let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
            // oracle: the single-threaded single-image path
            let singles: Vec<_> = imgs
                .iter()
                .map(|img| sim.attribute(img, Method::Guided, AttrOptions::default()))
                .collect();
            let mut ws = Workspace::with_shards(1);
            let mut out = BatchOutput::new();
            let mut baseline_cycles: Option<u64> = None;
            for shards in [1usize, 2, 4] {
                ws.shards = shards;
                sim.attribute_batch_into(
                    &mut ws,
                    &refs,
                    Method::Guided,
                    AttrOptions::default(),
                    false,
                    &mut out,
                );
                for (i, single) in singles.iter().enumerate() {
                    if out.relevance_of(i) != single.relevance.as_slice() {
                        return Err(format!("shards {shards}: image {i} relevance diverged"));
                    }
                    if out.logits_of(i) != single.logits.as_slice() {
                        return Err(format!("shards {shards}: image {i} logits diverged"));
                    }
                }
                // the Cost ledger is charged by a shard-independent pass
                let cycles = out.fp_cost.total_cycles() + out.bp_cost.total_cycles();
                match baseline_cycles {
                    None => baseline_cycles = Some(cycles),
                    Some(base) if base != cycles => {
                        return Err(format!(
                            "shards {shards}: ledger diverged ({cycles} vs {base} cycles)"
                        ));
                    }
                    Some(_) => {}
                }
            }
            // two workers, one shared Arc<Plan>, running concurrently
            let worker_results: Vec<(Vec<f32>, Vec<f32>)> = std::thread::scope(|sc| {
                let refs_ref = &refs;
                let handles: Vec<_> = (0..2)
                    .map(|_| {
                        let sim2 = sim.clone();
                        sc.spawn(move || {
                            let mut ws = Workspace::with_shards(2);
                            let mut out = BatchOutput::new();
                            sim2.attribute_batch_into(
                                &mut ws,
                                refs_ref,
                                Method::Guided,
                                AttrOptions::default(),
                                false,
                                &mut out,
                            );
                            (out.relevance.clone(), out.logits.clone())
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (w, (rel, logits)) in worker_results.iter().enumerate() {
                for (i, single) in singles.iter().enumerate() {
                    if &rel[i * n_in..(i + 1) * n_in] != single.relevance.as_slice() {
                        return Err(format!("worker {w}: image {i} relevance diverged"));
                    }
                    let n_out = single.logits.len();
                    if &logits[i * n_out..(i + 1) * n_out] != single.logits.as_slice() {
                        return Err(format!("worker {w}: image {i} logits diverged"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// P11: batching amortizes weight DRAM traffic — a batch pays exactly
/// the weight bytes of ONE pass (weight loads are image-independent),
/// so per-image weight traffic is 1/B, while total traffic stays below
/// B independent passes.
#[test]
fn prop_batch_weight_traffic_amortized() {
    run_prop(
        PropConfig { cases: 8, ..Default::default() },
        scenario,
        |s| {
            let mut rng = Pcg32::seeded(s.seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let sim = Simulator::new(net, &params, s.cfg).map_err(|e| e.to_string())?;
            let nb = 2 + rng.below(3) as usize; // 2..=4 images
            let imgs: Vec<Vec<f32>> = (0..nb)
                .map(|_| (0..n_in).map(|_| rng.f32()).collect())
                .collect();
            let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
            let batch = sim.attribute_batch(&refs, Method::Guided, AttrOptions::default());
            let single = sim.attribute(&imgs[0], Method::Guided, AttrOptions::default());
            if single.fp_cost.dram_weight_bytes == 0 {
                return Err("no weight traffic recorded".into());
            }
            if batch.fp_cost.dram_weight_bytes != single.fp_cost.dram_weight_bytes {
                return Err(format!(
                    "FP weight bytes {} != single {}",
                    batch.fp_cost.dram_weight_bytes, single.fp_cost.dram_weight_bytes
                ));
            }
            if batch.bp_cost.dram_weight_bytes != single.bp_cost.dram_weight_bytes {
                return Err(format!(
                    "BP weight bytes {} != single {}",
                    batch.bp_cost.dram_weight_bytes, single.bp_cost.dram_weight_bytes
                ));
            }
            let batch_total = batch.fp_cost.dram_read_bytes + batch.bp_cost.dram_read_bytes;
            let single_total = single.fp_cost.dram_read_bytes + single.bp_cost.dram_read_bytes;
            if batch_total >= nb as u64 * single_total {
                return Err("batching saved no traffic".into());
            }
            Ok(())
        },
    );
}

/// P13 (ISSUE-4): the autotuner's emissions are safe and honest — for
/// random tiny models, methods and seeds, every config the tuner emits
/// passes `HwConfig::validate()` and fits its board's `Capacity`; the
/// tuned winner never models more cycles than the default; a rerun
/// with the same seed/space produces a byte-identical frontier; and
/// running an emitted config through `attribute` reproduces the
/// default config's heatmap bit for bit (shape/contract included) —
/// tuning changes the cycle model, never the arithmetic.
#[test]
fn prop_dse_emissions_legal_feasible_bit_exact() {
    use attrax::dse::{self, Space, TuneSpec};
    run_prop(
        PropConfig { cases: 5, ..Default::default() },
        |r| r.next_u64(),
        |&seed| {
            let mut rng = Pcg32::seeded(seed);
            let (net, params) = random_model(&mut rng);
            let method = ALL_METHODS[rng.below(3) as usize];
            let spec = TuneSpec {
                space: Space::smoke(),
                boards: vec![Board::PynqZ2, Board::Zcu104],
                method,
                seed: rng.next_u64(),
                budget: 32,
                beam: 4,
                threads: 1 + rng.below(3) as usize,
                quality: false,
            };
            let report = dse::tune(&net, &params, &spec).map_err(|e| e.to_string())?;
            let rerun = dse::tune(&net, &params, &spec).map_err(|e| e.to_string())?;
            if report.to_json(&spec).to_string() != rerun.to_json(&spec).to_string() {
                return Err("same seed + same space produced different frontiers".into());
            }
            let n_in = net.input.elems();
            let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
            for o in &report.outcomes {
                for p in o.frontier.entries() {
                    p.cfg.validate().map_err(|e| format!("{}: emitted invalid: {e}", o.board))?;
                    if !o.board.fits(&p.util) {
                        return Err(format!("{}: emitted over-capacity config", o.board));
                    }
                }
                if o.best.cycles() > o.default_point.cycles() {
                    return Err(format!("{}: tuned slower than default", o.board));
                }
                let d = Simulator::new(net.clone(), &params, o.default_point.cfg)
                    .map_err(|e| e.to_string())?
                    .attribute(&img, method, AttrOptions::default());
                let t = Simulator::new(net.clone(), &params, o.best.cfg)
                    .map_err(|e| e.to_string())?
                    .attribute(&img, method, AttrOptions::default());
                if d.relevance.len() != n_in || t.relevance.len() != n_in {
                    return Err(format!("{}: heatmap shape contract broken", o.board));
                }
                if d.logits != t.logits || d.pred != t.pred || d.relevance != t.relevance {
                    return Err(format!("{}: tuned config not bit-exact with default", o.board));
                }
            }
            Ok(())
        },
    );
}

/// P14 (ISSUE-5): the xeval metrics are trustworthy measurements —
/// for random tiny models, methods and seeds: (a) fidelity scores and
/// faithfulness curves computed from 1/2/4-shard heatmaps are
/// bit-identical (the metrics inherit P12's concurrency determinism);
/// (b) rank-based metrics (Spearman, top-k, curve ordering) are
/// invariant under positive scaling of either heatmap; (c) the
/// identity comparison scores exact perfect fidelity.
#[test]
fn prop_xeval_metrics_deterministic_scale_invariant_identity_exact() {
    use attrax::xeval::{self, faithfulness, fidelity};
    run_prop(
        PropConfig { cases: 8, ..Default::default() },
        scenario,
        |s| {
            let mut rng = Pcg32::seeded(s.seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let sim = Simulator::new(net.clone(), &params, s.cfg).map_err(|e| e.to_string())?;
            let oracle = xeval::Oracle::new(&net, &params).map_err(|e| e.to_string())?;
            let method = ALL_METHODS[rng.below(3) as usize];
            let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
            let reference = oracle.attribute(&img, method, None);
            let k = (n_in / 10).max(1);

            // (a) shard-count invariance of the metrics
            let refs = [img.as_slice()];
            let mut base: Option<(Vec<f32>, f64, f64, Vec<f64>)> = None;
            for shards in [1usize, 2, 4] {
                let mut ws = Workspace::with_shards(shards);
                let mut out = BatchOutput::new();
                sim.attribute_batch_into(
                    &mut ws,
                    &refs,
                    method,
                    AttrOptions { target: Some(reference.pred), ..Default::default() },
                    false,
                    &mut out,
                );
                let heat = out.relevance_of(0).to_vec();
                let score = fidelity::score_pair(&heat, &reference.relevance, k);
                let curves = faithfulness::curves(&sim, &img, &heat, reference.pred, 4);
                match &base {
                    None => {
                        base = Some((heat, score.pearson, score.topk, curves.deletion.clone()))
                    }
                    Some((h0, p0, t0, d0)) => {
                        if &heat != h0 {
                            return Err(format!("shards {shards}: heatmap diverged"));
                        }
                        if score.pearson != *p0 || score.topk != *t0 {
                            return Err(format!("shards {shards}: fidelity diverged"));
                        }
                        if &curves.deletion != d0 {
                            return Err(format!("shards {shards}: deletion curve diverged"));
                        }
                    }
                }
            }
            let (heat, _, _, _) = base.unwrap();

            // (b) positive scaling never moves a rank metric: scale by
            // a power of two so the f32 ordering is exactly preserved
            let scaled: Vec<f32> = heat.iter().map(|v| v * 4.0).collect();
            let a = fidelity::score_pair(&heat, &reference.relevance, k);
            let b = fidelity::score_pair(&scaled, &reference.relevance, k);
            if a.spearman != b.spearman || a.topk != b.topk {
                return Err("rank metrics moved under positive scaling".into());
            }
            let ca = faithfulness::curves(&sim, &img, &heat, reference.pred, 4);
            let cb = faithfulness::curves(&sim, &img, &scaled, reference.pred, 4);
            if ca.deletion != cb.deletion || ca.insertion != cb.insertion {
                return Err("curves moved under positive scaling".into());
            }

            // (c) identity is exact, for both the quantized heatmap and
            // the oracle reference against themselves
            for h in [&heat, &reference.relevance] {
                let s = fidelity::score_pair(h, h, k);
                if s.pearson != 1.0 || s.spearman != 1.0 || s.topk != 1.0 {
                    return Err(format!(
                        "identity not exact: rho={} spearman={} topk={}",
                        s.pearson, s.spearman, s.topk
                    ));
                }
                if fidelity::infidelity_ppm(h, h) != 0 {
                    return Err("identity infidelity not zero".into());
                }
            }
            Ok(())
        },
    );
}

/// P6: quantization error of the whole attribution pipeline shrinks as
/// word width grows (8 -> 16 -> 24 bits, against the 32-bit run).
#[test]
fn prop_precision_monotone() {
    run_prop(
        PropConfig { cases: 6, ..Default::default() },
        |r| r.next_u64(),
        |&seed| {
            let mut rng = Pcg32::seeded(seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
            let run = |word: u32, frac: u32| -> Result<Vec<f32>, String> {
                let mut cfg = HwConfig::with_unroll(4, 4, 16);
                cfg.q = QFormat::new(word, frac);
                let sim = Simulator::new(net.clone(), &params, cfg).map_err(|e| e.to_string())?;
                Ok(sim.attribute(&img, Method::Saliency, AttrOptions::default()).relevance)
            };
            let gold = run(32, 18)?;
            let mut prev_err = f64::INFINITY;
            for (w, f) in [(10u32, 5u32), (16, 9), (24, 14)] {
                let rel = run(w, f)?;
                let err: f64 = rel
                    .iter()
                    .zip(&gold)
                    .map(|(a, b)| ((a - b) as f64).abs())
                    .sum::<f64>()
                    / rel.len() as f64;
                // allow tiny non-monotonicity at high precision (rounding luck)
                if err > prev_err * 1.05 + 1e-6 {
                    return Err(format!("{w}-bit error {err} > {prev_err}"));
                }
                prev_err = err;
            }
            Ok(())
        },
    );
}

/// P7: mask accounting scales with the graph: on-chip bits == 2*pool
/// outputs + fc relu bits (saliency), and deconvnet <= every method.
#[test]
fn prop_mask_budget_graph_driven() {
    run_prop(
        PropConfig { cases: 32, ..Default::default() },
        |r| r.next_u64(),
        |&seed| {
            let mut rng = Pcg32::seeded(seed);
            let (net, _) = random_model(&mut rng);
            let b = attrax::attribution::memory::mask_budget(&net);
            // recompute pool bits independently over the node graph
            let mut pool_bits = 0usize;
            for (i, nd) in net.nodes().iter().enumerate() {
                if matches!(nd.layer, attrax::model::Layer::MaxPool2) {
                    pool_bits += 2 * net.out_shape(i).elems();
                }
            }
            if b.pool_bits != pool_bits {
                return Err(format!("pool bits {} != {}", b.pool_bits, pool_bits));
            }
            for m in ALL_METHODS {
                if b.onchip_bits(Method::Deconvnet) > b.onchip_bits(m) {
                    return Err("deconvnet not minimal".into());
                }
                if b.conceptual_bits(m) < b.onchip_bits(m) {
                    return Err("conceptual < onchip".into());
                }
            }
            Ok(())
        },
    );
}

/// Random graph-IR manifest text: a conv stem, then either a straight
/// chain or a residual skip block (conv+relu forked into a second
/// shape-preserving conv+relu and re-joined by `add`), then
/// pool/flatten/fc head. Exercises the manifest loader + DAG schedule
/// end to end, not just the builder API.
fn random_graph_json(rng: &mut Pcg32) -> String {
    let ch0 = 1 + rng.below(3) as usize;
    let side = 8 * (1 + rng.below(2) as usize); // 8 or 16
    let ch = [4usize, 8][rng.below(2) as usize];
    let skip = rng.below(2) == 1;
    let hidden = 4 + rng.below(8) as usize;
    let mut nodes = vec![
        format!(
            r#"{{"name": "stem", "op": "conv", "in": ["image"], "out_ch": {ch}, "k": 3, "pad": 1}}"#
        ),
        r#"{"name": "stem_r", "op": "relu", "in": ["stem"]}"#.to_string(),
    ];
    // the head pools once, so its input is the last feature-map node
    let body_out = if skip {
        nodes.push(format!(
            r#"{{"name": "b1", "op": "conv", "in": ["stem_r"], "out_ch": {ch}, "k": 3, "pad": 1}}"#
        ));
        nodes.push(r#"{"name": "b1_r", "op": "relu", "in": ["b1"]}"#.to_string());
        nodes.push(r#"{"name": "res", "op": "add", "in": ["stem_r", "b1_r"]}"#.to_string());
        nodes.push(r#"{"name": "res_r", "op": "relu", "in": ["res"]}"#.to_string());
        "res_r"
    } else {
        nodes.push(format!(
            r#"{{"name": "c1", "op": "conv", "in": ["stem_r"], "out_ch": {ch}, "k": 3, "pad": 1}}"#
        ));
        nodes.push(r#"{"name": "c1_r", "op": "relu", "in": ["c1"]}"#.to_string());
        "c1_r"
    };
    nodes.push(format!(r#"{{"name": "pool", "op": "maxpool2", "in": ["{body_out}"]}}"#));
    nodes.push(r#"{"name": "flat", "op": "flatten", "in": ["pool"]}"#.to_string());
    nodes.push(format!(
        r#"{{"name": "fc1", "op": "fc", "in": ["flat"], "out": {hidden}}}"#
    ));
    nodes.push(r#"{"name": "fc1_r", "op": "relu", "in": ["fc1"]}"#.to_string());
    nodes.push(r#"{"name": "fc2", "op": "fc", "in": ["fc1_r"], "out": 3}"#.to_string());
    format!(
        r#"{{"schema": "attrax-graph/v1", "name": "prop", "input": [{ch0}, {side}, {side}], "nodes": [{}], "output": "fc2"}}"#,
        nodes.join(", ")
    )
}

/// P15 (ISSUE-6): graph-IR execution is deterministic and faithful —
/// random manifest-loaded chain/skip graphs attribute bit-identically
/// across 1/2/4 shard threads vs the single-image path, and the
/// manifest-loaded Table-III graph reproduces the builder-chain
/// network's heatmap bit for bit on the same synthetic weights.
#[test]
fn prop_graph_models_shard_invariant_and_table3_manifest_bit_exact() {
    run_prop(
        PropConfig { cases: 10, ..Default::default() },
        scenario,
        |s| {
            let mut rng = Pcg32::seeded(s.seed);
            let text = random_graph_json(&mut rng);
            let net = Network::from_graph_str(&text).map_err(|e| e.to_string())?;
            let params = Params::synthetic(&net, s.seed);
            let n_in = net.input.elems();
            let sim = Simulator::new(net, &params, s.cfg).map_err(|e| e.to_string())?;
            let nb = 2 + rng.below(3) as usize; // 2..=4 images
            let imgs: Vec<Vec<f32>> = (0..nb)
                .map(|_| (0..n_in).map(|_| rng.f32()).collect())
                .collect();
            let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
            for m in ALL_METHODS {
                let singles: Vec<_> = imgs
                    .iter()
                    .map(|img| sim.attribute(img, m, AttrOptions::default()))
                    .collect();
                for shards in [1usize, 2, 4] {
                    let mut ws = Workspace::with_shards(shards);
                    let mut out = BatchOutput::new();
                    sim.attribute_batch_into(
                        &mut ws,
                        &refs,
                        m,
                        AttrOptions::default(),
                        false,
                        &mut out,
                    );
                    for (i, single) in singles.iter().enumerate() {
                        if out.relevance_of(i) != single.relevance.as_slice() {
                            return Err(format!("{m} shards {shards}: image {i} diverged"));
                        }
                        if out.logits_of(i) != single.logits.as_slice() {
                            return Err(format!("{m} shards {shards}: image {i} FP diverged"));
                        }
                    }
                }
            }
            Ok(())
        },
    );

    // the Table-III manifest path must be bit-exact with the same chain
    // assembled through the pre-refactor NetworkBuilder constructor
    let manifest = Network::table3();
    let chain = NetworkBuilder::new(Shape::Chw(3, 32, 32))
        .conv("conv1", 32, 3, 1)
        .relu()
        .conv("conv2", 32, 3, 1)
        .relu()
        .maxpool2()
        .conv("conv3", 64, 3, 1)
        .relu()
        .conv("conv4", 64, 3, 1)
        .relu()
        .maxpool2()
        .flatten()
        .fc("fc1", 128)
        .relu()
        .fc("fc2", 10)
        .build()
        .unwrap();
    let params = Params::synthetic(&manifest, 42);
    assert_eq!(
        Params::synthetic(&chain, 42).tensors,
        params.tensors,
        "synthetic weights must not move under the manifest refactor"
    );
    let cfg = HwConfig::with_unroll(4, 4, 16);
    let sm = Simulator::new(manifest, &params, cfg).unwrap();
    let sc = Simulator::new(chain, &params, cfg).unwrap();
    let mut rng = Pcg32::seeded(99);
    let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.f32()).collect();
    for m in ALL_METHODS {
        let a = sm.attribute(&img, m, AttrOptions::default());
        let b = sc.attribute(&img, m, AttrOptions::default());
        assert_eq!(a.logits, b.logits, "{m}: manifest logits diverged from builder chain");
        assert_eq!(a.pred, b.pred);
        assert_eq!(a.relevance, b.relevance, "{m}: manifest heatmap diverged from builder chain");
        assert_eq!(
            a.fp_cost.total_cycles() + a.bp_cost.total_cycles(),
            b.fp_cost.total_cycles() + b.bp_cost.total_cycles(),
            "{m}: manifest cycle ledger diverged from builder chain"
        );
    }
}

/// P8: resource estimates are monotone in unroll and the chosen config
/// always fits its board.
#[test]
fn prop_resource_monotone_and_feasible() {
    let net = Network::table3();
    let unrolls = [(1, 1), (2, 2), (2, 4), (4, 4), (4, 8), (8, 8)];
    let mut prev = 0u32;
    for (noh, now) in unrolls {
        let cfg = HwConfig::with_unroll(noh, now, 16);
        let u = fpga::estimate_fp_bp(&cfg, &net, Method::Guided);
        assert!(u.dsp >= prev, "DSP not monotone at ({noh},{now})");
        assert!(u.lut > 0 && u.ff > 0 && u.bram_18k > 0);
        prev = u.dsp;
    }
    for b in [Board::PynqZ2, Board::Ultra96V2, Board::Zcu104] {
        for m in ALL_METHODS {
            let cfg = fpga::choose_config(b, &net, m);
            assert!(b.fits(&fpga::estimate_fp_bp(&cfg, &net, m)), "{b}/{m} config does not fit");
        }
    }
}

/// P9: Cost merge/breakdown arithmetic is associative and lossless
/// under random sequences of charges.
#[test]
fn prop_cost_ledger_arithmetic() {
    run_prop(
        PropConfig { cases: 64, ..Default::default() },
        |r| {
            let n = 1 + r.below(10) as usize;
            (0..n)
                .map(|_| (r.below(1000) as u64, r.below(1000) as u64))
                .collect::<Vec<_>>()
        },
        |charges| {
            let mut whole = Cost::new();
            let mut parts: Vec<Cost> = Vec::new();
            for (i, &(c, d)) in charges.iter().enumerate() {
                let mut p = Cost::new();
                p.compute_cycles = c;
                p.dram_cycles = d;
                p.checkpoint(&format!("l{i}"));
                whole.compute_cycles += c;
                whole.dram_cycles += d;
                parts.push(p);
            }
            let mut merged = Cost::new();
            for p in &parts {
                merged.merge(p);
            }
            if merged.total_cycles() != whole.total_cycles() {
                return Err("merge lost cycles".into());
            }
            let breakdown = merged.layer_breakdown();
            let sum: u64 = breakdown.iter().map(|(_, c)| c).sum();
            if sum != merged.total_cycles() {
                return Err("breakdown doesn't sum to total".into());
            }
            Ok(())
        },
    );
}

/// P16 (ISSUE-7): the all-zero fault plan is invisible — a fleet whose
/// devices carry zero-plan fault hooks serves bit-identical heatmaps,
/// logits, predictions and device-cycle ledgers to the plain
/// single-device coordinator on arbitrary models/configs, and every
/// injection, detection and recovery counter stays at zero.
#[test]
fn prop_zero_fault_plan_is_bit_invisible() {
    use attrax::coordinator::fleet::Device;
    use attrax::faults::{FaultHooks, FaultPlan};
    use std::sync::Arc;
    run_prop(
        PropConfig { cases: 8, ..Default::default() },
        scenario,
        |s| {
            let mut rng = Pcg32::seeded(s.seed);
            let (net, params) = random_model(&mut rng);
            let n_in = net.input.elems();
            let sim = Simulator::new(net, &params, s.cfg).map_err(|e| e.to_string())?;
            let hooks = FaultHooks::new(FaultPlan::none());
            let devices = (0..2u64)
                .map(|i| {
                    Arc::new(Device::from_sim(sim.clone(), Board::PynqZ2).with_faults(&hooks, i))
                })
                .collect::<Vec<_>>();
            let cfg = Config { workers: 1, ..Config::default() };
            let faulted = Coordinator::start_fleet(devices, cfg.clone(), None)
                .map_err(|e| e.to_string())?;
            let plain = Coordinator::start(sim, cfg, None).map_err(|e| e.to_string())?;
            for (k, m) in ALL_METHODS.into_iter().enumerate() {
                let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
                let a = faulted
                    .attribute_blocking(img.clone(), m)
                    .map_err(|e| e.to_string())?;
                let b = plain.attribute_blocking(img, m).map_err(|e| e.to_string())?;
                if a.relevance != b.relevance || a.logits != b.logits || a.pred != b.pred {
                    return Err(format!("{m}: request {k} diverged under zero-plan hooks"));
                }
                if a.device_cycles != b.device_cycles {
                    return Err(format!("{m}: request {k} cycle ledger diverged"));
                }
            }
            if hooks.stats.total_injected() != 0 || hooks.stats.total_detected() != 0 {
                return Err("zero plan injected or detected something".into());
            }
            let sa = faulted.shutdown();
            let sb = plain.shutdown();
            if sa.completed != 3 || sb.completed != 3 {
                return Err(format!(
                    "completed {} vs {} (want 3 each)",
                    sa.completed, sb.completed
                ));
            }
            for (name, snap) in [("faulted", &sa), ("plain", &sb)] {
                if snap.retries != 0
                    || snap.breaker_trips != 0
                    || snap.integrity_failures != 0
                    || snap.reconnects != 0
                    || snap.errors != 0
                {
                    return Err(format!("{name}: recovery counters moved under zero faults"));
                }
            }
            Ok(())
        },
    );
}
