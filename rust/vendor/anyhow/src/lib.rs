//! Offline drop-in for the subset of the [`anyhow`] crate's API that
//! attrax uses: [`Error`], [`Result`], and the `anyhow!` / `bail!` /
//! `ensure!` macros.
//!
//! The sandbox this repository builds in has no crates.io access, so
//! the real `anyhow` cannot be fetched; this crate keeps the call sites
//! source-compatible. Differences from upstream: no backtraces, no
//! error chaining/`context`, and `Error` stores only the rendered
//! message.
//!
//! [`anyhow`]: https://docs.rs/anyhow

use std::fmt;

/// A rendered error message (the `anyhow::Error` stand-in).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like upstream anyhow: any std error converts via `?`. `Error` itself
// deliberately does not implement `std::error::Error`, which keeps this
// blanket impl coherent with `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_debug_show_message() {
        let e = anyhow!("bad {} at {}", "value", 7);
        assert_eq!(e.to_string(), "bad value at 7");
        assert_eq!(format!("{e:?}"), "bad value at 7");
    }

    #[test]
    fn literal_and_expr_forms() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let s = String::from("owned message");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "owned message");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u64> {
            let n: u64 = std::result::Result::Err(io_err())?;
            Ok(n)
        }
        fn g() -> Result<u32> {
            let n = "not a number".parse::<u32>()?;
            Ok(n)
        }
        assert_eq!(f().unwrap_err().to_string(), "disk on fire");
        assert!(g().unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn bail_and_ensure() {
        fn b() -> Result<u32> {
            bail!("nope {}", 1);
        }
        fn e(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        fn bare(x: u32) -> Result<u32> {
            ensure!(x < 10);
            Ok(x)
        }
        assert_eq!(b().unwrap_err().to_string(), "nope 1");
        assert_eq!(e(3).unwrap(), 3);
        assert_eq!(e(30).unwrap_err().to_string(), "x too big: 30");
        assert!(bare(30).unwrap_err().to_string().contains("x < 10"));
    }
}
