//! API-identical stand-in for the PJRT runtime, built when the `pjrt`
//! feature is off (the offline default: the `xla` native crate is not
//! vendored). Every constructor returns `Err`, so callers that probe
//! with `Runtime::cpu()` degrade gracefully — the shadow verifier
//! disables itself, golden-path tests skip.

use std::path::Path;

use crate::model::{Manifest, Params};

const UNAVAILABLE: &str = "PJRT golden runtime unavailable: attrax was built without the \
     `pjrt` feature (the xla_extension crate is not vendored in this environment)";

/// Stub executable; never constructed (loading always fails).
pub struct Executable {
    pub n_outputs: usize,
}

/// Stub runtime; `cpu()` always fails.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn load(
        &self,
        _hlo_path: &Path,
        _manifest: &Manifest,
        _params: &Params,
        _n_outputs: usize,
    ) -> anyhow::Result<Executable> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }

    pub fn load_artifact(
        &self,
        _manifest: &Manifest,
        _params: &Params,
        _name: &str,
        _n_outputs: usize,
    ) -> anyhow::Result<Executable> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }
}

impl Executable {
    pub fn run(&self, _image: &[f32], _img_dims: &[usize]) -> anyhow::Result<Vec<Vec<f32>>> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }
}
