//! The real PJRT runtime, built only with the `pjrt` cargo feature
//! (requires the `xla` native crate — xla_extension 0.5.x).
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//! (64-bit instruction ids), while the text parser reassigns ids. See
//! python/compile/aot.py.

use std::path::Path;

use crate::model::{Manifest, Params};

/// A compiled attribution/forward executable plus its calling convention
/// (model parameters are runtime arguments, in manifest order, followed
/// by the image — keeps HLO text small; weights live in weights.bin).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Pre-built parameter literals in call order.
    param_literals: Vec<xla::Literal>,
    pub n_outputs: usize,
}

/// The PJRT golden runtime: one client, one executable per artifact.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact and bind the model parameters.
    /// `n_outputs` is the arity of the result tuple (forward: 1,
    /// attribution: 2).
    pub fn load(
        &self,
        hlo_path: &Path,
        manifest: &Manifest,
        params: &Params,
        n_outputs: usize,
    ) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {hlo_path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", hlo_path.display()))?;

        // parameter literals in manifest (= PARAM_SPEC) order
        let mut param_literals = Vec::with_capacity(manifest.params.len());
        for entry in &manifest.params {
            let t = params.get(&entry.name)?;
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshaping {}: {e}", entry.name))?;
            param_literals.push(lit);
        }
        Ok(Executable { exe, param_literals, n_outputs })
    }

    /// Convenience: load a named artifact from the manifest.
    pub fn load_artifact(
        &self,
        manifest: &Manifest,
        params: &Params,
        name: &str,
        n_outputs: usize,
    ) -> anyhow::Result<Executable> {
        self.load(&manifest.hlo_path(name)?, manifest, params, n_outputs)
    }
}

impl Executable {
    /// Run with a [3,32,32] (or manifest img_shape) image, returning the
    /// flattened f32 outputs in tuple order.
    pub fn run(&self, image: &[f32], img_dims: &[usize]) -> anyhow::Result<Vec<Vec<f32>>> {
        let dims: Vec<i64> = img_dims.iter().map(|&d| d as i64).collect();
        let img_lit = xla::Literal::vec1(image)
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("image reshape: {e}"))?;

        let mut args: Vec<&xla::Literal> = self.param_literals.iter().collect();
        args.push(&img_lit);

        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e}"))?;
        // aot.py lowers with return_tuple=True
        let elems = tuple
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decompose: {e}"))?;
        anyhow::ensure!(
            elems.len() == self.n_outputs,
            "expected {} outputs, got {}",
            self.n_outputs,
            elems.len()
        );
        elems
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}")))
            .collect()
    }
}
