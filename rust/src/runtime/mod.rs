//! PJRT runtime (S8): loads the AOT-compiled HLO-text artifacts from
//! Layer 2 and executes them on the XLA CPU client — the float *golden
//! path* the fixed-point simulator is validated against, and the
//! shadow-verification backend of the serving coordinator.
//!
//! The real implementation needs the `xla` native crate (xla_extension
//! 0.5.x), which is not available in the offline build environment, so
//! it is gated behind the `pjrt` cargo feature. Without the feature an
//! API-identical stub is built whose `Runtime::cpu()` returns `Err`;
//! everything that uses the golden path (shadow verifier, golden
//! integration tests, the fig-3/heatmap demos) probes that constructor
//! and degrades gracefully.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, Runtime};
