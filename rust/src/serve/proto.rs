//! Wire protocol for the networked serving subsystem (std-only).
//!
//! Every message is one *frame*:
//!
//! ```text
//! offset  size  field
//! 0       4     magic 0x5841_4901 ("XAI\x01", little-endian)
//! 4       4     header_len  H (LE u32, 1 ..= 64 KiB)
//! 8       4     payload_len P (LE u32, 0 ..= 64 MiB)
//! 12      H     header: compact JSON object, {"t":"req"|"resp"|"err", ...}
//! 12+H    P     payload: raw little-endian f32s
//! ```
//!
//! The JSON header (produced/consumed by [`crate::util::json`]) carries
//! the small typed fields; the bulk numerics ride in the raw payload so
//! image and heatmap f32s round-trip bit-exactly with no text-float
//! loss. Payload layout per kind:
//!
//! * `req`  — `n * elems` input-image f32s.
//! * `resp` — `n * elems` heatmap f32s, then `n * out_n` logit f32s
//!   (preds and modeled device cycles are small and ride in the
//!   header).
//! * `err`  — empty; the typed code ([`ErrCode`]) is in the header.
//!
//! Decoding is defensive: length caps are checked *before* any
//! allocation, malformed input yields a typed [`ProtoError`] (never a
//! panic), and a clean EOF between frames is distinguished from a
//! truncated frame.
//!
//! **Payload integrity (optional, version-negotiated).** A frame may
//! carry a `"crc"` header field: the IEEE CRC-32 of its payload bytes.
//! Decoders that predate the field ignore it (unknown header fields
//! are skipped), so old clients and servers interoperate unchanged; a
//! decoder that *does* see it verifies the payload and reports a
//! mismatch as the typed [`ProtoError::Integrity`] — a corrupted
//! heatmap or image is detected on the wire instead of shipped as
//! plausible-looking data. Requests opt in by setting
//! [`RequestFrame::with_crc`]; the server echoes the protection on the
//! response iff the request carried it.

use std::fmt;
use std::io::{Read, Write};

use crate::attribution::Method;
use crate::util::json::{arr, num, obj, s, Json};

/// Frame magic: "XAI" + version 1, read as a LE u32.
pub const MAGIC: u32 = 0x5841_4901;
/// Fixed preamble: magic + header_len + payload_len.
pub const PREAMBLE_LEN: usize = 12;
/// Cap on the JSON header (a request header is ~100 bytes).
pub const MAX_HEADER_BYTES: usize = 64 * 1024;
/// Cap on the raw payload: bounds decode-side allocation.
pub const MAX_PAYLOAD_BYTES: usize = 64 * 1024 * 1024;
/// Cap on images per request frame (admission checks it too).
pub const MAX_IMAGES_PER_FRAME: usize = 64;
/// Cap on the optional `slo_class` header field (a class name is a
/// short word like "gold"; anything longer is malformed, not data).
pub const MAX_SLO_CLASS_BYTES: usize = 64;

/// IEEE CRC-32 over payload bytes (shared with the plan's weight-slab
/// integrity manifest — [`crate::util::crc`]).
pub use crate::util::crc::crc32;

/// Typed rejection codes carried by error frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// Load shed: connection pool or request queue is full. Retry later.
    Busy,
    /// The server is draining (shutdown) or the coordinator is gone.
    Closed,
    /// The frame was well-formed enough to answer but semantically
    /// invalid (wrong image size, unknown method, oversized batch).
    BadRequest,
    /// The request's deadline elapsed before a response was ready.
    DeadlineExceeded,
    /// An integrity check failed (payload CRC mismatch on the wire, or
    /// a weight/gradient checksum violation on the device) and the
    /// result could not be recovered. Safe to resubmit.
    Integrity,
}

impl ErrCode {
    pub fn name(self) -> &'static str {
        match self {
            ErrCode::Busy => "busy",
            ErrCode::Closed => "closed",
            ErrCode::BadRequest => "bad_request",
            ErrCode::DeadlineExceeded => "deadline_exceeded",
            ErrCode::Integrity => "integrity",
        }
    }

    pub fn parse(text: &str) -> Option<ErrCode> {
        match text {
            "busy" => Some(ErrCode::Busy),
            "closed" => Some(ErrCode::Closed),
            "bad_request" => Some(ErrCode::BadRequest),
            "deadline_exceeded" => Some(ErrCode::DeadlineExceeded),
            "integrity" => Some(ErrCode::Integrity),
            _ => None,
        }
    }
}

impl fmt::Display for ErrCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Attribution request: `n` same-shape images in one frame.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestFrame {
    /// Client-chosen correlation id, echoed on the response.
    pub id: u64,
    pub method: Method,
    pub target: Option<usize>,
    /// Images in this frame (1 ..= [`MAX_IMAGES_PER_FRAME`]).
    pub n: usize,
    /// f32 elements per image.
    pub elems: usize,
    /// Per-request deadline; None = server default.
    pub deadline_ms: Option<u64>,
    /// Attach a payload CRC-32 and ask the server to do the same on
    /// the response. Decode sets this iff the frame carried a `"crc"`
    /// field (and the check passed).
    pub with_crc: bool,
    /// Trace-capture correlation tag: replay sets this to the
    /// original frame id when re-sending a recorded request, so the
    /// far end's own trace joins back to the source capture.
    /// Version-negotiated like `crc` — encoded only when `Some`, and
    /// old peers skip the unknown header field.
    pub trace_seq: Option<u64>,
    /// SLO class name (e.g. `"gold"`): the server resolves it against
    /// its loaded `*.slo.json` spec at admission and publishes the
    /// request into that class's latency histogram and good/bad
    /// counters. Version-negotiated like `crc`/`trace_seq` — encoded
    /// only when `Some`, skipped by old peers. A name the server's
    /// spec does not know is answered with a typed
    /// [`ErrCode::BadRequest`].
    pub slo_class: Option<String>,
    /// `n * elems` f32s, image-major.
    pub images: Vec<f32>,
}

/// Attribution response for one request frame.
#[derive(Clone, Debug, PartialEq)]
pub struct ResponseFrame {
    pub id: u64,
    pub n: usize,
    /// Heatmap f32s per image.
    pub elems: usize,
    /// Logit f32s per image.
    pub out_n: usize,
    /// Predicted class per image.
    pub preds: Vec<usize>,
    /// Modeled device cycles per image (the Table-IV number).
    pub device_cycles: Vec<u64>,
    /// Payload protected by a CRC-32 header field (see
    /// [`RequestFrame::with_crc`]); set by the server iff the request
    /// asked for it.
    pub with_crc: bool,
    /// `n * out_n` f32s, image-major.
    pub logits: Vec<f32>,
    /// `n * elems` relevance f32s, image-major.
    pub relevance: Vec<f32>,
}

/// Typed rejection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ErrorFrame {
    /// Request id this answers, or 0 when no request was decodable.
    pub id: u64,
    pub code: ErrCode,
    pub msg: String,
}

/// Any frame on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Request(RequestFrame),
    Response(ResponseFrame),
    Error(ErrorFrame),
}

/// Decode failure. Every malformed input maps here — decode never
/// panics and never allocates past the frame caps.
#[derive(Debug)]
pub enum ProtoError {
    /// Clean EOF at a frame boundary (peer closed between frames).
    Eof,
    /// Stream ended mid-frame.
    Truncated,
    BadMagic(u32),
    /// A length field exceeds the frame caps (checked pre-allocation).
    TooLarge { header_len: usize, payload_len: usize },
    /// Header JSON, field types, or payload-length arithmetic is wrong.
    Malformed(String),
    /// The header's `"crc"` field does not match the payload bytes:
    /// the payload was corrupted in flight (or by the fault injector).
    Integrity { expected: u32, got: u32 },
    Io(std::io::Error),
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtoError::Eof => write!(f, "connection closed"),
            ProtoError::Truncated => write!(f, "stream ended mid-frame"),
            ProtoError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            ProtoError::TooLarge { header_len, payload_len } => write!(
                f,
                "frame too large: header {header_len} B (cap {MAX_HEADER_BYTES}), \
                 payload {payload_len} B (cap {MAX_PAYLOAD_BYTES})"
            ),
            ProtoError::Malformed(msg) => write!(f, "malformed frame: {msg}"),
            ProtoError::Integrity { expected, got } => write!(
                f,
                "payload crc mismatch: header says {expected:#010x}, payload is {got:#010x}"
            ),
            ProtoError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for ProtoError {}

fn malformed(msg: impl Into<String>) -> ProtoError {
    ProtoError::Malformed(msg.into())
}

/// Validated frame lengths from the 12-byte preamble.
#[derive(Clone, Copy, Debug)]
pub struct Preamble {
    pub header_len: usize,
    pub payload_len: usize,
}

/// Parse + validate the fixed preamble. Rejects bad magic and
/// over-cap lengths before the caller allocates anything.
pub fn parse_preamble(pre: &[u8; PREAMBLE_LEN]) -> Result<Preamble, ProtoError> {
    let magic = u32::from_le_bytes([pre[0], pre[1], pre[2], pre[3]]);
    if magic != MAGIC {
        return Err(ProtoError::BadMagic(magic));
    }
    let header_len = u32::from_le_bytes([pre[4], pre[5], pre[6], pre[7]]) as usize;
    let payload_len = u32::from_le_bytes([pre[8], pre[9], pre[10], pre[11]]) as usize;
    if header_len > MAX_HEADER_BYTES || payload_len > MAX_PAYLOAD_BYTES {
        return Err(ProtoError::TooLarge { header_len, payload_len });
    }
    if header_len == 0 {
        return Err(malformed("empty header"));
    }
    Ok(Preamble { header_len, payload_len })
}

fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), ProtoError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Err(ProtoError::Truncated),
        Err(e) => Err(ProtoError::Io(e)),
    }
}

/// Read header + payload for an already-validated preamble and decode.
pub fn read_body<R: Read>(r: &mut R, pre: &Preamble) -> Result<Frame, ProtoError> {
    let mut header = vec![0u8; pre.header_len];
    read_full(r, &mut header)?;
    let mut payload = vec![0u8; pre.payload_len];
    read_full(r, &mut payload)?;
    decode(&header, &payload)
}

/// Read one whole frame. `Ok(None)` is a clean EOF at a frame
/// boundary; EOF anywhere inside a frame is [`ProtoError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, ProtoError> {
    let mut pre = [0u8; PREAMBLE_LEN];
    let mut have = 0usize;
    while have < PREAMBLE_LEN {
        match r.read(&mut pre[have..]) {
            Ok(0) if have == 0 => return Ok(None),
            Ok(0) => return Err(ProtoError::Truncated),
            Ok(k) => have += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    let p = parse_preamble(&pre)?;
    read_body(r, &p).map(Some)
}

// -- header field helpers ----------------------------------------------------

fn field_u64(j: &Json, key: &str) -> Result<u64, ProtoError> {
    let v = j
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| malformed(format!("missing numeric {key:?}")))?;
    if v < 0.0 || v.fract() != 0.0 {
        return Err(malformed(format!("{key:?} must be a non-negative integer")));
    }
    Ok(v as u64)
}

fn field_usize(j: &Json, key: &str) -> Result<usize, ProtoError> {
    Ok(field_u64(j, key)? as usize)
}

fn opt_field_u64(j: &Json, key: &str) -> Result<Option<u64>, ProtoError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(_) => field_u64(j, key).map(Some),
    }
}

/// Verify the optional `"crc"` header field against the payload.
/// Returns whether the field was present; a present-but-wrong CRC is
/// the typed [`ProtoError::Integrity`].
fn check_crc(j: &Json, payload: &[u8]) -> Result<bool, ProtoError> {
    match opt_field_u64(j, "crc")? {
        None => Ok(false),
        Some(v) => {
            if v > u32::MAX as u64 {
                return Err(malformed("crc exceeds 32 bits"));
            }
            let expected = v as u32;
            let got = crc32(payload);
            if got != expected {
                return Err(ProtoError::Integrity { expected, got });
            }
            Ok(true)
        }
    }
}

/// Decode a header + payload pair into a typed frame.
pub fn decode(header: &[u8], payload: &[u8]) -> Result<Frame, ProtoError> {
    let text = std::str::from_utf8(header).map_err(|_| malformed("header is not utf-8"))?;
    let j = Json::parse(text).map_err(|e| malformed(format!("header json: {e}")))?;
    let kind = j
        .get("t")
        .and_then(Json::as_str)
        .ok_or_else(|| malformed("missing frame kind \"t\""))?;
    match kind {
        "req" => decode_request(&j, payload),
        "resp" => decode_response(&j, payload),
        "err" => decode_error(&j, payload),
        other => Err(malformed(format!("unknown frame kind {other:?}"))),
    }
}

fn decode_request(j: &Json, payload: &[u8]) -> Result<Frame, ProtoError> {
    let id = field_u64(j, "id")?;
    let method = j
        .get("method")
        .and_then(Json::as_str)
        .and_then(Method::parse)
        .ok_or_else(|| malformed("missing or unknown method"))?;
    let n = field_usize(j, "n")?;
    let elems = field_usize(j, "elems")?;
    if n == 0 || elems == 0 {
        return Err(malformed("n and elems must be positive"));
    }
    if n > MAX_IMAGES_PER_FRAME {
        return Err(malformed(format!("n {n} exceeds {MAX_IMAGES_PER_FRAME} images per frame")));
    }
    let target = match j.get("target") {
        None | Some(Json::Null) => None,
        Some(_) => Some(field_usize(j, "target")?),
    };
    let deadline_ms = opt_field_u64(j, "deadline_ms")?;
    let trace_seq = opt_field_u64(j, "trace_seq")?;
    let slo_class = match j.get("slo_class") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let name = v.as_str().ok_or_else(|| malformed("slo_class must be a string"))?;
            if name.is_empty() || name.len() > MAX_SLO_CLASS_BYTES {
                return Err(malformed(format!(
                    "slo_class must be 1 ..= {MAX_SLO_CLASS_BYTES} bytes"
                )));
            }
            Some(name.to_string())
        }
    };
    let want = n
        .checked_mul(elems)
        .and_then(|x| x.checked_mul(4))
        .ok_or_else(|| malformed("n * elems overflows"))?;
    if payload.len() != want {
        return Err(malformed(format!("payload is {} B, n*elems*4 = {want} B", payload.len())));
    }
    let with_crc = check_crc(j, payload)?;
    let images = le_to_f32s(payload);
    Ok(Frame::Request(RequestFrame {
        id,
        method,
        target,
        n,
        elems,
        deadline_ms,
        with_crc,
        trace_seq,
        slo_class,
        images,
    }))
}

fn decode_response(j: &Json, payload: &[u8]) -> Result<Frame, ProtoError> {
    let id = field_u64(j, "id")?;
    let n = field_usize(j, "n")?;
    let elems = field_usize(j, "elems")?;
    let out_n = field_usize(j, "out_n")?;
    if n == 0 {
        return Err(malformed("n must be positive"));
    }
    // A response claiming n images but zero data per image would
    // decode to an empty-but-"valid" frame; reject it like the
    // request-side n/elems check does.
    if elems == 0 || out_n == 0 {
        return Err(malformed("elems and out_n must be positive"));
    }
    let preds_json =
        j.get("preds").and_then(Json::as_arr).ok_or_else(|| malformed("missing preds"))?;
    let cycles_json = j
        .get("device_cycles")
        .and_then(Json::as_arr)
        .ok_or_else(|| malformed("missing device_cycles"))?;
    if preds_json.len() != n || cycles_json.len() != n {
        return Err(malformed("preds/device_cycles length != n"));
    }
    let mut preds = Vec::with_capacity(n);
    for p in preds_json {
        preds.push(p.as_usize().ok_or_else(|| malformed("bad pred"))?);
    }
    let mut device_cycles = Vec::with_capacity(n);
    for c in cycles_json {
        let v = c.as_f64().ok_or_else(|| malformed("bad device cycle count"))?;
        if v < 0.0 {
            return Err(malformed("negative device cycle count"));
        }
        device_cycles.push(v as u64);
    }
    let rel_elems = n.checked_mul(elems).ok_or_else(|| malformed("n * elems overflows"))?;
    let logit_elems = n.checked_mul(out_n).ok_or_else(|| malformed("n * out_n overflows"))?;
    let want = rel_elems
        .checked_add(logit_elems)
        .and_then(|x| x.checked_mul(4))
        .ok_or_else(|| malformed("payload size overflows"))?;
    if payload.len() != want {
        return Err(malformed(format!(
            "payload is {} B, n*(elems+out_n)*4 = {want} B",
            payload.len()
        )));
    }
    let with_crc = check_crc(j, payload)?;
    // decode the two ranges straight from the payload bytes: no
    // intermediate full-payload Vec for a frame that can be 64 MiB
    let relevance = le_to_f32s(&payload[..rel_elems * 4]);
    let logits = le_to_f32s(&payload[rel_elems * 4..]);
    Ok(Frame::Response(ResponseFrame {
        id,
        n,
        elems,
        out_n,
        preds,
        device_cycles,
        with_crc,
        logits,
        relevance,
    }))
}

fn decode_error(j: &Json, payload: &[u8]) -> Result<Frame, ProtoError> {
    if !payload.is_empty() {
        return Err(malformed("error frames carry no payload"));
    }
    let id = field_u64(j, "id")?;
    let code = j
        .get("code")
        .and_then(Json::as_str)
        .and_then(ErrCode::parse)
        .ok_or_else(|| malformed("missing or unknown error code"))?;
    let msg = j.get("msg").and_then(Json::as_str).unwrap_or("").to_string();
    Ok(Frame::Error(ErrorFrame { id, code, msg }))
}

// -- encoding ----------------------------------------------------------------

/// Raw little-endian f32 bytes (the payload representation).
pub fn f32s_to_le(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_le`] (bit-exact; trailing partial chunk dropped).
pub fn le_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn encode_parts(f: &Frame) -> (String, Vec<u8>) {
    match f {
        Frame::Request(q) => {
            let mut pairs = vec![
                ("t", s("req")),
                ("id", num(q.id as f64)),
                ("method", s(q.method.name())),
                ("n", num(q.n as f64)),
                ("elems", num(q.elems as f64)),
            ];
            if let Some(t) = q.target {
                pairs.push(("target", num(t as f64)));
            }
            if let Some(d) = q.deadline_ms {
                pairs.push(("deadline_ms", num(d as f64)));
            }
            if let Some(ts) = q.trace_seq {
                pairs.push(("trace_seq", num(ts as f64)));
            }
            if let Some(c) = &q.slo_class {
                pairs.push(("slo_class", s(c)));
            }
            let payload = f32s_to_le(&q.images);
            if q.with_crc {
                pairs.push(("crc", num(crc32(&payload) as f64)));
            }
            (obj(pairs).to_string(), payload)
        }
        Frame::Response(r) => {
            let preds = arr(r.preds.iter().map(|&p| num(p as f64)).collect());
            let cycles = arr(r.device_cycles.iter().map(|&c| num(c as f64)).collect());
            let mut payload = f32s_to_le(&r.relevance);
            payload.extend_from_slice(&f32s_to_le(&r.logits));
            let mut pairs = vec![
                ("t", s("resp")),
                ("id", num(r.id as f64)),
                ("n", num(r.n as f64)),
                ("elems", num(r.elems as f64)),
                ("out_n", num(r.out_n as f64)),
                ("preds", preds),
                ("device_cycles", cycles),
            ];
            if r.with_crc {
                pairs.push(("crc", num(crc32(&payload) as f64)));
            }
            (obj(pairs).to_string(), payload)
        }
        Frame::Error(e) => {
            let header = obj(vec![
                ("t", s("err")),
                ("id", num(e.id as f64)),
                ("code", s(e.code.name())),
                ("msg", s(&e.msg)),
            ]);
            (header.to_string(), Vec::new())
        }
    }
}

/// Encode a frame to bytes (preamble + header + payload).
pub fn encode(f: &Frame) -> std::io::Result<Vec<u8>> {
    let (header, payload) = encode_parts(f);
    if header.len() > MAX_HEADER_BYTES || payload.len() > MAX_PAYLOAD_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("frame exceeds caps: header {} B, payload {} B", header.len(), payload.len()),
        ));
    }
    let mut buf = Vec::with_capacity(PREAMBLE_LEN + header.len() + payload.len());
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&(header.len() as u32).to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(header.as_bytes());
    buf.extend_from_slice(&payload);
    Ok(buf)
}

/// Encode + write + flush one frame as a single write.
pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> std::io::Result<()> {
    let buf = encode(f)?;
    w.write_all(&buf)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req() -> Frame {
        Frame::Request(RequestFrame {
            id: 7,
            method: Method::Guided,
            target: Some(2),
            n: 2,
            elems: 3,
            deadline_ms: Some(1500),
            with_crc: false,
            trace_seq: None,
            slo_class: None,
            images: vec![0.0, -1.5, f32::MIN_POSITIVE, 1.0, 2.5e-3, 1e20],
        })
    }

    fn resp() -> Frame {
        Frame::Response(ResponseFrame {
            id: 9,
            n: 2,
            elems: 2,
            out_n: 3,
            preds: vec![1, 0],
            device_cycles: vec![123_456, 123_456],
            with_crc: false,
            logits: vec![0.1, -0.2, 0.3, 0.4, 0.5, -0.6],
            relevance: vec![1.0, -2.0, 3.0, -4.0],
        })
    }

    #[test]
    fn request_roundtrip_bit_exact() {
        let f = req();
        let bytes = encode(&f).unwrap();
        let back = read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn response_roundtrip_bit_exact() {
        let f = resp();
        let bytes = encode(&f).unwrap();
        let back = read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn error_roundtrip() {
        let codes = [
            ErrCode::Busy,
            ErrCode::Closed,
            ErrCode::BadRequest,
            ErrCode::DeadlineExceeded,
            ErrCode::Integrity,
        ];
        for code in codes {
            let f = Frame::Error(ErrorFrame { id: 3, code, msg: "q \"full\"\n".into() });
            let bytes = encode(&f).unwrap();
            let back = read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap();
            assert_eq!(back, f);
        }
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(matches!(read_frame(&mut Cursor::new(&[] as &[u8])), Ok(None)));
    }

    #[test]
    fn truncation_is_typed() {
        let bytes = encode(&req()).unwrap();
        for cut in 1..bytes.len() {
            let r = read_frame(&mut Cursor::new(&bytes[..cut]));
            assert!(r.is_err(), "prefix of {cut} bytes must not decode");
        }
    }

    #[test]
    fn oversized_lengths_rejected_before_allocation() {
        let mut pre = [0u8; PREAMBLE_LEN];
        pre[0..4].copy_from_slice(&MAGIC.to_le_bytes());
        pre[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        pre[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(parse_preamble(&pre), Err(ProtoError::TooLarge { .. })));
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode(&req()).unwrap();
        bytes[0] ^= 0xff;
        assert!(matches!(read_frame(&mut Cursor::new(&bytes)), Err(ProtoError::BadMagic(_))));
    }

    #[test]
    fn payload_size_mismatch_rejected() {
        let header = br#"{"t":"req","id":1,"method":"guided","n":1,"elems":4}"#;
        assert!(matches!(decode(header, &[0u8; 12]), Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn crc_roundtrip_both_kinds() {
        for f in [req(), resp()] {
            let f = match f {
                Frame::Request(mut q) => {
                    q.with_crc = true;
                    Frame::Request(q)
                }
                Frame::Response(mut r) => {
                    r.with_crc = true;
                    Frame::Response(r)
                }
                e => e,
            };
            let bytes = encode(&f).unwrap();
            let back = read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap();
            assert_eq!(back, f, "crc-protected frame must round-trip with with_crc set");
        }
    }

    #[test]
    fn flipped_payload_byte_is_typed_integrity_error_with_crc() {
        let f = match req() {
            Frame::Request(mut q) => {
                q.with_crc = true;
                Frame::Request(q)
            }
            other => other,
        };
        let mut bytes = encode(&f).unwrap();
        let last = bytes.len() - 1; // payload trails the frame
        bytes[last] ^= 0x40;
        match read_frame(&mut Cursor::new(&bytes)) {
            Err(ProtoError::Integrity { expected, got }) => assert_ne!(expected, got),
            other => panic!("corrupted crc frame must yield Integrity, got {other:?}"),
        }
    }

    #[test]
    fn flipped_payload_byte_undetected_without_crc() {
        // Documents *why* the crc field exists: without it a payload
        // flip decodes as a different-but-valid frame.
        let mut bytes = encode(&req()).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        let back = read_frame(&mut Cursor::new(&bytes)).unwrap().unwrap();
        assert_ne!(back, req());
        assert!(matches!(back, Frame::Request(_)));
    }

    #[test]
    fn zero_data_response_rejected() {
        for (elems, out_n) in [(0usize, 3usize), (4, 0), (0, 0)] {
            let header = format!(
                "{{\"t\":\"resp\",\"id\":1,\"n\":1,\"elems\":{elems},\"out_n\":{out_n},\
                 \"preds\":[0],\"device_cycles\":[1]}}"
            );
            assert!(
                matches!(decode(header.as_bytes(), &[]), Err(ProtoError::Malformed(_))),
                "response with elems={elems} out_n={out_n} must be rejected"
            );
        }
    }
}
