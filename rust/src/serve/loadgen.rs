//! Load generator for a serve endpoint: N persistent connections,
//! open-loop (target RPS with exponential gaps) or closed-loop
//! hammering, configurable method mix and frame batch size. Emits the
//! numbers `BENCH_serve.json` records: sustained RPS, latency
//! percentiles, shed rate.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use super::client::{Client, ClientError};
use super::proto::ErrCode;
use crate::attribution::{Method, ALL_METHODS};
use crate::obs::export::{self, StatsSummary};
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Pcg32;
use crate::util::stats::Samples;

/// Workload description.
#[derive(Clone, Debug)]
pub struct Spec {
    pub addr: String,
    /// Concurrent client connections.
    pub conns: usize,
    /// Total request frames across all connections (0 = no frame
    /// limit, run until `secs`).
    pub requests: usize,
    /// Wall-clock budget in seconds; whichever of `requests`/`secs`
    /// hits first ends the run.
    pub secs: f64,
    /// Aggregate target arrival rate in frames/s (0 = closed loop).
    pub rps: f64,
    /// Images per request frame.
    pub batch: usize,
    /// f32s per image (must match the served model's input).
    pub elems: usize,
    /// Fixed method, or None to cycle through all three.
    pub method: Option<Method>,
    /// Per-request deadline (0 = none).
    pub timeout_ms: u64,
    pub seed: u64,
    /// Path to an `attrax-trace/v1` capture: replay its recorded
    /// request frames (method/batch mix and payloads) as the workload
    /// instead of synthesizing random images. `batch`/`elems`/`method`
    /// are ignored in this mode — the frames carry their own.
    pub trace: Option<String>,
    /// Address of the server's stats exposition endpoint
    /// (`serve --stats-addr`): scraped once before and once after the
    /// run, adding the server-side stage/unit breakdown (and a counter
    /// monotonicity check) to the report.
    pub stats_addr: Option<String>,
    /// SLO class mix (`--class-mix gold:1,silver:2`): every request is
    /// tagged with a class name, drawn from a weighted round-robin
    /// schedule over one *shared* sequence across connections, so the
    /// per-class request totals of a fixed-count run are deterministic
    /// regardless of thread scheduling. Empty = untagged requests.
    pub class_mix: Vec<(String, u32)>,
}

impl Default for Spec {
    fn default() -> Self {
        Spec {
            addr: "127.0.0.1:7878".into(),
            conns: 4,
            requests: 0,
            secs: 5.0,
            rps: 0.0,
            batch: 1,
            elems: 3 * 32 * 32,
            method: None,
            timeout_ms: 2000,
            seed: 42,
            trace: None,
            stats_addr: None,
            class_mix: Vec::new(),
        }
    }
}

/// Parse a `--class-mix` argument: comma-separated `name:weight`
/// pairs (`gold:1,silver:2,bronze:5`); weights are relative request
/// shares in the round-robin schedule.
pub fn parse_class_mix(text: &str) -> anyhow::Result<Vec<(String, u32)>> {
    let mut out: Vec<(String, u32)> = Vec::new();
    for part in text.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, w) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("class mix entry {part:?} is not name:weight"))?;
        anyhow::ensure!(!name.is_empty(), "class mix entry {part:?} has an empty name");
        let weight: u32 =
            w.parse().map_err(|_| anyhow::anyhow!("class {name:?}: weight {w:?} is not a u32"))?;
        anyhow::ensure!(weight > 0, "class {name:?}: weight must be positive");
        if out.iter().any(|(n, _)| n == name) {
            anyhow::bail!("class {name:?} appears twice in the mix");
        }
        out.push((name.to_string(), weight));
    }
    anyhow::ensure!(!out.is_empty(), "empty class mix");
    Ok(out)
}

/// Server-side view of a load run, scraped from the stats endpoint.
#[derive(Clone, Debug)]
pub struct ServerStats {
    /// Every unlabeled `_total` counter present in the pre-run scrape
    /// was `<=` its value in the post-run scrape (cumulative counters
    /// never move backwards).
    pub monotone: bool,
    /// Post-run scrape counters exactly equal the coordinator's final
    /// [`crate::coordinator::metrics::Snapshot`]. Only a harness that
    /// holds both sides can compute this (`loadgen --smoke` does);
    /// `None` = not checked.
    pub reconciled: Option<bool>,
    /// Parsed post-run scrape: counters, per-stage latency quantiles,
    /// per-unit engine profile, per-device fleet load.
    pub summary: StatsSummary,
}

/// Per-class client-side latency row of a classed run (`--class-mix`).
#[derive(Clone, Debug)]
pub struct ClassLat {
    pub class: String,
    /// Completed (Ok) frames tagged with this class.
    pub ok: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// Aggregate outcome of one load run.
#[derive(Clone, Debug)]
pub struct Report {
    pub sent: u64,
    pub ok: u64,
    /// `Busy` rejections (connection pool or queue full).
    pub shed: u64,
    pub deadline_exceeded: u64,
    pub closed: u64,
    pub errors: u64,
    pub wall_s: f64,
    /// Completed request frames per second.
    pub sustained_rps: f64,
    /// Completed images per second (`sustained_rps * batch`).
    pub image_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// shed / sent.
    pub shed_rate: f64,
    /// Per-class latency rows, in mix order (empty without
    /// `class_mix`).
    pub classes: Vec<ClassLat>,
    /// Server-side breakdown (present when the spec carried a
    /// `stats_addr` and both scrapes succeeded).
    pub server_stats: Option<ServerStats>,
}

impl Report {
    pub fn to_json(&self, spec: &Spec) -> Json {
        let mut fields = vec![
            ("bench", s("serve_loadgen")),
            ("addr", s(&spec.addr)),
            ("conns", num(spec.conns as f64)),
            ("batch", num(spec.batch as f64)),
            ("elems", num(spec.elems as f64)),
            ("rps_target", num(spec.rps)),
            ("timeout_ms", num(spec.timeout_ms as f64)),
            ("trace", s(spec.trace.as_deref().unwrap_or(""))),
            ("sent", num(self.sent as f64)),
            ("ok", num(self.ok as f64)),
            ("shed", num(self.shed as f64)),
            ("deadline_exceeded", num(self.deadline_exceeded as f64)),
            ("closed", num(self.closed as f64)),
            ("errors", num(self.errors as f64)),
            ("wall_s", num(self.wall_s)),
            ("sustained_rps", num(self.sustained_rps)),
            ("image_rps", num(self.image_rps)),
            (
                "latency_ms",
                obj(vec![
                    ("mean", num(self.mean_ms)),
                    ("p50", num(self.p50_ms)),
                    ("p95", num(self.p95_ms)),
                    ("p99", num(self.p99_ms)),
                ]),
            ),
            ("shed_rate", num(self.shed_rate)),
            (
                "classes",
                crate::util::json::arr(
                    self.classes
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("class", s(&c.class)),
                                ("ok", num(c.ok as f64)),
                                ("mean_ms", num(c.mean_ms)),
                                ("p50_ms", num(c.p50_ms)),
                                ("p95_ms", num(c.p95_ms)),
                                ("p99_ms", num(c.p99_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(ss) = &self.server_stats {
            fields.push((
                "server_stats",
                obj(vec![
                    ("monotone", Json::Bool(ss.monotone)),
                    (
                        "reconciled",
                        match ss.reconciled {
                            Some(b) => Json::Bool(b),
                            None => Json::Null,
                        },
                    ),
                    ("summary", ss.summary.to_json()),
                ]),
            ));
        }
        obj(fields)
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "sent={} ok={} shed={} deadline-exceeded={} closed={} errors={} wall={:.2}s\n\
             sustained: {:.1} req/s ({:.1} img/s)\n\
             latency: mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n\
             shed rate: {:.1}%",
            self.sent,
            self.ok,
            self.shed,
            self.deadline_exceeded,
            self.closed,
            self.errors,
            self.wall_s,
            self.sustained_rps,
            self.image_rps,
            self.mean_ms,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            100.0 * self.shed_rate,
        );
        for c in &self.classes {
            out.push_str(&format!(
                "\nclass {:<12} ok={:<7} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms",
                c.class, c.ok, c.mean_ms, c.p50_ms, c.p95_ms, c.p99_ms,
            ));
        }
        if let Some(ss) = &self.server_stats {
            out.push_str("\nserver stages (from --stats-addr scrape):");
            for st in &ss.summary.stages {
                out.push_str(&format!(
                    "\n  {:<14} n={:<7} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms",
                    st.stage, st.count, st.mean_ms, st.p50_ms, st.p95_ms, st.p99_ms,
                ));
            }
            if !ss.monotone {
                out.push_str("\nWARNING: server counters moved backwards between scrapes");
            }
        }
        out
    }
}

#[derive(Default)]
struct ConnStats {
    sent: u64,
    ok: u64,
    shed: u64,
    deadline: u64,
    closed: u64,
    errors: u64,
    lat_ms: Vec<f64>,
    /// Per-mix-class Ok latencies (indexed like `Spec::class_mix`).
    class_lat_ms: Vec<Vec<f64>>,
}

/// Expand the weighted mix into the repeating class-index schedule
/// the shared sequence strides over (`gold:1,silver:2` →
/// `[gold, silver, silver]`).
fn class_schedule(mix: &[(String, u32)]) -> Vec<usize> {
    mix.iter()
        .enumerate()
        .flat_map(|(i, (_, w))| std::iter::repeat(i).take(*w as usize))
        .collect()
}

/// One recorded request frame re-driven as workload.
struct TraceFrame {
    method: Method,
    images: Vec<Vec<f32>>,
}

/// Load the replayable request frames out of a capture (every
/// recorded request is real traffic, whatever its outcome was).
fn load_workload(path: &str) -> anyhow::Result<Vec<TraceFrame>> {
    let (_, records) = crate::obs::trace::TraceReader::open(path)?.read_all()?;
    let mut out = Vec::with_capacity(records.len());
    for rec in records {
        let req = rec.req;
        if req.elems == 0 {
            continue;
        }
        let images: Vec<Vec<f32>> =
            req.images.chunks_exact(req.elems).map(<[f32]>::to_vec).collect();
        if !images.is_empty() {
            out.push(TraceFrame { method: req.method, images });
        }
    }
    anyhow::ensure!(!out.is_empty(), "trace {path} holds no replayable request frames");
    Ok(out)
}

/// Run the workload. Errors only when no connection could be
/// established at all; per-request failures are counted in the report.
pub fn run(spec: &Spec) -> anyhow::Result<Report> {
    anyhow::ensure!(spec.conns > 0, "need at least one connection");
    let max_batch = super::proto::MAX_IMAGES_PER_FRAME;
    anyhow::ensure!(spec.batch > 0 && spec.batch <= max_batch, "batch must be 1..={max_batch}");
    anyhow::ensure!(spec.elems > 0, "elems must be positive");
    let workload = match &spec.trace {
        Some(path) => Some(load_workload(path)?),
        None => None,
    };
    // pre-run scrape: the baseline for the counter monotonicity check
    let pre_scrape = match &spec.stats_addr {
        Some(a) => Some(scrape_summary(a)?),
        None => None,
    };
    let per_conn_rate = spec.rps / spec.conns as f64;
    // shared frame budget so the total sent honors `requests` exactly
    let budget = AtomicUsize::new(if spec.requests == 0 { usize::MAX } else { spec.requests });
    // class tagging strides this one shared sequence (not per-conn
    // position — per-conn ticket counts vary with scheduling, the
    // shared sequence does not)
    let schedule = class_schedule(&spec.class_mix);
    let class_seq = AtomicUsize::new(0);
    let secs = if spec.secs > 0.0 { spec.secs } else { 3600.0 };
    let stop_at = Instant::now() + Duration::from_secs_f64(secs);
    let t0 = Instant::now();
    let results: Vec<anyhow::Result<ConnStats>> = std::thread::scope(|sc| {
        let budget = &budget;
        let (schedule, class_seq) = (schedule.as_slice(), &class_seq);
        let workload = workload.as_deref();
        let handles: Vec<_> = (0..spec.conns)
            .map(|c| {
                sc.spawn(move || {
                    conn_loop(spec, c, per_conn_rate, budget, stop_at, workload, schedule, class_seq)
                })
            })
            .collect();
        let mut out = Vec::with_capacity(handles.len());
        for h in handles {
            let joined = h.join();
            out.push(joined.unwrap_or_else(|_| Err(anyhow::anyhow!("loadgen thread panicked"))));
        }
        out
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut agg = ConnStats::default();
    agg.class_lat_ms.resize(spec.class_mix.len(), Vec::new());
    let mut first_err = None;
    let mut ok_conns = 0usize;
    for r in results {
        match r {
            Ok(st) => {
                ok_conns += 1;
                agg.sent += st.sent;
                agg.ok += st.ok;
                agg.shed += st.shed;
                agg.deadline += st.deadline;
                agg.closed += st.closed;
                agg.errors += st.errors;
                agg.lat_ms.extend_from_slice(&st.lat_ms);
                for (into, from) in agg.class_lat_ms.iter_mut().zip(&st.class_lat_ms) {
                    into.extend_from_slice(from);
                }
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    if ok_conns == 0 {
        return Err(first_err.unwrap_or_else(|| anyhow::anyhow!("no connections ran")));
    }
    let mut lat = Samples::new();
    for &x in &agg.lat_ms {
        lat.push(x);
    }
    // post-run scrape: counters must only have grown since the pre-run
    // baseline (each scrape is an independent one-shot TCP read)
    let server_stats = match (&spec.stats_addr, pre_scrape) {
        (Some(a), Some(pre)) => {
            let post = scrape_summary(a)?;
            let monotone = pre
                .counters
                .iter()
                .all(|(k, v)| post.counters.get(k).is_some_and(|p| p >= v));
            Some(ServerStats { monotone, reconciled: None, summary: post })
        }
        _ => None,
    };
    Ok(Report {
        sent: agg.sent,
        ok: agg.ok,
        shed: agg.shed,
        deadline_exceeded: agg.deadline,
        closed: agg.closed,
        errors: agg.errors,
        wall_s,
        sustained_rps: if wall_s > 0.0 { agg.ok as f64 / wall_s } else { 0.0 },
        image_rps: if wall_s > 0.0 { (agg.ok * spec.batch as u64) as f64 / wall_s } else { 0.0 },
        mean_ms: lat.mean(),
        p50_ms: lat.percentile(0.50),
        p95_ms: lat.percentile(0.95),
        p99_ms: lat.percentile(0.99),
        shed_rate: if agg.sent > 0 { agg.shed as f64 / agg.sent as f64 } else { 0.0 },
        classes: spec
            .class_mix
            .iter()
            .zip(&agg.class_lat_ms)
            .map(|((name, _), lat_ms)| {
                let mut lat = Samples::new();
                for &x in lat_ms {
                    lat.push(x);
                }
                ClassLat {
                    class: name.clone(),
                    ok: lat_ms.len() as u64,
                    mean_ms: lat.mean(),
                    p50_ms: lat.percentile(0.50),
                    p95_ms: lat.percentile(0.95),
                    p99_ms: lat.percentile(0.99),
                }
            })
            .collect(),
        server_stats,
    })
}

/// One scrape of a stats endpoint, parsed and summarized.
fn scrape_summary(addr: &str) -> anyhow::Result<StatsSummary> {
    let text = export::scrape(addr, Duration::from_secs(5))?;
    Ok(export::summarize(&export::parse(&text)?))
}

fn apply_timeout(client: &mut Client, timeout_ms: u64) -> std::io::Result<()> {
    if timeout_ms > 0 {
        client.set_timeout(Some(Duration::from_millis(timeout_ms)))
    } else {
        Ok(())
    }
}

/// Take one frame ticket from the shared budget (false = exhausted).
fn take_ticket(budget: &AtomicUsize) -> bool {
    budget.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| b.checked_sub(1)).is_ok()
}

#[allow(clippy::too_many_arguments)]
fn conn_loop(
    spec: &Spec,
    cid: usize,
    rate: f64,
    budget: &AtomicUsize,
    stop_at: Instant,
    workload: Option<&[TraceFrame]>,
    schedule: &[usize],
    class_seq: &AtomicUsize,
) -> anyhow::Result<ConnStats> {
    let mut client = Client::connect(spec.addr.as_str())?;
    apply_timeout(&mut client, spec.timeout_ms)?;
    let mut rng = Pcg32::seeded(spec.seed ^ (cid as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let mut st = ConnStats::default();
    st.class_lat_ms.resize(spec.class_mix.len(), Vec::new());
    let mut images: Vec<Vec<f32>> = (0..spec.batch).map(|_| vec![0.0f32; spec.elems]).collect();
    let mut i = 0usize;
    while Instant::now() < stop_at && take_ticket(budget) {
        if rate > 0.0 {
            // open-loop pacing: exponential inter-arrival gaps, capped
            // by the time left in the run so low rates stay faithful
            // and a mis-set rate cannot stall the thread
            let gap = Duration::from_secs_f64(-(1.0 - rng.f32() as f64).ln() / rate);
            let remaining = stop_at.saturating_duration_since(Instant::now());
            std::thread::sleep(gap.min(remaining));
        }
        let (refs, method): (Vec<&[f32]>, Method) = match workload {
            // recorded traffic: stride the capture round-robin across
            // connections so the global method/batch mix is preserved
            Some(frames) => {
                let f = &frames[(cid + i * spec.conns.max(1)) % frames.len()];
                (f.images.iter().map(|v| v.as_slice()).collect(), f.method)
            }
            None => {
                for img in &mut images {
                    for px in img.iter_mut() {
                        *px = rng.f32();
                    }
                }
                (
                    images.iter().map(|v| v.as_slice()).collect(),
                    spec.method.unwrap_or(ALL_METHODS[i % ALL_METHODS.len()]),
                )
            }
        };
        i += 1;
        let class_idx = if schedule.is_empty() {
            None
        } else {
            Some(schedule[class_seq.fetch_add(1, Ordering::Relaxed) % schedule.len()])
        };
        client.set_slo_class(class_idx.map(|ci| spec.class_mix[ci].0.as_str()));
        let t = Instant::now();
        st.sent += 1;
        match client.attribute_batch(&refs, method) {
            Ok(_) => {
                st.ok += 1;
                let ms = t.elapsed().as_secs_f64() * 1e3;
                st.lat_ms.push(ms);
                if let Some(ci) = class_idx {
                    st.class_lat_ms[ci].push(ms);
                }
            }
            Err(ClientError::Rejected { code: ErrCode::Busy, .. }) => st.shed += 1,
            Err(ClientError::Rejected { code: ErrCode::DeadlineExceeded, .. }) => st.deadline += 1,
            Err(ClientError::Rejected { code: ErrCode::Closed, .. }) => {
                st.closed += 1;
                break;
            }
            Err(ClientError::Rejected { .. }) => st.errors += 1,
            Err(_) => {
                // connection state unknown after an i/o or framing
                // error: reconnect once, give up on failure
                st.errors += 1;
                match Client::connect(spec.addr.as_str()) {
                    Ok(c) => {
                        client = c;
                        apply_timeout(&mut client, spec.timeout_ms)?;
                    }
                    Err(_) => break,
                }
            }
        }
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_mix_parses_names_and_weights() {
        let mix = parse_class_mix("gold:1,silver:2,bronze:5").unwrap();
        assert_eq!(
            mix,
            vec![("gold".to_string(), 1), ("silver".to_string(), 2), ("bronze".to_string(), 5)]
        );
        for bad in ["", "gold", "gold:", "gold:0", "gold:-1", ":3", "gold:1,gold:2"] {
            assert!(parse_class_mix(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn schedule_expands_weights_in_mix_order() {
        let mix = parse_class_mix("gold:1,silver:2").unwrap();
        assert_eq!(class_schedule(&mix), vec![0, 1, 1]);
        // exactly-known per-class totals for a fixed request count:
        // 10 tickets over [gold, silver, silver] → 4 gold, 6 silver
        let sched = class_schedule(&mix);
        let picks: Vec<usize> = (0..10).map(|k| sched[k % sched.len()]).collect();
        assert_eq!(picks.iter().filter(|&&c| c == 0).count(), 4);
        assert_eq!(picks.iter().filter(|&&c| c == 1).count(), 6);
        assert!(class_schedule(&[]).is_empty());
    }
}
