//! Networked serving subsystem (S11): the system's front door.
//!
//! Everything before this module is in-process: the coordinator
//! micro-batches requests onto the shared-plan execution core, but
//! nothing outside the process could reach it. `serve` turns the
//! library into a *service*, std-only (`std::net`, no async runtime):
//!
//! * [`proto`] — length-prefixed framed wire protocol: compact JSON
//!   header (via [`crate::util::json`]) + raw little-endian f32
//!   payload, with typed error frames (`Busy`, `Closed`,
//!   `BadRequest`, `DeadlineExceeded`, `Integrity`), hard frame-size
//!   caps, and an optional version-negotiated CRC-32 over the payload
//!   (`with_crc` — servers echo protection iff the request carried it).
//! * [`server`] — `TcpListener` acceptor with a bounded connection
//!   pool feeding the [`crate::coordinator::Coordinator`]: admission
//!   control sheds load with `Busy` instead of queueing unboundedly,
//!   per-request deadlines are enforced server-side, shutdown drains
//!   gracefully (in-flight requests answer, idle and new connections
//!   get `Closed`), and an optional [`crate::faults`] hook injects
//!   admission-site faults for chaos testing.
//! * [`client`] — blocking client with connection reuse,
//!   `attribute` / `attribute_batch`, timeout support, and opt-in
//!   recovery: a mid-frame I/O error marks the stream broken, and the
//!   next attempt reconnects with jittered backoff and resubmits the
//!   identical frame (same id — idempotent on the server side).
//! * [`loadgen`] — multi-connection load generator (`attrax loadgen`)
//!   emitting `BENCH_serve.json`: sustained RPS, p50/p95/p99 latency,
//!   shed rate; `--trace <capture>` replays a recorded traffic mix
//!   instead of synthetic images, and `--stats-addr` scrapes the
//!   server's stats endpoint before and after the run, adding the
//!   server-side per-stage/per-unit breakdown to the report.
//!
//! Observability hooks ([`crate::obs`]): the server stamps a
//! per-request span (stage timestamps + batch/device facts) and hands
//! it to `ServerConfig::recorder` once per answered frame —
//! `serve --trace` plugs in a [`crate::obs::trace::TraceWriter`] to
//! capture the `attrax-trace/v1` artifact that `attrax replay` and
//! `attrax doctor` consume. With no recorder the span costs a few
//! stack stores and zero heap. `ServerConfig::telemetry` feeds every
//! completed span into a lock-free [`crate::obs::telemetry::Registry`],
//! and `ServerConfig::stats_addr` exposes that registry (plus the
//! metrics snapshot and per-device fleet gauges) over a one-shot TCP
//! text endpoint that `attrax top` polls. `ServerConfig::slo` admits
//! the version-negotiated `slo_class` request tag (resolved to a fixed
//! registry slot at admission; unknown names answer `BadRequest`) so
//! `attrax monitor` can evaluate per-class burn rates, and
//! `ServerConfig::push_addr` pushes statsd-style counter deltas over
//! UDP for fleets a collector cannot scrape ([`crate::obs::push`]).
//!
//! Heatmap f32s cross the wire bit-exactly (raw LE payload, no text
//! floats), so a networked client sees the same numerics as an
//! in-process caller — asserted end-to-end in `rust/tests/e2e_net.rs`.

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod server;

pub use client::{Attribution, Client, ClientError};
pub use proto::{ErrCode, Frame, ProtoError};
pub use server::{Server, ServerConfig};
