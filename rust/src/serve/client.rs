//! Blocking client for the serve wire protocol: one reused TCP
//! connection, `attribute` / `attribute_batch` calls, per-request
//! deadlines.
//!
//! The connection is reused across calls (requests are answered in
//! order on one stream, so no multiplexing machinery is needed).
//! Rejections arrive as typed [`ErrCode`]s in
//! [`ClientError::Rejected`] — `Busy` means retry later, `Closed`
//! means the server is going away.

use std::fmt;
use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::proto::{self, ErrCode, Frame, ProtoError, RequestFrame};
use crate::attribution::Method;

/// One image's worth of a serving response.
#[derive(Clone, Debug)]
pub struct Attribution {
    pub pred: usize,
    pub logits: Vec<f32>,
    pub relevance: Vec<f32>,
    /// Modeled device cycles for this image (the Table-IV number).
    pub device_cycles: u64,
}

/// Client-side failure.
#[derive(Debug)]
pub enum ClientError {
    Io(io::Error),
    Proto(ProtoError),
    /// The server answered with a typed error frame.
    Rejected { code: ErrCode, msg: String },
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "i/o: {e}"),
            ClientError::Proto(e) => write!(f, "protocol: {e}"),
            ClientError::Rejected { code, msg } => write!(f, "rejected ({code}): {msg}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<ProtoError> for ClientError {
    fn from(e: ProtoError) -> Self {
        ClientError::Proto(e)
    }
}

/// Extra socket-timeout slack over the request deadline, so a
/// `DeadlineExceeded` error frame can still arrive.
const TIMEOUT_SLACK: Duration = Duration::from_millis(500);

pub struct Client {
    stream: TcpStream,
    next_id: u64,
    timeout: Option<Duration>,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, next_id: 1, timeout: None })
    }

    /// Per-request deadline: sent to the server in the request header
    /// and enforced locally as a socket read timeout (with slack so
    /// the server's `DeadlineExceeded` frame wins the race).
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> io::Result<()> {
        self.timeout = timeout;
        self.stream.set_read_timeout(timeout.map(|t| t + TIMEOUT_SLACK))
    }

    /// Attribute one image.
    pub fn attribute(&mut self, image: &[f32], method: Method) -> Result<Attribution, ClientError> {
        let mut v = self.attribute_batch(&[image], method)?;
        v.pop().ok_or_else(|| ClientError::Proto(ProtoError::Malformed("empty response".into())))
    }

    /// Attribute a batch of same-shape images in one request frame (the
    /// server fans them into the coordinator, which micro-batches them
    /// into one device pass). Results are image-ordered.
    pub fn attribute_batch(
        &mut self,
        images: &[&[f32]],
        method: Method,
    ) -> Result<Vec<Attribution>, ClientError> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let elems = images[0].len();
        if images.iter().any(|i| i.len() != elems) {
            return Err(ClientError::Proto(ProtoError::Malformed(
                "batch images must share one shape".into(),
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut flat = Vec::with_capacity(images.len() * elems);
        for img in images {
            flat.extend_from_slice(img);
        }
        let req = RequestFrame {
            id,
            method,
            target: None,
            n: images.len(),
            elems,
            // at least 1: a sub-millisecond timeout must not truncate
            // to 0, which the server reads as "no deadline"
            deadline_ms: self.timeout.map(|t| (t.as_millis() as u64).max(1)),
            images: flat,
        };
        proto::write_frame(&mut self.stream, &Frame::Request(req))?;
        match proto::read_frame(&mut self.stream)? {
            None => Err(ClientError::Proto(ProtoError::Eof)),
            Some(Frame::Error(e)) => Err(ClientError::Rejected { code: e.code, msg: e.msg }),
            Some(Frame::Request(_)) => Err(ClientError::Proto(ProtoError::Malformed(
                "server sent a request frame".into(),
            ))),
            Some(Frame::Response(r)) => {
                if r.id != id || r.n != images.len() {
                    return Err(ClientError::Proto(ProtoError::Malformed(format!(
                        "response for frame {} (n {}), expected frame {id} (n {})",
                        r.id,
                        r.n,
                        images.len()
                    ))));
                }
                let mut out = Vec::with_capacity(r.n);
                for b in 0..r.n {
                    out.push(Attribution {
                        pred: r.preds[b],
                        logits: r.logits[b * r.out_n..(b + 1) * r.out_n].to_vec(),
                        relevance: r.relevance[b * r.elems..(b + 1) * r.elems].to_vec(),
                        device_cycles: r.device_cycles[b],
                    });
                }
                Ok(out)
            }
        }
    }
}
