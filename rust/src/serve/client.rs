//! Blocking client for the serve wire protocol: one reused TCP
//! connection, `attribute` / `attribute_batch` calls, per-request
//! deadlines, and transport recovery.
//!
//! The connection is reused across calls (requests are answered in
//! order on one stream, so no multiplexing machinery is needed). A
//! mid-frame I/O or framing error marks the connection broken, so the
//! next call transparently reconnects instead of writing into a
//! desynced stream. With [`Client::set_recovery`], transient failures
//! (broken stream, `Busy`, `Integrity`) are retried in place with
//! jittered exponential backoff; resubmission reuses the same request
//! id, and because one stream carries one request at a time, a
//! resubmitted request is idempotent — the server computes it afresh
//! and at most one response is consumed per attempt.
//!
//! Rejections arrive as typed [`ErrCode`]s in
//! [`ClientError::Rejected`] — `Busy` means retry later, `Closed`
//! means the server is going away, `Integrity` means a payload was
//! corrupted in flight (resubmit).

use std::fmt;
use std::io;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::proto::{self, ErrCode, Frame, ProtoError, RequestFrame};
use crate::attribution::Method;
use crate::faults::{splitmix64, unit_f64};

/// One image's worth of a serving response.
#[derive(Clone, Debug)]
pub struct Attribution {
    pub pred: usize,
    pub logits: Vec<f32>,
    pub relevance: Vec<f32>,
    /// Modeled device cycles for this image (the Table-IV number).
    pub device_cycles: u64,
}

/// Client-side failure.
#[derive(Debug)]
pub enum ClientError {
    Io(io::Error),
    Proto(ProtoError),
    /// The server answered with a typed error frame.
    Rejected { code: ErrCode, msg: String },
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "i/o: {e}"),
            ClientError::Proto(e) => write!(f, "protocol: {e}"),
            ClientError::Rejected { code, msg } => write!(f, "rejected ({code}): {msg}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<ProtoError> for ClientError {
    fn from(e: ProtoError) -> Self {
        ClientError::Proto(e)
    }
}

/// Extra socket-timeout slack over the request deadline, so a
/// `DeadlineExceeded` error frame can still arrive.
const TIMEOUT_SLACK: Duration = Duration::from_millis(500);
/// Ceiling on any single backoff sleep.
const MAX_BACKOFF: Duration = Duration::from_millis(500);

pub struct Client {
    addr: SocketAddr,
    /// `None` = known broken; the next call reconnects.
    stream: Option<TcpStream>,
    next_id: u64,
    timeout: Option<Duration>,
    /// Ask for CRC-protected payloads in both directions.
    with_crc: bool,
    /// SLO class name tagged onto every request (None = untagged).
    slo_class: Option<String>,
    /// Transparent retries of transient failures (0 = fail fast).
    retries: u32,
    backoff: Duration,
    seed: u64,
    reconnects: u64,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let addr = stream.peer_addr()?;
        Ok(Client {
            addr,
            stream: Some(stream),
            next_id: 1,
            timeout: None,
            with_crc: false,
            slo_class: None,
            retries: 0,
            backoff: Duration::from_millis(2),
            seed: 0,
            reconnects: 0,
        })
    }

    /// Per-request deadline: sent to the server in the request header
    /// and enforced locally as a socket read timeout (with slack so
    /// the server's `DeadlineExceeded` frame wins the race).
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> io::Result<()> {
        self.timeout = timeout;
        match &self.stream {
            Some(s) => s.set_read_timeout(timeout.map(|t| t + TIMEOUT_SLACK)),
            None => Ok(()),
        }
    }

    /// Protect request payloads with a CRC-32 header field and ask the
    /// server to protect responses the same way (version-negotiated:
    /// old servers ignore the field and answer unprotected).
    pub fn set_crc(&mut self, on: bool) {
        self.with_crc = on;
    }

    /// Tag every subsequent request with an SLO class name, resolved
    /// by the server against its loaded `*.slo.json` spec
    /// (version-negotiated: old servers skip the unknown field).
    /// `None` reverts to untagged requests.
    pub fn set_slo_class(&mut self, class: Option<&str>) {
        self.slo_class = class.map(str::to_string);
    }

    /// Enable transparent recovery: up to `retries` re-attempts of a
    /// call after a transient failure (broken stream → reconnect,
    /// `Busy` shed, `Integrity` corruption), sleeping a jittered
    /// exponential backoff (seeded — reruns sleep identically) between
    /// attempts.
    pub fn set_recovery(&mut self, retries: u32, backoff: Duration, seed: u64) {
        self.retries = retries;
        self.backoff = backoff;
        self.seed = seed;
    }

    /// Transport reconnects performed so far (broken-stream recovery).
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Whether the connection is currently marked broken.
    pub fn is_broken(&self) -> bool {
        self.stream.is_none()
    }

    /// Attribute one image.
    pub fn attribute(&mut self, image: &[f32], method: Method) -> Result<Attribution, ClientError> {
        let mut v = self.attribute_batch(&[image], method)?;
        v.pop().ok_or_else(|| ClientError::Proto(ProtoError::Malformed("empty response".into())))
    }

    /// Attribute a batch of same-shape images in one request frame (the
    /// server fans them into the coordinator, which micro-batches them
    /// into one device pass). Results are image-ordered.
    pub fn attribute_batch(
        &mut self,
        images: &[&[f32]],
        method: Method,
    ) -> Result<Vec<Attribution>, ClientError> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let elems = images[0].len();
        if images.iter().any(|i| i.len() != elems) {
            return Err(ClientError::Proto(ProtoError::Malformed(
                "batch images must share one shape".into(),
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut flat = Vec::with_capacity(images.len() * elems);
        for img in images {
            flat.extend_from_slice(img);
        }
        // built once: resubmits reuse the identical frame (same id —
        // idempotent, since this stream carries one request at a time)
        let frame = Frame::Request(RequestFrame {
            id,
            method,
            target: None,
            n: images.len(),
            elems,
            // at least 1: a sub-millisecond timeout must not truncate
            // to 0, which the server reads as "no deadline"
            deadline_ms: self.timeout.map(|t| (t.as_millis() as u64).max(1)),
            with_crc: self.with_crc,
            trace_seq: None,
            slo_class: self.slo_class.clone(),
            images: flat,
        });
        let mut attempt = 0u32;
        loop {
            let err = match self.roundtrip(&frame, id, images.len()) {
                Ok(v) => return Ok(v),
                Err(e) => e,
            };
            if breaks_stream(&err) {
                // satellite of the fault model: a mid-frame failure
                // leaves the stream desynced — never write into it
                // again; the next attempt (or call) reconnects
                self.stream = None;
            }
            if attempt >= self.retries || !is_transient(&err) {
                return Err(err);
            }
            self.sleep_backoff(id, attempt);
            attempt += 1;
        }
    }

    fn roundtrip(
        &mut self,
        frame: &Frame,
        id: u64,
        n: usize,
    ) -> Result<Vec<Attribution>, ClientError> {
        let stream = self.ensure_stream()?;
        proto::write_frame(stream, frame)?;
        match proto::read_frame(stream)? {
            None => Err(ClientError::Proto(ProtoError::Eof)),
            Some(Frame::Error(e)) => Err(ClientError::Rejected { code: e.code, msg: e.msg }),
            Some(Frame::Request(_)) => Err(ClientError::Proto(ProtoError::Malformed(
                "server sent a request frame".into(),
            ))),
            Some(Frame::Response(r)) => {
                if r.id != id || r.n != n {
                    return Err(ClientError::Proto(ProtoError::Malformed(format!(
                        "response for frame {} (n {}), expected frame {id} (n {n})",
                        r.id, r.n,
                    ))));
                }
                let mut out = Vec::with_capacity(r.n);
                for b in 0..r.n {
                    out.push(Attribution {
                        pred: r.preds[b],
                        logits: r.logits[b * r.out_n..(b + 1) * r.out_n].to_vec(),
                        relevance: r.relevance[b * r.elems..(b + 1) * r.elems].to_vec(),
                        device_cycles: r.device_cycles[b],
                    });
                }
                Ok(out)
            }
        }
    }

    /// The live stream, reconnecting if the last call broke it.
    fn ensure_stream(&mut self) -> io::Result<&mut TcpStream> {
        if self.stream.is_none() {
            let s = TcpStream::connect(self.addr)?;
            let _ = s.set_nodelay(true);
            s.set_read_timeout(self.timeout.map(|t| t + TIMEOUT_SLACK))?;
            self.stream = Some(s);
            self.reconnects += 1;
        }
        Ok(self.stream.as_mut().expect("just ensured"))
    }

    /// Jittered exponential backoff, deterministic under a fixed seed.
    fn sleep_backoff(&self, id: u64, attempt: u32) {
        let h = splitmix64(self.seed ^ id.rotate_left(17) ^ attempt as u64);
        let factor = 0.5 + unit_f64(h); // [0.5, 1.5): desynchronizes herds
        let base = self.backoff.as_secs_f64() * (1u64 << attempt.min(6)) as f64;
        let dur = Duration::from_secs_f64((base * factor).min(MAX_BACKOFF.as_secs_f64()));
        if !dur.is_zero() {
            std::thread::sleep(dur);
        }
    }
}

/// After this error, is the stream unusable (reconnect before the next
/// write)? A typed error frame or a response-CRC mismatch consumed a
/// whole frame, so the stream stays synced; everything else desyncs.
fn breaks_stream(e: &ClientError) -> bool {
    match e {
        ClientError::Io(_) => true,
        ClientError::Proto(ProtoError::Integrity { .. }) => false,
        ClientError::Proto(_) => true,
        ClientError::Rejected { .. } => false,
    }
}

/// May a retry succeed? Broken streams and shed/corrupted requests are
/// transient; `Closed` and `DeadlineExceeded` are terminal.
fn is_transient(e: &ClientError) -> bool {
    match e {
        ClientError::Io(_) | ClientError::Proto(_) => true,
        ClientError::Rejected { code, .. } => {
            matches!(code, ErrCode::Busy | ErrCode::Integrity)
        }
    }
}
