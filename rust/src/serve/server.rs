//! TCP front door for the coordinator: bounded connection pool,
//! admission control, per-request deadlines, graceful drain.
//!
//! ```text
//!   TcpListener ──accept──▶ pool slot?  ──no──▶ Busy frame, close
//!        │                      │yes
//!        │               conn thread: decode frame ─▶ Coordinator
//!        │                      │     (queue full ─▶ Busy frame)
//!        │                      ◀── Response / DeadlineExceeded
//!        └─ drain: new conns get Closed, in-flight get answers
//! ```
//!
//! Shed policy (never queue unboundedly, never hang a client):
//! * connection pool at capacity → `Busy` error frame at accept time;
//! * coordinator queue full → `Busy` error frame for that request;
//! * request deadline elapsed → `DeadlineExceeded` error frame (the
//!   device result is discarded);
//! * draining → `Closed` error frame for new connections and for idle
//!   connections; requests already being served complete normally;
//! * undecodable bytes → `BadRequest` error frame, then the connection
//!   is dropped (framing is unrecoverable); semantically-bad but
//!   well-framed requests get `BadRequest` and the connection lives on.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::proto::{self, ErrCode, ErrorFrame, Frame, RequestFrame, ResponseFrame};
use crate::coordinator::{metrics, Coordinator, FailKind};
use crate::faults::{salt, FaultHooks, FaultStats};
use crate::obs::export::{device_lines, render_registry, snapshot_lines, StatsEndpoint};
use crate::obs::push::PushEmitter;
use crate::obs::slo::SloSpec;
use crate::obs::span::{Outcome, Recorder, Span, Stage};
use crate::obs::telemetry::Registry;

/// TCP serving configuration (the coordinator has its own
/// [`crate::coordinator::Config`] for queueing/batching).
#[derive(Clone)]
pub struct ServerConfig {
    /// Bounded connection pool: accepts beyond this are shed with
    /// `Busy` instead of queueing.
    pub max_conns: usize,
    /// Deadline applied to requests that carry none (0 = none).
    pub default_deadline_ms: u64,
    /// Fault hooks for the admission injection site and wire-CRC
    /// detection accounting. `None` = production serving.
    pub faults: Option<FaultHooks>,
    /// Per-request span sink (`serve --trace`). `None` = tracing off:
    /// spans are still stamped on the stack but never recorded, and
    /// the request path performs no extra heap allocation
    /// (`tests/alloc_regression.rs`).
    pub recorder: Option<Arc<dyn Recorder>>,
    /// Telemetry registry: every completed span's per-stage latencies
    /// feed its lock-free histograms, and the coordinator's counters
    /// dual-write into it (share the same `Arc` with
    /// [`crate::coordinator::Config::telemetry`] so the stats endpoint
    /// reconciles with the metrics snapshot). `None` = telemetry off,
    /// zero hot-path cost.
    pub telemetry: Option<Arc<Registry>>,
    /// Bind address for the one-shot stats exposition endpoint
    /// (`serve --stats-addr`; port 0 picks an ephemeral port — see
    /// [`Server::stats_addr`]). `None` = no endpoint.
    pub stats_addr: Option<String>,
    /// SLO objectives (`serve --slo`). When set, requests carrying a
    /// `slo_class` header resolve to a fixed registry slot at
    /// admission (unknown names → `BadRequest`) and Ok outcomes
    /// publish into the per-class good/bad counters and latency
    /// histogram. `None` = classed requests are rejected.
    pub slo: Option<Arc<SloSpec>>,
    /// Destination for the statsd push exporter (`serve --push-addr`,
    /// host:port UDP). Requires `telemetry`. `None` = no pushing.
    pub push_addr: Option<String>,
    /// Push interval in milliseconds (`serve --push-every`).
    pub push_every_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_conns: 32,
            default_deadline_ms: 0,
            faults: None,
            recorder: None,
            telemetry: None,
            stats_addr: None,
            slo: None,
            push_addr: None,
            push_every_ms: 1000,
        }
    }
}

impl std::fmt::Debug for ServerConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerConfig")
            .field("max_conns", &self.max_conns)
            .field("default_deadline_ms", &self.default_deadline_ms)
            .field("faults", &self.faults)
            .field("recorder", &self.recorder.as_ref().map(|_| "Some(<dyn Recorder>)"))
            .field("telemetry", &self.telemetry.as_ref().map(|_| "Some(<Registry>)"))
            .field("stats_addr", &self.stats_addr)
            .field("slo", &self.slo.as_ref().map(|s| s.names()))
            .field("push_addr", &self.push_addr)
            .field("push_every_ms", &self.push_every_ms)
            .finish()
    }
}

/// Ceiling used when a request has no deadline at all: nothing blocks
/// a connection thread forever.
const NO_DEADLINE: Duration = Duration::from_secs(600);
/// Idle read timeout: how often a connection thread re-checks drain.
const IDLE_TICK: Duration = Duration::from_millis(50);
/// Once the first preamble byte has arrived, the rest of the frame
/// must follow promptly (slow-loris guard: a stalled partial frame
/// must not hold a pool slot forever).
const BODY_TIMEOUT: Duration = Duration::from_secs(20);
/// Cap on any single response write: a peer that stops reading must
/// not wedge a connection thread (and with it, shutdown's join).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// During drain, how long a connection mid-preamble may stall before
/// the thread gives up on it (a stalled peer must not wedge shutdown).
const DRAIN_GRACE: Duration = Duration::from_secs(2);

struct Shared {
    coord: Coordinator,
    cfg: ServerConfig,
    draining: AtomicBool,
    conns: AtomicUsize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Admission-site fault clock: one tick per served request frame,
    /// shared across connections so injection schedules are stable.
    admission_seq: AtomicU64,
    /// Connection id source for span `conn_id` fields.
    conn_seq: AtomicU64,
}

/// A running TCP server. Owns the coordinator; [`Server::shutdown`]
/// drains connections, then shuts the coordinator down gracefully.
pub struct Server {
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    addr: SocketAddr,
    /// One-shot stats exposition endpoint (`--stats-addr`). Holds a
    /// clone of `shared` inside its render closure, so shutdown drops
    /// it before unwrapping the `Arc`.
    stats: Option<StatsEndpoint>,
    /// statsd push exporter (`--push-addr`): dies with the server,
    /// flushing a final snapshot on shutdown.
    push: Option<PushEmitter>,
}

impl Server {
    /// Bind `addr` (port 0 picks an ephemeral port — see
    /// [`Server::local_addr`]) and start accepting.
    pub fn start<A: ToSocketAddrs>(
        addr: A,
        coord: Coordinator,
        cfg: ServerConfig,
    ) -> anyhow::Result<Server> {
        anyhow::ensure!(cfg.max_conns > 0, "need at least one connection slot");
        anyhow::ensure!(
            cfg.push_addr.is_none() || cfg.telemetry.is_some(),
            "push export needs a telemetry registry (--push-addr without telemetry)"
        );
        // pin the SLO class names into their registry slots up front so
        // publication is index-only and exposition covers every class
        // from the first scrape
        if let (Some(reg), Some(spec)) = (&cfg.telemetry, &cfg.slo) {
            reg.install_classes(spec.names());
        }
        // pin the span epoch now so request stamps are small offsets
        crate::obs::span::epoch();
        let listener = TcpListener::bind(addr)?;
        // non-blocking accept so shutdown can stop the loop promptly
        listener.set_nonblocking(true)?;
        let bound = listener.local_addr()?;
        let shared = Arc::new(Shared {
            coord,
            cfg,
            draining: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            handles: Mutex::new(Vec::new()),
            admission_seq: AtomicU64::new(0),
            conn_seq: AtomicU64::new(0),
        });
        let stop = Arc::new(AtomicBool::new(false));
        // stats exposition endpoint: one-shot TCP scrapes rendering
        // registry + snapshot + per-device fleet gauges at read time
        let stats = match shared.cfg.stats_addr.clone() {
            Some(stats_addr) => {
                let sh = shared.clone();
                let render = Box::new(move || {
                    let mut out = String::new();
                    if let Some(reg) = &sh.cfg.telemetry {
                        // the queue-depth gauge is sampled at scrape
                        // time, not maintained on the request path
                        reg.queue_depth.set(sh.coord.queue_depth() as u64);
                        out.push_str(&render_registry(reg));
                    }
                    out.push_str(&snapshot_lines(&sh.coord.metrics.snapshot()));
                    out.push_str(&device_lines(sh.coord.devices()));
                    out
                });
                Some(StatsEndpoint::start(stats_addr.as_str(), render)?)
            }
            None => None,
        };
        let push = match (&shared.cfg.push_addr, &shared.cfg.telemetry) {
            (Some(addr), Some(reg)) => {
                Some(PushEmitter::start(reg.clone(), addr, shared.cfg.push_every_ms)?)
            }
            _ => None,
        };
        let acceptor = {
            let shared = shared.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("serve-acceptor".into())
                .spawn(move || accept_loop(listener, &shared, &stop))?
        };
        Ok(Server { shared, stop, acceptor: Some(acceptor), addr: bound, stats, push })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The stats endpoint's actually-bound address (resolves port 0);
    /// `None` when the server was started without `stats_addr`.
    pub fn stats_addr(&self) -> Option<SocketAddr> {
        self.stats.as_ref().map(|s| s.local_addr())
    }

    /// Open TCP connections right now (the pool gauge).
    pub fn open_conns(&self) -> usize {
        self.shared.conns.load(Ordering::Relaxed)
    }

    /// Graceful drain: idle and new connections get `Closed`, in-flight
    /// requests get their responses, then the coordinator shuts down
    /// and the final metrics snapshot is returned.
    pub fn shutdown(self) -> anyhow::Result<metrics::Snapshot> {
        let Server { shared, stop, acceptor, stats, push, .. } = self;
        // the endpoint's render closure holds a `shared` clone: join
        // its thread first or `Arc::try_unwrap` below can never win
        drop(stats);
        // join the push threads too: the final flush must happen while
        // the registry still reflects the finished run
        drop(push);
        shared.draining.store(true, Ordering::Relaxed);
        join_all(&shared.handles);
        stop.store(true, Ordering::Relaxed);
        if let Some(a) = acceptor {
            let _ = a.join();
        }
        // the acceptor is gone, so no new connection threads can spawn;
        // join any spawned in the drain window
        join_all(&shared.handles);
        if let Some(rec) = &shared.cfg.recorder {
            rec.flush();
        }
        let shared = Arc::try_unwrap(shared)
            .map_err(|_| anyhow::anyhow!("connection threads still alive at shutdown"))?;
        Ok(shared.coord.shutdown())
    }
}

fn join_all(handles: &Mutex<Vec<std::thread::JoinHandle<()>>>) {
    loop {
        // a connection thread that panicked (handler bug, injected
        // fault) poisons nothing we care about: the Vec of handles is
        // still valid, and shutdown must keep draining rather than
        // double-panic on `PoisonError`
        let hs: Vec<_> =
            handles.lock().unwrap_or_else(|e| e.into_inner()).drain(..).collect();
        if hs.is_empty() {
            return;
        }
        for h in hs {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<Shared>, stop: &AtomicBool) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                admit(shared, stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

fn admit(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    if shared.draining.load(Ordering::Relaxed) {
        let _ = write_err(&mut stream, 0, ErrCode::Closed, "server draining");
        return;
    }
    let prev = shared.conns.fetch_add(1, Ordering::AcqRel);
    if prev >= shared.cfg.max_conns {
        // bounded pool: shed, don't queue
        shared.conns.fetch_sub(1, Ordering::AcqRel);
        shared.coord.metrics.record_busy();
        let _ = write_err(&mut stream, 0, ErrCode::Busy, "connection pool full");
        return;
    }
    let sh = shared.clone();
    let spawned = std::thread::Builder::new().name("serve-conn".into()).spawn(move || {
        handle_conn(&sh, stream);
        sh.conns.fetch_sub(1, Ordering::AcqRel);
    });
    match spawned {
        // tolerate a poisoned handle list (see `join_all`): accepting
        // new connections must survive one crashed handler thread
        Ok(h) => shared.handles.lock().unwrap_or_else(|e| e.into_inner()).push(h),
        Err(_) => {
            shared.conns.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

fn write_err(stream: &mut TcpStream, id: u64, code: ErrCode, msg: &str) -> std::io::Result<()> {
    proto::write_frame(stream, &Frame::Error(ErrorFrame { id, code, msg: msg.to_string() }))
}

/// Read-timeout/interrupt kinds: the idle tick, not a dead peer.
fn is_retry_kind(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted
    )
}

/// One connection: read frames until EOF, error, or drain. The
/// preamble is read byte-wise under a short timeout so an idle
/// connection notices drain without ever splitting a frame.
fn handle_conn(shared: &Shared, mut stream: TcpStream) {
    let m = &shared.coord.metrics;
    let conn_id = shared.conn_seq.fetch_add(1, Ordering::Relaxed) + 1;
    m.record_conn_open();
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_TICK));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut pre = [0u8; proto::PREAMBLE_LEN];
    let mut have = 0usize;
    // when the first preamble byte arrived (slow-loris deadline)
    let mut started: Option<Instant> = None;
    let mut drain_since: Option<Instant> = None;
    loop {
        if shared.draining.load(Ordering::Relaxed) {
            if have == 0 {
                let _ = write_err(&mut stream, 0, ErrCode::Closed, "server draining");
                break;
            }
            // mid-preamble during drain: give the bytes a bounded
            // grace period, then abandon the stalled peer
            let since = *drain_since.get_or_insert_with(Instant::now);
            if since.elapsed() > DRAIN_GRACE {
                break;
            }
        }
        if let Some(t0) = started {
            // a partial preamble must complete within the body budget,
            // or the connection is freeing its pool slot
            if t0.elapsed() > BODY_TIMEOUT {
                let _ = write_err(&mut stream, 0, ErrCode::BadRequest, "preamble timed out");
                break;
            }
        }
        match stream.read(&mut pre[have..]) {
            Ok(0) => break, // peer closed
            Ok(k) => {
                have += k;
                if started.is_none() {
                    started = Some(Instant::now());
                }
            }
            Err(e) if is_retry_kind(e.kind()) => continue,
            Err(_) => break,
        }
        if have < proto::PREAMBLE_LEN {
            continue;
        }
        have = 0;
        started = None;
        // span Accept stamp: the frame's preamble is fully on the host
        let accept_ns = crate::obs::span::now_ns();
        let pb = match proto::parse_preamble(&pre) {
            Ok(p) => p,
            Err(e) => {
                // framing is unrecoverable: answer typed, then drop
                let _ = write_err(&mut stream, 0, ErrCode::BadRequest, &e.to_string());
                break;
            }
        };
        let _ = stream.set_read_timeout(Some(BODY_TIMEOUT));
        let frame = proto::read_body(&mut stream, &pb);
        let _ = stream.set_read_timeout(Some(IDLE_TICK));
        match frame {
            Ok(Frame::Request(req)) => {
                if !serve_request(shared, &mut stream, req, conn_id, accept_ns) {
                    break;
                }
            }
            // body bytes were fully consumed, so framing is intact:
            // answer typed and keep the connection alive
            Ok(_) => {
                let ok =
                    write_err(&mut stream, 0, ErrCode::BadRequest, "expected a request frame");
                if ok.is_err() {
                    break;
                }
            }
            Err(proto::ProtoError::Malformed(msg)) => {
                let ok = write_err(&mut stream, 0, ErrCode::BadRequest, &msg);
                if ok.is_err() {
                    break;
                }
            }
            // the CRC caught a corrupted payload, but every body byte
            // was consumed — framing is intact, so answer typed and
            // keep the connection: the client resubmits idempotently
            Err(e @ proto::ProtoError::Integrity { .. }) => {
                m.record_integrity_failure();
                if let Some(hooks) = &shared.cfg.faults {
                    FaultStats::bump(&hooks.stats.detected_crc);
                }
                let ok = write_err(&mut stream, 0, ErrCode::Integrity, &e.to_string());
                if ok.is_err() {
                    break;
                }
            }
            // truncated body / i/o error: the stream is desynced
            Err(e) => {
                let _ = write_err(&mut stream, 0, ErrCode::BadRequest, &e.to_string());
                break;
            }
        }
    }
    m.record_conn_close();
}

/// Answer a request with a typed error, completing its span. Returns
/// false when the connection should be dropped (write failure).
fn answer_err(
    shared: &Shared,
    stream: &mut TcpStream,
    span: &mut Span,
    req: &RequestFrame,
    code: ErrCode,
    msg: &str,
) -> bool {
    span.outcome = Outcome::Err(code);
    let frame = Frame::Error(ErrorFrame { id: req.id, code, msg: msg.to_string() });
    span.stamp_now(Stage::Encode);
    let ok = proto::write_frame(stream, &frame).is_ok();
    if ok {
        span.stamp_now(Stage::Flush);
    }
    if let Some(reg) = &shared.cfg.telemetry {
        reg.observe_span(span);
    }
    if let Some(rec) = &shared.cfg.recorder {
        rec.record(span, req, &frame);
    }
    ok
}

/// Serve one request frame. Returns false when the connection should
/// be dropped (write failure).
fn serve_request(
    shared: &Shared,
    stream: &mut TcpStream,
    req: RequestFrame,
    conn_id: u64,
    accept_ns: u64,
) -> bool {
    let m = &shared.coord.metrics;
    let mut span = Span::start(req.id, conn_id, req.n as u32, req.method);
    span.stamp(Stage::Accept, accept_ns);
    span.stamp_now(Stage::Decode);
    span.trace_seq = req.trace_seq;
    // resolve the optional `slo_class` header to its fixed registry
    // slot now, so publication later is pure index arithmetic. The
    // spec is the contract: an unknown (or spec-less) class name is a
    // client error, not a silently-unclassed request.
    let slo_idx = match (&req.slo_class, &shared.cfg.slo) {
        (None, _) => None,
        (Some(name), Some(spec)) => match spec.index_of(name) {
            Some(i) => Some(i),
            None => {
                let msg = format!("unknown slo_class {name:?}");
                return answer_err(shared, stream, &mut span, &req, ErrCode::BadRequest, &msg);
            }
        },
        (Some(name), None) => {
            let msg = format!("server has no SLO spec; slo_class {name:?} rejected");
            return answer_err(shared, stream, &mut span, &req, ErrCode::BadRequest, &msg);
        }
    };
    let elems = shared.coord.sim().net.input.elems();
    if req.elems != elems {
        let msg = format!("image has {} elems, model wants {elems}", req.elems);
        return answer_err(shared, stream, &mut span, &req, ErrCode::BadRequest, &msg);
    }
    let t0 = Instant::now();
    let deadline_ms = req.deadline_ms.unwrap_or(shared.cfg.default_deadline_ms);
    span.deadline_ms = deadline_ms;
    let budget = if deadline_ms == 0 {
        NO_DEADLINE
    } else {
        Duration::from_millis(deadline_ms)
    };

    // admission fault site: forced sheds exercise the client's
    // retry-on-Busy / deadline handling without real overload
    if let Some(hooks) = &shared.cfg.faults {
        let seq = shared.admission_seq.fetch_add(1, Ordering::Relaxed);
        let p = &hooks.plan;
        if p.admission.busy.decide(p.seed, salt::ADMISSION_BUSY, seq) {
            FaultStats::bump(&hooks.stats.injected_admission_busy);
            m.record_busy();
            let msg = "injected: admission shed";
            return answer_err(shared, stream, &mut span, &req, ErrCode::Busy, msg);
        }
        if p.admission.deadline.decide(p.seed, salt::ADMISSION_DEADLINE, seq) {
            FaultStats::bump(&hooks.stats.injected_admission_deadline);
            m.record_deadline_exceeded();
            let msg = "injected: admission deadline";
            return answer_err(shared, stream, &mut span, &req, ErrCode::DeadlineExceeded, msg);
        }
    }
    span.stamp_now(Stage::Admit);

    // admit every image of the frame; the coordinator micro-batches
    // same-method submissions back into one device pass
    let deadline = Some(t0 + budget);
    let mut rxs = Vec::with_capacity(req.n);
    for img in req.images.chunks_exact(elems) {
        let (tx, rx) = mpsc::channel();
        match shared.coord.submit_deadline(img.to_vec(), req.method, req.target, deadline, tx) {
            Ok(_) => rxs.push(rx),
            Err(why) => {
                // shed the whole frame, but wait out the co-submitted
                // images so their replies don't race the next frame
                for rx in rxs.drain(..) {
                    let _ = rx.recv_timeout(budget);
                }
                let (code, msg) = match why {
                    "queue full" => (ErrCode::Busy, "queue full"),
                    "shutting down" => (ErrCode::Closed, "coordinator shutting down"),
                    other => (ErrCode::BadRequest, other),
                };
                if code == ErrCode::Busy {
                    m.record_busy();
                }
                return answer_err(shared, stream, &mut span, &req, code, msg);
            }
        }
    }
    span.stamp_now(Stage::Enqueue);

    let mut preds = Vec::with_capacity(req.n);
    let mut device_cycles = Vec::with_capacity(req.n);
    let mut relevance = Vec::with_capacity(req.n * elems);
    let mut logits = Vec::new();
    let mut out_n = 0usize;
    for (b, (rx, img)) in rxs.iter().zip(req.images.chunks_exact(elems)).enumerate() {
        let left = budget.saturating_sub(t0.elapsed());
        match rx.recv_timeout(left) {
            Ok(Ok(resp)) => {
                // sampled PJRT shadow verification (no-op when the
                // coordinator has no verifier)
                shared.coord.shadow_check(img, &resp);
                if b == 0 {
                    // batch facts from the first image's micro-batch;
                    // later images aggregate below
                    span.stamp(Stage::BatchForm, resp.batch_form_ns);
                    span.stamp(Stage::Dispatch, resp.dispatch_ns);
                    span.batch_id = resp.batch_id;
                    span.batch_size = resp.batch_size;
                    span.device_index = resp.device_index;
                }
                // the frame's device work completes when its last
                // image does; retries/trips are worst-case across it
                span.stamp(Stage::DeviceComplete, resp.complete_ns.max(span.stages[Stage::DeviceComplete as usize]));
                span.attempts = span.attempts.max(resp.attempts);
                span.breaker_tripped |= resp.breaker_tripped;
                span.device_cycles += resp.device_cycles;
                preds.push(resp.pred);
                device_cycles.push(resp.device_cycles);
                out_n = resp.logits.len();
                logits.extend_from_slice(&resp.logits);
                relevance.extend_from_slice(&resp.relevance);
            }
            Ok(Err(failure)) => {
                let (code, msg) = match failure.kind {
                    FailKind::Closed => (ErrCode::Closed, "coordinator closed"),
                    // detected-but-unrecoverable corruption: the
                    // service refuses to ship untrusted output
                    FailKind::Integrity => {
                        (ErrCode::Integrity, "integrity checks failed on every attempt")
                    }
                    FailKind::Unavailable => (ErrCode::Busy, "no healthy device"),
                };
                if code == ErrCode::Busy {
                    m.record_busy();
                }
                return answer_err(shared, stream, &mut span, &req, code, msg);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                m.record_deadline_exceeded();
                let msg = format!("deadline of {deadline_ms} ms exceeded");
                return answer_err(shared, stream, &mut span, &req, ErrCode::DeadlineExceeded, &msg);
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return answer_err(shared, stream, &mut span, &req, ErrCode::Closed, "worker gone");
            }
        }
    }
    let frame = Frame::Response(ResponseFrame {
        id: req.id,
        n: req.n,
        elems,
        out_n,
        preds,
        device_cycles,
        // version-negotiated: protect the response payload iff the
        // client protected (and thereby requested) it
        with_crc: req.with_crc,
        logits,
        relevance,
    });
    span.stamp_now(Stage::Encode);
    let ok = proto::write_frame(stream, &frame).is_ok();
    if ok {
        span.stamp_now(Stage::Flush);
    }
    if let Some(reg) = &shared.cfg.telemetry {
        reg.observe_span(&span);
        // classed publication: only Ok outcomes count (sheds and typed
        // errors never reach here), good = within the class's latency
        // threshold. This keeps Σ(good+bad) per class reconcilable
        // against the coordinator's `completed` counter.
        if let (Some(idx), Some(spec)) = (slo_idx, &shared.cfg.slo) {
            let total_ns = span.total_ns();
            reg.observe_class(idx, total_ns, total_ns <= spec.classes[idx].latency_ns());
        }
    }
    if let Some(rec) = &shared.cfg.recorder {
        rec.record(&span, &req, &frame);
    }
    ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::Method;
    use crate::coordinator::Config;
    use crate::hls::HwConfig;
    use crate::sched::tests_support::tiny_sim;
    use crate::serve::client::Client;

    #[test]
    fn server_survives_a_poisoned_handle_mutex() {
        let coord = Coordinator::start(
            tiny_sim(41, HwConfig::pynq_z2()),
            Config { workers: 1, ..Default::default() },
            None,
        )
        .unwrap();
        let server = Server::start("127.0.0.1:0", coord, ServerConfig::default()).unwrap();
        // a thread that panics while holding the handle-list lock
        // poisons the mutex — the seed's failure mode when a handler
        // crashed: `shutdown` and `admit` would then panic on
        // `unwrap()` instead of draining
        let sh = server.shared.clone();
        let _ = std::thread::spawn(move || {
            let _g = sh.handles.lock().unwrap();
            panic!("deliberate handler crash");
        })
        .join();
        assert!(server.shared.handles.is_poisoned());
        // new connections are still admitted after the poison...
        let mut c = Client::connect(server.local_addr()).unwrap();
        let img = vec![0.5f32; 128];
        let a = c.attribute(&img, Method::Saliency).unwrap();
        assert_eq!(a.relevance.len(), 128);
        // ...and graceful shutdown completes with a snapshot
        let snap = server.shutdown().unwrap();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn classed_requests_publish_into_slots_and_unknown_names_are_rejected() {
        use crate::obs::slo::SloSpec;
        use crate::serve::client::ClientError;
        let reg = Arc::new(Registry::new());
        let spec = Arc::new(SloSpec::synthetic(&["gold".into(), "silver".into()]));
        let coord = Coordinator::start(
            tiny_sim(41, HwConfig::pynq_z2()),
            Config { workers: 1, telemetry: Some(reg.clone()), ..Default::default() },
            None,
        )
        .unwrap();
        let cfg =
            ServerConfig { telemetry: Some(reg.clone()), slo: Some(spec), ..Default::default() };
        let server = Server::start("127.0.0.1:0", coord, cfg).unwrap();
        // starting the server pinned the spec's names into their slots
        assert_eq!(reg.class_names(), ["gold".to_string(), "silver".to_string()]);
        let mut c = Client::connect(server.local_addr()).unwrap();
        let img = vec![0.5f32; 128];
        c.set_slo_class(Some("silver"));
        c.attribute(&img, Method::Saliency).unwrap();
        // the synthetic spec's thresholds are minutes wide: good
        assert_eq!((reg.class_good[1].get(), reg.class_bad[1].get()), (1, 0));
        assert_eq!(reg.class_good[0].get() + reg.class_bad[0].get(), 0, "gold slot untouched");
        // unknown class: typed BadRequest, and the connection lives on
        c.set_slo_class(Some("platinum"));
        match c.attribute(&img, Method::Saliency) {
            Err(ClientError::Rejected { code: ErrCode::BadRequest, .. }) => {}
            other => panic!("want a BadRequest rejection, got {other:?}"),
        }
        c.set_slo_class(None);
        c.attribute(&img, Method::Saliency).unwrap();
        let snap = server.shutdown().unwrap();
        assert_eq!(snap.completed, 2, "the rejected frame never reached the coordinator");
        // only Ok outcomes are classed: one silver, nothing else
        let classed: u64 = (0..2).map(|i| reg.class_good[i].get() + reg.class_bad[i].get()).sum();
        assert_eq!(classed, 1);
    }
}
