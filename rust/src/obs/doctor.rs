//! `attrax doctor`: offline fleet diagnosis over a captured trace.
//!
//! The doctor never touches the live stack — it audits the
//! `attrax-trace/v1` artifact alone, so two runs over the same trace
//! emit byte-identical reports (no wall-clock fields, no randomness).
//! It decomposes every span into per-stage latency segments
//! (p50/p95/p99/mean per stage, in ms) and checks a typed findings
//! taxonomy against configurable thresholds:
//!
//! * `deadline_miss_rate` — per deadline-class SLO violations
//!   (`deadline_exceeded` outcomes among deadline-bearing requests);
//! * `shed_storm` — the densest burst of `busy` sheds in any window
//!   of [`DoctorSpec::shed_window`] consecutive records;
//! * `underfull_batches` — mean batch fill vs the capture's
//!   `max_batch` (paying batching latency without its throughput);
//! * `linger_dominance` — share of end-to-end latency spent between
//!   enqueue and batch formation (queue wait + batching linger);
//! * `breaker_flap` — requests that saw a circuit-breaker trip;
//! * `queue_wait_outliers` — enqueue→batch-form waits beyond
//!   [`DoctorSpec::outlier_factor`] × the median wait;
//! * `device_skew` — fleet load imbalance: the busiest device's span
//!   count vs the per-device mean (max/mean ratio);
//! * `slo_burn` — with an `attrax-slo/v1` spec loaded
//!   (`doctor --slo`), per-class burn rate from the trace: the bad
//!   fraction among each class's Ok-outcome frames (total latency
//!   over the class threshold) relative to its allowed `1 - target`.
//!   The same arithmetic as the live [`crate::obs::slo::evaluate`],
//!   fed from spans instead of scrapes.
//!
//! Every check always emits a [`Finding`] (value + threshold +
//! violated flag) so the report is a complete health record, not just
//! a list of failures; the CLI exits nonzero iff any finding is
//! violated (or the trace itself is corrupt).

use std::collections::BTreeMap;

use crate::obs::span::{Outcome, Span, Stage, ALL_STAGES};
use crate::obs::trace::{TraceError, TraceMeta, TraceReader, TraceRecord};
use crate::serve::proto::ErrCode;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats::Samples;

pub const DOCTOR_SCHEMA: &str = "attrax-doctor/v1";

/// Audit thresholds. Defaults are lenient (report-only): every check
/// still runs and reports its value, but nothing is flagged until the
/// operator tightens the knob.
#[derive(Clone, Debug)]
pub struct DoctorSpec {
    /// Max tolerated deadline-miss fraction per deadline class.
    pub max_deadline_miss_rate: f64,
    /// Max tolerated `busy` sheds inside one [`Self::shed_window`].
    pub max_shed_burst: u64,
    /// Sliding-window size (records) for shed-storm detection.
    pub shed_window: usize,
    /// Min tolerated mean batch fill (batch_size / max_batch).
    pub min_batch_fill: f64,
    /// Max tolerated share of latency spent waiting for batch
    /// formation.
    pub max_linger_share: f64,
    /// Max tolerated breaker-trip-affected requests.
    pub max_breaker_trips: u64,
    /// A queue wait beyond `outlier_factor × median` is an outlier.
    pub outlier_factor: f64,
    /// Max tolerated queue-wait outliers.
    pub max_queue_outliers: u64,
    /// Max tolerated per-device load skew (busiest device's span
    /// count / per-device mean; 1.0 = perfectly balanced).
    pub max_device_skew: f64,
    /// SLO objectives to audit classed frames against (`doctor
    /// --slo`). `None` = no `slo_burn` findings.
    pub slo: Option<crate::obs::slo::SloSpec>,
}

impl Default for DoctorSpec {
    fn default() -> DoctorSpec {
        DoctorSpec {
            max_deadline_miss_rate: 1.0,
            max_shed_burst: u64::MAX,
            shed_window: 50,
            min_batch_fill: 0.0,
            max_linger_share: 1.0,
            max_breaker_trips: u64::MAX,
            outlier_factor: 10.0,
            max_queue_outliers: u64::MAX,
            max_device_skew: f64::INFINITY,
            slo: None,
        }
    }
}

/// One check's verdict. `value` vs `threshold` direction depends on
/// the check (documented per kind); `violated` is authoritative.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub kind: &'static str,
    pub detail: String,
    pub value: f64,
    pub threshold: f64,
    pub violated: bool,
}

impl Finding {
    fn to_json(&self) -> Json {
        obj(vec![
            ("kind", s(self.kind)),
            ("detail", s(&self.detail)),
            ("value", num(self.value)),
            ("threshold", num(self.threshold)),
            ("violated", Json::Bool(self.violated)),
        ])
    }
}

/// Latency summary for one pipeline segment, in milliseconds.
#[derive(Clone, Debug, Default)]
pub struct StageStat {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl StageStat {
    fn of(samples: &Samples) -> StageStat {
        StageStat {
            count: samples.len(),
            mean_ms: samples.mean(),
            p50_ms: samples.percentile(0.50),
            p95_ms: samples.percentile(0.95),
            p99_ms: samples.percentile(0.99),
        }
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("count", num(self.count as f64)),
            ("mean_ms", num(self.mean_ms)),
            ("p50_ms", num(self.p50_ms)),
            ("p95_ms", num(self.p95_ms)),
            ("p99_ms", num(self.p99_ms)),
        ])
    }
}

/// The full audit: stage decomposition + outcome tally + findings.
#[derive(Clone, Debug)]
pub struct DoctorReport {
    pub frames: usize,
    /// Outcome name → count (sorted, so JSON is canonical).
    pub outcomes: BTreeMap<String, u64>,
    /// Segment name → stats, in pipeline order (plus `"total"`).
    pub stages: Vec<(&'static str, StageStat)>,
    pub findings: Vec<Finding>,
}

impl DoctorReport {
    pub fn violations(&self) -> usize {
        self.findings.iter().filter(|f| f.violated).count()
    }

    pub fn to_json(&self) -> Json {
        let outcomes =
            self.outcomes.iter().map(|(k, v)| (k.as_str(), num(*v as f64))).collect::<Vec<_>>();
        let stages =
            self.stages.iter().map(|(name, st)| (*name, st.to_json())).collect::<Vec<_>>();
        obj(vec![
            ("schema", s(DOCTOR_SCHEMA)),
            ("frames", num(self.frames as f64)),
            ("outcomes", obj(outcomes)),
            ("stages", obj(stages)),
            ("findings", arr(self.findings.iter().map(Finding::to_json).collect())),
            ("violations", num(self.violations() as f64)),
        ])
    }

    /// Human-readable digest for the CLI.
    pub fn summary(&self) -> String {
        let mut out = format!("{} frames audited\n", self.frames);
        for (name, st) in &self.stages {
            if st.count == 0 {
                continue;
            }
            out.push_str(&format!(
                "  {name:<16} n={:<6} p50={:.3}ms p95={:.3}ms p99={:.3}ms\n",
                st.count, st.p50_ms, st.p95_ms, st.p99_ms
            ));
        }
        for f in &self.findings {
            let mark = if f.violated { "FAIL" } else { "ok  " };
            out.push_str(&format!("  [{mark}] {}: {}\n", f.kind, f.detail));
        }
        out
    }
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// Audit the trace at `path`. A corrupt/truncated trace is a
/// [`TraceError`], not a finding — the caller must treat it as fatal.
pub fn diagnose(path: &str, spec: &DoctorSpec) -> Result<DoctorReport, TraceError> {
    let (meta, records) = TraceReader::open(path)?.read_all()?;
    Ok(diagnose_records(&meta, &records, spec))
}

/// Audit a rotated multi-segment capture as one stream (segments in
/// order; every segment must carry the same meta record).
pub fn diagnose_segments<P: AsRef<std::path::Path>>(
    paths: &[P],
    spec: &DoctorSpec,
) -> Result<DoctorReport, TraceError> {
    let (meta, records) = crate::obs::trace::read_all_segments(paths)?;
    Ok(diagnose_records(&meta, &records, spec))
}

/// The audit core — pure function of the records (test seam).
pub fn diagnose_records(
    meta: &TraceMeta,
    records: &[TraceRecord],
    spec: &DoctorSpec,
) -> DoctorReport {
    let spans: Vec<&Span> = records.iter().map(|r| &r.span).collect();

    // outcome tally
    let mut outcomes: BTreeMap<String, u64> = BTreeMap::new();
    for sp in &spans {
        *outcomes.entry(sp.outcome.name().to_string()).or_insert(0) += 1;
    }

    // per-stage latency decomposition (stage i = segment ending at i)
    let mut stages = Vec::new();
    let mut total = Samples::new();
    for st in ALL_STAGES.iter().skip(1) {
        let mut seg = Samples::new();
        for sp in &spans {
            if let Some(ns) = sp.segment_ns(*st) {
                seg.push(ms(ns));
            }
        }
        stages.push((st.name(), StageStat::of(&seg)));
    }
    for sp in &spans {
        total.push(ms(sp.total_ns()));
    }
    stages.push(("total", StageStat::of(&total)));

    let mut findings = Vec::new();
    findings.extend(check_deadlines(&spans, spec));
    findings.push(check_shed_storm(&spans, spec));
    findings.push(check_batch_fill(&spans, meta, spec));
    findings.push(check_linger(&spans, spec));
    findings.push(check_breakers(&spans, spec));
    findings.push(check_queue_outliers(&spans, spec));
    findings.push(check_device_skew(&spans, spec));
    findings.extend(check_slo_burn(records, spec));

    DoctorReport { frames: spans.len(), outcomes, stages, findings }
}

/// SLO audit per deadline class (requests sharing a `deadline_ms`).
fn check_deadlines(spans: &[&Span], spec: &DoctorSpec) -> Vec<Finding> {
    let mut classes: BTreeMap<u64, (u64, u64)> = BTreeMap::new();
    for sp in spans {
        if sp.deadline_ms == 0 {
            continue; // no deadline: nothing to miss
        }
        let e = classes.entry(sp.deadline_ms).or_insert((0, 0));
        e.0 += 1;
        if sp.outcome == Outcome::Err(ErrCode::DeadlineExceeded) {
            e.1 += 1;
        }
    }
    classes
        .iter()
        .map(|(class, (n, missed))| {
            let rate = *missed as f64 / *n as f64;
            Finding {
                kind: "deadline_miss_rate",
                detail: format!("class {class}ms: {missed}/{n} requests missed their deadline"),
                value: rate,
                threshold: spec.max_deadline_miss_rate,
                violated: rate > spec.max_deadline_miss_rate,
            }
        })
        .collect()
}

/// Densest `busy` burst in any `shed_window` consecutive records.
fn check_shed_storm(spans: &[&Span], spec: &DoctorSpec) -> Finding {
    let win = spec.shed_window.max(1);
    let busy: Vec<u64> =
        spans.iter().map(|sp| u64::from(sp.outcome == Outcome::Err(ErrCode::Busy))).collect();
    let mut in_win: u64 = busy.iter().take(win).sum();
    let mut worst = in_win;
    for i in win..busy.len() {
        in_win += busy[i];
        in_win -= busy[i - win];
        worst = worst.max(in_win);
    }
    Finding {
        kind: "shed_storm",
        detail: format!("densest busy-shed burst: {worst} in any {win} consecutive requests"),
        value: worst as f64,
        threshold: spec.max_shed_burst as f64,
        violated: worst > spec.max_shed_burst,
    }
}

/// Mean batch fill across served requests.
fn check_batch_fill(spans: &[&Span], meta: &TraceMeta, spec: &DoctorSpec) -> Finding {
    let cap = meta.max_batch.max(1) as f64;
    let mut fill = Samples::new();
    let mut underfull = 0u64;
    for sp in spans {
        if sp.batch_size == 0 {
            continue; // never batched (shed before enqueue)
        }
        fill.push(sp.batch_size as f64 / cap);
        if (sp.batch_size as usize) < meta.max_batch {
            underfull += 1;
        }
    }
    let mean = if fill.is_empty() { 1.0 } else { fill.mean() };
    Finding {
        kind: "underfull_batches",
        detail: format!(
            "mean batch fill {:.3} of max_batch={} ({underfull}/{} requests under-full)",
            mean,
            meta.max_batch,
            fill.len()
        ),
        value: mean,
        threshold: spec.min_batch_fill,
        violated: mean < spec.min_batch_fill,
    }
}

/// Share of end-to-end latency spent between enqueue and batch
/// formation (queue wait + batching linger).
fn check_linger(spans: &[&Span], spec: &DoctorSpec) -> Finding {
    let (mut wait_ns, mut total_ns) = (0u128, 0u128);
    for sp in spans {
        if let Some(w) = sp.segment_ns(Stage::BatchForm) {
            wait_ns += w as u128;
            total_ns += sp.total_ns() as u128;
        }
    }
    let share = if total_ns == 0 { 0.0 } else { wait_ns as f64 / total_ns as f64 };
    Finding {
        kind: "linger_dominance",
        detail: format!("batch-formation wait is {:.1}% of end-to-end latency", share * 100.0),
        value: share,
        threshold: spec.max_linger_share,
        violated: share > spec.max_linger_share,
    }
}

fn check_breakers(spans: &[&Span], spec: &DoctorSpec) -> Finding {
    let trips = spans.iter().filter(|sp| sp.breaker_tripped).count() as u64;
    Finding {
        kind: "breaker_flap",
        detail: format!("{trips} requests saw a circuit-breaker trip"),
        value: trips as f64,
        threshold: spec.max_breaker_trips as f64,
        violated: trips > spec.max_breaker_trips,
    }
}

/// Queue waits beyond `outlier_factor × median` wait.
fn check_queue_outliers(spans: &[&Span], spec: &DoctorSpec) -> Finding {
    let mut waits = Samples::new();
    for sp in spans {
        if let Some(w) = sp.segment_ns(Stage::BatchForm) {
            waits.push(ms(w));
        }
    }
    let median = waits.percentile(0.50);
    let cut = median * spec.outlier_factor;
    let outliers = if waits.is_empty() || median <= 0.0 {
        0u64
    } else {
        spans
            .iter()
            .filter_map(|sp| sp.segment_ns(Stage::BatchForm))
            .filter(|&w| ms(w) > cut)
            .count() as u64
    };
    Finding {
        kind: "queue_wait_outliers",
        detail: format!(
            "{outliers} waits beyond {:.1}× the {median:.3}ms median queue wait",
            spec.outlier_factor
        ),
        value: outliers as f64,
        threshold: spec.max_queue_outliers as f64,
        violated: outliers > spec.max_queue_outliers,
    }
}

/// Fleet load imbalance: the busiest device's span count vs the
/// per-device mean. Only spans actually served by a device count
/// (`device_index == u32::MAX` means "never dispatched"). A fleet of
/// 0 or 1 devices cannot be skewed (value 0.0 / 1.0 respectively).
fn check_device_skew(spans: &[&Span], spec: &DoctorSpec) -> Finding {
    let mut counts: BTreeMap<u32, u64> = BTreeMap::new();
    for sp in spans {
        if sp.device_index != u32::MAX {
            *counts.entry(sp.device_index).or_insert(0) += 1;
        }
    }
    let (value, detail) = if counts.is_empty() {
        (0.0, "no spans reached a device".to_string())
    } else if counts.len() == 1 {
        let (dev, n) = counts.iter().next().map(|(d, n)| (*d, *n)).unwrap();
        (1.0, format!("single device {dev} served all {n} spans"))
    } else {
        let total: u64 = counts.values().sum();
        let (busiest, max) = counts.iter().max_by_key(|(_, n)| **n).map(|(d, n)| (*d, *n)).unwrap();
        let mean = total as f64 / counts.len() as f64;
        let ratio = max as f64 / mean;
        (
            ratio,
            format!(
                "busiest device {busiest} served {max}/{total} spans across {} devices \
                 ({ratio:.3}x the per-device mean)",
                counts.len()
            ),
        )
    };
    Finding {
        kind: "device_skew",
        detail,
        value,
        threshold: spec.max_device_skew,
        violated: value > spec.max_device_skew,
    }
}

/// Per-class SLO burn from the trace. Only Ok outcomes count (sheds
/// and typed errors are other checks' business), matching the live
/// registry's classification; an idle class is vacuously clean.
fn check_slo_burn(records: &[TraceRecord], spec: &DoctorSpec) -> Vec<Finding> {
    let Some(slo) = &spec.slo else {
        return Vec::new();
    };
    slo.classes
        .iter()
        .map(|class| {
            let (mut good, mut bad) = (0u64, 0u64);
            for r in records {
                if r.span.outcome != Outcome::Ok
                    || r.req.slo_class.as_deref() != Some(class.name.as_str())
                {
                    continue;
                }
                if r.span.total_ns() <= class.latency_ns() {
                    good += 1;
                } else {
                    bad += 1;
                }
            }
            let total = good + bad;
            let allowed = 1.0 - class.target;
            let burn = if total == 0 || allowed <= 0.0 {
                0.0
            } else {
                (bad as f64 / total as f64) / allowed
            };
            Finding {
                kind: "slo_burn",
                detail: format!(
                    "class {:?}: {bad}/{total} Ok frames over {}ms against target {} \
                     (burn {burn:.3})",
                    class.name, class.latency_ms, class.target
                ),
                value: burn,
                threshold: 1.0,
                violated: burn > 1.0,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::Method;
    use crate::serve::proto::{ErrorFrame, Frame, RequestFrame, ResponseFrame};

    fn meta() -> TraceMeta {
        TraceMeta {
            board: "pynq-z2".into(),
            model: "table3".into(),
            weights: "synthetic:1".into(),
            config: "default".into(),
            elems: 2,
            out_n: 2,
            workers: 1,
            max_batch: 4,
            max_wait_ms: 1,
        }
    }

    /// A record whose span walked the whole pipeline with the given
    /// queue wait, batch size, and outcome.
    fn rec(seq: u64, wait_ns: u64, batch_size: u32, outcome: Outcome) -> TraceRecord {
        let mut span = Span::start(seq, 1, 1, Method::Guided);
        span.stages = [0; crate::obs::span::N_STAGES];
        let t0 = 1_000_000 * (seq + 1);
        span.stamp(Stage::Accept, t0);
        span.stamp(Stage::Decode, t0 + 10_000);
        span.stamp(Stage::Admit, t0 + 20_000);
        span.stamp(Stage::Enqueue, t0 + 30_000);
        span.stamp(Stage::BatchForm, t0 + 30_000 + wait_ns);
        span.stamp(Stage::Dispatch, t0 + 40_000 + wait_ns);
        span.stamp(Stage::DeviceComplete, t0 + 140_000 + wait_ns);
        span.stamp(Stage::Encode, t0 + 150_000 + wait_ns);
        span.stamp(Stage::Flush, t0 + 160_000 + wait_ns);
        span.batch_id = seq;
        span.batch_size = batch_size;
        span.device_index = 0;
        span.attempts = 1;
        span.deadline_ms = 100;
        span.outcome = outcome;
        let req = RequestFrame {
            id: seq,
            method: Method::Guided,
            target: None,
            n: 1,
            elems: 2,
            deadline_ms: Some(100),
            with_crc: false,
            trace_seq: None,
            slo_class: None,
            images: vec![0.0, 1.0],
        };
        let reply = match outcome {
            Outcome::Ok => Frame::Response(ResponseFrame {
                id: seq,
                n: 1,
                elems: 2,
                out_n: 2,
                preds: vec![0],
                device_cycles: vec![100],
                with_crc: false,
                logits: vec![1.0, 0.0],
                relevance: vec![0.5, 0.5],
            }),
            Outcome::Err(code) => {
                Frame::Error(ErrorFrame { id: seq, code, msg: "injected".into() })
            }
        };
        TraceRecord { span, req, reply }
    }

    #[test]
    fn healthy_trace_has_no_violations_and_full_decomposition() {
        let records: Vec<TraceRecord> =
            (0..20).map(|i| rec(i, 50_000, 4, Outcome::Ok)).collect();
        let report = diagnose_records(&meta(), &records, &DoctorSpec::default());
        assert_eq!(report.frames, 20);
        assert_eq!(report.violations(), 0);
        assert_eq!(report.outcomes.get("ok"), Some(&20));
        // every non-accept stage got a sample from every span
        for (name, st) in &report.stages {
            assert_eq!(st.count, 20, "stage {name} sampled {}", st.count);
            assert!(st.p99_ms >= st.p50_ms);
        }
        // identical waits: no outliers
        let f = report.findings.iter().find(|f| f.kind == "queue_wait_outliers").unwrap();
        assert_eq!(f.value, 0.0);
    }

    #[test]
    fn pathologies_are_flagged_against_tight_thresholds() {
        let mut records: Vec<TraceRecord> = Vec::new();
        for i in 0..40 {
            // half the deadline class misses; sheds cluster early;
            // batches run half-full; one wait is a 100x outlier
            let outcome = match i {
                0..=4 => Outcome::Err(ErrCode::Busy),
                5..=9 => Outcome::Err(ErrCode::DeadlineExceeded),
                _ => Outcome::Ok,
            };
            let wait = if i == 20 { 5_000_000 } else { 50_000 };
            let mut r = rec(i, wait, 2, outcome);
            if i == 30 {
                r.span.breaker_tripped = true;
                r.span.attempts = 2;
            }
            records.push(r);
        }
        let spec = DoctorSpec {
            max_deadline_miss_rate: 0.05,
            max_shed_burst: 2,
            shed_window: 10,
            min_batch_fill: 0.9,
            max_linger_share: 1.0,
            max_breaker_trips: 0,
            outlier_factor: 10.0,
            max_queue_outliers: 0,
            max_device_skew: f64::INFINITY,
            slo: None,
        };
        let report = diagnose_records(&meta(), &records, &spec);
        let violated: Vec<&str> =
            report.findings.iter().filter(|f| f.violated).map(|f| f.kind).collect();
        assert!(violated.contains(&"deadline_miss_rate"), "{violated:?}");
        assert!(violated.contains(&"shed_storm"), "{violated:?}");
        assert!(violated.contains(&"underfull_batches"), "{violated:?}");
        assert!(violated.contains(&"breaker_flap"), "{violated:?}");
        assert!(violated.contains(&"queue_wait_outliers"), "{violated:?}");
        assert_eq!(report.violations(), violated.len());
    }

    #[test]
    fn device_skew_measures_fleet_imbalance() {
        // balanced: 10 spans each on devices 0 and 1 -> ratio 1.0
        let mut records: Vec<TraceRecord> = Vec::new();
        for i in 0..20u64 {
            let mut r = rec(i, 50_000, 4, Outcome::Ok);
            r.span.device_index = (i % 2) as u32;
            records.push(r);
        }
        let spec = DoctorSpec { max_device_skew: 1.5, ..DoctorSpec::default() };
        let report = diagnose_records(&meta(), &records, &spec);
        let f = report.findings.iter().find(|f| f.kind == "device_skew").unwrap();
        assert_eq!(f.value, 1.0);
        assert!(!f.violated);

        // skewed: 18 spans on device 0, 2 on device 1 -> ratio 1.8
        for (i, r) in records.iter_mut().enumerate() {
            r.span.device_index = u32::from(i >= 18);
        }
        let report = diagnose_records(&meta(), &records, &spec);
        let f = report.findings.iter().find(|f| f.kind == "device_skew").unwrap();
        assert!((f.value - 1.8).abs() < 1e-12, "{}", f.value);
        assert!(f.violated, "1.8x skew beyond the 1.5 threshold");
        assert!(f.detail.contains("busiest device 0"), "{}", f.detail);

        // undispatched spans are excluded entirely
        for r in records.iter_mut() {
            r.span.device_index = u32::MAX;
        }
        let report = diagnose_records(&meta(), &records, &spec);
        let f = report.findings.iter().find(|f| f.kind == "device_skew").unwrap();
        assert_eq!(f.value, 0.0);
        assert!(!f.violated);
    }

    #[test]
    fn single_device_fleet_is_never_skewed() {
        // rec() pins device_index = 0: default captures stay clean
        let records: Vec<TraceRecord> = (0..8).map(|i| rec(i, 50_000, 4, Outcome::Ok)).collect();
        let spec = DoctorSpec { max_device_skew: 1.0, ..DoctorSpec::default() };
        let report = diagnose_records(&meta(), &records, &spec);
        let f = report.findings.iter().find(|f| f.kind == "device_skew").unwrap();
        assert_eq!(f.value, 1.0);
        assert!(!f.violated, "ratio 1.0 is not beyond a 1.0 threshold");
    }

    #[test]
    fn slo_burn_audits_classed_ok_frames_per_class() {
        use crate::obs::slo::{SloClass, SloSpec};
        // rec() spans span accept→flush in 210 µs (0.21 ms)
        let mut records: Vec<TraceRecord> =
            (0..20).map(|i| rec(i, 50_000, 4, Outcome::Ok)).collect();
        for r in records.iter_mut().take(10) {
            r.req.slo_class = Some("gold".into());
        }
        // half the classed frames are sheds: they never count
        for r in records.iter_mut().take(5) {
            r.span.outcome = Outcome::Err(ErrCode::Busy);
        }
        let slo = SloSpec {
            classes: vec![
                SloClass { name: "gold".into(), latency_ms: 0.1, target: 0.9, budget: 1 },
                SloClass { name: "silver".into(), latency_ms: 1.0, target: 0.9, budget: 1 },
            ],
        };
        let spec = DoctorSpec { slo: Some(slo), ..DoctorSpec::default() };
        let report = diagnose_records(&meta(), &records, &spec);
        let burns: Vec<&Finding> =
            report.findings.iter().filter(|f| f.kind == "slo_burn").collect();
        assert_eq!(burns.len(), 2, "one finding per spec class");
        // gold: 5 Ok classed frames, all over 0.1 ms → bad fraction
        // 1.0 against an allowed 0.1 → burn 10
        assert!((burns[0].value - 10.0).abs() < 1e-9, "{}", burns[0].value);
        assert!(burns[0].violated);
        // silver: idle class is vacuously clean
        assert_eq!(burns[1].value, 0.0);
        assert!(!burns[1].violated);
        // without a spec, no slo finding exists at all
        let plain = diagnose_records(&meta(), &records, &DoctorSpec::default());
        assert!(plain.findings.iter().all(|f| f.kind != "slo_burn"));
    }

    #[test]
    fn report_json_is_deterministic_and_schema_tagged() {
        let records: Vec<TraceRecord> = (0..10)
            .map(|i| {
                rec(i, 10_000 * (i + 1), 3, if i == 3 { Outcome::Err(ErrCode::Busy) } else { Outcome::Ok })
            })
            .collect();
        let a = diagnose_records(&meta(), &records, &DoctorSpec::default()).to_json().to_string();
        let b = diagnose_records(&meta(), &records, &DoctorSpec::default()).to_json().to_string();
        assert_eq!(a, b);
        assert!(a.contains("\"schema\":\"attrax-doctor/v1\""), "{a}");
        // re-parseable
        Json::parse(&a).unwrap();
    }

    #[test]
    fn empty_trace_audits_cleanly() {
        let report = diagnose_records(&meta(), &[], &DoctorSpec::default());
        assert_eq!(report.frames, 0);
        assert_eq!(report.violations(), 0);
        for f in &report.findings {
            assert!(f.value.is_finite(), "{}: {}", f.kind, f.value);
        }
        let j = report.to_json().to_string();
        Json::parse(&j).unwrap();
    }
}
