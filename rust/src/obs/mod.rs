//! Observability: per-request spans, the `attrax-trace/v1` capture
//! artifact, deterministic replay, and the offline `doctor` audit.
//!
//! The design splits cleanly along the hot/cold boundary:
//!
//! * [`span`] is the hot path — a fixed-size, heap-free per-request
//!   ledger the server always stamps (nanosecond stage timestamps +
//!   batch/device/retry facts), handed to an optional
//!   [`span::Recorder`] when one is configured and dropped otherwise;
//! * [`trace`] is the cold sink — a CRC-protected, append-only,
//!   schema-tagged record stream holding each span plus the exact
//!   wire frames that crossed the socket;
//! * [`replay`] re-drives a captured trace against a rebuilt
//!   coordinator (or a live server) and reconciles every heatmap
//!   bitwise — the engine's determinism contract, enforced end to
//!   end;
//! * [`doctor`] audits a trace offline for SLO misses, shed storms,
//!   batching pathologies, breaker flaps, and queue-wait outliers,
//!   emitting the byte-stable `attrax-doctor/v1` report.

pub mod doctor;
pub mod replay;
pub mod span;
pub mod trace;

pub use doctor::{diagnose, DoctorReport, DoctorSpec, Finding};
pub use replay::{replay_in_process, replay_live, replay_with_sim, ReplayReport, Timing};
pub use span::{Recorder, Span, Stage};
pub use trace::{TraceMeta, TraceReader, TraceWriter};
