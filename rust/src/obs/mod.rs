//! Observability: per-request spans, the `attrax-trace/v1` capture
//! artifact, deterministic replay, and the offline `doctor` audit.
//!
//! The design splits cleanly along the hot/cold boundary:
//!
//! * [`span`] is the hot path — a fixed-size, heap-free per-request
//!   ledger the server always stamps (nanosecond stage timestamps +
//!   batch/device/retry facts), handed to an optional
//!   [`span::Recorder`] when one is configured and dropped otherwise;
//! * [`trace`] is the cold sink — a CRC-protected, append-only,
//!   schema-tagged record stream holding each span plus the exact
//!   wire frames that crossed the socket;
//! * [`replay`] re-drives a captured trace against a rebuilt
//!   coordinator (or a live server) and reconciles every heatmap
//!   bitwise — the engine's determinism contract, enforced end to
//!   end;
//! * [`doctor`] audits a trace offline for SLO misses, shed storms,
//!   batching pathologies, breaker flaps, queue-wait outliers, and
//!   fleet load imbalance, emitting the byte-stable `attrax-doctor/v1`
//!   report;
//! * [`telemetry`] is the *live* hot path — a lock-free metrics
//!   registry (counters/gauges/fixed-edge histograms, atomics only)
//!   plus the per-fused-unit engine profiler and the deterministic
//!   1-in-N span sampler;
//! * [`export`] is the live cold side — Prometheus-style text
//!   exposition of the registry over a one-shot TCP endpoint
//!   (`serve --stats-addr`), with the scrape client, parser, and
//!   `attrax top` dashboard renderer;
//! * [`slo`] turns scrapes into verdicts — the `attrax-slo/v1`
//!   objective artifact (per-class latency threshold, success target,
//!   error budget) and the pure counter-delta evaluator behind
//!   `attrax monitor`'s burn-rate table;
//! * [`push`] inverts the export direction for fleets behind NAT —
//!   statsd-style counter deltas over UDP from a bounded-queue
//!   emitter thread (`serve --push-addr`), drops counted in the
//!   registry rather than ever blocking a request.

pub mod doctor;
pub mod export;
pub mod push;
pub mod replay;
pub mod slo;
pub mod span;
pub mod telemetry;
pub mod trace;

pub use doctor::{diagnose, diagnose_segments, DoctorReport, DoctorSpec, Finding};
pub use export::{scrape, StatsEndpoint, StatsSummary};
pub use push::PushEmitter;
pub use slo::{evaluate, SloReport, SloSpec};
pub use replay::{
    replay_in_process, replay_live, replay_segments_in_process, replay_segments_live,
    replay_with_sim, ReplayReport, Timing,
};
pub use span::{Recorder, Span, Stage};
pub use telemetry::{Registry, SampledRecorder, UnitProfiler};
pub use trace::{read_all_segments, TraceMeta, TraceReader, TraceWriter};
