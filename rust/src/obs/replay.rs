//! Deterministic trace replay: re-drive a captured `attrax-trace/v1`
//! stream against a freshly built in-process coordinator (or a live
//! server) and reconcile every heatmap bitwise against the recorded
//! responses.
//!
//! The engine is bit-exact regardless of batch composition (the
//! fixed-point pipeline admits no data races and no
//! accumulation-order freedom), so a replay on the same model, same
//! weights, and same board-derived config must reproduce every pred,
//! logit, and relevance value to the bit. What is deliberately *not*
//! reconciled: per-image `device_cycles` (the per-batch total is
//! divided across whatever micro-batch the scheduler formed, which
//! varies with timing) and load-dependent outcomes (`busy`,
//! `deadline_exceeded` — those records are counted as skipped, not
//! replayed). Any payload mismatch is a divergence; divergences make
//! [`ReplayReport::ok`] false and the CLI exit nonzero.

use std::net::TcpStream;
use std::sync::mpsc;
use std::time::Duration;

use crate::attribution::Method;
use crate::coordinator::{Config, Coordinator};
use crate::fpga::{self, Board};
use crate::model::{artifacts_dir, load_artifacts, Network, Params};
use crate::obs::span::{Outcome, Stage};
use crate::obs::trace::{TraceMeta, TraceReader, TraceRecord};
use crate::sched::Simulator;
use crate::serve::proto::{self, Frame, ResponseFrame};

/// Inter-record pacing.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Timing {
    /// Sleep the recorded accept-to-accept gaps (capped at 1 s each).
    Recorded,
    /// No pacing: replay as fast as the stack answers.
    Asap,
}

impl Timing {
    pub fn parse(s: &str) -> Option<Timing> {
        match s {
            "recorded" => Some(Timing::Recorded),
            "asap" => Some(Timing::Asap),
            _ => None,
        }
    }
}

/// Per-gap pacing cap: a trace captured across an idle hour should
/// not take an hour to replay.
const MAX_GAP: Duration = Duration::from_secs(1);

/// Replay outcome tally. `matched + diverged + skipped == frames`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplayReport {
    /// Records in the trace (excluding meta).
    pub frames: usize,
    /// Records whose re-driven response reconciled bitwise.
    pub matched: usize,
    /// Records whose re-driven response differed (or failed).
    pub diverged: usize,
    /// Records with load-dependent error outcomes — not replayable
    /// deterministically, so not reconciled.
    pub skipped: usize,
}

impl ReplayReport {
    pub fn ok(&self) -> bool {
        self.diverged == 0
    }
}

/// Rebuild the serving stack the trace was captured on. Refuses
/// traces whose environment is not reproducible from the meta record
/// alone (non-built-in model, tuned/custom hardware config).
fn sim_from_meta(meta: &TraceMeta) -> anyhow::Result<Simulator> {
    anyhow::ensure!(
        meta.model == "table3",
        "trace was captured on model {:?}; in-process replay only rebuilds the built-in table3 \
         model (use --addr to replay against a live server)",
        meta.model
    );
    anyhow::ensure!(
        meta.config == "default",
        "trace was captured on a custom hardware config; in-process replay only rebuilds \
         board-default configs (use --addr to replay against a live server)"
    );
    let board = Board::parse(&meta.board)
        .ok_or_else(|| anyhow::anyhow!("trace names unknown board {:?}", meta.board))?;
    let net = Network::table3();
    let cfg = fpga::choose_config(board, &net, Method::Guided);
    let params = match meta.weights.strip_prefix("synthetic:") {
        Some(seed) => {
            let seed: u64 = seed
                .parse()
                .map_err(|_| anyhow::anyhow!("bad synthetic weights seed {:?}", meta.weights))?;
            Params::synthetic(&net, seed)
        }
        None if meta.weights == "artifacts" => load_artifacts(&artifacts_dir())?.1,
        None => anyhow::bail!("trace names unknown weights spec {:?}", meta.weights),
    };
    let sim = Simulator::new(net, &params, cfg)?;
    anyhow::ensure!(
        sim.net.input.elems() == meta.elems,
        "rebuilt model takes {} elems, trace says {}",
        sim.net.input.elems(),
        meta.elems
    );
    Ok(sim)
}

/// The recorded response for an ok-outcome record, or `None` when the
/// record is not bitwise-reconcilable (error outcome / error reply).
fn recorded_response(rec: &TraceRecord) -> Option<&ResponseFrame> {
    if rec.span.outcome != Outcome::Ok {
        return None;
    }
    match &rec.reply {
        Frame::Response(r) => Some(r),
        _ => None,
    }
}

/// Bitwise equality for the replay-comparable parts of two responses
/// (`device_cycles` excluded — see module docs).
fn responses_match(a: &ResponseFrame, b: &ResponseFrame) -> bool {
    a.n == b.n
        && a.elems == b.elems
        && a.out_n == b.out_n
        && a.preds == b.preds
        && a.logits.len() == b.logits.len()
        && a.relevance.len() == b.relevance.len()
        && a.logits.iter().zip(&b.logits).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.relevance.iter().zip(&b.relevance).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn pace(timing: Timing, prev_accept: &mut u64, rec: &TraceRecord) {
    if timing != Timing::Recorded {
        return;
    }
    if let Some(accept) = rec.span.get(Stage::Accept) {
        if *prev_accept != 0 && accept > *prev_accept {
            std::thread::sleep(Duration::from_nanos(accept - *prev_accept).min(MAX_GAP));
        }
        *prev_accept = accept;
    }
}

/// Replay `path` against a coordinator built on `sim` — the test seam
/// (tests pass a tiny model; the CLI builds from the trace meta via
/// [`replay_in_process`]). Records are re-driven strictly in recorded
/// order, whole frames at a time, preserving each frame's
/// method/batch mix.
pub fn replay_with_sim(
    path: &str,
    sim: Simulator,
    timing: Timing,
) -> anyhow::Result<ReplayReport> {
    let (meta, records) = TraceReader::open(path)?.read_all()?;
    replay_records_with_sim(&meta, &records, sim, timing)
}

/// The in-process replay core: re-drive already-loaded records
/// through a coordinator built on `sim` (shared by the single-file
/// and multi-segment entry points).
fn replay_records_with_sim(
    meta: &TraceMeta,
    records: &[TraceRecord],
    sim: Simulator,
    timing: Timing,
) -> anyhow::Result<ReplayReport> {
    anyhow::ensure!(
        sim.net.input.elems() == meta.elems,
        "replay model takes {} elems, trace says {}",
        sim.net.input.elems(),
        meta.elems
    );
    let coord = Coordinator::start(
        sim,
        Config {
            workers: meta.workers.max(1),
            max_batch: meta.max_batch.max(1),
            max_wait_ms: meta.max_wait_ms,
            ..Default::default()
        },
        None,
    )?;
    let mut report = ReplayReport::default();
    let mut prev_accept = 0u64;
    for rec in records {
        report.frames += 1;
        pace(timing, &mut prev_accept, rec);
        let Some(recorded) = recorded_response(rec) else {
            report.skipped += 1;
            continue;
        };
        match redrive_frame(&coord, rec) {
            Some(again) if responses_match(recorded, &again) => report.matched += 1,
            _ => report.diverged += 1,
        }
    }
    coord.shutdown();
    Ok(report)
}

/// Re-drive one recorded frame through the coordinator; `None` when
/// any image fails (counts as divergence at the caller).
fn redrive_frame(coord: &Coordinator, rec: &TraceRecord) -> Option<ResponseFrame> {
    let req = &rec.req;
    let mut rxs = Vec::with_capacity(req.n);
    for img in req.images.chunks_exact(req.elems) {
        let (tx, rx) = mpsc::channel();
        coord.submit(img.to_vec(), req.method, req.target, tx).ok()?;
        rxs.push(rx);
    }
    let mut preds = Vec::with_capacity(req.n);
    let mut device_cycles = Vec::with_capacity(req.n);
    let mut logits = Vec::new();
    let mut relevance = Vec::with_capacity(req.images.len());
    let mut out_n = 0usize;
    for rx in rxs {
        let resp = rx.recv().ok()?.ok()?;
        preds.push(resp.pred);
        device_cycles.push(resp.device_cycles);
        out_n = resp.logits.len();
        logits.extend_from_slice(&resp.logits);
        relevance.extend_from_slice(&resp.relevance);
    }
    Some(ResponseFrame {
        id: req.id,
        n: req.n,
        elems: req.elems,
        out_n,
        preds,
        device_cycles,
        with_crc: req.with_crc,
        logits,
        relevance,
    })
}

/// Replay `path` against a coordinator rebuilt from the trace's own
/// meta record (board, model, weights spec, batching knobs).
pub fn replay_in_process(path: &str, timing: Timing) -> anyhow::Result<ReplayReport> {
    let meta = TraceReader::open(path)?.meta.clone();
    let sim = sim_from_meta(&meta)?;
    replay_with_sim(path, sim, timing)
}

/// Replay a rotated multi-segment capture in-process as one stream
/// (segments in order, coordinator rebuilt once from the shared meta).
pub fn replay_segments_in_process(
    paths: &[String],
    timing: Timing,
) -> anyhow::Result<ReplayReport> {
    let (meta, records) = crate::obs::trace::read_all_segments(paths)?;
    let sim = sim_from_meta(&meta)?;
    replay_records_with_sim(&meta, &records, sim, timing)
}

/// Replay `path` against a live server at `addr`, resending the exact
/// recorded request frames over one connection (preserving arrival
/// order) with `trace_seq` set to the original frame id so the far
/// end's own trace can be joined back to this one.
pub fn replay_live(path: &str, addr: &str, timing: Timing) -> anyhow::Result<ReplayReport> {
    replay_segments_live(std::slice::from_ref(&path.to_string()), addr, timing)
}

/// Live replay of a rotated multi-segment capture: one connection and
/// one resend sequence shared across all segments (the far end sees
/// the same stream the original server did). Segments are read
/// incrementally; every segment must repeat the first one's meta.
pub fn replay_segments_live(
    paths: &[String],
    addr: &str,
    timing: Timing,
) -> anyhow::Result<ReplayReport> {
    anyhow::ensure!(!paths.is_empty(), "no trace segments given");
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut report = ReplayReport::default();
    let mut prev_accept = 0u64;
    let mut seq = 0u64;
    let mut meta: Option<TraceMeta> = None;
    for path in paths {
        let mut reader = TraceReader::open(path)?;
        match &meta {
            None => meta = Some(reader.meta.clone()),
            Some(m) => anyhow::ensure!(
                *m == reader.meta,
                "segment {path} has a different meta record (not the same capture)"
            ),
        }
        while let Some(rec) = reader.next()? {
            report.frames += 1;
            pace(timing, &mut prev_accept, &rec);
            let Some(recorded) = recorded_response(&rec) else {
                report.skipped += 1;
                continue;
            };
            seq += 1;
            let mut req = rec.req.clone();
            req.trace_seq = Some(rec.req.id);
            req.id = seq;
            proto::write_frame(&mut stream, &Frame::Request(req))?;
            let reply = proto::read_frame(&mut stream)
                .map_err(|e| anyhow::anyhow!("live reply: {e}"))?
                .ok_or_else(|| anyhow::anyhow!("server closed the connection mid-replay"))?;
            match reply {
                Frame::Response(again) if responses_match(recorded, &again) => report.matched += 1,
                _ => report.diverged += 1,
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::Span;
    use crate::obs::trace::TraceWriter;
    use crate::serve::proto::{ErrCode, ErrorFrame, RequestFrame};

    #[test]
    fn timing_parses() {
        assert_eq!(Timing::parse("recorded"), Some(Timing::Recorded));
        assert_eq!(Timing::parse("asap"), Some(Timing::Asap));
        assert_eq!(Timing::parse("warp"), None);
    }

    #[test]
    fn response_match_is_bitwise_and_ignores_cycles() {
        let a = ResponseFrame {
            id: 1,
            n: 1,
            elems: 2,
            out_n: 1,
            preds: vec![0],
            device_cycles: vec![10],
            with_crc: false,
            logits: vec![0.5],
            relevance: vec![1.0, -0.0],
        };
        let mut b = a.clone();
        b.device_cycles = vec![999]; // batch-composition-dependent
        assert!(responses_match(&a, &b));
        b.relevance[1] = 0.0; // -0.0 vs 0.0: equal as floats, not as bits
        assert!(!responses_match(&a, &b));
    }

    #[test]
    fn error_outcome_records_are_skipped_not_compared() {
        let rec = TraceRecord {
            span: {
                let mut s = Span::start(1, 1, 1, Method::Guided);
                s.outcome = Outcome::Err(ErrCode::Busy);
                s
            },
            req: RequestFrame {
                id: 1,
                method: Method::Guided,
                target: None,
                n: 1,
                elems: 2,
                deadline_ms: None,
                with_crc: false,
                trace_seq: None,
                slo_class: None,
                images: vec![0.0, 1.0],
            },
            reply: Frame::Error(ErrorFrame { id: 1, code: ErrCode::Busy, msg: "shed".into() }),
        };
        assert!(recorded_response(&rec).is_none());
    }

    #[test]
    fn in_process_replay_refuses_custom_configs() {
        let path =
            std::env::temp_dir().join(format!("attrax_replay_custom_{}.trace", std::process::id()));
        let meta = TraceMeta {
            board: "pynq-z2".into(),
            model: "table3".into(),
            weights: "synthetic:1".into(),
            config: "custom".into(),
            elems: 4,
            out_n: 2,
            workers: 1,
            max_batch: 1,
            max_wait_ms: 0,
        };
        let w = TraceWriter::create(&path, &meta).unwrap();
        w.finish().unwrap();
        let err = replay_in_process(path.to_str().unwrap(), Timing::Asap).unwrap_err();
        assert!(err.to_string().contains("custom hardware config"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
