//! Stats exposition: render the live [`Registry`] as Prometheus-style
//! text lines, serve them over a one-shot TCP endpoint, and parse them
//! back (`loadgen --stats-addr`, `attrax top`).
//!
//! The exposition grammar is one metric per line:
//!
//! ```text
//! name value
//! name{label="value",label2="value2"} value
//! ```
//!
//! `#`-prefixed lines are comments. Label values are quoted with the
//! same backslash-escape grammar as JSON strings
//! ([`crate::util::json::escape`]), so any unit/board name round-trips.
//! Values print as Rust `f64`/`u64` literals (`parse::<f64>` reads
//! every one back). The endpoint is deliberately one-shot: a client
//! connects, the server writes one full render and closes — no HTTP,
//! no request parsing, no keep-alive state — so a scrape can never
//! wedge a serving thread.
//!
//! Naming: every metric is `attrax_`-prefixed; monotone counters end
//! `_total`; histograms follow the `_bucket{le=...}`/`_count`/`_sum`
//! cumulative convention with deterministic power-of-two edges
//! ([`Histogram::edge`]). `attrax_snapshot_*` lines mirror
//! [`Snapshot`]'s fields one-for-one (a test destructures the struct
//! with no `..` to keep that set exhaustive).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::fleet::Device;
use crate::coordinator::metrics::Snapshot;
use crate::hls::Phase;
use crate::obs::span::{Stage, ALL_STAGES};
use crate::obs::telemetry::{Histogram, Registry, HIST_BUCKETS};
use crate::util::json::escape;

// ---------------------------------------------------------------------------
// Rendering
// ---------------------------------------------------------------------------

/// The registry's monotone counters with their exported names, in
/// exposition order. One row per [`Registry`] counter field — the
/// single source of truth shared by the renderer, the reconciliation
/// check in `loadgen`, and the coverage tests.
pub fn counter_pairs(reg: &Registry) -> Vec<(&'static str, u64)> {
    vec![
        ("attrax_completed_total", reg.completed.get()),
        ("attrax_rejected_total", reg.rejected.get()),
        ("attrax_rejected_busy_total", reg.rejected_busy.get()),
        ("attrax_deadline_exceeded_total", reg.deadline_exceeded.get()),
        ("attrax_errors_total", reg.errors.get()),
        ("attrax_retries_total", reg.retries.get()),
        ("attrax_breaker_trips_total", reg.breaker_trips.get()),
        ("attrax_integrity_failures_total", reg.integrity_failures.get()),
        ("attrax_reconnects_total", reg.reconnects.get()),
        ("attrax_conns_total", reg.conns_total.get()),
        ("attrax_verified_total", reg.verified.get()),
        ("attrax_spans_sampled_out_total", reg.spans_sampled_out.get()),
        ("attrax_push_dropped_total", reg.push_dropped.get()),
    ]
}

fn phase_label(phase: Phase) -> &'static str {
    match phase {
        Phase::Forward => "fwd",
        Phase::Backward => "bwd",
    }
}

fn push_label(out: &mut String, key: &str, value: &str) {
    out.push_str(key);
    out.push('=');
    escape(value, out);
}

fn push_hist(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    let cum = h.cumulative();
    for (i, &c) in cum.iter().enumerate() {
        out.push_str(name);
        out.push_str("_bucket{");
        if !labels.is_empty() {
            out.push_str(labels);
            out.push(',');
        }
        match Histogram::edge(i) {
            Some(e) => {
                out.push_str("le=\"");
                out.push_str(&e.to_string());
                out.push('"');
            }
            None => out.push_str("le=\"+Inf\""),
        }
        out.push_str("} ");
        out.push_str(&c.to_string());
        out.push('\n');
    }
    for (suffix, v) in [("_count", h.count()), ("_sum", h.sum())] {
        out.push_str(name);
        out.push_str(suffix);
        if !labels.is_empty() {
            out.push('{');
            out.push_str(labels);
            out.push('}');
        }
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
}

/// Render the registry: counters, gauges, the per-stage and
/// end-to-end latency histograms, and (when installed) the per-unit
/// engine profile.
pub fn render_registry(reg: &Registry) -> String {
    let mut out = String::with_capacity(8192);
    out.push_str("# attrax stats exposition\n");
    for (name, v) in counter_pairs(reg) {
        out.push_str(name);
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
    for (name, v) in [
        ("attrax_conns_open", reg.conns_open.get()),
        ("attrax_queue_depth", reg.queue_depth.get()),
    ] {
        out.push_str(name);
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
    for st in ALL_STAGES {
        if st == Stage::Accept {
            continue; // a span's first stamp opens no segment
        }
        let mut labels = String::new();
        push_label(&mut labels, "stage", st.name());
        push_hist(&mut out, "attrax_stage_ns", &labels, &reg.stage_ns[st as usize]);
    }
    push_hist(&mut out, "attrax_request_ns", "", &reg.request_ns);
    for (idx, class) in reg.class_names().iter().enumerate() {
        let mut labels = String::new();
        push_label(&mut labels, "class", class);
        for (name, v) in [
            ("attrax_class_good_total", reg.class_good[idx].get()),
            ("attrax_class_bad_total", reg.class_bad[idx].get()),
        ] {
            out.push_str(name);
            out.push('{');
            out.push_str(&labels);
            out.push_str("} ");
            out.push_str(&v.to_string());
            out.push('\n');
        }
        push_hist(&mut out, "attrax_class_request_ns", &labels, &reg.class_request_ns[idx]);
    }
    if let Some(prof) = reg.profiler() {
        for row in prof.rows() {
            let mut labels = String::new();
            push_label(&mut labels, "unit", &row.unit);
            labels.push(',');
            push_label(&mut labels, "kind", row.kind.name());
            labels.push(',');
            push_label(&mut labels, "phase", phase_label(row.phase));
            for (name, v) in [
                ("attrax_unit_passes_total", row.passes),
                ("attrax_unit_cycles_total", row.cycles),
                ("attrax_unit_wall_ns_total", row.wall_ns),
            ] {
                out.push_str(name);
                out.push('{');
                out.push_str(&labels);
                out.push_str("} ");
                out.push_str(&v.to_string());
                out.push('\n');
            }
        }
    }
    out
}

/// Render the coordinator's [`Snapshot`] as `attrax_snapshot_*`
/// lines. The destructure is exhaustive (no `..`) on purpose: adding
/// a `Snapshot` field without exporting it fails to compile.
pub fn snapshot_lines(snap: &Snapshot) -> String {
    let Snapshot {
        completed,
        rejected,
        rejected_busy,
        deadline_exceeded,
        open_conns,
        total_conns,
        errors,
        retries,
        breaker_trips,
        integrity_failures,
        reconnects,
        wall_s,
        throughput_ips,
        p50_ms,
        p95_ms,
        p99_ms,
        mean_ms,
        mean_queue_wait_ms,
        p50_queue_wait_ms,
        p95_queue_wait_ms,
        p99_queue_wait_ms,
        mean_sim_mcycles,
        verified,
        mean_verify_corr,
        min_verify_corr,
    } = snap;
    let ints: [(&str, u64); 11] = [
        ("completed", *completed),
        ("rejected", *rejected),
        ("rejected_busy", *rejected_busy),
        ("deadline_exceeded", *deadline_exceeded),
        ("open_conns", *open_conns),
        ("total_conns", *total_conns),
        ("errors", *errors),
        ("retries", *retries),
        ("breaker_trips", *breaker_trips),
        ("integrity_failures", *integrity_failures),
        ("reconnects", *reconnects),
    ];
    let floats: [(&str, f64); 13] = [
        ("wall_s", *wall_s),
        ("throughput_ips", *throughput_ips),
        ("p50_ms", *p50_ms),
        ("p95_ms", *p95_ms),
        ("p99_ms", *p99_ms),
        ("mean_ms", *mean_ms),
        ("mean_queue_wait_ms", *mean_queue_wait_ms),
        ("p50_queue_wait_ms", *p50_queue_wait_ms),
        ("p95_queue_wait_ms", *p95_queue_wait_ms),
        ("p99_queue_wait_ms", *p99_queue_wait_ms),
        ("mean_sim_mcycles", *mean_sim_mcycles),
        ("mean_verify_corr", *mean_verify_corr),
        ("min_verify_corr", *min_verify_corr),
    ];
    let mut out = String::new();
    for (name, v) in ints {
        out.push_str("attrax_snapshot_");
        out.push_str(name);
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
    out.push_str("attrax_snapshot_verified ");
    out.push_str(&verified.to_string());
    out.push('\n');
    for (name, v) in floats {
        out.push_str("attrax_snapshot_");
        out.push_str(name);
        out.push(' ');
        if v.is_finite() {
            out.push_str(&v.to_string());
        } else {
            out.push_str("NaN");
        }
        out.push('\n');
    }
    out
}

/// Render per-device fleet gauges: completed requests, the router's
/// in-flight load estimate, and breaker state/trips.
pub fn device_lines(devices: &[Arc<Device>]) -> String {
    let mut out = String::new();
    for (i, dev) in devices.iter().enumerate() {
        let mut labels = String::new();
        push_label(&mut labels, "device", &i.to_string());
        labels.push(',');
        push_label(&mut labels, "board", dev.board.name());
        let rows: [(&str, u64); 4] = [
            ("attrax_device_completed_total", dev.completed.load(Ordering::Relaxed)),
            ("attrax_device_inflight_us", dev.inflight_us()),
            ("attrax_device_breaker_open", dev.breaker.is_open() as u64),
            ("attrax_device_breaker_trips_total", dev.breaker.trips()),
        ];
        for (name, v) in rows {
            out.push_str(name);
            out.push('{');
            out.push_str(&labels);
            out.push_str("} ");
            out.push_str(&v.to_string());
            out.push('\n');
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Endpoint
// ---------------------------------------------------------------------------

/// One-shot TCP stats endpoint: each accepted connection gets one
/// full render and an immediate close. Runs its accept loop on a
/// dedicated thread; dropping the endpoint stops and joins it.
pub struct StatsEndpoint {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatsEndpoint {
    pub fn start<A: ToSocketAddrs>(
        addr: A,
        render: Box<dyn Fn() -> String + Send + Sync>,
    ) -> anyhow::Result<StatsEndpoint> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name("attrax-stats".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
                            let body = render();
                            let _ = stream.write_all(body.as_bytes());
                            // drop closes the socket: one shot per conn
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            })?;
        Ok(StatsEndpoint { addr: local, stop, thread: Some(thread) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for StatsEndpoint {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl std::fmt::Debug for StatsEndpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StatsEndpoint").field("addr", &self.addr).finish()
    }
}

/// Fetch one exposition body from a stats endpoint.
pub fn scrape(addr: &str, timeout: Duration) -> anyhow::Result<String> {
    let sa = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow::anyhow!("stats addr {addr} resolves to nothing"))?;
    let mut stream = TcpStream::connect_timeout(&sa, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    let mut body = String::new();
    stream.read_to_string(&mut body)?;
    Ok(body)
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

/// One parsed exposition line.
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Metric {
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

fn unescape(s: &str) -> anyhow::Result<(String, usize)> {
    // `s` starts just past the opening quote; returns (value, bytes
    // consumed including the closing quote).
    let mut out = String::new();
    let mut chars = s.char_indices();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Ok((out, i + 1)),
            '\\' => match chars.next() {
                Some((_, '"')) => out.push('"'),
                Some((_, '\\')) => out.push('\\'),
                Some((_, 'n')) => out.push('\n'),
                Some((_, 'r')) => out.push('\r'),
                Some((_, 't')) => out.push('\t'),
                Some((_, 'u')) => {
                    let mut code = 0u32;
                    for _ in 0..4 {
                        let (_, h) = chars
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                        code = code * 16
                            + h.to_digit(16).ok_or_else(|| anyhow::anyhow!("bad \\u digit"))?;
                    }
                    out.push(
                        char::from_u32(code)
                            .ok_or_else(|| anyhow::anyhow!("bad \\u code point"))?,
                    );
                }
                other => anyhow::bail!("bad escape {other:?} in label value"),
            },
            c => out.push(c),
        }
    }
    anyhow::bail!("unterminated label value")
}

fn parse_line(line: &str) -> anyhow::Result<Metric> {
    let (head, rest) = match line.find(|c| c == '{' || c == ' ') {
        Some(i) => line.split_at(i),
        None => anyhow::bail!("no value on line {line:?}"),
    };
    let name = head.to_string();
    anyhow::ensure!(!name.is_empty(), "empty metric name in {line:?}");
    let mut labels = Vec::new();
    let mut rest = rest;
    if let Some(stripped) = rest.strip_prefix('{') {
        let mut cur = stripped;
        loop {
            let eq = cur
                .find('=')
                .ok_or_else(|| anyhow::anyhow!("label without '=' in {line:?}"))?;
            let key = cur[..eq].trim().to_string();
            let after = &cur[eq + 1..];
            let q = after
                .strip_prefix('"')
                .ok_or_else(|| anyhow::anyhow!("unquoted label value in {line:?}"))?;
            let (value, used) = unescape(q)?;
            labels.push((key, value));
            let tail = &after[1 + used..];
            if let Some(t) = tail.strip_prefix(',') {
                cur = t;
            } else if let Some(t) = tail.strip_prefix('}') {
                rest = t;
                break;
            } else {
                anyhow::bail!("expected ',' or '}}' after label in {line:?}");
            }
        }
    }
    let value: f64 = rest
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad value {:?} in {line:?}", rest.trim()))?;
    Ok(Metric { name, labels, value })
}

/// Parse a full exposition body line-by-line (comments and blank
/// lines skipped; any malformed line is an error).
pub fn parse(text: &str) -> anyhow::Result<Vec<Metric>> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(parse_line(line)?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Summarizing (loadgen report + `attrax top`)
// ---------------------------------------------------------------------------

/// Per-stage latency quantiles recovered from the cumulative
/// histogram buckets of one scrape.
#[derive(Clone, Debug, PartialEq)]
pub struct StageQuantiles {
    pub stage: String,
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// One per-unit engine profile row from a scrape.
#[derive(Clone, Debug, PartialEq)]
pub struct UnitRow {
    pub unit: String,
    pub kind: String,
    pub phase: String,
    pub passes: u64,
    pub cycles: u64,
    pub wall_ns: u64,
}

/// One per-SLO-class row from a scrape: the registry's good/bad
/// counters plus the class latency quantiles. The raw counts feed
/// [`crate::obs::slo::evaluate`]'s pure counter arithmetic.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassRow {
    pub class: String,
    /// Completions within the class's latency threshold.
    pub good: u64,
    /// Completions over it.
    pub bad: u64,
    /// Class latency quantiles (None until something was observed).
    pub lat: Option<StageQuantiles>,
}

/// One per-device fleet row from a scrape.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceRow {
    pub device: u64,
    pub board: String,
    pub completed: u64,
    pub inflight_us: u64,
    pub breaker_open: bool,
    pub breaker_trips: u64,
}

/// Structured view of one scrape.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSummary {
    /// Unlabeled `_total` counters by full metric name.
    pub counters: std::collections::BTreeMap<String, f64>,
    /// Unlabeled non-counter values (gauges + `attrax_snapshot_*`).
    pub gauges: std::collections::BTreeMap<String, f64>,
    pub stages: Vec<StageQuantiles>,
    /// Per-SLO-class rows (exposition order = spec slot order).
    pub classes: Vec<ClassRow>,
    pub units: Vec<UnitRow>,
    pub devices: Vec<DeviceRow>,
}

fn bucket_quantile(buckets: &[(f64, f64)], total: f64, q: f64) -> f64 {
    // buckets: (upper edge ns, cumulative count) sorted by edge.
    // The rank is placed *within* its bucket by linear interpolation
    // (reporting the raw upper edge overstates quantiles by up to 2x
    // on power-of-two edges). Two cases keep their exact old-edge
    // values: a histogram whose whole mass sits in one bucket (nothing
    // to interpolate against — every quantile is that bucket's edge)
    // and a rank landing in the +Inf overflow bucket (no finite edge).
    if total <= 0.0 {
        return 0.0;
    }
    let rank = (q * total).ceil().clamp(1.0, total);
    let mut lower = 0.0;
    let mut prev_cum = 0.0;
    for &(edge, cum) in buckets {
        if cum >= rank {
            if !edge.is_finite() {
                return f64::INFINITY;
            }
            let in_bucket = cum - prev_cum;
            if in_bucket <= 0.0 || in_bucket >= total {
                return edge;
            }
            return lower + (rank - prev_cum) / in_bucket * (edge - lower);
        }
        if edge.is_finite() {
            lower = edge;
        }
        prev_cum = cum;
    }
    f64::INFINITY
}

fn hist_quantiles(metrics: &[Metric], name: &str, filter: Option<(&str, &str)>) -> StageQuantiles {
    let bucket_name = format!("{name}_bucket");
    let matches = |m: &Metric| match filter {
        Some((k, v)) => m.label(k) == Some(v),
        None => true,
    };
    let mut buckets: Vec<(f64, f64)> = metrics
        .iter()
        .filter(|m| m.name == bucket_name && matches(m))
        .filter_map(|m| {
            let le = m.label("le")?;
            let edge = if le == "+Inf" { f64::INFINITY } else { le.parse().ok()? };
            Some((edge, m.value))
        })
        .collect();
    buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
    let count_of = |suffix: &str| {
        metrics
            .iter()
            .find(|m| m.name == format!("{name}{suffix}") && matches(m))
            .map_or(0.0, |m| m.value)
    };
    let (count, sum) = (count_of("_count"), count_of("_sum"));
    let ns_to_ms = 1e-6;
    StageQuantiles {
        stage: filter.map(|(_, v)| v.to_string()).unwrap_or_else(|| "request".into()),
        count: count as u64,
        mean_ms: if count > 0.0 { sum / count * ns_to_ms } else { 0.0 },
        p50_ms: bucket_quantile(&buckets, count, 0.50) * ns_to_ms,
        p95_ms: bucket_quantile(&buckets, count, 0.95) * ns_to_ms,
        p99_ms: bucket_quantile(&buckets, count, 0.99) * ns_to_ms,
    }
}

/// Build the structured summary of one parsed scrape: counters,
/// gauges, per-stage quantiles (pipeline order, stamped stages only),
/// the end-to-end `request` row, per-unit profile rows (exposition
/// order), and per-device fleet rows.
pub fn summarize(metrics: &[Metric]) -> StatsSummary {
    let mut out = StatsSummary::default();
    for m in metrics {
        if m.labels.is_empty() {
            if m.name.ends_with("_total") {
                out.counters.insert(m.name.clone(), m.value);
            } else if !m.name.ends_with("_bucket")
                && !m.name.ends_with("_count")
                && !m.name.ends_with("_sum")
            {
                out.gauges.insert(m.name.clone(), m.value);
            }
        }
    }
    for st in ALL_STAGES {
        if st == Stage::Accept {
            continue;
        }
        let q = hist_quantiles(metrics, "attrax_stage_ns", Some(("stage", st.name())));
        if q.count > 0 {
            out.stages.push(q);
        }
    }
    let req = hist_quantiles(metrics, "attrax_request_ns", None);
    if req.count > 0 {
        out.stages.push(req);
    }
    for m in metrics.iter().filter(|m| m.name == "attrax_class_good_total") {
        let Some(class) = m.label("class") else {
            continue;
        };
        let bad = metrics
            .iter()
            .find(|b| b.name == "attrax_class_bad_total" && b.label("class") == Some(class))
            .map_or(0.0, |b| b.value);
        let q = hist_quantiles(metrics, "attrax_class_request_ns", Some(("class", class)));
        out.classes.push(ClassRow {
            class: class.to_string(),
            good: m.value as u64,
            bad: bad as u64,
            lat: (q.count > 0).then_some(q),
        });
    }
    // units: keyed rows appear as passes/cycles/wall triples; walk the
    // passes rows (exposition order = plan order) and join the rest.
    let find = |name: &str, unit: &str, phase: &str| {
        metrics
            .iter()
            .find(|m| m.name == name && m.label("unit") == Some(unit) && m.label("phase") == Some(phase))
            .map_or(0.0, |m| m.value)
    };
    for m in metrics.iter().filter(|m| m.name == "attrax_unit_passes_total") {
        let (Some(unit), Some(kind), Some(phase)) =
            (m.label("unit"), m.label("kind"), m.label("phase"))
        else {
            continue;
        };
        out.units.push(UnitRow {
            unit: unit.to_string(),
            kind: kind.to_string(),
            phase: phase.to_string(),
            passes: m.value as u64,
            cycles: find("attrax_unit_cycles_total", unit, phase) as u64,
            wall_ns: find("attrax_unit_wall_ns_total", unit, phase) as u64,
        });
    }
    let dev_find = |name: &str, device: &str| {
        metrics
            .iter()
            .find(|m| m.name == name && m.label("device") == Some(device))
            .map_or(0.0, |m| m.value)
    };
    let mut dev_rows: Vec<DeviceRow> = metrics
        .iter()
        .filter(|m| m.name == "attrax_device_completed_total")
        .filter_map(|m| {
            let device = m.label("device")?;
            Some(DeviceRow {
                device: device.parse().ok()?,
                board: m.label("board").unwrap_or("?").to_string(),
                completed: m.value as u64,
                inflight_us: dev_find("attrax_device_inflight_us", device) as u64,
                breaker_open: dev_find("attrax_device_breaker_open", device) != 0.0,
                breaker_trips: dev_find("attrax_device_breaker_trips_total", device) as u64,
            })
        })
        .collect();
    dev_rows.sort_by_key(|d| d.device);
    out.devices = dev_rows;
    out
}

impl StatsSummary {
    /// JSON shape embedded in `BENCH_serve.json` (`server_stats`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, num, obj, s, Json};
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
        );
        let stages = arr(self
            .stages
            .iter()
            .map(|st| {
                obj(vec![
                    ("stage", s(&st.stage)),
                    ("count", num(st.count as f64)),
                    ("mean_ms", num(st.mean_ms)),
                    ("p50_ms", num(st.p50_ms)),
                    ("p95_ms", num(st.p95_ms)),
                    ("p99_ms", num(st.p99_ms)),
                ])
            })
            .collect());
        let classes = arr(self
            .classes
            .iter()
            .map(|c| {
                let mut pairs = vec![
                    ("class", s(&c.class)),
                    ("good", num(c.good as f64)),
                    ("bad", num(c.bad as f64)),
                ];
                if let Some(l) = &c.lat {
                    pairs.push(("count", num(l.count as f64)));
                    pairs.push(("mean_ms", num(l.mean_ms)));
                    pairs.push(("p50_ms", num(l.p50_ms)));
                    pairs.push(("p95_ms", num(l.p95_ms)));
                    pairs.push(("p99_ms", num(l.p99_ms)));
                }
                obj(pairs)
            })
            .collect());
        let units = arr(self
            .units
            .iter()
            .map(|u| {
                obj(vec![
                    ("unit", s(&u.unit)),
                    ("kind", s(&u.kind)),
                    ("phase", s(&u.phase)),
                    ("passes", num(u.passes as f64)),
                    ("cycles", num(u.cycles as f64)),
                    ("wall_ns", num(u.wall_ns as f64)),
                ])
            })
            .collect());
        let devices = arr(self
            .devices
            .iter()
            .map(|d| {
                obj(vec![
                    ("device", num(d.device as f64)),
                    ("board", s(&d.board)),
                    ("completed", num(d.completed as f64)),
                    ("inflight_us", num(d.inflight_us as f64)),
                    ("breaker_open", Json::Bool(d.breaker_open)),
                    ("breaker_trips", num(d.breaker_trips as f64)),
                ])
            })
            .collect());
        obj(vec![
            ("counters", counters),
            ("stages", stages),
            ("classes", classes),
            ("units", units),
            ("devices", devices),
        ])
    }
}

// ---------------------------------------------------------------------------
// Dashboard (`attrax top`)
// ---------------------------------------------------------------------------

fn counter(sum: &StatsSummary, name: &str) -> f64 {
    sum.counters.get(name).copied().unwrap_or(0.0)
}

/// Render one `attrax top` frame from the current scrape summary
/// (and, when available, the previous one for rate computation over
/// `dt_s` seconds of wall time between scrapes).
pub fn dashboard(prev: Option<&StatsSummary>, cur: &StatsSummary, dt_s: f64) -> String {
    let mut out = String::with_capacity(4096);
    let completed = counter(cur, "attrax_completed_total");
    let rps = match prev {
        Some(p) if dt_s > 0.0 => (completed - counter(p, "attrax_completed_total")).max(0.0) / dt_s,
        _ => 0.0,
    };
    let gauge = |n: &str| cur.gauges.get(n).copied().unwrap_or(0.0);
    out.push_str(&format!(
        "attrax top — {rps:.1} req/s | completed {completed:.0} | shed {:.0} | \
         deadline {:.0} | errors {:.0} | retries {:.0}\n",
        counter(cur, "attrax_rejected_busy_total"),
        counter(cur, "attrax_deadline_exceeded_total"),
        counter(cur, "attrax_errors_total"),
        counter(cur, "attrax_retries_total"),
    ));
    out.push_str(&format!(
        "conns open {:.0} / total {:.0} | queue depth {:.0} | sampled-out spans {:.0}\n",
        gauge("attrax_conns_open"),
        counter(cur, "attrax_conns_total"),
        gauge("attrax_queue_depth"),
        counter(cur, "attrax_spans_sampled_out_total"),
    ));
    if !cur.stages.is_empty() {
        out.push_str("\n  stage              count      mean_ms     p50_ms     p95_ms     p99_ms\n");
        for st in &cur.stages {
            out.push_str(&format!(
                "  {:<16} {:>8} {:>12.3} {:>10.3} {:>10.3} {:>10.3}\n",
                st.stage, st.count, st.mean_ms, st.p50_ms, st.p95_ms, st.p99_ms
            ));
        }
    }
    if !cur.classes.is_empty() {
        out.push_str("\n  class            good      bad     p50_ms     p95_ms     p99_ms\n");
        for c in &cur.classes {
            let (p50, p95, p99) = c
                .lat
                .as_ref()
                .map_or((0.0, 0.0, 0.0), |l| (l.p50_ms, l.p95_ms, l.p99_ms));
            out.push_str(&format!(
                "  {:<14} {:>6} {:>8} {:>10.3} {:>10.3} {:>10.3}\n",
                c.class, c.good, c.bad, p50, p95, p99
            ));
        }
    }
    if !cur.units.is_empty() {
        let total_wall: u64 = cur.units.iter().map(|u| u.wall_ns).sum();
        out.push_str("\n  unit       kind     phase    passes       Mcycles      wall_ms   wall%\n");
        for u in &cur.units {
            let share = if total_wall > 0 { 100.0 * u.wall_ns as f64 / total_wall as f64 } else { 0.0 };
            out.push_str(&format!(
                "  {:<10} {:<8} {:<5} {:>9} {:>13.3} {:>12.3} {:>6.1}\n",
                u.unit,
                u.kind,
                u.phase,
                u.passes,
                u.cycles as f64 / 1e6,
                u.wall_ns as f64 / 1e6,
                share
            ));
        }
    }
    if !cur.devices.is_empty() {
        out.push_str("\n  device  board        completed  inflight_us  breaker  trips\n");
        for d in &cur.devices {
            out.push_str(&format!(
                "  {:<7} {:<12} {:>9} {:>12} {:<8} {:>5}\n",
                d.device,
                d.board,
                d.completed,
                d.inflight_us,
                if d.breaker_open { "OPEN" } else { "closed" },
                d.breaker_trips
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::EngineKind;
    use crate::obs::telemetry::UnitProfiler;

    #[test]
    fn render_parse_roundtrip_with_hard_label_values() {
        let reg = Registry::new();
        reg.completed.add(7);
        reg.install_profiler(Arc::new(UnitProfiler::new(vec![(
            "we\"ird\\unit\n".into(),
            EngineKind::Conv,
        )])));
        reg.profiler().unwrap().record(0, Phase::Forward, 123, 456);
        let text = render_registry(&reg);
        let metrics = parse(&text).unwrap();
        let m = metrics
            .iter()
            .find(|m| m.name == "attrax_unit_cycles_total")
            .expect("profiler row exported");
        assert_eq!(m.label("unit"), Some("we\"ird\\unit\n"), "escaping round-trips");
        assert_eq!(m.value, 123.0);
        let c = metrics.iter().find(|m| m.name == "attrax_completed_total").unwrap();
        assert_eq!(c.value, 7.0);
    }

    #[test]
    fn every_rendered_metric_is_unique() {
        let reg = Registry::new();
        reg.install_profiler(Arc::new(UnitProfiler::new(vec![
            ("c1".into(), EngineKind::Conv),
            ("f1".into(), EngineKind::Vmm),
        ])));
        let text = render_registry(&reg);
        let metrics = parse(&text).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for m in &metrics {
            let mut key = m.name.clone();
            for (k, v) in &m.labels {
                key.push_str(&format!("|{k}={v}"));
            }
            assert!(seen.insert(key.clone()), "duplicate series {key}");
        }
        assert!(metrics.len() > 12 + 2 + 8 * (HIST_BUCKETS + 2));
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(parse("attrax_x{unterminated=\"v} 1").is_err());
        assert!(parse("attrax_x nope").is_err());
        assert!(parse("attrax_x{k=unquoted} 1").is_err());
        assert!(parse("").unwrap().is_empty());
        assert!(parse("# comment only\n\n").unwrap().is_empty());
    }

    #[test]
    fn stage_quantiles_come_from_cumulative_buckets() {
        let reg = Registry::new();
        // 90 fast decodes (~2 µs) and 10 slow ones (~1 ms)
        for _ in 0..90 {
            reg.stage_ns[Stage::Decode as usize].observe(2_000);
        }
        for _ in 0..10 {
            reg.stage_ns[Stage::Decode as usize].observe(1_000_000);
        }
        let metrics = parse(&render_registry(&reg)).unwrap();
        let sum = summarize(&metrics);
        let decode = sum.stages.iter().find(|s| s.stage == "decode").expect("decode row");
        assert_eq!(decode.count, 100);
        assert!(decode.p50_ms <= 0.005, "p50 in the fast buckets, got {}", decode.p50_ms);
        assert!(decode.p95_ms >= 0.5, "p95 must see the slow tail, got {}", decode.p95_ms);
        assert!(decode.p50_ms <= decode.p95_ms && decode.p95_ms <= decode.p99_ms);
        // stages without observations are omitted entirely
        assert!(!sum.stages.iter().any(|s| s.stage == "encode"));
    }

    #[test]
    fn snapshot_lines_cover_every_field_and_parse() {
        let snap = Snapshot {
            completed: 1,
            rejected: 2,
            rejected_busy: 3,
            deadline_exceeded: 4,
            open_conns: 5,
            total_conns: 6,
            errors: 7,
            retries: 8,
            breaker_trips: 9,
            integrity_failures: 10,
            reconnects: 11,
            wall_s: 1.5,
            throughput_ips: 2.5,
            p50_ms: 3.5,
            p95_ms: 4.5,
            p99_ms: 5.5,
            mean_ms: 6.5,
            mean_queue_wait_ms: 7.5,
            p50_queue_wait_ms: 8.5,
            p95_queue_wait_ms: 9.5,
            p99_queue_wait_ms: 10.5,
            mean_sim_mcycles: 11.5,
            verified: 12,
            mean_verify_corr: 0.25,
            min_verify_corr: f64::NAN,
        };
        let metrics = parse(&snapshot_lines(&snap)).unwrap();
        assert_eq!(metrics.len(), 25, "one line per Snapshot field");
        let get = |n: &str| {
            metrics
                .iter()
                .find(|m| m.name == format!("attrax_snapshot_{n}"))
                .unwrap_or_else(|| panic!("missing attrax_snapshot_{n}"))
                .value
        };
        assert_eq!(get("completed"), 1.0);
        assert_eq!(get("reconnects"), 11.0);
        assert_eq!(get("verified"), 12.0);
        assert_eq!(get("mean_verify_corr"), 0.25);
        assert!(get("min_verify_corr").is_nan(), "NaN survives the wire");
    }

    #[test]
    fn endpoint_serves_one_shot_scrapes() {
        let ep = StatsEndpoint::start(
            "127.0.0.1:0",
            Box::new(|| "attrax_completed_total 42\n".to_string()),
        )
        .unwrap();
        let addr = ep.local_addr().to_string();
        for _ in 0..3 {
            let body = scrape(&addr, Duration::from_secs(2)).unwrap();
            let metrics = parse(&body).unwrap();
            assert_eq!(metrics.len(), 1);
            assert_eq!(metrics[0].value, 42.0);
        }
        drop(ep); // joins the accept thread
        assert!(scrape(&addr, Duration::from_millis(200)).is_err(), "endpoint gone after drop");
    }

    #[test]
    fn bucket_quantile_interpolates_within_buckets() {
        // 100 obs: 90 in (0, 1000], 10 in (1000, 2000].
        let b = [(1000.0, 90.0), (2000.0, 100.0), (f64::INFINITY, 100.0)];
        // Old-edge behavior is preserved where the rank exhausts its
        // bucket: rank 90 is the whole first bucket, rank 100 the whole
        // second one — both land exactly on the upper edge.
        assert_eq!(bucket_quantile(&b, 100.0, 0.9), 1000.0);
        assert_eq!(bucket_quantile(&b, 100.0, 1.0), 2000.0);
        // Mid-bucket ranks interpolate linearly instead of overstating
        // to the edge: rank 45 sits 45/90 through [0, 1000], rank 95
        // sits 5/10 through [1000, 2000].
        assert_eq!(bucket_quantile(&b, 100.0, 0.45), 500.0);
        assert_eq!(bucket_quantile(&b, 100.0, 0.95), 1500.0);
        // Empty histogram reports 0 as before.
        assert_eq!(bucket_quantile(&b, 0.0, 0.5), 0.0);
    }

    #[test]
    fn bucket_quantile_single_bucket_and_overflow_keep_exact_edges() {
        // Whole mass in one bucket: nothing to interpolate against, so
        // every quantile reports that bucket's edge (the old value).
        let single = [(1000.0, 0.0), (2000.0, 10.0), (f64::INFINITY, 10.0)];
        for q in [0.01, 0.5, 0.99, 1.0] {
            assert_eq!(bucket_quantile(&single, 10.0, q), 2000.0);
        }
        // Ranks in the +Inf overflow bucket have no finite edge (old
        // behavior); finite ranks below still interpolate normally.
        let over = [(1000.0, 5.0), (f64::INFINITY, 10.0)];
        assert_eq!(bucket_quantile(&over, 10.0, 0.99), f64::INFINITY);
        assert_eq!(bucket_quantile(&over, 10.0, 0.5), 1000.0);
    }

    #[test]
    fn class_rows_roundtrip_through_exposition() {
        let reg = Registry::new();
        reg.install_classes(vec!["gold".into(), "silver".into()]);
        reg.observe_class(0, 2_000, true);
        reg.observe_class(0, 1_000_000, false);
        reg.observe_class(1, 2_000, true);
        let sum = summarize(&parse(&render_registry(&reg)).unwrap());
        assert_eq!(sum.classes.len(), 2, "one row per installed class");
        let gold = &sum.classes[0];
        assert_eq!((gold.class.as_str(), gold.good, gold.bad), ("gold", 1, 1));
        let lat = gold.lat.as_ref().expect("observed class has quantiles");
        assert_eq!(lat.count, 2);
        assert!(lat.p99_ms >= 0.5, "tail sees the slow request: {}", lat.p99_ms);
        let silver = &sum.classes[1];
        assert_eq!((silver.good, silver.bad), (1, 0));
        // rows survive the dashboard and JSON embeddings
        let frame = dashboard(None, &sum, 0.0);
        assert!(frame.contains("gold") && frame.contains("silver"), "{frame}");
        let js = sum.to_json().to_string();
        assert!(js.contains("\"classes\":[{\"bad\":1"), "{js}");
    }

    #[test]
    fn dashboard_renders_rates_and_tables() {
        let reg = Registry::new();
        reg.completed.add(100);
        reg.stage_ns[Stage::Decode as usize].observe(2_000);
        let prev = summarize(&parse(&render_registry(&reg)).unwrap());
        reg.completed.add(50);
        let cur = summarize(&parse(&render_registry(&reg)).unwrap());
        let frame = dashboard(Some(&prev), &cur, 2.0);
        assert!(frame.contains("25.0 req/s"), "50 completions / 2 s:\n{frame}");
        assert!(frame.contains("decode"));
        let cold = dashboard(None, &cur, 0.0);
        assert!(cold.contains("0.0 req/s"));
    }
}
