//! The `attrax-trace/v1` artifact: an append-only stream of records,
//! each framed like the wire protocol (fixed preamble + compact JSON
//! header + raw payload) and CRC-32-protected, so a truncated or
//! bit-flipped trace surfaces as a typed [`TraceError`] instead of a
//! silently wrong replay.
//!
//! ```text
//! offset  size  field
//! 0       4     magic "XTR1" (LE)
//! 4       4     header_len H (LE u32, 1 ..= 64 KiB)
//! 8       4     payload_len P (LE u32, 0 ..= 128 MiB)
//! 12      H     header: {"k":"meta"|"span", "crc":<crc32(payload)>, ...}
//! 12+H    P     payload (span records: encoded request frame bytes
//!               followed by encoded reply frame bytes, split at the
//!               header's "req_len")
//! ```
//!
//! The first record is always `k:"meta"` (capture environment: board,
//! model, weights spec, coordinator knobs) — everything replay needs
//! to rebuild a bit-identical in-process serving stack. Every
//! subsequent record is one completed request span with the exact
//! wire frames that crossed the socket. Writing is streaming (one
//! `BufWriter`, bounded memory); reading is incremental
//! ([`TraceReader::next`]).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::attribution::Method;
use crate::obs::span::{Outcome, Recorder, Span, N_STAGES};
use crate::serve::proto::{self, Frame, RequestFrame};
use crate::util::crc::crc32;
use crate::util::json::{arr, num, obj, s, Json};

pub const TRACE_SCHEMA: &str = "attrax-trace/v1";
/// Record preamble magic: "XTR1", little-endian.
pub const TRACE_MAGIC: u32 = u32::from_le_bytes(*b"XTR1");
pub const TRACE_PREAMBLE_LEN: usize = 12;
pub const MAX_TRACE_HEADER_BYTES: usize = 64 * 1024;
/// A span payload carries two full wire frames, so allow 2× the wire
/// payload cap.
pub const MAX_TRACE_PAYLOAD_BYTES: usize = 128 * 1024 * 1024;

/// Typed trace read failures.
#[derive(Debug)]
pub enum TraceError {
    /// Record preamble or body ended mid-read.
    Truncated,
    BadMagic(u32),
    TooLarge { header_len: usize, payload_len: usize },
    /// Header/payload structurally invalid.
    Malformed(String),
    /// CRC mismatch: the trace bytes were corrupted.
    Integrity { expected: u32, got: u32 },
    Io(std::io::Error),
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Truncated => write!(f, "trace truncated mid-record"),
            TraceError::BadMagic(m) => write!(f, "bad trace record magic {m:#010x}"),
            TraceError::TooLarge { header_len, payload_len } => {
                write!(f, "trace record too large (header {header_len} B, payload {payload_len} B)")
            }
            TraceError::Malformed(m) => write!(f, "malformed trace record: {m}"),
            TraceError::Integrity { expected, got } => {
                write!(f, "trace integrity failure: crc expected {expected:#010x} got {got:#010x}")
            }
            TraceError::Io(e) => write!(f, "trace i/o error: {e}"),
        }
    }
}

impl std::error::Error for TraceError {}

impl From<std::io::Error> for TraceError {
    fn from(e: std::io::Error) -> TraceError {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            TraceError::Truncated
        } else {
            TraceError::Io(e)
        }
    }
}

fn malformed<S: Into<String>>(m: S) -> TraceError {
    TraceError::Malformed(m.into())
}

/// Capture environment, recorded once as the first record. `weights`
/// is `"synthetic:<seed>"` or `"artifacts"`; `config` is `"default"`
/// (board-derived `choose_config`) or `"custom"` (tuned/explicit —
/// in-process replay refuses it, live replay still works).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMeta {
    pub board: String,
    pub model: String,
    pub weights: String,
    pub config: String,
    pub elems: usize,
    pub out_n: usize,
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait_ms: u64,
}

impl TraceMeta {
    fn to_json(&self) -> Json {
        obj(vec![
            ("k", s("meta")),
            ("schema", s(TRACE_SCHEMA)),
            ("board", s(&self.board)),
            ("model", s(&self.model)),
            ("weights", s(&self.weights)),
            ("config", s(&self.config)),
            ("elems", num(self.elems as f64)),
            ("out_n", num(self.out_n as f64)),
            ("workers", num(self.workers as f64)),
            ("max_batch", num(self.max_batch as f64)),
            ("max_wait_ms", num(self.max_wait_ms as f64)),
            ("crc", num(0.0)), // meta payload is empty; crc32("") == 0
        ])
    }

    fn from_json(j: &Json) -> Result<TraceMeta, TraceError> {
        let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != TRACE_SCHEMA {
            return Err(malformed(format!("unsupported trace schema {schema:?}")));
        }
        let text = |k: &str| -> Result<String, TraceError> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| malformed(format!("meta missing {k:?}")))
        };
        Ok(TraceMeta {
            board: text("board")?,
            model: text("model")?,
            weights: text("weights")?,
            config: text("config")?,
            elems: get_u64(j, "elems")? as usize,
            out_n: get_u64(j, "out_n")? as usize,
            workers: get_u64(j, "workers")? as usize,
            max_batch: get_u64(j, "max_batch")? as usize,
            max_wait_ms: get_u64(j, "max_wait_ms")?,
        })
    }
}

fn get_u64(j: &Json, key: &str) -> Result<u64, TraceError> {
    j.get(key)
        .and_then(Json::as_f64)
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as u64)
        .ok_or_else(|| malformed(format!("missing/invalid field {key:?}")))
}

/// One replayable exchange: the span plus the exact frames that
/// crossed the wire.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    pub span: Span,
    pub req: RequestFrame,
    pub reply: Frame,
}

fn span_header(span: &Span, req_len: usize, payload_crc: u32) -> Json {
    let stages = span.stages.iter().map(|&t| num(t as f64)).collect::<Vec<_>>();
    let mut pairs = vec![
        ("k", s("span")),
        ("crc", num(payload_crc as f64)),
        ("req_len", num(req_len as f64)),
        ("frame_id", num(span.frame_id as f64)),
        ("conn_id", num(span.conn_id as f64)),
        ("n", num(span.n as f64)),
        ("method", s(span.method.name())),
        ("stages", arr(stages)),
        ("batch_id", num(span.batch_id as f64)),
        ("batch_size", num(span.batch_size as f64)),
        ("device", num(span.device_index as f64)),
        ("attempts", num(span.attempts as f64)),
        ("breaker", Json::Bool(span.breaker_tripped)),
        ("cycles", num(span.device_cycles as f64)),
        ("deadline_ms", num(span.deadline_ms as f64)),
        ("outcome", s(span.outcome.name())),
    ];
    if let Some(ts) = span.trace_seq {
        pairs.push(("trace_seq", num(ts as f64)));
    }
    obj(pairs)
}

fn span_from_header(j: &Json) -> Result<Span, TraceError> {
    let method_name =
        j.get("method").and_then(Json::as_str).ok_or_else(|| malformed("span missing method"))?;
    let method =
        Method::parse(method_name).ok_or_else(|| malformed(format!("bad method {method_name:?}")))?;
    let outcome_name =
        j.get("outcome").and_then(Json::as_str).ok_or_else(|| malformed("span missing outcome"))?;
    let outcome = Outcome::parse(outcome_name)
        .ok_or_else(|| malformed(format!("bad outcome {outcome_name:?}")))?;
    let stages_j =
        j.get("stages").and_then(Json::as_arr).ok_or_else(|| malformed("span missing stages"))?;
    if stages_j.len() != N_STAGES {
        return Err(malformed(format!("span has {} stages, expected {N_STAGES}", stages_j.len())));
    }
    let mut stages = [0u64; N_STAGES];
    for (i, v) in stages_j.iter().enumerate() {
        stages[i] = v
            .as_f64()
            .filter(|t| *t >= 0.0 && t.fract() == 0.0)
            .ok_or_else(|| malformed("bad stage timestamp"))? as u64;
    }
    let trace_seq = match j.get("trace_seq") {
        None | Some(Json::Null) => None,
        Some(_) => Some(get_u64(j, "trace_seq")?),
    };
    Ok(Span {
        frame_id: get_u64(j, "frame_id")?,
        conn_id: get_u64(j, "conn_id")?,
        n: get_u64(j, "n")? as u32,
        method,
        stages,
        batch_id: get_u64(j, "batch_id")?,
        batch_size: get_u64(j, "batch_size")? as u32,
        device_index: get_u64(j, "device")? as u32,
        attempts: get_u64(j, "attempts")? as u32,
        breaker_tripped: j.get("breaker").and_then(Json::as_bool).unwrap_or(false),
        device_cycles: get_u64(j, "cycles")?,
        deadline_ms: get_u64(j, "deadline_ms")?,
        trace_seq,
        outcome,
    })
}

fn write_record<W: Write>(w: &mut W, header: &Json, payload: &[u8]) -> std::io::Result<u64> {
    let htext = header.to_string();
    w.write_all(&TRACE_MAGIC.to_le_bytes())?;
    w.write_all(&(htext.len() as u32).to_le_bytes())?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(htext.as_bytes())?;
    w.write_all(payload)?;
    Ok((TRACE_PREAMBLE_LEN + htext.len() + payload.len()) as u64)
}

/// Path of segment `i` of a rotating capture: segment 0 is the base
/// path itself, segment `i > 0` inserts the index before the
/// extension (`foo.trace` → `foo.1.trace`; extensionless `foo` →
/// `foo.1`). Replay/doctor take the explicit segment list — nothing
/// is inferred from what happens to sit next to a file on disk.
pub fn segment_path<P: AsRef<Path>>(base: P, i: u32) -> std::path::PathBuf {
    let base = base.as_ref();
    if i == 0 {
        return base.to_path_buf();
    }
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    let name = match base.extension().and_then(|e| e.to_str()) {
        Some(ext) => format!("{stem}.{i}.{ext}"),
        None => format!("{stem}.{i}"),
    };
    base.with_file_name(name)
}

struct WriterState {
    w: BufWriter<File>,
    /// Bytes written to the current segment.
    bytes: u64,
    /// Span records in the current segment (rotation requires ≥ 1 so
    /// no segment is ever meta-only, however small the cap).
    seg_records: u64,
    /// Index of the current segment (0 = the base path).
    segment: u32,
}

/// Streaming trace writer; implements [`Recorder`] so it plugs into
/// `ServerConfig::recorder` directly. Thread-safe (connection threads
/// record concurrently); a failed write poisons nothing — the error
/// is remembered and surfaced by [`TraceWriter::finish`].
///
/// With a segment-size cap ([`TraceWriter::create_rotating`]) the
/// writer rolls to the next [`segment_path`] before any record that
/// would start past the cap, repeating the meta record first so every
/// segment is a self-contained, independently-replayable
/// `attrax-trace/v1` stream. Rotation is lazy — a segment is only
/// opened when a record needs it, so a capture never ends with an
/// empty trailing segment.
pub struct TraceWriter {
    inner: Mutex<WriterState>,
    meta: TraceMeta,
    base: std::path::PathBuf,
    max_segment_bytes: u64,
    io_errors: AtomicU64,
    records: AtomicU64,
}

impl TraceWriter {
    /// Create `path` and write the meta record (no rotation).
    pub fn create<P: AsRef<Path>>(path: P, meta: &TraceMeta) -> std::io::Result<TraceWriter> {
        TraceWriter::create_rotating(path, meta, u64::MAX)
    }

    /// Create a rotating capture: a new segment starts whenever the
    /// current one holds at least `max_segment_bytes` bytes.
    pub fn create_rotating<P: AsRef<Path>>(
        path: P,
        meta: &TraceMeta,
        max_segment_bytes: u64,
    ) -> std::io::Result<TraceWriter> {
        let base = path.as_ref().to_path_buf();
        let mut w = BufWriter::new(File::create(&base)?);
        let bytes = write_record(&mut w, &meta.to_json(), &[])?;
        Ok(TraceWriter {
            inner: Mutex::new(WriterState { w, bytes, seg_records: 0, segment: 0 }),
            meta: meta.clone(),
            base,
            max_segment_bytes: max_segment_bytes.max(1),
            io_errors: AtomicU64::new(0),
            records: AtomicU64::new(0),
        })
    }

    pub fn records(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }

    /// Number of segments written so far (≥ 1).
    pub fn segments(&self) -> u32 {
        let g = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        g.segment + 1
    }

    /// The paths of every segment written so far, in order.
    pub fn segment_paths(&self) -> Vec<std::path::PathBuf> {
        (0..self.segments()).map(|i| segment_path(&self.base, i)).collect()
    }

    /// Roll to the next segment if the current one is at the cap (and
    /// holds at least one span — a segment is never meta-only).
    fn maybe_rotate(&self, state: &mut WriterState) -> std::io::Result<()> {
        if state.seg_records == 0 || state.bytes < self.max_segment_bytes {
            return Ok(());
        }
        state.w.flush()?;
        let next = state.segment + 1;
        let mut w = BufWriter::new(File::create(segment_path(&self.base, next))?);
        let bytes = write_record(&mut w, &self.meta.to_json(), &[])?;
        state.w = w;
        state.bytes = bytes;
        state.seg_records = 0;
        state.segment = next;
        Ok(())
    }

    /// Flush and report: `Ok(records_written)` or the first I/O
    /// failure class (count of failed writes).
    pub fn finish(&self) -> Result<u64, u64> {
        self.flush();
        match self.io_errors.load(Ordering::Relaxed) {
            0 => Ok(self.records()),
            n => Err(n),
        }
    }
}

impl Recorder for TraceWriter {
    fn record(&self, span: &Span, req: &RequestFrame, reply: &Frame) {
        // Re-encode both frames; the encoder is canonical, so these
        // are the bytes that crossed the wire.
        let req_bytes = match proto::encode(&Frame::Request(req.clone())) {
            Ok(b) => b,
            Err(_) => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        let reply_bytes = match proto::encode(reply) {
            Ok(b) => b,
            Err(_) => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        let mut payload = req_bytes;
        let req_len = payload.len();
        payload.extend_from_slice(&reply_bytes);
        let header = span_header(span, req_len, crc32(&payload));
        let mut g = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if self.maybe_rotate(&mut g).is_err() {
            self.io_errors.fetch_add(1, Ordering::Relaxed);
            return;
        }
        match write_record(&mut g.w, &header, &payload) {
            Ok(n) => {
                g.bytes += n;
                g.seg_records += 1;
                self.records.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                self.io_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn flush(&self) {
        let mut g = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if g.w.flush().is_err() {
            self.io_errors.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl std::fmt::Debug for TraceWriter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceWriter {{ records: {} }}", self.records())
    }
}

/// Incremental trace reader. The constructor consumes and validates
/// the meta record; [`TraceReader::next`] yields span records until
/// clean EOF.
pub struct TraceReader {
    r: BufReader<File>,
    pub meta: TraceMeta,
}

impl TraceReader {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<TraceReader, TraceError> {
        let mut r = BufReader::new(File::open(path).map_err(TraceError::Io)?);
        let (header, payload) = match read_raw_record(&mut r)? {
            Some(rec) => rec,
            None => return Err(TraceError::Truncated),
        };
        if header.get("k").and_then(Json::as_str) != Some("meta") {
            return Err(malformed("first trace record is not meta"));
        }
        if !payload.is_empty() {
            return Err(malformed("meta record carries a payload"));
        }
        let meta = TraceMeta::from_json(&header)?;
        Ok(TraceReader { r, meta })
    }

    /// Next span record; `Ok(None)` on clean EOF.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Result<Option<TraceRecord>, TraceError> {
        let (header, payload) = match read_raw_record(&mut self.r)? {
            Some(rec) => rec,
            None => return Ok(None),
        };
        if header.get("k").and_then(Json::as_str) != Some("span") {
            return Err(malformed("expected a span record"));
        }
        let span = span_from_header(&header)?;
        let req_len = get_u64(&header, "req_len")? as usize;
        if req_len > payload.len() {
            return Err(malformed("req_len exceeds payload"));
        }
        let req = match decode_one_frame(&payload[..req_len])? {
            Frame::Request(q) => q,
            other => return Err(malformed(format!("payload request is {}", frame_kind(&other)))),
        };
        let reply = decode_one_frame(&payload[req_len..])?;
        Ok(Some(TraceRecord { span, req, reply }))
    }

    /// Drain the remaining records into a vec (plus the already-read
    /// meta). Convenience for replay/doctor.
    pub fn read_all(mut self) -> Result<(TraceMeta, Vec<TraceRecord>), TraceError> {
        let mut out = Vec::new();
        while let Some(rec) = self.next()? {
            out.push(rec);
        }
        Ok((self.meta, out))
    }
}

/// Read a multi-segment capture in order: every segment must be a
/// self-contained trace whose meta record equals the first segment's
/// (a rotated capture repeats it verbatim); the records concatenate.
pub fn read_all_segments<P: AsRef<Path>>(
    paths: &[P],
) -> Result<(TraceMeta, Vec<TraceRecord>), TraceError> {
    let mut iter = paths.iter();
    let first = iter.next().ok_or_else(|| malformed("no trace segments given"))?;
    let (meta, mut records) = TraceReader::open(first)?.read_all()?;
    for p in iter {
        let (m, recs) = TraceReader::open(p)?.read_all()?;
        if m != meta {
            return Err(malformed(format!(
                "segment {} has a different meta record (not the same capture)",
                p.as_ref().display()
            )));
        }
        records.extend(recs);
    }
    Ok((meta, records))
}

fn frame_kind(f: &Frame) -> &'static str {
    match f {
        Frame::Request(_) => "a request",
        Frame::Response(_) => "a response",
        Frame::Error(_) => "an error",
    }
}

fn decode_one_frame(bytes: &[u8]) -> Result<Frame, TraceError> {
    let mut cur = std::io::Cursor::new(bytes);
    let f = proto::read_frame(&mut cur)
        .map_err(|e| malformed(format!("embedded wire frame: {e}")))?
        .ok_or_else(|| malformed("empty embedded wire frame"))?;
    if (cur.position() as usize) != bytes.len() {
        return Err(malformed("trailing bytes after embedded wire frame"));
    }
    Ok(f)
}

/// Read one record's (header, payload); `Ok(None)` on clean EOF at a
/// record boundary. Verifies the header's `crc` against the payload.
fn read_raw_record<R: Read>(r: &mut R) -> Result<Option<(Json, Vec<u8>)>, TraceError> {
    let mut pre = [0u8; TRACE_PREAMBLE_LEN];
    let mut have = 0usize;
    while have < TRACE_PREAMBLE_LEN {
        match r.read(&mut pre[have..]) {
            Ok(0) if have == 0 => return Ok(None),
            Ok(0) => return Err(TraceError::Truncated),
            Ok(k) => have += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let magic = u32::from_le_bytes(pre[0..4].try_into().unwrap());
    if magic != TRACE_MAGIC {
        return Err(TraceError::BadMagic(magic));
    }
    let header_len = u32::from_le_bytes(pre[4..8].try_into().unwrap()) as usize;
    let payload_len = u32::from_le_bytes(pre[8..12].try_into().unwrap()) as usize;
    if header_len == 0 || header_len > MAX_TRACE_HEADER_BYTES || payload_len > MAX_TRACE_PAYLOAD_BYTES
    {
        return Err(TraceError::TooLarge { header_len, payload_len });
    }
    let mut header = vec![0u8; header_len];
    r.read_exact(&mut header)?;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload)?;
    let text = std::str::from_utf8(&header).map_err(|_| malformed("header is not utf-8"))?;
    let j = Json::parse(text).map_err(|e| malformed(format!("header json: {e}")))?;
    let expected = get_u64(&j, "crc")? as u32;
    let got = crc32(&payload);
    if expected != got {
        return Err(TraceError::Integrity { expected, got });
    }
    Ok(Some((j, payload)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::Stage;
    use crate::serve::proto::{ErrCode, ErrorFrame, ResponseFrame};

    fn meta() -> TraceMeta {
        TraceMeta {
            board: "pynq-z2".into(),
            model: "table3".into(),
            weights: "synthetic:42".into(),
            config: "default".into(),
            elems: 4,
            out_n: 2,
            workers: 2,
            max_batch: 4,
            max_wait_ms: 1,
        }
    }

    fn sample(seq: u64) -> (Span, RequestFrame, Frame) {
        let req = RequestFrame {
            id: seq,
            method: Method::Guided,
            target: None,
            n: 1,
            elems: 4,
            deadline_ms: Some(100),
            with_crc: false,
            trace_seq: None,
            slo_class: None,
            images: vec![0.5, -1.25, 2.0, 0.0],
        };
        let reply = Frame::Response(ResponseFrame {
            id: seq,
            n: 1,
            elems: 4,
            out_n: 2,
            preds: vec![1],
            device_cycles: vec![1234],
            with_crc: false,
            logits: vec![0.1, 0.9],
            relevance: vec![1.0, 2.0, 3.0, 4.0],
        });
        let mut span = Span::start(seq, 7, 1, Method::Guided);
        span.stamp(Stage::Decode, 1000 + seq);
        span.stamp(Stage::Flush, 2000 + seq);
        span.batch_id = 3;
        span.batch_size = 2;
        span.device_index = 0;
        span.attempts = 1;
        span.device_cycles = 1234;
        span.deadline_ms = 100;
        (span, req, reply)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("attrax_trace_{}_{name}.trace", std::process::id()))
    }

    #[test]
    fn roundtrip_spans_and_frames() {
        let path = tmp("roundtrip");
        let w = TraceWriter::create(&path, &meta()).unwrap();
        let mut originals = Vec::new();
        for seq in 0..3u64 {
            let (mut span, mut req, reply) = sample(seq);
            if seq == 1 {
                // classed requests persist their tag (doctor reads it
                // back for the per-class burn audit)
                req.slo_class = Some("gold".to_string());
            }
            if seq == 2 {
                span.trace_seq = Some(99);
                span.outcome = Outcome::Err(ErrCode::Busy);
            }
            w.record(&span, &req, &reply);
            originals.push((span, req, reply));
        }
        assert_eq!(w.finish(), Ok(3));

        let (m, recs) = TraceReader::open(&path).unwrap().read_all().unwrap();
        assert_eq!(m, meta());
        assert_eq!(recs.len(), 3);
        for (rec, (span, req, reply)) in recs.iter().zip(&originals) {
            assert_eq!(rec.span.frame_id, span.frame_id);
            assert_eq!(rec.span.stages, span.stages);
            assert_eq!(rec.span.outcome, span.outcome);
            assert_eq!(rec.span.trace_seq, span.trace_seq);
            assert_eq!(rec.span.batch_size, span.batch_size);
            assert_eq!(&rec.req, req);
            assert_eq!(&rec.reply, reply);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn error_replies_roundtrip() {
        let path = tmp("err");
        let w = TraceWriter::create(&path, &meta()).unwrap();
        let (mut span, req, _) = sample(0);
        span.outcome = Outcome::Err(ErrCode::DeadlineExceeded);
        let reply = Frame::Error(ErrorFrame {
            id: 0,
            code: ErrCode::DeadlineExceeded,
            msg: "budget elapsed".into(),
        });
        w.record(&span, &req, &reply);
        assert_eq!(w.finish(), Ok(1));
        let (_, recs) = TraceReader::open(&path).unwrap().read_all().unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].reply, reply);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_is_typed() {
        let path = tmp("corrupt");
        let w = TraceWriter::create(&path, &meta()).unwrap();
        let (span, req, reply) = sample(0);
        w.record(&span, &req, &reply);
        w.finish().unwrap();
        let clean = std::fs::read(&path).unwrap();

        // flip one payload byte near the end: CRC must catch it
        let mut corrupt = clean.clone();
        let last = corrupt.len() - 5;
        corrupt[last] ^= 0x40;
        std::fs::write(&path, &corrupt).unwrap();
        let mut rd = TraceReader::open(&path).unwrap();
        assert!(matches!(rd.next(), Err(TraceError::Integrity { .. })));

        // truncate mid-record
        std::fs::write(&path, &clean[..clean.len() - 3]).unwrap();
        let mut rd = TraceReader::open(&path).unwrap();
        assert!(matches!(rd.next(), Err(TraceError::Truncated)));

        // stomp a record magic
        let mut bad = clean.clone();
        bad[0] = b'Q';
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(TraceReader::open(&path), Err(TraceError::BadMagic(_))));

        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn segment_paths_insert_index_before_extension() {
        let p = |s: &str, i| segment_path(s, i).to_string_lossy().into_owned();
        assert_eq!(p("cap.trace", 0), "cap.trace");
        assert_eq!(p("cap.trace", 1), "cap.1.trace");
        assert_eq!(p("cap.trace", 12), "cap.12.trace");
        assert_eq!(p("dir/cap.trace", 2), "dir/cap.2.trace");
        assert_eq!(p("noext", 1), "noext.1");
    }

    #[test]
    fn rotation_yields_self_contained_segments_that_concatenate() {
        let base = tmp("rotate");
        // tiny cap: every span record starts a fresh segment after the
        // first (meta alone already exceeds 64 bytes)
        let w = TraceWriter::create_rotating(&base, &meta(), 64).unwrap();
        let mut originals = Vec::new();
        for seq in 0..5u64 {
            let (span, req, reply) = sample(seq);
            w.record(&span, &req, &reply);
            originals.push(req);
        }
        assert_eq!(w.finish(), Ok(5));
        assert_eq!(w.segments(), 5, "lazy rotation: first record stays in segment 0");
        let paths = w.segment_paths();
        assert_eq!(paths[0], base);
        assert_eq!(paths[1], segment_path(&base, 1));

        // each segment is independently a valid capture with the meta
        for (i, p) in paths.iter().enumerate() {
            let (m, recs) = TraceReader::open(p).unwrap().read_all().unwrap();
            assert_eq!(m, meta(), "segment {i} repeats the meta record");
            assert_eq!(recs.len(), 1);
        }
        // and the segment list concatenates in order
        let (m, recs) = read_all_segments(&paths).unwrap();
        assert_eq!(m, meta());
        assert_eq!(recs.len(), 5);
        for (rec, req) in recs.iter().zip(&originals) {
            assert_eq!(&rec.req, req);
        }
        for p in &paths {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn uncapped_writer_never_rotates() {
        let base = tmp("norotate");
        let w = TraceWriter::create(&base, &meta()).unwrap();
        for seq in 0..10u64 {
            let (span, req, reply) = sample(seq);
            w.record(&span, &req, &reply);
        }
        assert_eq!(w.finish(), Ok(10));
        assert_eq!(w.segments(), 1);
        let (_, recs) = read_all_segments(&[&base]).unwrap();
        assert_eq!(recs.len(), 10);
        std::fs::remove_file(&base).ok();
    }

    #[test]
    fn mismatched_segment_meta_is_rejected() {
        let a = tmp("seg_a");
        let b = tmp("seg_b");
        let w = TraceWriter::create(&a, &meta()).unwrap();
        let (span, req, reply) = sample(0);
        w.record(&span, &req, &reply);
        w.finish().unwrap();
        let mut other = meta();
        other.board = "zcu104".into();
        let w = TraceWriter::create(&b, &other).unwrap();
        w.record(&span, &req, &reply);
        w.finish().unwrap();
        assert!(matches!(read_all_segments(&[&a, &b]), Err(TraceError::Malformed(_))));
        assert!(matches!(
            read_all_segments(&Vec::<std::path::PathBuf>::new()),
            Err(TraceError::Malformed(_))
        ));
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn missing_meta_is_rejected() {
        let path = tmp("nometa");
        std::fs::write(&path, b"").unwrap();
        assert!(matches!(TraceReader::open(&path), Err(TraceError::Truncated)));
        std::fs::remove_file(&path).ok();
    }
}
