//! SLO objectives and burn-rate evaluation.
//!
//! An SLO spec is a small schema-tagged artifact (`attrax-slo/v1`,
//! conventionally `*.slo.json`) naming request classes — e.g. `gold` /
//! `silver` / `bronze` — each with a latency threshold, a target
//! success fraction, and an absolute error budget:
//!
//! ```json
//! {"schema":"attrax-slo/v1","classes":[
//!   {"name":"gold","latency_ms":50.0,"target":0.999,"budget":100},
//!   {"name":"bronze","latency_ms":500.0,"target":0.9,"budget":10000}]}
//! ```
//!
//! It is loaded and validated like the tuned-config artifact
//! ([`crate::dse::tune`]): schema checked first, every class checked
//! field by field, any violation a typed `anyhow` error naming the
//! offending class. The server loads it via `serve --slo`, resolves
//! each request's optional `slo_class` wire field to a fixed class
//! index at admission, and publishes completions into the registry's
//! preallocated per-class slots
//! ([`crate::obs::telemetry::Registry::observe_class`]).
//!
//! **Evaluation is pure counter arithmetic.** [`evaluate`] maps
//! (spec, previous scrape, current scrape) to per-class compliance,
//! remaining error budget, and burn rates over two windows — the delta
//! window between the scrapes and the process lifetime — using only
//! the monotone `attrax_class_good_total` / `attrax_class_bad_total`
//! counters. No wall clock is read, so identical inputs give
//! byte-identical verdicts (the property `attrax monitor --smoke`
//! reruns are gated on in `scripts/ci.sh`).
//!
//! A *good* request completed successfully within its class's latency
//! threshold; every other completion of a classed request is *bad*.
//! The burn rate is the classic SRE ratio: observed bad fraction over
//! allowed bad fraction (`1 - target`) — burn 1.0 spends the budget
//! exactly at the target rate, above 1.0 the class is out of
//! compliance.

use std::path::Path;

use crate::obs::export::StatsSummary;
use crate::serve::proto::MAX_SLO_CLASS_BYTES;
use crate::util::json::{arr, num, obj, s, Json};

/// Schema tag of the spec artifact.
pub const SLO_SCHEMA: &str = "attrax-slo/v1";
/// Schema tag of the evaluation report (`BENCH_slo.json`).
pub const SLO_REPORT_SCHEMA: &str = "attrax-slo-report/v1";
/// Preallocated per-class registry slots; a spec may not exceed it.
pub use crate::obs::telemetry::MAX_SLO_CLASSES;

/// One named request class.
#[derive(Clone, Debug, PartialEq)]
pub struct SloClass {
    pub name: String,
    /// A completion within this many milliseconds is *good*.
    pub latency_ms: f64,
    /// Required good fraction, exclusive on both ends (0, 1).
    pub target: f64,
    /// Absolute error budget: cumulative bad completions above this
    /// count the budget *exhausted*.
    pub budget: u64,
}

impl SloClass {
    /// The latency threshold in integer nanoseconds (span clock units).
    pub fn latency_ns(&self) -> u64 {
        (self.latency_ms * 1e6).round() as u64
    }
}

/// A validated SLO spec: an ordered set of classes. The order is the
/// class-index order the registry slots use.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SloSpec {
    pub classes: Vec<SloClass>,
}

impl SloSpec {
    /// Parse + validate a spec artifact (see module docs for the shape).
    pub fn parse(text: &str) -> anyhow::Result<SloSpec> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("slo artifact: {e}"))?;
        let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
        anyhow::ensure!(
            schema == SLO_SCHEMA,
            "slo artifact schema {schema:?} (expected {SLO_SCHEMA:?})"
        );
        let classes_json = j
            .get("classes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("slo artifact: missing \"classes\" array"))?;
        anyhow::ensure!(!classes_json.is_empty(), "slo artifact: no classes");
        anyhow::ensure!(
            classes_json.len() <= MAX_SLO_CLASSES,
            "slo artifact: {} classes exceed the {MAX_SLO_CLASSES} registry slots",
            classes_json.len()
        );
        let mut classes = Vec::with_capacity(classes_json.len());
        for (i, c) in classes_json.iter().enumerate() {
            let name = c
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("slo class #{i}: missing \"name\""))?
                .to_string();
            anyhow::ensure!(
                !name.is_empty() && name.len() <= MAX_SLO_CLASS_BYTES,
                "slo class #{i}: name must be 1 ..= {MAX_SLO_CLASS_BYTES} bytes"
            );
            let latency_ms = c
                .get("latency_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("slo class {name:?}: missing \"latency_ms\""))?;
            anyhow::ensure!(
                latency_ms.is_finite() && latency_ms > 0.0,
                "slo class {name:?}: latency_ms must be a positive finite number"
            );
            let target = c
                .get("target")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("slo class {name:?}: missing \"target\""))?;
            anyhow::ensure!(
                target > 0.0 && target < 1.0,
                "slo class {name:?}: target must be strictly between 0 and 1"
            );
            let budget = c
                .get("budget")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("slo class {name:?}: missing \"budget\""))?;
            anyhow::ensure!(
                budget >= 0.0 && budget.fract() == 0.0,
                "slo class {name:?}: budget must be a non-negative integer"
            );
            if classes.iter().any(|prev: &SloClass| prev.name == name) {
                anyhow::bail!("slo artifact: duplicate class name {name:?}");
            }
            classes.push(SloClass { name, latency_ms, target, budget: budget as u64 });
        }
        Ok(SloSpec { classes })
    }

    /// Load + validate a `*.slo.json` file.
    pub fn load(path: &Path) -> anyhow::Result<SloSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        SloSpec::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// The fixed class index a wire `slo_class` name resolves to.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.classes.iter().position(|c| c.name == name)
    }

    /// Class names in slot order (what the registry installs).
    pub fn names(&self) -> Vec<String> {
        self.classes.iter().map(|c| c.name.clone()).collect()
    }

    /// A permissive spec synthesized from bare class names (used by
    /// `loadgen --smoke --class-mix`, which needs the loopback server
    /// to admit the mix's classes without a spec file on disk):
    /// generous thresholds, lax targets, effectively infinite budget.
    pub fn synthetic(names: &[String]) -> SloSpec {
        SloSpec {
            classes: names
                .iter()
                .map(|n| SloClass {
                    name: n.clone(),
                    latency_ms: 600_000.0,
                    target: 0.5,
                    budget: u64::MAX / 2,
                })
                .collect(),
        }
    }

    /// The spec as artifact JSON (inverse of [`SloSpec::parse`]).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema", s(SLO_SCHEMA)),
            (
                "classes",
                arr(self
                    .classes
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("name", s(&c.name)),
                            ("latency_ms", num(c.latency_ms)),
                            ("target", num(c.target)),
                            ("budget", num(c.budget as f64)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Per-class verdict from [`evaluate`]. All counts are exact counter
/// values; all ratios are derived from them and nothing else.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassVerdict {
    pub name: String,
    /// Cumulative good/bad completions at the current scrape.
    pub good: u64,
    pub bad: u64,
    /// Completions inside the delta window (current - previous).
    pub delta_good: u64,
    pub delta_bad: u64,
    /// Good fraction over the delta window (1.0 with no traffic —
    /// an idle class is vacuously compliant).
    pub compliance: f64,
    /// `compliance >= target`.
    pub compliant: bool,
    /// Burn rate over the delta window: bad fraction / (1 - target).
    pub burn_window: f64,
    /// Burn rate over the process lifetime (cumulative counters).
    pub burn_total: f64,
    pub budget: u64,
    /// `budget - bad`, saturating at zero.
    pub budget_remaining: u64,
    /// Cumulative bad completions exceed the budget.
    pub exhausted: bool,
}

/// Evaluation of one scrape pair against a spec.
#[derive(Clone, Debug, PartialEq)]
pub struct SloReport {
    pub classes: Vec<ClassVerdict>,
}

impl SloReport {
    /// Any class has spent its whole error budget (the `attrax
    /// monitor` nonzero-exit condition).
    pub fn exhausted(&self) -> bool {
        self.classes.iter().any(|c| c.exhausted)
    }

    /// Every class is compliant and inside its budget.
    pub fn healthy(&self) -> bool {
        self.classes.iter().all(|c| c.compliant && !c.exhausted)
    }

    /// The burn table rendered for the `attrax monitor` dashboard.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "  {:<10} {:>8} {:>8} {:>9} {:>8} {:>8} {:>10}  state\n",
            "class", "good", "bad", "complnce", "burn(w)", "burn(t)", "budget"
        ));
        for c in &self.classes {
            let state = if c.exhausted {
                "EXHAUSTED"
            } else if !c.compliant {
                "burning"
            } else {
                "ok"
            };
            out.push_str(&format!(
                "  {:<10} {:>8} {:>8} {:>8.4}% {:>8.2} {:>8.2} {:>10}  {state}\n",
                c.name,
                c.good,
                c.bad,
                c.compliance * 100.0,
                c.burn_window,
                c.burn_total,
                c.budget_remaining,
            ));
        }
        out
    }

    /// Deterministic report JSON: counts, ratios and verdicts only —
    /// no wall clock, no latencies — so identical counter inputs
    /// serialize byte-identically.
    pub fn to_json(&self) -> Json {
        arr(self
            .classes
            .iter()
            .map(|c| {
                obj(vec![
                    ("name", s(&c.name)),
                    ("good", num(c.good as f64)),
                    ("bad", num(c.bad as f64)),
                    ("delta_good", num(c.delta_good as f64)),
                    ("delta_bad", num(c.delta_bad as f64)),
                    ("compliance", num(c.compliance)),
                    ("compliant", Json::Bool(c.compliant)),
                    ("burn_window", num(c.burn_window)),
                    ("burn_total", num(c.burn_total)),
                    ("budget", num(c.budget as f64)),
                    ("budget_remaining", num(c.budget_remaining as f64)),
                    ("exhausted", Json::Bool(c.exhausted)),
                ])
            })
            .collect())
    }
}

/// Good fraction of a (good, bad) pair; idle windows are vacuously
/// fully compliant.
fn fraction_good(good: u64, bad: u64) -> f64 {
    let total = good + bad;
    if total == 0 {
        1.0
    } else {
        good as f64 / total as f64
    }
}

/// Burn rate: observed bad fraction over the allowed bad fraction.
/// `target` is validated into (0, 1), so the allowance is positive.
fn burn(good: u64, bad: u64, target: f64) -> f64 {
    (1.0 - fraction_good(good, bad)) / (1.0 - target)
}

/// Pure SLO evaluation of a scrape pair. `prev` is `None` on the first
/// observation (the delta window is then the whole lifetime). Counter
/// deltas only — no wall clock — so identical inputs give
/// byte-identical verdicts.
pub fn evaluate(spec: &SloSpec, prev: Option<&StatsSummary>, cur: &StatsSummary) -> SloReport {
    let lookup = |summary: &StatsSummary, name: &str| -> (u64, u64) {
        summary
            .classes
            .iter()
            .find(|r| r.class == name)
            .map(|r| (r.good, r.bad))
            .unwrap_or((0, 0))
    };
    let classes = spec
        .classes
        .iter()
        .map(|c| {
            let (good, bad) = lookup(cur, &c.name);
            let (pg, pb) = prev.map(|p| lookup(p, &c.name)).unwrap_or((0, 0));
            // a restarted server resets its counters; clamp instead of
            // underflowing so a stale prev scrape cannot panic
            let delta_good = good.saturating_sub(pg);
            let delta_bad = bad.saturating_sub(pb);
            let compliance = fraction_good(delta_good, delta_bad);
            ClassVerdict {
                name: c.name.clone(),
                good,
                bad,
                delta_good,
                delta_bad,
                compliance,
                compliant: compliance >= c.target,
                burn_window: burn(delta_good, delta_bad, c.target),
                burn_total: burn(good, bad, c.target),
                budget: c.budget,
                budget_remaining: c.budget.saturating_sub(bad),
                exhausted: bad > c.budget,
            }
        })
        .collect();
    SloReport { classes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::ClassRow;

    fn spec() -> SloSpec {
        SloSpec {
            classes: vec![
                SloClass { name: "gold".into(), latency_ms: 50.0, target: 0.99, budget: 10 },
                SloClass { name: "bronze".into(), latency_ms: 500.0, target: 0.9, budget: 100 },
            ],
        }
    }

    fn summary(rows: &[(&str, u64, u64)]) -> StatsSummary {
        StatsSummary {
            classes: rows
                .iter()
                .map(|&(class, good, bad)| ClassRow {
                    class: class.into(),
                    good,
                    bad,
                    lat: None,
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn spec_roundtrips_through_artifact_json() {
        let sp = spec();
        let text = sp.to_json().to_string();
        assert_eq!(SloSpec::parse(&text).unwrap(), sp);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        let cases = [
            (r#"{"classes":[]}"#, "schema"),
            (r#"{"schema":"attrax-slo/v0","classes":[]}"#, "schema"),
            (r#"{"schema":"attrax-slo/v1"}"#, "classes"),
            (r#"{"schema":"attrax-slo/v1","classes":[]}"#, "no classes"),
            (
                r#"{"schema":"attrax-slo/v1","classes":[{"latency_ms":1,"target":0.5,"budget":0}]}"#,
                "name",
            ),
            (
                r#"{"schema":"attrax-slo/v1","classes":[{"name":"g","latency_ms":0,"target":0.5,"budget":0}]}"#,
                "latency_ms",
            ),
            (
                r#"{"schema":"attrax-slo/v1","classes":[{"name":"g","latency_ms":1,"target":1,"budget":0}]}"#,
                "target",
            ),
            (
                r#"{"schema":"attrax-slo/v1","classes":[{"name":"g","latency_ms":1,"target":0.5,"budget":1.5}]}"#,
                "budget",
            ),
            (
                r#"{"schema":"attrax-slo/v1","classes":[{"name":"g","latency_ms":1,"target":0.5,"budget":0},{"name":"g","latency_ms":1,"target":0.5,"budget":0}]}"#,
                "duplicate",
            ),
        ];
        for (text, needle) in cases {
            let err = SloSpec::parse(text).expect_err(text).to_string();
            assert!(err.contains(needle), "error {err:?} should mention {needle:?}");
        }
    }

    #[test]
    fn parse_rejects_too_many_classes() {
        let classes: Vec<String> = (0..=MAX_SLO_CLASSES)
            .map(|i| format!(r#"{{"name":"c{i}","latency_ms":1,"target":0.5,"budget":0}}"#))
            .collect();
        let text = format!(r#"{{"schema":"attrax-slo/v1","classes":[{}]}}"#, classes.join(","));
        assert!(SloSpec::parse(&text).unwrap_err().to_string().contains("registry slots"));
    }

    #[test]
    fn index_of_is_slot_order() {
        let sp = spec();
        assert_eq!(sp.index_of("gold"), Some(0));
        assert_eq!(sp.index_of("bronze"), Some(1));
        assert_eq!(sp.index_of("silver"), None);
        assert_eq!(sp.names(), vec!["gold".to_string(), "bronze".to_string()]);
    }

    #[test]
    fn latency_threshold_converts_to_ns() {
        let c = SloClass { name: "g".into(), latency_ms: 1.5, target: 0.5, budget: 0 };
        assert_eq!(c.latency_ns(), 1_500_000);
    }

    #[test]
    fn evaluate_is_pure_counter_arithmetic() {
        let sp = spec();
        let prev = summary(&[("gold", 90, 0), ("bronze", 50, 5)]);
        let cur = summary(&[("gold", 188, 2), ("bronze", 140, 15)]);
        let rep = evaluate(&sp, Some(&prev), &cur);
        let gold = &rep.classes[0];
        assert_eq!((gold.good, gold.bad), (188, 2));
        assert_eq!((gold.delta_good, gold.delta_bad), (98, 2));
        assert_eq!(gold.compliance, 0.98);
        assert!(!gold.compliant, "98% < 99% target");
        // bad fraction 2% against a 1% allowance: burning at 2x
        assert!((gold.burn_window - 2.0).abs() < 1e-12, "burn {}", gold.burn_window);
        assert_eq!(gold.budget_remaining, 8);
        assert!(!gold.exhausted);
        let bronze = &rep.classes[1];
        assert_eq!((bronze.delta_good, bronze.delta_bad), (90, 10));
        assert!(bronze.compliant, "90% meets the 90% bronze target");
        assert!(!rep.healthy(), "gold is burning");
        assert!(!rep.exhausted());
        // determinism: same inputs, byte-identical verdict JSON
        let again = evaluate(&sp, Some(&prev), &cur);
        assert_eq!(rep.to_json().to_string(), again.to_json().to_string());
    }

    #[test]
    fn idle_class_is_vacuously_compliant() {
        let sp = spec();
        let rep = evaluate(&sp, None, &summary(&[]));
        assert!(rep.healthy());
        for c in &rep.classes {
            assert_eq!(c.compliance, 1.0);
            assert_eq!(c.burn_window, 0.0);
            assert_eq!(c.budget_remaining, c.budget);
        }
    }

    #[test]
    fn budget_exhaustion_trips_on_strictly_more_bad_than_budget() {
        let sp = spec();
        let at = evaluate(&sp, None, &summary(&[("gold", 0, 10)]));
        assert!(!at.classes[0].exhausted, "bad == budget is the last allowed state");
        assert_eq!(at.classes[0].budget_remaining, 0);
        let over = evaluate(&sp, None, &summary(&[("gold", 0, 11)]));
        assert!(over.classes[0].exhausted);
        assert!(over.exhausted());
    }

    #[test]
    fn counter_reset_clamps_instead_of_underflowing() {
        let sp = spec();
        let prev = summary(&[("gold", 1000, 5)]);
        let cur = summary(&[("gold", 10, 0)]); // restarted server
        let rep = evaluate(&sp, Some(&prev), &cur);
        assert_eq!((rep.classes[0].delta_good, rep.classes[0].delta_bad), (0, 0));
        assert!(rep.classes[0].compliant);
    }

    #[test]
    fn synthetic_spec_is_valid_and_permissive() {
        let names = vec!["gold".to_string(), "silver".to_string()];
        let sp = SloSpec::synthetic(&names);
        assert_eq!(sp.names(), names);
        // must survive its own artifact round-trip (i.e. validate)
        assert_eq!(SloSpec::parse(&sp.to_json().to_string()).unwrap(), sp);
    }
}
