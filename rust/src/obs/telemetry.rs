//! Live telemetry (S18): a lock-free metrics registry and a per-unit
//! engine profiler.
//!
//! Everything here is publish-side machinery for the hot path, so the
//! memory model is deliberate:
//!
//! * every metric is a plain `AtomicU64` updated with `Relaxed`
//!   ordering — publication is a handful of uncontended atomic adds,
//!   never a lock, never a heap allocation (the
//!   `tests/alloc_regression.rs` pin extends over it);
//! * the registry holds a **fixed** field per metric — no name→metric
//!   map, no interning, no registration at request time. The exported
//!   name set is decided at compile time (`obs::export` renders it);
//! * histograms use fixed log-scale bucket edges (powers of two from
//!   2^10 ns up), so bucket boundaries are deterministic across runs
//!   and machines and two scrapes are directly comparable;
//! * nothing in the registry reads the wall clock. Durations are
//!   observed from `obs::span` stamps; rates are the *scraper's*
//!   business (two scrapes + wall time between them).
//!
//! Scrape-side reads are `Relaxed` too: a scrape concurrent with
//! traffic sees each metric at some recent value, not a consistent
//! cross-metric cut. The one exact-reconciliation guarantee is with
//! [`crate::coordinator::metrics::Metrics`]: its record methods
//! dual-write these counters inside the same critical section that
//! updates the snapshot state, so a quiesced server scrapes counters
//! that equal its final `Snapshot` exactly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::hls::{EngineKind, Phase};
use crate::obs::span::{Recorder, Span, Stage, ALL_STAGES, N_STAGES};
use crate::sched::Plan;
use crate::serve::proto::{Frame, RequestFrame};

/// Monotonic counter. `Relaxed` everywhere: per-metric totals are
/// exact, cross-metric views are advisory.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (never underflows below zero).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Preallocated per-SLO-class registry slots. A spec
/// ([`crate::obs::slo::SloSpec`]) may define at most this many
/// classes; the bound keeps classed publication a fixed-size array
/// index with no registration at request time.
pub const MAX_SLO_CLASSES: usize = 8;

/// First finite bucket edge is `2^HIST_SHIFT` = 1024 ns (~1 µs).
pub const HIST_SHIFT: u32 = 10;
/// 26 finite power-of-two edges (2^10 .. 2^35 ns ≈ 34 s) + overflow.
pub const HIST_BUCKETS: usize = 27;

/// Fixed-boundary log-scale histogram of `u64` values (ns). Bucket `i`
/// holds values `v` with `edge(i-1) < v <= edge(i)`; the last bucket
/// is the +Inf overflow. Observing is one index computation from
/// `leading_zeros` plus three relaxed atomic adds — O(1), alloc-free.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Upper edge of bucket `i`, or `None` for the +Inf overflow.
    pub fn edge(i: usize) -> Option<u64> {
        if i + 1 < HIST_BUCKETS {
            Some(1u64 << (HIST_SHIFT + i as u32))
        } else {
            None
        }
    }

    /// Deterministic bucket index for a value.
    pub fn bucket_index(v: u64) -> usize {
        if v <= (1 << HIST_SHIFT) {
            return 0;
        }
        // bits(v-1) - SHIFT: v in (2^(b-1), 2^b] lands in bucket b-SHIFT
        let bits = 64 - (v - 1).leading_zeros();
        ((bits - HIST_SHIFT) as usize).min(HIST_BUCKETS - 1)
    }

    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Cumulative per-bucket counts (monotone non-decreasing by
    /// construction; the last entry equals a concurrent lower bound on
    /// [`Histogram::count`]).
    pub fn cumulative(&self) -> [u64; HIST_BUCKETS] {
        let mut out = [0u64; HIST_BUCKETS];
        let mut cum = 0u64;
        for (i, slot) in out.iter_mut().enumerate() {
            cum += self.buckets[i].load(Ordering::Relaxed);
            *slot = cum;
        }
        out
    }

    /// Quantile estimate: the upper edge of the bucket holding rank
    /// `ceil(q * count)` (`u64::MAX` for the overflow bucket, 0 when
    /// empty). Exact to within one bucket width, like any fixed-bucket
    /// histogram.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let cum = self.cumulative();
        let total = cum[HIST_BUCKETS - 1];
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        for (i, &c) in cum.iter().enumerate() {
            if c >= rank {
                return Self::edge(i).unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }
}

/// The serving stack's live metric set: fixed fields, all atomics.
///
/// Counters mirror [`crate::coordinator::metrics::Snapshot`]'s
/// monotone fields one-for-one (the `Metrics` record methods
/// dual-write them when a registry is attached); gauges and the
/// stage/request histograms are registry-only (spans feed them via
/// [`Registry::observe_span`]).
#[derive(Debug, Default)]
pub struct Registry {
    // -- counters (dual-written by coordinator::metrics) --
    pub completed: Counter,
    pub rejected: Counter,
    pub rejected_busy: Counter,
    pub deadline_exceeded: Counter,
    pub errors: Counter,
    pub retries: Counter,
    pub breaker_trips: Counter,
    pub integrity_failures: Counter,
    pub reconnects: Counter,
    pub conns_total: Counter,
    pub verified: Counter,
    // -- registry-only counters --
    /// Spans dropped by a [`SampledRecorder`] (`--trace-sample N`).
    pub spans_sampled_out: Counter,
    /// Datagrams the push exporter dropped (bounded queue full or UDP
    /// send failure — push is lossy by design, but the loss is counted).
    pub push_dropped: Counter,
    // -- per-SLO-class slots (see [`Registry::observe_class`]) --
    /// Completions within the class's latency threshold.
    pub class_good: [Counter; MAX_SLO_CLASSES],
    /// Completions over the threshold.
    pub class_bad: [Counter; MAX_SLO_CLASSES],
    /// End-to-end request latency per class.
    pub class_request_ns: [Histogram; MAX_SLO_CLASSES],
    class_names: OnceLock<Vec<String>>,
    // -- gauges --
    pub conns_open: Gauge,
    /// Coordinator queue depth. Set by the exposition endpoint at
    /// scrape time (the queue is the source of truth; the gauge is a
    /// sample of it, not an up/down ledger).
    pub queue_depth: Gauge,
    // -- histograms (ns) --
    /// Per-stage pipeline segment latency, indexed by
    /// [`Stage`]` as usize` (the `Accept` slot stays empty: a span's
    /// first stamp opens no segment).
    pub stage_ns: [Histogram; N_STAGES],
    /// End-to-end accept→last-stamp latency.
    pub request_ns: Histogram,
    profiler: OnceLock<Arc<UnitProfiler>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Install the per-unit engine profiler (once; later calls are
    /// ignored so racing workers can all try).
    pub fn install_profiler(&self, p: Arc<UnitProfiler>) {
        let _ = self.profiler.set(p);
    }

    pub fn profiler(&self) -> Option<&Arc<UnitProfiler>> {
        self.profiler.get()
    }

    /// Install the SLO class-name list (slot order = spec order; at
    /// most [`MAX_SLO_CLASSES`] names are kept). Once, first install
    /// wins — like [`Registry::install_profiler`]. Names are only read
    /// at scrape time; classed *publication* is index-based and never
    /// touches them.
    pub fn install_classes(&self, mut names: Vec<String>) {
        names.truncate(MAX_SLO_CLASSES);
        let _ = self.class_names.set(names);
    }

    /// Installed class names in slot order (empty until installed).
    pub fn class_names(&self) -> &[String] {
        self.class_names.get().map(Vec::as_slice).unwrap_or(&[])
    }

    /// Publish one classed completion: latency into the class
    /// histogram, one good-or-bad tick. Array index + relaxed atomics
    /// only — no strings, no allocation (pinned in
    /// `tests/alloc_regression.rs`). Out-of-range indices are ignored
    /// (admission rejects unknown classes before they get here).
    pub fn observe_class(&self, idx: usize, latency_ns: u64, good: bool) {
        if idx >= MAX_SLO_CLASSES {
            return;
        }
        self.class_request_ns[idx].observe(latency_ns);
        if good {
            self.class_good[idx].inc();
        } else {
            self.class_bad[idx].inc();
        }
    }

    /// Fold one completed request span into the latency histograms.
    /// Atomics only — safe on the recorder-disabled hot path.
    pub fn observe_span(&self, span: &Span) {
        for st in ALL_STAGES {
            if st == Stage::Accept {
                continue;
            }
            if let Some(ns) = span.segment_ns(st) {
                self.stage_ns[st as usize].observe(ns);
            }
        }
        self.request_ns.observe(span.total_ns());
    }
}

/// One (unit, phase) profile slot.
#[derive(Debug, Default)]
pub struct UnitSlot {
    /// Modeled device cycles attributed to this unit (under the plan's
    /// own tile-latency model).
    pub cycles: AtomicU64,
    /// Measured host wall time spent executing this unit.
    pub wall_ns: AtomicU64,
    /// Batch passes through this unit.
    pub passes: AtomicU64,
}

/// Aggregated per-unit profile row (scrape/report side).
#[derive(Clone, Debug, PartialEq)]
pub struct ProfileRow {
    pub unit: String,
    pub kind: EngineKind,
    pub phase: Phase,
    pub passes: u64,
    pub cycles: u64,
    pub wall_ns: u64,
}

/// Per-fused-unit engine profiler: the live counterpart of the paper's
/// Table III per-layer dataflow analysis. One slot pair (forward /
/// backward) per plan unit, preallocated at construction so recording
/// is three relaxed atomic adds — the `sched` execution loops call
/// [`UnitProfiler::record`] with cycle/wall deltas around each unit
/// dispatch when a profiler is attached to the worker's `Workspace`.
#[derive(Debug)]
pub struct UnitProfiler {
    names: Vec<String>,
    kinds: Vec<EngineKind>,
    fwd: Vec<UnitSlot>,
    bwd: Vec<UnitSlot>,
}

impl UnitProfiler {
    /// Slots for an explicit (name, kind) unit list.
    pub fn new(meta: Vec<(String, EngineKind)>) -> UnitProfiler {
        let (names, kinds): (Vec<_>, Vec<_>) = meta.into_iter().unzip();
        let n = names.len();
        UnitProfiler {
            names,
            kinds,
            fwd: (0..n).map(|_| UnitSlot::default()).collect(),
            bwd: (0..n).map(|_| UnitSlot::default()).collect(),
        }
    }

    /// Slots matching a compiled plan's fused-unit list.
    pub fn for_plan(plan: &Plan) -> UnitProfiler {
        UnitProfiler::new(plan.unit_meta())
    }

    pub fn n_units(&self) -> usize {
        self.names.len()
    }

    pub fn unit_name(&self, ui: usize) -> &str {
        &self.names[ui]
    }

    pub fn unit_kind(&self, ui: usize) -> EngineKind {
        self.kinds[ui]
    }

    pub fn slot(&self, ui: usize, phase: Phase) -> &UnitSlot {
        match phase {
            Phase::Forward => &self.fwd[ui],
            Phase::Backward => &self.bwd[ui],
        }
    }

    /// Attribute one unit dispatch: `cycles` modeled device cycles and
    /// `wall_ns` measured host time. Alloc-free.
    pub fn record(&self, ui: usize, phase: Phase, cycles: u64, wall_ns: u64) {
        let slot = self.slot(ui, phase);
        slot.cycles.fetch_add(cycles, Ordering::Relaxed);
        slot.wall_ns.fetch_add(wall_ns, Ordering::Relaxed);
        slot.passes.fetch_add(1, Ordering::Relaxed);
    }

    /// All (unit, phase) rows in plan order, forward then backward per
    /// unit (the report/export shape).
    pub fn rows(&self) -> Vec<ProfileRow> {
        let mut out = Vec::with_capacity(2 * self.names.len());
        for ui in 0..self.names.len() {
            for phase in [Phase::Forward, Phase::Backward] {
                let slot = self.slot(ui, phase);
                out.push(ProfileRow {
                    unit: self.names[ui].clone(),
                    kind: self.kinds[ui],
                    phase,
                    passes: slot.passes.load(Ordering::Relaxed),
                    cycles: slot.cycles.load(Ordering::Relaxed),
                    wall_ns: slot.wall_ns.load(Ordering::Relaxed),
                });
            }
        }
        out
    }
}

/// SplitMix64: the standard 64-bit avalanche mixer (public-domain
/// constants). Pure function of the input — no RNG state, no clock.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Deterministic 1-in-N span sampling wrapper (ISSUE 9 satellite):
/// keeps a trace capture bounded under sustained overload. The keep
/// decision is a pure hash of the recorder's own arrival sequence —
/// no RNG, no clock — so two identical runs sample identically.
/// Sampled-out requests still count (`spans_sampled_out`, locally and
/// in an attached [`Registry`]).
pub struct SampledRecorder {
    inner: Arc<dyn Recorder>,
    every: u64,
    seq: AtomicU64,
    sampled_out: AtomicU64,
    registry: Option<Arc<Registry>>,
}

impl SampledRecorder {
    /// Keep ~1 in `every` spans (`every <= 1` keeps all).
    pub fn new(
        inner: Arc<dyn Recorder>,
        every: u64,
        registry: Option<Arc<Registry>>,
    ) -> SampledRecorder {
        SampledRecorder {
            inner,
            every: every.max(1),
            seq: AtomicU64::new(0),
            sampled_out: AtomicU64::new(0),
            registry,
        }
    }

    pub fn sampled_out(&self) -> u64 {
        self.sampled_out.load(Ordering::Relaxed)
    }
}

impl Recorder for SampledRecorder {
    fn record(&self, span: &Span, req: &RequestFrame, reply: &Frame) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        if self.every <= 1 || splitmix64(seq) % self.every == 0 {
            self.inner.record(span, req, reply);
        } else {
            self.sampled_out.fetch_add(1, Ordering::Relaxed);
            if let Some(reg) = &self.registry {
                reg.spans_sampled_out.inc();
            }
        }
    }

    fn flush(&self) {
        self.inner.flush();
    }
}

impl std::fmt::Debug for SampledRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SampledRecorder")
            .field("every", &self.every)
            .field("seq", &self.seq.load(Ordering::Relaxed))
            .field("sampled_out", &self.sampled_out())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::Method;
    use crate::obs::span::CountingRecorder;

    #[test]
    fn histogram_edges_are_deterministic_powers_of_two() {
        assert_eq!(Histogram::edge(0), Some(1024));
        assert_eq!(Histogram::edge(1), Some(2048));
        assert_eq!(Histogram::edge(HIST_BUCKETS - 2), Some(1u64 << 35));
        assert_eq!(Histogram::edge(HIST_BUCKETS - 1), None, "last bucket is +Inf");
        // boundary placement: v <= edge(i) lands in bucket i
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(1024), 0);
        assert_eq!(Histogram::bucket_index(1025), 1);
        assert_eq!(Histogram::bucket_index(2048), 1);
        assert_eq!(Histogram::bucket_index(2049), 2);
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // every value lands in the bucket whose edge bounds it
        for v in [1u64, 7, 1023, 1024, 1025, 99_999, 1 << 20, (1 << 35) + 1] {
            let i = Histogram::bucket_index(v);
            if let Some(edge) = Histogram::edge(i) {
                assert!(v <= edge, "{v} above its bucket edge {edge}");
            }
            if i > 0 {
                let lower = Histogram::edge(i - 1).unwrap();
                assert!(v > lower, "{v} below its bucket floor {lower}");
            }
        }
    }

    #[test]
    fn histogram_cumulative_counts_are_monotone_and_quantiles_bound() {
        let h = Histogram::new();
        let mut x = 1u64;
        for _ in 0..1000 {
            x = splitmix64(x);
            h.observe(x % 50_000_000 + 1);
        }
        assert_eq!(h.count(), 1000);
        let cum = h.cumulative();
        for w in cum.windows(2) {
            assert!(w[0] <= w[1], "cumulative counts must be monotone");
        }
        assert_eq!(cum[HIST_BUCKETS - 1], 1000);
        let (p50, p95, p99) = (h.quantile_ns(0.50), h.quantile_ns(0.95), h.quantile_ns(0.99));
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p99 <= 1 << 26, "observations cap at 5e7, p99 edge must stay near");
        // quantiles are bucket edges: deterministic across reruns
        let h2 = Histogram::new();
        let mut x = 1u64;
        for _ in 0..1000 {
            x = splitmix64(x);
            h2.observe(x % 50_000_000 + 1);
        }
        assert_eq!(h2.quantile_ns(0.95), p95);
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0);
    }

    #[test]
    fn gauge_never_underflows() {
        let g = Gauge::default();
        g.inc();
        g.dec();
        g.dec();
        assert_eq!(g.get(), 0);
        g.set(5);
        assert_eq!(g.get(), 5);
    }

    #[test]
    fn observe_span_fills_stage_and_total_histograms() {
        let reg = Registry::new();
        let mut sp = Span::start(1, 1, 1, Method::Guided);
        sp.stages = [0; N_STAGES];
        sp.stamp(Stage::Accept, 1_000);
        sp.stamp(Stage::Decode, 3_000);
        sp.stamp(Stage::Flush, 10_000);
        reg.observe_span(&sp);
        assert_eq!(reg.stage_ns[Stage::Decode as usize].count(), 1);
        assert_eq!(reg.stage_ns[Stage::Decode as usize].sum(), 2_000);
        assert_eq!(reg.stage_ns[Stage::Flush as usize].sum(), 7_000);
        assert_eq!(reg.stage_ns[Stage::Admit as usize].count(), 0, "unstamped stage stays empty");
        assert_eq!(reg.stage_ns[Stage::Accept as usize].count(), 0, "accept opens no segment");
        assert_eq!(reg.request_ns.count(), 1);
        assert_eq!(reg.request_ns.sum(), 9_000);
    }

    #[test]
    fn profiler_slots_accumulate_per_unit_and_phase() {
        let p = UnitProfiler::new(vec![
            ("c1".into(), EngineKind::Conv),
            ("f1".into(), EngineKind::Vmm),
        ]);
        p.record(0, Phase::Forward, 100, 10);
        p.record(0, Phase::Forward, 100, 10);
        p.record(0, Phase::Backward, 300, 30);
        p.record(1, Phase::Forward, 50, 5);
        let rows = p.rows();
        assert_eq!(rows.len(), 4, "fwd+bwd per unit");
        let c1f = &rows[0];
        assert_eq!((c1f.unit.as_str(), c1f.phase), ("c1", Phase::Forward));
        assert_eq!((c1f.passes, c1f.cycles, c1f.wall_ns), (2, 200, 20));
        let c1b = &rows[1];
        assert_eq!((c1b.passes, c1b.cycles), (1, 300));
        assert_eq!(rows[2].kind, EngineKind::Vmm);
        assert_eq!(rows[3].passes, 0, "untouched slot reads zero");
    }

    #[test]
    fn registry_installs_exactly_one_profiler() {
        let reg = Registry::new();
        assert!(reg.profiler().is_none());
        let a = Arc::new(UnitProfiler::new(vec![("u".into(), EngineKind::Pool)]));
        let b = Arc::new(UnitProfiler::new(vec![("v".into(), EngineKind::Relu)]));
        reg.install_profiler(a.clone());
        reg.install_profiler(b);
        assert_eq!(reg.profiler().unwrap().unit_name(0), "u", "first install wins");
        assert!(Arc::ptr_eq(reg.profiler().unwrap(), &a));
    }

    #[test]
    fn registry_installs_exactly_one_class_name_list() {
        let reg = Registry::new();
        assert!(reg.class_names().is_empty());
        reg.install_classes(vec!["gold".into(), "bronze".into()]);
        reg.install_classes(vec!["other".into()]);
        assert_eq!(reg.class_names(), ["gold".to_string(), "bronze".to_string()]);
        // an oversized list truncates to the preallocated slot count
        let reg2 = Registry::new();
        reg2.install_classes((0..MAX_SLO_CLASSES + 3).map(|i| format!("c{i}")).collect());
        assert_eq!(reg2.class_names().len(), MAX_SLO_CLASSES);
    }

    #[test]
    fn observe_class_publishes_into_fixed_slots() {
        let reg = Registry::new();
        reg.observe_class(0, 2_000, true);
        reg.observe_class(0, 3_000, true);
        reg.observe_class(0, 9_000_000, false);
        reg.observe_class(1, 5_000, true);
        assert_eq!(reg.class_good[0].get(), 2);
        assert_eq!(reg.class_bad[0].get(), 1);
        assert_eq!(reg.class_request_ns[0].count(), 3);
        assert_eq!(reg.class_request_ns[0].sum(), 2_000 + 3_000 + 9_000_000);
        assert_eq!(reg.class_good[1].get(), 1);
        assert_eq!(reg.class_bad[1].get(), 0);
        // out-of-range index is a no-op, not a panic
        reg.observe_class(MAX_SLO_CLASSES, 1, true);
        reg.observe_class(usize::MAX, 1, false);
        let total: u64 = (0..MAX_SLO_CLASSES)
            .map(|i| reg.class_good[i].get() + reg.class_bad[i].get())
            .sum();
        assert_eq!(total, 4);
    }

    fn span_for(seq: u64) -> (Span, RequestFrame, Frame) {
        let sp = Span::start(seq, 1, 1, Method::Guided);
        let req = RequestFrame {
            id: seq,
            method: Method::Guided,
            target: None,
            n: 1,
            elems: 2,
            deadline_ms: None,
            with_crc: false,
            trace_seq: None,
            slo_class: None,
            images: vec![0.0, 1.0],
        };
        let reply = Frame::Request(req.clone());
        (sp, req, reply)
    }

    #[test]
    fn sampling_is_deterministic_and_counts_everything() {
        let run = |every: u64| {
            let inner = Arc::new(CountingRecorder::default());
            let reg = Arc::new(Registry::new());
            let rec = SampledRecorder::new(inner.clone(), every, Some(reg.clone()));
            for i in 0..400 {
                let (sp, req, reply) = span_for(i);
                rec.record(&sp, &req, &reply);
            }
            (
                inner.seen.load(Ordering::Relaxed),
                rec.sampled_out(),
                reg.spans_sampled_out.get(),
            )
        };
        let (kept, dropped, reg_dropped) = run(8);
        assert_eq!(kept + dropped, 400, "every span is either kept or counted out");
        assert_eq!(dropped, reg_dropped);
        assert!(kept > 0, "a 1-in-8 sampler must keep something over 400 spans");
        assert!(dropped > kept, "a 1-in-8 sampler must drop the bulk");
        // pure hash of sequence: reruns sample identically
        assert_eq!(run(8), (kept, dropped, reg_dropped));
        // every=1 keeps everything
        let (k1, d1, _) = run(1);
        assert_eq!((k1, d1), (400, 0));
    }
}
