//! Per-request span ledger: fixed-size, zero-alloc-when-disabled.
//!
//! A [`Span`] is a stack-allocated record of one request's trip
//! through the serving stack — nine stage timestamps plus the
//! batch/device/retry facts the coordinator stamps into its
//! [`crate::coordinator::Response`]. Timestamps are nanoseconds since
//! a process-local epoch ([`now_ns`]), never wall clock, so traces
//! carry durations and ordering but no real-world time. When no
//! [`Recorder`] is configured the server still stamps the span (an
//! array store per stage — no heap) and drops it on the floor;
//! `tests/alloc_regression.rs` pins that the disabled path allocates
//! nothing per request.

use std::sync::OnceLock;
use std::time::Instant;

use crate::attribution::Method;
use crate::serve::proto::{ErrCode, Frame, RequestFrame};

/// The per-request pipeline stages, in traversal order. Indexes into
/// [`Span::stages`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Stage {
    /// Frame preamble seen on the socket (per-frame, not per-conn).
    Accept = 0,
    /// Wire frame decoded into a typed request.
    Decode = 1,
    /// Admission checks passed (shape, deadline budget, fault sites).
    Admit = 2,
    /// All images of the frame accepted by the coordinator queue.
    Enqueue = 3,
    /// Worker closed the micro-batch containing the first image.
    BatchForm = 4,
    /// Batch handed to the chosen device (first attempt).
    Dispatch = 5,
    /// Device pass (including retries) finished.
    DeviceComplete = 6,
    /// Response frame encoded.
    Encode = 7,
    /// Response bytes flushed to the socket.
    Flush = 8,
}

pub const N_STAGES: usize = 9;

pub const ALL_STAGES: [Stage; N_STAGES] = [
    Stage::Accept,
    Stage::Decode,
    Stage::Admit,
    Stage::Enqueue,
    Stage::BatchForm,
    Stage::Dispatch,
    Stage::DeviceComplete,
    Stage::Encode,
    Stage::Flush,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Accept => "accept",
            Stage::Decode => "decode",
            Stage::Admit => "admit",
            Stage::Enqueue => "enqueue",
            Stage::BatchForm => "batch_form",
            Stage::Dispatch => "dispatch",
            Stage::DeviceComplete => "device_complete",
            Stage::Encode => "encode",
            Stage::Flush => "flush",
        }
    }

    pub fn parse(s: &str) -> Option<Stage> {
        ALL_STAGES.iter().copied().find(|st| st.name() == s)
    }
}

/// How the request ended, mirroring the wire outcome.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Outcome {
    Ok,
    Err(ErrCode),
}

impl Outcome {
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Ok => "ok",
            Outcome::Err(c) => c.name(),
        }
    }

    pub fn parse(s: &str) -> Option<Outcome> {
        if s == "ok" {
            Some(Outcome::Ok)
        } else {
            ErrCode::parse(s).map(Outcome::Err)
        }
    }
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-local trace epoch. First call pins it; the server pins
/// it at startup so request stamps are small positive offsets.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the trace epoch (never 0 — 0 means "unreached").
pub fn now_ns() -> u64 {
    ns_of(Instant::now())
}

/// Convert an `Instant` captured elsewhere (e.g. the coordinator's
/// enqueue stamp) to epoch nanoseconds. Saturates to 1 for instants
/// that predate the epoch.
pub fn ns_of(t: Instant) -> u64 {
    t.duration_since(epoch()).as_nanos().max(1) as u64
}

/// One request's ledger. Fixed-size (no heap); `stages[i] == 0` means
/// the request never reached that stage.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// Wire frame id (client-chosen).
    pub frame_id: u64,
    /// Server-assigned connection sequence number.
    pub conn_id: u64,
    /// Images in the frame.
    pub n: u32,
    pub method: Method,
    /// ns-since-epoch per [`Stage`]; 0 = unreached.
    pub stages: [u64; N_STAGES],
    /// Coordinator micro-batch id of the first image (0 = none).
    pub batch_id: u64,
    /// Size of that micro-batch (0 = never batched).
    pub batch_size: u32,
    /// Fleet index of the device that answered (u32::MAX = none).
    pub device_index: u32,
    /// Device execution attempts (1 = first try succeeded).
    pub attempts: u32,
    /// A breaker recorded a trip while serving this request.
    pub breaker_tripped: bool,
    /// Modeled device cycles (per-image share × n).
    pub device_cycles: u64,
    /// Effective deadline budget in ms (0 = none).
    pub deadline_ms: u64,
    /// `trace_seq` header field, when the client sent one (replay
    /// tags resent frames with the original frame id).
    pub trace_seq: Option<u64>,
    pub outcome: Outcome,
}

impl Span {
    pub fn start(frame_id: u64, conn_id: u64, n: u32, method: Method) -> Span {
        let mut s = Span {
            frame_id,
            conn_id,
            n,
            method,
            stages: [0; N_STAGES],
            batch_id: 0,
            batch_size: 0,
            device_index: u32::MAX,
            attempts: 0,
            breaker_tripped: false,
            device_cycles: 0,
            deadline_ms: 0,
            trace_seq: None,
            outcome: Outcome::Ok,
        };
        s.stamp_now(Stage::Accept);
        s
    }

    /// Stamp `stage` with the current epoch-relative time.
    pub fn stamp_now(&mut self, stage: Stage) {
        self.stages[stage as usize] = now_ns();
    }

    /// Stamp `stage` with a timestamp captured elsewhere (0 ignored).
    pub fn stamp(&mut self, stage: Stage, ns: u64) {
        if ns != 0 {
            self.stages[stage as usize] = ns;
        }
    }

    pub fn get(&self, stage: Stage) -> Option<u64> {
        match self.stages[stage as usize] {
            0 => None,
            t => Some(t),
        }
    }

    /// Duration in ns from the latest stamped stage before `to` up to
    /// `to` itself; `None` if `to` (or every prior stage) is unstamped.
    pub fn segment_ns(&self, to: Stage) -> Option<u64> {
        let i = to as usize;
        let end = self.stages[i];
        if i == 0 || end == 0 {
            return None;
        }
        let start = self.stages[..i].iter().rev().copied().find(|&t| t != 0)?;
        Some(end.saturating_sub(start))
    }

    /// Total accept→last-stamped-stage duration in ns.
    pub fn total_ns(&self) -> u64 {
        let first = self.stages.iter().copied().find(|&t| t != 0).unwrap_or(0);
        let last = self.stages.iter().rev().copied().find(|&t| t != 0).unwrap_or(0);
        last.saturating_sub(first)
    }
}

/// Sink for completed spans. The server calls `record` exactly once
/// per answered request frame (success *and* typed-error outcomes),
/// passing the decoded request and the reply frame that went on the
/// wire, so a recorder can persist the full exchange. Implementations
/// must be cheap and must never panic — they run on connection
/// threads.
pub trait Recorder: Send + Sync {
    fn record(&self, span: &Span, req: &RequestFrame, reply: &Frame);

    /// Flush buffered records (called at server drain).
    fn flush(&self) {}
}

/// Recorder that counts but retains nothing — test aid.
#[derive(Default, Debug)]
pub struct CountingRecorder {
    pub seen: std::sync::atomic::AtomicU64,
}

impl Recorder for CountingRecorder {
    fn record(&self, _span: &Span, _req: &RequestFrame, _reply: &Frame) {
        self.seen.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_roundtrip_and_order() {
        for (i, st) in ALL_STAGES.iter().enumerate() {
            assert_eq!(*st as usize, i);
            assert_eq!(Stage::parse(st.name()), Some(*st));
        }
        assert_eq!(Stage::parse("nope"), None);
    }

    #[test]
    fn outcome_names_roundtrip() {
        for o in [
            Outcome::Ok,
            Outcome::Err(ErrCode::Busy),
            Outcome::Err(ErrCode::Closed),
            Outcome::Err(ErrCode::BadRequest),
            Outcome::Err(ErrCode::DeadlineExceeded),
            Outcome::Err(ErrCode::Integrity),
        ] {
            assert_eq!(Outcome::parse(o.name()), Some(o));
        }
        assert_eq!(Outcome::parse("sorcery"), None);
    }

    #[test]
    fn segments_and_total() {
        let mut s = Span::start(1, 1, 1, Method::Guided);
        s.stages = [0; N_STAGES];
        s.stamp(Stage::Accept, 100);
        s.stamp(Stage::Decode, 150);
        s.stamp(Stage::Admit, 0); // ignored: 0 means unreached
        s.stamp(Stage::Enqueue, 300);
        assert_eq!(s.segment_ns(Stage::Decode), Some(50));
        // admit unstamped -> segment skips back to decode
        assert_eq!(s.segment_ns(Stage::Admit), None);
        assert_eq!(s.segment_ns(Stage::Enqueue), Some(150));
        assert_eq!(s.total_ns(), 200);
        assert_eq!(s.get(Stage::Admit), None);
        assert_eq!(s.get(Stage::Accept), Some(100));
    }

    #[test]
    fn now_is_monotonic_nonzero() {
        let a = now_ns();
        let b = now_ns();
        assert!(a >= 1);
        assert!(b >= a);
    }
}
