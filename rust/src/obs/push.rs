//! Push-based metric export: statsd-style lines over UDP.
//!
//! The pull-based stats endpoint ([`crate::obs::export::StatsEndpoint`])
//! covers interactive scraping, but edge fleets often sit behind NAT
//! where the collector cannot reach in. [`PushEmitter`] inverts the
//! direction: a ticker thread snapshots the registry every
//! `every_ms`, renders counter *deltas* (statsd `|c`) and gauge
//! absolutes (`|g`), and hands datagram-sized chunks to a sender
//! thread over a bounded queue. Nothing here ever blocks a request
//! path:
//!
//! * rendering happens on the ticker thread from relaxed atomic
//!   loads — publication stays lock- and allocation-free;
//! * the queue is a `sync_channel`; when the sender falls behind the
//!   ticker drops the datagram and bumps the registry's
//!   `push_dropped` counter (visible in the pull exposition, so a
//!   lossy push path is itself observable);
//! * UDP send failures likewise count as drops rather than erroring.
//!
//! The emitter dies with the server: [`PushEmitter`] joins both
//! threads on drop, flushing one final snapshot first so short runs
//! (e.g. `--smoke`) still emit their totals.

use std::net::UdpSocket;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver as MpscReceiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs::export::counter_pairs;
use crate::obs::telemetry::Registry;

/// Bounded queue depth between the ticker and the sender. Deep enough
/// to absorb a transient stall, small enough that a dead collector
/// cannot pin unbounded memory.
const QUEUE_DEPTH: usize = 64;

/// Keep each datagram under the conventional safe UDP payload size.
const MAX_DATAGRAM_BYTES: usize = 1400;

/// statsd metric names must not contain the protocol's own
/// delimiters; replace anything suspicious from label-derived parts.
fn sanitize(name: &str, out: &mut String) {
    for c in name.chars() {
        match c {
            ':' | '|' | '@' | '\n' | ' ' => out.push('_'),
            _ => out.push(c),
        }
    }
}

/// Render one statsd snapshot: counter deltas vs `last` (updated in
/// place) and gauge absolutes. Pure string-building so it can be
/// tested without sockets; returns one `name:value|type` line per
/// metric, newline-terminated.
fn render_lines(reg: &Registry, last: &mut Vec<u64>) -> String {
    let pairs = counter_pairs(reg);
    last.resize(pairs.len(), 0);
    let mut out = String::with_capacity(1024);
    for (i, (name, v)) in pairs.iter().enumerate() {
        let delta = v.saturating_sub(last[i]);
        last[i] = *v;
        if delta == 0 {
            continue; // statsd counters are increments; zero is noise
        }
        sanitize(name, &mut out);
        out.push(':');
        out.push_str(&delta.to_string());
        out.push_str("|c\n");
    }
    for (name, v) in [
        ("attrax_conns_open", reg.conns_open.get()),
        ("attrax_queue_depth", reg.queue_depth.get()),
    ] {
        sanitize(name, &mut out);
        out.push(':');
        out.push_str(&v.to_string());
        out.push_str("|g\n");
    }
    for (idx, class) in reg.class_names().iter().enumerate() {
        for (suffix, v) in [
            ("good", reg.class_good[idx].get()),
            ("bad", reg.class_bad[idx].get()),
        ] {
            out.push_str("attrax_class_");
            sanitize(class, &mut out);
            out.push('_');
            out.push_str(suffix);
            out.push(':');
            out.push_str(&v.to_string());
            out.push_str("|g\n"); // absolute, so the collector needs no delta state
        }
    }
    out
}

/// Split rendered lines into datagram-sized chunks on line
/// boundaries. A single oversized line (cannot happen with our fixed
/// metric names, but belt-and-braces) becomes its own datagram.
fn chunk_datagrams(lines: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for line in lines.split_inclusive('\n') {
        if !cur.is_empty() && cur.len() + line.len() > MAX_DATAGRAM_BYTES {
            out.push(std::mem::take(&mut cur));
        }
        cur.push_str(line);
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Background statsd push exporter. Construct with [`PushEmitter::start`];
/// drop to flush and join. Owned by the server so its lifetime matches
/// the stats endpoint's.
pub struct PushEmitter {
    stop: Arc<AtomicBool>,
    ticker: Option<JoinHandle<()>>,
    sender: Option<JoinHandle<()>>,
}

impl PushEmitter {
    /// Spawn the ticker + sender pair pushing `registry` snapshots to
    /// `addr` (host:port) every `every_ms` milliseconds. Resolution
    /// and binding happen up front so a bad address fails loudly at
    /// startup instead of silently dropping forever.
    pub fn start(registry: Arc<Registry>, addr: &str, every_ms: u64) -> std::io::Result<Self> {
        let sock = UdpSocket::bind("0.0.0.0:0")?;
        sock.connect(addr)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx): (SyncSender<String>, MpscReceiver<String>) = sync_channel(QUEUE_DEPTH);

        let send_reg = Arc::clone(&registry);
        let sender = std::thread::spawn(move || {
            // Exits when the ticker drops its `tx`.
            while let Ok(datagram) = rx.recv() {
                if sock.send(datagram.as_bytes()).is_err() {
                    send_reg.push_dropped.inc();
                }
            }
        });

        let tick_stop = Arc::clone(&stop);
        let every = Duration::from_millis(every_ms.max(1));
        let ticker = std::thread::spawn(move || {
            let mut last: Vec<u64> = Vec::new();
            let mut emit = |final_flush: bool| {
                let lines = render_lines(&registry, &mut last);
                for datagram in chunk_datagrams(&lines) {
                    match tx.try_send(datagram) {
                        Ok(()) => {}
                        Err(TrySendError::Full(_)) if !final_flush => {
                            registry.push_dropped.inc();
                        }
                        // On the final flush give the sender a moment
                        // to drain rather than dropping the totals.
                        Err(TrySendError::Full(d)) => {
                            if tx.send(d).is_err() {
                                registry.push_dropped.inc();
                            }
                        }
                        Err(TrySendError::Disconnected(_)) => return,
                    }
                }
            };
            while !tick_stop.load(Ordering::Relaxed) {
                // Sleep in small steps so shutdown is prompt even with
                // long push intervals.
                let mut slept = Duration::ZERO;
                while slept < every && !tick_stop.load(Ordering::Relaxed) {
                    let step = (every - slept).min(Duration::from_millis(5));
                    std::thread::sleep(step);
                    slept += step;
                }
                if tick_stop.load(Ordering::Relaxed) {
                    break;
                }
                emit(false);
            }
            emit(true); // final snapshot so short runs still report
        });

        Ok(Self { stop, ticker: Some(ticker), sender: Some(sender) })
    }
}

impl Drop for PushEmitter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.ticker.take() {
            let _ = t.join(); // drops tx, which in turn stops the sender
        }
        if let Some(s) = self.sender.take() {
            let _ = s.join();
        }
    }
}

/// Std-only test collector: binds an ephemeral UDP port and gathers
/// lines until `timeout` with no traffic. Used by tests and the CI
/// gate; not part of the serving path.
pub struct Receiver {
    sock: UdpSocket,
}

impl Receiver {
    pub fn bind() -> std::io::Result<Self> {
        let sock = UdpSocket::bind("127.0.0.1:0")?;
        Ok(Self { sock })
    }

    /// `host:port` to point a [`PushEmitter`] at.
    pub fn addr(&self) -> String {
        self.sock.local_addr().map(|a| a.to_string()).unwrap_or_default()
    }

    /// Collect individual statsd lines until no datagram arrives for
    /// `idle`. Each datagram may carry many newline-separated lines.
    pub fn recv_lines(&self, idle: Duration) -> Vec<String> {
        let _ = self.sock.set_read_timeout(Some(idle));
        let mut buf = [0u8; 64 * 1024];
        let mut lines = Vec::new();
        while let Ok(n) = self.sock.recv(&mut buf) {
            let text = String::from_utf8_lossy(&buf[..n]);
            lines.extend(text.lines().map(str::to_string));
        }
        lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_emits_counter_deltas_and_gauge_absolutes() {
        let reg = Registry::new();
        reg.completed.add(10);
        reg.conns_open.set(3);
        let mut last = Vec::new();
        let first = render_lines(&reg, &mut last);
        assert!(first.contains("attrax_completed_total:10|c"), "{first}");
        assert!(first.contains("attrax_conns_open:3|g"), "{first}");
        // unchanged counters render nothing on the next tick; gauges repeat
        let second = render_lines(&reg, &mut last);
        assert!(!second.contains("attrax_completed_total"), "{second}");
        assert!(second.contains("attrax_conns_open:3|g"), "{second}");
        // a new increment shows up as its delta, not the running total
        reg.completed.add(5);
        let third = render_lines(&reg, &mut last);
        assert!(third.contains("attrax_completed_total:5|c"), "{third}");
    }

    #[test]
    fn render_covers_installed_classes() {
        let reg = Registry::new();
        reg.install_classes(vec!["gold".into()]);
        reg.observe_class(0, 1_000, true);
        reg.observe_class(0, 9_999_999, false);
        let mut last = Vec::new();
        let lines = render_lines(&reg, &mut last);
        assert!(lines.contains("attrax_class_gold_good:1|g"), "{lines}");
        assert!(lines.contains("attrax_class_gold_bad:1|g"), "{lines}");
    }

    #[test]
    fn sanitize_strips_statsd_delimiters() {
        let mut out = String::new();
        sanitize("we|ird:na me\n", &mut out);
        assert_eq!(out, "we_ird_na_me_");
    }

    #[test]
    fn chunking_respects_datagram_size_and_line_boundaries() {
        let line = format!("{}:1|c\n", "x".repeat(200));
        let many = line.repeat(20); // ~4 KiB total
        let chunks = chunk_datagrams(&many);
        assert!(chunks.len() > 1, "must split");
        for c in &chunks {
            assert!(c.len() <= MAX_DATAGRAM_BYTES, "chunk of {} bytes", c.len());
            assert!(c.ends_with('\n'), "chunks end on line boundaries");
        }
        assert_eq!(chunks.concat(), many, "no lines lost or reordered");
    }

    #[test]
    fn emitter_pushes_to_udp_receiver_and_flushes_on_drop() {
        let reg = Arc::new(Registry::new());
        reg.install_classes(vec!["gold".into()]);
        let rx = Receiver::bind().unwrap();
        let emitter = PushEmitter::start(Arc::clone(&reg), &rx.addr(), 10).unwrap();
        reg.completed.add(42);
        reg.observe_class(0, 500, true);
        std::thread::sleep(Duration::from_millis(60));
        drop(emitter); // joins both threads, final flush included
        let lines = rx.recv_lines(Duration::from_millis(300));
        assert!(
            lines.iter().any(|l| l.starts_with("attrax_completed_total:") && l.ends_with("|c")),
            "completed counter pushed: {lines:?}"
        );
        assert!(
            lines.iter().any(|l| l == "attrax_class_gold_good:1|g"),
            "classed slot pushed: {lines:?}"
        );
        // the deltas across all pushed datagrams sum to the true total
        let total: u64 = lines
            .iter()
            .filter_map(|l| l.strip_prefix("attrax_completed_total:"))
            .filter_map(|v| v.strip_suffix("|c"))
            .filter_map(|v| v.parse::<u64>().ok())
            .sum();
        assert_eq!(total, 42);
    }

    #[test]
    fn bad_address_fails_at_startup() {
        let reg = Arc::new(Registry::new());
        assert!(PushEmitter::start(reg, "not-an-addr", 10).is_err());
    }
}
