//! DRAM/AXI traffic model (S5): every tile load/store goes through
//! here, charging bytes, burst transactions and bus cycles into the
//! `Cost` ledger (paper §III-A: tiles move over AXI between DRAM and
//! on-chip buffers).
//!
//! The timing model is a standard burst model: a transfer of `bytes`
//! issued as `bursts` transactions costs
//! `ceil(bytes / axi_bytes_per_cycle) + bursts * axi_burst_overhead`
//! cycles. Contiguous rows of a tile form one burst each; fully
//! contiguous tensors form a single burst.

use super::{Cost, HwConfig};

/// Account a DRAM read of `bytes` split into `bursts` transactions.
pub fn read(cfg: &HwConfig, cost: &mut Cost, bytes: u64, bursts: u64) {
    transfer(cfg, cost, bytes, bursts, false);
}

/// Account a DRAM write of `bytes` split into `bursts` transactions.
pub fn write(cfg: &HwConfig, cost: &mut Cost, bytes: u64, bursts: u64) {
    transfer(cfg, cost, bytes, bursts, true);
}

fn transfer(cfg: &HwConfig, cost: &mut Cost, bytes: u64, bursts: u64, is_write: bool) {
    if bytes == 0 {
        return;
    }
    let bursts = bursts.max(1);
    let cycles = bytes.div_ceil(cfg.axi_bytes_per_cycle as u64) + bursts * cfg.axi_burst_overhead;
    cost.dram_cycles += cycles;
    cost.dram_bursts += bursts;
    if is_write {
        cost.dram_write_bytes += bytes;
    } else {
        cost.dram_read_bytes += bytes;
    }
}

/// Read a model-weight tile: like [`read`], and additionally tracked in
/// the `dram_weight_bytes` ledger so weight-reuse optimizations (the
/// batch-N path amortizing weight fetches across images) are visible
/// separately from activation/gradient traffic.
pub fn read_weights(cfg: &HwConfig, cost: &mut Cost, bytes: u64, bursts: u64) {
    read(cfg, cost, bytes, bursts);
    if bytes > 0 {
        cost.dram_weight_bytes += bytes;
    }
}

/// Read a row-tiled 2-D region: `rows` bursts of `row_words` words.
pub fn read_tile_rows(cfg: &HwConfig, cost: &mut Cost, rows: u64, row_words: u64) {
    read(cfg, cost, rows * row_words * cfg.word_bytes() as u64, rows);
}

/// Write a row-tiled 2-D region.
pub fn write_tile_rows(cfg: &HwConfig, cost: &mut Cost, rows: u64, row_words: u64) {
    write(cfg, cost, rows * row_words * cfg.word_bytes() as u64, rows);
}

/// Read a contiguous block of `words` (single burst).
pub fn read_contig(cfg: &HwConfig, cost: &mut Cost, words: u64) {
    read(cfg, cost, words * cfg.word_bytes() as u64, 1);
}

/// Write a contiguous block of `words` (single burst).
pub fn write_contig(cfg: &HwConfig, cost: &mut Cost, words: u64) {
    write(cfg, cost, words * cfg.word_bytes() as u64, 1);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_cost_formula() {
        let cfg = HwConfig::pynq_z2(); // 8 B/cycle, 16 cycle overhead
        let mut c = Cost::new();
        read(&cfg, &mut c, 800, 10);
        assert_eq!(c.dram_cycles, 100 + 160);
        assert_eq!(c.dram_read_bytes, 800);
        assert_eq!(c.dram_bursts, 10);
        write(&cfg, &mut c, 8, 1);
        assert_eq!(c.dram_cycles, 260 + 1 + 16);
        assert_eq!(c.dram_write_bytes, 8);
    }

    #[test]
    fn weight_reads_tracked_separately() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        read(&cfg, &mut c, 100, 1);
        read_weights(&cfg, &mut c, 60, 2);
        assert_eq!(c.dram_read_bytes, 160);
        assert_eq!(c.dram_weight_bytes, 60);
        read_weights(&cfg, &mut c, 0, 1);
        assert_eq!(c.dram_weight_bytes, 60);
    }

    #[test]
    fn zero_bytes_free() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        read(&cfg, &mut c, 0, 5);
        assert_eq!(c.dram_cycles, 0);
        assert_eq!(c.dram_bursts, 0);
    }

    #[test]
    fn row_tiles_charge_per_row_bursts() {
        let cfg = HwConfig::pynq_z2(); // 2-byte words
        let mut c = Cost::new();
        read_tile_rows(&cfg, &mut c, 10, 18); // 10 rows x 18 words x 2B
        assert_eq!(c.dram_read_bytes, 360);
        assert_eq!(c.dram_bursts, 10);
        assert_eq!(c.dram_cycles, 45 + 160);
    }

    #[test]
    fn contiguous_single_burst() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        read_contig(&cfg, &mut c, 2304);
        assert_eq!(c.dram_bursts, 1);
        assert_eq!(c.dram_cycles, 576 + 16);
    }

    #[test]
    fn fewer_bursts_cheaper_same_bytes() {
        let cfg = HwConfig::pynq_z2();
        let mut a = Cost::new();
        let mut b = Cost::new();
        read(&cfg, &mut a, 4096, 1);
        read(&cfg, &mut b, 4096, 64);
        assert!(a.dram_cycles < b.dram_cycles);
    }
}
