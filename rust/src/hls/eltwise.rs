//! Elementwise add unit (the residual/skip join). Forward streams two
//! same-length DRAM tensors through the ALU lanes as a Q-format
//! *saturating* add (optionally fusing the following ReLU into the
//! output store, like conv/VMM do). Backward reuses the same datapath
//! as [`accumulate`]: at a fan-out fork the BP pass must *sum* the
//! gradients arriving from each consumer, and that sum is this engine
//! run in accumulate mode over the partial-gradient slab.

use super::{dram, Cost, HwConfig};

/// `out[i] = sat(a[i] + b[i])`, ReLU-clamped when `relu` is set.
///
/// Allocate-and-call wrapper over [`forward_into`].
pub fn forward(cfg: &HwConfig, cost: &mut Cost, a: &[i32], b: &[i32], relu: bool) -> Vec<i32> {
    let mut out = Vec::new();
    forward_into(cfg, cost, a, b, relu, &mut out);
    out
}

/// The elementwise-add forward core, writing into a caller slab (the
/// workspace-driven path). Both operands stream from DRAM, one sum per
/// ALU lane per cycle, result streams back.
pub fn forward_into(
    cfg: &HwConfig,
    cost: &mut Cost,
    a: &[i32],
    b: &[i32],
    relu: bool,
    out: &mut Vec<i32>,
) {
    out.clear();
    out.resize(a.len(), 0);
    forward_slice(cfg, cost, a, b, relu, out);
}

/// Slice-level core of [`forward_into`] for callers that own the output
/// slab (the workspace-driven batch path writes per-image sub-slices).
pub fn forward_slice(
    cfg: &HwConfig,
    cost: &mut Cost,
    a: &[i32],
    b: &[i32],
    relu: bool,
    out: &mut [i32],
) {
    assert_eq!(a.len(), b.len(), "eltwise add operand length mismatch");
    assert_eq!(out.len(), a.len(), "eltwise add output length mismatch");
    let n = a.len();
    dram::read_contig(cfg, cost, n as u64);
    dram::read_contig(cfg, cost, n as u64);
    for i in 0..n {
        let s = cfg.q.saturate(a[i] as i64 + b[i] as i64);
        out[i] = if relu { s.max(0) } else { s };
    }
    let lanes = cfg.conv_macs_parallel() as u64;
    cost.compute_cycles += (n as u64).div_ceil(lanes) + cfg.pipeline_depth;
    dram::write_contig(cfg, cost, n as u64);
}

/// `into[i] = sat(into[i] + g[i])` — gradient accumulation at a fan-out
/// fork point during BP. Same streaming cost shape as the forward add:
/// two operand reads, one write.
pub fn accumulate(cfg: &HwConfig, cost: &mut Cost, g: &[i32], into: &mut [i32]) {
    assert_eq!(g.len(), into.len(), "eltwise accumulate length mismatch");
    let n = g.len();
    dram::read_contig(cfg, cost, n as u64);
    dram::read_contig(cfg, cost, n as u64);
    for i in 0..n {
        into[i] = cfg.q.saturate(into[i] as i64 + g[i] as i64);
    }
    let lanes = cfg.conv_macs_parallel() as u64;
    cost.compute_cycles += (n as u64).div_ceil(lanes) + cfg.pipeline_depth;
    dram::write_contig(cfg, cost, n as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::QFormat;

    fn q(vals: &[f32]) -> Vec<i32> {
        let f = QFormat::paper16();
        vals.iter().map(|&v| f.from_f32(v)).collect()
    }

    #[test]
    fn add_is_elementwise_and_exact() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let a = q(&[1.0, -2.0, 0.5, 0.0]);
        let b = q(&[0.25, 1.0, -0.5, -3.0]);
        let out = forward(&cfg, &mut c, &a, &b, false);
        assert_eq!(out, q(&[1.25, -1.0, 0.0, -3.0]));
    }

    #[test]
    fn fused_relu_clamps_negatives() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let a = q(&[1.0, -2.0]);
        let b = q(&[0.5, 1.0]);
        let out = forward(&cfg, &mut c, &a, &b, true);
        assert_eq!(out, vec![q(&[1.5])[0], 0]);
    }

    #[test]
    fn add_saturates_at_word_limits() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let max = (1i32 << (cfg.q.word_bits - 1)) - 1;
        let min = -(1i32 << (cfg.q.word_bits - 1));
        assert_eq!(forward(&cfg, &mut c, &[max], &[max], false), vec![max]);
        assert_eq!(forward(&cfg, &mut c, &[min], &[min], false), vec![min]);
    }

    #[test]
    fn accumulate_matches_forward_sum() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let g = q(&[0.5, -1.0, 2.0]);
        let mut into = q(&[1.0, 1.0, -0.5]);
        accumulate(&cfg, &mut c, &g, &mut into);
        assert_eq!(into, q(&[1.5, 0.0, 1.5]));
    }

    #[test]
    fn cost_accounts_two_reads_one_write() {
        let cfg = HwConfig::pynq_z2();
        let n = 1024usize;
        let a = vec![1i32; n];
        let b = vec![2i32; n];
        let mut c = Cost::new();
        forward(&cfg, &mut c, &a, &b, false);
        let wb = cfg.word_bytes() as u64;
        assert_eq!(c.dram_read_bytes, 2 * n as u64 * wb);
        assert_eq!(c.dram_write_bytes, n as u64 * wb);
        let lanes = cfg.conv_macs_parallel() as u64;
        assert_eq!(c.compute_cycles, (n as u64).div_ceil(lanes) + cfg.pipeline_depth);
        // accumulate charges the same streaming shape
        let mut c2 = Cost::new();
        let mut into = b.clone();
        accumulate(&cfg, &mut c2, &a, &mut into);
        assert_eq!(c2.dram_read_bytes, c.dram_read_bytes);
        assert_eq!(c2.dram_write_bytes, c.dram_write_bytes);
        assert_eq!(c2.compute_cycles, c.compute_cycles);
    }
}
