//! HLS-style compute engines (S3): the rust re-expression of the
//! paper's Vitis HLS template library (§III).
//!
//! Each engine is *functionally* bit-exact Q-format fixed point and
//! *temporally* tile-based: data is moved DRAM → on-chip buffer in
//! tiles sized by `HwConfig`, computed with the configured
//! `N_oh × N_ow` MAC unroll, and stored back — charging DRAM traffic
//! and compute cycles into a `Cost` ledger exactly as the loop nests
//! execute. The cycle totals therefore emerge from the same tiling /
//! unroll structure the paper synthesizes, rather than from a closed-
//! form formula.

pub mod conv;
pub mod dram;
pub mod eltwise;
pub mod pool;
pub mod relu;
pub mod vmm;

use crate::fx::QFormat;

/// Why a [`HwConfig`] is illegal (returned by [`HwConfig::validate`],
/// the single legality gate: `sched::Plan` construction,
/// `Simulator::with_config` and the `dse::space` enumerator all go
/// through it, so no other layer re-checks knob consistency).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// A structural knob that must be at least 1 is zero.
    ZeroKnob(&'static str),
    /// The row unroll must divide the row tile (each of the `n_oh`
    /// MAC lanes owns an equal slice of the output-tile rows).
    UnrollRows { n_oh: usize, tile_oh: usize },
    /// The column unroll must divide the column tile.
    UnrollCols { n_ow: usize, tile_ow: usize },
    /// The VMM block size must divide the input-vector tile: the BP
    /// pass reuses the `[vmm_tile][vmm_in_tile]` weight buffer with
    /// the roles swapped, so an indivisible pair would leave
    /// partially-filled banks.
    VmmIndivisible { vmm_tile: usize, vmm_in_tile: usize },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroKnob(knob) => write!(f, "config knob {knob} must be positive"),
            ConfigError::UnrollRows { n_oh, tile_oh } => {
                write!(f, "row unroll n_oh={n_oh} must divide tile_oh={tile_oh}")
            }
            ConfigError::UnrollCols { n_ow, tile_ow } => {
                write!(f, "col unroll n_ow={n_ow} must divide tile_ow={tile_ow}")
            }
            ConfigError::VmmIndivisible { vmm_tile, vmm_in_tile } => {
                write!(f, "vmm_tile={vmm_tile} must divide vmm_in_tile={vmm_in_tile}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Design-time hardware configuration (paper §IV-B "Design
/// Configuration"): unroll factors, tile/buffer dims, VMM block size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HwConfig {
    /// MAC unroll along output rows (paper N_oh). Must divide `tile_oh`.
    pub n_oh: usize,
    /// MAC unroll along output cols (paper N_ow). Must divide `tile_ow`.
    pub n_ow: usize,
    /// Conv output-tile spatial dims (buffer sizing).
    pub tile_oh: usize,
    pub tile_ow: usize,
    /// Conv channel tiling (output / input channels per tile).
    pub tile_oc: usize,
    pub tile_ic: usize,
    /// VMM block size (paper: "buffer size is set to 16/32"): output
    /// elements per tile AND parallel MACs in the VMM block.
    pub vmm_tile: usize,
    /// VMM input-vector tile length.
    pub vmm_in_tile: usize,
    /// Fixed-point format of the datapath.
    pub q: QFormat,
    /// AXI bus width in bytes moved per cycle (64-bit AXI @ fabric clock).
    pub axi_bytes_per_cycle: usize,
    /// Fixed cycles per AXI burst transaction (address phase + latency).
    pub axi_burst_overhead: u64,
    /// Pipeline fill depth charged once per innermost pipelined loop.
    pub pipeline_depth: u64,
    /// If true, tile load/compute/store overlap (HLS dataflow double
    /// buffering); latency per tile = max instead of sum. The paper's
    /// baseline design is sequential-per-tile (false).
    pub overlap_tiles: bool,
}

impl HwConfig {
    /// A config with the paper's common structure, parameterized by the
    /// unroll factors and VMM size that Table IV varies per board.
    pub fn with_unroll(n_oh: usize, n_ow: usize, vmm_tile: usize) -> HwConfig {
        HwConfig {
            n_oh,
            n_ow,
            tile_oh: 8,
            tile_ow: 8,
            tile_oc: 16,
            tile_ic: 16,
            vmm_tile,
            vmm_in_tile: 256,
            q: QFormat::paper16(),
            axi_bytes_per_cycle: 8,
            axi_burst_overhead: 16,
            pipeline_depth: 8,
            overlap_tiles: false,
        }
    }

    /// Paper Table IV configurations.
    pub fn pynq_z2() -> HwConfig {
        HwConfig::with_unroll(4, 4, 16)
    }
    pub fn ultra96_v2() -> HwConfig {
        HwConfig::with_unroll(4, 8, 16)
    }
    pub fn zcu104() -> HwConfig {
        HwConfig::with_unroll(8, 8, 32)
    }

    /// Parallel MACs in the conv block == its DSP usage (paper §IV-B).
    pub fn conv_macs_parallel(&self) -> usize {
        self.n_oh * self.n_ow
    }

    /// The single legality check for a configuration. Every knob that
    /// sizes a loop or a buffer must be positive (a zero tile would
    /// turn the engine tile loops into zero-step iterators), the
    /// unrolls must divide their tiles, and the VMM block must divide
    /// the input-vector tile (see [`ConfigError`] for each arm).
    pub fn validate(&self) -> Result<(), ConfigError> {
        let positives = [
            ("n_oh", self.n_oh),
            ("n_ow", self.n_ow),
            ("tile_oh", self.tile_oh),
            ("tile_ow", self.tile_ow),
            ("tile_oc", self.tile_oc),
            ("tile_ic", self.tile_ic),
            ("vmm_tile", self.vmm_tile),
            ("vmm_in_tile", self.vmm_in_tile),
            ("axi_bytes_per_cycle", self.axi_bytes_per_cycle),
            ("pipeline_depth", self.pipeline_depth as usize),
        ];
        for (knob, v) in positives {
            if v == 0 {
                return Err(ConfigError::ZeroKnob(knob));
            }
        }
        if self.tile_oh % self.n_oh != 0 {
            return Err(ConfigError::UnrollRows { n_oh: self.n_oh, tile_oh: self.tile_oh });
        }
        if self.tile_ow % self.n_ow != 0 {
            return Err(ConfigError::UnrollCols { n_ow: self.n_ow, tile_ow: self.tile_ow });
        }
        if self.vmm_in_tile % self.vmm_tile != 0 {
            return Err(ConfigError::VmmIndivisible {
                vmm_tile: self.vmm_tile,
                vmm_in_tile: self.vmm_in_tile,
            });
        }
        Ok(())
    }

    /// Bytes per datapath word in DRAM (activations/weights/gradients).
    pub fn word_bytes(&self) -> usize {
        (self.q.word_bits as usize).div_ceil(8)
    }
}

/// Reusable engine scratch memory (the host mirror of the on-chip
/// buffers): the padded-input slab and the accumulator tiles every
/// `_into` engine entry point works in. Buffers are resized in place and
/// keep their capacity across calls, so a warm scratch makes the engine
/// cores allocation-free (DESIGN.md §Plan/Workspace).
#[derive(Default)]
pub struct EngineScratch {
    /// Padded/line-buffered input slab (conv forward).
    pub xp: Vec<i32>,
    /// i64 accumulator slab: output tiles (conv/vmm) or the full
    /// gradient accumulator (fused unpool-conv), one region per image.
    pub acc: Vec<i64>,
}

impl EngineScratch {
    pub fn new() -> EngineScratch {
        EngineScratch::default()
    }
}

/// Execution phase — selects the DRAM access pattern (paper Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Forward,
    Backward,
}

/// The engine family a fused plan unit dispatches to — the label axis
/// of the per-unit telemetry profile (paper Table III rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Conv,
    Vmm,
    Pool,
    Relu,
    Eltwise,
}

impl EngineKind {
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Conv => "conv",
            EngineKind::Vmm => "vmm",
            EngineKind::Pool => "pool",
            EngineKind::Relu => "relu",
            EngineKind::Eltwise => "eltwise",
        }
    }
}

/// Cycle/traffic ledger, filled in by the engines as they execute.
#[derive(Clone, Debug, Default)]
pub struct Cost {
    pub compute_cycles: u64,
    pub dram_cycles: u64,
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    /// Subset of `dram_read_bytes` that is model-weight traffic — the
    /// quantity the batch-N execution path amortizes across images
    /// (each weight tile is fetched once per batch, not once per image).
    pub dram_weight_bytes: u64,
    pub dram_bursts: u64,
    pub macs: u64,
    /// (label, total cycles at that point) checkpoints per layer.
    pub layers: Vec<(String, u64)>,
}

impl Cost {
    pub fn new() -> Cost {
        Cost::default()
    }

    /// Total cycles under the sequential (non-overlapped) tile model.
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.dram_cycles
    }

    /// Total cycles under the HLS dataflow (double-buffered) model:
    /// tile load/compute/store overlap, so the longer of the compute
    /// and DRAM streams bounds the phase. This whole-phase bound is the
    /// optimistic twin of [`Cost::total_cycles`] — the same granularity
    /// `sched::pipeline` uses for the FP/BP overlap — and is what the
    /// DSE scores when a candidate sets `HwConfig::overlap_tiles`
    /// (which in turn pays the doubled ping-pong buffers in
    /// `fpga::resources`).
    pub fn overlapped_cycles(&self) -> u64 {
        self.compute_cycles.max(self.dram_cycles)
    }

    /// Modeled cycles under the tile-latency model `cfg` selects:
    /// [`Cost::overlapped_cycles`] when `overlap_tiles` is set,
    /// [`Cost::total_cycles`] (the paper's sequential baseline)
    /// otherwise.
    pub fn cycles_under(&self, cfg: &HwConfig) -> u64 {
        if cfg.overlap_tiles {
            self.overlapped_cycles()
        } else {
            self.total_cycles()
        }
    }

    pub fn latency_ms(&self, freq_mhz: f64) -> f64 {
        self.total_cycles() as f64 / (freq_mhz * 1e3)
    }

    /// Close out a layer: record the running total under `label`.
    pub fn checkpoint(&mut self, label: &str) {
        self.layers.push((label.to_string(), self.total_cycles()));
    }

    /// Per-layer cycle deltas derived from the checkpoints.
    pub fn layer_breakdown(&self) -> Vec<(String, u64)> {
        let mut out = Vec::with_capacity(self.layers.len());
        let mut prev = 0u64;
        for (name, total) in &self.layers {
            out.push((name.clone(), total - prev));
            prev = *total;
        }
        out
    }

    pub fn merge(&mut self, other: &Cost) {
        self.compute_cycles += other.compute_cycles;
        self.dram_cycles += other.dram_cycles;
        self.dram_read_bytes += other.dram_read_bytes;
        self.dram_write_bytes += other.dram_write_bytes;
        self.dram_weight_bytes += other.dram_weight_bytes;
        self.dram_bursts += other.dram_bursts;
        self.macs += other.macs;
        let base: u64 = self.layers.last().map(|(_, t)| *t).unwrap_or(0);
        for (n, t) in &other.layers {
            self.layers.push((n.clone(), base + t));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_validate() {
        for cfg in [HwConfig::pynq_z2(), HwConfig::ultra96_v2(), HwConfig::zcu104()] {
            cfg.validate().unwrap();
        }
        assert_eq!(HwConfig::pynq_z2().conv_macs_parallel(), 16);
        assert_eq!(HwConfig::ultra96_v2().conv_macs_parallel(), 32);
        assert_eq!(HwConfig::zcu104().conv_macs_parallel(), 64);
        assert_eq!(HwConfig::zcu104().vmm_tile, 32);
    }

    #[test]
    fn bad_configs_rejected() {
        let mut c = HwConfig::pynq_z2();
        c.n_oh = 3; // does not divide tile_oh=8
        assert!(c.validate().is_err());
        let mut c = HwConfig::pynq_z2();
        c.vmm_tile = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn every_rejection_arm_is_typed() {
        let base = HwConfig::pynq_z2();
        // each zero-able knob reports itself by name
        let zeros: [(&str, fn(&mut HwConfig)); 10] = [
            ("n_oh", |c| c.n_oh = 0),
            ("n_ow", |c| c.n_ow = 0),
            ("tile_oh", |c| c.tile_oh = 0),
            ("tile_ow", |c| c.tile_ow = 0),
            ("tile_oc", |c| c.tile_oc = 0),
            ("tile_ic", |c| c.tile_ic = 0),
            ("vmm_tile", |c| c.vmm_tile = 0),
            ("vmm_in_tile", |c| c.vmm_in_tile = 0),
            ("axi_bytes_per_cycle", |c| c.axi_bytes_per_cycle = 0),
            ("pipeline_depth", |c| c.pipeline_depth = 0),
        ];
        for (knob, poke) in zeros {
            let mut c = base;
            poke(&mut c);
            assert_eq!(c.validate(), Err(ConfigError::ZeroKnob(knob)), "{knob}");
        }
        let mut c = base;
        c.n_oh = 3;
        assert_eq!(c.validate(), Err(ConfigError::UnrollRows { n_oh: 3, tile_oh: 8 }));
        let mut c = base;
        c.n_ow = 5;
        assert_eq!(c.validate(), Err(ConfigError::UnrollCols { n_ow: 5, tile_ow: 8 }));
        let mut c = base;
        c.vmm_tile = 24; // 256 % 24 != 0
        assert_eq!(
            c.validate(),
            Err(ConfigError::VmmIndivisible { vmm_tile: 24, vmm_in_tile: 256 })
        );
        // errors render a human-readable reason
        assert!(c.validate().unwrap_err().to_string().contains("vmm_tile=24"));
    }

    #[test]
    fn overlapped_cycles_bound_the_sequential_model() {
        let mut c = Cost::new();
        c.compute_cycles = 70;
        c.dram_cycles = 50;
        assert_eq!(c.overlapped_cycles(), 70);
        assert_eq!(c.total_cycles(), 120);
        let mut seq = HwConfig::pynq_z2();
        assert_eq!(c.cycles_under(&seq), 120);
        seq.overlap_tiles = true;
        assert_eq!(c.cycles_under(&seq), 70);
        // the dataflow bound is never worse and never better than 2x
        assert!(c.overlapped_cycles() <= c.total_cycles());
        assert!(c.total_cycles() <= 2 * c.overlapped_cycles());
    }

    #[test]
    fn cost_bookkeeping() {
        let mut c = Cost::new();
        c.compute_cycles = 100;
        c.dram_cycles = 50;
        c.checkpoint("a");
        c.compute_cycles += 30;
        c.checkpoint("b");
        assert_eq!(c.total_cycles(), 180);
        assert_eq!(c.layer_breakdown(), vec![("a".to_string(), 150), ("b".to_string(), 30)]);
        assert!((c.latency_ms(100.0) - 0.0018).abs() < 1e-12);

        let mut d = Cost::new();
        d.compute_cycles = 20;
        d.dram_weight_bytes = 64;
        d.checkpoint("c");
        c.merge(&d);
        assert_eq!(c.total_cycles(), 200);
        assert_eq!(c.layers.last().unwrap().1, 200);
        assert_eq!(c.dram_weight_bytes, 64);
    }

    #[test]
    fn word_bytes_follow_format() {
        let mut c = HwConfig::pynq_z2();
        assert_eq!(c.word_bytes(), 2);
        c.q = QFormat::new(8, 4);
        assert_eq!(c.word_bytes(), 1);
        c.q = QFormat::new(32, 16);
        assert_eq!(c.word_bytes(), 4);
    }
}
