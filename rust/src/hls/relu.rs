//! ReLU gradient unit (paper §III-D + Fig. 4): elementwise BP dataflow
//! for the three attribution methods, streaming over gradient tiles.
//!
//! The FP ReLU never appears as a standalone pass — it is fused into the
//! conv/VMM output store (see conv::Post / vmm relu_mask). The BP pass
//! streams gradient tiles through the method's dataflow. For saliency /
//! guided, the FP mask for conv layers is *recomputed from the DRAM
//! activation* (`mask == act > 0`) rather than stored — the paper §V
//! memory optimization — so the load pattern charges an activation read.

use super::{dram, Cost, HwConfig};
use crate::attribution::Method;

/// Where the positivity mask comes from during BP.
pub enum MaskSource<'a> {
    /// On-chip 1-bit mask (FC ReLU — the 128-bit BRAM mask).
    OnChip(&'a [bool]),
    /// Recompute from the post-ReLU activation stored in DRAM
    /// (conv ReLUs; charges the activation reload traffic).
    FromDram(&'a [i32]),
    /// No mask needed (deconvnet).
    None,
}

/// Apply the method's ReLU backward dataflow to a gradient tensor.
///
/// Allocate-and-call wrapper over [`backward_in_place`].
pub fn backward(
    cfg: &HwConfig,
    cost: &mut Cost,
    method: Method,
    g: &[i32],
    mask: MaskSource<'_>,
) -> Vec<i32> {
    let mut out = g.to_vec();
    backward_in_place(cfg, cost, method, &mut out, mask);
    out
}

/// The elementwise ReLU backward core, mutating the gradient in place —
/// the zero-allocation entry point the workspace-driven attribute path
/// uses (the hardware unit is in-place too: it streams the gradient
/// tile through the ALU lanes and writes it back).
pub fn backward_in_place(
    cfg: &HwConfig,
    cost: &mut Cost,
    method: Method,
    g: &mut [i32],
    mask: MaskSource<'_>,
) {
    let n = g.len();
    // gradient tile streams through the elementwise unit; throughput is
    // limited by the DRAM stream, one elem/cycle through the ALU lanes
    dram::read_contig(cfg, cost, n as u64);
    match (&mask, method) {
        (_, Method::Deconvnet) => {
            for v in g.iter_mut() {
                *v = (*v).max(0);
            }
        }
        (MaskSource::OnChip(m), _) => {
            assert_eq!(m.len(), n, "mask length mismatch");
            for (v, &b) in g.iter_mut().zip(m.iter()) {
                *v = method.relu_bwd_raw(b, *v);
            }
        }
        (MaskSource::FromDram(act), _) => {
            assert_eq!(act.len(), n, "activation length mismatch");
            // charge the activation reload (the §V trade: traffic, not BRAM)
            dram::read_contig(cfg, cost, n as u64);
            for (v, &a) in g.iter_mut().zip(act.iter()) {
                *v = method.relu_bwd_raw(a > 0, *v);
            }
        }
        (MaskSource::None, m) => panic!("method {m} requires a mask source"),
    }
    let lanes = cfg.conv_macs_parallel() as u64;
    cost.compute_cycles += (n as u64).div_ceil(lanes) + cfg.pipeline_depth;
    dram::write_contig(cfg, cost, n as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::QFormat;

    fn q(vals: &[f32]) -> Vec<i32> {
        let f = QFormat::paper16();
        vals.iter().map(|&v| f.from_f32(v)).collect()
    }

    #[test]
    fn saliency_uses_mask() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let g = q(&[1.0, -2.0, 3.0, -4.0]);
        let m = vec![true, true, false, false];
        let out = backward(&cfg, &mut c, Method::Saliency, &g, MaskSource::OnChip(&m));
        assert_eq!(out, vec![g[0], g[1], 0, 0]);
    }

    #[test]
    fn deconvnet_ignores_mask_entirely() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let g = q(&[1.0, -2.0, 3.0, -4.0]);
        let out = backward(&cfg, &mut c, Method::Deconvnet, &g, MaskSource::None);
        assert_eq!(out, vec![g[0], 0, g[2], 0]);
    }

    #[test]
    fn guided_combines_both() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let g = q(&[1.0, -2.0, 3.0, -4.0]);
        let m = vec![true, true, false, false];
        let out = backward(&cfg, &mut c, Method::Guided, &g, MaskSource::OnChip(&m));
        assert_eq!(out, vec![g[0], 0, 0, 0]);
    }

    #[test]
    fn dram_mask_recompute_equals_onchip() {
        let cfg = HwConfig::pynq_z2();
        let g = q(&[0.5, -0.5, 2.0, -2.0, 1.0]);
        // activation (post-relu, as in DRAM): zero where mask=false
        let act = q(&[0.7, 0.0, 1.2, 0.0, 0.0]);
        let m: Vec<bool> = act.iter().map(|&a| a > 0).collect();
        for method in [Method::Saliency, Method::Guided] {
            let mut c1 = Cost::new();
            let mut c2 = Cost::new();
            let a = backward(&cfg, &mut c1, method, &g, MaskSource::OnChip(&m));
            let b = backward(&cfg, &mut c2, method, &g, MaskSource::FromDram(&act));
            assert_eq!(a, b);
            // the DRAM variant pays an extra activation read
            assert!(c2.dram_read_bytes > c1.dram_read_bytes);
        }
    }

    #[test]
    #[should_panic(expected = "requires a mask source")]
    fn saliency_without_mask_panics() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        backward(&cfg, &mut c, Method::Saliency, &[1, 2], MaskSource::None);
    }
}
