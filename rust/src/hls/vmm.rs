//! Tiled vector-matrix-multiply engine for FC layers (paper §III-C/E).
//!
//! FP: y = W·x (+b), output-stationary over input tiles. BP: gx = Wᵀ·g,
//! the *same* block with the weight buffer loaded "in a transpose
//! manner from DRAM" — modeled as a strided (per-element-burst) load
//! pattern whose traffic the cost ledger charges accordingly.

use super::{dram, Cost, HwConfig};

/// FP fully-connected: `w` is [OUT,IN] row-major raw Q, `x` is [IN].
/// Returns `[OUT]`. If `relu_mask` is Some, ReLU is fused into the
/// output store and the positivity mask is written there (the FC ReLU
/// mask the paper keeps on-chip).
pub fn forward(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    x: &[i32],
    bias: Option<&[i32]>,
    mut relu_mask: Option<&mut Vec<bool>>,
) -> Vec<i32> {
    assert_eq!(w.len(), out_n * in_n);
    assert_eq!(x.len(), in_n);
    let q = cfg.q;
    let mut out = vec![0i32; out_n];
    let mut acc = vec![0i64; cfg.vmm_tile];

    let mut o0 = 0;
    while o0 < out_n {
        let to = cfg.vmm_tile.min(out_n - o0);
        acc[..to].fill(0);
        let mut i0 = 0;
        while i0 < in_n {
            let ti = cfg.vmm_in_tile.min(in_n - i0);
            // loads: x tile (contiguous), W tile (one burst per out row)
            dram::read_contig(cfg, cost, ti as u64);
            dram::read(cfg, cost, (to * ti * cfg.word_bytes()) as u64, to as u64);
            // MAC loop: vmm_tile parallel lanes over the output elements
            for o in 0..to {
                let row = (o0 + o) * in_n;
                let mut s = 0i64;
                for i in 0..ti {
                    s += w[row + i0 + i] as i64 * x[i0 + i] as i64;
                }
                acc[o] += s;
            }
            // cycles: ti iterations, `to` lanes unrolled (partial tiles
            // still occupy the full block)
            cost.compute_cycles += ti as u64 + cfg.pipeline_depth;
            cost.macs += (to * ti) as u64;
            i0 += ti;
        }
        for o in 0..to {
            let mut v = q.rescale_acc(acc[o]);
            if let Some(b) = bias {
                v = q.add(v, b[o0 + o]);
            }
            if let Some(m) = relu_mask.as_deref_mut() {
                m[o0 + o] = v > 0;
                if v < 0 {
                    v = 0;
                }
            }
            out[o0 + o] = v;
        }
        dram::write_contig(cfg, cost, to as u64);
        o0 += to;
    }
    out
}

/// BP fully-connected: gx = Wᵀ·g. Same compute block; the weight tile
/// is loaded transposed, which on a row-major DRAM layout costs one
/// burst per *element column* — the paper's modified access pattern
/// (§III-E "loaded in a transpose manner").
pub fn backward(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    g: &[i32],
) -> Vec<i32> {
    assert_eq!(w.len(), out_n * in_n);
    assert_eq!(g.len(), out_n);
    let q = cfg.q;
    let mut out = vec![0i32; in_n];
    let mut acc = vec![0i64; cfg.vmm_tile];

    let mut i0 = 0;
    while i0 < in_n {
        let ti = cfg.vmm_tile.min(in_n - i0); // output elements of BP
        acc[..ti].fill(0);
        let mut o0 = 0;
        while o0 < out_n {
            let to = cfg.vmm_in_tile.min(out_n - o0); // reduction extent
            dram::read_contig(cfg, cost, to as u64);
            // transpose load: W[o0..o0+to, i0..i0+ti] fetched column-major;
            // every element of a column is strided by in_n in DRAM, so the
            // fetch degenerates to one short burst per *row segment*
            // touched: `to` bursts (vs the FP path's `to`-rows-as-one-
            // tile pattern costing vmm_tile bursts) — the price of the
            // paper's transpose-manner access pattern
            dram::read(cfg, cost, (to * ti * cfg.word_bytes()) as u64, to as u64);
            for i in 0..ti {
                let mut s = 0i64;
                for o in 0..to {
                    s += w[(o0 + o) * in_n + i0 + i] as i64 * g[o0 + o] as i64;
                }
                acc[i] += s;
            }
            cost.compute_cycles += to as u64 + cfg.pipeline_depth;
            cost.macs += (to * ti) as u64;
            o0 += to;
        }
        for i in 0..ti {
            out[i0 + i] = q.rescale_acc(acc[i]);
        }
        dram::write_contig(cfg, cost, ti as u64);
        i0 += ti;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::{quantize_slice, QFormat};
    use crate::util::rng::Pcg32;

    fn rand_vec(rng: &mut Pcg32, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(lo, hi)).collect()
    }

    #[test]
    fn forward_matches_f64() {
        let mut rng = Pcg32::seeded(31);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 300);
        let wf = rand_vec(&mut rng, out_n * in_n, -0.1, 0.1);
        let xf = rand_vec(&mut rng, in_n, -1.0, 1.0);
        let bf = rand_vec(&mut rng, out_n, -0.5, 0.5);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let got = forward(
            &cfg,
            &mut cost,
            &quantize_slice(q, &wf),
            (out_n, in_n),
            &quantize_slice(q, &xf),
            Some(&quantize_slice(q, &bf)),
            None,
        );
        for o in 0..out_n {
            let want: f64 = (0..in_n)
                .map(|i| wf[o * in_n + i] as f64 * xf[i] as f64)
                .sum::<f64>()
                + bf[o] as f64;
            let g = q.to_f32(got[o]) as f64;
            assert!((g - want).abs() < 0.05, "o={o}: {g} vs {want}");
        }
        assert_eq!(cost.macs, (out_n * in_n) as u64);
    }

    #[test]
    fn backward_matches_transpose_product() {
        let mut rng = Pcg32::seeded(32);
        let q = QFormat::paper16();
        let (out_n, in_n) = (10, 128);
        let wf = rand_vec(&mut rng, out_n * in_n, -0.3, 0.3);
        let gf = rand_vec(&mut rng, out_n, -1.0, 1.0);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let got = backward(
            &cfg,
            &mut cost,
            &quantize_slice(q, &wf),
            (out_n, in_n),
            &quantize_slice(q, &gf),
        );
        for i in 0..in_n {
            let want: f64 = (0..out_n).map(|o| wf[o * in_n + i] as f64 * gf[o] as f64).sum();
            let g = q.to_f32(got[i]) as f64;
            assert!((g - want).abs() < 0.05, "i={i}: {g} vs {want}");
        }
    }

    #[test]
    fn relu_fusion_masks_negatives() {
        let q = QFormat::paper16();
        // W = -I (2x2), x = (1, -1) -> y = (-1, 1) -> relu (0, 1)
        let w = quantize_slice(q, &[-1.0, 0.0, 0.0, -1.0]);
        let x = quantize_slice(q, &[1.0, -1.0]);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let mut mask = vec![false; 2];
        let y = forward(&cfg, &mut cost, &w, (2, 2), &x, None, Some(&mut mask));
        assert_eq!(y, vec![0, q.from_f32(1.0)]);
        assert_eq!(mask, vec![false, true]);
    }

    #[test]
    fn transpose_load_charges_more_bursts() {
        let mut rng = Pcg32::seeded(33);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 128);
        let w = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let v = quantize_slice(q, &rand_vec(&mut rng, in_n, -1.0, 1.0));
        let g = quantize_slice(q, &rand_vec(&mut rng, out_n, -1.0, 1.0));
        let cfg = HwConfig::pynq_z2();
        let mut cf = Cost::new();
        let mut cb = Cost::new();
        forward(&cfg, &mut cf, &w, (out_n, in_n), &v, None, None);
        backward(&cfg, &mut cb, &w, (out_n, in_n), &g);
        // same weight bytes, different burst pattern (BP strided)
        assert_eq!(cf.macs, cb.macs);
        assert!(cb.dram_bursts > cf.dram_bursts, "{} vs {}", cb.dram_bursts, cf.dram_bursts);
    }

    #[test]
    fn vmm_tile_parallelism_in_cycles() {
        let mut rng = Pcg32::seeded(34);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 512);
        let w = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let x = quantize_slice(q, &rand_vec(&mut rng, in_n, -1.0, 1.0));
        let mut c16 = Cost::new();
        let mut c32 = Cost::new();
        forward(&HwConfig::with_unroll(4, 4, 16), &mut c16, &w, (out_n, in_n), &x, None, None);
        forward(&HwConfig::with_unroll(4, 4, 32), &mut c32, &w, (out_n, in_n), &x, None, None);
        assert_eq!(c16.macs, c32.macs);
        assert!(c32.compute_cycles < c16.compute_cycles);
    }
}
