//! Tiled vector-matrix-multiply engine for FC layers (paper §III-C/E).
//!
//! FP: y = W·x (+b), output-stationary over input tiles. BP: gx = Wᵀ·g,
//! the *same* block with the weight buffer loaded "in a transpose
//! manner from DRAM" — modeled as a strided (per-element-burst) load
//! pattern whose traffic the cost ledger charges accordingly.
//!
//! Both directions have batch-N entry points (`forward_batch`,
//! `backward_batch`) that fetch each weight tile once per batch; the
//! single-image functions are batch-of-one wrappers, so batched and
//! single execution are bit-exact by construction (DESIGN.md §Batching).

use super::{dram, Cost, HwConfig};

/// FP fully-connected: `w` is [OUT,IN] row-major raw Q, `x` is [IN].
/// Returns `[OUT]`. If `relu_mask` is Some, ReLU is fused into the
/// output store and the positivity mask is written there (the FC ReLU
/// mask the paper keeps on-chip).
///
/// Thin wrapper over [`forward_batch`] with a batch of one.
pub fn forward(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    dims: (usize, usize),
    x: &[i32],
    bias: Option<&[i32]>,
    relu_mask: Option<&mut Vec<bool>>,
) -> Vec<i32> {
    let (out_n, _) = dims;
    let mut masks = relu_mask.as_ref().map(|_| vec![vec![false; out_n]; 1]);
    let mut outs = forward_batch(cfg, cost, w, dims, &[x], bias, masks.as_mut());
    if let (Some(dst), Some(mut src)) = (relu_mask, masks) {
        *dst = src.pop().expect("batch of one");
    }
    outs.pop().expect("batch of one")
}

/// Batch-N FP fully-connected (the tentpole batching path): each weight
/// tile is fetched from DRAM once per batch and multiplied against every
/// image's input tile while it sits in the on-chip buffer. Per-image
/// arithmetic is independent (one accumulator lane group per image, same
/// order as batch=1), so results are bit-exact with [`forward`]. When
/// `relu_masks` is Some it must hold one `vec![false; out_n]` per image.
pub fn forward_batch(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    xs: &[&[i32]],
    bias: Option<&[i32]>,
    mut relu_masks: Option<&mut Vec<Vec<bool>>>,
) -> Vec<Vec<i32>> {
    let nb = xs.len();
    assert!(nb > 0, "empty batch");
    assert_eq!(w.len(), out_n * in_n);
    for x in xs {
        assert_eq!(x.len(), in_n);
    }
    if let Some(ms) = relu_masks.as_deref_mut() {
        assert_eq!(ms.len(), nb, "one relu mask per image");
        for m in ms.iter() {
            assert_eq!(m.len(), out_n, "mask length mismatch");
        }
    }
    let q = cfg.q;
    let mut outs = vec![vec![0i32; out_n]; nb];
    let mut acc = vec![0i64; nb * cfg.vmm_tile];

    let mut o0 = 0;
    while o0 < out_n {
        let to = cfg.vmm_tile.min(out_n - o0);
        acc.fill(0);
        let mut i0 = 0;
        while i0 < in_n {
            let ti = cfg.vmm_in_tile.min(in_n - i0);
            // loads: x tile (contiguous) per image, W tile (one burst per
            // out row) ONCE per batch — the batching win
            for _ in 0..nb {
                dram::read_contig(cfg, cost, ti as u64);
            }
            dram::read_weights(cfg, cost, (to * ti * cfg.word_bytes()) as u64, to as u64);
            // MAC loop: vmm_tile parallel lanes over the output elements
            for (b, x) in xs.iter().enumerate() {
                let accb = &mut acc[b * cfg.vmm_tile..b * cfg.vmm_tile + to];
                for (o, a) in accb.iter_mut().enumerate() {
                    let row = (o0 + o) * in_n;
                    let mut s = 0i64;
                    for i in 0..ti {
                        s += w[row + i0 + i] as i64 * x[i0 + i] as i64;
                    }
                    *a += s;
                }
            }
            // cycles: ti iterations per image, `to` lanes unrolled (partial
            // tiles still occupy the full block); one fill per tile
            cost.compute_cycles += nb as u64 * ti as u64 + cfg.pipeline_depth;
            cost.macs += (nb * to * ti) as u64;
            i0 += ti;
        }
        for b in 0..nb {
            for o in 0..to {
                let mut v = q.rescale_acc(acc[b * cfg.vmm_tile + o]);
                if let Some(bs) = bias {
                    v = q.add(v, bs[o0 + o]);
                }
                if let Some(ms) = relu_masks.as_deref_mut() {
                    ms[b][o0 + o] = v > 0;
                    if v < 0 {
                        v = 0;
                    }
                }
                outs[b][o0 + o] = v;
            }
            dram::write_contig(cfg, cost, to as u64);
        }
        o0 += to;
    }
    outs
}

/// BP fully-connected: gx = Wᵀ·g. Same compute block; the weight tile
/// is loaded transposed, which on a row-major DRAM layout costs one
/// burst per *element column* — the paper's modified access pattern
/// (§III-E "loaded in a transpose manner").
pub fn backward(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    dims: (usize, usize),
    g: &[i32],
) -> Vec<i32> {
    backward_batch(cfg, cost, w, dims, &[g]).pop().expect("batch of one")
}

/// Batch-N BP fully-connected: gx = Wᵀ·g for every gradient in the
/// batch, with each (transpose-manner) weight tile fetched once per
/// batch. Bit-exact with [`backward`] per image.
pub fn backward_batch(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    gs: &[&[i32]],
) -> Vec<Vec<i32>> {
    let nb = gs.len();
    assert!(nb > 0, "empty batch");
    assert_eq!(w.len(), out_n * in_n);
    for g in gs {
        assert_eq!(g.len(), out_n);
    }
    let q = cfg.q;
    let mut outs = vec![vec![0i32; in_n]; nb];
    let mut acc = vec![0i64; nb * cfg.vmm_tile];

    let mut i0 = 0;
    while i0 < in_n {
        let ti = cfg.vmm_tile.min(in_n - i0); // output elements of BP
        acc.fill(0);
        let mut o0 = 0;
        while o0 < out_n {
            let to = cfg.vmm_in_tile.min(out_n - o0); // reduction extent
            for _ in 0..nb {
                dram::read_contig(cfg, cost, to as u64);
            }
            // transpose load: W[o0..o0+to, i0..i0+ti] fetched column-major;
            // every element of a column is strided by in_n in DRAM, so the
            // fetch degenerates to one short burst per *row segment*
            // touched: `to` bursts (vs the FP path's `to`-rows-as-one-
            // tile pattern costing vmm_tile bursts) — the price of the
            // paper's transpose-manner access pattern. Fetched once per
            // batch.
            dram::read_weights(cfg, cost, (to * ti * cfg.word_bytes()) as u64, to as u64);
            for (b, g) in gs.iter().enumerate() {
                let accb = &mut acc[b * cfg.vmm_tile..b * cfg.vmm_tile + ti];
                for (i, a) in accb.iter_mut().enumerate() {
                    let mut s = 0i64;
                    for o in 0..to {
                        s += w[(o0 + o) * in_n + i0 + i] as i64 * g[o0 + o] as i64;
                    }
                    *a += s;
                }
            }
            cost.compute_cycles += nb as u64 * to as u64 + cfg.pipeline_depth;
            cost.macs += (nb * to * ti) as u64;
            o0 += to;
        }
        for (b, out) in outs.iter_mut().enumerate() {
            for i in 0..ti {
                out[i0 + i] = q.rescale_acc(acc[b * cfg.vmm_tile + i]);
            }
            dram::write_contig(cfg, cost, ti as u64);
        }
        i0 += ti;
    }
    outs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::{quantize_slice, QFormat};
    use crate::util::rng::Pcg32;

    fn rand_vec(rng: &mut Pcg32, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(lo, hi)).collect()
    }

    #[test]
    fn forward_matches_f64() {
        let mut rng = Pcg32::seeded(31);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 300);
        let wf = rand_vec(&mut rng, out_n * in_n, -0.1, 0.1);
        let xf = rand_vec(&mut rng, in_n, -1.0, 1.0);
        let bf = rand_vec(&mut rng, out_n, -0.5, 0.5);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let got = forward(
            &cfg,
            &mut cost,
            &quantize_slice(q, &wf),
            (out_n, in_n),
            &quantize_slice(q, &xf),
            Some(&quantize_slice(q, &bf)),
            None,
        );
        for o in 0..out_n {
            let want: f64 = (0..in_n)
                .map(|i| wf[o * in_n + i] as f64 * xf[i] as f64)
                .sum::<f64>()
                + bf[o] as f64;
            let g = q.to_f32(got[o]) as f64;
            assert!((g - want).abs() < 0.05, "o={o}: {g} vs {want}");
        }
        assert_eq!(cost.macs, (out_n * in_n) as u64);
    }

    #[test]
    fn backward_matches_transpose_product() {
        let mut rng = Pcg32::seeded(32);
        let q = QFormat::paper16();
        let (out_n, in_n) = (10, 128);
        let wf = rand_vec(&mut rng, out_n * in_n, -0.3, 0.3);
        let gf = rand_vec(&mut rng, out_n, -1.0, 1.0);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let got = backward(
            &cfg,
            &mut cost,
            &quantize_slice(q, &wf),
            (out_n, in_n),
            &quantize_slice(q, &gf),
        );
        for i in 0..in_n {
            let want: f64 = (0..out_n).map(|o| wf[o * in_n + i] as f64 * gf[o] as f64).sum();
            let g = q.to_f32(got[i]) as f64;
            assert!((g - want).abs() < 0.05, "i={i}: {g} vs {want}");
        }
    }

    #[test]
    fn relu_fusion_masks_negatives() {
        let q = QFormat::paper16();
        // W = -I (2x2), x = (1, -1) -> y = (-1, 1) -> relu (0, 1)
        let w = quantize_slice(q, &[-1.0, 0.0, 0.0, -1.0]);
        let x = quantize_slice(q, &[1.0, -1.0]);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let mut mask = vec![false; 2];
        let y = forward(&cfg, &mut cost, &w, (2, 2), &x, None, Some(&mut mask));
        assert_eq!(y, vec![0, q.from_f32(1.0)]);
        assert_eq!(mask, vec![false, true]);
    }

    #[test]
    fn transpose_load_charges_more_bursts() {
        let mut rng = Pcg32::seeded(33);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 128);
        let w = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let v = quantize_slice(q, &rand_vec(&mut rng, in_n, -1.0, 1.0));
        let g = quantize_slice(q, &rand_vec(&mut rng, out_n, -1.0, 1.0));
        let cfg = HwConfig::pynq_z2();
        let mut cf = Cost::new();
        let mut cb = Cost::new();
        forward(&cfg, &mut cf, &w, (out_n, in_n), &v, None, None);
        backward(&cfg, &mut cb, &w, (out_n, in_n), &g);
        // same weight bytes, different burst pattern (BP strided)
        assert_eq!(cf.macs, cb.macs);
        assert!(cb.dram_bursts > cf.dram_bursts, "{} vs {}", cb.dram_bursts, cf.dram_bursts);
    }

    #[test]
    fn batch_matches_single_and_amortizes_weights() {
        let mut rng = Pcg32::seeded(37);
        let q = QFormat::paper16();
        let (out_n, in_n) = (40, 300);
        let wf = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let bf = quantize_slice(q, &rand_vec(&mut rng, out_n, -0.5, 0.5));
        let xs: Vec<Vec<i32>> = (0..4)
            .map(|_| quantize_slice(q, &rand_vec(&mut rng, in_n, -1.0, 1.0)))
            .collect();
        let cfg = HwConfig::pynq_z2();
        let refs: Vec<&[i32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut cb = Cost::new();
        let mut batch_masks = vec![vec![false; out_n]; 4];
        let batch = forward_batch(
            &cfg,
            &mut cb,
            &wf,
            (out_n, in_n),
            &refs,
            Some(&bf),
            Some(&mut batch_masks),
        );
        for (i, x) in xs.iter().enumerate() {
            let mut cs = Cost::new();
            let mut mask = vec![false; out_n];
            let single =
                forward(&cfg, &mut cs, &wf, (out_n, in_n), x, Some(&bf), Some(&mut mask));
            assert_eq!(batch[i], single, "image {i} fp diverged");
            assert_eq!(batch_masks[i], mask, "image {i} mask diverged");
            assert_eq!(cb.dram_weight_bytes, cs.dram_weight_bytes);
        }

        // BP duals
        let gs: Vec<Vec<i32>> = (0..4)
            .map(|_| quantize_slice(q, &rand_vec(&mut rng, out_n, -1.0, 1.0)))
            .collect();
        let grefs: Vec<&[i32]> = gs.iter().map(|v| v.as_slice()).collect();
        let mut cbb = Cost::new();
        let bb = backward_batch(&cfg, &mut cbb, &wf, (out_n, in_n), &grefs);
        for (i, g) in gs.iter().enumerate() {
            let mut cs = Cost::new();
            let single = backward(&cfg, &mut cs, &wf, (out_n, in_n), g);
            assert_eq!(bb[i], single, "image {i} bp diverged");
            assert_eq!(cbb.dram_weight_bytes, cs.dram_weight_bytes);
        }
    }

    #[test]
    fn vmm_tile_parallelism_in_cycles() {
        let mut rng = Pcg32::seeded(34);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 512);
        let w = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let x = quantize_slice(q, &rand_vec(&mut rng, in_n, -1.0, 1.0));
        let mut c16 = Cost::new();
        let mut c32 = Cost::new();
        forward(&HwConfig::with_unroll(4, 4, 16), &mut c16, &w, (out_n, in_n), &x, None, None);
        forward(&HwConfig::with_unroll(4, 4, 32), &mut c32, &w, (out_n, in_n), &x, None, None);
        assert_eq!(c16.macs, c32.macs);
        assert!(c32.compute_cycles < c16.compute_cycles);
    }
}
