//! Tiled vector-matrix-multiply engine for FC layers (paper §III-C/E).
//!
//! FP: y = W·x (+b), output-stationary over input tiles. BP: gx = Wᵀ·g,
//! the *same* block with the weight buffer loaded "in a transpose
//! manner from DRAM" — modeled as a strided (per-element-burst) load
//! pattern whose traffic the cost ledger charges accordingly.
//!
//! Both directions have batch-N `_into` cores ([`forward_batch_into`],
//! [`backward_batch_into`]) that fetch each weight tile once per batch,
//! work in caller-provided flat slabs (zero steady-state allocations),
//! and shard the per-image loops across scoped threads — each image
//! owns a disjoint accumulator/output region and runs the batch=1 loop
//! order, so sharding is bit-exact by construction and the `Cost`
//! ledger (charged by a separate single-threaded pass) is
//! shard-invariant. The `Vec`-returning signatures are thin
//! allocate-and-call wrappers (DESIGN.md §Batching, §Plan/Workspace).

use super::{dram, Cost, EngineScratch, HwConfig};

/// FP fully-connected: `w` is [OUT,IN] row-major raw Q, `x` is [IN].
/// Returns `[OUT]`. If `relu_mask` is Some, ReLU is fused into the
/// output store and the positivity mask is written there (the FC ReLU
/// mask the paper keeps on-chip).
///
/// Thin wrapper over [`forward_batch`] with a batch of one.
pub fn forward(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    dims: (usize, usize),
    x: &[i32],
    bias: Option<&[i32]>,
    relu_mask: Option<&mut Vec<bool>>,
) -> Vec<i32> {
    let (out_n, _) = dims;
    let mut masks = relu_mask.as_ref().map(|_| vec![vec![false; out_n]; 1]);
    let mut outs = forward_batch(cfg, cost, w, dims, &[x], bias, masks.as_mut());
    if let (Some(dst), Some(mut src)) = (relu_mask, masks) {
        *dst = src.pop().expect("batch of one");
    }
    outs.pop().expect("batch of one")
}

/// Batch-N FP fully-connected: allocate-and-call wrapper over
/// [`forward_batch_into`]. When `relu_masks` is Some it must hold one
/// `vec![false; out_n]` per image.
pub fn forward_batch(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    xs: &[&[i32]],
    bias: Option<&[i32]>,
    relu_masks: Option<&mut Vec<Vec<bool>>>,
) -> Vec<Vec<i32>> {
    let nb = xs.len();
    assert!(nb > 0, "empty batch");
    if let Some(ms) = relu_masks.as_deref() {
        assert_eq!(ms.len(), nb, "one relu mask per image");
        for m in ms.iter() {
            assert_eq!(m.len(), out_n, "mask length mismatch");
        }
    }
    let mut flat = Vec::with_capacity(nb * in_n);
    for x in xs {
        assert_eq!(x.len(), in_n);
        flat.extend_from_slice(x);
    }
    let mut scratch = EngineScratch::new();
    let mut outs = Vec::new();
    let mut mask_flat = relu_masks.as_ref().map(|_| vec![false; nb * out_n]);
    forward_batch_into(
        cfg,
        cost,
        &mut scratch,
        w,
        (out_n, in_n),
        &flat,
        nb,
        bias,
        mask_flat.as_deref_mut(),
        1,
        &mut outs,
    );
    if let (Some(ms), Some(flat_m)) = (relu_masks, mask_flat) {
        for (b, m) in ms.iter_mut().enumerate() {
            m.copy_from_slice(&flat_m[b * out_n..(b + 1) * out_n]);
        }
    }
    (0..nb).map(|b| outs[b * out_n..(b + 1) * out_n].to_vec()).collect()
}

/// Batch-N FP fully-connected core: each weight tile is fetched from
/// DRAM once per batch and multiplied against every image's input tile
/// while it sits in the on-chip buffer. `xs` is a flat [nb, IN] slab;
/// outputs land in the reusable `outs` slab ([nb, OUT]); `masks`, when
/// present, is a flat [nb, OUT] slab. Cost pass + image-sharded compute
/// pass — bit-exact with [`forward`] for any shard count.
#[allow(clippy::too_many_arguments)]
pub fn forward_batch_into(
    cfg: &HwConfig,
    cost: &mut Cost,
    scratch: &mut EngineScratch,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    xs: &[i32],
    nb: usize,
    bias: Option<&[i32]>,
    masks: Option<&mut [bool]>,
    shards: usize,
    outs: &mut Vec<i32>,
) {
    assert!(nb > 0, "empty batch");
    assert_eq!(w.len(), out_n * in_n);
    assert_eq!(xs.len(), nb * in_n);
    if let Some(ms) = masks.as_deref() {
        assert_eq!(ms.len(), nb * out_n, "mask slab length mismatch");
    }
    outs.resize(nb * out_n, 0);
    scratch.acc.resize(nb * cfg.vmm_tile, 0);

    // --- cost pass ----------------------------------------------------
    let mut o0 = 0;
    while o0 < out_n {
        let to = cfg.vmm_tile.min(out_n - o0);
        let mut i0 = 0;
        while i0 < in_n {
            let ti = cfg.vmm_in_tile.min(in_n - i0);
            // loads: x tile (contiguous) per image, W tile (one burst
            // per out row) ONCE per batch — the batching win
            for _ in 0..nb {
                dram::read_contig(cfg, cost, ti as u64);
            }
            dram::read_weights(cfg, cost, (to * ti * cfg.word_bytes()) as u64, to as u64);
            // cycles: ti iterations per image, `to` lanes unrolled
            // (partial tiles still occupy the full block); one fill per
            // tile
            cost.compute_cycles += nb as u64 * ti as u64 + cfg.pipeline_depth;
            cost.macs += (nb * to * ti) as u64;
            i0 += ti;
        }
        for _ in 0..nb {
            dram::write_contig(cfg, cost, to as u64);
        }
        o0 += to;
    }

    // --- compute pass: shard the batch across threads -----------------
    let shards = shards.clamp(1, nb);
    let masks: &mut [bool] = masks.unwrap_or(&mut []);
    if shards == 1 {
        fwd_range(cfg, nb, w, (out_n, in_n), xs, bias, &mut scratch.acc, outs, masks);
    } else {
        std::thread::scope(|sc| {
            let mut acc: &mut [i64] = &mut scratch.acc;
            let mut o: &mut [i32] = outs;
            let mut m: &mut [bool] = masks;
            let mask_stride = if m.is_empty() { 0 } else { out_n };
            let mut lo = 0;
            for t in 0..shards {
                let hi = (t + 1) * nb / shards;
                let n = hi - lo;
                let tmp = acc;
                let (acc_t, rest) = tmp.split_at_mut(n * cfg.vmm_tile);
                acc = rest;
                let tmp = o;
                let (o_t, rest) = tmp.split_at_mut(n * out_n);
                o = rest;
                let tmp = m;
                let (m_t, rest) = tmp.split_at_mut(n * mask_stride);
                m = rest;
                let xs_t = &xs[lo * in_n..hi * in_n];
                sc.spawn(move || {
                    fwd_range(cfg, n, w, (out_n, in_n), xs_t, bias, acc_t, o_t, m_t);
                });
                lo = hi;
            }
        });
    }
}

/// FP compute pass over a contiguous image range (per-image loop order
/// identical to batch=1 — sharding is bit-exact).
#[allow(clippy::too_many_arguments)]
fn fwd_range(
    cfg: &HwConfig,
    nb: usize,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    xs: &[i32],
    bias: Option<&[i32]>,
    acc: &mut [i64],
    outs: &mut [i32],
    masks: &mut [bool],
) {
    let q = cfg.q;
    for b in 0..nb {
        let x = &xs[b * in_n..(b + 1) * in_n];
        let accb = &mut acc[b * cfg.vmm_tile..(b + 1) * cfg.vmm_tile];
        let ob = &mut outs[b * out_n..(b + 1) * out_n];
        let mut o0 = 0;
        while o0 < out_n {
            let to = cfg.vmm_tile.min(out_n - o0);
            accb[..to].fill(0);
            let mut i0 = 0;
            while i0 < in_n {
                let ti = cfg.vmm_in_tile.min(in_n - i0);
                // MAC loop: vmm_tile parallel lanes over the outputs
                for (o, a) in accb[..to].iter_mut().enumerate() {
                    let row = (o0 + o) * in_n;
                    let mut s = 0i64;
                    for i in 0..ti {
                        s += w[row + i0 + i] as i64 * x[i0 + i] as i64;
                    }
                    *a += s;
                }
                i0 += ti;
            }
            for o in 0..to {
                let mut v = q.rescale_acc(accb[o]);
                if let Some(bs) = bias {
                    v = q.add(v, bs[o0 + o]);
                }
                if !masks.is_empty() {
                    masks[b * out_n + o0 + o] = v > 0;
                    if v < 0 {
                        v = 0;
                    }
                }
                ob[o0 + o] = v;
            }
            o0 += to;
        }
    }
}

/// BP fully-connected: gx = Wᵀ·g. Same compute block; the weight tile
/// is loaded transposed, which on a row-major DRAM layout costs one
/// burst per *element column* — the paper's modified access pattern
/// (§III-E "loaded in a transpose manner").
pub fn backward(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    dims: (usize, usize),
    g: &[i32],
) -> Vec<i32> {
    backward_batch(cfg, cost, w, dims, &[g]).pop().expect("batch of one")
}

/// Batch-N BP fully-connected: allocate-and-call wrapper over
/// [`backward_batch_into`]. Bit-exact with [`backward`] per image.
pub fn backward_batch(
    cfg: &HwConfig,
    cost: &mut Cost,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    gs: &[&[i32]],
) -> Vec<Vec<i32>> {
    let nb = gs.len();
    assert!(nb > 0, "empty batch");
    let mut flat = Vec::with_capacity(nb * out_n);
    for g in gs {
        assert_eq!(g.len(), out_n);
        flat.extend_from_slice(g);
    }
    let mut scratch = EngineScratch::new();
    let mut outs = Vec::new();
    backward_batch_into(cfg, cost, &mut scratch, w, (out_n, in_n), &flat, nb, 1, &mut outs);
    (0..nb).map(|b| outs[b * in_n..(b + 1) * in_n].to_vec()).collect()
}

/// Batch-N BP fully-connected core: gx = Wᵀ·g for every gradient in
/// the flat [nb, OUT] slab, with each (transpose-manner) weight tile
/// fetched once per batch; results land in the reusable [nb, IN] slab.
/// Cost pass + image-sharded compute pass — bit-exact with
/// [`backward`] for any shard count.
#[allow(clippy::too_many_arguments)]
pub fn backward_batch_into(
    cfg: &HwConfig,
    cost: &mut Cost,
    scratch: &mut EngineScratch,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    gs: &[i32],
    nb: usize,
    shards: usize,
    outs: &mut Vec<i32>,
) {
    assert!(nb > 0, "empty batch");
    assert_eq!(w.len(), out_n * in_n);
    assert_eq!(gs.len(), nb * out_n);
    outs.resize(nb * in_n, 0);
    scratch.acc.resize(nb * cfg.vmm_tile, 0);

    // --- cost pass ----------------------------------------------------
    let mut i0 = 0;
    while i0 < in_n {
        let ti = cfg.vmm_tile.min(in_n - i0); // output elements of BP
        let mut o0 = 0;
        while o0 < out_n {
            let to = cfg.vmm_in_tile.min(out_n - o0); // reduction extent
            for _ in 0..nb {
                dram::read_contig(cfg, cost, to as u64);
            }
            // transpose load: W[o0..o0+to, i0..i0+ti] fetched column-
            // major; every element of a column is strided by in_n in
            // DRAM, so the fetch degenerates to one short burst per
            // *row segment* touched: `to` bursts (vs the FP path's
            // `to`-rows-as-one-tile pattern costing vmm_tile bursts) —
            // the price of the paper's transpose-manner access pattern.
            // Fetched once per batch.
            dram::read_weights(cfg, cost, (to * ti * cfg.word_bytes()) as u64, to as u64);
            cost.compute_cycles += nb as u64 * to as u64 + cfg.pipeline_depth;
            cost.macs += (nb * to * ti) as u64;
            o0 += to;
        }
        for _ in 0..nb {
            dram::write_contig(cfg, cost, ti as u64);
        }
        i0 += ti;
    }

    // --- compute pass: shard the batch across threads -----------------
    let shards = shards.clamp(1, nb);
    if shards == 1 {
        bwd_range(cfg, nb, w, (out_n, in_n), gs, &mut scratch.acc, outs);
    } else {
        std::thread::scope(|sc| {
            let mut acc: &mut [i64] = &mut scratch.acc;
            let mut o: &mut [i32] = outs;
            let mut lo = 0;
            for t in 0..shards {
                let hi = (t + 1) * nb / shards;
                let n = hi - lo;
                let tmp = acc;
                let (acc_t, rest) = tmp.split_at_mut(n * cfg.vmm_tile);
                acc = rest;
                let tmp = o;
                let (o_t, rest) = tmp.split_at_mut(n * in_n);
                o = rest;
                let gs_t = &gs[lo * out_n..hi * out_n];
                sc.spawn(move || {
                    bwd_range(cfg, n, w, (out_n, in_n), gs_t, acc_t, o_t);
                });
                lo = hi;
            }
        });
    }
}

/// BP compute pass over a contiguous image range.
fn bwd_range(
    cfg: &HwConfig,
    nb: usize,
    w: &[i32],
    (out_n, in_n): (usize, usize),
    gs: &[i32],
    acc: &mut [i64],
    outs: &mut [i32],
) {
    let q = cfg.q;
    for b in 0..nb {
        let g = &gs[b * out_n..(b + 1) * out_n];
        let accb = &mut acc[b * cfg.vmm_tile..(b + 1) * cfg.vmm_tile];
        let ob = &mut outs[b * in_n..(b + 1) * in_n];
        let mut i0 = 0;
        while i0 < in_n {
            let ti = cfg.vmm_tile.min(in_n - i0);
            accb[..ti].fill(0);
            let mut o0 = 0;
            while o0 < out_n {
                let to = cfg.vmm_in_tile.min(out_n - o0);
                for (i, a) in accb[..ti].iter_mut().enumerate() {
                    let mut s = 0i64;
                    for o in 0..to {
                        s += w[(o0 + o) * in_n + i0 + i] as i64 * g[o0 + o] as i64;
                    }
                    *a += s;
                }
                o0 += to;
            }
            for i in 0..ti {
                ob[i0 + i] = q.rescale_acc(accb[i]);
            }
            i0 += ti;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::{quantize_slice, QFormat};
    use crate::util::rng::Pcg32;

    fn rand_vec(rng: &mut Pcg32, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(lo, hi)).collect()
    }

    #[test]
    fn forward_matches_f64() {
        let mut rng = Pcg32::seeded(31);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 300);
        let wf = rand_vec(&mut rng, out_n * in_n, -0.1, 0.1);
        let xf = rand_vec(&mut rng, in_n, -1.0, 1.0);
        let bf = rand_vec(&mut rng, out_n, -0.5, 0.5);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let got = forward(
            &cfg,
            &mut cost,
            &quantize_slice(q, &wf),
            (out_n, in_n),
            &quantize_slice(q, &xf),
            Some(&quantize_slice(q, &bf)),
            None,
        );
        for o in 0..out_n {
            let want: f64 = (0..in_n)
                .map(|i| wf[o * in_n + i] as f64 * xf[i] as f64)
                .sum::<f64>()
                + bf[o] as f64;
            let g = q.to_f32(got[o]) as f64;
            assert!((g - want).abs() < 0.05, "o={o}: {g} vs {want}");
        }
        assert_eq!(cost.macs, (out_n * in_n) as u64);
    }

    #[test]
    fn backward_matches_transpose_product() {
        let mut rng = Pcg32::seeded(32);
        let q = QFormat::paper16();
        let (out_n, in_n) = (10, 128);
        let wf = rand_vec(&mut rng, out_n * in_n, -0.3, 0.3);
        let gf = rand_vec(&mut rng, out_n, -1.0, 1.0);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let got = backward(
            &cfg,
            &mut cost,
            &quantize_slice(q, &wf),
            (out_n, in_n),
            &quantize_slice(q, &gf),
        );
        for i in 0..in_n {
            let want: f64 = (0..out_n).map(|o| wf[o * in_n + i] as f64 * gf[o] as f64).sum();
            let g = q.to_f32(got[i]) as f64;
            assert!((g - want).abs() < 0.05, "i={i}: {g} vs {want}");
        }
    }

    #[test]
    fn relu_fusion_masks_negatives() {
        let q = QFormat::paper16();
        // W = -I (2x2), x = (1, -1) -> y = (-1, 1) -> relu (0, 1)
        let w = quantize_slice(q, &[-1.0, 0.0, 0.0, -1.0]);
        let x = quantize_slice(q, &[1.0, -1.0]);
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let mut mask = vec![false; 2];
        let y = forward(&cfg, &mut cost, &w, (2, 2), &x, None, Some(&mut mask));
        assert_eq!(y, vec![0, q.from_f32(1.0)]);
        assert_eq!(mask, vec![false, true]);
    }

    #[test]
    fn transpose_load_charges_more_bursts() {
        let mut rng = Pcg32::seeded(33);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 128);
        let w = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let v = quantize_slice(q, &rand_vec(&mut rng, in_n, -1.0, 1.0));
        let g = quantize_slice(q, &rand_vec(&mut rng, out_n, -1.0, 1.0));
        let cfg = HwConfig::pynq_z2();
        let mut cf = Cost::new();
        let mut cb = Cost::new();
        forward(&cfg, &mut cf, &w, (out_n, in_n), &v, None, None);
        backward(&cfg, &mut cb, &w, (out_n, in_n), &g);
        // same weight bytes, different burst pattern (BP strided)
        assert_eq!(cf.macs, cb.macs);
        assert!(cb.dram_bursts > cf.dram_bursts, "{} vs {}", cb.dram_bursts, cf.dram_bursts);
    }

    #[test]
    fn batch_matches_single_and_amortizes_weights() {
        let mut rng = Pcg32::seeded(37);
        let q = QFormat::paper16();
        let (out_n, in_n) = (40, 300);
        let wf = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let bf = quantize_slice(q, &rand_vec(&mut rng, out_n, -0.5, 0.5));
        let xs: Vec<Vec<i32>> = (0..4)
            .map(|_| quantize_slice(q, &rand_vec(&mut rng, in_n, -1.0, 1.0)))
            .collect();
        let cfg = HwConfig::pynq_z2();
        let refs: Vec<&[i32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut cb = Cost::new();
        let mut batch_masks = vec![vec![false; out_n]; 4];
        let batch = forward_batch(
            &cfg,
            &mut cb,
            &wf,
            (out_n, in_n),
            &refs,
            Some(&bf),
            Some(&mut batch_masks),
        );
        for (i, x) in xs.iter().enumerate() {
            let mut cs = Cost::new();
            let mut mask = vec![false; out_n];
            let single =
                forward(&cfg, &mut cs, &wf, (out_n, in_n), x, Some(&bf), Some(&mut mask));
            assert_eq!(batch[i], single, "image {i} fp diverged");
            assert_eq!(batch_masks[i], mask, "image {i} mask diverged");
            assert_eq!(cb.dram_weight_bytes, cs.dram_weight_bytes);
        }

        // BP duals
        let gs: Vec<Vec<i32>> = (0..4)
            .map(|_| quantize_slice(q, &rand_vec(&mut rng, out_n, -1.0, 1.0)))
            .collect();
        let grefs: Vec<&[i32]> = gs.iter().map(|v| v.as_slice()).collect();
        let mut cbb = Cost::new();
        let bb = backward_batch(&cfg, &mut cbb, &wf, (out_n, in_n), &grefs);
        for (i, g) in gs.iter().enumerate() {
            let mut cs = Cost::new();
            let single = backward(&cfg, &mut cs, &wf, (out_n, in_n), g);
            assert_eq!(bb[i], single, "image {i} bp diverged");
            assert_eq!(cbb.dram_weight_bytes, cs.dram_weight_bytes);
        }
    }

    #[test]
    fn sharded_vmm_bit_exact_and_cost_invariant() {
        let mut rng = Pcg32::seeded(61);
        let q = QFormat::paper16();
        let (out_n, in_n) = (40, 300);
        let nb = 5;
        let w = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let b = quantize_slice(q, &rand_vec(&mut rng, out_n, -0.5, 0.5));
        let xs = quantize_slice(q, &rand_vec(&mut rng, nb * in_n, -1.0, 1.0));
        let gs = quantize_slice(q, &rand_vec(&mut rng, nb * out_n, -1.0, 1.0));
        let cfg = HwConfig::pynq_z2();

        let fwd = |shards: usize| -> (Cost, Vec<i32>, Vec<bool>) {
            let mut cost = Cost::new();
            let mut out = Vec::new();
            let mut mask = vec![false; nb * out_n];
            forward_batch_into(
                &cfg,
                &mut cost,
                &mut EngineScratch::new(),
                &w,
                (out_n, in_n),
                &xs,
                nb,
                Some(&b),
                Some(&mut mask),
                shards,
                &mut out,
            );
            (cost, out, mask)
        };
        let bwd = |shards: usize| -> (Cost, Vec<i32>) {
            let mut cost = Cost::new();
            let mut out = Vec::new();
            backward_batch_into(
                &cfg,
                &mut cost,
                &mut EngineScratch::new(),
                &w,
                (out_n, in_n),
                &gs,
                nb,
                shards,
                &mut out,
            );
            (cost, out)
        };
        let (base_cost, base, base_mask) = fwd(1);
        let (bb_cost, bb) = bwd(1);
        for shards in [2, 3, 5, 9] {
            let (cost, got, mask) = fwd(shards);
            assert_eq!(got, base, "fp shards {shards}");
            assert_eq!(mask, base_mask, "fp mask shards {shards}");
            assert_eq!(cost.total_cycles(), base_cost.total_cycles());
            assert_eq!(cost.dram_bursts, base_cost.dram_bursts);

            let (cost, got) = bwd(shards);
            assert_eq!(got, bb, "bp shards {shards}");
            assert_eq!(cost.total_cycles(), bb_cost.total_cycles());
        }
    }

    #[test]
    fn vmm_tile_parallelism_in_cycles() {
        let mut rng = Pcg32::seeded(34);
        let q = QFormat::paper16();
        let (out_n, in_n) = (128, 512);
        let w = quantize_slice(q, &rand_vec(&mut rng, out_n * in_n, -0.1, 0.1));
        let x = quantize_slice(q, &rand_vec(&mut rng, in_n, -1.0, 1.0));
        let mut c16 = Cost::new();
        let mut c32 = Cost::new();
        forward(&HwConfig::with_unroll(4, 4, 16), &mut c16, &w, (out_n, in_n), &x, None, None);
        forward(&HwConfig::with_unroll(4, 4, 32), &mut c32, &w, (out_n, in_n), &x, None, None);
        assert_eq!(c16.macs, c32.macs);
        assert!(c32.compute_cycles < c16.compute_cycles);
    }
}
