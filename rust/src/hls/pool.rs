//! Standalone max-pool / unpool units (paper §III-D, Fig. 5).
//!
//! In the scheduler's default dataflow these never run standalone: the
//! FP pool is absorbed into the conv output store (`conv::Post::ReluPool`)
//! and the BP unpool is fused into the gradient conv
//! (`conv::input_grad_unpool`). The standalone units exist for (a) the
//! unfused-ablation bench, (b) networks whose pool is not preceded by a
//! conv, and (c) differential testing of the fused paths.

use super::{dram, Cost, HwConfig};

/// 2x2/stride-2 max pool. Returns (pooled [C,H/2,W/2], 2-bit argmax).
///
/// Allocate-and-call wrapper over [`maxpool2_into`].
pub fn maxpool2(
    cfg: &HwConfig,
    cost: &mut Cost,
    x: &[i32],
    (c_n, h, w): (usize, usize, usize),
) -> (Vec<i32>, Vec<u8>) {
    let (ph, pw) = (h / 2, w / 2);
    let mut out = vec![0i32; c_n * ph * pw];
    let mut idx = vec![0u8; c_n * ph * pw];
    maxpool2_into(cfg, cost, x, (c_n, h, w), &mut out, &mut idx);
    (out, idx)
}

/// 2x2/stride-2 max pool into caller-provided buffers (`out`/`idx` must
/// be [C, H/2, W/2]) — the zero-allocation entry point.
pub fn maxpool2_into(
    cfg: &HwConfig,
    cost: &mut Cost,
    x: &[i32],
    (c_n, h, w): (usize, usize, usize),
    out: &mut [i32],
    idx: &mut [u8],
) {
    assert_eq!(x.len(), c_n * h * w);
    assert!(h % 2 == 0 && w % 2 == 0);
    let (ph, pw) = (h / 2, w / 2);
    assert_eq!(out.len(), c_n * ph * pw);
    assert_eq!(idx.len(), c_n * ph * pw);
    dram::read_tile_rows(cfg, cost, (c_n * h) as u64, w as u64);
    for ch in 0..c_n {
        for py in 0..ph {
            for px in 0..pw {
                let mut best = i32::MIN;
                let mut bi = 0u8;
                for d in 0..4usize {
                    let v = x[ch * h * w + (2 * py + d / 2) * w + (2 * px + d % 2)];
                    if v > best {
                        best = v;
                        bi = d as u8;
                    }
                }
                out[ch * ph * pw + py * pw + px] = best;
                idx[ch * ph * pw + py * pw + px] = bi;
            }
        }
    }
    // scan is sequential over windows (II=1, one window/cycle)
    cost.compute_cycles += (c_n * ph * pw) as u64 + cfg.pipeline_depth;
    dram::write_tile_rows(cfg, cost, (c_n * ph) as u64, pw as u64);
}

/// Unpool: route gradient to the cached argmax position (paper Fig. 5b).
///
/// Allocate-and-call wrapper over [`unpool2_into`].
pub fn unpool2(
    cfg: &HwConfig,
    cost: &mut Cost,
    g: &[i32],
    (c_n, ph, pw): (usize, usize, usize),
    idx: &[u8],
) -> Vec<i32> {
    let mut out = vec![0i32; c_n * 2 * ph * 2 * pw];
    unpool2_into(cfg, cost, g, (c_n, ph, pw), idx, &mut out);
    out
}

/// Unpool into a caller-provided [C, 2*PH, 2*PW] buffer — the
/// zero-allocation entry point. The buffer is fully overwritten (the
/// 3/4 structurally-zero positions are cleared here).
pub fn unpool2_into(
    cfg: &HwConfig,
    cost: &mut Cost,
    g: &[i32],
    (c_n, ph, pw): (usize, usize, usize),
    idx: &[u8],
    out: &mut [i32],
) {
    assert_eq!(g.len(), c_n * ph * pw);
    assert_eq!(idx.len(), g.len());
    let (h, w) = (2 * ph, 2 * pw);
    assert_eq!(out.len(), c_n * h * w);
    out.fill(0);
    dram::read_tile_rows(cfg, cost, (c_n * ph) as u64, pw as u64);
    dram::read(cfg, cost, (g.len() as u64).div_ceil(4), c_n as u64); // 2-bit idx
    for ch in 0..c_n {
        for py in 0..ph {
            for px in 0..pw {
                let pi = ch * ph * pw + py * pw + px;
                let (dy, dx) = ((idx[pi] >> 1) as usize, (idx[pi] & 1) as usize);
                out[ch * h * w + (2 * py + dy) * w + (2 * px + dx)] = g[pi];
            }
        }
    }
    cost.compute_cycles += (c_n * ph * pw) as u64 + cfg.pipeline_depth;
    dram::write_tile_rows(cfg, cost, (c_n * h) as u64, w as u64);
}

// ---------------------------------------------------------------------------
// 2-bit argmax packing (paper §III-D / §V): the index mask the hardware
// keeps on-chip is 2 bits per pooled element. The host state mirrors
// that density by packing 4 indices per byte; the engines consume the
// unpacked u8 form (the DRAM-traffic model already charges the packed
// density via `div_ceil(4)`, unchanged).
// ---------------------------------------------------------------------------

/// Bytes needed for `elems` packed 2-bit indices.
pub fn packed2_len(elems: usize) -> usize {
    elems.div_ceil(4)
}

/// Pack a flat [nb, elems] slab of 2-bit indices, 4 per byte, into
/// `out` ([nb, ceil(elems/4)], per-image byte-aligned). Resizes `out`
/// in place (capacity reused — allocation-free when warm).
pub fn pack2_slab_into(idx: &[u8], nb: usize, elems: usize, out: &mut Vec<u8>) {
    assert_eq!(idx.len(), nb * elems);
    let stride = packed2_len(elems);
    out.resize(nb * stride, 0);
    out.fill(0);
    for b in 0..nb {
        let src = &idx[b * elems..(b + 1) * elems];
        let dst = &mut out[b * stride..(b + 1) * stride];
        for (i, &v) in src.iter().enumerate() {
            debug_assert!(v < 4, "argmax index out of 2-bit range");
            dst[i / 4] |= (v & 3) << ((i % 4) * 2);
        }
    }
}

/// Unpack a flat [nb, ceil(elems/4)] packed slab back to one index per
/// byte ([nb, elems]). Resizes `out` in place.
pub fn unpack2_slab_into(packed: &[u8], nb: usize, elems: usize, out: &mut Vec<u8>) {
    let stride = packed2_len(elems);
    assert_eq!(packed.len(), nb * stride);
    out.resize(nb * elems, 0);
    for b in 0..nb {
        let src = &packed[b * stride..(b + 1) * stride];
        let dst = &mut out[b * elems..(b + 1) * elems];
        for (i, d) in dst.iter_mut().enumerate() {
            *d = (src[i / 4] >> ((i % 4) * 2)) & 3;
        }
    }
}

/// Pack one image's indices (convenience over [`pack2_slab_into`]).
pub fn pack2(idx: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    pack2_slab_into(idx, 1, idx.len(), &mut out);
    out
}

/// Unpack one image's indices (convenience over [`unpack2_slab_into`]).
pub fn unpack2(packed: &[u8], elems: usize) -> Vec<u8> {
    let mut out = Vec::new();
    unpack2_slab_into(packed, 1, elems, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_picks_max_and_index() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        // one channel, 4x4: windows have maxima at known positions
        #[rustfmt::skip]
        let x = vec![
            1, 9, 2, 2,
            3, 4, 8, 2,
            5, 5, 1, 1,
            6, 5, 1, 7,
        ];
        let (p, i) = maxpool2(&cfg, &mut c, &x, (1, 4, 4));
        assert_eq!(p, vec![9, 8, 6, 7]);
        // idx encodes (dy*2+dx): 9 at (0,1)=1, 8 at (1,0)=2, 6 at (1,0)=2, 7 at (1,1)=3
        assert_eq!(i, vec![1, 2, 2, 3]);
    }

    #[test]
    fn unpool_routes_by_index() {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let g = vec![10, 20, 30, 40];
        let idx = vec![1u8, 1, 2, 3];
        let out = unpool2(&cfg, &mut c, &g, (1, 2, 2), &idx);
        #[rustfmt::skip]
        let want = vec![
            0, 10, 0, 20,
            0, 0, 0, 0,
            0, 0, 0, 0,
            30, 0, 0, 40,
        ];
        assert_eq!(out, want);
    }

    #[test]
    fn pool_unpool_roundtrip_preserves_grad_at_max() {
        let mut rng = crate::util::rng::Pcg32::seeded(8);
        let (c_n, h, w) = (4, 8, 8);
        let x: Vec<i32> = (0..c_n * h * w).map(|_| rng.below(1000) as i32 - 500).collect();
        let cfg = HwConfig::pynq_z2();
        let mut cost = Cost::new();
        let (_, idx) = maxpool2(&cfg, &mut cost, &x, (c_n, h, w));
        let g: Vec<i32> = (0..c_n * h / 2 * w / 2).map(|_| rng.below(100) as i32 + 1).collect();
        let up = unpool2(&cfg, &mut cost, &g, (c_n, h / 2, w / 2), &idx);
        // each window: exactly one nonzero, equal to the window's gradient
        for ch in 0..c_n {
            for py in 0..h / 2 {
                for px in 0..w / 2 {
                    let vals: Vec<i32> = (0..4)
                        .map(|d| up[ch * h * w + (2 * py + d / 2) * w + (2 * px + d % 2)])
                        .collect();
                    let nz: Vec<&i32> = vals.iter().filter(|&&v| v != 0).collect();
                    assert_eq!(nz.len(), 1);
                    assert_eq!(*nz[0], g[ch * (h / 2) * (w / 2) + py * (w / 2) + px]);
                }
            }
        }
    }

    #[test]
    fn pack2_roundtrips_and_is_4x_denser() {
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        for elems in [1usize, 3, 4, 5, 16, 63, 64] {
            for nb in [1usize, 2, 5] {
                let idx: Vec<u8> = (0..nb * elems).map(|_| rng.below(4) as u8).collect();
                let mut packed = Vec::new();
                pack2_slab_into(&idx, nb, elems, &mut packed);
                assert_eq!(packed.len(), nb * packed2_len(elems));
                assert!(packed.len() * 4 >= idx.len());
                let mut back = Vec::new();
                unpack2_slab_into(&packed, nb, elems, &mut back);
                assert_eq!(back, idx, "nb={nb} elems={elems}");
            }
        }
        // single-image convenience forms agree with the slab forms
        let idx: Vec<u8> = (0..13).map(|_| rng.below(4) as u8).collect();
        assert_eq!(unpack2(&pack2(&idx), idx.len()), idx);
        assert_eq!(pack2(&idx).len(), packed2_len(13));
    }

    #[test]
    fn argmax_is_row_major_first_on_ties
    () {
        let cfg = HwConfig::pynq_z2();
        let mut c = Cost::new();
        let x = vec![5, 5, 5, 5]; // all tied
        let (_, i) = maxpool2(&cfg, &mut c, &x, (1, 2, 2));
        assert_eq!(i, vec![0]); // strict > keeps the first
    }
}
