//! Tiled output-stationary convolution engine (paper §III-B, §III-E).
//!
//! One engine serves both phases — the paper's central reuse claim:
//!
//! * **FP**: `forward()` with the normal kernel view. ReLU is fused
//!   into the output store (in-place on the output buffer, §III-D) and
//!   max-pooling is absorbed into the store as well (only pooled values
//!   travel back to DRAM).
//! * **BP**: `input_grad()` — the *same* `forward()` loop nest invoked
//!   with the flipped-transposed weight view (Fig. 6 / Table I); only
//!   the DRAM access pattern differs, which `weights::flip_transpose`
//!   models as the load-time index transformation.
//! * **BP after a max-pool**: `input_grad_unpool()` fuses the unpool
//!   routing into the gradient conv: it iterates the *pooled* grid and
//!   scatters through the cached 2-bit argmax indices, doing 1/4 of the
//!   naive MACs. This is what puts the measured BP/FP latency ratio in
//!   the paper's 50-72% band (DESIGN.md E3 discussion).
//!
//! All arithmetic is raw Q-format (i32 storage, i64 accumulate,
//! rescale + saturate once per output element).
//!
//! Every engine has a **batch-N `_into` core** ([`forward_batch_into`],
//! [`input_grad_unpool_batch_into`]) that loops images *inside* the
//! per-tile weight load (each weight tile fetched from DRAM once per
//! batch, DESIGN.md §Batching) and works entirely in caller-provided
//! flat slabs ([`EngineScratch`] + [`ConvBatchOut`]) so a warm steady
//! state performs **zero heap allocations**. The cores split execution
//! into a single-threaded *cost pass* (the `Cost` ledger walks the tile
//! loop nest exactly as before) and a *compute pass* that can be
//! **sharded across OS threads** by image: every image owns a disjoint
//! accumulator/output region and runs the identical batch=1 loop order,
//! so sharding is bit-exact by construction for any thread count and
//! the ledger is shard-invariant. The older `Vec`-returning signatures
//! (`forward`, `forward_batch`, `input_grad*`) are thin allocate-and-
//! call wrappers over the cores.

use super::{dram, Cost, EngineScratch, HwConfig};

/// What the output store does with each computed element (paper §III-D:
/// non-linear layers are absorbed into the store of the layer before).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Post {
    /// Store raw conv outputs.
    Plain,
    /// Apply ReLU in the output buffer before storing.
    Relu,
    /// ReLU, then 2x2/2 max-pool during the store scan.
    ReluPool,
}

/// Outputs of one conv layer evaluation.
#[derive(Clone, Debug)]
pub struct ConvResult {
    /// Full-resolution output [O,OH,OW] (post-ReLU if fused).
    pub out: Vec<i32>,
    /// ReLU positivity mask (1 bit/elem). Present when Post != Plain.
    pub mask: Option<Vec<bool>>,
    /// Pooled output [O,OH/2,OW/2] when Post == ReluPool.
    pub pooled: Option<Vec<i32>>,
    /// 2-bit argmax indices, row-major within each 2x2 window.
    pub pool_idx: Option<Vec<u8>>,
}

/// Reusable flat-slab outputs of a batched conv evaluation: image `b`'s
/// tensor occupies the `b`-th fixed-stride region of each slab. Unused
/// slabs (mask when `Post::Plain`, pooled/pool_idx unless
/// `Post::ReluPool`) are resized to zero length. Buffers are resized in
/// place and keep capacity across calls.
#[derive(Default)]
pub struct ConvBatchOut {
    /// [nb, O, OH, OW] full-resolution output (post-ReLU if fused).
    pub out: Vec<i32>,
    /// [nb, O, OH, OW] ReLU positivity mask; empty when Post == Plain.
    pub mask: Vec<bool>,
    /// [nb, O, OH/2, OW/2] pooled output; empty unless Post == ReluPool.
    pub pooled: Vec<i32>,
    /// Same dims as `pooled`: 2-bit argmax, one index per byte.
    pub pool_idx: Vec<u8>,
}

impl ConvBatchOut {
    pub fn new() -> ConvBatchOut {
        ConvBatchOut::default()
    }
}

/// Flipped-transposed weight view (paper Fig. 6): swap in/out channel
/// dims and rotate each kernel 180°. In hardware this is a DRAM
/// *address-pattern* change during buffer load (Table I); here we
/// materialize the view once per model load.
pub fn flip_transpose(w: &[i32], o: usize, i: usize, k: usize) -> Vec<i32> {
    assert_eq!(w.len(), o * i * k * k);
    let mut out = vec![0i32; w.len()];
    for oc in 0..o {
        for ic in 0..i {
            for kh in 0..k {
                for kw in 0..k {
                    let src = ((oc * i + ic) * k + kh) * k + kw;
                    let dst = ((ic * o + oc) * k + (k - 1 - kh)) * k + (k - 1 - kw);
                    out[dst] = w[src];
                }
            }
        }
    }
    out
}

/// Scatter-ordered view of a BP weight view for the fused unpool-conv:
/// `w_bp` is [OUT, CG, K, K] (as produced by [`flip_transpose`]); the
/// result is [CG, K, K, OUT] so each scatter tap is one long contiguous
/// FMA over the output channels (§Perf opt 3). Host layout only —
/// results and cost accounting are unchanged. Precomputed once per plan
/// so the steady-state BP path never re-materializes it.
pub fn flip_scatter(w_bp: &[i32], out_ch: usize, cg_n: usize, k: usize) -> Vec<i32> {
    assert_eq!(w_bp.len(), out_ch * cg_n * k * k);
    let mut wsc = vec![0i32; w_bp.len()];
    for o in 0..out_ch {
        for cg in 0..cg_n {
            for t in 0..k * k {
                wsc[(cg * k * k + t) * out_ch + o] = w_bp[(o * cg_n + cg) * k * k + t];
            }
        }
    }
    wsc
}

/// Tiled conv2d, stride 1. `x`: [I,H,W] raw Q, `w`: [O,I,K,K] raw Q,
/// `bias`: [O] raw Q or None. Output spatial dims: H+2*pad-K+1.
///
/// Thin wrapper over [`forward_batch`] with a batch of one — the batch
/// core is the only implementation, so single and batched execution are
/// bit-exact by construction.
#[allow(clippy::too_many_arguments)]
pub fn forward(
    cfg: &HwConfig,
    cost: &mut Cost,
    x: &[i32],
    in_shape: (usize, usize, usize),
    wgt: &[i32],
    oc_k: (usize, usize),
    bias: Option<&[i32]>,
    pad: usize,
    post: Post,
) -> ConvResult {
    forward_batch(cfg, cost, &[x], in_shape, wgt, oc_k, bias, pad, post)
        .pop()
        .expect("batch of one")
}

/// Batch-N tiled conv2d: allocate-and-call wrapper over
/// [`forward_batch_into`] (flattens the inputs, splits the slab outputs
/// back into per-image [`ConvResult`]s). Runs unsharded — the
/// steady-state serving path uses the `_into` core directly.
#[allow(clippy::too_many_arguments)]
pub fn forward_batch(
    cfg: &HwConfig,
    cost: &mut Cost,
    xs: &[&[i32]],
    (ic_n, h, w_n): (usize, usize, usize),
    wgt: &[i32],
    (oc_n, k): (usize, usize),
    bias: Option<&[i32]>,
    pad: usize,
    post: Post,
) -> Vec<ConvResult> {
    let nb = xs.len();
    assert!(nb > 0, "empty batch");
    let img_elems = ic_n * h * w_n;
    let mut flat = Vec::with_capacity(nb * img_elems);
    for x in xs {
        assert_eq!(x.len(), img_elems, "input size mismatch");
        flat.extend_from_slice(x);
    }
    let mut scratch = EngineScratch::new();
    let mut slab = ConvBatchOut::new();
    forward_batch_into(
        cfg,
        cost,
        &mut scratch,
        &flat,
        nb,
        (ic_n, h, w_n),
        wgt,
        (oc_n, k),
        bias,
        pad,
        post,
        1,
        &mut slab,
    );
    let oh = h + 2 * pad - (k - 1);
    let ow = w_n + 2 * pad - (k - 1);
    let out_elems = oc_n * oh * ow;
    let pool_elems = if post == Post::ReluPool { oc_n * (oh / 2) * (ow / 2) } else { 0 };
    (0..nb)
        .map(|b| ConvResult {
            out: slab.out[b * out_elems..(b + 1) * out_elems].to_vec(),
            mask: if post == Post::Plain {
                None
            } else {
                Some(slab.mask[b * out_elems..(b + 1) * out_elems].to_vec())
            },
            pooled: if post == Post::ReluPool {
                Some(slab.pooled[b * pool_elems..(b + 1) * pool_elems].to_vec())
            } else {
                None
            },
            pool_idx: if post == Post::ReluPool {
                Some(slab.pool_idx[b * pool_elems..(b + 1) * pool_elems].to_vec())
            } else {
                None
            },
        })
        .collect()
}

/// Batch-N tiled conv2d core: identical loop nest to the paper's
/// engine, but the image loop sits *inside* the per-tile weight load,
/// so each weight tile travels DRAM → on-chip exactly once per batch.
///
/// `xs` is a flat [nb, I, H, W] slab; results land in the reusable
/// `out` slabs. The `Cost` ledger is charged by a single-threaded pass
/// over the tile loop nest (identical totals to the legacy path); the
/// arithmetic then runs in a compute pass sharded across up to `shards`
/// scoped threads, each owning a disjoint image range of the
/// accumulator/output slabs — per-image loop order is exactly the
/// batch=1 order, so results are bit-exact for any shard count.
#[allow(clippy::too_many_arguments)]
pub fn forward_batch_into(
    cfg: &HwConfig,
    cost: &mut Cost,
    scratch: &mut EngineScratch,
    xs: &[i32],
    nb: usize,
    (ic_n, h, w_n): (usize, usize, usize),
    wgt: &[i32],
    (oc_n, k): (usize, usize),
    bias: Option<&[i32]>,
    pad: usize,
    post: Post,
    shards: usize,
    out: &mut ConvBatchOut,
) {
    assert!(nb > 0, "empty batch");
    assert_eq!(xs.len(), nb * ic_n * h * w_n, "input size mismatch");
    assert_eq!(wgt.len(), oc_n * ic_n * k * k, "weight size mismatch");
    let oh = h + 2 * pad - (k - 1);
    let ow = w_n + 2 * pad - (k - 1);
    if post == Post::ReluPool {
        assert!(oh % 2 == 0 && ow % 2 == 0, "pool needs even output dims");
    }
    let out_elems = oc_n * oh * ow;
    let mask_elems = if post == Post::Plain { 0 } else { out_elems };
    let pool_elems = if post == Post::ReluPool { oc_n * (oh / 2) * (ow / 2) } else { 0 };
    out.out.resize(nb * out_elems, 0);
    out.mask.resize(nb * mask_elems, false);
    out.pooled.resize(nb * pool_elems, 0);
    out.pool_idx.resize(nb * pool_elems, 0);

    // §Perf: pre-pad each input once (the line-buffer zero-fill the FPGA
    // does at load time) so the MAC loops below are branch-free
    // contiguous row FMAs that LLVM can vectorize. Host-only layout
    // choice; cycle/traffic accounting is unchanged.
    let (ph, pw) = (h + 2 * pad, w_n + 2 * pad);
    let padded_elems = ic_n * ph * pw;
    scratch.xp.resize(nb * padded_elems, 0);
    scratch.xp.fill(0);
    for b in 0..nb {
        let src_base = b * ic_n * h * w_n;
        let dst_base = b * padded_elems;
        for c in 0..ic_n {
            for y in 0..h {
                let src = src_base + c * h * w_n + y * w_n;
                let dst = dst_base + c * ph * pw + (y + pad) * pw + pad;
                scratch.xp[dst..dst + w_n].copy_from_slice(&xs[src..src + w_n]);
            }
        }
    }
    let tile_elems = cfg.tile_oc * cfg.tile_oh * cfg.tile_ow;
    scratch.acc.resize(nb * tile_elems, 0);

    // --- cost pass: the tile loop nest (paper §III-B), charged exactly
    // as the legacy interleaved execution did --------------------------
    let mut oc0 = 0;
    while oc0 < oc_n {
        let toc = cfg.tile_oc.min(oc_n - oc0);
        let mut oy0 = 0;
        while oy0 < oh {
            let toh = cfg.tile_oh.min(oh - oy0);
            let mut ox0 = 0;
            while ox0 < ow {
                let tow = cfg.tile_ow.min(ow - ox0);
                let mut ic0 = 0;
                while ic0 < ic_n {
                    let tic = cfg.tile_ic.min(ic_n - ic0);
                    // DRAM -> input buffer: halo tile rows (bounds-
                    // clipped), once per image — activation traffic
                    // scales with the batch
                    let in_rows = (toh + k - 1) as u64 * tic as u64;
                    for _ in 0..nb {
                        dram::read_tile_rows(cfg, cost, in_rows, (tow + k - 1) as u64);
                    }
                    // DRAM -> weight buffer: one burst per output
                    // channel, fetched ONCE for the whole batch
                    dram::read_weights(
                        cfg,
                        cost,
                        (toc * tic * k * k * cfg.word_bytes()) as u64,
                        toc as u64,
                    );
                    // cycles: ceil-division by the unroll lanes (partial
                    // tiles still occupy full lanes); one pipeline fill
                    // per tile, amortized across the batch
                    let spatial_iters =
                        (toh.div_ceil(cfg.n_oh) * tow.div_ceil(cfg.n_ow)) as u64;
                    cost.compute_cycles +=
                        nb as u64 * spatial_iters * (toc * tic * k * k) as u64
                            + cfg.pipeline_depth;
                    cost.macs += (nb * toh * tow * toc * tic * k * k) as u64;
                    ic0 += tic;
                }
                // output store (paper §III-D): with a fused pool only
                // pooled values leave the chip
                for _ in 0..nb {
                    if post == Post::ReluPool {
                        dram::write_tile_rows(cfg, cost, (toc * toh / 2) as u64, (tow / 2) as u64);
                    } else {
                        dram::write_tile_rows(cfg, cost, (toc * toh) as u64, tow as u64);
                    }
                }
                ox0 += tow;
            }
            oy0 += toh;
        }
        oc0 += toc;
    }

    // --- compute pass: shard the batch across threads -----------------
    let shards = shards.clamp(1, nb);
    if shards == 1 {
        fwd_range(
            cfg,
            nb,
            (ic_n, ph, pw),
            (oc_n, k),
            (oh, ow),
            wgt,
            bias,
            post,
            &scratch.xp,
            &mut scratch.acc,
            &mut out.out,
            &mut out.mask,
            &mut out.pooled,
            &mut out.pool_idx,
        );
    } else {
        std::thread::scope(|sc| {
            let xp = &scratch.xp[..];
            let mut acc: &mut [i64] = &mut scratch.acc;
            let mut o: &mut [i32] = &mut out.out;
            let mut m: &mut [bool] = &mut out.mask;
            let mut p: &mut [i32] = &mut out.pooled;
            let mut pi: &mut [u8] = &mut out.pool_idx;
            let mut lo = 0;
            for t in 0..shards {
                let hi = (t + 1) * nb / shards;
                let n = hi - lo;
                let tmp = acc;
                let (acc_t, rest) = tmp.split_at_mut(n * tile_elems);
                acc = rest;
                let tmp = o;
                let (o_t, rest) = tmp.split_at_mut(n * out_elems);
                o = rest;
                let tmp = m;
                let (m_t, rest) = tmp.split_at_mut(n * mask_elems);
                m = rest;
                let tmp = p;
                let (p_t, rest) = tmp.split_at_mut(n * pool_elems);
                p = rest;
                let tmp = pi;
                let (pi_t, rest) = tmp.split_at_mut(n * pool_elems);
                pi = rest;
                let xp_t = &xp[lo * padded_elems..hi * padded_elems];
                sc.spawn(move || {
                    fwd_range(
                        cfg,
                        n,
                        (ic_n, ph, pw),
                        (oc_n, k),
                        (oh, ow),
                        wgt,
                        bias,
                        post,
                        xp_t,
                        acc_t,
                        o_t,
                        m_t,
                        p_t,
                        pi_t,
                    );
                });
                lo = hi;
            }
        });
    }
}

/// Compute pass over a contiguous image range: the full tile loop nest
/// for `nb` images whose padded-input / accumulator / output regions
/// are the given sub-slabs. Loop order per image is identical to
/// batch=1, so any sharding of the batch is bit-exact.
#[allow(clippy::too_many_arguments)]
fn fwd_range(
    cfg: &HwConfig,
    nb: usize,
    (ic_n, ph, pw): (usize, usize, usize),
    (oc_n, k): (usize, usize),
    (oh, ow): (usize, usize),
    wgt: &[i32],
    bias: Option<&[i32]>,
    post: Post,
    xp: &[i32],
    acc: &mut [i64],
    out: &mut [i32],
    mask: &mut [bool],
    pooled: &mut [i32],
    pool_idx: &mut [u8],
) {
    let q = cfg.q;
    let tile_elems = cfg.tile_oc * cfg.tile_oh * cfg.tile_ow;
    let padded_elems = ic_n * ph * pw;
    let out_elems = oc_n * oh * ow;
    let (pool_h, pool_w) = (oh / 2, ow / 2);
    let pool_elems = oc_n * pool_h * pool_w;
    // fast path for word widths <= 16: operands fit i16, so each
    // product fits i32 (vpmulld-friendly); only the accumulator needs
    // i64 (§Perf opt 2)
    let narrow = cfg.q.word_bits <= 16;

    let mut oc0 = 0;
    while oc0 < oc_n {
        let toc = cfg.tile_oc.min(oc_n - oc0);
        let mut oy0 = 0;
        while oy0 < oh {
            let toh = cfg.tile_oh.min(oh - oy0);
            let mut ox0 = 0;
            while ox0 < ow {
                let tow = cfg.tile_ow.min(ow - ox0);
                // output-stationary accumulation across input-channel
                // tiles; one accumulator region per image
                for b in 0..nb {
                    let xpb = &xp[b * padded_elems..(b + 1) * padded_elems];
                    let accb = &mut acc[b * tile_elems..(b + 1) * tile_elems];
                    accb.fill(0);
                    let mut ic0 = 0;
                    while ic0 < ic_n {
                        let tic = cfg.tile_ic.min(ic_n - ic0);
                        // MAC loops: N_oh x N_ow unrolled lanes, II=1.
                        // Host layout: tap-outer / row-inner so the
                        // innermost loop is a contiguous multiply-
                        // accumulate the autovectorizer handles.
                        for oc in 0..toc {
                            for ic in 0..tic {
                                let wbase = ((oc0 + oc) * ic_n + (ic0 + ic)) * k * k;
                                let xbase = (ic0 + ic) * ph * pw;
                                for kh in 0..k {
                                    for kw in 0..k {
                                        let wv = wgt[wbase + kh * k + kw];
                                        if wv == 0 {
                                            continue; // quantized-to-zero tap
                                        }
                                        for ty in 0..toh {
                                            let xrow = xbase + (oy0 + ty + kh) * pw + ox0 + kw;
                                            let arow = (oc * cfg.tile_oh + ty) * cfg.tile_ow;
                                            let xs_row = &xpb[xrow..xrow + tow];
                                            let accs = &mut accb[arow..arow + tow];
                                            if narrow {
                                                for (a, &xv) in accs.iter_mut().zip(xs_row) {
                                                    *a += (xv * wv) as i64;
                                                }
                                            } else {
                                                let wv = wv as i64;
                                                for (a, &xv) in accs.iter_mut().zip(xs_row) {
                                                    *a += xv as i64 * wv;
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                        ic0 += tic;
                    }

                    // --- output store with fused post-ops (§III-D) ----
                    let ob = &mut out[b * out_elems..(b + 1) * out_elems];
                    let mb = if mask.is_empty() {
                        &mut mask[0..0]
                    } else {
                        &mut mask[b * out_elems..(b + 1) * out_elems]
                    };
                    for oc in 0..toc {
                        for ty in 0..toh {
                            for tx in 0..tow {
                                let mut v = q
                                    .rescale_acc(accb[(oc * cfg.tile_oh + ty) * cfg.tile_ow + tx]);
                                if let Some(bs) = bias {
                                    v = q.add(v, bs[oc0 + oc]);
                                }
                                let gi = (oc0 + oc) * oh * ow + (oy0 + ty) * ow + (ox0 + tx);
                                if post != Post::Plain {
                                    mb[gi] = v > 0;
                                    if v < 0 {
                                        v = 0;
                                    }
                                }
                                ob[gi] = v;
                            }
                        }
                    }
                    if post == Post::ReluPool {
                        // pool scan during store: max of each 2x2 window
                        let pv = &mut pooled[b * pool_elems..(b + 1) * pool_elems];
                        let pib = &mut pool_idx[b * pool_elems..(b + 1) * pool_elems];
                        for oc in 0..toc {
                            for py in (oy0 / 2)..((oy0 + toh) / 2) {
                                for px in (ox0 / 2)..((ox0 + tow) / 2) {
                                    let mut best = i32::MIN;
                                    let mut bidx = 0u8;
                                    for dy in 0..2 {
                                        for dx in 0..2 {
                                            let v = ob[(oc0 + oc) * oh * ow
                                                + (2 * py + dy) * ow
                                                + (2 * px + dx)];
                                            if v > best {
                                                best = v;
                                                bidx = (dy * 2 + dx) as u8;
                                            }
                                        }
                                    }
                                    pv[(oc0 + oc) * pool_h * pool_w + py * pool_w + px] = best;
                                    pib[(oc0 + oc) * pool_h * pool_w + py * pool_w + px] = bidx;
                                }
                            }
                        }
                    }
                }
                ox0 += tow;
            }
            oy0 += toh;
        }
        oc0 += toc;
    }
}

/// BP conv (paper §III-E): gradient w.r.t. the layer input — the same
/// engine with the flipped-transposed weight view. `w_bp` must come
/// from [`flip_transpose`]; `g` is the upstream gradient [O,OH,OW].
pub fn input_grad(
    cfg: &HwConfig,
    cost: &mut Cost,
    g: &[i32],
    g_shape: (usize, usize, usize),
    w_bp: &[i32],
    out_ch: usize,
    k: usize,
    pad: usize,
) -> Vec<i32> {
    let bp_pad = k - 1 - pad;
    forward(cfg, cost, g, g_shape, w_bp, (out_ch, k), None, bp_pad, Post::Plain).out
}

/// Batch-N BP conv: [`input_grad`] over a batch of upstream gradients,
/// sharing each flipped-transposed weight tile across the batch (the
/// same amortization as [`forward_batch`], which it delegates to).
#[allow(clippy::too_many_arguments)]
pub fn input_grad_batch(
    cfg: &HwConfig,
    cost: &mut Cost,
    gs: &[&[i32]],
    g_shape: (usize, usize, usize),
    w_bp: &[i32],
    out_ch: usize,
    k: usize,
    pad: usize,
) -> Vec<Vec<i32>> {
    let bp_pad = k - 1 - pad;
    forward_batch(cfg, cost, gs, g_shape, w_bp, (out_ch, k), None, bp_pad, Post::Plain)
        .into_iter()
        .map(|r| r.out)
        .collect()
}

/// BP conv fused with unpooling (paper §III-D/E combined): the upstream
/// gradient arrives on the *pooled* grid [Cg,PH,PW] together with the
/// 2-bit argmax indices; the engine scatters each pooled gradient
/// through its cached argmax position directly into the gradient-conv
/// accumulation, skipping the 3/4 of positions that are structurally
/// zero. MACs = naive/4.
#[allow(clippy::too_many_arguments)]
pub fn input_grad_unpool(
    cfg: &HwConfig,
    cost: &mut Cost,
    g_pooled: &[i32],
    shape: (usize, usize, usize),
    pool_idx: &[u8],
    w_bp: &[i32],
    out_ch: usize,
    k: usize,
    pad: usize,
) -> Vec<i32> {
    input_grad_unpool_batch(cfg, cost, &[g_pooled], shape, &[pool_idx], w_bp, out_ch, k, pad)
        .pop()
        .expect("batch of one")
}

/// Batch-N fused unpool + gradient conv: allocate-and-call wrapper over
/// [`input_grad_unpool_batch_into`] (materializes the scatter-ordered
/// weight view per call; the plan-driven serving path precomputes it).
#[allow(clippy::too_many_arguments)]
pub fn input_grad_unpool_batch(
    cfg: &HwConfig,
    cost: &mut Cost,
    gs_pooled: &[&[i32]],
    (cg_n, ph, pw): (usize, usize, usize),
    pool_idxs: &[&[u8]],
    w_bp: &[i32],
    out_ch: usize,
    k: usize,
    pad: usize,
) -> Vec<Vec<i32>> {
    let nb = gs_pooled.len();
    assert!(nb > 0, "empty batch");
    assert_eq!(pool_idxs.len(), nb, "one pool-index mask per image");
    let g_elems = cg_n * ph * pw;
    let mut g_flat = Vec::with_capacity(nb * g_elems);
    let mut idx_flat = Vec::with_capacity(nb * g_elems);
    for b in 0..nb {
        assert_eq!(gs_pooled[b].len(), g_elems);
        assert_eq!(pool_idxs[b].len(), g_elems);
        g_flat.extend_from_slice(gs_pooled[b]);
        idx_flat.extend_from_slice(pool_idxs[b]);
    }
    let w_sc = flip_scatter(w_bp, out_ch, cg_n, k);
    let mut scratch = EngineScratch::new();
    let mut out = Vec::new();
    input_grad_unpool_batch_into(
        cfg,
        cost,
        &mut scratch,
        &g_flat,
        nb,
        (cg_n, ph, pw),
        &idx_flat,
        &w_sc,
        out_ch,
        k,
        pad,
        1,
        &mut out,
    );
    let (h, w_n) = (2 * ph, 2 * pw);
    let bp_pad = k - 1 - pad;
    let (oh, ow) = (h + 2 * bp_pad - (k - 1), w_n + 2 * bp_pad - (k - 1));
    let out_elems = out_ch * oh * ow;
    (0..nb).map(|b| out[b * out_elems..(b + 1) * out_elems].to_vec()).collect()
}

/// Batch-N fused unpool + gradient conv core: the image loop sits
/// inside the per-tile weight-view load, so the flipped-transposed
/// weights for a channel block are fetched once per batch. `gs` and
/// `idx` are flat [nb, Cg, PH, PW] slabs; `w_sc` is the
/// [`flip_scatter`] view of the BP weights. Cost pass + image-sharded
/// compute pass as in [`forward_batch_into`] — bit-exact with the
/// single-image path for any shard count.
#[allow(clippy::too_many_arguments)]
pub fn input_grad_unpool_batch_into(
    cfg: &HwConfig,
    cost: &mut Cost,
    scratch: &mut EngineScratch,
    gs: &[i32],
    nb: usize,
    (cg_n, ph, pw): (usize, usize, usize),
    idx: &[u8],
    w_sc: &[i32],
    out_ch: usize,
    k: usize,
    pad: usize,
    shards: usize,
    out: &mut Vec<i32>,
) {
    assert!(nb > 0, "empty batch");
    let g_elems = cg_n * ph * pw;
    assert_eq!(gs.len(), nb * g_elems);
    assert_eq!(idx.len(), gs.len());
    assert_eq!(w_sc.len(), out_ch * cg_n * k * k);
    let (h, w_n) = (2 * ph, 2 * pw);
    let bp_pad = k - 1 - pad;
    let (oh, ow) = (h + 2 * bp_pad - (k - 1), w_n + 2 * bp_pad - (k - 1));
    let grad_elems = oh * ow * out_ch;
    let out_elems = out_ch * oh * ow;
    scratch.acc.resize(nb * grad_elems, 0);
    out.resize(nb * out_elems, 0);

    // --- cost pass: tile over the pooled grid (what the on-chip
    // gradient buffer holds during BP) ---------------------------------
    let (tile_ph, tile_pw) = (cfg.tile_oh.max(2) / 2 * 2, cfg.tile_ow.max(2) / 2 * 2);
    let mut c0 = 0;
    while c0 < cg_n {
        let tc = cfg.tile_ic.min(cg_n - c0);
        let mut py0 = 0;
        while py0 < ph {
            let tph = tile_ph.min(ph - py0);
            let mut px0 = 0;
            while px0 < pw {
                let tpw = tile_pw.min(pw - px0);
                // loads: pooled gradient tile + packed 2-bit indices,
                // once per image
                for _ in 0..nb {
                    dram::read_tile_rows(cfg, cost, (tc * tph) as u64, tpw as u64);
                    dram::read(cfg, cost, ((tc * tph * tpw) as u64).div_ceil(4), tc as u64);
                }
                // weight view for this channel block: ONCE per batch
                dram::read_weights(
                    cfg,
                    cost,
                    (out_ch * tc * k * k * cfg.word_bytes()) as u64,
                    out_ch as u64,
                );
                // cycles: one MAC group per (image, pooled elem,
                // out_ch, tap), parallel over the N_oh x N_ow lanes;
                // one pipeline fill per tile, amortized across the batch
                let macs = (nb * tc * tph * tpw * out_ch * k * k) as u64;
                cost.compute_cycles +=
                    macs.div_ceil(cfg.conv_macs_parallel() as u64) + cfg.pipeline_depth;
                cost.macs += macs;
                px0 += tpw;
            }
            py0 += tph;
        }
        c0 += tc;
    }
    for _ in 0..nb {
        dram::write_tile_rows(cfg, cost, (out_ch * oh) as u64, ow as u64);
    }

    // --- compute pass: shard the batch across threads -----------------
    let shards = shards.clamp(1, nb);
    if shards == 1 {
        unpool_grad_range(
            cfg,
            nb,
            (cg_n, ph, pw),
            w_sc,
            out_ch,
            k,
            bp_pad,
            (oh, ow),
            gs,
            idx,
            &mut scratch.acc,
            out,
        );
    } else {
        std::thread::scope(|sc| {
            let mut acc: &mut [i64] = &mut scratch.acc;
            let mut o: &mut [i32] = out;
            let mut lo = 0;
            for t in 0..shards {
                let hi = (t + 1) * nb / shards;
                let n = hi - lo;
                let tmp = acc;
                let (acc_t, rest) = tmp.split_at_mut(n * grad_elems);
                acc = rest;
                let tmp = o;
                let (o_t, rest) = tmp.split_at_mut(n * out_elems);
                o = rest;
                let gs_t = &gs[lo * g_elems..hi * g_elems];
                let idx_t = &idx[lo * g_elems..hi * g_elems];
                sc.spawn(move || {
                    unpool_grad_range(
                        cfg,
                        n,
                        (cg_n, ph, pw),
                        w_sc,
                        out_ch,
                        k,
                        bp_pad,
                        (oh, ow),
                        gs_t,
                        idx_t,
                        acc_t,
                        o_t,
                    );
                });
                lo = hi;
            }
        });
    }
}

/// Compute pass of the fused unpool + gradient conv over a contiguous
/// image range. §Perf opt 3: accumulate in [y][x][o] order (contiguous
/// in the output channel) against the pre-transposed `w_sc` view so
/// each scatter tap is one long contiguous FMA over out_ch; transpose
/// back to [o][y][x] at store time. Host layout only.
#[allow(clippy::too_many_arguments)]
fn unpool_grad_range(
    cfg: &HwConfig,
    nb: usize,
    (cg_n, ph, pw): (usize, usize, usize),
    w_sc: &[i32],
    out_ch: usize,
    k: usize,
    bp_pad: usize,
    (oh, ow): (usize, usize),
    gs: &[i32],
    idx: &[u8],
    acc: &mut [i64],
    out: &mut [i32],
) {
    let q = cfg.q;
    let g_elems = cg_n * ph * pw;
    let grad_elems = oh * ow * out_ch;
    let out_elems = out_ch * oh * ow;
    let narrow = cfg.q.word_bits <= 16;
    let (tile_ph, tile_pw) = (cfg.tile_oh.max(2) / 2 * 2, cfg.tile_ow.max(2) / 2 * 2);

    for b in 0..nb {
        let g_pooled = &gs[b * g_elems..(b + 1) * g_elems];
        let pool_idx = &idx[b * g_elems..(b + 1) * g_elems];
        let accb = &mut acc[b * grad_elems..(b + 1) * grad_elems];
        accb.fill(0);
        let mut c0 = 0;
        while c0 < cg_n {
            let tc = cfg.tile_ic.min(cg_n - c0);
            let mut py0 = 0;
            while py0 < ph {
                let tph = tile_ph.min(ph - py0);
                let mut px0 = 0;
                while px0 < pw {
                    let tpw = tile_pw.min(pw - px0);
                    for cg in c0..c0 + tc {
                        for py in py0..py0 + tph {
                            for px in px0..px0 + tpw {
                                let pi = cg * ph * pw + py * pw + px;
                                let gv = g_pooled[pi];
                                if gv == 0 {
                                    continue;
                                }
                                let pidx = pool_idx[pi];
                                let yy = 2 * py + (pidx >> 1) as usize;
                                let xx = 2 * px + (pidx & 1) as usize;
                                for kh in 0..k {
                                    let oy = yy + bp_pad;
                                    if oy < kh || oy - kh >= oh {
                                        continue;
                                    }
                                    let oy = oy - kh;
                                    for kw in 0..k {
                                        let oxp = xx + bp_pad;
                                        if oxp < kw || oxp - kw >= ow {
                                            continue;
                                        }
                                        let abase = (oy * ow + (oxp - kw)) * out_ch;
                                        let wbase = (cg * k * k + kh * k + kw) * out_ch;
                                        let accs = &mut accb[abase..abase + out_ch];
                                        let ws = &w_sc[wbase..wbase + out_ch];
                                        if narrow {
                                            for (a, &wv) in accs.iter_mut().zip(ws) {
                                                *a += (gv * wv) as i64;
                                            }
                                        } else {
                                            let gv = gv as i64;
                                            for (a, &wv) in accs.iter_mut().zip(ws) {
                                                *a += gv * wv as i64;
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                    px0 += tpw;
                }
                py0 += tph;
            }
            c0 += tc;
        }
        // rescale + store the gradient tensor (transpose back to [o][y][x])
        let ob = &mut out[b * out_elems..(b + 1) * out_elems];
        for y in 0..oh {
            for x in 0..ow {
                let base = (y * ow + x) * out_ch;
                for o in 0..out_ch {
                    ob[o * oh * ow + y * ow + x] = q.rescale_acc(accb[base + o]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::{quantize_slice, QFormat};
    use crate::util::rng::Pcg32;

    fn cfg() -> HwConfig {
        HwConfig::pynq_z2()
    }

    /// Naive f64 conv on dequantized values — the oracle.
    fn conv_ref(
        x: &[f64],
        (ic, h, w): (usize, usize, usize),
        wg: &[f64],
        (oc, k): (usize, usize),
        bias: &[f64],
        pad: usize,
    ) -> Vec<f64> {
        let oh = h + 2 * pad - (k - 1);
        let ow = w + 2 * pad - (k - 1);
        let mut out = vec![0f64; oc * oh * ow];
        for o in 0..oc {
            for y in 0..oh {
                for xp in 0..ow {
                    let mut s = bias.get(o).copied().unwrap_or(0.0);
                    for c in 0..ic {
                        for kh in 0..k {
                            for kw in 0..k {
                                let iy = (y + kh) as isize - pad as isize;
                                let ix = (xp + kw) as isize - pad as isize;
                                if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                                    s += x[c * h * w + iy as usize * w + ix as usize]
                                        * wg[((o * ic + c) * k + kh) * k + kw];
                                }
                            }
                        }
                    }
                    out[o * oh * ow + y * ow + xp] = s;
                }
            }
        }
        out
    }

    fn rand_vec(rng: &mut Pcg32, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(lo, hi)).collect()
    }

    #[test]
    fn matches_reference_within_quantization() {
        let mut rng = Pcg32::seeded(42);
        let (ic, h, w, oc, k, pad) = (3, 12, 12, 8, 3, 1);
        let xf = rand_vec(&mut rng, ic * h * w, -1.0, 1.0);
        let wf = rand_vec(&mut rng, oc * ic * k * k, -0.5, 0.5);
        let bf = rand_vec(&mut rng, oc, -0.2, 0.2);
        let q = QFormat::paper16();
        let c = cfg();
        let mut cost = Cost::new();
        let r = forward(
            &c,
            &mut cost,
            &quantize_slice(q, &xf),
            (ic, h, w),
            &quantize_slice(q, &wf),
            (oc, k),
            Some(&quantize_slice(q, &bf)),
            pad,
            Post::Plain,
        );
        let want = conv_ref(
            &xf.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            (ic, h, w),
            &wf.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            (oc, k),
            &bf.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            pad,
        );
        for (i, (&got, &want)) in r.out.iter().zip(&want).enumerate() {
            let g = q.to_f32(got) as f64;
            assert!(
                (g - want).abs() < 0.05,
                "elem {i}: got {g}, want {want}"
            );
        }
        assert!(cost.macs >= (oc * ic * h * w * k * k) as u64);
        assert!(cost.dram_read_bytes > 0 && cost.dram_write_bytes > 0);
    }

    #[test]
    fn identity_kernel_exact() {
        // 1x1 identity kernel, no pad: output == input exactly (raw)
        let q = QFormat::paper16();
        let x: Vec<i32> = (0..16).map(|i| q.from_f32(i as f32 * 0.25 - 2.0)).collect();
        let wgt = vec![q.from_f32(1.0)];
        let mut cost = Cost::new();
        let r = forward(&cfg(), &mut cost, &x, (1, 4, 4), &wgt, (1, 1), None, 0, Post::Plain);
        assert_eq!(r.out, x);
    }

    #[test]
    fn relu_fusion_and_mask() {
        let q = QFormat::paper16();
        let x: Vec<i32> = [-1.0f32, 2.0, -3.0, 4.0].iter().map(|&v| q.from_f32(v)).collect();
        let wgt = vec![q.from_f32(1.0)];
        let mut cost = Cost::new();
        let r = forward(&cfg(), &mut cost, &x, (1, 2, 2), &wgt, (1, 1), None, 0, Post::Relu);
        assert_eq!(r.out, vec![0, q.from_f32(2.0), 0, q.from_f32(4.0)]);
        assert_eq!(r.mask.unwrap(), vec![false, true, false, true]);
    }

    #[test]
    fn pool_fusion_matches_separate() {
        let mut rng = Pcg32::seeded(7);
        let q = QFormat::paper16();
        let (ic, h, w, oc) = (4, 8, 8, 4);
        let x = quantize_slice(q, &rand_vec(&mut rng, ic * h * w, -1.0, 1.0));
        let wg = quantize_slice(q, &rand_vec(&mut rng, oc * ic * 9, -0.4, 0.4));
        let c = cfg();
        let mut cost = Cost::new();
        let fused = forward(&c, &mut cost, &x, (ic, h, w), &wg, (oc, 3), None, 1, Post::ReluPool);
        let mut cost2 = Cost::new();
        let plain = forward(&c, &mut cost2, &x, (ic, h, w), &wg, (oc, 3), None, 1, Post::Relu);
        // oracle pool over the plain relu output
        let (ph, pw) = (h / 2, w / 2);
        let pooled = fused.pooled.unwrap();
        let idx = fused.pool_idx.unwrap();
        for ch in 0..oc {
            for py in 0..ph {
                for px in 0..pw {
                    let vals: Vec<i32> = (0..4)
                        .map(|d| plain.out[ch * h * w + (2 * py + d / 2) * w + (2 * px + d % 2)])
                        .collect();
                    let pi = ch * ph * pw + py * pw + px;
                    assert_eq!(pooled[pi], *vals.iter().max().unwrap());
                    assert_eq!(vals[idx[pi] as usize], pooled[pi]);
                }
            }
        }
        // fused pool writes 4x fewer output bytes
        assert!(cost.dram_write_bytes < cost2.dram_write_bytes);
    }

    #[test]
    fn flip_transpose_involution() {
        let mut rng = Pcg32::seeded(9);
        let (o, i, k) = (4, 3, 3);
        let w: Vec<i32> = (0..o * i * k * k).map(|_| rng.below(1000) as i32 - 500).collect();
        let wt = flip_transpose(&w, o, i, k);
        let wtt = flip_transpose(&wt, i, o, k);
        assert_eq!(w, wtt);
    }

    #[test]
    fn input_grad_matches_autodiff_identity() {
        // conv with pad=1 k=3: d out / d in through flipped-transpose conv.
        // Check against f64 oracle: grad_in = conv(g, flipT(w), pad=1)
        let mut rng = Pcg32::seeded(13);
        let q = QFormat::paper16();
        let (ic, h, w, oc, k, pad) = (3, 8, 8, 5, 3, 1);
        let gf = rand_vec(&mut rng, oc * h * w, -1.0, 1.0);
        let wf = rand_vec(&mut rng, oc * ic * k * k, -0.5, 0.5);
        let qg = quantize_slice(q, &gf);
        let qw = quantize_slice(q, &wf);
        let wbp = flip_transpose(&qw, oc, ic, k);
        let c = cfg();
        let mut cost = Cost::new();
        let got = input_grad(&c, &mut cost, &qg, (oc, h, w), &wbp, ic, k, pad);
        // oracle: flipped-transposed f64 conv
        let mut wtf = vec![0f64; ic * oc * k * k];
        for o in 0..oc {
            for i_ in 0..ic {
                for kh in 0..k {
                    for kw in 0..k {
                        wtf[((i_ * oc + o) * k + (k - 1 - kh)) * k + (k - 1 - kw)] =
                            wf[((o * ic + i_) * k + kh) * k + kw] as f64;
                    }
                }
            }
        }
        let want = conv_ref(
            &gf.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            (oc, h, w),
            &wtf,
            (ic, k),
            &[],
            k - 1 - pad,
        );
        for (i, (&g, &wv)) in got.iter().zip(&want).enumerate() {
            assert!((q.to_f32(g) as f64 - wv).abs() < 0.06, "elem {i}");
        }
    }

    #[test]
    fn fused_unpool_equals_unpool_then_conv() {
        let mut rng = Pcg32::seeded(21);
        let q = QFormat::paper16();
        let (cg, ph, pw, out_ch, k, pad) = (8, 4, 4, 6, 3, 1);
        let gp = quantize_slice(q, &rand_vec(&mut rng, cg * ph * pw, -1.0, 1.0));
        let idx: Vec<u8> = (0..cg * ph * pw).map(|_| rng.below(4) as u8).collect();
        let wf = rand_vec(&mut rng, out_ch * cg * k * k, -0.5, 0.5);
        let qw = quantize_slice(q, &wf);
        let wbp = flip_transpose(&qw, cg, out_ch, k); // note: conv had out=cg, in=out_ch
        let c = cfg();

        // path A: fused
        let mut ca = Cost::new();
        let fused = input_grad_unpool(&c, &mut ca, &gp, (cg, ph, pw), &idx, &wbp, out_ch, k, pad);

        // path B: materialize the unpooled gradient, then plain BP conv
        let (h, w) = (2 * ph, 2 * pw);
        let mut gu = vec![0i32; cg * h * w];
        for ch in 0..cg {
            for py in 0..ph {
                for px in 0..pw {
                    let pi = ch * ph * pw + py * pw + px;
                    let (dy, dx) = ((idx[pi] >> 1) as usize, (idx[pi] & 1) as usize);
                    gu[ch * h * w + (2 * py + dy) * w + (2 * px + dx)] = gp[pi];
                }
            }
        }
        let mut cb = Cost::new();
        let naive = input_grad(&c, &mut cb, &gu, (cg, h, w), &wbp, out_ch, k, pad);

        assert_eq!(fused, naive, "fused unpool-conv must equal unpool+conv exactly");
        // and it must be cheaper: 1/4 the MACs
        assert_eq!(ca.macs * 4, cb.macs);
        assert!(ca.compute_cycles < cb.compute_cycles);
    }

    #[test]
    fn batch_matches_single_and_amortizes_weights() {
        let mut rng = Pcg32::seeded(29);
        let q = QFormat::paper16();
        let (ic, h, w, oc, k, pad) = (3, 12, 12, 8, 3, 1);
        let imgs: Vec<Vec<i32>> = (0..3)
            .map(|_| quantize_slice(q, &rand_vec(&mut rng, ic * h * w, -1.0, 1.0)))
            .collect();
        let wg = quantize_slice(q, &rand_vec(&mut rng, oc * ic * k * k, -0.5, 0.5));
        let bf = quantize_slice(q, &rand_vec(&mut rng, oc, -0.2, 0.2));
        let c = cfg();
        for post in [Post::Plain, Post::Relu, Post::ReluPool] {
            let refs: Vec<&[i32]> = imgs.iter().map(|v| v.as_slice()).collect();
            let mut cb = Cost::new();
            let batch =
                forward_batch(&c, &mut cb, &refs, (ic, h, w), &wg, (oc, k), Some(&bf), pad, post);
            let mut read_single_total = 0;
            for (i, r) in batch.iter().enumerate() {
                let mut cs = Cost::new();
                let single =
                    forward(&c, &mut cs, &imgs[i], (ic, h, w), &wg, (oc, k), Some(&bf), pad, post);
                assert_eq!(r.out, single.out, "post {post:?} image {i}: out diverged");
                assert_eq!(r.mask, single.mask);
                assert_eq!(r.pooled, single.pooled);
                assert_eq!(r.pool_idx, single.pool_idx);
                // weights fetched once per batch == once per single run, so
                // the batch pays 1x (not 3x) the weight traffic
                assert_eq!(cb.dram_weight_bytes, cs.dram_weight_bytes, "post {post:?}");
                read_single_total += cs.dram_read_bytes;
            }
            assert!(cb.dram_read_bytes < read_single_total, "post {post:?}");
        }
    }

    #[test]
    fn sharded_forward_bit_exact_and_cost_invariant() {
        // any shard count yields the exact same slabs AND the exact same
        // ledger (the cost pass is shard-independent by construction)
        let mut rng = Pcg32::seeded(57);
        let q = QFormat::paper16();
        let (ic, h, w, oc, k, pad) = (3, 12, 12, 8, 3, 1);
        let nb = 5;
        let flat = quantize_slice(q, &rand_vec(&mut rng, nb * ic * h * w, -1.0, 1.0));
        let wg = quantize_slice(q, &rand_vec(&mut rng, oc * ic * k * k, -0.5, 0.5));
        let bf = quantize_slice(q, &rand_vec(&mut rng, oc, -0.2, 0.2));
        let c = cfg();
        #[allow(clippy::too_many_arguments)]
        fn run(
            c: &HwConfig,
            flat: &[i32],
            nb: usize,
            shape: (usize, usize, usize),
            wg: &[i32],
            oc_k: (usize, usize),
            bf: &[i32],
            pad: usize,
            post: Post,
            shards: usize,
        ) -> (Cost, ConvBatchOut) {
            let mut cost = Cost::new();
            let mut out = ConvBatchOut::new();
            forward_batch_into(
                c,
                &mut cost,
                &mut EngineScratch::new(),
                flat,
                nb,
                shape,
                wg,
                oc_k,
                Some(bf),
                pad,
                post,
                shards,
                &mut out,
            );
            (cost, out)
        }
        for post in [Post::Plain, Post::Relu, Post::ReluPool] {
            let (base_cost, base) =
                run(&c, &flat, nb, (ic, h, w), &wg, (oc, k), &bf, pad, post, 1);
            for shards in [2, 3, 5, 8] {
                let (cost, got) =
                    run(&c, &flat, nb, (ic, h, w), &wg, (oc, k), &bf, pad, post, shards);
                assert_eq!(got.out, base.out, "post {post:?} shards {shards}");
                assert_eq!(got.mask, base.mask, "post {post:?} shards {shards}");
                assert_eq!(got.pooled, base.pooled, "post {post:?} shards {shards}");
                assert_eq!(got.pool_idx, base.pool_idx, "post {post:?} shards {shards}");
                assert_eq!(cost.total_cycles(), base_cost.total_cycles());
                assert_eq!(cost.dram_read_bytes, base_cost.dram_read_bytes);
                assert_eq!(cost.dram_weight_bytes, base_cost.dram_weight_bytes);
            }
        }
    }

    #[test]
    fn batch_input_grad_unpool_matches_single() {
        let mut rng = Pcg32::seeded(31);
        let q = QFormat::paper16();
        let (cg, ph, pw, out_ch, k, pad) = (8, 4, 4, 6, 3, 1);
        let gs: Vec<Vec<i32>> = (0..3)
            .map(|_| quantize_slice(q, &rand_vec(&mut rng, cg * ph * pw, -1.0, 1.0)))
            .collect();
        let idxs: Vec<Vec<u8>> = (0..3)
            .map(|_| (0..cg * ph * pw).map(|_| rng.below(4) as u8).collect())
            .collect();
        let wf = rand_vec(&mut rng, out_ch * cg * k * k, -0.5, 0.5);
        let wbp = flip_transpose(&quantize_slice(q, &wf), cg, out_ch, k);
        let c = cfg();
        let grefs: Vec<&[i32]> = gs.iter().map(|v| v.as_slice()).collect();
        let irefs: Vec<&[u8]> = idxs.iter().map(|v| v.as_slice()).collect();
        let mut cb = Cost::new();
        let batch = input_grad_unpool_batch(
            &c, &mut cb, &grefs, (cg, ph, pw), &irefs, &wbp, out_ch, k, pad,
        );
        for i in 0..3 {
            let mut cs = Cost::new();
            let single = input_grad_unpool(
                &c, &mut cs, &gs[i], (cg, ph, pw), &idxs[i], &wbp, out_ch, k, pad,
            );
            assert_eq!(batch[i], single, "image {i} diverged");
            assert_eq!(cb.dram_weight_bytes, cs.dram_weight_bytes);
        }
    }

    #[test]
    fn sharded_unpool_grad_bit_exact() {
        let mut rng = Pcg32::seeded(59);
        let q = QFormat::paper16();
        let (cg, ph, pw, out_ch, k, pad) = (8, 4, 4, 6, 3, 1);
        let nb = 4;
        let g_elems = cg * ph * pw;
        let flat = quantize_slice(q, &rand_vec(&mut rng, nb * g_elems, -1.0, 1.0));
        let idx: Vec<u8> = (0..nb * g_elems).map(|_| rng.below(4) as u8).collect();
        let wf = rand_vec(&mut rng, out_ch * cg * k * k, -0.5, 0.5);
        let wbp = flip_transpose(&quantize_slice(q, &wf), cg, out_ch, k);
        let w_sc = flip_scatter(&wbp, out_ch, cg, k);
        let c = cfg();
        let run = |shards: usize| -> (Cost, Vec<i32>) {
            let mut cost = Cost::new();
            let mut out = Vec::new();
            input_grad_unpool_batch_into(
                &c,
                &mut cost,
                &mut EngineScratch::new(),
                &flat,
                nb,
                (cg, ph, pw),
                &idx,
                &w_sc,
                out_ch,
                k,
                pad,
                shards,
                &mut out,
            );
            (cost, out)
        };
        let (base_cost, base) = run(1);
        for shards in [2, 4, 7] {
            let (cost, got) = run(shards);
            assert_eq!(got, base, "shards {shards}");
            assert_eq!(cost.total_cycles(), base_cost.total_cycles());
        }
    }

    #[test]
    fn unroll_reduces_cycles_not_macs() {
        let mut rng = Pcg32::seeded(3);
        let q = QFormat::paper16();
        let x = quantize_slice(q, &rand_vec(&mut rng, 3 * 16 * 16, -1.0, 1.0));
        let wg = quantize_slice(q, &rand_vec(&mut rng, 8 * 3 * 9, -0.5, 0.5));
        let mut c1 = Cost::new();
        let mut c2 = Cost::new();
        let cfg1 = HwConfig::with_unroll(2, 2, 16);
        let cfg2 = HwConfig::with_unroll(8, 8, 16);
        forward(&cfg1, &mut c1, &x, (3, 16, 16), &wg, (8, 3), None, 1, Post::Plain);
        forward(&cfg2, &mut c2, &x, (3, 16, 16), &wg, (8, 3), None, 1, Post::Plain);
        assert_eq!(c1.macs, c2.macs);
        assert!(c1.compute_cycles > 3 * c2.compute_cycles, "{} vs {}", c1.compute_cycles, c2.compute_cycles);
        assert_eq!(c1.dram_read_bytes, c2.dram_read_bytes);
    }

    #[test]
    fn partial_tiles_handled() {
        // dims that do not divide the 8x8/16ch tiles
        let mut rng = Pcg32::seeded(17);
        let q = QFormat::paper16();
        let (ic, h, w, oc) = (5, 11, 9, 7);
        let xf = rand_vec(&mut rng, ic * h * w, -1.0, 1.0);
        let wf = rand_vec(&mut rng, oc * ic * 9, -0.4, 0.4);
        let mut cost = Cost::new();
        let r = forward(
            &cfg(),
            &mut cost,
            &quantize_slice(q, &xf),
            (ic, h, w),
            &quantize_slice(q, &wf),
            (oc, 3),
            None,
            1,
            Post::Plain,
        );
        let want = conv_ref(
            &xf.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            (ic, h, w),
            &wf.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            (oc, 3),
            &[],
            1,
        );
        assert_eq!(r.out.len(), want.len());
        for (i, (&g, &wv)) in r.out.iter().zip(&want).enumerate() {
            assert!((q.to_f32(g) as f64 - wv).abs() < 0.06, "elem {i}");
        }
    }
}
