//! Pareto frontier over evaluated design points.
//!
//! Objectives (all minimized): modeled attribution **cycles**, probe
//! **infidelity** (ppm vs the unquantized oracle — identically 0 when
//! the tuner runs quality-blind, collapsing the frontier to the
//! legacy latency × resource behavior), FP+BP **BRAM** banks, FP+BP
//! **DSP** slices — the latency/quality/resource tradeoff the
//! ApproXAI line of work frames XAI acceleration as. FF/LUT
//! participate only as deterministic tie-breakers: the affine fabric
//! model makes them near-collinear with the DSP axis, so adding them
//! as objectives would only pad the frontier with noise points.
//!
//! Everything here is order-independent and totally ordered: the same
//! set of points produces the same frontier (and the same serialized
//! bytes) no matter which thread scored what first — the reproducibility
//! contract `BENCH_dse.json` is held to.

use super::eval::DesignPoint;
use crate::fpga::Board;
use crate::hls::HwConfig;

/// Total order over every knob of a config — the ultimate tie-breaker,
/// so two distinct configs never compare equal.
#[allow(clippy::type_complexity)]
pub fn cfg_key(
    c: &HwConfig,
) -> (usize, usize, usize, usize, usize, usize, usize, usize, usize, u64, (bool, u32, u32, u64)) {
    (
        c.n_oh,
        c.n_ow,
        c.tile_oh,
        c.tile_ow,
        c.tile_oc,
        c.tile_ic,
        c.vmm_tile,
        c.vmm_in_tile,
        c.axi_bytes_per_cycle,
        c.pipeline_depth,
        (c.overlap_tiles, c.q.word_bits, c.q.frac_bits, c.axi_burst_overhead),
    )
}

/// Deterministic ranking key: fastest first, then faithful (probe
/// infidelity — 0 everywhere on quality-blind runs, so the legacy
/// order is untouched), then frugal (BRAM, DSP, LUT, FF), then the
/// full config key. `entries()[0]` under this key is the tuned winner
/// — the latency-optimal point, most faithful then cheapest among
/// equals.
#[allow(clippy::type_complexity)]
pub fn rank_key(
    p: &DesignPoint,
) -> (
    u64,
    u64,
    u32,
    u32,
    u32,
    u32,
    (usize, usize, usize, usize, usize, usize, usize, usize, usize, u64, (bool, u32, u32, u64)),
) {
    (
        p.cycles(),
        p.infidelity_ppm,
        p.util.bram_18k,
        p.util.dsp,
        p.util.lut,
        p.util.ff,
        cfg_key(&p.cfg),
    )
}

fn objectives(p: &DesignPoint) -> (u64, u64, u32, u32) {
    (p.cycles(), p.infidelity_ppm, p.util.bram_18k, p.util.dsp)
}

/// Does `a` Pareto-dominate `b` (no worse on every objective, strictly
/// better on at least one)?
pub fn dominates(a: &DesignPoint, b: &DesignPoint) -> bool {
    let (ac, af, ab, ad) = objectives(a);
    let (bc, bf, bb, bd) = objectives(b);
    ac <= bc
        && af <= bf
        && ab <= bb
        && ad <= bd
        && (ac < bc || af < bf || ab < bb || ad < bd)
}

/// The set of non-dominated design points seen so far.
#[derive(Clone, Debug, Default)]
pub struct Frontier {
    entries: Vec<DesignPoint>,
}

impl Frontier {
    pub fn new() -> Frontier {
        Frontier::default()
    }

    /// Offer a point. Returns whether it joined the frontier (points
    /// it dominates are evicted). Objective ties keep exactly one
    /// point — the one with the smaller [`rank_key`] — so the final
    /// set is independent of insertion order.
    pub fn insert(&mut self, p: DesignPoint) -> bool {
        for e in &self.entries {
            if dominates(e, &p) {
                return false;
            }
            if objectives(e) == objectives(&p) && rank_key(e) <= rank_key(&p) {
                return false;
            }
        }
        self.entries.retain(|e| !dominates(&p, e) && objectives(e) != objectives(&p));
        self.entries.push(p);
        true
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Frontier points sorted by [`rank_key`] (deterministic).
    pub fn entries(&self) -> Vec<&DesignPoint> {
        let mut v: Vec<&DesignPoint> = self.entries.iter().collect();
        v.sort_by_key(|p| rank_key(p));
        v
    }

    /// The tuned winner: minimal [`rank_key`] (fastest, then cheapest).
    pub fn best(&self) -> Option<&DesignPoint> {
        self.entries.iter().min_by_key(|p| rank_key(p))
    }

    /// The paper-style "maximally use the chip under the cap" pick:
    /// the frontier point with the highest mean utilization percentage
    /// on `board` (ties broken by [`rank_key`]).
    pub fn max_utilization(&self, board: Board) -> Option<&DesignPoint> {
        self.entries().into_iter().max_by(|a, b| {
            let mean = |p: &DesignPoint| board.percent(&p.util).iter().sum::<f64>() / 4.0;
            mean(a)
                .partial_cmp(&mean(b))
                .unwrap()
                // entries() is ascending by rank_key and max_by keeps
                // the *last* maximum, so prefer the earlier (smaller
                // key) entry by treating it as the greater one on ties
                .then(std::cmp::Ordering::Greater)
        })
    }

    /// Is this exact configuration on the frontier? (Note: NOT a
    /// Pareto-optimality test — an objective-tied twin with a smaller
    /// key replaces a config here without dominating it; use
    /// [`dominates`] against the explored set for that verdict.)
    pub fn contains_cfg(&self, cfg: &HwConfig) -> bool {
        self.entries.iter().any(|e| e.cfg == *cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::Utilization;

    fn point(cycles: u64, bram: u32, dsp: u32, n_oh: usize) -> DesignPoint {
        let cfg = {
            let mut c = HwConfig::with_unroll(n_oh, 1, 16);
            c.tile_oh = n_oh.max(8); // keep it legal for any n_oh
            c
        };
        let util = Utilization { bram_18k: bram, dsp, ff: 1000, lut: 2000 };
        DesignPoint { cfg, fp_util: util, util, fp_cycles: cycles, bp_cycles: 0, infidelity_ppm: 0 }
    }

    #[test]
    fn dominance_and_eviction() {
        let mut f = Frontier::new();
        assert!(f.insert(point(100, 10, 10, 1)));
        // dominated on all axes -> rejected
        assert!(!f.insert(point(110, 11, 11, 2)));
        // dominates the incumbent -> evicts it
        assert!(f.insert(point(90, 9, 9, 4)));
        assert_eq!(f.len(), 1);
        // incomparable (faster, hungrier) -> coexists
        assert!(f.insert(point(50, 20, 20, 8)));
        assert_eq!(f.len(), 2);
        assert_eq!(f.best().unwrap().cycles(), 50);
    }

    #[test]
    fn order_independent_and_tie_deterministic() {
        let pts = [
            point(100, 10, 10, 1),
            point(100, 10, 10, 2),
            point(80, 15, 10, 4),
            point(90, 12, 20, 8),
        ];
        let build = |order: &[usize]| {
            let mut f = Frontier::new();
            for &i in order {
                f.insert(pts[i].clone());
            }
            f.entries().iter().map(|p| (rank_key(p))).collect::<Vec<_>>()
        };
        let a = build(&[0, 1, 2, 3]);
        let b = build(&[3, 2, 1, 0]);
        let c = build(&[1, 3, 0, 2]);
        assert_eq!(a, b);
        assert_eq!(a, c);
        // exactly one of the two objective-tied points survives — the
        // smaller config key (n_oh=1)
        let f = {
            let mut f = Frontier::new();
            for p in &pts {
                f.insert(p.clone());
            }
            f
        };
        assert!(f.contains_cfg(&pts[0].cfg));
        assert!(!f.contains_cfg(&pts[1].cfg));
    }

    #[test]
    fn quality_axis_breaks_objective_ties_and_dominates() {
        // two candidates identical on cycles/BRAM/DSP but not fidelity:
        // quality-blind they tie (one survives by config key); with the
        // probe on, the faithful one strictly dominates the other
        let faithful = point(100, 10, 10, 1);
        let mut garbage = point(100, 10, 10, 2);
        garbage.infidelity_ppm = 900_000;
        assert!(dominates(&faithful, &garbage));
        assert!(!dominates(&garbage, &faithful));
        let mut f = Frontier::new();
        assert!(f.insert(garbage.clone()));
        assert!(f.insert(faithful.clone()));
        assert_eq!(f.len(), 1, "the low-fidelity twin must be evicted");
        assert!(f.contains_cfg(&faithful.cfg));
        assert!(!f.contains_cfg(&garbage.cfg));
        // insertion order must not matter
        let mut g = Frontier::new();
        g.insert(faithful.clone());
        g.insert(garbage.clone());
        assert!(g.contains_cfg(&faithful.cfg) && !g.contains_cfg(&garbage.cfg));
        // a faster-but-unfaithful point still coexists: quality is a
        // tradeoff axis, not a filter
        let mut fast_garbage = point(50, 10, 10, 4);
        fast_garbage.infidelity_ppm = 900_000;
        assert!(f.insert(fast_garbage));
        assert_eq!(f.len(), 2);
        // the winner prefers faithful among equal-latency points
        assert_eq!(f.best().unwrap().cycles(), 50);
    }

    #[test]
    fn max_utilization_prefers_the_fuller_chip() {
        let mut f = Frontier::new();
        f.insert(point(100, 10, 30, 1)); // frugal
        f.insert(point(60, 40, 120, 8)); // fast and hungry
        let m = f.max_utilization(Board::PynqZ2).unwrap();
        assert_eq!(m.util.dsp, 120);
        // and the latency pick is the same point here (it dominates on
        // cycles but not resources — both are on the frontier)
        assert_eq!(f.len(), 2);
        assert_eq!(f.best().unwrap().cycles(), 60);
    }
}
