//! Candidate evaluation: prune on resources first, pay for the cost
//! model only on survivors.
//!
//! The two-stage shape mirrors how an HLS engineer explores a design
//! space: a resource estimate (`fpga::resources::feasibility`) costs
//! microseconds, a full modeled-cycle pass costs milliseconds, so a
//! candidate that cannot be placed on the board is rejected before the
//! simulator ever runs. Survivors are scored by executing one probe
//! attribution on the *existing* cycle model — `Simulator::with_config`
//! over a shared `Arc<Plan>`, the same engines/ledger the serving path
//! uses — so a DSE number and a `attrax report` number can never
//! disagree. The cycle/traffic ledger is structural (tile loop trip
//! counts, not data values), so one deterministic probe image fully
//! characterizes a candidate.
//!
//! Plans are quantized per fixed-point format: the evaluator builds
//! one `Plan` per distinct `QFormat` in the space up front, and every
//! candidate borrows the plan matching its `q` (a config swap is an
//! `Arc` bump, never a re-quantization).

use std::sync::Arc;

use crate::attribution::Method;
use crate::fpga::{self, Board, Feasibility, Utilization};
use crate::fx::QFormat;
use crate::hls::{ConfigError, HwConfig};
use crate::model::{Network, Params};
use crate::sched::{AttrOptions, BatchOutput, Plan, Simulator, Workspace};
use crate::util::rng::Pcg32;

/// One fully evaluated design point: the candidate configuration, its
/// estimated FP / FP+BP resource builds, its modeled attribution
/// cycles (per phase, under the tile-latency model the config selects
/// — see `Cost::cycles_under`) and, when the quality probe is enabled,
/// the heatmap infidelity against the unquantized reference oracle.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub cfg: HwConfig,
    /// Inference-only build estimate.
    pub fp_util: Utilization,
    /// Feature-attribution (FP+BP) build estimate — the build that
    /// must fit the board.
    pub util: Utilization,
    pub fp_cycles: u64,
    pub bp_cycles: u64,
    /// `(1 − Pearson(probe heatmap, oracle heatmap))` in
    /// parts-per-million (`xeval::fidelity::infidelity_ppm`); `0` when
    /// the evaluator runs quality-blind, so the frontier degenerates
    /// to the latency × BRAM × DSP behavior of the quality-off tuner.
    pub infidelity_ppm: u64,
}

impl DesignPoint {
    /// Modeled cycles for one full attribution (FP + BP).
    pub fn cycles(&self) -> u64 {
        self.fp_cycles + self.bp_cycles
    }

    pub fn latency_ms(&self, freq_mhz: f64) -> f64 {
        self.cycles() as f64 / (freq_mhz * 1e3)
    }

    /// Probe-heatmap fidelity as a Pearson correlation (1.0 = exact or
    /// quality probe disabled).
    pub fn fidelity(&self) -> f64 {
        1.0 - self.infidelity_ppm as f64 / 1e6
    }
}

/// Why a candidate never reached the cost model.
#[derive(Clone, Debug)]
pub enum Pruned {
    /// Rejected by the central legality gate ([`HwConfig::validate`]).
    Invalid(ConfigError),
    /// Legal, but the FP+BP build exceeds the board (the offending
    /// utilization estimate is attached).
    OverCapacity(Utilization),
}

impl std::fmt::Display for Pruned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Pruned::Invalid(e) => write!(f, "invalid config: {e}"),
            Pruned::OverCapacity(u) => write!(
                f,
                "over capacity: BRAM {} DSP {} FF {} LUT {}",
                u.bram_18k, u.dsp, u.ff, u.lut
            ),
        }
    }
}

/// The quality probe's reference: the unquantized oracle heatmap for
/// the probe image, and the class it explains.
struct QualityRef {
    target: usize,
    reference: Vec<f32>,
}

/// Shared, read-only candidate evaluator (safe to borrow from scoped
/// scoring threads): the network, one quantized plan per fixed-point
/// format, the attribution method under tuning and the probe image.
pub struct Evaluator {
    net: Network,
    method: Method,
    probe: Vec<f32>,
    /// One plan per distinct `QFormat` (tiny; linear lookup).
    plans: Vec<Arc<Plan>>,
    /// `Some` when every scored candidate also pays for a fidelity
    /// probe against the oracle reference ([`Evaluator::enable_quality`]).
    quality: Option<QualityRef>,
}

impl Evaluator {
    /// Quantize one plan per distinct format in `qs` and synthesize a
    /// deterministic probe image. `params` only shapes the plan — the
    /// cycle ledger is weight-value-independent.
    pub fn new(
        net: &Network,
        params: &Params,
        qs: &[QFormat],
        method: Method,
        probe_seed: u64,
    ) -> anyhow::Result<Evaluator> {
        anyhow::ensure!(!qs.is_empty(), "evaluator needs at least one fixed-point format");
        let mut plans: Vec<Arc<Plan>> = Vec::new();
        for &q in qs {
            if plans.iter().any(|p| p.cfg.q == q) {
                continue;
            }
            let mut cfg = HwConfig::with_unroll(1, 1, 16);
            cfg.q = q;
            plans.push(Arc::new(Plan::new(net.clone(), params, cfg)?));
        }
        let mut rng = Pcg32::seeded(probe_seed);
        let probe = (0..net.input.elems()).map(|_| rng.f32()).collect();
        Ok(Evaluator { net: net.clone(), method, probe, plans, quality: None })
    }

    pub fn method(&self) -> Method {
        self.method
    }

    /// Turn on the fidelity probe: compute the unquantized oracle
    /// heatmap for the probe image once; every scored candidate is
    /// then compared against it (`DesignPoint::infidelity_ppm`). Both
    /// paths explain the oracle's predicted class, so a prediction
    /// flip under quantization registers as infidelity rather than as
    /// two heatmaps faithfully explaining different classes.
    pub fn enable_quality(&mut self, params: &Params) -> anyhow::Result<()> {
        let oracle = crate::xeval::Oracle::new(&self.net, params)?;
        let r = oracle.attribute(&self.probe, self.method, None);
        self.quality = Some(QualityRef { target: r.pred, reference: r.relevance });
        Ok(())
    }

    pub fn quality_enabled(&self) -> bool {
        self.quality.is_some()
    }

    /// Stage 1 — the cheap gate: legality, then resource estimate
    /// against the board's capacity. No cycle modeling happens here.
    pub fn prune(&self, board: Board, cfg: &HwConfig) -> Result<Feasibility, Pruned> {
        cfg.validate().map_err(Pruned::Invalid)?;
        let f = fpga::feasibility(board, cfg, &self.net, self.method);
        if !f.fits {
            return Err(Pruned::OverCapacity(f.fp_bp));
        }
        Ok(f)
    }

    /// Stage 2 — the cost pass: run one probe attribution under `cfg`
    /// on the shared plan, reusing the caller's workspace/output slabs
    /// (scoring threads keep one pair warm across a whole chunk), and
    /// return per-phase cycles under the tile-latency model `cfg`
    /// selects plus the fidelity-probe infidelity (0 when quality is
    /// off; the heatmap is already in `out`, so the probe costs one
    /// correlation, never a second attribution). `cfg` must be valid
    /// and carry a format the evaluator planned.
    fn probe_point(
        &self,
        ws: &mut Workspace,
        out: &mut BatchOutput,
        cfg: &HwConfig,
    ) -> (u64, u64, u64) {
        let plan = self
            .plans
            .iter()
            .find(|p| p.cfg.q == cfg.q)
            .expect("candidate QFormat was not in the evaluator's space");
        let sim = Simulator::with_config(plan.clone(), *cfg).expect("pruned candidates are valid");
        let probe: &[f32] = &self.probe;
        // the BP start class is structural noise for the ledger (every
        // layer is walked regardless), so pinning it to the oracle's
        // prediction changes nothing for quality-blind runs
        let opts = match &self.quality {
            Some(qr) => AttrOptions { target: Some(qr.target), ..Default::default() },
            None => AttrOptions::default(),
        };
        sim.attribute_batch_into(ws, &[probe], self.method, opts, false, out);
        let infidelity_ppm = match &self.quality {
            Some(qr) => crate::xeval::fidelity::infidelity_ppm(out.relevance_of(0), &qr.reference),
            None => 0,
        };
        (out.fp_cost.cycles_under(cfg), out.bp_cost.cycles_under(cfg), infidelity_ppm)
    }

    /// Cost pass reusing the resource estimates the prune gate already
    /// computed (the driver path: estimates are never paid twice).
    pub fn score_feasible(
        &self,
        ws: &mut Workspace,
        out: &mut BatchOutput,
        cfg: &HwConfig,
        feas: &Feasibility,
    ) -> DesignPoint {
        let (fp_cycles, bp_cycles, infidelity_ppm) = self.probe_point(ws, out, cfg);
        DesignPoint {
            cfg: *cfg,
            fp_util: feas.fp,
            util: feas.fp_bp,
            fp_cycles,
            bp_cycles,
            infidelity_ppm,
        }
    }

    /// Cost pass that estimates resources itself (for callers without
    /// a prior [`Evaluator::prune`] result).
    pub fn score_with(
        &self,
        ws: &mut Workspace,
        out: &mut BatchOutput,
        cfg: &HwConfig,
    ) -> DesignPoint {
        let (fp_cycles, bp_cycles, infidelity_ppm) = self.probe_point(ws, out, cfg);
        DesignPoint {
            cfg: *cfg,
            fp_util: fpga::estimate_fp(cfg, &self.net),
            util: fpga::estimate_fp_bp(cfg, &self.net, self.method),
            fp_cycles,
            bp_cycles,
            infidelity_ppm,
        }
    }

    /// [`Evaluator::score_with`] with throwaway slabs.
    pub fn score(&self, cfg: &HwConfig) -> DesignPoint {
        let mut ws = Workspace::with_shards(1);
        let mut out = BatchOutput::new();
        self.score_with(&mut ws, &mut out, cfg)
    }

    /// Prune, then score: the full per-candidate pipeline.
    pub fn evaluate(&self, board: Board, cfg: &HwConfig) -> Result<DesignPoint, Pruned> {
        self.prune(board, cfg)?;
        Ok(self.score(cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::tests_support::tiny_net_params;

    fn evaluator() -> Evaluator {
        let (net, params) = tiny_net_params(11);
        Evaluator::new(&net, &params, &[QFormat::paper16()], Method::Guided, 7).unwrap()
    }

    #[test]
    fn prune_rejects_before_cost() {
        let ev = evaluator();
        // illegal knob -> typed Invalid
        let mut bad = HwConfig::pynq_z2();
        bad.n_oh = 3;
        assert!(matches!(ev.prune(Board::PynqZ2, &bad), Err(Pruned::Invalid(_))));
        // legal but too large for the small board -> OverCapacity
        let big = HwConfig::zcu104();
        match ev.prune(Board::PynqZ2, &big) {
            Err(Pruned::OverCapacity(u)) => assert!(!Board::PynqZ2.fits(&u)),
            other => panic!("expected capacity prune, got {other:?}"),
        }
        // the board's own config passes with headroom reported
        let f = ev.prune(Board::PynqZ2, &HwConfig::pynq_z2()).unwrap();
        assert!(f.fits);
    }

    #[test]
    fn score_is_deterministic_and_structural() {
        let ev = evaluator();
        let cfg = HwConfig::pynq_z2();
        let a = ev.score(&cfg);
        let b = ev.score(&cfg);
        assert!(a.cycles() > 0);
        assert_eq!(a.cycles(), b.cycles());
        assert_eq!(a.util, b.util);
        // a wider AXI strictly reduces modeled cycles (same compute)
        let mut fast = cfg;
        fast.axi_bytes_per_cycle = 16;
        assert!(ev.score(&fast).cycles() < a.cycles());
        // dataflow overlap reduces cycles further but costs BRAM
        let mut ovl = fast;
        ovl.overlap_tiles = true;
        let o = ev.score(&ovl);
        assert!(o.cycles() < ev.score(&fast).cycles());
        assert!(o.util.bram_18k > a.util.bram_18k);
    }

    #[test]
    fn quality_probe_scores_formats_apart() {
        let (net, params) = tiny_net_params(11);
        let q_lo = QFormat::new(16, 2);
        let mut ev =
            Evaluator::new(&net, &params, &[QFormat::paper16(), q_lo], Method::Guided, 7).unwrap();
        // quality off: every point reports zero infidelity
        let hi_cfg = HwConfig::pynq_z2();
        let mut lo_cfg = hi_cfg;
        lo_cfg.q = q_lo;
        assert_eq!(ev.score(&hi_cfg).infidelity_ppm, 0);
        assert!(!ev.quality_enabled());
        // quality on: the paper format tracks the oracle, the 2-bit
        // fraction format does not — same cycles, same resources
        ev.enable_quality(&params).unwrap();
        assert!(ev.quality_enabled());
        let hi = ev.score(&hi_cfg);
        let lo = ev.score(&lo_cfg);
        assert!(
            hi.infidelity_ppm < lo.infidelity_ppm,
            "Q16.9 {} vs Q16.2 {}",
            hi.infidelity_ppm,
            lo.infidelity_ppm
        );
        assert!(hi.fidelity() > 0.8, "paper-format probe fidelity {}", hi.fidelity());
        assert_eq!(hi.cycles(), lo.cycles(), "word width unchanged => same cycle model");
        assert_eq!(hi.util, lo.util);
        // deterministic: same probe, same score
        assert_eq!(ev.score(&lo_cfg).infidelity_ppm, lo.infidelity_ppm);
    }

    #[test]
    fn evaluate_chains_prune_and_score() {
        let ev = evaluator();
        let p = ev.evaluate(Board::Zcu104, &HwConfig::zcu104()).unwrap();
        assert!(p.cycles() > 0);
        assert!(Board::Zcu104.fits(&p.util));
        assert!(ev.evaluate(Board::PynqZ2, &HwConfig::zcu104()).is_err());
    }
}
