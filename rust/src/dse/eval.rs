//! Candidate evaluation: prune on resources first, pay for the cost
//! model only on survivors.
//!
//! The two-stage shape mirrors how an HLS engineer explores a design
//! space: a resource estimate (`fpga::resources::feasibility`) costs
//! microseconds, a full modeled-cycle pass costs milliseconds, so a
//! candidate that cannot be placed on the board is rejected before the
//! simulator ever runs. Survivors are scored by executing one probe
//! attribution on the *existing* cycle model — `Simulator::with_config`
//! over a shared `Arc<Plan>`, the same engines/ledger the serving path
//! uses — so a DSE number and a `attrax report` number can never
//! disagree. The cycle/traffic ledger is structural (tile loop trip
//! counts, not data values), so one deterministic probe image fully
//! characterizes a candidate.
//!
//! Plans are quantized per fixed-point format: the evaluator builds
//! one `Plan` per distinct `QFormat` in the space up front, and every
//! candidate borrows the plan matching its `q` (a config swap is an
//! `Arc` bump, never a re-quantization).

use std::sync::Arc;

use crate::attribution::Method;
use crate::fpga::{self, Board, Feasibility, Utilization};
use crate::fx::QFormat;
use crate::hls::{ConfigError, HwConfig};
use crate::model::{Network, Params};
use crate::sched::{AttrOptions, BatchOutput, Plan, Simulator, Workspace};
use crate::util::rng::Pcg32;

/// One fully evaluated design point: the candidate configuration, its
/// estimated FP / FP+BP resource builds and its modeled attribution
/// cycles (per phase, under the tile-latency model the config selects
/// — see `Cost::cycles_under`).
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub cfg: HwConfig,
    /// Inference-only build estimate.
    pub fp_util: Utilization,
    /// Feature-attribution (FP+BP) build estimate — the build that
    /// must fit the board.
    pub util: Utilization,
    pub fp_cycles: u64,
    pub bp_cycles: u64,
}

impl DesignPoint {
    /// Modeled cycles for one full attribution (FP + BP).
    pub fn cycles(&self) -> u64 {
        self.fp_cycles + self.bp_cycles
    }

    pub fn latency_ms(&self, freq_mhz: f64) -> f64 {
        self.cycles() as f64 / (freq_mhz * 1e3)
    }
}

/// Why a candidate never reached the cost model.
#[derive(Clone, Debug)]
pub enum Pruned {
    /// Rejected by the central legality gate ([`HwConfig::validate`]).
    Invalid(ConfigError),
    /// Legal, but the FP+BP build exceeds the board (the offending
    /// utilization estimate is attached).
    OverCapacity(Utilization),
}

impl std::fmt::Display for Pruned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Pruned::Invalid(e) => write!(f, "invalid config: {e}"),
            Pruned::OverCapacity(u) => write!(
                f,
                "over capacity: BRAM {} DSP {} FF {} LUT {}",
                u.bram_18k, u.dsp, u.ff, u.lut
            ),
        }
    }
}

/// Shared, read-only candidate evaluator (safe to borrow from scoped
/// scoring threads): the network, one quantized plan per fixed-point
/// format, the attribution method under tuning and the probe image.
pub struct Evaluator {
    net: Network,
    method: Method,
    probe: Vec<f32>,
    /// One plan per distinct `QFormat` (tiny; linear lookup).
    plans: Vec<Arc<Plan>>,
}

impl Evaluator {
    /// Quantize one plan per distinct format in `qs` and synthesize a
    /// deterministic probe image. `params` only shapes the plan — the
    /// cycle ledger is weight-value-independent.
    pub fn new(
        net: &Network,
        params: &Params,
        qs: &[QFormat],
        method: Method,
        probe_seed: u64,
    ) -> anyhow::Result<Evaluator> {
        anyhow::ensure!(!qs.is_empty(), "evaluator needs at least one fixed-point format");
        let mut plans: Vec<Arc<Plan>> = Vec::new();
        for &q in qs {
            if plans.iter().any(|p| p.cfg.q == q) {
                continue;
            }
            let mut cfg = HwConfig::with_unroll(1, 1, 16);
            cfg.q = q;
            plans.push(Arc::new(Plan::new(net.clone(), params, cfg)?));
        }
        let mut rng = Pcg32::seeded(probe_seed);
        let probe = (0..net.input.elems()).map(|_| rng.f32()).collect();
        Ok(Evaluator { net: net.clone(), method, probe, plans })
    }

    pub fn method(&self) -> Method {
        self.method
    }

    /// Stage 1 — the cheap gate: legality, then resource estimate
    /// against the board's capacity. No cycle modeling happens here.
    pub fn prune(&self, board: Board, cfg: &HwConfig) -> Result<Feasibility, Pruned> {
        cfg.validate().map_err(Pruned::Invalid)?;
        let f = fpga::feasibility(board, cfg, &self.net, self.method);
        if !f.fits {
            return Err(Pruned::OverCapacity(f.fp_bp));
        }
        Ok(f)
    }

    /// Stage 2 — the cost pass: run one probe attribution under `cfg`
    /// on the shared plan, reusing the caller's workspace/output slabs
    /// (scoring threads keep one pair warm across a whole chunk), and
    /// return per-phase cycles under the tile-latency model `cfg`
    /// selects. `cfg` must be valid and carry a format the evaluator
    /// planned.
    fn probe_cycles(
        &self,
        ws: &mut Workspace,
        out: &mut BatchOutput,
        cfg: &HwConfig,
    ) -> (u64, u64) {
        let plan = self
            .plans
            .iter()
            .find(|p| p.cfg.q == cfg.q)
            .expect("candidate QFormat was not in the evaluator's space");
        let sim = Simulator::with_config(plan.clone(), *cfg).expect("pruned candidates are valid");
        let probe: &[f32] = &self.probe;
        sim.attribute_batch_into(ws, &[probe], self.method, AttrOptions::default(), false, out);
        (out.fp_cost.cycles_under(cfg), out.bp_cost.cycles_under(cfg))
    }

    /// Cost pass reusing the resource estimates the prune gate already
    /// computed (the driver path: estimates are never paid twice).
    pub fn score_feasible(
        &self,
        ws: &mut Workspace,
        out: &mut BatchOutput,
        cfg: &HwConfig,
        feas: &Feasibility,
    ) -> DesignPoint {
        let (fp_cycles, bp_cycles) = self.probe_cycles(ws, out, cfg);
        DesignPoint { cfg: *cfg, fp_util: feas.fp, util: feas.fp_bp, fp_cycles, bp_cycles }
    }

    /// Cost pass that estimates resources itself (for callers without
    /// a prior [`Evaluator::prune`] result).
    pub fn score_with(
        &self,
        ws: &mut Workspace,
        out: &mut BatchOutput,
        cfg: &HwConfig,
    ) -> DesignPoint {
        let (fp_cycles, bp_cycles) = self.probe_cycles(ws, out, cfg);
        DesignPoint {
            cfg: *cfg,
            fp_util: fpga::estimate_fp(cfg, &self.net),
            util: fpga::estimate_fp_bp(cfg, &self.net, self.method),
            fp_cycles,
            bp_cycles,
        }
    }

    /// [`Evaluator::score_with`] with throwaway slabs.
    pub fn score(&self, cfg: &HwConfig) -> DesignPoint {
        let mut ws = Workspace::with_shards(1);
        let mut out = BatchOutput::new();
        self.score_with(&mut ws, &mut out, cfg)
    }

    /// Prune, then score: the full per-candidate pipeline.
    pub fn evaluate(&self, board: Board, cfg: &HwConfig) -> Result<DesignPoint, Pruned> {
        self.prune(board, cfg)?;
        Ok(self.score(cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::tests_support::tiny_net_params;

    fn evaluator() -> Evaluator {
        let (net, params) = tiny_net_params(11);
        Evaluator::new(&net, &params, &[QFormat::paper16()], Method::Guided, 7).unwrap()
    }

    #[test]
    fn prune_rejects_before_cost() {
        let ev = evaluator();
        // illegal knob -> typed Invalid
        let mut bad = HwConfig::pynq_z2();
        bad.n_oh = 3;
        assert!(matches!(ev.prune(Board::PynqZ2, &bad), Err(Pruned::Invalid(_))));
        // legal but too large for the small board -> OverCapacity
        let big = HwConfig::zcu104();
        match ev.prune(Board::PynqZ2, &big) {
            Err(Pruned::OverCapacity(u)) => assert!(!Board::PynqZ2.fits(&u)),
            other => panic!("expected capacity prune, got {other:?}"),
        }
        // the board's own config passes with headroom reported
        let f = ev.prune(Board::PynqZ2, &HwConfig::pynq_z2()).unwrap();
        assert!(f.fits);
    }

    #[test]
    fn score_is_deterministic_and_structural() {
        let ev = evaluator();
        let cfg = HwConfig::pynq_z2();
        let a = ev.score(&cfg);
        let b = ev.score(&cfg);
        assert!(a.cycles() > 0);
        assert_eq!(a.cycles(), b.cycles());
        assert_eq!(a.util, b.util);
        // a wider AXI strictly reduces modeled cycles (same compute)
        let mut fast = cfg;
        fast.axi_bytes_per_cycle = 16;
        assert!(ev.score(&fast).cycles() < a.cycles());
        // dataflow overlap reduces cycles further but costs BRAM
        let mut ovl = fast;
        ovl.overlap_tiles = true;
        let o = ev.score(&ovl);
        assert!(o.cycles() < ev.score(&fast).cycles());
        assert!(o.util.bram_18k > a.util.bram_18k);
    }

    #[test]
    fn evaluate_chains_prune_and_score() {
        let ev = evaluator();
        let p = ev.evaluate(Board::Zcu104, &HwConfig::zcu104()).unwrap();
        assert!(p.cycles() > 0);
        assert!(Board::Zcu104.fits(&p.util));
        assert!(ev.evaluate(Board::PynqZ2, &HwConfig::zcu104()).is_err());
    }
}
