//! Declarative search-space description over [`HwConfig`] knobs.
//!
//! A [`Space`] is a list of candidate values per knob; the candidate
//! set is the cross product, addressed by a mixed-radix **raw index**
//! in `0..raw_size()`. Indices are the search currency: sampling,
//! neighborhood moves and dedup all happen in index space, and a
//! config materializes only when a candidate is actually considered.
//!
//! Legality is *not* re-implemented here: every decoded config goes
//! through the one central gate, [`HwConfig::validate`] — the space
//! enumerates candidates, the config type owns the constraints
//! (divisibility, nonzero knobs), so the two can never drift apart.
//!
//! `axi_burst_overhead` is deliberately not an axis: it is a property
//! of the memory controller, not of the accelerator design, so every
//! candidate inherits the platform constant. The fixed-point format
//! *is* an axis (the datapath is precision-configurable), but the
//! predefined spaces pin it to the paper's Q16.9 — precision is an
//! accuracy contract with the serving layer, not a free latency knob.

use crate::fx::QFormat;
use crate::hls::{ConfigError, HwConfig};
use crate::util::rng::Pcg32;

/// Candidate values per `HwConfig` knob (the cross product is the
/// search space). Empty axes are illegal.
#[derive(Clone, Debug)]
pub struct Space {
    pub n_oh: Vec<usize>,
    pub n_ow: Vec<usize>,
    pub tile_oh: Vec<usize>,
    pub tile_ow: Vec<usize>,
    pub tile_oc: Vec<usize>,
    pub tile_ic: Vec<usize>,
    pub vmm_tile: Vec<usize>,
    pub vmm_in_tile: Vec<usize>,
    pub axi_bytes_per_cycle: Vec<usize>,
    pub pipeline_depth: Vec<u64>,
    /// The §IV-B dataflow knob (double-buffered tile overlap).
    pub overlap_tiles: Vec<bool>,
    pub q: Vec<QFormat>,
}

pub const N_AXES: usize = 12;

impl Space {
    /// The board-tuning space: every knob the paper's configuration
    /// step varies, plus tiling/bus/dataflow dimensions it fixes.
    /// ~97k raw candidates — beam territory, not exhaustive.
    pub fn paper() -> Space {
        Space {
            n_oh: vec![1, 2, 4, 8, 16],
            n_ow: vec![1, 2, 4, 8, 16],
            tile_oh: vec![8, 16],
            tile_ow: vec![8, 16],
            tile_oc: vec![8, 16, 32],
            tile_ic: vec![8, 16, 32],
            vmm_tile: vec![16, 32, 64],
            vmm_in_tile: vec![128, 256, 512],
            axi_bytes_per_cycle: vec![4, 8, 16],
            pipeline_depth: vec![4, 8],
            overlap_tiles: vec![false, true],
            q: vec![QFormat::paper16()],
        }
    }

    /// Tiny fully-enumerable space (16 raw candidates, all valid) for
    /// `attrax tune --smoke`, CI and tests.
    pub fn smoke() -> Space {
        Space {
            n_oh: vec![2, 4],
            n_ow: vec![4],
            tile_oh: vec![8],
            tile_ow: vec![8],
            tile_oc: vec![16],
            tile_ic: vec![16],
            vmm_tile: vec![16, 32],
            vmm_in_tile: vec![256],
            axi_bytes_per_cycle: vec![8, 16],
            pipeline_depth: vec![8],
            overlap_tiles: vec![false, true],
            q: vec![QFormat::paper16()],
        }
    }

    /// The [`Space::smoke`] space with the fixed-point format opened as
    /// a real axis (32 raw candidates): the paper's Q16.9 plus a
    /// same-width, 2-fraction-bit format. Both cost identical cycles,
    /// traffic and resources (the models see only the word *width*),
    /// so a quality-blind tuner cannot tell them apart — the ISSUE-5
    /// demonstration space for `attrax tune --smoke --quality`.
    pub fn smoke_quality() -> Space {
        Space { q: vec![QFormat::paper16(), QFormat::new(16, 2)], ..Space::smoke() }
    }

    /// Axis lengths in canonical order (the mixed-radix digits of a
    /// raw index, least significant first).
    pub fn axes(&self) -> [usize; N_AXES] {
        [
            self.n_oh.len(),
            self.n_ow.len(),
            self.tile_oh.len(),
            self.tile_ow.len(),
            self.tile_oc.len(),
            self.tile_ic.len(),
            self.vmm_tile.len(),
            self.vmm_in_tile.len(),
            self.axi_bytes_per_cycle.len(),
            self.pipeline_depth.len(),
            self.overlap_tiles.len(),
            self.q.len(),
        ]
    }

    /// Total raw candidates (valid and invalid). Panics on empty axes.
    pub fn raw_size(&self) -> u64 {
        self.axes()
            .iter()
            .map(|&l| {
                assert!(l > 0, "empty space axis");
                l as u64
            })
            .product()
    }

    fn decode(&self, mut idx: u64) -> [usize; N_AXES] {
        assert!(idx < self.raw_size(), "index {idx} out of space");
        let mut digits = [0usize; N_AXES];
        for (d, len) in digits.iter_mut().zip(self.axes()) {
            *d = (idx % len as u64) as usize;
            idx /= len as u64;
        }
        digits
    }

    fn encode(&self, digits: &[usize; N_AXES]) -> u64 {
        let mut idx = 0u64;
        let mut stride = 1u64;
        for (&d, len) in digits.iter().zip(self.axes()) {
            debug_assert!(d < len);
            idx += d as u64 * stride;
            stride *= len as u64;
        }
        idx
    }

    /// Materialize the candidate at a raw index (legality NOT checked
    /// — pair with [`Space::checked_at`] or [`HwConfig::validate`]).
    pub fn config_at(&self, idx: u64) -> HwConfig {
        let d = self.decode(idx);
        let mut cfg = HwConfig::with_unroll(self.n_oh[d[0]], self.n_ow[d[1]], self.vmm_tile[d[6]]);
        cfg.tile_oh = self.tile_oh[d[2]];
        cfg.tile_ow = self.tile_ow[d[3]];
        cfg.tile_oc = self.tile_oc[d[4]];
        cfg.tile_ic = self.tile_ic[d[5]];
        cfg.vmm_in_tile = self.vmm_in_tile[d[7]];
        cfg.axi_bytes_per_cycle = self.axi_bytes_per_cycle[d[8]];
        cfg.pipeline_depth = self.pipeline_depth[d[9]];
        cfg.overlap_tiles = self.overlap_tiles[d[10]];
        cfg.q = self.q[d[11]];
        cfg
    }

    /// The candidate at `idx`, run through the central legality gate.
    pub fn checked_at(&self, idx: u64) -> Result<HwConfig, ConfigError> {
        let cfg = self.config_at(idx);
        cfg.validate()?;
        Ok(cfg)
    }

    /// The raw index of a config whose every knob value appears in
    /// this space (None otherwise) — used to seed the search with the
    /// board's default design point.
    pub fn index_of(&self, cfg: &HwConfig) -> Option<u64> {
        let pos = |xs: &[usize], v: usize| xs.iter().position(|&x| x == v);
        let digits = [
            pos(&self.n_oh, cfg.n_oh)?,
            pos(&self.n_ow, cfg.n_ow)?,
            pos(&self.tile_oh, cfg.tile_oh)?,
            pos(&self.tile_ow, cfg.tile_ow)?,
            pos(&self.tile_oc, cfg.tile_oc)?,
            pos(&self.tile_ic, cfg.tile_ic)?,
            pos(&self.vmm_tile, cfg.vmm_tile)?,
            pos(&self.vmm_in_tile, cfg.vmm_in_tile)?,
            self.axi_bytes_per_cycle.iter().position(|&x| x == cfg.axi_bytes_per_cycle)?,
            self.pipeline_depth.iter().position(|&x| x == cfg.pipeline_depth)?,
            self.overlap_tiles.iter().position(|&x| x == cfg.overlap_tiles)?,
            self.q.iter().position(|&x| x == cfg.q)?,
        ];
        Some(self.encode(&digits))
    }

    /// Every valid candidate, ascending by raw index. Materializes the
    /// whole space — only for spaces the caller knows are small (the
    /// tuner switches to sampled search beyond its budget).
    pub fn enumerate(&self) -> Vec<(u64, HwConfig)> {
        (0..self.raw_size())
            .filter_map(|idx| self.checked_at(idx).ok().map(|cfg| (idx, cfg)))
            .collect()
    }

    /// A uniformly random raw index (one digit per axis, so no modulo
    /// bias regardless of the space size).
    pub fn sample(&self, rng: &mut Pcg32) -> u64 {
        let mut digits = [0usize; N_AXES];
        for (d, len) in digits.iter_mut().zip(self.axes()) {
            *d = rng.below(len as u32) as usize;
        }
        self.encode(&digits)
    }

    /// One-step neighbors of `idx`: each axis moved one position up or
    /// down its value list, all other knobs held. Deterministic order
    /// (axis-major, -1 before +1); legality is the caller's check.
    pub fn neighbors(&self, idx: u64) -> Vec<u64> {
        let digits = self.decode(idx);
        let axes = self.axes();
        let mut out = Vec::with_capacity(2 * N_AXES);
        for ax in 0..N_AXES {
            for delta in [-1isize, 1] {
                let d = digits[ax] as isize + delta;
                if d < 0 || d as usize >= axes[ax] {
                    continue;
                }
                let mut moved = digits;
                moved[ax] = d as usize;
                out.push(self.encode(&moved));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_space_is_tiny_and_fully_valid() {
        let s = Space::smoke();
        assert_eq!(s.raw_size(), 16);
        let all = s.enumerate();
        assert_eq!(all.len(), 16, "every smoke candidate is legal");
        for (idx, cfg) in &all {
            cfg.validate().unwrap();
            assert_eq!(s.config_at(*idx), *cfg);
        }
    }

    #[test]
    fn smoke_quality_space_opens_the_format_axis() {
        let s = Space::smoke_quality();
        assert_eq!(s.raw_size(), 32);
        assert_eq!(s.enumerate().len(), 32, "every candidate is legal");
        // every knob tuple appears once per format
        let with_q = |q: QFormat| {
            s.enumerate().into_iter().filter(|(_, c)| c.q == q).count()
        };
        assert_eq!(with_q(QFormat::paper16()), 16);
        assert_eq!(with_q(QFormat::new(16, 2)), 16);
    }

    #[test]
    fn paper_space_counts_and_validity() {
        let s = Space::paper();
        assert_eq!(s.raw_size(), 97_200);
        // spot-check: an index decoding to n_oh=16, tile_oh=8 is
        // rejected by the central gate, not silently emitted
        let bad = s
            .index_of(&{
                let mut c = HwConfig::with_unroll(16, 1, 16);
                c.vmm_in_tile = 128;
                c.axi_bytes_per_cycle = 4;
                c.pipeline_depth = 4;
                c
            })
            .unwrap();
        assert!(s.checked_at(bad).is_err());
    }

    #[test]
    fn index_roundtrip_and_default_configs_present() {
        let s = Space::paper();
        for cfg in [HwConfig::pynq_z2(), HwConfig::ultra96_v2(), HwConfig::zcu104()] {
            let idx = s.index_of(&cfg).expect("paper defaults live in the paper space");
            assert_eq!(s.config_at(idx), cfg);
        }
        // a config with an off-axis knob is not in the space
        let mut odd = HwConfig::pynq_z2();
        odd.vmm_in_tile = 300;
        assert_eq!(s.index_of(&odd), None);
    }

    #[test]
    fn sampling_is_deterministic_and_in_range() {
        let s = Space::paper();
        let draw = |seed: u64| -> Vec<u64> {
            let mut rng = Pcg32::seeded(seed);
            (0..64).map(|_| s.sample(&mut rng)).collect()
        };
        let a = draw(9);
        assert_eq!(a, draw(9));
        assert_ne!(a, draw(10));
        assert!(a.iter().all(|&i| i < s.raw_size()));
    }

    #[test]
    fn neighbors_move_one_knob_one_step() {
        let s = Space::smoke();
        let idx = s.index_of(&{
            let mut c = HwConfig::with_unroll(2, 4, 16);
            c.axi_bytes_per_cycle = 8;
            c
        })
        .unwrap();
        let nbs = s.neighbors(idx);
        // 4 two-valued axes, each at position 0 -> one move apiece
        assert_eq!(nbs.len(), 4);
        for nb in nbs {
            assert_ne!(nb, idx);
            let a = s.config_at(idx);
            let b = s.config_at(nb);
            let diffs = [
                a.n_oh != b.n_oh,
                a.vmm_tile != b.vmm_tile,
                a.axi_bytes_per_cycle != b.axi_bytes_per_cycle,
                a.overlap_tiles != b.overlap_tiles,
            ];
            assert_eq!(diffs.iter().filter(|&&d| d).count(), 1, "{b:?}");
        }
    }
}
