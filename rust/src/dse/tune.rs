//! The DSE driver: exhaustive search for small spaces, seeded
//! beam/neighborhood search for large ones, parallelized across
//! candidates with the same `std::thread::scope` sharding pattern the
//! engine compute passes use.
//!
//! Determinism contract: for a fixed (space, spec) the whole run —
//! candidate order, frontier, winner, serialized artifacts — is
//! byte-identical across reruns and thread counts. Randomness comes
//! only from `util::rng` seeded per board, candidate batches are
//! scored into index-addressed slots (threads never race on order),
//! and every collection that reaches JSON is either sorted or a
//! `BTreeMap`.
//!
//! Two artifacts come out of a run:
//! * `BENCH_dse.json` — the full report: per-board prune counters,
//!   default-vs-tuned design points, speedup, and the Pareto frontier.
//! * the **tuned-config artifact** (`attrax tune --tuned <path>`) —
//!   just the winning `HwConfig` per board, the file `attrax serve
//!   --config` / `attrax loadgen --smoke --config` load at startup.

use std::collections::BTreeSet;
use std::path::Path;

use super::eval::{DesignPoint, Evaluator, Pruned};
use super::pareto::{dominates, rank_key, Frontier};
use super::space::Space;
use crate::attribution::Method;
use crate::fpga::{self, Board, Feasibility, Utilization};
use crate::hls::HwConfig;
use crate::model::{Network, Params};
use crate::sched::{auto_shards, BatchOutput, Workspace};
use crate::util::json::{self, Json};
use crate::util::rng::Pcg32;

/// Schema tag of the tuned-config artifact.
pub const TUNED_SCHEMA: &str = "attrax-tuned/v1";

/// What to search and how hard.
#[derive(Clone, Debug)]
pub struct TuneSpec {
    pub space: Space,
    pub boards: Vec<Board>,
    pub method: Method,
    pub seed: u64,
    /// Max cost-model evaluations per board. Spaces no larger than
    /// this are searched exhaustively; bigger ones get seeded
    /// beam/neighborhood search under this cap.
    pub budget: usize,
    /// Beam width of the neighborhood-refinement rounds.
    pub beam: usize,
    /// Scoring threads (0 = the host's available parallelism).
    pub threads: usize,
    /// Run the xeval fidelity probe on every scored candidate and add
    /// the infidelity objective to the frontier (`attrax tune
    /// --quality`). Off by default: quality-blind runs keep the legacy
    /// latency × BRAM × DSP behavior bit for bit.
    pub quality: bool,
}

impl Default for TuneSpec {
    fn default() -> TuneSpec {
        TuneSpec {
            space: Space::paper(),
            boards: fpga::ALL_BOARDS.to_vec(),
            method: Method::Guided,
            seed: 42,
            budget: 160,
            beam: 8,
            threads: 0,
            quality: false,
        }
    }
}

/// One board's search outcome.
#[derive(Clone, Debug)]
pub struct BoardOutcome {
    pub board: Board,
    /// Distinct candidates considered (scored + pruned).
    pub visited: usize,
    pub pruned_invalid: usize,
    pub pruned_capacity: usize,
    pub scored: usize,
    pub frontier: Frontier,
    /// The board's current default (`fpga::choose_config`), evaluated
    /// under the same cost model.
    pub default_point: DesignPoint,
    /// The tuned winner (latency-optimal frontier point).
    pub best: DesignPoint,
    /// `true` when no explored point Pareto-dominates the default —
    /// the "default is already Pareto-optimal" verdict. (An
    /// objective-tied twin may replace the default *on* the frontier
    /// without dominating it; the default is still optimal then.)
    pub default_on_frontier: bool,
    /// default cycles / tuned cycles (>= 1.0 when tuning helped).
    pub speedup: f64,
}

/// A full tuning run.
#[derive(Clone, Debug)]
pub struct TuneReport {
    pub seed: u64,
    pub method: Method,
    /// Whether the xeval fidelity probe scored every candidate
    /// (distinguishes "measured perfect fidelity" from "never
    /// measured" in [`TuneReport::render`]).
    pub quality: bool,
    pub outcomes: Vec<BoardOutcome>,
}

/// A stable per-board RNG stream id, independent of the order boards
/// were listed in the spec.
fn board_stream(b: Board) -> u64 {
    match b {
        Board::PynqZ2 => 0x70_79_6e_71,
        Board::Ultra96V2 => 0x75_39_36_76,
        Board::Zcu104 => 0x7a_63_75_34,
    }
}

/// Candidate admission bookkeeping: dedup + prune counters.
struct Admission {
    seen: BTreeSet<u64>,
    invalid: usize,
    capacity: usize,
}

impl Admission {
    fn new() -> Admission {
        Admission { seen: BTreeSet::new(), invalid: 0, capacity: 0 }
    }

    /// Consider raw index `idx`: dedup, legality-check, capacity-prune.
    /// Returns the config (with the prune gate's resource estimates,
    /// so scoring never pays for them twice) only when it deserves a
    /// cost pass.
    fn admit(
        &mut self,
        ev: &Evaluator,
        space: &Space,
        board: Board,
        idx: u64,
    ) -> Option<(HwConfig, Feasibility)> {
        if !self.seen.insert(idx) {
            return None;
        }
        let cfg = space.config_at(idx);
        match ev.prune(board, &cfg) {
            Ok(feas) => Some((cfg, feas)),
            Err(Pruned::Invalid(_)) => {
                self.invalid += 1;
                None
            }
            Err(Pruned::OverCapacity(_)) => {
                self.capacity += 1;
                None
            }
        }
    }
}

/// Score a batch of already-admitted candidates, sharded across
/// `threads` scoped threads. Results land in index-addressed slots, so
/// the output order equals the input order for any thread count; each
/// thread keeps one warm `Workspace`/`BatchOutput` pair for its whole
/// chunk (the same arena-reuse discipline as the coordinator workers).
fn score_batch(
    ev: &Evaluator,
    cands: &[(HwConfig, Feasibility)],
    threads: usize,
) -> Vec<DesignPoint> {
    if cands.is_empty() {
        return Vec::new();
    }
    let threads = threads.clamp(1, cands.len());
    let chunk = cands.len().div_ceil(threads);
    let mut out: Vec<Option<DesignPoint>> = vec![None; cands.len()];
    std::thread::scope(|scope| {
        for (cs, os) in cands.chunks(chunk).zip(out.chunks_mut(chunk)) {
            scope.spawn(move || {
                let mut ws = Workspace::with_shards(1);
                let mut bo = BatchOutput::new();
                for ((c, f), o) in cs.iter().zip(os.iter_mut()) {
                    *o = Some(ev.score_feasible(&mut ws, &mut bo, c, f));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("every slot scored")).collect()
}

/// Search one board: exhaustive when the space fits the budget, else
/// seeded sampling + beam/neighborhood refinement. Returns every
/// scored `(raw index, point)` plus the admission counters.
fn search_board(
    ev: &Evaluator,
    spec: &TuneSpec,
    board: Board,
    default_seed: Option<(u64, DesignPoint)>,
    threads: usize,
) -> (Vec<(u64, DesignPoint)>, Admission) {
    let space = &spec.space;
    let mut adm = Admission::new();
    let mut scored: Vec<(u64, DesignPoint)> = Vec::new();
    // the default design point is already scored by the caller; when it
    // lives in the space, seed the search with it (it anchors the beam
    // and is never cost-evaluated a second time)
    if let Some((didx, dpt)) = default_seed {
        adm.seen.insert(didx);
        scored.push((didx, dpt));
    }

    if space.raw_size() <= spec.budget as u64 {
        // exhaustive: every raw index, ascending
        let mut batch: Vec<(u64, (HwConfig, Feasibility))> = Vec::new();
        for idx in 0..space.raw_size() {
            if let Some(cand) = adm.admit(ev, space, board, idx) {
                batch.push((idx, cand));
            }
        }
        let cands: Vec<(HwConfig, Feasibility)> = batch.iter().map(|(_, c)| *c).collect();
        let pts = score_batch(ev, &cands, threads);
        scored.extend(batch.iter().map(|(i, _)| *i).zip(pts));
        return (scored, adm);
    }

    // --- seeded phase: uniform samples, up to half the budget (the
    // default design point, when in-space, is already seeded above) ---
    let mut rng = Pcg32::new(spec.seed, board_stream(board));
    let target = (spec.budget / 2).max(spec.beam).max(1).min(spec.budget);
    let mut batch: Vec<(u64, (HwConfig, Feasibility))> = Vec::new();
    let mut attempts = 0usize;
    let max_attempts = spec.budget.saturating_mul(64).max(1024);
    while batch.len() < target && attempts < max_attempts {
        attempts += 1;
        let idx = space.sample(&mut rng);
        if let Some(cand) = adm.admit(ev, space, board, idx) {
            batch.push((idx, cand));
        }
    }
    let cands: Vec<(HwConfig, Feasibility)> = batch.iter().map(|(_, c)| *c).collect();
    let pts = score_batch(ev, &cands, threads);
    scored.extend(batch.iter().map(|(i, _)| *i).zip(pts));

    // --- beam rounds: expand the neighborhoods of the current best
    // points until the budget is spent or the frontier region is dry --
    while scored.len() < spec.budget {
        let mut order: Vec<usize> = (0..scored.len()).collect();
        order.sort_by_key(|&i| rank_key(&scored[i].1));
        let mut batch: Vec<(u64, (HwConfig, Feasibility))> = Vec::new();
        'expand: for &i in order.iter().take(spec.beam) {
            for nb in space.neighbors(scored[i].0) {
                if scored.len() + batch.len() >= spec.budget {
                    break 'expand;
                }
                if let Some(cand) = adm.admit(ev, space, board, nb) {
                    batch.push((nb, cand));
                }
            }
        }
        if batch.is_empty() {
            break; // every beam neighborhood explored
        }
        let cands: Vec<(HwConfig, Feasibility)> = batch.iter().map(|(_, c)| *c).collect();
        let pts = score_batch(ev, &cands, threads);
        scored.extend(batch.iter().map(|(i, _)| *i).zip(pts));
    }
    (scored, adm)
}

/// Run the full design-space exploration: per board, prune the space
/// against the board's capacity, score survivors on the modeled-cycle
/// cost model, and reduce to the Pareto frontier + tuned winner.
pub fn tune(net: &Network, params: &Params, spec: &TuneSpec) -> anyhow::Result<TuneReport> {
    anyhow::ensure!(!spec.boards.is_empty(), "tune needs at least one board");
    anyhow::ensure!(spec.budget >= 1, "tune budget must be at least 1");
    let threads = if spec.threads == 0 { auto_shards() } else { spec.threads };
    // plan the space's formats plus the default config's (choose_config
    // always picks the paper datapath; the evaluator dedupes)
    let mut qs = spec.space.q.clone();
    qs.push(crate::fx::QFormat::paper16());
    let mut ev = Evaluator::new(net, params, &qs, spec.method, spec.seed)?;
    if spec.quality {
        ev.enable_quality(params)?;
    }
    let ev = ev;

    let mut outcomes = Vec::with_capacity(spec.boards.len());
    for &board in &spec.boards {
        let default_cfg = fpga::choose_config(board, net, spec.method);
        let default_point = ev.score(&default_cfg);
        let default_seed =
            spec.space.index_of(&default_cfg).map(|idx| (idx, default_point.clone()));
        let (scored, adm) = search_board(&ev, spec, board, default_seed, threads);

        let mut frontier = Frontier::new();
        frontier.insert(default_point.clone());
        for (_, p) in &scored {
            frontier.insert(p.clone());
        }
        let best = frontier.best().expect("frontier holds at least the default").clone();
        let speedup = default_point.cycles() as f64 / best.cycles() as f64;
        // Pareto-optimality of the default is a dominance question, not
        // frontier membership: an objective-tied twin with a smaller
        // config key replaces the default on the frontier without
        // actually beating it.
        let default_dominated = scored.iter().any(|(_, p)| dominates(p, &default_point));
        outcomes.push(BoardOutcome {
            board,
            visited: adm.seen.len(),
            pruned_invalid: adm.invalid,
            pruned_capacity: adm.capacity,
            scored: scored.len(),
            default_on_frontier: !default_dominated,
            frontier,
            default_point,
            best,
            speedup,
        });
    }
    Ok(TuneReport { seed: spec.seed, method: spec.method, quality: spec.quality, outcomes })
}

// ---------------------------------------------------------------------------
// Rendering + artifacts
// ---------------------------------------------------------------------------

fn util_json(u: &Utilization) -> Json {
    json::obj(vec![
        ("bram_18k", json::num(u.bram_18k as f64)),
        ("dsp", json::num(u.dsp as f64)),
        ("ff", json::num(u.ff as f64)),
        ("lut", json::num(u.lut as f64)),
    ])
}

fn point_json(p: &DesignPoint) -> Json {
    json::obj(vec![
        ("config", super::cfg_to_json(&p.cfg)),
        ("fp_cycles", json::num(p.fp_cycles as f64)),
        ("bp_cycles", json::num(p.bp_cycles as f64)),
        ("cycles", json::num(p.cycles() as f64)),
        ("latency_ms", json::num(p.latency_ms(fpga::TARGET_FREQ_MHZ))),
        ("infidelity_ppm", json::num(p.infidelity_ppm as f64)),
        ("fidelity", json::num(p.fidelity())),
        ("fp_util", util_json(&p.fp_util)),
        ("util", util_json(&p.util)),
    ])
}

impl TuneReport {
    /// The `BENCH_dse.json` payload. Deterministic for a fixed
    /// (space, spec): board keys are a `BTreeMap`, frontiers are
    /// rank-sorted.
    pub fn to_json(&self, spec: &TuneSpec) -> Json {
        let boards = self
            .outcomes
            .iter()
            .map(|o| {
                let frontier: Vec<Json> =
                    o.frontier.entries().into_iter().map(point_json).collect();
                let max_util = o
                    .frontier
                    .max_utilization(o.board)
                    .map(point_json)
                    .unwrap_or(Json::Null);
                (
                    o.board.name(),
                    json::obj(vec![
                        ("visited", json::num(o.visited as f64)),
                        ("pruned_invalid", json::num(o.pruned_invalid as f64)),
                        ("pruned_capacity", json::num(o.pruned_capacity as f64)),
                        ("scored", json::num(o.scored as f64)),
                        ("default", point_json(&o.default_point)),
                        ("best", point_json(&o.best)),
                        ("max_utilization", max_util),
                        ("speedup", json::num(o.speedup)),
                        ("default_on_frontier", Json::Bool(o.default_on_frontier)),
                        ("frontier", json::arr(frontier)),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("bench", json::s("dse")),
            // decimal string: u64 seeds above 2^53 don't survive the
            // f64-backed JSON number representation
            ("seed", json::s(&self.seed.to_string())),
            ("method", json::s(self.method.name())),
            ("budget", json::num(spec.budget as f64)),
            ("beam", json::num(spec.beam as f64)),
            ("quality", Json::Bool(spec.quality)),
            ("raw_space", json::num(spec.space.raw_size() as f64)),
            ("boards", json::obj(boards)),
        ])
    }

    /// The tuned-config artifact: just the winning config per board
    /// (what `attrax serve --config` consumes), plus provenance.
    pub fn tuned_json(&self) -> Json {
        let configs = self
            .outcomes
            .iter()
            .map(|o| (o.board.name(), super::cfg_to_json(&o.best.cfg)))
            .collect();
        json::obj(vec![
            ("schema", json::s(TUNED_SCHEMA)),
            ("seed", json::s(&self.seed.to_string())),
            ("method", json::s(self.method.name())),
            ("configs", json::obj(configs)),
        ])
    }

    /// Human summary table.
    pub fn render(&self) -> String {
        let mut s = format!(
            "{:<12} {:>9} {:>9} {:>8} {:>9} {:>9} {:>8} {:>9}\n",
            "board", "visited", "pruned", "scored", "default", "tuned", "speedup", "frontier"
        );
        for o in &self.outcomes {
            s.push_str(&format!(
                "{:<12} {:>9} {:>9} {:>8} {:>9} {:>9} {:>7.2}x {:>9}\n",
                o.board.name(),
                o.visited,
                o.pruned_invalid + o.pruned_capacity,
                o.scored,
                o.default_point.cycles(),
                o.best.cycles(),
                o.speedup,
                o.frontier.len(),
            ));
            let c = &o.best.cfg;
            s.push_str(&format!(
                "             tuned: N_oh={} N_ow={} tile={}x{} oc/ic={}/{} vmm={}/{} axi={}B dataflow={}{}\n",
                c.n_oh,
                c.n_ow,
                c.tile_oh,
                c.tile_ow,
                c.tile_oc,
                c.tile_ic,
                c.vmm_tile,
                c.vmm_in_tile,
                c.axi_bytes_per_cycle,
                c.overlap_tiles,
                if o.default_on_frontier { " (default on frontier)" } else { "" },
            ));
            if self.quality {
                s.push_str(&format!(
                    "             tuned probe fidelity: {:.4} (Q{}.{})\n",
                    o.best.fidelity(),
                    c.q.word_bits,
                    c.q.frac_bits
                ));
            }
        }
        s
    }
}

/// Tuned configs loaded back from an artifact (keyed by board name).
#[derive(Clone, Debug)]
pub struct TunedConfigs {
    pub seed: u64,
    pub method: Method,
    pub configs: std::collections::BTreeMap<String, HwConfig>,
}

impl TunedConfigs {
    pub fn for_board(&self, board: Board) -> Option<HwConfig> {
        self.configs.get(board.name()).copied()
    }

    pub fn board_names(&self) -> Vec<&str> {
        self.configs.keys().map(|k| k.as_str()).collect()
    }
}

/// Parse a tuned-config artifact; every config re-passes the central
/// legality gate, so a hand-edited file cannot smuggle an illegal
/// design into the server.
pub fn parse_tuned(text: &str) -> anyhow::Result<TunedConfigs> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("tuned artifact: {e}"))?;
    let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
    anyhow::ensure!(
        schema == TUNED_SCHEMA,
        "tuned artifact schema {schema:?} (expected {TUNED_SCHEMA:?})"
    );
    let method = j
        .get("method")
        .and_then(Json::as_str)
        .and_then(Method::parse)
        .ok_or_else(|| anyhow::anyhow!("tuned artifact: missing/unknown method"))?;
    let seed = j
        .get("seed")
        .and_then(Json::as_str)
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0);
    let obj = j
        .get("configs")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("tuned artifact: missing configs object"))?;
    let mut configs = std::collections::BTreeMap::new();
    for (name, cj) in obj {
        let cfg = super::cfg_from_json(cj)
            .map_err(|e| anyhow::anyhow!("tuned artifact, board {name}: {e}"))?;
        configs.insert(name.clone(), cfg);
    }
    anyhow::ensure!(!configs.is_empty(), "tuned artifact holds no configs");
    Ok(TunedConfigs { seed, method, configs })
}

/// Load a tuned-config artifact from disk.
pub fn load_tuned(path: &Path) -> anyhow::Result<TunedConfigs> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    parse_tuned(&text)
}

/// Write a JSON value to disk with a trailing newline.
pub fn write_json(path: &Path, j: &Json) -> anyhow::Result<()> {
    std::fs::write(path, format!("{j}\n"))
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::tests_support::tiny_net_params;

    fn smoke_spec(seed: u64) -> TuneSpec {
        TuneSpec {
            space: Space::smoke(),
            boards: vec![Board::PynqZ2, Board::Zcu104],
            seed,
            budget: 32,
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn exhaustive_tune_visits_the_whole_smoke_space() {
        let (net, params) = tiny_net_params(3);
        let r = tune(&net, &params, &smoke_spec(1)).unwrap();
        assert_eq!(r.outcomes.len(), 2);
        for o in &r.outcomes {
            // every smoke candidate is legal; capacity may prune some
            assert_eq!(o.visited, 16);
            assert_eq!(o.pruned_invalid, 0);
            assert_eq!(o.scored + o.pruned_capacity, 16);
            assert!(o.speedup >= 1.0, "{}: tuned can never lose", o.board);
            assert!(!o.frontier.is_empty());
        }
    }

    #[test]
    fn tuned_beats_or_matches_default_and_fits() {
        let (net, params) = tiny_net_params(5);
        let r = tune(&net, &params, &smoke_spec(2)).unwrap();
        for o in &r.outcomes {
            assert!(o.best.cfg.validate().is_ok());
            assert!(o.board.fits(&o.best.util));
            // the smoke space contains a wider AXI + dataflow overlap,
            // both strictly faster than the sequential default
            assert!(
                o.best.cycles() < o.default_point.cycles() || o.default_on_frontier,
                "{}: tuned {} vs default {}",
                o.board,
                o.best.cycles(),
                o.default_point.cycles()
            );
        }
    }

    #[test]
    fn same_seed_same_bytes() {
        let (net, params) = tiny_net_params(7);
        let spec = smoke_spec(9);
        let a = tune(&net, &params, &spec).unwrap().to_json(&spec).to_string();
        let mut spec_mt = spec.clone();
        spec_mt.threads = 4; // thread count must not leak into results
        let b = tune(&net, &params, &spec_mt).unwrap().to_json(&spec_mt).to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn tuned_artifact_roundtrips_and_validates() {
        let (net, params) = tiny_net_params(9);
        let r = tune(&net, &params, &smoke_spec(4)).unwrap();
        let text = r.tuned_json().to_string();
        let back = parse_tuned(&text).unwrap();
        assert_eq!(back.method, Method::Guided);
        assert_eq!(back.seed, 4, "seed survives the string round-trip");
        for o in &r.outcomes {
            assert_eq!(back.for_board(o.board), Some(o.best.cfg));
        }
        assert_eq!(back.for_board(Board::Ultra96V2), None);
        // tampering with a knob is caught by the legality gate on load
        let bad = text.replace("\"n_oh\":", "\"n_oh\":0,\"was_n_oh\":");
        assert!(parse_tuned(&bad).is_err());
        // wrong schema rejected
        assert!(parse_tuned("{\"schema\":\"nope\"}").is_err());
    }

    #[test]
    fn beam_search_respects_budget_on_large_spaces() {
        let (net, params) = tiny_net_params(11);
        let spec = TuneSpec {
            space: Space::paper(),
            boards: vec![Board::Ultra96V2],
            seed: 5,
            budget: 24,
            beam: 4,
            threads: 2,
            ..Default::default()
        };
        let r = tune(&net, &params, &spec).unwrap();
        let o = &r.outcomes[0];
        assert!(o.scored <= 24, "budget blown: {}", o.scored);
        assert!(o.scored > 0);
        assert!(o.visited >= o.scored);
        // reruns are byte-identical here too
        let a = r.to_json(&spec).to_string();
        let b = tune(&net, &params, &spec).unwrap().to_json(&spec).to_string();
        assert_eq!(a, b);
    }
}
