//! Design-space exploration & autotuning (S12): search the
//! [`HwConfig`](crate::hls::HwConfig) space under per-board resource
//! constraints and emit Pareto-optimal tuned configurations.
//!
//! The paper's §IV-A configuration step picks tile/unroll parameters
//! "to maximally use on-chip resources while adhering to the resource
//! constraints" of each target board — but hand-picks them. This
//! subsystem derives them:
//!
//! * [`space`] — declarative knob-value lists whose cross product is
//!   the candidate set, addressed by mixed-radix raw indices; legality
//!   stays centralized in `HwConfig::validate`.
//! * [`eval`] — two-stage candidate evaluation: microsecond resource
//!   pruning (`fpga::resources::feasibility`) *before* the
//!   millisecond modeled-cycle pass (`Simulator::with_config` over a
//!   shared `Arc<Plan>`, the same ledger the serving path reports).
//! * [`pareto`] — the latency × infidelity × BRAM × DSP frontier with
//!   fully deterministic tie-breaking (same inputs ⇒ same bytes out);
//!   the infidelity axis is identically zero unless the tuner runs
//!   with the xeval quality probe (`TuneSpec::quality`).
//! * [`tune`] — the driver: exhaustive for small spaces, seeded
//!   beam/neighborhood search under an evaluation budget for large
//!   ones, candidates scored in parallel with `std::thread::scope`
//!   sharding. Emits `BENCH_dse.json` and the tuned-config artifact
//!   that `attrax serve --config <path>` runs on.
//!
//! See DESIGN.md §"dse: search space, pruning, and Pareto selection"
//! and EXPERIMENTS.md E16.

pub mod eval;
pub mod pareto;
pub mod space;
pub mod tune;

pub use eval::{DesignPoint, Evaluator, Pruned};
pub use pareto::Frontier;
pub use space::Space;
pub use tune::{load_tuned, tune, TuneReport, TuneSpec, TunedConfigs, TUNED_SCHEMA};

use crate::fx::QFormat;
use crate::hls::HwConfig;
use crate::util::json::{self, Json};

/// Serialize every `HwConfig` knob (the tuned-artifact schema — one
/// flat object, integer-valued except the dataflow flag).
pub fn cfg_to_json(c: &HwConfig) -> Json {
    json::obj(vec![
        ("n_oh", json::num(c.n_oh as f64)),
        ("n_ow", json::num(c.n_ow as f64)),
        ("tile_oh", json::num(c.tile_oh as f64)),
        ("tile_ow", json::num(c.tile_ow as f64)),
        ("tile_oc", json::num(c.tile_oc as f64)),
        ("tile_ic", json::num(c.tile_ic as f64)),
        ("vmm_tile", json::num(c.vmm_tile as f64)),
        ("vmm_in_tile", json::num(c.vmm_in_tile as f64)),
        ("axi_bytes_per_cycle", json::num(c.axi_bytes_per_cycle as f64)),
        ("axi_burst_overhead", json::num(c.axi_burst_overhead as f64)),
        ("pipeline_depth", json::num(c.pipeline_depth as f64)),
        ("overlap_tiles", Json::Bool(c.overlap_tiles)),
        ("q_word_bits", json::num(c.q.word_bits as f64)),
        ("q_frac_bits", json::num(c.q.frac_bits as f64)),
    ])
}

/// Parse a config serialized by [`cfg_to_json`] and run it through the
/// central legality gate (unknown keys are ignored; missing keys are
/// an error).
pub fn cfg_from_json(j: &Json) -> anyhow::Result<HwConfig> {
    let field = |k: &str| -> anyhow::Result<usize> {
        let n = j
            .get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing field {k}"))?;
        // exact integers only: `as usize` truncation would silently run
        // a different design than the file states
        anyhow::ensure!(
            n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64,
            "field {k} must be a non-negative integer, got {n}"
        );
        Ok(n as usize)
    };
    let q = {
        let wb = field("q_word_bits")?;
        let fb = field("q_frac_bits")?;
        anyhow::ensure!(
            (2..=32).contains(&wb) && fb < wb,
            "bad fixed-point format Q{wb}.{fb}"
        );
        QFormat::new(wb as u32, fb as u32)
    };
    let cfg = HwConfig {
        n_oh: field("n_oh")?,
        n_ow: field("n_ow")?,
        tile_oh: field("tile_oh")?,
        tile_ow: field("tile_ow")?,
        tile_oc: field("tile_oc")?,
        tile_ic: field("tile_ic")?,
        vmm_tile: field("vmm_tile")?,
        vmm_in_tile: field("vmm_in_tile")?,
        q,
        axi_bytes_per_cycle: field("axi_bytes_per_cycle")?,
        axi_burst_overhead: field("axi_burst_overhead")? as u64,
        pipeline_depth: field("pipeline_depth")? as u64,
        overlap_tiles: j
            .get("overlap_tiles")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow::anyhow!("missing field overlap_tiles"))?,
    };
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_json_roundtrips_every_knob() {
        let mut c = HwConfig::zcu104();
        c.overlap_tiles = true;
        c.axi_bytes_per_cycle = 16;
        c.pipeline_depth = 4;
        let j = cfg_to_json(&c);
        let back = cfg_from_json(&j).unwrap();
        assert_eq!(back, c);
        // serialized form reparses from text too
        let text = j.to_string();
        assert_eq!(cfg_from_json(&Json::parse(&text).unwrap()).unwrap(), c);
    }

    #[test]
    fn cfg_from_json_rejects_missing_and_illegal() {
        let j = cfg_to_json(&HwConfig::pynq_z2());
        // drop a field
        let mut m = j.as_obj().unwrap().clone();
        m.remove("vmm_tile");
        assert!(cfg_from_json(&Json::Obj(m)).is_err());
        // illegal knob value is caught by validate()
        let mut m = j.as_obj().unwrap().clone();
        m.insert("n_oh".into(), json::num(3.0));
        let err = cfg_from_json(&Json::Obj(m)).unwrap_err().to_string();
        assert!(err.contains("n_oh"), "{err}");
        // fractional knob values are rejected, not truncated
        let mut m = j.as_obj().unwrap().clone();
        m.insert("vmm_tile".into(), json::num(16.5));
        let err = cfg_from_json(&Json::Obj(m)).unwrap_err().to_string();
        assert!(err.contains("vmm_tile"), "{err}");
    }
}
