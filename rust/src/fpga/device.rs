//! Target FPGA device profiles (S4): resource capacities of the three
//! boards the paper evaluates (§IV-A), from the Xilinx data sheets.
//!
//! * Pynq-Z2    — Zynq-7000 XC7Z020
//! * Ultra96-V2 — Zynq UltraScale+ ZU3EG
//! * ZCU104     — Zynq UltraScale+ ZU7EV

use super::resources::Utilization;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Board {
    PynqZ2,
    Ultra96V2,
    Zcu104,
}

pub const ALL_BOARDS: [Board; 3] = [Board::PynqZ2, Board::Ultra96V2, Board::Zcu104];

/// Available resources (BRAM in 18Kb units, as Vitis reports them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capacity {
    pub bram_18k: u32,
    pub dsp: u32,
    pub ff: u32,
    pub lut: u32,
}

impl Board {
    pub fn name(&self) -> &'static str {
        match self {
            Board::PynqZ2 => "Pynq-Z2",
            Board::Ultra96V2 => "Ultra96-V2",
            Board::Zcu104 => "ZCU104",
        }
    }

    pub fn parse(s: &str) -> Option<Board> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "pynqz2" | "pynq" | "z7020" => Some(Board::PynqZ2),
            "ultra96v2" | "ultra96" | "zu3eg" => Some(Board::Ultra96V2),
            "zcu104" | "zu7ev" => Some(Board::Zcu104),
            _ => None,
        }
    }

    pub fn capacity(&self) -> Capacity {
        match self {
            // XC7Z020: 280 BRAM18K, 220 DSP48, 106,400 FF, 53,200 LUT
            Board::PynqZ2 => Capacity { bram_18k: 280, dsp: 220, ff: 106_400, lut: 53_200 },
            // ZU3EG: 432 BRAM18K, 360 DSP48, 141,120 FF, 70,560 LUT
            Board::Ultra96V2 => Capacity { bram_18k: 432, dsp: 360, ff: 141_120, lut: 70_560 },
            // ZU7EV: 624 BRAM18K, 1,728 DSP48, 460,800 FF, 230,400 LUT
            Board::Zcu104 => Capacity { bram_18k: 624, dsp: 1728, ff: 460_800, lut: 230_400 },
        }
    }

    /// Does `u` fit on this board?
    pub fn fits(&self, u: &Utilization) -> bool {
        let c = self.capacity();
        u.bram_18k <= c.bram_18k && u.dsp <= c.dsp && u.ff <= c.ff && u.lut <= c.lut
    }

    /// Capacity left after placing `u`, saturating at zero per axis
    /// (an over-capacity build reports zero headroom there, it does
    /// not wrap).
    pub fn headroom(&self, u: &Utilization) -> Utilization {
        let c = self.capacity();
        Utilization {
            bram_18k: c.bram_18k.saturating_sub(u.bram_18k),
            dsp: c.dsp.saturating_sub(u.dsp),
            ff: c.ff.saturating_sub(u.ff),
            lut: c.lut.saturating_sub(u.lut),
        }
    }

    /// Utilization percentages (BRAM, DSP, FF, LUT) like Table IV prints.
    pub fn percent(&self, u: &Utilization) -> [f64; 4] {
        let c = self.capacity();
        [
            100.0 * u.bram_18k as f64 / c.bram_18k as f64,
            100.0 * u.dsp as f64 / c.dsp as f64,
            100.0 * u.ff as f64 / c.ff as f64,
            100.0 * u.lut as f64 / c.lut as f64,
        ]
    }
}

impl std::fmt::Display for Board {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacities_match_datasheets() {
        assert_eq!(Board::PynqZ2.capacity().lut, 53_200);
        assert_eq!(Board::Ultra96V2.capacity().dsp, 360);
        assert_eq!(Board::Zcu104.capacity().bram_18k, 624);
    }

    #[test]
    fn paper_percentages_consistent() {
        // Table IV reports Pynq FP: DSP 32 (14%), LUT 38.4K (72%) — our
        // capacities must reproduce those percentages
        let u = Utilization { bram_18k: 10, dsp: 32, ff: 18_600, lut: 38_400 };
        let p = Board::PynqZ2.percent(&u);
        assert!((p[1] - 14.5).abs() < 1.0, "DSP% {}", p[1]);
        assert!((p[3] - 72.2).abs() < 1.0, "LUT% {}", p[3]);
        // Ultra96 FP: DSP 48 (13%), LUT 47.8K (67%)
        let u = Utilization { bram_18k: 10, dsp: 48, ff: 19_200, lut: 47_800 };
        let p = Board::Ultra96V2.percent(&u);
        assert!((p[1] - 13.3).abs() < 1.0);
        assert!((p[3] - 67.7).abs() < 1.5);
        // ZCU104 FP: DSP 96 (5%), LUT 68.1K (29%)
        let u = Utilization { bram_18k: 10, dsp: 96, ff: 27_200, lut: 68_100 };
        let p = Board::Zcu104.percent(&u);
        assert!((p[1] - 5.5).abs() < 1.0);
        assert!((p[3] - 29.6).abs() < 1.0);
    }

    #[test]
    fn parse_aliases() {
        assert_eq!(Board::parse("pynq-z2"), Some(Board::PynqZ2));
        assert_eq!(Board::parse("ULTRA96"), Some(Board::Ultra96V2));
        assert_eq!(Board::parse("zcu104"), Some(Board::Zcu104));
        assert_eq!(Board::parse("versal"), None);
    }

    #[test]
    fn fits_checks_every_axis() {
        let big = Utilization { bram_18k: 9999, dsp: 1, ff: 1, lut: 1 };
        assert!(!Board::Zcu104.fits(&big));
        let ok = Utilization { bram_18k: 1, dsp: 1, ff: 1, lut: 1 };
        assert!(Board::PynqZ2.fits(&ok));
    }
}
