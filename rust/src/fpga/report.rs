//! Vitis-style synthesis report generator: renders the resource
//! estimate, buffer inventory and per-layer timing of a configured
//! design as the kind of text report `vitis_hls -f` would emit — the
//! artifact a hardware engineer would diff against the real tool.

use crate::attribution::Method;
use crate::fpga::{estimate_fp, estimate_fp_bp, Board, TARGET_FREQ_MHZ};
use crate::hls::{Cost, HwConfig};
use crate::model::Network;

/// Render a full report for a design point.
pub fn render(
    board: Board,
    cfg: &HwConfig,
    net: &Network,
    method: Method,
    fp_cost: &Cost,
    bp_cost: &Cost,
) -> String {
    let mut s = String::new();
    let cap = board.capacity();
    let ufp = estimate_fp(cfg, net);
    let ubp = estimate_fp_bp(cfg, net, method);

    s.push_str(&format!(
        "== attrax synthesis report ==\n\
         * Target        : {board} @ {TARGET_FREQ_MHZ:.0} MHz\n\
         * Network       : {} params, {} fwd MACs\n\
         * Method        : {method}\n\
         * Configuration : N_oh={} N_ow={} tile={}x{} oc/ic={}/{} VMM={} Q{}.{}\n\n",
        net.param_count(),
        net.forward_macs(),
        cfg.n_oh,
        cfg.n_ow,
        cfg.tile_oh,
        cfg.tile_ow,
        cfg.tile_oc,
        cfg.tile_ic,
        cfg.vmm_tile,
        cfg.q.word_bits,
        cfg.q.frac_bits,
    ));

    s.push_str("-- Utilization Estimates ------------------------------------\n");
    s.push_str(&format!(
        "{:<12} {:>10} {:>10} {:>10} {:>10}\n",
        "", "BRAM_18K", "DSP", "FF", "LUT"
    ));
    for (label, u) in [("FP only", ufp), ("FP+BP", ubp)] {
        s.push_str(&format!(
            "{:<12} {:>10} {:>10} {:>10} {:>10}\n",
            label, u.bram_18k, u.dsp, u.ff, u.lut
        ));
        let p = board.percent(&u);
        s.push_str(&format!(
            "{:<12} {:>9.0}% {:>9.0}% {:>9.0}% {:>9.0}%\n",
            "  (util)", p[0], p[1], p[2], p[3]
        ));
    }
    s.push_str(&format!(
        "{:<12} {:>10} {:>10} {:>10} {:>10}\n\n",
        "available", cap.bram_18k, cap.dsp, cap.ff, cap.lut
    ));

    s.push_str("-- Timing (modeled) -----------------------------------------\n");
    // phase cycles under the tile-latency model the config selects:
    // sequential sum by default, load/compute/store overlap when the
    // dataflow knob is set (matches the DSE cost model)
    let fp_cyc = fp_cost.cycles_under(cfg);
    let bp_cyc = bp_cost.cycles_under(cfg);
    let to_ms = |c: u64| c as f64 / (TARGET_FREQ_MHZ * 1e3);
    s.push_str(&format!(
        "inference (FP)           : {:>12} cycles  {:>8.2} ms\n\
         attribution BP           : {:>12} cycles  {:>8.2} ms\n\
         feature attribution total: {:>12} cycles  {:>8.2} ms{}\n\n",
        fp_cyc,
        to_ms(fp_cyc),
        bp_cyc,
        to_ms(bp_cyc),
        fp_cyc + bp_cyc,
        to_ms(fp_cyc + bp_cyc),
        if cfg.overlap_tiles { "  (dataflow tile overlap)" } else { "" },
    ));

    s.push_str("-- Per-layer latency ----------------------------------------\n");
    if cfg.overlap_tiles {
        // checkpoints record the sequential running sum; the dataflow
        // overlap credit applies at phase granularity only, so these
        // rows intentionally sum past the overlapped totals above
        s.push_str("  (sequential-model rows; overlap applies per phase, not per layer)\n");
    }
    for (phase, cost) in [("FP", fp_cost), ("BP", bp_cost)] {
        for (name, cycles) in cost.layer_breakdown() {
            s.push_str(&format!(
                "  {phase}  {:<10} {:>12} cycles  {:>8.3} ms\n",
                name,
                cycles,
                cycles as f64 / (TARGET_FREQ_MHZ * 1e3)
            ));
        }
    }

    s.push_str(&format!(
        "\n-- DRAM traffic ----------------------------------------------\n\
         FP : read {:>12} B  write {:>12} B  bursts {:>8}\n\
         BP : read {:>12} B  write {:>12} B  bursts {:>8}\n",
        fp_cost.dram_read_bytes,
        fp_cost.dram_write_bytes,
        fp_cost.dram_bursts,
        bp_cost.dram_read_bytes,
        bp_cost.dram_write_bytes,
        bp_cost.dram_bursts,
    ));
    let fits = board.fits(&ubp);
    s.push_str(&format!(
        "\nfeasibility: design {} on {board}\n",
        if fits { "FITS" } else { "DOES NOT FIT" }
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::Cost;

    #[test]
    fn report_contains_all_sections() {
        let net = Network::table3();
        let cfg = HwConfig::pynq_z2();
        let mut fp = Cost::new();
        fp.compute_cycles = 1_000_000;
        fp.checkpoint("conv1");
        let mut bp = Cost::new();
        bp.compute_cycles = 600_000;
        bp.dram_read_bytes = 42;
        bp.checkpoint("conv1ᵀ");
        let r = render(Board::PynqZ2, &cfg, &net, Method::Guided, &fp, &bp);
        for key in [
            "Utilization Estimates",
            "BRAM_18K",
            "Timing (modeled)",
            "Per-layer latency",
            "DRAM traffic",
            "conv1ᵀ",
            "FITS",
            "591274",
        ] {
            assert!(r.contains(key), "report missing {key:?}:\n{r}");
        }
    }

    #[test]
    fn infeasible_design_flagged() {
        let net = Network::table3();
        // force an enormous config that cannot fit the smallest board
        let mut cfg = HwConfig::with_unroll(8, 8, 32);
        cfg.tile_oc = 64;
        cfg.tile_ic = 64;
        let r = render(Board::PynqZ2, &cfg, &net, Method::Guided, &Cost::new(), &Cost::new());
        assert!(r.contains("DOES NOT FIT") || r.contains("FITS"));
    }
}
