//! FPGA platform models (S4/S5): device capacities, the HLS-style
//! resource estimator, and clocking.

pub mod device;
pub mod report;
pub mod resources;

pub use device::{Board, Capacity, ALL_BOARDS};
pub use resources::{
    choose_config, estimate_fp, estimate_fp_bp, estimate_pipelined, feasibility, Feasibility,
    Utilization,
};

/// The paper's synthesis target clock (§IV-A).
pub const TARGET_FREQ_MHZ: f64 = 100.0;
