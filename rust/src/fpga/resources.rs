//! HLS-style resource estimator (S4): predicts BRAM/DSP/FF/LUT for a
//! hardware configuration, for inference-only (FP) and full feature
//! attribution (FP+BP) builds — the generator of Table IV's resource
//! columns.
//!
//! DSP and BRAM counts are *structural* (derived from the configured
//! unroll factors and buffer geometry, like Vitis' own report). FF/LUT
//! are *calibrated affine models*: HLS fabric usage is dominated by (1)
//! partitioned-buffer LUTRAM + read/write muxing, which scales with the
//! MAC unroll, and (2) the layer-sequencing controller, which roughly
//! doubles when the BP phase is added (paper §IV-B). The coefficients
//! below were fit to the paper's three synthesized design points and
//! are documented as such — they are a model of Vitis, not a
//! re-implementation of it; see EXPERIMENTS.md E3 for measured-vs-paper
//! deltas on all twelve resource cells.

use super::device::Board;
use crate::attribution::Method;
use crate::hls::HwConfig;
use crate::model::Network;

/// Resource usage, BRAM in 18Kb units (Vitis reporting convention).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Utilization {
    pub bram_18k: u32,
    pub dsp: u32,
    pub ff: u32,
    pub lut: u32,
}

impl Utilization {
    /// Per-axis overhead of `self` relative to `base`, saturating at
    /// zero. DSE compares arbitrary config pairs, so `base` may exceed
    /// `self` on some axis — raw `u32` subtraction would panic in
    /// debug builds there (regression-tested below).
    pub fn delta(&self, base: &Utilization) -> Utilization {
        Utilization {
            bram_18k: self.bram_18k.saturating_sub(base.bram_18k),
            dsp: self.dsp.saturating_sub(base.dsp),
            ff: self.ff.saturating_sub(base.ff),
            lut: self.lut.saturating_sub(base.lut),
        }
    }
}

const BRAM_BITS: usize = 18 * 1024;

/// Words -> BRAM18K units for a buffer of `words` x `bits` mapped to
/// block RAM (1 unit minimum — a bank can't be fractional).
fn bram_units(words: usize, bits: usize) -> u32 {
    ((words * bits).div_ceil(BRAM_BITS)).max(1) as u32
}

/// On-chip buffer inventory for a config (the §III-A buffers).
/// Returns (bram_units, lutram_lut_cost).
fn buffer_costs(cfg: &HwConfig) -> (u32, u32) {
    let bits = cfg.q.word_bits as usize;
    let k = 3; // the library's conv kernel footprint for buffer sizing
    let mut bram = 0u32;
    let mut lutram = 0u32;

    // conv weight buffer [tile_oc][tile_ic][k][k] — block RAM
    bram += bram_units(cfg.tile_oc * cfg.tile_ic * k * k, bits);
    // conv input buffer [tile_ic][tile_oh+k-1][tile_ow+k-1] — partitioned
    // by the row unroll into N_oh banks; small banks land in LUTRAM
    let in_words = cfg.tile_ic * (cfg.tile_oh + k - 1) * (cfg.tile_ow + k - 1);
    let in_bank = in_words / cfg.n_oh.max(1);
    if in_bank * bits >= BRAM_BITS / 2 {
        bram += cfg.n_oh as u32 * bram_units(in_bank, bits);
    } else {
        // LUTRAM: 64 bits per LUT6 (distributed RAM)
        lutram += ((in_words * bits) / 64) as u32;
    }
    // conv output buffer [tile_oc][tile_oh][tile_ow] — partitioned
    // N_oh x N_ow for parallel accumulation; always LUTRAM at these sizes
    let out_words = cfg.tile_oc * cfg.tile_oh * cfg.tile_ow;
    lutram += ((out_words * bits * 2) / 64) as u32; // x2: wide accumulators

    // VMM weight buffer [vmm_tile][vmm_in_tile] — block RAM
    bram += bram_units(cfg.vmm_tile * cfg.vmm_in_tile, bits);
    // VMM input/output vectors — LUTRAM
    lutram += (((cfg.vmm_in_tile + cfg.vmm_tile) * bits) / 64) as u32;

    // HLS dataflow double buffering (§IV-B): overlapping tile
    // load/compute/store needs ping-pong copies of every tile buffer.
    // The cycle model credits the overlap (`Cost::overlapped_cycles`);
    // this is the memory bill, so DSE cannot pick the knob for free.
    if cfg.overlap_tiles {
        bram *= 2;
        lutram *= 2;
    }

    (bram, lutram)
}

/// Mask storage in BRAM18K units for the BP phase: the §V on-chip bits
/// (pool argmax + FC ReLU masks), packed into the fewest banks.
fn mask_bram(net: &Network, method: Method) -> u32 {
    let bits = crate::attribution::memory::mask_budget(net).onchip_bits(method);
    (bits.div_ceil(BRAM_BITS * 2)) as u32 // packed pair of 18K = 1 BRAM36 reported as 1
}

// -- calibrated fabric model (fit to paper Table IV, see module doc) -------
const LUT_BASE: f64 = 28_600.0; // AXI + controller + fixed buffers
const LUT_PER_CONV_MAC: f64 = 590.0; // operand mux + MAC glue per unrolled lane
const LUT_PER_VMM_MAC: f64 = 30.0;
const LUT_BP_BASE: f64 = 13_000.0; // 2nd scheduler pass + BP load muxes
const LUT_BP_PER_CONV_MAC: f64 = 70.0;
const FF_BASE: f64 = 12_800.0;
const FF_PER_MAC: f64 = 180.0;
const FF_BP: f64 = 7_400.0;

/// Estimate resources for an inference-only (FP) build.
pub fn estimate_fp(cfg: &HwConfig, _net: &Network) -> Utilization {
    let conv_macs = cfg.conv_macs_parallel() as u32;
    let (bram, lutram) = buffer_costs(cfg);
    Utilization {
        bram_18k: bram,
        dsp: conv_macs + cfg.vmm_tile as u32,
        ff: (FF_BASE + FF_PER_MAC * (conv_macs as f64 + cfg.vmm_tile as f64)) as u32,
        lut: (LUT_BASE
            + LUT_PER_CONV_MAC * conv_macs as f64
            + LUT_PER_VMM_MAC * cfg.vmm_tile as f64) as u32
            + lutram / 4, // distributed RAM shares LUTs with logic
    }
}

/// Estimate resources for a feature-attribution (FP+BP) build.
pub fn estimate_fp_bp(cfg: &HwConfig, net: &Network, method: Method) -> Utilization {
    let fp = estimate_fp(cfg, net);
    let conv_macs = cfg.conv_macs_parallel() as f64;
    Utilization {
        // +mask banks; compute blocks and main buffers are REUSED (the
        // paper's headline: BRAM/DSP overhead ≈ 1 unit)
        bram_18k: fp.bram_18k + mask_bram(net, method),
        // +1 DSP: gradient address-generation / index arithmetic
        dsp: fp.dsp + 1,
        ff: fp.ff + FF_BP as u32,
        lut: fp.lut + (LUT_BP_BASE + LUT_BP_PER_CONV_MAC * conv_macs) as u32,
    }
}

/// Estimate for the *pipelined* FP/BP variant (§IV-B: "on larger FPGAs
/// the FP and BP phases can be pipelined ... at the cost of separate
/// compute blocks"): duplicated conv+VMM datapaths and buffers.
pub fn estimate_pipelined(cfg: &HwConfig, net: &Network, method: Method) -> Utilization {
    let fp = estimate_fp(cfg, net);
    let fpbp = estimate_fp_bp(cfg, net, method);
    Utilization {
        bram_18k: fp.bram_18k + fpbp.bram_18k,
        dsp: fp.dsp + fpbp.dsp,
        ff: fp.ff + fpbp.ff,
        lut: fp.lut + fpbp.lut,
    }
}

/// One candidate's pre-cost feasibility picture: the FP and FP+BP
/// utilization estimates, whether the FP+BP build (the one that must
/// be placed) fits the board, and the per-axis headroom left under the
/// capacity cap.
///
/// This is the DSE prune gate: estimating resources costs microseconds
/// while a cycle-model pass costs milliseconds, so capacity-infeasible
/// candidates are rejected *before* any cost evaluation
/// (`dse::eval::Evaluator::prune`).
#[derive(Clone, Copy, Debug)]
pub struct Feasibility {
    pub fp: Utilization,
    pub fp_bp: Utilization,
    pub fits: bool,
    /// Capacity minus the FP+BP build, saturating per axis.
    pub headroom: Utilization,
}

/// Estimate a candidate's resources and check them against `board`
/// (the capacity/utilization pruning entry point — no cycle modeling).
pub fn feasibility(board: Board, cfg: &HwConfig, net: &Network, method: Method) -> Feasibility {
    let fp = estimate_fp(cfg, net);
    let fp_bp = estimate_fp_bp(cfg, net, method);
    Feasibility { fp, fp_bp, fits: board.fits(&fp_bp), headroom: board.headroom(&fp_bp) }
}

/// The paper's platform-configuration step (§IV-A: "hardware
/// configuration ... chosen according to the target FPGA platform"):
/// pick the largest unroll whose FP+BP build fits the board.
pub fn choose_config(board: Board, net: &Network, method: Method) -> HwConfig {
    // candidate unrolls, largest first; tile is 8x8 so unroll caps at 8
    let candidates = [(8usize, 8usize), (4, 8), (4, 4), (2, 4), (2, 2), (1, 2), (1, 1)];
    let vmm = if board.capacity().dsp >= 500 { 32 } else { 16 };
    for (noh, now) in candidates {
        let cfg = HwConfig::with_unroll(noh, now, vmm);
        let u = estimate_fp_bp(&cfg, net, method);
        if board.fits(&u) {
            return cfg;
        }
    }
    HwConfig::with_unroll(1, 1, 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::ALL_BOARDS;

    fn net() -> Network {
        Network::table3()
    }

    #[test]
    fn dsp_counts_match_table4_exactly() {
        // Table IV: Pynq 32/33, Ultra96 48/49, ZCU104 96/97
        let cases = [
            (HwConfig::pynq_z2(), 32, 33),
            (HwConfig::ultra96_v2(), 48, 49),
            (HwConfig::zcu104(), 96, 97),
        ];
        for (cfg, fp_dsp, bp_dsp) in cases {
            assert_eq!(estimate_fp(&cfg, &net()).dsp, fp_dsp);
            assert_eq!(estimate_fp_bp(&cfg, &net(), Method::Guided).dsp, bp_dsp);
        }
    }

    #[test]
    fn bram_nearly_constant_fp_to_bp() {
        // the paper's headline reuse claim: BRAM overhead is ~1 unit
        for cfg in [HwConfig::pynq_z2(), HwConfig::ultra96_v2(), HwConfig::zcu104()] {
            let fp = estimate_fp(&cfg, &net());
            let bp = estimate_fp_bp(&cfg, &net(), Method::Guided);
            let d = bp.bram_18k - fp.bram_18k;
            assert!(d <= 2, "BRAM overhead {d} too large for {cfg:?}");
            assert!(fp.bram_18k >= 5 && fp.bram_18k <= 20, "FP BRAM {}", fp.bram_18k);
        }
    }

    #[test]
    fn lut_in_paper_band() {
        // within 20% of Table IV's LUT cells (calibrated model)
        let cases = [
            (HwConfig::pynq_z2(), 38_400.0, 52_900.0),
            (HwConfig::ultra96_v2(), 47_800.0, 62_900.0),
            (HwConfig::zcu104(), 68_100.0, 85_700.0),
        ];
        for (cfg, paper_fp, paper_bp) in cases {
            let fp = estimate_fp(&cfg, &net()).lut as f64;
            let bp = estimate_fp_bp(&cfg, &net(), Method::Guided).lut as f64;
            assert!((fp - paper_fp).abs() / paper_fp < 0.20, "FP LUT {fp} vs {paper_fp}");
            assert!((bp - paper_bp).abs() / paper_bp < 0.20, "BP LUT {bp} vs {paper_bp}");
        }
    }

    #[test]
    fn ff_in_paper_band() {
        let cases = [
            (HwConfig::pynq_z2(), 18_600.0, 26_700.0),
            (HwConfig::ultra96_v2(), 19_200.0, 25_600.0),
            (HwConfig::zcu104(), 27_200.0, 34_900.0),
        ];
        for (cfg, paper_fp, paper_bp) in cases {
            let fp = estimate_fp(&cfg, &net()).ff as f64;
            let bp = estimate_fp_bp(&cfg, &net(), Method::Guided).ff as f64;
            assert!((fp - paper_fp).abs() / paper_fp < 0.25, "FP FF {fp} vs {paper_fp}");
            assert!((bp - paper_bp).abs() / paper_bp < 0.25, "BP FF {bp} vs {paper_bp}");
        }
    }

    #[test]
    fn choose_config_reproduces_paper_table4() {
        // the configuration-selection procedure lands on the paper's
        // unroll factors for all three boards
        let c = choose_config(Board::PynqZ2, &net(), Method::Guided);
        assert_eq!((c.n_oh, c.n_ow, c.vmm_tile), (4, 4, 16));
        let c = choose_config(Board::Ultra96V2, &net(), Method::Guided);
        assert_eq!((c.n_oh, c.n_ow, c.vmm_tile), (4, 8, 16));
        let c = choose_config(Board::Zcu104, &net(), Method::Guided);
        assert_eq!((c.n_oh, c.n_ow, c.vmm_tile), (8, 8, 32));
    }

    #[test]
    fn chosen_configs_fit_their_boards() {
        for b in ALL_BOARDS {
            let cfg = choose_config(b, &net(), Method::Guided);
            assert!(b.fits(&estimate_fp_bp(&cfg, &net(), Method::Guided)));
        }
    }

    #[test]
    fn pipelined_roughly_doubles_compute_resources() {
        let cfg = HwConfig::zcu104();
        let seq = estimate_fp_bp(&cfg, &net(), Method::Guided);
        let pipe = estimate_pipelined(&cfg, &net(), Method::Guided);
        assert!(pipe.dsp > seq.dsp + estimate_fp(&cfg, &net()).dsp - 2);
        assert!(pipe.lut > seq.lut);
    }

    #[test]
    fn delta_saturates_instead_of_underflowing() {
        // DSE compares arbitrary pairs: base bigger than self on some
        // axes must clamp to zero, not panic in debug builds
        let small = Utilization { bram_18k: 3, dsp: 40, ff: 10_000, lut: 50_000 };
        let big = Utilization { bram_18k: 10, dsp: 20, ff: 20_000, lut: 30_000 };
        let d = small.delta(&big);
        assert_eq!(d, Utilization { bram_18k: 0, dsp: 20, ff: 0, lut: 20_000 });
        // the ordinary direction still reports the true overhead
        let d = big.delta(&small);
        assert_eq!(d, Utilization { bram_18k: 7, dsp: 0, ff: 10_000, lut: 0 });
        // identical inputs are a zero delta both ways
        assert_eq!(small.delta(&small), Utilization::default());
    }

    #[test]
    fn overlap_tiles_pays_double_buffers() {
        let mut cfg = HwConfig::pynq_z2();
        let seq = estimate_fp_bp(&cfg, &net(), Method::Guided);
        cfg.overlap_tiles = true;
        let ovl = estimate_fp_bp(&cfg, &net(), Method::Guided);
        // ping-pong buffers: strictly more BRAM, unchanged DSP (the
        // datapath is not duplicated, only the tile memories)
        assert!(ovl.bram_18k > seq.bram_18k, "{} vs {}", ovl.bram_18k, seq.bram_18k);
        assert_eq!(ovl.dsp, seq.dsp);
    }

    #[test]
    fn feasibility_agrees_with_fits_and_headroom() {
        let n = net();
        let f = feasibility(Board::PynqZ2, &HwConfig::pynq_z2(), &n, Method::Guided);
        assert!(f.fits);
        assert_eq!(f.fp_bp, estimate_fp_bp(&HwConfig::pynq_z2(), &n, Method::Guided));
        let cap = Board::PynqZ2.capacity();
        assert_eq!(f.headroom.dsp, cap.dsp - f.fp_bp.dsp);
        // the ZCU104 design point is too large for the small board,
        // with zero (saturated) headroom on the exhausted axis
        let big = HwConfig::zcu104();
        let f = feasibility(Board::PynqZ2, &big, &n, Method::Guided);
        assert!(!f.fits);
        assert_eq!(f.headroom.dsp.min(f.headroom.lut), 0);
    }

    #[test]
    fn mask_bram_method_dependent() {
        // deconvnet's mask footprint <= saliency's (Table II)
        let n = net();
        assert!(mask_bram(&n, Method::Deconvnet) <= mask_bram(&n, Method::Saliency));
        assert!(mask_bram(&n, Method::Saliency) >= 1);
    }
}
