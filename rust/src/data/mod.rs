//! shapes-32 generator (S14): the rust twin of `python/compile/data.py`.
//!
//! Serving-side request generation needs fresh labelled samples with
//! ground-truth salient-region masks (for the localization metric). The
//! spec matches the python generator exactly — same 10 classes, same
//! parameter ranges — though the PRNG differs, so samples are from the
//! same *distribution*, not bit-identical (nothing ever compares
//! cross-language samples; the trained CNN generalizes across both, as
//! the end-to-end accuracy check in `examples/xai_serve` demonstrates).

use crate::util::rng::Pcg32;

pub const NUM_CLASSES: usize = 10;
pub const IMG_C: usize = 3;
pub const IMG_H: usize = 32;
pub const IMG_W: usize = 32;
pub const IMG_LEN: usize = IMG_C * IMG_H * IMG_W;

pub const CLASS_NAMES: [&str; NUM_CLASSES] = [
    "circle", "square", "triangle", "h-stripes", "v-stripes", "diagonal", "cross",
    "ring", "checker", "dot-grid",
];

/// One generated sample: channel-major image, label, salient-pixel mask.
#[derive(Clone, Debug)]
pub struct Sample {
    pub image: Vec<f32>, // [3*32*32], CHW, values in [0,1]
    pub label: usize,
    pub mask: Vec<bool>, // [32*32], true where the shape was drawn
}

fn shape_mask(cls: usize, rng: &mut Pcg32) -> Vec<bool> {
    let cy = rng.uniform(10.0, 22.0);
    let cx = rng.uniform(10.0, 22.0);
    let r = rng.uniform(5.0, 9.0);
    let mut mask = vec![false; IMG_H * IMG_W];
    for y in 0..IMG_H {
        for x in 0..IMG_W {
            let fy = y as f32;
            let fx = x as f32;
            let dy = fy - cy;
            let dx = fx - cx;
            let inside = match cls {
                0 => dy * dy + dx * dx <= r * r,
                1 => dy.abs() <= r && dx.abs() <= r,
                2 => {
                    // triangle, apex up: h in [0,1] from apex to base
                    let h = (fy - (cy - r)) / (2.0 * r);
                    (0.0..=1.0).contains(&h) && dx.abs() <= h * r
                }
                3 => {
                    let period = ((r as i32) / 2).max(2);
                    dy.abs() <= r && dx.abs() <= r && ((y as i32) / period) % 2 == 0
                }
                4 => {
                    let period = ((r as i32) / 2).max(2);
                    dy.abs() <= r && dx.abs() <= r && ((x as i32) / period) % 2 == 0
                }
                5 => (dy - dx).abs() <= 2.0 && dy.abs() <= r,
                6 => (dy.abs() <= 2.0 || dx.abs() <= 2.0) && dy.abs() <= r && dx.abs() <= r,
                7 => {
                    let d2 = dy * dy + dx * dx;
                    d2 <= r * r && d2 >= (r - 2.5) * (r - 2.5)
                }
                8 => {
                    let period = ((r as i32) / 2).max(2);
                    dy.abs() <= r
                        && dx.abs() <= r
                        && ((y as i32) / period + (x as i32) / period) % 2 == 0
                }
                9 => {
                    let period = ((r as i32) / 2 + 1).max(3);
                    dy.abs() <= r
                        && dx.abs() <= r
                        && (y as i32) % period < 2
                        && (x as i32) % period < 2
                }
                _ => panic!("bad class {cls}"),
            };
            mask[y * IMG_W + x] = inside;
        }
    }
    mask
}

/// Generate one sample of class `cls`.
pub fn make_sample(cls: usize, rng: &mut Pcg32) -> Sample {
    assert!(cls < NUM_CLASSES);
    // noisy background
    let mut image = vec![0f32; IMG_LEN];
    for v in image.iter_mut() {
        *v = rng.uniform(0.0, 0.35);
    }
    let mask = shape_mask(cls, rng);
    // one saturated color with a muted channel
    let mut color = [rng.uniform(0.6, 1.0), rng.uniform(0.6, 1.0), rng.uniform(0.6, 1.0)];
    let muted = rng.below(3) as usize;
    color[muted] *= rng.uniform(0.1, 0.4);
    for (i, &m) in mask.iter().enumerate() {
        if m {
            for c in 0..IMG_C {
                let v = color[c] + 0.05 * rng.normal();
                image[c * IMG_H * IMG_W + i] = v.clamp(0.0, 1.0);
            }
        }
    }
    Sample { image, label: cls, mask }
}

/// Generate `n` samples cycling through classes (balanced).
pub fn make_dataset(n: usize, seed: u64) -> Vec<Sample> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|i| make_sample(i % NUM_CLASSES, &mut rng)).collect()
}

/// Fraction of positive attribution mass inside the ground-truth mask —
/// the localization metric for heatmap quality (E12). A heatmap that
/// ignores the shape scores ~ mask_area/total; a perfect one scores 1.
pub fn localization_score(relevance: &[f32], mask: &[bool]) -> f64 {
    assert_eq!(relevance.len(), IMG_LEN);
    assert_eq!(mask.len(), IMG_H * IMG_W);
    let mut inside = 0f64;
    let mut total = 0f64;
    for c in 0..IMG_C {
        for i in 0..IMG_H * IMG_W {
            let v = relevance[c * IMG_H * IMG_W + i].abs() as f64;
            total += v;
            if mask[i] {
                inside += v;
            }
        }
    }
    if total == 0.0 {
        0.0
    } else {
        inside / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shapes_and_ranges() {
        let mut rng = Pcg32::seeded(1);
        for cls in 0..NUM_CLASSES {
            let s = make_sample(cls, &mut rng);
            assert_eq!(s.image.len(), IMG_LEN);
            assert_eq!(s.mask.len(), IMG_H * IMG_W);
            assert_eq!(s.label, cls);
            assert!(s.image.iter().all(|&v| (0.0..=1.0).contains(&v)));
            let area = s.mask.iter().filter(|&&m| m).count();
            assert!(area > 8, "class {cls} drew only {area} pixels");
            assert!(area < 600, "class {cls} drew {area} pixels (too many)");
        }
    }

    #[test]
    fn shape_pixels_brighter_than_background() {
        // the drawn shape should be distinguishable: mean intensity inside
        // the mask is well above the background mean for most samples
        let mut rng = Pcg32::seeded(7);
        let mut wins = 0;
        for i in 0..50 {
            let s = make_sample(i % NUM_CLASSES, &mut rng);
            let (mut fg, mut nf, mut bg, mut nb) = (0f32, 0, 0f32, 0);
            for p in 0..IMG_H * IMG_W {
                for c in 0..IMG_C {
                    let v = s.image[c * IMG_H * IMG_W + p];
                    if s.mask[p] {
                        fg += v;
                        nf += 1;
                    } else {
                        bg += v;
                        nb += 1;
                    }
                }
            }
            if fg / nf as f32 > bg / nb as f32 + 0.15 {
                wins += 1;
            }
        }
        assert!(wins >= 45, "only {wins}/50 samples had clear contrast");
    }

    #[test]
    fn dataset_balanced_and_deterministic() {
        let a = make_dataset(40, 123);
        let b = make_dataset(40, 123);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.image, y.image);
        }
        let count0 = a.iter().filter(|s| s.label == 0).count();
        assert_eq!(count0, 4);
    }

    #[test]
    fn localization_metric_behaves() {
        let mut rel = vec![0f32; IMG_LEN];
        let mut mask = vec![false; IMG_H * IMG_W];
        for i in 0..100 {
            mask[i] = true;
        }
        // all relevance inside the mask -> 1.0
        for c in 0..IMG_C {
            for i in 0..100 {
                rel[c * 1024 + i] = 1.0;
            }
        }
        assert!((localization_score(&rel, &mask) - 1.0).abs() < 1e-9);
        // all outside -> 0.0
        let mut rel2 = vec![0f32; IMG_LEN];
        for c in 0..IMG_C {
            rel2[c * 1024 + 200] = -2.0; // abs counted
        }
        assert_eq!(localization_score(&rel2, &mask), 0.0);
        // empty relevance -> 0
        assert_eq!(localization_score(&vec![0f32; IMG_LEN], &mask), 0.0);
    }
}
