//! Q-format fixed-point arithmetic (S1): the bit-exact software model of
//! the paper's 16-bit datapath (§IV-A: "configurable data precision is
//! set to 16-bit fixed point for activations, weights and gradient
//! values").
//!
//! Values are stored as `i32` raw integers in Q(m).(f) with saturation
//! to the configured word width; MACs accumulate in `i64` (the FPGA DSP
//! accumulator is 48-bit — i64 is a faithful superset) and are rescaled
//! once per output with round-to-nearest, exactly like an HLS
//! `ap_fixed<W, I, AP_RND, AP_SAT>` pipeline with a wide accumulator.
//!
//! The word width is runtime-configurable (8..=32 bits) to drive the
//! precision-sweep ablation (EXPERIMENTS.md E11).

/// A Q-format descriptor: `word_bits` total (incl. sign), `frac_bits`
/// fractional. Default Q16.9 == 1 sign + 6 integer + 9 fraction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    pub word_bits: u32,
    pub frac_bits: u32,
}

impl QFormat {
    pub const fn new(word_bits: u32, frac_bits: u32) -> Self {
        assert!(word_bits >= 2 && word_bits <= 32);
        assert!(frac_bits < word_bits);
        QFormat { word_bits, frac_bits }
    }

    /// The paper's configuration: 16-bit words, 9 fractional bits.
    pub const fn paper16() -> Self {
        QFormat::new(16, 9)
    }

    /// One raw LSB as a real value.
    pub fn resolution(&self) -> f64 {
        1.0 / (1i64 << self.frac_bits) as f64
    }

    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.word_bits - 1)) - 1
    }

    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.word_bits - 1))
    }

    /// Largest representable real value.
    pub fn max_value(&self) -> f64 {
        self.max_raw() as f64 * self.resolution()
    }

    /// Saturate a wide value into the word range.
    #[inline]
    pub fn saturate(&self, v: i64) -> i32 {
        v.clamp(self.min_raw(), self.max_raw()) as i32
    }

    /// Quantize a real value: round-to-nearest-even-free (ties away from
    /// zero, like `round()` in the AOT quant kernel), saturating.
    #[inline]
    pub fn from_f32(&self, x: f32) -> i32 {
        let scaled = (x as f64) * (1i64 << self.frac_bits) as f64;
        if !scaled.is_finite() {
            return if scaled.is_sign_negative() {
                self.min_raw() as i32
            } else {
                self.max_raw() as i32
            };
        }
        self.saturate(scaled.round() as i64)
    }

    #[inline]
    pub fn to_f32(&self, raw: i32) -> f32 {
        (raw as f64 * self.resolution()) as f32
    }

    /// Quantize-dequantize in one step (the python `quantize_fx` twin).
    pub fn roundtrip(&self, x: f32) -> f32 {
        self.to_f32(self.from_f32(x))
    }

    /// Saturating add of two raw values.
    #[inline]
    pub fn add(&self, a: i32, b: i32) -> i32 {
        self.saturate(a as i64 + b as i64)
    }

    /// Multiply two raw Q values -> raw Q value (rescale + saturate).
    /// A single DSP multiply with output rescaling.
    #[inline]
    pub fn mul(&self, a: i32, b: i32) -> i32 {
        let wide = a as i64 * b as i64; // Q(2f)
        self.saturate(rescale(wide, self.frac_bits))
    }

    /// Rescale a Q(2f) accumulator (sum of raw products) back to Q(f),
    /// round-to-nearest, saturating — the once-per-output-element step.
    #[inline]
    pub fn rescale_acc(&self, acc: i64) -> i32 {
        self.saturate(rescale(acc, self.frac_bits))
    }
}

/// Shift right by `frac` with round-to-nearest (ties away from zero).
#[inline]
fn rescale(v: i64, frac: u32) -> i64 {
    if frac == 0 {
        return v;
    }
    let half = 1i64 << (frac - 1);
    if v >= 0 {
        (v + half) >> frac
    } else {
        -((-v + half) >> frac)
    }
}

/// Quantize an f32 slice into raw Q values.
pub fn quantize_slice(fmt: QFormat, xs: &[f32]) -> Vec<i32> {
    xs.iter().map(|&x| fmt.from_f32(x)).collect()
}

/// Dequantize raw Q values back to f32.
pub fn dequantize_slice(fmt: QFormat, xs: &[i32]) -> Vec<f32> {
    xs.iter().map(|&x| fmt.to_f32(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const Q: QFormat = QFormat::paper16();

    #[test]
    fn paper_format_ranges() {
        assert_eq!(Q.max_raw(), 32767);
        assert_eq!(Q.min_raw(), -32768);
        assert!((Q.resolution() - 1.0 / 512.0).abs() < 1e-15);
        assert!((Q.max_value() - 63.998046875).abs() < 1e-9);
    }

    #[test]
    fn quantize_roundtrip_small_values() {
        for &x in &[0.0f32, 0.5, -0.5, 1.0 / 512.0, 3.14159, -17.25] {
            let rt = Q.roundtrip(x);
            assert!(
                (rt - x).abs() <= Q.resolution() as f32 / 2.0 + 1e-7,
                "x={x} rt={rt}"
            );
        }
    }

    #[test]
    fn saturation_clamps() {
        assert_eq!(Q.from_f32(1e6), 32767);
        assert_eq!(Q.from_f32(-1e6), -32768);
        assert_eq!(Q.from_f32(f32::INFINITY), 32767);
        assert_eq!(Q.from_f32(f32::NEG_INFINITY), -32768);
        assert_eq!(Q.add(32000, 32000), 32767);
        assert_eq!(Q.add(-32000, -32000), -32768);
    }

    #[test]
    fn mul_matches_float_within_resolution() {
        let pairs = [(1.5f32, 2.25f32), (-3.0, 0.125), (7.75, -7.75), (0.001953125, 4.0)];
        for (a, b) in pairs {
            let qa = Q.from_f32(a);
            let qb = Q.from_f32(b);
            let got = Q.to_f32(Q.mul(qa, qb));
            assert!(
                (got - a * b).abs() <= 2.0 * Q.resolution() as f32,
                "{a}*{b}: got {got}"
            );
        }
    }

    #[test]
    fn rescale_rounds_to_nearest_sym() {
        // 1.5 LSB should round to 2, -1.5 LSB to -2 (ties away from zero)
        let f = QFormat::new(16, 1);
        assert_eq!(f.rescale_acc(3), 2); // 3/2 = 1.5 -> 2
        assert_eq!(f.rescale_acc(-3), -2);
        assert_eq!(f.rescale_acc(2), 1);
        assert_eq!(f.rescale_acc(-2), -1);
    }

    #[test]
    fn mac_chain_matches_float() {
        // dot product in Q vs f64, random-ish values well inside range
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        let a: Vec<f32> = (0..256).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..256).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let qa = quantize_slice(Q, &a);
        let qb = quantize_slice(Q, &b);
        let mut acc = 0i64;
        for i in 0..256 {
            acc += qa[i] as i64 * qb[i] as i64;
        }
        let got = Q.to_f32(Q.rescale_acc(acc));
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        // error budget: 256 products each with <= .5 LSB input error
        assert!((got - want).abs() < 0.3, "got {got} want {want}");
    }

    #[test]
    fn narrow_formats() {
        let f8 = QFormat::new(8, 4);
        assert_eq!(f8.max_raw(), 127);
        assert_eq!(f8.from_f32(10.0), 127); // saturates at 7.9375
        assert!((f8.roundtrip(1.25) - 1.25).abs() < 1e-6);
        let f32b = QFormat::new(32, 16);
        assert!((f32b.roundtrip(1234.56789) - 1234.56789).abs() < 2e-5);
    }

    #[test]
    fn property_quantize_error_bounded() {
        crate::util::prop::run_prop(
            Default::default(),
            |r| r.uniform(-60.0, 60.0),
            |&x| {
                let e = (Q.roundtrip(x) - x).abs();
                if e <= Q.resolution() as f32 * 0.5 + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("error {e} for {x}"))
                }
            },
        );
    }
}
