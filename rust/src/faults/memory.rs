//! Copy-on-inject model-memory view: SEU bit flips in weight slabs
//! without ever touching the shared pristine `Arc<Plan>`.
//!
//! A device's "BRAM contents" are modeled as a view over the plan: by
//! default it *is* the shared pristine plan (no copy, no overhead);
//! when the injector fires a weight flip, the view becomes a private
//! corrupted clone ([`Plan::with_flipped_weight_bit`]) carrying the
//! original build-time checksum manifest. The pre-execution scrub
//! ([`Simulator::verify_integrity`]) then detects the flip, and
//! recovery reloads the view from the pristine plan — the DRAM golden
//! copy, in hardware terms.

use std::sync::Arc;
use std::sync::Mutex;

use crate::sched::{IntegrityError, Plan, Simulator};

/// A device's corruptible model-memory view.
pub struct CorruptibleView {
    /// The golden copy (shared, never mutated).
    pristine: Simulator,
    /// The corrupted private copy, when a flip has been injected and
    /// not yet scrubbed. Holds the only strong reference to its plan.
    corrupted: Mutex<Option<Simulator>>,
}

impl CorruptibleView {
    pub fn new(pristine: Simulator) -> CorruptibleView {
        CorruptibleView { pristine, corrupted: Mutex::new(None) }
    }

    /// The pristine simulator (for oracle / recovery callers).
    pub fn pristine(&self) -> &Simulator {
        &self.pristine
    }

    /// Inject: flip one seed-chosen bit in a private clone of the
    /// plan's weight slabs. Returns the flipped slab's name; `None` if
    /// the model has no weights (nothing to corrupt). Idempotent under
    /// repeated injections before a scrub — the newest flip wins.
    pub fn flip_weight_bit(&self, seed: u64) -> Option<String> {
        let (corrupt, slab) = self.pristine.plan().with_flipped_weight_bit(seed)?;
        let sim = Simulator::with_config(Arc::new(corrupt), self.pristine.cfg)
            .expect("clone keeps the plan's own Q format");
        *self.corrupted.lock().unwrap() = Some(sim);
        Some(slab)
    }

    /// Scrub model memory before trusting it: re-checksum the current
    /// view against the build-time manifest. On a detected flip the
    /// view is reloaded from the pristine plan (recovery) and the
    /// violation is returned so the caller can fail the request
    /// typed-ly and count the detection.
    pub fn scrub(&self) -> Result<(), IntegrityError> {
        let mut g = self.corrupted.lock().unwrap();
        let Some(view) = g.as_ref() else {
            // pristine fast path: the manifest was computed from these
            // exact slabs at build, no fault can have been injected
            return Ok(());
        };
        match view.verify_integrity() {
            Ok(()) => Ok(()),
            Err(e) => {
                *g = None; // reload from the DRAM golden copy
                Err(e)
            }
        }
    }

    /// The simulator to execute with right now: the corrupted view if
    /// one is installed (callers scrub first on protected paths).
    pub fn current(&self) -> Simulator {
        self.corrupted.lock().unwrap().clone().unwrap_or_else(|| self.pristine.clone())
    }

    /// Whether a corrupted view is currently installed.
    pub fn is_corrupted(&self) -> bool {
        self.corrupted.lock().unwrap().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::HwConfig;
    use crate::sched::tests_support::tiny_sim;

    #[test]
    fn scrub_detects_flip_and_recovers() {
        let view = CorruptibleView::new(tiny_sim(11, HwConfig::pynq_z2()));
        assert!(view.scrub().is_ok(), "pristine view always passes");
        let slab = view.flip_weight_bit(0xdead_beef).expect("tiny net has weights");
        assert!(view.is_corrupted());
        let err = view.scrub().expect_err("flip must be detected");
        assert_eq!(err.slab, slab, "the violated slab is named");
        assert_ne!(err.expected, err.got);
        // recovery: the view reloaded from the pristine plan
        assert!(!view.is_corrupted());
        assert!(view.scrub().is_ok());
    }

    #[test]
    fn pristine_plan_is_never_mutated() {
        let sim = tiny_sim(12, HwConfig::pynq_z2());
        let view = CorruptibleView::new(sim.clone());
        for seed in 0..8u64 {
            view.flip_weight_bit(seed * 0x9e37_79b9);
            let _ = view.scrub();
        }
        assert!(sim.verify_integrity().is_ok(), "shared Arc<Plan> must stay pristine");
    }

    #[test]
    fn different_seeds_hit_different_slabs_eventually() {
        let view = CorruptibleView::new(tiny_sim(13, HwConfig::pynq_z2()));
        let mut slabs = std::collections::BTreeSet::new();
        for seed in 0..64u64 {
            slabs.insert(view.flip_weight_bit(seed.wrapping_mul(0x2545_f491_4f6c_dd1d)).unwrap());
            let _ = view.scrub();
        }
        assert!(slabs.len() > 1, "bit picker should cover more than one slab: {slabs:?}");
    }
}
