//! Wire-level fault injection: a frame-aware TCP proxy that sits
//! between client and server and corrupts, truncates, or delays
//! protocol frames according to the [`FaultPlan`]'s wire sites.
//!
//! The proxy understands just enough of the wire format — the 12-byte
//! preamble — to inject at *frame* granularity, which is what makes
//! the faults meaningful: a flipped payload bit exercises the CRC
//! path, a truncated frame exercises the client's broken-stream
//! reconnect, a delay exercises deadline handling. Decisions are drawn
//! from the same counter-based hash as every other site (per
//! connection, per direction, per frame), so a chaos run replays
//! bit-identically regardless of thread scheduling.

use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::{salt, splitmix64, FaultHooks, FaultPlan, FaultStats};
use crate::serve::proto::{self, PREAMBLE_LEN};

/// How long a pump blocks on a read before re-checking the stop flag.
const POLL: Duration = Duration::from_millis(20);

/// A fault-injecting proxy listener. Clients connect to
/// [`WireProxy::addr`]; every byte is forwarded to the upstream server
/// with per-frame faults applied in both directions.
pub struct WireProxy {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

impl WireProxy {
    /// Start proxying `127.0.0.1:0` → `upstream` with the given fault
    /// hooks. An all-zero plan makes this a transparent relay.
    pub fn start(upstream: SocketAddr, hooks: FaultHooks) -> io::Result<WireProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept = thread::spawn(move || accept_loop(listener, upstream, hooks, stop2));
        Ok(WireProxy { addr, stop, accept: Some(accept) })
    }

    /// The address clients should connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and wind down all pumps.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WireProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    upstream: SocketAddr,
    hooks: FaultHooks,
    stop: Arc<AtomicBool>,
) {
    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut conn_id = 0u64;
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((down, _)) => {
                let hooks = hooks.clone();
                let stop = stop.clone();
                let id = conn_id;
                conn_id += 1;
                conns.push(thread::spawn(move || relay_conn(down, upstream, hooks, id, stop)));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => thread::sleep(POLL),
            Err(_) => break,
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

/// Bridge one downstream connection to a fresh upstream connection,
/// pumping frames independently in both directions.
fn relay_conn(
    down: TcpStream,
    upstream: SocketAddr,
    hooks: FaultHooks,
    id: u64,
    stop: Arc<AtomicBool>,
) {
    let Ok(up) = TcpStream::connect(upstream) else {
        let _ = down.shutdown(Shutdown::Both);
        return;
    };
    let (Ok(down2), Ok(up2)) = (down.try_clone(), up.try_clone()) else {
        return;
    };
    // direction 0: client → server, direction 1: server → client
    let h2 = hooks.clone();
    let stop2 = stop.clone();
    let c2s = thread::spawn(move || pump(down, up, &h2, id, 0, &stop2));
    pump(up2, down2, &hooks, id, 1, &stop);
    let _ = c2s.join();
}

/// Forward frames from `from` to `to`, applying wire faults. Runs
/// until EOF, error, an injected truncation, or proxy stop.
fn pump(
    mut from: TcpStream,
    mut to: TcpStream,
    hooks: &FaultHooks,
    conn_id: u64,
    dir: u64,
    stop: &AtomicBool,
) {
    let _ = from.set_read_timeout(Some(POLL));
    let plan: &FaultPlan = &hooks.plan;
    // per-connection, per-direction decision stream
    let seed = plan.seed.wrapping_add(conn_id.wrapping_mul(0xbf58_476d_1ce4_e5b9));
    let seed = seed ^ dir.wrapping_mul(0x94d0_49bb_1331_11eb);
    let mut seq = 0u64;
    loop {
        let mut pre = [0u8; PREAMBLE_LEN];
        match read_full(&mut from, &mut pre, stop) {
            ReadEnd::Full => {}
            ReadEnd::CleanEof => break,
            ReadEnd::Broken | ReadEnd::Stopped => {
                let _ = to.shutdown(Shutdown::Both);
                let _ = from.shutdown(Shutdown::Both);
                return;
            }
        }
        let Ok(p) = proto::parse_preamble(&pre) else {
            // not a frame we understand: hand the bytes on and fall
            // back to a dumb byte relay for the rest of the stream
            if to.write_all(&pre).is_ok() {
                let _ = io::copy(&mut from, &mut to);
            }
            break;
        };
        let mut body = vec![0u8; p.header_len + p.payload_len];
        match read_full(&mut from, &mut body, stop) {
            ReadEnd::Full => {}
            _ => {
                let _ = to.shutdown(Shutdown::Both);
                let _ = from.shutdown(Shutdown::Both);
                return;
            }
        }

        if plan.wire.delay.decide(seed, salt::WIRE_DELAY, seq) {
            FaultStats::bump(&hooks.stats.injected_wire_delay);
            if plan.wire.delay_ms > 0 {
                thread::sleep(Duration::from_millis(plan.wire.delay_ms));
            }
        }
        if plan.wire.truncate.decide(seed, salt::WIRE_TRUNCATE, seq) {
            FaultStats::bump(&hooks.stats.injected_wire_truncate);
            // forward the preamble plus half the body, then kill the
            // connection mid-frame — the receiver sees `Truncated`
            let _ = to.write_all(&pre);
            let _ = to.write_all(&body[..body.len() / 2]);
            let _ = to.shutdown(Shutdown::Both);
            let _ = from.shutdown(Shutdown::Both);
            return;
        }
        if p.payload_len > 0 && plan.wire.corrupt.decide(seed, salt::WIRE_CORRUPT, seq) {
            FaultStats::bump(&hooks.stats.injected_wire_corrupt);
            let h = splitmix64(seed ^ salt::WIRE_CORRUPT ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let bit = h % (p.payload_len as u64 * 8);
            body[p.header_len + (bit / 8) as usize] ^= 1u8 << (bit % 8);
        }

        if to.write_all(&pre).is_err() || to.write_all(&body).is_err() {
            let _ = from.shutdown(Shutdown::Both);
            return;
        }
        seq += 1;
    }
    // clean EOF at a frame boundary: half-close so the peer sees it
    let _ = to.shutdown(Shutdown::Write);
}

enum ReadEnd {
    Full,
    /// EOF before the first byte of this read (frame boundary).
    CleanEof,
    /// EOF or error partway through.
    Broken,
    Stopped,
}

/// Fill `buf`, polling the stop flag across read timeouts.
fn read_full(from: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> ReadEnd {
    let mut have = 0usize;
    while have < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return ReadEnd::Stopped;
        }
        match from.read(&mut buf[have..]) {
            Ok(0) if have == 0 => return ReadEnd::CleanEof,
            Ok(0) => return ReadEnd::Broken,
            Ok(n) => have += n,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut
                    || e.kind() == io::ErrorKind::Interrupted =>
            {
                continue
            }
            Err(_) => return ReadEnd::Broken,
        }
    }
    ReadEnd::Full
}

#[cfg(test)]
mod tests {
    use super::super::SiteSpec;
    use super::*;
    use crate::attribution::Method;
    use crate::serve::proto::{read_frame, write_frame, Frame, ProtoError, RequestFrame};
    use std::sync::mpsc;

    fn sample_req(with_crc: bool) -> Frame {
        Frame::Request(RequestFrame {
            id: 7,
            method: Method::Saliency,
            target: None,
            n: 1,
            elems: 8,
            deadline_ms: None,
            with_crc,
            trace_seq: None,
            slo_class: None,
            images: vec![0.25; 8],
        })
    }

    /// Upstream that reads one frame per connection and reports the
    /// decode outcome over a channel.
    fn one_shot_upstream() -> (SocketAddr, mpsc::Receiver<Result<Option<Frame>, ProtoError>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (tx, rx) = mpsc::channel();
        thread::spawn(move || {
            while let Ok((mut conn, _)) = listener.accept() {
                let _ = tx.send(read_frame(&mut conn));
            }
        });
        (addr, rx)
    }

    #[test]
    fn transparent_when_plan_is_zero() {
        let (addr, rx) = one_shot_upstream();
        let mut proxy = WireProxy::start(addr, FaultHooks::new(FaultPlan::none())).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        write_frame(&mut c, &sample_req(true)).unwrap();
        let got = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got.unwrap(), Some(sample_req(true)));
        proxy.stop();
    }

    #[test]
    fn corrupted_payload_is_caught_by_crc() {
        let (addr, rx) = one_shot_upstream();
        let mut plan = FaultPlan::none();
        plan.wire.corrupt = SiteSpec::rate(1.0);
        let hooks = FaultHooks::new(plan);
        let mut proxy = WireProxy::start(addr, hooks.clone()).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        write_frame(&mut c, &sample_req(true)).unwrap();
        let got = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(
            matches!(got, Err(ProtoError::Integrity { .. })),
            "flip must surface as Integrity, got {got:?}"
        );
        assert_eq!(hooks.stats.injected_wire_corrupt.load(Ordering::Relaxed), 1);
        proxy.stop();
    }

    #[test]
    fn truncation_breaks_the_stream_mid_frame() {
        let (addr, rx) = one_shot_upstream();
        let mut plan = FaultPlan::none();
        plan.wire.truncate = SiteSpec::rate(1.0);
        let hooks = FaultHooks::new(plan);
        let mut proxy = WireProxy::start(addr, hooks.clone()).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        write_frame(&mut c, &sample_req(false)).unwrap();
        let got = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(
            matches!(got, Err(ProtoError::Truncated)),
            "receiver must see a mid-frame EOF, got {got:?}"
        );
        assert_eq!(hooks.stats.injected_wire_truncate.load(Ordering::Relaxed), 1);
        proxy.stop();
    }
}
