//! Deterministic fault injection for the serving stack (DESIGN.md
//! §fault model and recovery matrix).
//!
//! Edge FPGAs live with transient faults — SEU bit flips in BRAM
//! weight tiles, stalled AXI transfers, flaky links — so the serving
//! layers must *detect or recover from* injected faults rather than
//! ship corrupt heatmaps or hang. This module is the injection plane:
//!
//! * [`FaultPlan`] — a seeded, schema-tagged (`attrax-faults/v1`)
//!   description of per-site fault rates and arm windows. Decisions
//!   are pure functions of `(seed, site, sequence number)`, so a run
//!   with one client connection and one worker is bit-reproducible
//!   regardless of thread scheduling.
//! * [`wire::WireProxy`] — a frame-aware TCP proxy that truncates,
//!   corrupts, or delays frames in flight (detected by the protocol's
//!   CRC-32 payload field and typed truncation errors).
//! * Admission faults — forced `Busy`/`DeadlineExceeded` at the
//!   server's front door (exercises client retry policies).
//! * [`device::DeviceInjector`] — per-device stall, wrong-answer,
//!   crash-on-Nth-request, and memory bit flips in a copy-on-inject
//!   view of the plan's weight slabs ([`memory::CorruptibleView`] —
//!   the shared `Arc<Plan>` is never mutated). Wrong answers are
//!   caught by dual-modular-redundancy re-execution, weight flips by
//!   the plan's build-time checksum manifest.
//! * [`chaos`] — the `attrax chaos` harness: drive an in-process
//!   server under a `FaultPlan` and emit `BENCH_chaos.json` with
//!   fault/detection/recovery accounting and an escaped-fault oracle.
//!
//! An all-zero plan ([`FaultPlan::none`]) injects nothing and the
//! protected paths take their fast branches — heatmaps, cycle ledgers
//! and metrics stay bit-identical to a build without this module
//! (property P16).

pub mod chaos;
pub mod device;
pub mod memory;
pub mod wire;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::json::{num, obj, s, Json};

/// Schema tag carried by `*.faults.json` configs.
pub const SCHEMA: &str = "attrax-faults/v1";

/// Site salts: every injection site hashes under its own constant so
/// rates are independent across sites at the same sequence number.
pub mod salt {
    pub const WIRE_CORRUPT: u64 = 0x7749_5243_0000_0001;
    pub const WIRE_TRUNCATE: u64 = 0x7749_5254_0000_0002;
    pub const WIRE_DELAY: u64 = 0x7749_5244_0000_0003;
    pub const ADMISSION_BUSY: u64 = 0x4144_4d42_0000_0004;
    pub const ADMISSION_DEADLINE: u64 = 0x4144_4d44_0000_0005;
    pub const DEVICE_STALL: u64 = 0x4445_5653_0000_0006;
    pub const DEVICE_WRONG: u64 = 0x4445_5657_0000_0007;
    pub const MEM_WEIGHT: u64 = 0x4d45_4d57_0000_0008;
    pub const MEM_GRAD: u64 = 0x4d45_4d47_0000_0009;
}

/// SplitMix64 finalizer: the deterministic per-decision hash. Public
/// so other layers (client backoff jitter, perturbation indices) can
/// derive seeded values without a stateful RNG.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Map a hash to a uniform f64 in `[0, 1)` (top 53 bits).
pub fn unit_f64(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// One injection site: a fault probability plus an arm window over the
/// site's sequence counter (`[from, until)` — fire only inside it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SiteSpec {
    pub rate: f64,
    pub from: u64,
    pub until: u64,
}

impl SiteSpec {
    /// Never fires.
    pub const OFF: SiteSpec = SiteSpec { rate: 0.0, from: 0, until: u64::MAX };

    /// Armed for every sequence number at probability `rate`.
    pub fn rate(rate: f64) -> SiteSpec {
        SiteSpec { rate, from: 0, until: u64::MAX }
    }

    pub fn is_off(&self) -> bool {
        self.rate <= 0.0
    }

    /// Deterministic decision for this site at sequence number `seq`:
    /// a pure hash of `(seed, salt, seq)`, independent of thread
    /// interleaving and wall clock.
    pub fn decide(&self, seed: u64, salt: u64, seq: u64) -> bool {
        if self.rate <= 0.0 || seq < self.from || seq >= self.until {
            return false;
        }
        if self.rate >= 1.0 {
            return true;
        }
        unit_f64(splitmix64(seed ^ salt ^ seq.wrapping_mul(0x2545_f491_4f6c_dd1d))) < self.rate
    }
}

/// Wire-layer faults, applied per frame by the proxy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireSpec {
    /// Flip one payload bit of a forwarded frame.
    pub corrupt: SiteSpec,
    /// Forward only a prefix of the frame, then kill the connection.
    pub truncate: SiteSpec,
    /// Hold the frame for `delay_ms` before forwarding.
    pub delay: SiteSpec,
    pub delay_ms: u64,
}

/// Admission-layer faults: forced typed rejections at the server's
/// front door, before the request reaches the coordinator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionSpec {
    pub busy: SiteSpec,
    pub deadline: SiteSpec,
}

/// Device-layer faults, applied per device execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceSpec {
    /// Stall the device for `stall_ms` before it answers.
    pub stall: SiteSpec,
    pub stall_ms: u64,
    /// Perturb the first execution pass's output (caught by DMR).
    pub wrong: SiteSpec,
    /// Crash the device permanently on its Nth request (0 = never).
    pub crash_every: u64,
}

/// Memory faults: SEU-style bit flips.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemorySpec {
    /// Flip one bit in a copy-on-inject view of the plan's weight
    /// slabs (caught by the checksum-manifest scrub).
    pub weight_flip: SiteSpec,
    /// Flip one bit in the gradient/relevance slab of the first DMR
    /// pass (caught by the re-execution compare).
    pub grad_flip: SiteSpec,
}

/// A complete seeded fault schedule. `FaultPlan::none()` is the
/// all-zero plan: nothing fires, protected paths take their fast
/// branches, results are bit-identical to an uninstrumented build.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub wire: WireSpec,
    pub admission: AdmissionSpec,
    pub device: DeviceSpec,
    pub memory: MemorySpec,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            wire: WireSpec {
                corrupt: SiteSpec::OFF,
                truncate: SiteSpec::OFF,
                delay: SiteSpec::OFF,
                delay_ms: 0,
            },
            admission: AdmissionSpec { busy: SiteSpec::OFF, deadline: SiteSpec::OFF },
            device: DeviceSpec {
                stall: SiteSpec::OFF,
                stall_ms: 0,
                wrong: SiteSpec::OFF,
                crash_every: 0,
            },
            memory: MemorySpec { weight_flip: SiteSpec::OFF, grad_flip: SiteSpec::OFF },
        }
    }

    /// True when no site can ever fire.
    pub fn is_none(&self) -> bool {
        self.wire.corrupt.is_off()
            && self.wire.truncate.is_off()
            && self.wire.delay.is_off()
            && self.admission.busy.is_off()
            && self.admission.deadline.is_off()
            && self.device.stall.is_off()
            && self.device.wrong.is_off()
            && self.device.crash_every == 0
            && self.memory.weight_flip.is_off()
            && self.memory.grad_flip.is_off()
    }

    /// Schema-tagged canonical JSON (`attrax-faults/v1`).
    pub fn to_json(&self) -> String {
        let site = |sp: &SiteSpec| {
            if sp.from == 0 && sp.until == u64::MAX {
                num(sp.rate)
            } else {
                obj(vec![
                    ("rate", num(sp.rate)),
                    ("from", num(sp.from as f64)),
                    ("until", num(sp.until as f64)),
                ])
            }
        };
        obj(vec![
            ("schema", s(SCHEMA)),
            ("seed", num(self.seed as f64)),
            (
                "wire",
                obj(vec![
                    ("corrupt", site(&self.wire.corrupt)),
                    ("truncate", site(&self.wire.truncate)),
                    ("delay", site(&self.wire.delay)),
                    ("delay_ms", num(self.wire.delay_ms as f64)),
                ]),
            ),
            (
                "admission",
                obj(vec![
                    ("busy", site(&self.admission.busy)),
                    ("deadline", site(&self.admission.deadline)),
                ]),
            ),
            (
                "device",
                obj(vec![
                    ("stall", site(&self.device.stall)),
                    ("stall_ms", num(self.device.stall_ms as f64)),
                    ("wrong", site(&self.device.wrong)),
                    ("crash_every", num(self.device.crash_every as f64)),
                ]),
            ),
            (
                "memory",
                obj(vec![
                    ("weight_flip", site(&self.memory.weight_flip)),
                    ("grad_flip", site(&self.memory.grad_flip)),
                ]),
            ),
        ])
        .to_string()
    }

    /// Parse a `*.faults.json` config (absent sites default to off).
    pub fn from_json(text: &str) -> anyhow::Result<FaultPlan> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("faults json: {e}"))?;
        let tag = j.get("schema").and_then(Json::as_str).unwrap_or("");
        anyhow::ensure!(tag == SCHEMA, "not a fault plan: schema {tag:?}, want {SCHEMA:?}");
        let site = |j: Option<&Json>, what: &str| -> anyhow::Result<SiteSpec> {
            match j {
                None | Some(Json::Null) => Ok(SiteSpec::OFF),
                Some(v) => {
                    if let Some(rate) = v.as_f64() {
                        anyhow::ensure!(
                            (0.0..=1.0).contains(&rate),
                            "{what}: rate {rate} outside [0, 1]"
                        );
                        return Ok(SiteSpec::rate(rate));
                    }
                    let rate = v
                        .get("rate")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow::anyhow!("{what}: missing rate"))?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&rate),
                        "{what}: rate {rate} outside [0, 1]"
                    );
                    let from = v.get("from").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                    let until = match v.get("until").and_then(Json::as_f64) {
                        Some(u) => u as u64,
                        None => u64::MAX,
                    };
                    anyhow::ensure!(from < until, "{what}: empty arm window");
                    Ok(SiteSpec { rate, from, until })
                }
            }
        };
        let u = |j: Option<&Json>, default: u64| -> u64 {
            j.and_then(Json::as_f64).map(|v| v as u64).unwrap_or(default)
        };
        let mut p = FaultPlan::none();
        p.seed = u(j.get("seed"), 0);
        if let Some(w) = j.get("wire") {
            p.wire.corrupt = site(w.get("corrupt"), "wire.corrupt")?;
            p.wire.truncate = site(w.get("truncate"), "wire.truncate")?;
            p.wire.delay = site(w.get("delay"), "wire.delay")?;
            p.wire.delay_ms = u(w.get("delay_ms"), 0);
        }
        if let Some(a) = j.get("admission") {
            p.admission.busy = site(a.get("busy"), "admission.busy")?;
            p.admission.deadline = site(a.get("deadline"), "admission.deadline")?;
        }
        if let Some(d) = j.get("device") {
            p.device.stall = site(d.get("stall"), "device.stall")?;
            p.device.stall_ms = u(d.get("stall_ms"), 0);
            p.device.wrong = site(d.get("wrong"), "device.wrong")?;
            p.device.crash_every = u(d.get("crash_every"), 0);
        }
        if let Some(m) = j.get("memory") {
            p.memory.weight_flip = site(m.get("weight_flip"), "memory.weight_flip")?;
            p.memory.grad_flip = site(m.get("grad_flip"), "memory.grad_flip")?;
        }
        Ok(p)
    }

    /// Load a `*.faults.json` file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        FaultPlan::from_json(&text)
    }
}

/// Shared injection/detection accounting, updated lock-free from every
/// layer. `injected_*` count faults that actually fired; `detected_*`
/// count the integrity machinery catching them.
#[derive(Debug, Default)]
pub struct FaultStats {
    pub injected_wire_corrupt: AtomicU64,
    pub injected_wire_truncate: AtomicU64,
    pub injected_wire_delay: AtomicU64,
    pub injected_admission_busy: AtomicU64,
    pub injected_admission_deadline: AtomicU64,
    pub injected_device_stall: AtomicU64,
    pub injected_device_wrong: AtomicU64,
    pub injected_device_crash: AtomicU64,
    pub injected_mem_weight_flip: AtomicU64,
    pub injected_mem_grad_flip: AtomicU64,
    /// Wire CRC mismatches caught at decode (server or client side).
    pub detected_crc: AtomicU64,
    /// Weight-slab checksum violations caught by the pre-execution scrub.
    pub detected_checksum: AtomicU64,
    /// DMR re-execution divergences.
    pub detected_dmr: AtomicU64,
}

impl FaultStats {
    pub fn new() -> Arc<FaultStats> {
        Arc::new(FaultStats::default())
    }

    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// `(name, count)` rows in canonical order, for reports and JSON.
    pub fn rows(&self) -> Vec<(&'static str, u64)> {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        vec![
            ("wire_corrupt", g(&self.injected_wire_corrupt)),
            ("wire_truncate", g(&self.injected_wire_truncate)),
            ("wire_delay", g(&self.injected_wire_delay)),
            ("admission_busy", g(&self.injected_admission_busy)),
            ("admission_deadline", g(&self.injected_admission_deadline)),
            ("device_stall", g(&self.injected_device_stall)),
            ("device_wrong", g(&self.injected_device_wrong)),
            ("device_crash", g(&self.injected_device_crash)),
            ("mem_weight_flip", g(&self.injected_mem_weight_flip)),
            ("mem_grad_flip", g(&self.injected_mem_grad_flip)),
        ]
    }

    /// Total injected faults across every site.
    pub fn total_injected(&self) -> u64 {
        self.rows().iter().map(|(_, c)| c).sum()
    }

    /// Total detections by the integrity machinery.
    pub fn total_detected(&self) -> u64 {
        self.detected_crc.load(Ordering::Relaxed)
            + self.detected_checksum.load(Ordering::Relaxed)
            + self.detected_dmr.load(Ordering::Relaxed)
    }
}

/// The (plan, stats) pair a fault-aware component hangs on to.
#[derive(Clone, Debug)]
pub struct FaultHooks {
    pub plan: Arc<FaultPlan>,
    pub stats: Arc<FaultStats>,
}

impl FaultHooks {
    pub fn new(plan: FaultPlan) -> FaultHooks {
        FaultHooks { plan: Arc::new(plan), stats: FaultStats::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_rate_shaped() {
        let site = SiteSpec::rate(0.25);
        let run = |seed: u64, slt: u64| -> Vec<bool> {
            (0..4000).map(|q| site.decide(seed, slt, q)).collect()
        };
        let fires = run(42, salt::WIRE_CORRUPT);
        let again = run(42, salt::WIRE_CORRUPT);
        assert_eq!(fires, again, "same (seed, site, seq) must decide identically");
        let hits = fires.iter().filter(|&&b| b).count();
        assert!((800..1200).contains(&hits), "rate 0.25 over 4000: got {hits}");
        // different salt => different pattern; different seed too
        assert_ne!(fires, run(42, salt::DEVICE_WRONG));
        assert_ne!(fires, run(43, salt::WIRE_CORRUPT));
    }

    #[test]
    fn arm_window_gates_decisions() {
        let site = SiteSpec { rate: 1.0, from: 10, until: 20 };
        for q in 0..30 {
            assert_eq!(site.decide(7, 1, q), (10..20).contains(&q));
        }
        assert!(!SiteSpec::OFF.decide(7, 1, 5));
    }

    #[test]
    fn json_roundtrip() {
        let mut p = FaultPlan::none();
        p.seed = 99;
        p.wire.corrupt = SiteSpec::rate(0.125);
        p.wire.truncate = SiteSpec { rate: 0.5, from: 3, until: 17 };
        p.wire.delay_ms = 4;
        p.admission.busy = SiteSpec::rate(0.0625);
        p.device.stall = SiteSpec::rate(0.25);
        p.device.stall_ms = 2;
        p.device.wrong = SiteSpec::rate(0.03125);
        p.device.crash_every = 40;
        p.memory.weight_flip = SiteSpec::rate(0.015625);
        p.memory.grad_flip = SiteSpec::rate(0.015625);
        let text = p.to_json();
        assert!(text.contains("\"schema\":\"attrax-faults/v1\""));
        let back = FaultPlan::from_json(&text).unwrap();
        assert_eq!(back, p);
        // canonical serialization is stable
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(FaultPlan::from_json("{}").is_err(), "missing schema tag");
        let bad_rate = format!("{{\"schema\":\"{SCHEMA}\",\"wire\":{{\"corrupt\":1.5}}}}");
        assert!(FaultPlan::from_json(&bad_rate).is_err(), "rate outside [0,1]");
        let empty_window = format!(
            "{{\"schema\":\"{SCHEMA}\",\"wire\":{{\"corrupt\":{{\"rate\":0.5,\"from\":9,\"until\":9}}}}}}"
        );
        assert!(FaultPlan::from_json(&empty_window).is_err(), "empty arm window");
    }

    #[test]
    fn none_plan_is_none() {
        assert!(FaultPlan::none().is_none());
        let mut p = FaultPlan::none();
        p.device.crash_every = 1;
        assert!(!p.is_none());
    }
}
