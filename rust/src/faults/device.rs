//! Per-device fault injector: stall, wrong-answer, crash-on-Nth, and
//! memory bit flips — plus the detection machinery that keeps every
//! one of them from escaping as corrupt output.
//!
//! Detection is *honest*: the injector never "self-reports" a wrong
//! answer. Weight flips land in a copy-on-inject view and are caught
//! by the checksum-manifest scrub that runs before every protected
//! execution; transient faults (wrong-answer, gradient-slab flips)
//! perturb only the first of two executions and are caught by
//! bit-exact dual-modular-redundancy comparison — the classic SEU
//! mitigation on edge FPGAs, where a second pass is cheaper than a
//! corrupted explanation. DMR runs only when an injector is attached,
//! so the no-faults serving path keeps its exact performance and
//! numerics.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::memory::CorruptibleView;
use super::{salt, splitmix64, FaultHooks, FaultStats};
use crate::attribution::Method;
use crate::coordinator::fleet::DeviceFault;
use crate::sched::{AttrOptions, BatchOutput, Simulator, Workspace};
use std::sync::Arc;

/// Fault injector attached to one device.
pub struct DeviceInjector {
    plan: Arc<super::FaultPlan>,
    stats: Arc<FaultStats>,
    /// Per-device salt: two devices under one plan draw independent
    /// fault schedules.
    instance: u64,
    /// This device's execution sequence counter (the injection clock).
    seq: AtomicU64,
    /// Crash-on-Nth is permanent once it fires.
    crashed: AtomicBool,
    /// The device's corruptible model-memory view.
    view: CorruptibleView,
    /// Scratch for the DMR second pass.
    dmr: Mutex<(Workspace, BatchOutput)>,
}

impl DeviceInjector {
    pub fn new(hooks: &FaultHooks, instance: u64, pristine: Simulator) -> DeviceInjector {
        DeviceInjector {
            plan: hooks.plan.clone(),
            stats: hooks.stats.clone(),
            instance,
            seq: AtomicU64::new(0),
            crashed: AtomicBool::new(false),
            view: CorruptibleView::new(pristine),
            dmr: Mutex::new((Workspace::with_shards(1), BatchOutput::new())),
        }
    }

    /// Requests this injector has seen.
    pub fn seq(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    pub fn is_crashed(&self) -> bool {
        self.crashed.load(Ordering::Relaxed)
    }

    /// The protected execution pipeline: inject per-site faults, scrub
    /// model memory, execute, DMR-compare. Every injected fault either
    /// has no observable effect (stall/delay) or surfaces as a typed
    /// [`DeviceFault`] — never as silently corrupt output.
    pub fn execute(
        &self,
        ws: &mut Workspace,
        imgs: &[&[f32]],
        method: Method,
        opts: AttrOptions,
        out: &mut BatchOutput,
    ) -> Result<(), DeviceFault> {
        if self.crashed.load(Ordering::Relaxed) {
            return Err(DeviceFault::Crash);
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let p = &*self.plan;
        let seed = p.seed ^ self.instance.wrapping_mul(0xa076_1d64_78bd_642f);

        // crash-on-Nth request: permanent device death
        if p.device.crash_every > 0 && seq + 1 >= p.device.crash_every {
            if !self.crashed.swap(true, Ordering::Relaxed) {
                FaultStats::bump(&self.stats.injected_device_crash);
            }
            return Err(DeviceFault::Crash);
        }

        // stall: the request is answered, late (deadline pressure)
        if p.device.stall.decide(seed, salt::DEVICE_STALL, seq) {
            FaultStats::bump(&self.stats.injected_device_stall);
            if p.device.stall_ms > 0 {
                std::thread::sleep(Duration::from_millis(p.device.stall_ms));
            }
        }

        // memory fault: SEU in a weight slab (copy-on-inject — the
        // shared pristine Arc<Plan> is never touched)
        if p.memory.weight_flip.decide(seed, salt::MEM_WEIGHT, seq) {
            FaultStats::bump(&self.stats.injected_mem_weight_flip);
            self.view.flip_weight_bit(splitmix64(seed ^ salt::MEM_WEIGHT ^ seq));
        }

        // scrub before trusting model memory; a detected flip reloads
        // the view from the pristine plan (recovery on next attempt)
        if let Err(e) = self.view.scrub() {
            FaultStats::bump(&self.stats.detected_checksum);
            return Err(DeviceFault::WeightCorruption(e));
        }
        let sim = self.view.current();

        // first pass
        sim.attribute_batch_into(ws, imgs, method, opts, false, out);

        // transient faults perturb the first pass's observable output:
        // `wrong` models a compute upset, `grad_flip` an SEU in the
        // gradient slab that propagates to the relevance map
        if p.device.wrong.decide(seed, salt::DEVICE_WRONG, seq) {
            FaultStats::bump(&self.stats.injected_device_wrong);
            perturb(out, seed ^ salt::DEVICE_WRONG, seq);
        }
        if p.memory.grad_flip.decide(seed, salt::MEM_GRAD, seq) {
            FaultStats::bump(&self.stats.injected_mem_grad_flip);
            perturb(out, seed ^ salt::MEM_GRAD, seq);
        }

        // DMR: re-execute and compare bit-exactly (P12 guarantees the
        // clean path is deterministic, so any divergence is a fault)
        let mut g = self.dmr.lock().unwrap();
        let (ws2, out2) = &mut *g;
        sim.attribute_batch_into(ws2, imgs, method, opts, false, out2);
        if !outputs_equal(out, out2) {
            FaultStats::bump(&self.stats.detected_dmr);
            return Err(DeviceFault::OutputDivergence);
        }
        Ok(())
    }
}

/// Flip one mantissa bit of one seed-chosen relevance element — the
/// injected transient corruption.
fn perturb(out: &mut BatchOutput, seed: u64, seq: u64) {
    if out.relevance.is_empty() {
        return;
    }
    let h = splitmix64(seed ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let idx = (h % out.relevance.len() as u64) as usize;
    let bit = ((h >> 40) % 23) as u32; // stay in the f32 mantissa
    out.relevance[idx] = f32::from_bits(out.relevance[idx].to_bits() ^ (1u32 << bit));
}

/// Bit-exact output comparison (NaN-safe: compares representations).
fn outputs_equal(a: &BatchOutput, b: &BatchOutput) -> bool {
    a.preds == b.preds
        && a.logits.len() == b.logits.len()
        && a.logits.iter().zip(&b.logits).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.relevance.len() == b.relevance.len()
        && a.relevance.iter().zip(&b.relevance).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[cfg(test)]
mod tests {
    use super::super::{FaultPlan, SiteSpec};
    use super::*;
    use crate::hls::HwConfig;
    use crate::sched::tests_support::tiny_sim;

    fn img() -> Vec<f32> {
        (0..128).map(|i| (i % 13) as f32 / 13.0).collect()
    }

    fn run_one(inj: &DeviceInjector) -> Result<(), DeviceFault> {
        let image = img();
        let mut ws = Workspace::with_shards(1);
        let mut out = BatchOutput::new();
        inj.execute(&mut ws, &[&image], Method::Saliency, AttrOptions::default(), &mut out)
    }

    #[test]
    fn zero_plan_injector_is_never_built_but_executes_cleanly() {
        // even if constructed directly with an all-zero plan, the
        // pipeline passes every request
        let hooks = FaultHooks::new(FaultPlan::none());
        let inj = DeviceInjector::new(&hooks, 0, tiny_sim(31, HwConfig::pynq_z2()));
        for _ in 0..4 {
            run_one(&inj).expect("no sites armed");
        }
        assert_eq!(hooks.stats.total_injected(), 0);
    }

    #[test]
    fn wrong_answer_is_caught_by_dmr() {
        let mut p = FaultPlan::none();
        p.seed = 5;
        p.device.wrong = SiteSpec::rate(1.0);
        let hooks = FaultHooks::new(p);
        let inj = DeviceInjector::new(&hooks, 0, tiny_sim(32, HwConfig::pynq_z2()));
        assert_eq!(run_one(&inj), Err(DeviceFault::OutputDivergence));
        assert_eq!(hooks.stats.injected_device_wrong.load(Ordering::Relaxed), 1);
        assert_eq!(hooks.stats.detected_dmr.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn weight_flip_is_caught_by_scrub_and_recovers() {
        let mut p = FaultPlan::none();
        p.seed = 6;
        p.memory.weight_flip = SiteSpec { rate: 1.0, from: 0, until: 1 }; // first request only
        let hooks = FaultHooks::new(p);
        let inj = DeviceInjector::new(&hooks, 0, tiny_sim(33, HwConfig::pynq_z2()));
        match run_one(&inj) {
            Err(DeviceFault::WeightCorruption(e)) => assert!(!e.slab.is_empty()),
            other => panic!("expected WeightCorruption, got {other:?}"),
        }
        // recovery: the view reloaded from the pristine plan
        run_one(&inj).expect("second request runs on the recovered view");
        assert_eq!(hooks.stats.detected_checksum.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn crash_on_nth_is_permanent() {
        let mut p = FaultPlan::none();
        p.device.crash_every = 3;
        let hooks = FaultHooks::new(p);
        let inj = DeviceInjector::new(&hooks, 0, tiny_sim(34, HwConfig::pynq_z2()));
        run_one(&inj).expect("request 1 fine");
        run_one(&inj).expect("request 2 fine");
        assert_eq!(run_one(&inj), Err(DeviceFault::Crash));
        assert!(inj.is_crashed());
        assert_eq!(run_one(&inj), Err(DeviceFault::Crash), "crashes are permanent");
        assert_eq!(hooks.stats.injected_device_crash.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn clean_requests_match_plain_simulator_bit_exactly() {
        let mut p = FaultPlan::none();
        p.seed = 7;
        // sites armed but never firing in the window we use
        p.device.wrong = SiteSpec { rate: 1.0, from: 1000, until: 2000 };
        let hooks = FaultHooks::new(p);
        let sim = tiny_sim(35, HwConfig::pynq_z2());
        let inj = DeviceInjector::new(&hooks, 0, sim.clone());
        let image = img();
        let mut ws = Workspace::with_shards(1);
        let mut out = BatchOutput::new();
        inj.execute(&mut ws, &[&image], Method::Guided, AttrOptions::default(), &mut out)
            .expect("not in the arm window");
        let want = sim.attribute(&image, Method::Guided, AttrOptions::default());
        assert_eq!(out.preds[0], want.pred);
        assert_eq!(out.relevance_of(0), want.relevance.as_slice());
    }
}
