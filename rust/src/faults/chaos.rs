//! The `attrax chaos` harness: drive the full serving stack — client →
//! wire proxy → TCP server → coordinator → device fleet — under a
//! seeded [`FaultPlan`] and account for every fault's fate.
//!
//! The harness owns a ground-truth oracle: each request's attribution
//! is precomputed on a pristine simulator, and every served response is
//! compared bitwise against it. A fault can then end exactly one of
//! three ways:
//!
//! * **recovered** — the request succeeded with bit-exact output even
//!   though at least one fault fired while it was in flight (retry,
//!   reconnect, resubmit, scrub-reload, or DMR re-execution did its
//!   job);
//! * **failed** — the request surfaced a typed error to the client
//!   (detected and refused: honest, but unavailable);
//! * **escaped** — the client accepted output that differs from the
//!   oracle. This is the integrity failure mode the stack exists to
//!   prevent; the CI gate asserts it is zero.
//!
//! Determinism: the harness uses one client connection and one
//! coordinator worker, so every injection site sees a reproducible
//! sequence number stream and `BENCH_chaos.json` is byte-identical
//! across reruns of the same spec. No wall-clock value enters the
//! report — the latency figure is the modeled device-cycle p99.

use std::time::Duration;

use crate::attribution::ALL_METHODS;
use crate::coordinator::fleet::Device;
use crate::coordinator::{Config, Coordinator};
use crate::fpga::Board;
use crate::hls::HwConfig;
use crate::sched::tests_support::tiny_sim;
use crate::sched::AttrOptions;
use crate::serve::{Client, Server, ServerConfig};
use crate::util::json::{num, obj, s};

use super::wire::WireProxy;
use super::{splitmix64, unit_f64, FaultHooks, FaultPlan, SiteSpec};

/// Schema tag carried by `BENCH_chaos.json`.
pub const REPORT_SCHEMA: &str = "attrax-chaos/v1";

/// One chaos campaign: a request count, a fault schedule, and the
/// recovery machinery's knobs.
#[derive(Clone, Debug)]
pub struct ChaosSpec {
    /// Requests the client issues (sequentially, one connection).
    pub requests: usize,
    /// Seed for the tiny model's parameters and the request images.
    pub model_seed: u64,
    pub plan: FaultPlan,
    /// CRC-protect payloads in both directions. Without it, wire
    /// corruption is *undetectable* and will show up as escaped.
    pub with_crc: bool,
    /// Client-side transparent retries per request.
    pub client_retries: u32,
    /// Client backoff base between retries.
    pub backoff: Duration,
    /// Devices in the fleet (failover needs at least 2).
    pub devices: usize,
}

impl ChaosSpec {
    /// The fixed `--smoke` campaign: every fault site armed at a
    /// modest rate, two devices, CRC on. Small enough for CI, busy
    /// enough that every detection and recovery path fires.
    pub fn smoke() -> ChaosSpec {
        let mut plan = FaultPlan::none();
        plan.seed = 7;
        plan.wire.corrupt = SiteSpec::rate(0.08);
        plan.wire.truncate = SiteSpec::rate(0.04);
        plan.wire.delay = SiteSpec::rate(0.05);
        plan.wire.delay_ms = 1;
        plan.admission.busy = SiteSpec::rate(0.06);
        plan.admission.deadline = SiteSpec::rate(0.02);
        plan.device.stall = SiteSpec::rate(0.05);
        plan.device.stall_ms = 1;
        plan.device.wrong = SiteSpec::rate(0.08);
        plan.device.crash_every = 25;
        plan.memory.weight_flip = SiteSpec::rate(0.05);
        plan.memory.grad_flip = SiteSpec::rate(0.05);
        ChaosSpec {
            requests: 60,
            model_seed: 11,
            plan,
            with_crc: true,
            client_retries: 5,
            backoff: Duration::from_millis(1),
            devices: 2,
        }
    }
}

/// Outcome accounting for one campaign. All counts; the only derived
/// floats (`availability`, `p99_device_mcycles`) are pure functions of
/// deterministic inputs, so the JSON is byte-stable across reruns.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosReport {
    pub seed: u64,
    pub requests: u64,
    /// Bit-exact successes (includes `recovered`).
    pub ok: u64,
    /// Typed errors surfaced to the client after retries ran out.
    pub failed: u64,
    /// Accepted-but-wrong responses. Must be zero with CRC on.
    pub escaped: u64,
    /// Bit-exact successes during which at least one fault fired.
    pub recovered: u64,
    /// Injected-fault counts by site, canonical order.
    pub injected: Vec<(&'static str, u64)>,
    pub detected_crc: u64,
    pub detected_checksum: u64,
    pub detected_dmr: u64,
    pub retries: u64,
    pub breaker_trips: u64,
    pub integrity_failures: u64,
    pub reconnects: u64,
    /// `ok / requests`.
    pub availability: f64,
    /// p99 of modeled device cycles over successful requests, in
    /// megacycles (the "latency under faults" figure — modeled, so it
    /// is reproducible; wall time is not).
    pub p99_device_mcycles: f64,
}

impl ChaosReport {
    /// Canonical `BENCH_chaos.json` body.
    pub fn to_json(&self) -> String {
        let injected =
            self.injected.iter().map(|&(name, c)| (name, num(c as f64))).collect::<Vec<_>>();
        obj(vec![
            ("schema", s(REPORT_SCHEMA)),
            ("seed", num(self.seed as f64)),
            (
                "requests",
                obj(vec![
                    ("total", num(self.requests as f64)),
                    ("ok", num(self.ok as f64)),
                    ("failed", num(self.failed as f64)),
                    ("escaped", num(self.escaped as f64)),
                    ("recovered", num(self.recovered as f64)),
                ]),
            ),
            ("availability", num(self.availability)),
            ("p99_device_mcycles", num(self.p99_device_mcycles)),
            ("injected", obj(injected)),
            (
                "detected",
                obj(vec![
                    ("crc", num(self.detected_crc as f64)),
                    ("checksum", num(self.detected_checksum as f64)),
                    ("dmr", num(self.detected_dmr as f64)),
                ]),
            ),
            (
                "recovery",
                obj(vec![
                    ("retries", num(self.retries as f64)),
                    ("breaker_trips", num(self.breaker_trips as f64)),
                    ("integrity_failures", num(self.integrity_failures as f64)),
                    ("reconnects", num(self.reconnects as f64)),
                ]),
            ),
        ])
        .to_string()
    }
}

/// A deterministic request image: `elems` floats in `[0, 1)` hashed
/// from `(seed, request index)`.
fn request_image(seed: u64, q: u64, elems: usize) -> Vec<f32> {
    (0..elems)
        .map(|i| {
            let h = splitmix64(seed ^ q.rotate_left(23) ^ (i as u64).wrapping_mul(0x9e37));
            unit_f64(h) as f32
        })
        .collect()
}

/// Run one campaign end to end and account for every request.
pub fn run(spec: &ChaosSpec) -> anyhow::Result<ChaosReport> {
    anyhow::ensure!(spec.requests > 0, "chaos needs at least one request");
    anyhow::ensure!(spec.devices > 0, "chaos needs at least one device");
    let sim = tiny_sim(spec.model_seed, HwConfig::pynq_z2());
    let elems = sim.net.input.elems();
    let oracle = sim.clone();

    let hooks = FaultHooks::new(spec.plan);
    let devices = (0..spec.devices)
        .map(|i| {
            let d = Device::from_sim(sim.clone(), Board::PynqZ2).with_faults(&hooks, i as u64);
            std::sync::Arc::new(d)
        })
        .collect::<Vec<_>>();
    // one worker: device/admission sequence numbers then depend only on
    // the (deterministic) request + retry stream, not thread timing
    let coord = Coordinator::start_fleet(
        devices,
        Config { workers: 1, max_batch: 1, ..Config::default() },
        None,
    )?;
    let metrics = coord.metrics.clone();
    let server = Server::start(
        "127.0.0.1:0",
        coord,
        ServerConfig {
            max_conns: 4,
            default_deadline_ms: 0,
            faults: Some(hooks.clone()),
            ..Default::default()
        },
    )?;
    let mut proxy = WireProxy::start(server.local_addr(), hooks.clone())?;

    let mut client = Client::connect(proxy.addr())?;
    client.set_crc(spec.with_crc);
    client.set_recovery(spec.client_retries, spec.backoff, spec.plan.seed);

    let (mut ok, mut failed, mut escaped, mut recovered) = (0u64, 0u64, 0u64, 0u64);
    let mut ok_cycles: Vec<u64> = Vec::with_capacity(spec.requests);
    for q in 0..spec.requests as u64 {
        let image = request_image(spec.model_seed, q, elems);
        let method = ALL_METHODS[(q % 3) as usize];
        let want = oracle.attribute(&image, method, AttrOptions::default());
        let fired_before = hooks.stats.total_injected();
        match client.attribute(&image, method) {
            Ok(got) => {
                let exact = got.pred == want.pred
                    && got.relevance.len() == want.relevance.len()
                    && got
                        .relevance
                        .iter()
                        .zip(&want.relevance)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                if exact {
                    ok += 1;
                    ok_cycles.push(got.device_cycles);
                    if hooks.stats.total_injected() > fired_before {
                        recovered += 1;
                    }
                } else {
                    escaped += 1;
                }
            }
            Err(_) => failed += 1,
        }
    }
    // fold client-side transport recovery into the one metrics record
    for _ in 0..client.reconnects() {
        metrics.record_reconnect();
    }
    drop(client);
    proxy.stop();
    let snap = server.shutdown()?;

    ok_cycles.sort_unstable();
    let p99 = if ok_cycles.is_empty() {
        0.0
    } else {
        let idx = ((ok_cycles.len() as f64) * 0.99).ceil() as usize;
        ok_cycles[idx.clamp(1, ok_cycles.len()) - 1] as f64 / 1.0e6
    };
    Ok(ChaosReport {
        seed: spec.plan.seed,
        requests: spec.requests as u64,
        ok,
        failed,
        escaped,
        recovered,
        injected: hooks.stats.rows(),
        detected_crc: hooks.stats.detected_crc.load(std::sync::atomic::Ordering::Relaxed),
        detected_checksum: hooks
            .stats
            .detected_checksum
            .load(std::sync::atomic::Ordering::Relaxed),
        detected_dmr: hooks.stats.detected_dmr.load(std::sync::atomic::Ordering::Relaxed),
        retries: snap.retries,
        breaker_trips: snap.breaker_trips,
        integrity_failures: snap.integrity_failures,
        reconnects: snap.reconnects,
        availability: ok as f64 / spec.requests as f64,
        p99_device_mcycles: p99,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_plan_serves_everything_bit_exactly() {
        let spec = ChaosSpec {
            requests: 9,
            model_seed: 3,
            plan: FaultPlan::none(),
            with_crc: false,
            client_retries: 0,
            backoff: Duration::ZERO,
            devices: 1,
        };
        let r = run(&spec).unwrap();
        assert_eq!(r.ok, 9);
        assert_eq!(r.failed, 0);
        assert_eq!(r.escaped, 0);
        assert_eq!(r.recovered, 0);
        assert_eq!(r.injected.iter().map(|(_, c)| c).sum::<u64>(), 0);
        assert_eq!(r.availability, 1.0);
        assert!(r.p99_device_mcycles > 0.0);
    }

    #[test]
    fn smoke_campaign_recovers_everything_and_is_deterministic() {
        let a = run(&ChaosSpec::smoke()).unwrap();
        // the CI contract: faults fired, none escaped, recovery ran
        assert!(a.injected.iter().map(|(_, c)| c).sum::<u64>() > 0, "no faults fired");
        assert_eq!(a.escaped, 0, "corrupt output escaped to the client");
        assert!(a.recovered > 0, "no request needed recovery");
        assert!(a.ok + a.failed == a.requests);
        // byte-identical across reruns (same spec, fresh stack)
        let b = run(&ChaosSpec::smoke()).unwrap();
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn report_json_is_schema_tagged() {
        let spec = ChaosSpec {
            requests: 3,
            model_seed: 5,
            plan: FaultPlan::none(),
            with_crc: true,
            client_retries: 1,
            backoff: Duration::ZERO,
            devices: 1,
        };
        let r = run(&spec).unwrap();
        let text = r.to_json();
        assert!(text.contains("\"schema\":\"attrax-chaos/v1\""));
        assert!(text.contains("\"availability\":1"));
    }
}
