//! The `attrax eval` driver: run fidelity, faithfulness and the
//! sanity check over a seeded image set and emit the schema-tagged
//! `BENCH_xeval.json` artifact.
//!
//! Everything is deterministic for a fixed [`EvalSpec`]: images come
//! from `util::rng`, the randomized twin is seeded, no wall-clock
//! value reaches the artifact — two consecutive runs emit
//! byte-identical JSON (the reproducibility bar `BENCH_dse.json` set).
//!
//! Quality metrics are *configuration-invariant* (P2: tiling/unroll
//! never change the arithmetic), so unlike the DSE report there is no
//! board axis here — the sweep axis is the fixed-point format, the
//! only knob that moves heatmap values.

use crate::attribution::{Method, ALL_METHODS};
use crate::fx::QFormat;
use crate::hls::HwConfig;
use crate::model::{Network, Params};
use crate::sched::{AttrOptions, Simulator};
use crate::util::json::{self, Json};
use crate::util::rng::Pcg32;

use super::faithfulness::{self, Curves};
use super::fidelity::{score_pair, FidelityScore, Oracle};
use super::sanity::{self, SanityOutcome, SANITY_RHO_MAX};

/// Schema tag of the `BENCH_xeval.json` artifact.
pub const XEVAL_SCHEMA: &str = "attrax-xeval/v1";

/// Seed offset of the randomized-weights twin, so the sanity shuffle
/// never reuses the image stream.
const SANITY_SEED_XOR: u64 = 0x5a_5a_11_7e;

/// What to evaluate and how hard.
#[derive(Clone, Debug)]
pub struct EvalSpec {
    /// Fixed-point formats to sweep, all distinct. The **first** entry
    /// is the serving format: faithfulness, sanity and the identity
    /// self-check run there.
    pub qformats: Vec<QFormat>,
    /// Seeded evaluation images (uniform in `[0,1)` — structureless on
    /// purpose: the sanity check must not be gifted input structure a
    /// randomized model could echo).
    pub images: usize,
    pub seed: u64,
    /// Top-k fraction of the input size for the pixel-intersection
    /// metric (`k = clamp(round(frac · n), 1, n)`).
    pub topk_frac: f64,
    /// Points per deletion/insertion curve (endpoints included).
    pub steps: usize,
}

impl Default for EvalSpec {
    fn default() -> EvalSpec {
        EvalSpec {
            qformats: vec![
                QFormat::paper16(),
                QFormat::new(12, 6),
                QFormat::new(8, 4),
                QFormat::new(16, 2),
            ],
            images: 4,
            seed: 42,
            topk_frac: 0.1,
            steps: 6,
        }
    }
}

impl EvalSpec {
    /// The CI/offline smoke spec: 2 images, 3 formats, short curves.
    pub fn smoke() -> EvalSpec {
        EvalSpec {
            qformats: vec![QFormat::paper16(), QFormat::new(8, 4), QFormat::new(16, 2)],
            images: 2,
            steps: 5,
            ..Default::default()
        }
    }
}

/// Canonical Q-format label (`Q16.9` = 16-bit word, 9 fraction bits).
pub fn qname(q: QFormat) -> String {
    format!("Q{}.{}", q.word_bits, q.frac_bits)
}

/// Per-(method, format) fidelity: the image mean plus per-image scores.
#[derive(Clone, Debug)]
pub struct FidelitySummary {
    pub q: QFormat,
    pub mean: FidelityScore,
    pub per_image: Vec<FidelityScore>,
}

/// One method's full evaluation.
#[derive(Clone, Debug)]
pub struct MethodEval {
    pub method: Method,
    /// One summary per spec format, in spec order.
    pub fidelity: Vec<FidelitySummary>,
    /// Mean deletion/insertion curves over the image set (serving
    /// format); AUCs are the matching trapezoid integrals.
    pub curves: Curves,
    pub sanity: SanityOutcome,
    /// Identity comparison (serving-format heatmap vs itself): must be
    /// exactly `(1.0, 1.0, 1.0, cap)` — the acceptance self-check.
    /// `score_pair` short-circuits elementwise-equal inputs, so this
    /// alone would be a tautology; see `self_check_raw`.
    pub self_check: FidelityScore,
    /// The same identity comparison pushed through the *full* metric
    /// arithmetic (`util::stats::pearson`/`spearman` directly, no
    /// equality shortcut): must land within float round-off of 1.0, so
    /// a bug in the correlation/ranking code fails the gate instead of
    /// hiding behind the shortcut.
    pub self_check_raw: (f64, f64),
}

/// A full evaluation run.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub seed: u64,
    pub images: usize,
    pub topk: usize,
    pub steps: usize,
    pub qformats: Vec<QFormat>,
    pub methods: Vec<MethodEval>,
}

fn mean_scores(scores: &[FidelityScore]) -> FidelityScore {
    let n = scores.len() as f64;
    FidelityScore {
        pearson: scores.iter().map(|s| s.pearson).sum::<f64>() / n,
        spearman: scores.iter().map(|s| s.spearman).sum::<f64>() / n,
        topk: scores.iter().map(|s| s.topk).sum::<f64>() / n,
        snr_db: scores.iter().map(|s| s.snr_db).sum::<f64>() / n,
    }
}

/// Run the full evaluation: per method, quantized-vs-oracle fidelity
/// across the format sweep, deletion/insertion faithfulness and the
/// parameter-randomization sanity check on the serving format.
pub fn run_eval(net: &Network, params: &Params, spec: &EvalSpec) -> anyhow::Result<EvalReport> {
    anyhow::ensure!(!spec.qformats.is_empty(), "eval needs at least one fixed-point format");
    for (i, a) in spec.qformats.iter().enumerate() {
        anyhow::ensure!(
            !spec.qformats[..i].contains(a),
            "duplicate format {} in the sweep",
            qname(*a)
        );
    }
    anyhow::ensure!(spec.images >= 1, "eval needs at least one image");
    anyhow::ensure!(spec.steps >= 2, "curves need at least their two endpoints");
    anyhow::ensure!(
        spec.topk_frac > 0.0 && spec.topk_frac <= 1.0,
        "topk_frac must be in (0, 1]"
    );

    let oracle = Oracle::new(net, params)?;
    let mut sims = Vec::with_capacity(spec.qformats.len());
    for &q in &spec.qformats {
        // any valid tiling works here: heatmaps are bit-identical
        // across unroll/tile configs (property P2) — only `q` moves
        // the arithmetic, so this choice is a speed knob, not part of
        // the measured reference semantics
        let mut cfg = HwConfig::with_unroll(1, 1, 16);
        cfg.q = q;
        sims.push(Simulator::new(net.clone(), params, cfg)?);
    }
    let serving = &sims[0];
    let rand_sim = Simulator::new(
        net.clone(),
        &sanity::shuffle_params(params, spec.seed ^ SANITY_SEED_XOR),
        serving.cfg,
    )?;

    let n_in = net.input.elems();
    let k = ((spec.topk_frac * n_in as f64).round() as usize).clamp(1, n_in);
    let mut rng = Pcg32::seeded(spec.seed);
    let images: Vec<Vec<f32>> =
        (0..spec.images).map(|_| (0..n_in).map(|_| rng.f32()).collect()).collect();
    let img_refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();

    let mut methods = Vec::with_capacity(ALL_METHODS.len());
    for method in ALL_METHODS {
        // one unquantized reference per image; its prediction is the
        // class BOTH paths explain (a prediction flip under
        // quantization must show up as heatmap infidelity, not as two
        // heatmaps faithfully explaining different classes)
        let references: Vec<_> =
            images.iter().map(|img| oracle.attribute(img, method, None)).collect();

        let mut fidelity = Vec::with_capacity(sims.len());
        let mut serving_heatmaps: Vec<Vec<f32>> = Vec::new();
        for (qi, sim) in sims.iter().enumerate() {
            let mut per_image = Vec::with_capacity(images.len());
            for (img, r) in images.iter().zip(&references) {
                let qr = sim.attribute(
                    img,
                    method,
                    AttrOptions { target: Some(r.pred), ..Default::default() },
                );
                per_image.push(score_pair(&qr.relevance, &r.relevance, k));
                if qi == 0 {
                    serving_heatmaps.push(qr.relevance);
                }
            }
            fidelity.push(FidelitySummary {
                q: spec.qformats[qi],
                mean: mean_scores(&per_image),
                per_image,
            });
        }

        // mean faithfulness curves on the serving format
        let per_image_curves: Vec<Curves> = images
            .iter()
            .zip(&serving_heatmaps)
            .zip(&references)
            .map(|((img, heat), r)| faithfulness::curves(serving, img, heat, r.pred, spec.steps))
            .collect();
        let n = per_image_curves.len() as f64;
        let mut deletion = vec![0f64; spec.steps];
        let mut insertion = vec![0f64; spec.steps];
        for c in &per_image_curves {
            for i in 0..spec.steps {
                deletion[i] += c.deletion[i];
                insertion[i] += c.insertion[i];
            }
        }
        for v in deletion.iter_mut().chain(insertion.iter_mut()) {
            *v /= n;
        }
        let curves = Curves {
            fractions: per_image_curves[0].fractions.clone(),
            deletion,
            insertion,
            deletion_auc: per_image_curves.iter().map(|c| c.deletion_auc).sum::<f64>() / n,
            insertion_auc: per_image_curves.iter().map(|c| c.insertion_auc).sum::<f64>() / n,
        };

        let sanity = sanity::check(serving, &rand_sim, &img_refs, method);
        let h0 = &serving_heatmaps[0];
        let self_check = score_pair(h0, h0, k);
        let self_check_raw =
            (crate::util::stats::pearson(h0, h0), crate::util::stats::spearman(h0, h0));
        methods.push(MethodEval { method, fidelity, curves, sanity, self_check, self_check_raw });
    }

    Ok(EvalReport {
        seed: spec.seed,
        images: spec.images,
        topk: k,
        steps: spec.steps,
        qformats: spec.qformats.clone(),
        methods,
    })
}

// ---------------------------------------------------------------------------
// Rendering + artifact
// ---------------------------------------------------------------------------

fn score_json(s: &FidelityScore) -> Json {
    json::obj(vec![
        ("pearson", json::num(s.pearson)),
        ("spearman", json::num(s.spearman)),
        ("topk", json::num(s.topk)),
        ("snr_db", json::num(s.snr_db)),
    ])
}

impl EvalReport {
    /// Did every method's identity self-check score exact fidelity —
    /// both through `score_pair`'s equality shortcut AND through the
    /// raw correlation arithmetic — and its sanity check report
    /// decorrelation? (The `--smoke` acceptance gate.)
    pub fn all_checks_pass(&self) -> bool {
        self.methods.iter().all(|m| {
            m.self_check.pearson == 1.0
                && m.self_check.spearman == 1.0
                && m.self_check.topk == 1.0
                && (m.self_check_raw.0 - 1.0).abs() < 1e-9
                && (m.self_check_raw.1 - 1.0).abs() < 1e-9
                && m.sanity.pass
        })
    }

    /// The `BENCH_xeval.json` payload (deterministic: method order is
    /// `ALL_METHODS`, objects are `BTreeMap`-keyed, no timestamps).
    pub fn to_json(&self) -> Json {
        let methods = self
            .methods
            .iter()
            .map(|m| {
                let fid = m
                    .fidelity
                    .iter()
                    .map(|f| {
                        let per: Vec<Json> = f.per_image.iter().map(score_json).collect();
                        let mut o = score_json(&f.mean);
                        if let Json::Obj(map) = &mut o {
                            map.insert("per_image".into(), json::arr(per));
                        }
                        (qname(f.q), o)
                    })
                    .collect::<Vec<_>>();
                let fid_obj = Json::Obj(fid.into_iter().collect());
                let curve_arr =
                    |xs: &[f64]| json::arr(xs.iter().map(|&v| json::num(v)).collect());
                (
                    m.method.name(),
                    json::obj(vec![
                        ("fidelity", fid_obj),
                        (
                            "faithfulness",
                            json::obj(vec![
                                ("fractions", curve_arr(&m.curves.fractions)),
                                ("deletion", curve_arr(&m.curves.deletion)),
                                ("insertion", curve_arr(&m.curves.insertion)),
                                ("deletion_auc", json::num(m.curves.deletion_auc)),
                                ("insertion_auc", json::num(m.curves.insertion_auc)),
                            ]),
                        ),
                        (
                            "sanity",
                            json::obj(vec![
                                ("mean_abs_pearson", json::num(m.sanity.mean_abs_pearson)),
                                ("mean_abs_spearman", json::num(m.sanity.mean_abs_spearman)),
                                ("threshold", json::num(SANITY_RHO_MAX)),
                                ("pass", Json::Bool(m.sanity.pass)),
                            ]),
                        ),
                        ("self_check", {
                            let mut o = score_json(&m.self_check);
                            if let Json::Obj(map) = &mut o {
                                map.insert(
                                    "raw_pearson".into(),
                                    json::num(m.self_check_raw.0),
                                );
                                map.insert(
                                    "raw_spearman".into(),
                                    json::num(m.self_check_raw.1),
                                );
                            }
                            o
                        }),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("bench", json::s("xeval")),
            ("schema", json::s(XEVAL_SCHEMA)),
            // decimal string: u64 seeds above 2^53 don't survive f64
            ("seed", json::s(&self.seed.to_string())),
            ("images", json::num(self.images as f64)),
            ("topk", json::num(self.topk as f64)),
            ("steps", json::num(self.steps as f64)),
            (
                "qformats",
                json::arr(self.qformats.iter().map(|&q| json::s(&qname(q))).collect()),
            ),
            ("methods", json::obj(methods)),
        ])
    }

    /// Human summary table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "{:<11} {:<7} {:>8} {:>9} {:>6} {:>8}   {:>8} {:>8}   {:>7}\n",
            "method", "format", "pearson", "spearman", "top-k", "SNR(dB)", "del-AUC",
            "ins-AUC", "sanity"
        );
        for m in &self.methods {
            for (i, f) in m.fidelity.iter().enumerate() {
                let (del, ins, sane) = if i == 0 {
                    (
                        format!("{:>8.3}", m.curves.deletion_auc),
                        format!("{:>8.3}", m.curves.insertion_auc),
                        format!(
                            "{:>7}",
                            if m.sanity.pass { "pass" } else { "FAIL" }
                        ),
                    )
                } else {
                    (format!("{:>8}", "-"), format!("{:>8}", "-"), format!("{:>7}", "-"))
                };
                out.push_str(&format!(
                    "{:<11} {:<7} {:>8.4} {:>9.4} {:>6.3} {:>8.1}   {del} {ins}   {sane}\n",
                    if i == 0 { m.method.name() } else { "" },
                    qname(f.q),
                    f.mean.pearson,
                    f.mean.spearman,
                    f.mean.topk,
                    f.mean.snr_db,
                ));
            }
            out.push_str(&format!(
                "{:<11} sanity |ρ|: pearson {:.4} spearman {:.4} (threshold {SANITY_RHO_MAX})\n",
                "", m.sanity.mean_abs_pearson, m.sanity.mean_abs_spearman
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::tests_support::tiny_net_params;

    fn tiny_spec() -> EvalSpec {
        EvalSpec {
            qformats: vec![QFormat::paper16(), QFormat::new(16, 2)],
            images: 2,
            seed: 9,
            topk_frac: 0.1,
            steps: 4,
        }
    }

    #[test]
    fn run_is_deterministic_and_self_checked() {
        let (net, params) = tiny_net_params(71);
        let spec = tiny_spec();
        let a = run_eval(&net, &params, &spec).unwrap();
        let b = run_eval(&net, &params, &spec).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.methods.len(), 3);
        for m in &a.methods {
            // the identity comparison is exact by contract, and the
            // raw arithmetic pass (no equality shortcut) lands within
            // round-off of it
            assert_eq!(m.self_check.pearson, 1.0, "{}", m.method);
            assert_eq!(m.self_check.spearman, 1.0, "{}", m.method);
            assert_eq!(m.self_check.topk, 1.0, "{}", m.method);
            assert!((m.self_check_raw.0 - 1.0).abs() < 1e-9, "{}", m.method);
            assert!((m.self_check_raw.1 - 1.0).abs() < 1e-9, "{}", m.method);
            assert_eq!(m.fidelity.len(), 2);
            for f in &m.fidelity {
                assert_eq!(f.per_image.len(), 2);
                assert!(f.mean.pearson.is_finite());
            }
            assert!(m.curves.deletion_auc.is_finite());
        }
        // the artifact parses back and carries the schema tag
        let j = Json::parse(&a.to_json().to_string()).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(XEVAL_SCHEMA));
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("xeval"));
        assert!(j.path(&["methods", "guided", "sanity", "pass"]).is_some());
    }

    #[test]
    fn paper_format_beats_q16_2_on_fidelity() {
        // Q16.2 keeps two fraction bits — heatmap resolution 0.25 —
        // while Q16.9 resolves 1/512: the paper format must track the
        // oracle strictly better on every method's mean Pearson
        let (net, params) = tiny_net_params(73);
        let r = run_eval(&net, &params, &tiny_spec()).unwrap();
        for m in &r.methods {
            let hi = &m.fidelity[0].mean;
            let lo = &m.fidelity[1].mean;
            assert!(
                hi.pearson > lo.pearson,
                "{}: Q16.9 ρ={} vs Q16.2 ρ={}",
                m.method,
                hi.pearson,
                lo.pearson
            );
            assert!(hi.pearson > 0.8, "{}: paper-format fidelity only {}", m.method, hi.pearson);
            assert!(hi.snr_db > lo.snr_db, "{}", m.method);
        }
    }

    #[test]
    fn spec_validation_rejects_nonsense() {
        let (net, params) = tiny_net_params(75);
        let mut s = tiny_spec();
        s.qformats.clear();
        assert!(run_eval(&net, &params, &s).is_err());
        let mut s = tiny_spec();
        s.qformats.push(QFormat::paper16());
        assert!(run_eval(&net, &params, &s).is_err(), "duplicate format");
        let mut s = tiny_spec();
        s.images = 0;
        assert!(run_eval(&net, &params, &s).is_err());
        let mut s = tiny_spec();
        s.steps = 1;
        assert!(run_eval(&net, &params, &s).is_err());
        let mut s = tiny_spec();
        s.topk_frac = 0.0;
        assert!(run_eval(&net, &params, &s).is_err());
    }
}
