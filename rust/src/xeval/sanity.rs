//! Parameter-randomization sanity check (the Adebayo-style guard): an
//! attribution that is genuinely *gradient-dependent* must decorrelate
//! when the model's weights are destroyed. If reshuffling every
//! parameter tensor leaves the heatmap looking the same, the dataflow
//! is echoing the input (an edge detector), not explaining the model.
//!
//! The randomization is a seeded Fisher-Yates **reshuffle** of each
//! tensor's values — the weight *distribution* is preserved exactly
//! (same values, new positions), so activations keep their scale and
//! the comparison isolates structure, not magnitude.

use crate::attribution::Method;
use crate::model::Params;
use crate::sched::{AttrOptions, Simulator};
use crate::util::rng::Pcg32;
use crate::util::stats::{pearson, spearman};

/// Documented decorrelation threshold: the check passes when the mean
/// |Pearson| and mean |Spearman| between original and
/// randomized-weight heatmaps both fall below this. Independent
/// heatmaps of dimension d correlate at O(1/√d) (≈0.02 for the
/// Table-III input), so 0.5 leaves a wide margin while still failing
/// any dataflow that substantially survives weight destruction.
pub const SANITY_RHO_MAX: f64 = 0.5;

/// Outcome of the randomization check for one method.
#[derive(Clone, Copy, Debug)]
pub struct SanityOutcome {
    pub mean_abs_pearson: f64,
    pub mean_abs_spearman: f64,
    pub pass: bool,
}

/// Independently reshuffle every parameter tensor (deterministic for a
/// fixed seed; tensors are visited in `BTreeMap` name order, so the
/// result is independent of how the store was built).
pub fn shuffle_params(params: &Params, seed: u64) -> Params {
    let mut rng = Pcg32::seeded(seed);
    let mut out = params.clone();
    for tensor in out.tensors.values_mut() {
        rng.shuffle(&mut tensor.data);
    }
    out
}

/// Run the check: attribute `images` on the true-weight simulator and
/// on the reshuffled-weight twin (both from each image's own argmax —
/// the two models legitimately disagree on the prediction), and
/// compare the heatmaps.
pub fn check(
    sim: &Simulator,
    randomized: &Simulator,
    images: &[&[f32]],
    method: Method,
) -> SanityOutcome {
    assert!(!images.is_empty(), "sanity check needs at least one image");
    let (mut sum_p, mut sum_s) = (0f64, 0f64);
    for img in images {
        let a = sim.attribute(img, method, AttrOptions::default());
        let b = randomized.attribute(img, method, AttrOptions::default());
        // degenerate heatmaps (e.g. all-zero after randomization) hit
        // the pearson/spearman constant-input contract: 0.0 against a
        // varying heatmap — i.e. they count as decorrelated, never NaN
        sum_p += pearson(&a.relevance, &b.relevance).abs();
        sum_s += spearman(&a.relevance, &b.relevance).abs();
    }
    let n = images.len() as f64;
    let (mean_abs_pearson, mean_abs_spearman) = (sum_p / n, sum_s / n);
    SanityOutcome {
        mean_abs_pearson,
        mean_abs_spearman,
        pass: mean_abs_pearson < SANITY_RHO_MAX && mean_abs_spearman < SANITY_RHO_MAX,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::HwConfig;
    use crate::sched::tests_support::tiny_net_params;
    use crate::util::rng::Pcg32;

    #[test]
    fn shuffle_is_a_seeded_permutation() {
        let (_, params) = tiny_net_params(61);
        let a = shuffle_params(&params, 9);
        let b = shuffle_params(&params, 9);
        let c = shuffle_params(&params, 10);
        let mut any_moved = false;
        for (name, t) in &params.tensors {
            let (ta, tb, tc) = (&a.tensors[name], &b.tensors[name], &c.tensors[name]);
            // multiset preserved: same values, possibly new order
            let mut orig = t.data.clone();
            let mut shuf = ta.data.clone();
            orig.sort_by(|x, y| x.partial_cmp(y).unwrap());
            shuf.sort_by(|x, y| x.partial_cmp(y).unwrap());
            assert_eq!(orig, shuf, "{name}: shuffle changed the value multiset");
            assert_eq!(ta.shape, t.shape);
            assert_eq!(ta.data, tb.data, "{name}: same seed, different shuffle");
            if ta.data != t.data || ta.data != tc.data {
                any_moved = true;
            }
        }
        assert!(any_moved, "shuffles changed nothing at all");
    }

    #[test]
    fn identity_twin_fails_the_check() {
        // negative control: "randomizing" with the original weights
        // correlates perfectly, so the check must NOT pass — the test
        // that the metric can actually detect a sanity violation
        let (net, params) = tiny_net_params(63);
        let sim = Simulator::new(net, &params, HwConfig::pynq_z2()).unwrap();
        let n_in = sim.net.input.elems();
        let mut rng = Pcg32::seeded(64);
        let imgs: Vec<Vec<f32>> = (0..2).map(|_| (0..n_in).map(|_| rng.f32()).collect()).collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let out = check(&sim, &sim, &refs, Method::Guided);
        assert!(out.mean_abs_pearson > 0.999_999, "{}", out.mean_abs_pearson);
        assert!(out.mean_abs_spearman > 0.999_999, "{}", out.mean_abs_spearman);
        assert!(!out.pass);
    }

    #[test]
    fn check_is_deterministic() {
        let (net, params) = tiny_net_params(65);
        let rand_params = shuffle_params(&params, 66);
        let sim = Simulator::new(net.clone(), &params, HwConfig::pynq_z2()).unwrap();
        let rand_sim = Simulator::new(net, &rand_params, HwConfig::pynq_z2()).unwrap();
        let n_in = sim.net.input.elems();
        let mut rng = Pcg32::seeded(67);
        let imgs: Vec<Vec<f32>> = (0..2).map(|_| (0..n_in).map(|_| rng.f32()).collect()).collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        for method in crate::attribution::ALL_METHODS {
            let a = check(&sim, &rand_sim, &refs, method);
            let b = check(&sim, &rand_sim, &refs, method);
            assert_eq!(a.mean_abs_pearson, b.mean_abs_pearson);
            assert_eq!(a.mean_abs_spearman, b.mean_abs_spearman);
            assert!(a.mean_abs_pearson.is_finite() && a.mean_abs_spearman.is_finite());
            assert!(a.mean_abs_pearson >= 0.0 && a.mean_abs_pearson <= 1.0);
        }
    }
}
