//! Deletion/insertion faithfulness curves: a heatmap is scored by
//! whether the pixels it nominates are the ones the network actually
//! relies on.
//!
//! **Deletion**: rank pixels by attributed relevance (channel-summed,
//! value-descending, index-ascending ties), progressively replace the
//! top-ranked pixels with the masking baseline, re-run the forward
//! pass and watch the target logit. A faithful heatmap makes the logit
//! collapse quickly → *low* deletion AUC is good.
//!
//! **Insertion**: the dual — start from the fully-masked baseline and
//! progressively reveal the top-ranked pixels. A faithful heatmap
//! recovers the logit quickly → *high* insertion AUC is good.
//!
//! **Masking policy** (documented contract, DESIGN.md §xeval): the
//! baseline is the *per-channel mean* of the image under evaluation —
//! masking destroys spatial information without moving the input off
//! its per-channel operating point (a zero baseline would conflate
//! "pixel removed" with "pixel painted black", a legal input value).
//! A masked pixel is replaced across **all** channels at once; the
//! per-pixel rank is the channel-summed relevance
//! ([`attribution::channel_sum`]).
//!
//! The curve samples `steps` fractions uniformly in `[0, 1]`
//! (endpoints included: step 0 is the untouched image for deletion /
//! the pure baseline for insertion, step `steps−1` the reverse), and
//! the AUC is [`util::stats::auc`] over the raw target logit (this
//! stack has no softmax; logits are the device's native output). All
//! `2·steps − 2` distinct masked variants (the two endpoint inputs are
//! shared between the curves) run through one
//! [`Simulator::logits_batch`] pass, so the model weights stream from
//! DRAM once per curve pair.

use crate::attribution::channel_sum;
use crate::model::Shape;
use crate::sched::Simulator;
use crate::util::stats::auc;

use super::top_k_indices;

/// One image's deletion/insertion curve pair.
#[derive(Clone, Debug)]
pub struct Curves {
    /// Masked-pixel fractions (the shared x axis), `0.0 ..= 1.0`.
    pub fractions: Vec<f64>,
    /// Target logit with the top `fᵢ` pixels mean-filled.
    pub deletion: Vec<f64>,
    /// Target logit with only the top `fᵢ` pixels revealed.
    pub insertion: Vec<f64>,
    pub deletion_auc: f64,
    pub insertion_auc: f64,
}

/// Compute the curve pair for one (image, heatmap, target class)
/// triple on the quantized simulator. `steps >= 2` (the endpoints).
pub fn curves(
    sim: &Simulator,
    image: &[f32],
    heatmap: &[f32],
    target: usize,
    steps: usize,
) -> Curves {
    assert!(steps >= 2, "a curve needs at least its two endpoints");
    let (c, h, w) = match sim.net.input {
        Shape::Chw(c, h, w) => (c, h, w),
        Shape::Flat(n) => (1, 1, n),
    };
    let hw = h * w;
    assert_eq!(image.len(), c * hw, "image/shape mismatch");
    let site_rel = channel_sum(heatmap, (c, h, w));
    let order = top_k_indices(&site_rel, hw);

    let ch_mean: Vec<f32> =
        (0..c).map(|ch| image[ch * hw..(ch + 1) * hw].iter().sum::<f32>() / hw as f32).collect();
    let baseline: Vec<f32> = (0..c * hw).map(|i| ch_mean[i / hw]).collect();

    let fractions: Vec<f64> = (0..steps).map(|i| i as f64 / (steps - 1) as f64).collect();
    // variant layout: one deletion variant per fraction (indices
    // 0..steps), then insertion variants for the *interior* fractions
    // only — the endpoints are shared (deletion f=0 == insertion f=1
    // == the untouched image; deletion f=1 == insertion f=0 == the
    // pure baseline), so a curve pair costs 2·steps − 2 forward
    // passes, not 2·steps.
    let mut variants: Vec<Vec<f32>> = Vec::with_capacity(2 * steps - 2);
    for &f in &fractions {
        let n_mask = (f * hw as f64).round() as usize;
        let mut del = image.to_vec();
        for &site in &order[..n_mask] {
            for ch in 0..c {
                del[ch * hw + site] = ch_mean[ch];
            }
        }
        variants.push(del);
    }
    for &f in &fractions[1..steps - 1] {
        let n_mask = (f * hw as f64).round() as usize;
        let mut ins = baseline.clone();
        for &site in &order[..n_mask] {
            for ch in 0..c {
                ins[ch * hw + site] = image[ch * hw + site];
            }
        }
        variants.push(ins);
    }
    let refs: Vec<&[f32]> = variants.iter().map(|v| v.as_slice()).collect();
    let logits = sim.logits_batch(&refs);
    let deletion: Vec<f64> = (0..steps).map(|i| logits[i][target] as f64).collect();
    let insertion: Vec<f64> = (0..steps)
        .map(|i| {
            if i == 0 {
                deletion[steps - 1] // pure baseline
            } else if i == steps - 1 {
                deletion[0] // untouched image
            } else {
                logits[steps + (i - 1)][target] as f64
            }
        })
        .collect();
    let deletion_auc = auc(&fractions, &deletion);
    let insertion_auc = auc(&fractions, &insertion);
    Curves { fractions, deletion, insertion, deletion_auc, insertion_auc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::Method;
    use crate::hls::HwConfig;
    use crate::sched::tests_support::tiny_sim;
    use crate::sched::AttrOptions;
    use crate::util::rng::Pcg32;

    #[test]
    fn curve_endpoints_pin_the_masking_semantics() {
        let sim = tiny_sim(51, HwConfig::pynq_z2());
        let n_in = sim.net.input.elems();
        let mut rng = Pcg32::seeded(52);
        let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
        let r = sim.attribute(&img, Method::Guided, AttrOptions::default());
        let cv = curves(&sim, &img, &r.relevance, r.pred, 5);
        assert_eq!(cv.fractions, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
        // fraction 0: deletion is the untouched image, insertion the
        // pure baseline; fraction 1: exactly swapped
        let orig = r.logits[r.pred] as f64;
        assert_eq!(cv.deletion[0], orig);
        assert_eq!(cv.insertion[4], orig);
        assert_eq!(cv.deletion[4], cv.insertion[0], "full mask == pure baseline");
        // both AUCs are finite trapezoid sums over these points
        assert!(cv.deletion_auc.is_finite() && cv.insertion_auc.is_finite());
    }

    #[test]
    fn curves_are_deterministic_and_heatmap_sensitive() {
        let sim = tiny_sim(53, HwConfig::pynq_z2());
        let n_in = sim.net.input.elems();
        let mut rng = Pcg32::seeded(54);
        let img: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
        let r = sim.attribute(&img, Method::Saliency, AttrOptions::default());
        let a = curves(&sim, &img, &r.relevance, r.pred, 4);
        let b = curves(&sim, &img, &r.relevance, r.pred, 4);
        assert_eq!(a.deletion, b.deletion);
        assert_eq!(a.insertion, b.insertion);
        // positive scaling of the heatmap never changes the ranking,
        // hence never the curves
        let scaled: Vec<f32> = r.relevance.iter().map(|v| v * 3.5).collect();
        let c = curves(&sim, &img, &scaled, r.pred, 4);
        assert_eq!(a.deletion, c.deletion);
        assert_eq!(a.insertion, c.insertion);
        // a reversed heatmap masks different pixels first (interior
        // points differ; endpoints are rank-independent by definition)
        let rev: Vec<f32> = r.relevance.iter().map(|v| -v).collect();
        let d = curves(&sim, &img, &rev, r.pred, 4);
        assert!(
            a.deletion[1..3] != d.deletion[1..3] || a.insertion[1..3] != d.insertion[1..3],
            "reversed ranking produced identical curves"
        );
    }
}
