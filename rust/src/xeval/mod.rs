//! Attribution-quality evaluation (S13, ISSUE-5): is a fixed-point
//! heatmap still *right*?
//!
//! The paper claims 16-bit fixed-point heatmaps come at "minimal
//! overhead" — every other subsystem in this repo measures the
//! overhead (cycles, traffic, BRAM/DSP) and none measures the claim's
//! other half. `xeval` supplies the quality axis, three ways:
//!
//! * [`fidelity`] — quantized-vs-exact agreement: each heatmap is
//!   computed twice, once through the fixed-point
//!   [`Simulator`](crate::sched::Simulator) path and once through a
//!   straight-line unquantized [`fidelity::Oracle`] (f32 storage, f64
//!   accumulation, no tiling, no Q-format), then scored by Pearson /
//!   Spearman correlation, top-k pixel intersection and SNR — per
//!   method and per `QFormat`.
//! * [`faithfulness`] — does the heatmap identify the pixels the
//!   network actually relies on? Deletion/insertion curves: rank
//!   pixels by attributed relevance, progressively mean-fill them,
//!   re-run the forward pass and integrate the target-logit decay
//!   (`util::stats::auc`).
//! * [`sanity`] — the parameter-randomization check: reshuffling the
//!   layer weights (seeded) must *decorrelate* the attributions. A
//!   dataflow that survives this check is provably reading gradients,
//!   not echoing the input.
//! * [`report`] — the `attrax eval` driver: runs all three over a
//!   seeded image set and emits the schema-tagged `BENCH_xeval.json`
//!   artifact (byte-identical across reruns).
//!
//! The same fidelity scalar feeds the autotuner: with
//! `TuneSpec::quality` (CLI `attrax tune --quality`) every scored
//! candidate carries `DesignPoint::infidelity_ppm` and the Pareto
//! frontier grows a fidelity objective, so a Q-format that produces
//! garbage heatmaps can no longer win on latency ties.
//!
//! See DESIGN.md §"xeval: quality metrics and the reference oracle"
//! and EXPERIMENTS.md E17.

pub mod faithfulness;
pub mod fidelity;
pub mod report;
pub mod sanity;

pub use faithfulness::Curves;
pub use fidelity::{FidelityScore, Oracle};
pub use report::{run_eval, EvalReport, EvalSpec, XEVAL_SCHEMA};
pub use sanity::{shuffle_params, SanityOutcome, SANITY_RHO_MAX};

/// Indices of the `k` largest values, ordered value-descending with
/// index-ascending tie-breaks — the one deterministic pixel ranking
/// every xeval metric shares (top-k intersection, deletion/insertion
/// masking order). Ranks by *signed* value: attribution methods put
/// evidence-for at the top, and the deletion curve must remove exactly
/// what the method claims matters most. Panics on NaN (heatmaps are
/// finite by construction).
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&i, &j| xs[j].partial_cmp(&xs[i]).expect("NaN in heatmap").then(i.cmp(&j)));
    idx.truncate(k.min(xs.len()));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_is_deterministic_value_desc_index_asc() {
        let xs = [1.0f32, 5.0, 5.0, -2.0, 3.0];
        assert_eq!(top_k_indices(&xs, 3), vec![1, 2, 4]);
        // k larger than the input clamps
        assert_eq!(top_k_indices(&xs, 99).len(), 5);
        assert_eq!(top_k_indices(&[], 4), Vec::<usize>::new());
        // positive scaling never reorders
        let scaled: Vec<f32> = xs.iter().map(|v| v * 17.5).collect();
        assert_eq!(top_k_indices(&xs, 5), top_k_indices(&scaled, 5));
    }
}
