//! Fixed-point fidelity: quantized heatmaps scored against an
//! unquantized reference oracle.
//!
//! The [`Oracle`] is the straight-line functional twin of the device
//! simulator: the same layer semantics (cross-correlation conv with
//! the engines' padding convention, `v > 0` ReLU masks, first-max 2×2
//! pool argmax, Fig.-4 ReLU-backward dataflows via
//! [`Method::relu_bwd_f32`]) with none of the device machinery — f32
//! storage, f64 accumulation, no tiling, no `QFormat`, no cost ledger.
//! Everything the two paths disagree on is therefore quantization, and
//! [`score_pair`] measures exactly that disagreement.

use crate::attribution::Method;
use crate::model::{Layer, Network, Params, Shape};
use crate::sched::argmax;
use crate::util::stats::{pearson, spearman};

use super::top_k_indices;

/// SNR values are clamped to ±this many dB so a bit-exact (or
/// completely degenerate) comparison still serializes as finite JSON.
pub const SNR_CAP_DB: f64 = 300.0;

/// Worst-case infidelity (see [`infidelity_ppm`]): Pearson −1 → 2e6.
pub const INFIDELITY_WORST_PPM: u64 = 2_000_000;

/// Per-heatmap agreement between a quantized attribution and its
/// unquantized reference.
#[derive(Clone, Copy, Debug)]
pub struct FidelityScore {
    /// Pearson correlation of the raw heatmap values.
    pub pearson: f64,
    /// Spearman rank correlation (what a human reading the heatmap
    /// perceives: the relevance *ordering*).
    pub spearman: f64,
    /// |top-k(quant) ∩ top-k(ref)| / k — do the two paths nominate the
    /// same most-relevant pixels?
    pub topk: f64,
    /// 10·log10(Σ ref² / Σ (ref − quant)²), clamped to ±[`SNR_CAP_DB`].
    pub snr_db: f64,
}

/// Score a quantized heatmap against its reference with top-`k`
/// intersection. Identical inputs score exactly
/// `(1.0, 1.0, 1.0, SNR_CAP_DB)` by definition — short-circuited
/// before the correlation arithmetic, so the identity comparison is
/// not exposed to `sqrt` round-off.
pub fn score_pair(quant: &[f32], reference: &[f32], k: usize) -> FidelityScore {
    assert_eq!(quant.len(), reference.len(), "heatmap length mismatch");
    assert!(k >= 1, "top-k needs k >= 1");
    if quant == reference {
        return FidelityScore { pearson: 1.0, spearman: 1.0, topk: 1.0, snr_db: SNR_CAP_DB };
    }
    let k = k.min(quant.len());
    let top_q = top_k_indices(quant, k);
    let mut in_ref = vec![false; reference.len()];
    for &i in &top_k_indices(reference, k) {
        in_ref[i] = true;
    }
    let hits = top_q.iter().filter(|&&i| in_ref[i]).count();
    let (mut sig, mut err) = (0f64, 0f64);
    for (&q, &r) in quant.iter().zip(reference.iter()) {
        sig += r as f64 * r as f64;
        err += (r as f64 - q as f64) * (r as f64 - q as f64);
    }
    let snr_db = if err == 0.0 {
        SNR_CAP_DB
    } else if sig == 0.0 {
        -SNR_CAP_DB
    } else {
        (10.0 * (sig / err).log10()).clamp(-SNR_CAP_DB, SNR_CAP_DB)
    };
    FidelityScore {
        pearson: pearson(quant, reference),
        spearman: spearman(quant, reference),
        topk: hits as f64 / k as f64,
        snr_db,
    }
}

/// The scalar the autotuner minimizes: `(1 − Pearson)` in
/// parts-per-million, clamped to `[0, 2e6]`, with degenerate (NaN)
/// correlations mapped to the worst score. Integer-valued so the
/// Pareto order stays total and the serialized frontier stays
/// byte-identical across reruns.
pub fn infidelity_ppm(quant: &[f32], reference: &[f32]) -> u64 {
    if quant == reference {
        return 0;
    }
    let rho = pearson(quant, reference);
    if !rho.is_finite() {
        return INFIDELITY_WORST_PPM;
    }
    ((1.0 - rho).clamp(0.0, 2.0) * 1e6).round() as u64
}

// ---------------------------------------------------------------------------
// The reference oracle
// ---------------------------------------------------------------------------

/// One resolved layer of the reference network (f32 parameters,
/// pre-validated shapes — no per-call `Result` plumbing).
enum RefLayer {
    Conv {
        w: Vec<f32>, // [O,I,K,K]
        b: Vec<f32>,
        in_shape: (usize, usize, usize),
        out_ch: usize,
        k: usize,
        pad: usize,
    },
    Relu,
    Pool {
        in_shape: (usize, usize, usize),
    },
    Flatten,
    Fc {
        w: Vec<f32>, // [OUT,IN]
        b: Vec<f32>,
        out_n: usize,
        in_n: usize,
    },
}

/// Result of one reference attribution.
#[derive(Clone, Debug)]
pub struct RefAttr {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub relevance: Vec<f32>,
}

/// The unquantized reference: straight-line forward + backward over
/// the same layer vocabulary the device plan executes.
pub struct Oracle {
    in_elems: usize,
    out_n: usize,
    layers: Vec<RefLayer>,
}

impl Oracle {
    /// Resolve a network + f32 parameter store into the reference
    /// form. Shape validation mirrors `Plan::new`.
    pub fn new(net: &Network, params: &Params) -> anyhow::Result<Oracle> {
        let mut layers = Vec::with_capacity(net.layers.len());
        for (i, layer) in net.layers.iter().enumerate() {
            match layer {
                Layer::Conv { name, in_ch, out_ch, k, pad } => {
                    let (wt, bt) = params.conv(name)?;
                    anyhow::ensure!(
                        wt.shape == vec![*out_ch, *in_ch, *k, *k],
                        "{name}: weight shape {:?} != layer dims",
                        wt.shape
                    );
                    let in_shape = match net.shapes[i] {
                        Shape::Chw(c, h, w) => (c, h, w),
                        s => anyhow::bail!("conv {name} on non-CHW input {s}"),
                    };
                    layers.push(RefLayer::Conv {
                        w: wt.data.clone(),
                        b: bt.data.clone(),
                        in_shape,
                        out_ch: *out_ch,
                        k: *k,
                        pad: *pad,
                    });
                }
                Layer::Relu => layers.push(RefLayer::Relu),
                Layer::MaxPool2 => {
                    let in_shape = match net.shapes[i] {
                        Shape::Chw(c, h, w) => (c, h, w),
                        s => anyhow::bail!("pool on non-CHW input {s}"),
                    };
                    layers.push(RefLayer::Pool { in_shape });
                }
                Layer::Flatten => layers.push(RefLayer::Flatten),
                Layer::Fc { name, in_dim, out_dim } => {
                    let (wt, bt) = params.fc(name)?;
                    anyhow::ensure!(
                        wt.shape == vec![*out_dim, *in_dim],
                        "{name}: weight shape {:?} != layer dims",
                        wt.shape
                    );
                    layers.push(RefLayer::Fc {
                        w: wt.data.clone(),
                        b: bt.data.clone(),
                        out_n: *out_dim,
                        in_n: *in_dim,
                    });
                }
            }
        }
        Ok(Oracle { in_elems: net.input.elems(), out_n: net.output_shape().elems(), layers })
    }

    /// One reference attribution: forward with mask/argmax capture,
    /// then the method's gradient backpropagation from `target` (the
    /// forward argmax when `None`).
    pub fn attribute(&self, image: &[f32], method: Method, target: Option<usize>) -> RefAttr {
        assert_eq!(image.len(), self.in_elems, "input size mismatch");
        let n = self.layers.len();
        let mut relu_masks: Vec<Option<Vec<bool>>> = (0..n).map(|_| None).collect();
        let mut pool_idx: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();

        // ---- forward -------------------------------------------------
        let mut act: Vec<f32> = image.to_vec();
        for (i, layer) in self.layers.iter().enumerate() {
            match layer {
                RefLayer::Conv { w, b, in_shape, out_ch, k, pad } => {
                    act = conv_forward(&act, *in_shape, w, b, *out_ch, *k, *pad);
                }
                RefLayer::Relu => {
                    // mask convention matches the engines: strictly
                    // positive pre-activation
                    let mask: Vec<bool> = act.iter().map(|&v| v > 0.0).collect();
                    for (v, &m) in act.iter_mut().zip(&mask) {
                        if !m {
                            *v = 0.0;
                        }
                    }
                    relu_masks[i] = Some(mask);
                }
                RefLayer::Pool { in_shape } => {
                    let (p, idx) = maxpool2(&act, *in_shape);
                    pool_idx[i] = Some(idx);
                    act = p;
                }
                RefLayer::Flatten => {}
                RefLayer::Fc { w, b, out_n, in_n } => {
                    act = fc_forward(w, *out_n, *in_n, &act, b);
                }
            }
        }
        let logits = act;
        let pred = argmax(&logits);

        // ---- backward ------------------------------------------------
        let start = target.unwrap_or(pred);
        assert!(start < self.out_n, "target class out of range");
        let mut g = vec![0f32; self.out_n];
        g[start] = 1.0;
        for (i, layer) in self.layers.iter().enumerate().rev() {
            match layer {
                RefLayer::Fc { w, out_n, in_n, .. } => {
                    g = fc_backward(w, *out_n, *in_n, &g);
                }
                RefLayer::Relu => {
                    let mask = relu_masks[i].as_ref().expect("relu mask missing");
                    for (v, &m) in g.iter_mut().zip(mask) {
                        *v = method.relu_bwd_f32(m, *v);
                    }
                }
                RefLayer::Pool { in_shape } => {
                    let (c, h, w) = *in_shape;
                    let idx = pool_idx[i].as_ref().expect("pool idx missing");
                    g = unpool2(&g, (c, h / 2, w / 2), idx);
                }
                RefLayer::Flatten => {}
                RefLayer::Conv { w, in_shape, out_ch, k, pad, .. } => {
                    g = conv_input_grad(&g, *in_shape, w, *out_ch, *k, *pad);
                }
            }
        }
        assert_eq!(g.len(), self.in_elems, "BP must walk back to the input");
        RefAttr { logits, pred, relevance: g }
    }
}

/// Cross-correlation conv, the engines' convention:
/// `out[o][oy][ox] = b[o] + Σ w[o][i][ky][kx] · x[i][oy+ky−pad][ox+kx−pad]`
/// with zero padding; output is `[O, H+2p−(k−1), W+2p−(k−1)]`.
fn conv_forward(
    x: &[f32],
    (ic, h, w): (usize, usize, usize),
    wt: &[f32],
    bias: &[f32],
    oc: usize,
    k: usize,
    pad: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), ic * h * w);
    let oh = h + 2 * pad - (k - 1);
    let ow = w + 2 * pad - (k - 1);
    let mut out = vec![0f32; oc * oh * ow];
    for o in 0..oc {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = bias[o] as f64;
                for i in 0..ic {
                    for ky in 0..k {
                        let y = (oy + ky) as isize - pad as isize;
                        if y < 0 || y >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let xx = (ox + kx) as isize - pad as isize;
                            if xx < 0 || xx >= w as isize {
                                continue;
                            }
                            acc += wt[((o * ic + i) * k + ky) * k + kx] as f64
                                * x[(i * h + y as usize) * w + xx as usize] as f64;
                        }
                    }
                }
                out[(o * oh + oy) * ow + ox] = acc as f32;
            }
        }
    }
    out
}

/// Adjoint of [`conv_forward`]: scatter each output gradient through
/// the taps that produced it.
fn conv_input_grad(
    g: &[f32],
    (ic, h, w): (usize, usize, usize),
    wt: &[f32],
    oc: usize,
    k: usize,
    pad: usize,
) -> Vec<f32> {
    let oh = h + 2 * pad - (k - 1);
    let ow = w + 2 * pad - (k - 1);
    assert_eq!(g.len(), oc * oh * ow);
    let mut acc = vec![0f64; ic * h * w];
    for o in 0..oc {
        for oy in 0..oh {
            for ox in 0..ow {
                let gv = g[(o * oh + oy) * ow + ox] as f64;
                if gv == 0.0 {
                    continue;
                }
                for i in 0..ic {
                    for ky in 0..k {
                        let y = (oy + ky) as isize - pad as isize;
                        if y < 0 || y >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let xx = (ox + kx) as isize - pad as isize;
                            if xx < 0 || xx >= w as isize {
                                continue;
                            }
                            acc[(i * h + y as usize) * w + xx as usize] +=
                                wt[((o * ic + i) * k + ky) * k + kx] as f64 * gv;
                        }
                    }
                }
            }
        }
    }
    acc.into_iter().map(|v| v as f32).collect()
}

/// 2×2/2 max pool with the engines' first-max argmax convention
/// (row-major window scan, strictly-greater replaces).
fn maxpool2(x: &[f32], (c, h, w): (usize, usize, usize)) -> (Vec<f32>, Vec<u8>) {
    assert_eq!(x.len(), c * h * w);
    assert!(h % 2 == 0 && w % 2 == 0);
    let (ph, pw) = (h / 2, w / 2);
    let mut out = vec![0f32; c * ph * pw];
    let mut idx = vec![0u8; c * ph * pw];
    for ch in 0..c {
        for py in 0..ph {
            for px in 0..pw {
                let mut best = f32::NEG_INFINITY;
                let mut bi = 0u8;
                for d in 0..4usize {
                    let v = x[ch * h * w + (2 * py + d / 2) * w + (2 * px + d % 2)];
                    if v > best {
                        best = v;
                        bi = d as u8;
                    }
                }
                out[ch * ph * pw + py * pw + px] = best;
                idx[ch * ph * pw + py * pw + px] = bi;
            }
        }
    }
    (out, idx)
}

/// Route each pooled gradient back to its argmax position.
fn unpool2(g: &[f32], (c, ph, pw): (usize, usize, usize), idx: &[u8]) -> Vec<f32> {
    assert_eq!(g.len(), c * ph * pw);
    assert_eq!(idx.len(), g.len());
    let (h, w) = (2 * ph, 2 * pw);
    let mut out = vec![0f32; c * h * w];
    for ch in 0..c {
        for py in 0..ph {
            for px in 0..pw {
                let pi = ch * ph * pw + py * pw + px;
                let (dy, dx) = ((idx[pi] >> 1) as usize, (idx[pi] & 1) as usize);
                out[ch * h * w + (2 * py + dy) * w + (2 * px + dx)] = g[pi];
            }
        }
    }
    out
}

fn fc_forward(w: &[f32], out_n: usize, in_n: usize, x: &[f32], bias: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), in_n);
    assert_eq!(w.len(), out_n * in_n);
    (0..out_n)
        .map(|o| {
            let mut acc = bias[o] as f64;
            for i in 0..in_n {
                acc += w[o * in_n + i] as f64 * x[i] as f64;
            }
            acc as f32
        })
        .collect()
}

fn fc_backward(w: &[f32], out_n: usize, in_n: usize, g: &[f32]) -> Vec<f32> {
    assert_eq!(g.len(), out_n);
    (0..in_n)
        .map(|i| {
            let mut acc = 0f64;
            for o in 0..out_n {
                acc += w[o * in_n + i] as f64 * g[o] as f64;
            }
            acc as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::ALL_METHODS;
    use crate::fx::QFormat;
    use crate::hls::HwConfig;
    use crate::sched::tests_support::tiny_net_params;
    use crate::sched::{AttrOptions, Simulator};
    use crate::util::rng::Pcg32;

    #[test]
    fn score_pair_identity_is_exact() {
        let mut rng = Pcg32::seeded(3);
        let h: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let s = score_pair(&h, &h, 6);
        assert_eq!(s.pearson, 1.0);
        assert_eq!(s.spearman, 1.0);
        assert_eq!(s.topk, 1.0);
        assert_eq!(s.snr_db, SNR_CAP_DB);
        assert_eq!(infidelity_ppm(&h, &h), 0);
    }

    #[test]
    fn score_pair_detects_disagreement() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let anti: Vec<f32> = a.iter().map(|v| -v).collect();
        let s = score_pair(&anti, &a, 2);
        assert!((s.pearson + 1.0).abs() < 1e-9);
        assert!((s.spearman + 1.0).abs() < 1e-9);
        assert_eq!(s.topk, 0.0, "top-2 of a and -a are disjoint");
        assert_eq!(infidelity_ppm(&anti, &a), INFIDELITY_WORST_PPM);
        // degenerate reference: constant vs varying is no correlation,
        // mapped to a defined (worst-of-range) infidelity, never NaN
        let flat = [0.0f32; 4];
        assert_eq!(infidelity_ppm(&flat, &a), 1_000_000);
        // half-window shift keeps half the top-2
        let shifted = [4.0f32, 3.0, 2.0, 1.0];
        let s = score_pair(&shifted, &a, 2);
        assert_eq!(s.topk, 0.0);
        let near = [1.0f32, 4.0, 2.0, 3.0];
        assert_eq!(score_pair(&near, &a, 2).topk, 0.5);
    }

    #[test]
    fn snr_scales_with_error() {
        let r = [1.0f32, -1.0, 1.0, -1.0];
        let q1: Vec<f32> = r.iter().map(|v| v + 0.1).collect();
        let q2: Vec<f32> = r.iter().map(|v| v + 0.01).collect();
        let s1 = score_pair(&q1, &r, 1).snr_db;
        let s2 = score_pair(&q2, &r, 1).snr_db;
        assert!((s1 - 20.0).abs() < 1e-6, "{s1}");
        assert!(s2 > s1 + 19.0, "10x smaller error ≈ +20 dB, got {s1} vs {s2}");
    }

    #[test]
    fn oracle_matches_quantized_path_at_high_precision() {
        // the one test that pins the oracle to the engines' conventions:
        // at Q24.16 (resolution ≈ 1.5e-5) the fixed-point path is a
        // fine-grained approximation of the oracle, so the two heatmaps
        // must correlate near-perfectly for every method
        let (net, params) = tiny_net_params(41);
        let oracle = Oracle::new(&net, &params).unwrap();
        let mut cfg = HwConfig::with_unroll(1, 1, 16);
        cfg.q = QFormat::new(24, 16);
        let sim = Simulator::new(net.clone(), &params, cfg).unwrap();
        let mut rng = Pcg32::seeded(42);
        let img: Vec<f32> = (0..net.input.elems()).map(|_| rng.f32()).collect();
        for method in ALL_METHODS {
            let r = oracle.attribute(&img, method, None);
            assert_eq!(r.logits.len(), 3);
            let q = sim.attribute(
                &img,
                method,
                AttrOptions { target: Some(r.pred), ..Default::default() },
            );
            let rho = pearson(&q.relevance, &r.relevance);
            assert!(rho > 0.99, "{method}: high-precision path diverged, rho={rho}");
            // logits agree closely too (same prediction)
            assert_eq!(q.pred, r.pred, "{method}");
            for (a, b) in q.logits.iter().zip(&r.logits) {
                assert!((a - b).abs() < 0.01, "{method}: logits {a} vs {b}");
            }
        }
    }

    #[test]
    fn oracle_is_deterministic_and_target_sensitive() {
        let (net, params) = tiny_net_params(43);
        let oracle = Oracle::new(&net, &params).unwrap();
        let mut rng = Pcg32::seeded(44);
        let img: Vec<f32> = (0..net.input.elems()).map(|_| rng.f32()).collect();
        let a = oracle.attribute(&img, Method::Guided, None);
        let b = oracle.attribute(&img, Method::Guided, None);
        assert_eq!(a.relevance, b.relevance);
        assert_eq!(a.logits, b.logits);
        let c0 = oracle.attribute(&img, Method::Saliency, Some(0));
        let c2 = oracle.attribute(&img, Method::Saliency, Some(2));
        assert_ne!(c0.relevance, c2.relevance);
        // methods disagree on relevance, agree on the forward pass
        let sal = oracle.attribute(&img, Method::Saliency, None);
        let dec = oracle.attribute(&img, Method::Deconvnet, None);
        assert_ne!(sal.relevance, dec.relevance);
        assert_eq!(sal.logits, dec.logits);
    }

    #[test]
    fn conv_adjoint_is_consistent() {
        // <conv(x), g> == <x, conv_input_grad(g)> — the defining
        // property of the adjoint, checked on random tensors
        let mut rng = Pcg32::seeded(7);
        let (ic, h, w, oc, k, pad) = (2, 6, 6, 3, 3, 1);
        let x: Vec<f32> = (0..ic * h * w).map(|_| rng.normal()).collect();
        let wt: Vec<f32> = (0..oc * ic * k * k).map(|_| rng.normal()).collect();
        let bias = vec![0f32; oc];
        let y = conv_forward(&x, (ic, h, w), &wt, &bias, oc, k, pad);
        let g: Vec<f32> = (0..y.len()).map(|_| rng.normal()).collect();
        let gx = conv_input_grad(&g, (ic, h, w), &wt, oc, k, pad);
        let lhs: f64 = y.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = x.iter().zip(&gx).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }
}
