//! Fixed-point fidelity: quantized heatmaps scored against an
//! unquantized reference oracle.
//!
//! The [`Oracle`] is the straight-line functional twin of the device
//! simulator: the same layer semantics (cross-correlation conv with
//! the engines' padding convention, `v > 0` ReLU masks, first-max 2×2
//! pool argmax, Fig.-4 ReLU-backward dataflows via
//! [`Method::relu_bwd_f32`]) with none of the device machinery — f32
//! storage, f64 accumulation, no tiling, no `QFormat`, no cost ledger.
//! Everything the two paths disagree on is therefore quantization, and
//! [`score_pair`] measures exactly that disagreement.

use crate::attribution::Method;
use crate::model::{Layer, Network, NodeId, Params, Shape, SrcRef};
use crate::sched::argmax;
use crate::util::stats::{pearson, spearman};

use super::top_k_indices;

/// SNR values are clamped to ±this many dB so a bit-exact (or
/// completely degenerate) comparison still serializes as finite JSON.
pub const SNR_CAP_DB: f64 = 300.0;

/// Worst-case infidelity (see [`infidelity_ppm`]): Pearson −1 → 2e6.
pub const INFIDELITY_WORST_PPM: u64 = 2_000_000;

/// Per-heatmap agreement between a quantized attribution and its
/// unquantized reference.
#[derive(Clone, Copy, Debug)]
pub struct FidelityScore {
    /// Pearson correlation of the raw heatmap values.
    pub pearson: f64,
    /// Spearman rank correlation (what a human reading the heatmap
    /// perceives: the relevance *ordering*).
    pub spearman: f64,
    /// |top-k(quant) ∩ top-k(ref)| / k — do the two paths nominate the
    /// same most-relevant pixels?
    pub topk: f64,
    /// 10·log10(Σ ref² / Σ (ref − quant)²), clamped to ±[`SNR_CAP_DB`].
    pub snr_db: f64,
}

/// Score a quantized heatmap against its reference with top-`k`
/// intersection. Identical inputs score exactly
/// `(1.0, 1.0, 1.0, SNR_CAP_DB)` by definition — short-circuited
/// before the correlation arithmetic, so the identity comparison is
/// not exposed to `sqrt` round-off.
pub fn score_pair(quant: &[f32], reference: &[f32], k: usize) -> FidelityScore {
    assert_eq!(quant.len(), reference.len(), "heatmap length mismatch");
    assert!(k >= 1, "top-k needs k >= 1");
    if quant == reference {
        return FidelityScore { pearson: 1.0, spearman: 1.0, topk: 1.0, snr_db: SNR_CAP_DB };
    }
    let k = k.min(quant.len());
    let top_q = top_k_indices(quant, k);
    let mut in_ref = vec![false; reference.len()];
    for &i in &top_k_indices(reference, k) {
        in_ref[i] = true;
    }
    let hits = top_q.iter().filter(|&&i| in_ref[i]).count();
    let (mut sig, mut err) = (0f64, 0f64);
    for (&q, &r) in quant.iter().zip(reference.iter()) {
        sig += r as f64 * r as f64;
        err += (r as f64 - q as f64) * (r as f64 - q as f64);
    }
    let snr_db = if err == 0.0 {
        SNR_CAP_DB
    } else if sig == 0.0 {
        -SNR_CAP_DB
    } else {
        (10.0 * (sig / err).log10()).clamp(-SNR_CAP_DB, SNR_CAP_DB)
    };
    FidelityScore {
        pearson: pearson(quant, reference),
        spearman: spearman(quant, reference),
        topk: hits as f64 / k as f64,
        snr_db,
    }
}

/// The scalar the autotuner minimizes: `(1 − Pearson)` in
/// parts-per-million, clamped to `[0, 2e6]`, with degenerate (NaN)
/// correlations mapped to the worst score. Integer-valued so the
/// Pareto order stays total and the serialized frontier stays
/// byte-identical across reruns.
pub fn infidelity_ppm(quant: &[f32], reference: &[f32]) -> u64 {
    if quant == reference {
        return 0;
    }
    let rho = pearson(quant, reference);
    if !rho.is_finite() {
        return INFIDELITY_WORST_PPM;
    }
    ((1.0 - rho).clamp(0.0, 2.0) * 1e6).round() as u64
}

// ---------------------------------------------------------------------------
// The reference oracle
// ---------------------------------------------------------------------------

/// One resolved layer of the reference network (f32 parameters,
/// pre-validated shapes — no per-call `Result` plumbing).
enum RefLayer {
    Conv {
        w: Vec<f32>, // [O,I,K,K]
        b: Vec<f32>,
        in_shape: (usize, usize, usize),
        out_ch: usize,
        k: usize,
        pad: usize,
    },
    Relu,
    Pool {
        in_shape: (usize, usize, usize),
    },
    Flatten,
    Fc {
        w: Vec<f32>, // [OUT,IN]
        b: Vec<f32>,
        out_n: usize,
        in_n: usize,
    },
    /// Elementwise skip-connection join; backward fans the gradient
    /// out to both operands (summing at forks, like the device path's
    /// `eltwise::accumulate` — but in f32).
    Add,
}

/// A step's resolved input: the image or an earlier step's output.
#[derive(Clone, Copy)]
enum RefSrc {
    Image,
    Step(usize),
}

/// One scheduled node of the reference network.
struct RefStep {
    layer: RefLayer,
    inputs: Vec<RefSrc>,
}

fn ref_src<'a>(s: RefSrc, outs: &'a [Vec<f32>], image: &'a [f32]) -> &'a [f32] {
    match s {
        RefSrc::Image => image,
        RefSrc::Step(j) => &outs[j],
    }
}

/// Deposit a step's input gradient at its source, summing when the
/// source fans out to several consumers.
fn ref_deposit(
    src: RefSrc,
    gi: Vec<f32>,
    grads: &mut [Option<Vec<f32>>],
    g_img: &mut Option<Vec<f32>>,
) {
    let slot = match src {
        RefSrc::Image => g_img,
        RefSrc::Step(j) => &mut grads[j],
    };
    match slot {
        None => *slot = Some(gi),
        Some(t) => {
            for (t, g) in t.iter_mut().zip(&gi) {
                *t += g;
            }
        }
    }
}

/// Result of one reference attribution.
#[derive(Clone, Debug)]
pub struct RefAttr {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub relevance: Vec<f32>,
}

/// The unquantized reference: straight-line forward + backward over
/// the same node schedule the device plan executes (DAGs included —
/// a fork's gradients are summed at the deposit, an add node fans its
/// gradient out to both operands).
pub struct Oracle {
    in_elems: usize,
    out_n: usize,
    steps: Vec<RefStep>,
}

impl Oracle {
    /// Resolve a network + f32 parameter store into the reference
    /// form. Shape validation mirrors `Plan::new`; the walk order is
    /// the network's own topological schedule.
    pub fn new(net: &Network, params: &Params) -> anyhow::Result<Oracle> {
        let mut step_of = vec![usize::MAX; net.nodes().len()];
        let mut steps = Vec::with_capacity(net.schedule().len());
        for (si, &ni) in net.schedule().iter().enumerate() {
            let nd = net.node(ni);
            let inputs: Vec<RefSrc> = nd
                .inputs
                .iter()
                .map(|s| match s {
                    SrcRef::Image => RefSrc::Image,
                    SrcRef::Node(NodeId(j)) => RefSrc::Step(step_of[*j]),
                })
                .collect();
            let chw = |what: &str| -> anyhow::Result<(usize, usize, usize)> {
                match net.src_shape(nd.inputs[0]) {
                    Shape::Chw(c, h, w) => Ok((c, h, w)),
                    s => anyhow::bail!("{what} on non-CHW input {s}"),
                }
            };
            let layer = match &nd.layer {
                Layer::Conv { name, in_ch, out_ch, k, pad } => {
                    let (wt, bt) = params.conv(name)?;
                    anyhow::ensure!(
                        wt.shape == vec![*out_ch, *in_ch, *k, *k],
                        "{name}: weight shape {:?} != layer dims",
                        wt.shape
                    );
                    RefLayer::Conv {
                        w: wt.data.clone(),
                        b: bt.data.clone(),
                        in_shape: chw(&format!("conv {name}"))?,
                        out_ch: *out_ch,
                        k: *k,
                        pad: *pad,
                    }
                }
                Layer::Relu => RefLayer::Relu,
                Layer::MaxPool2 => RefLayer::Pool { in_shape: chw("pool")? },
                Layer::Flatten => RefLayer::Flatten,
                Layer::Fc { name, in_dim, out_dim } => {
                    let (wt, bt) = params.fc(name)?;
                    anyhow::ensure!(
                        wt.shape == vec![*out_dim, *in_dim],
                        "{name}: weight shape {:?} != layer dims",
                        wt.shape
                    );
                    RefLayer::Fc {
                        w: wt.data.clone(),
                        b: bt.data.clone(),
                        out_n: *out_dim,
                        in_n: *in_dim,
                    }
                }
                Layer::Add => RefLayer::Add,
            };
            steps.push(RefStep { layer, inputs });
            step_of[ni] = si;
        }
        Ok(Oracle { in_elems: net.input.elems(), out_n: net.output_shape().elems(), steps })
    }

    /// One reference attribution: forward with mask/argmax capture,
    /// then the method's gradient backpropagation from `target` (the
    /// forward argmax when `None`).
    pub fn attribute(&self, image: &[f32], method: Method, target: Option<usize>) -> RefAttr {
        assert_eq!(image.len(), self.in_elems, "input size mismatch");
        let n = self.steps.len();
        let mut relu_masks: Vec<Option<Vec<bool>>> = (0..n).map(|_| None).collect();
        let mut pool_idx: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();

        // ---- forward -------------------------------------------------
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(n);
        for (i, step) in self.steps.iter().enumerate() {
            let out = match &step.layer {
                RefLayer::Conv { w, b, in_shape, out_ch, k, pad } => {
                    let x = ref_src(step.inputs[0], &outs, image);
                    conv_forward(x, *in_shape, w, b, *out_ch, *k, *pad)
                }
                RefLayer::Relu => {
                    // mask convention matches the engines: strictly
                    // positive pre-activation
                    let x = ref_src(step.inputs[0], &outs, image);
                    let mask: Vec<bool> = x.iter().map(|&v| v > 0.0).collect();
                    let out: Vec<f32> =
                        x.iter().zip(&mask).map(|(&v, &m)| if m { v } else { 0.0 }).collect();
                    relu_masks[i] = Some(mask);
                    out
                }
                RefLayer::Pool { in_shape } => {
                    let x = ref_src(step.inputs[0], &outs, image);
                    let (p, idx) = maxpool2(x, *in_shape);
                    pool_idx[i] = Some(idx);
                    p
                }
                RefLayer::Flatten => ref_src(step.inputs[0], &outs, image).to_vec(),
                RefLayer::Fc { w, b, out_n, in_n } => {
                    let x = ref_src(step.inputs[0], &outs, image);
                    fc_forward(w, *out_n, *in_n, x, b)
                }
                RefLayer::Add => {
                    let a = ref_src(step.inputs[0], &outs, image);
                    let b = ref_src(step.inputs[1], &outs, image);
                    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
                }
            };
            outs.push(out);
        }
        let logits = outs.last().expect("empty network").clone();
        let pred = argmax(&logits);

        // ---- backward ------------------------------------------------
        let start = target.unwrap_or(pred);
        assert!(start < self.out_n, "target class out of range");
        let mut grads: Vec<Option<Vec<f32>>> = vec![None; n];
        let mut g_img: Option<Vec<f32>> = None;
        let mut seed = vec![0f32; self.out_n];
        seed[start] = 1.0;
        grads[n - 1] = Some(seed);
        for (i, step) in self.steps.iter().enumerate().rev() {
            let mut g = grads[i].take().expect("step gradient never deposited");
            match &step.layer {
                RefLayer::Fc { w, out_n, in_n, .. } => {
                    let gi = fc_backward(w, *out_n, *in_n, &g);
                    ref_deposit(step.inputs[0], gi, &mut grads, &mut g_img);
                }
                RefLayer::Relu => {
                    let mask = relu_masks[i].as_ref().expect("relu mask missing");
                    for (v, &m) in g.iter_mut().zip(mask) {
                        *v = method.relu_bwd_f32(m, *v);
                    }
                    ref_deposit(step.inputs[0], g, &mut grads, &mut g_img);
                }
                RefLayer::Pool { in_shape } => {
                    let (c, h, w) = *in_shape;
                    let idx = pool_idx[i].as_ref().expect("pool idx missing");
                    let gi = unpool2(&g, (c, h / 2, w / 2), idx);
                    ref_deposit(step.inputs[0], gi, &mut grads, &mut g_img);
                }
                RefLayer::Flatten => {
                    ref_deposit(step.inputs[0], g, &mut grads, &mut g_img);
                }
                RefLayer::Conv { w, in_shape, out_ch, k, pad, .. } => {
                    let gi = conv_input_grad(&g, *in_shape, w, *out_ch, *k, *pad);
                    ref_deposit(step.inputs[0], gi, &mut grads, &mut g_img);
                }
                RefLayer::Add => {
                    ref_deposit(step.inputs[0], g.clone(), &mut grads, &mut g_img);
                    ref_deposit(step.inputs[1], g, &mut grads, &mut g_img);
                }
            }
        }
        let g = g_img.expect("BP must walk back to the input");
        assert_eq!(g.len(), self.in_elems, "BP must walk back to the input");
        RefAttr { logits, pred, relevance: g }
    }
}

/// Cross-correlation conv, the engines' convention:
/// `out[o][oy][ox] = b[o] + Σ w[o][i][ky][kx] · x[i][oy+ky−pad][ox+kx−pad]`
/// with zero padding; output is `[O, H+2p−(k−1), W+2p−(k−1)]`.
fn conv_forward(
    x: &[f32],
    (ic, h, w): (usize, usize, usize),
    wt: &[f32],
    bias: &[f32],
    oc: usize,
    k: usize,
    pad: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), ic * h * w);
    let oh = h + 2 * pad - (k - 1);
    let ow = w + 2 * pad - (k - 1);
    let mut out = vec![0f32; oc * oh * ow];
    for o in 0..oc {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = bias[o] as f64;
                for i in 0..ic {
                    for ky in 0..k {
                        let y = (oy + ky) as isize - pad as isize;
                        if y < 0 || y >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let xx = (ox + kx) as isize - pad as isize;
                            if xx < 0 || xx >= w as isize {
                                continue;
                            }
                            acc += wt[((o * ic + i) * k + ky) * k + kx] as f64
                                * x[(i * h + y as usize) * w + xx as usize] as f64;
                        }
                    }
                }
                out[(o * oh + oy) * ow + ox] = acc as f32;
            }
        }
    }
    out
}

/// Adjoint of [`conv_forward`]: scatter each output gradient through
/// the taps that produced it.
fn conv_input_grad(
    g: &[f32],
    (ic, h, w): (usize, usize, usize),
    wt: &[f32],
    oc: usize,
    k: usize,
    pad: usize,
) -> Vec<f32> {
    let oh = h + 2 * pad - (k - 1);
    let ow = w + 2 * pad - (k - 1);
    assert_eq!(g.len(), oc * oh * ow);
    let mut acc = vec![0f64; ic * h * w];
    for o in 0..oc {
        for oy in 0..oh {
            for ox in 0..ow {
                let gv = g[(o * oh + oy) * ow + ox] as f64;
                if gv == 0.0 {
                    continue;
                }
                for i in 0..ic {
                    for ky in 0..k {
                        let y = (oy + ky) as isize - pad as isize;
                        if y < 0 || y >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let xx = (ox + kx) as isize - pad as isize;
                            if xx < 0 || xx >= w as isize {
                                continue;
                            }
                            acc[(i * h + y as usize) * w + xx as usize] +=
                                wt[((o * ic + i) * k + ky) * k + kx] as f64 * gv;
                        }
                    }
                }
            }
        }
    }
    acc.into_iter().map(|v| v as f32).collect()
}

/// 2×2/2 max pool with the engines' first-max argmax convention
/// (row-major window scan, strictly-greater replaces).
fn maxpool2(x: &[f32], (c, h, w): (usize, usize, usize)) -> (Vec<f32>, Vec<u8>) {
    assert_eq!(x.len(), c * h * w);
    assert!(h % 2 == 0 && w % 2 == 0);
    let (ph, pw) = (h / 2, w / 2);
    let mut out = vec![0f32; c * ph * pw];
    let mut idx = vec![0u8; c * ph * pw];
    for ch in 0..c {
        for py in 0..ph {
            for px in 0..pw {
                let mut best = f32::NEG_INFINITY;
                let mut bi = 0u8;
                for d in 0..4usize {
                    let v = x[ch * h * w + (2 * py + d / 2) * w + (2 * px + d % 2)];
                    if v > best {
                        best = v;
                        bi = d as u8;
                    }
                }
                out[ch * ph * pw + py * pw + px] = best;
                idx[ch * ph * pw + py * pw + px] = bi;
            }
        }
    }
    (out, idx)
}

/// Route each pooled gradient back to its argmax position.
fn unpool2(g: &[f32], (c, ph, pw): (usize, usize, usize), idx: &[u8]) -> Vec<f32> {
    assert_eq!(g.len(), c * ph * pw);
    assert_eq!(idx.len(), g.len());
    let (h, w) = (2 * ph, 2 * pw);
    let mut out = vec![0f32; c * h * w];
    for ch in 0..c {
        for py in 0..ph {
            for px in 0..pw {
                let pi = ch * ph * pw + py * pw + px;
                let (dy, dx) = ((idx[pi] >> 1) as usize, (idx[pi] & 1) as usize);
                out[ch * h * w + (2 * py + dy) * w + (2 * px + dx)] = g[pi];
            }
        }
    }
    out
}

fn fc_forward(w: &[f32], out_n: usize, in_n: usize, x: &[f32], bias: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), in_n);
    assert_eq!(w.len(), out_n * in_n);
    (0..out_n)
        .map(|o| {
            let mut acc = bias[o] as f64;
            for i in 0..in_n {
                acc += w[o * in_n + i] as f64 * x[i] as f64;
            }
            acc as f32
        })
        .collect()
}

fn fc_backward(w: &[f32], out_n: usize, in_n: usize, g: &[f32]) -> Vec<f32> {
    assert_eq!(g.len(), out_n);
    (0..in_n)
        .map(|i| {
            let mut acc = 0f64;
            for o in 0..out_n {
                acc += w[o * in_n + i] as f64 * g[o] as f64;
            }
            acc as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::ALL_METHODS;
    use crate::fx::QFormat;
    use crate::hls::HwConfig;
    use crate::sched::tests_support::tiny_net_params;
    use crate::sched::{AttrOptions, Simulator};
    use crate::util::rng::Pcg32;

    #[test]
    fn score_pair_identity_is_exact() {
        let mut rng = Pcg32::seeded(3);
        let h: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let s = score_pair(&h, &h, 6);
        assert_eq!(s.pearson, 1.0);
        assert_eq!(s.spearman, 1.0);
        assert_eq!(s.topk, 1.0);
        assert_eq!(s.snr_db, SNR_CAP_DB);
        assert_eq!(infidelity_ppm(&h, &h), 0);
    }

    #[test]
    fn score_pair_detects_disagreement() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let anti: Vec<f32> = a.iter().map(|v| -v).collect();
        let s = score_pair(&anti, &a, 2);
        assert!((s.pearson + 1.0).abs() < 1e-9);
        assert!((s.spearman + 1.0).abs() < 1e-9);
        assert_eq!(s.topk, 0.0, "top-2 of a and -a are disjoint");
        assert_eq!(infidelity_ppm(&anti, &a), INFIDELITY_WORST_PPM);
        // degenerate reference: constant vs varying is no correlation,
        // mapped to a defined (worst-of-range) infidelity, never NaN
        let flat = [0.0f32; 4];
        assert_eq!(infidelity_ppm(&flat, &a), 1_000_000);
        // half-window shift keeps half the top-2
        let shifted = [4.0f32, 3.0, 2.0, 1.0];
        let s = score_pair(&shifted, &a, 2);
        assert_eq!(s.topk, 0.0);
        let near = [1.0f32, 4.0, 2.0, 3.0];
        assert_eq!(score_pair(&near, &a, 2).topk, 0.5);
    }

    #[test]
    fn snr_scales_with_error() {
        let r = [1.0f32, -1.0, 1.0, -1.0];
        let q1: Vec<f32> = r.iter().map(|v| v + 0.1).collect();
        let q2: Vec<f32> = r.iter().map(|v| v + 0.01).collect();
        let s1 = score_pair(&q1, &r, 1).snr_db;
        let s2 = score_pair(&q2, &r, 1).snr_db;
        assert!((s1 - 20.0).abs() < 1e-6, "{s1}");
        assert!(s2 > s1 + 19.0, "10x smaller error ≈ +20 dB, got {s1} vs {s2}");
    }

    #[test]
    fn oracle_matches_quantized_path_at_high_precision() {
        // the one test that pins the oracle to the engines' conventions:
        // at Q24.16 (resolution ≈ 1.5e-5) the fixed-point path is a
        // fine-grained approximation of the oracle, so the two heatmaps
        // must correlate near-perfectly for every method
        let (net, params) = tiny_net_params(41);
        let oracle = Oracle::new(&net, &params).unwrap();
        let mut cfg = HwConfig::with_unroll(1, 1, 16);
        cfg.q = QFormat::new(24, 16);
        let sim = Simulator::new(net.clone(), &params, cfg).unwrap();
        let mut rng = Pcg32::seeded(42);
        let img: Vec<f32> = (0..net.input.elems()).map(|_| rng.f32()).collect();
        for method in ALL_METHODS {
            let r = oracle.attribute(&img, method, None);
            assert_eq!(r.logits.len(), 3);
            let q = sim.attribute(
                &img,
                method,
                AttrOptions { target: Some(r.pred), ..Default::default() },
            );
            let rho = pearson(&q.relevance, &r.relevance);
            assert!(rho > 0.99, "{method}: high-precision path diverged, rho={rho}");
            // logits agree closely too (same prediction)
            assert_eq!(q.pred, r.pred, "{method}");
            for (a, b) in q.logits.iter().zip(&r.logits) {
                assert!((a - b).abs() < 0.01, "{method}: logits {a} vs {b}");
            }
        }
    }

    #[test]
    fn oracle_walks_residual_graphs() {
        // the oracle follows the same schedule as the plan, so the
        // fork/join (gradient fan-out summation) must line up with the
        // device path's eltwise accumulate at high precision
        let net = Network::from_graph_str(include_str!(
            "../../../examples/graphs/residual16.graph.json"
        ))
        .unwrap();
        let params = Params::synthetic(&net, 45);
        let oracle = Oracle::new(&net, &params).unwrap();
        let mut cfg = HwConfig::with_unroll(1, 1, 16);
        cfg.q = QFormat::new(24, 16);
        let sim = Simulator::new(net.clone(), &params, cfg).unwrap();
        let mut rng = Pcg32::seeded(46);
        let img: Vec<f32> = (0..net.input.elems()).map(|_| rng.f32()).collect();
        for method in ALL_METHODS {
            let r = oracle.attribute(&img, method, None);
            let q = sim.attribute(
                &img,
                method,
                AttrOptions { target: Some(r.pred), ..Default::default() },
            );
            assert_eq!(q.pred, r.pred, "{method}");
            let rho = pearson(&q.relevance, &r.relevance);
            assert!(rho > 0.99, "{method}: residual path diverged, rho={rho}");
        }
    }

    #[test]
    fn oracle_is_deterministic_and_target_sensitive() {
        let (net, params) = tiny_net_params(43);
        let oracle = Oracle::new(&net, &params).unwrap();
        let mut rng = Pcg32::seeded(44);
        let img: Vec<f32> = (0..net.input.elems()).map(|_| rng.f32()).collect();
        let a = oracle.attribute(&img, Method::Guided, None);
        let b = oracle.attribute(&img, Method::Guided, None);
        assert_eq!(a.relevance, b.relevance);
        assert_eq!(a.logits, b.logits);
        let c0 = oracle.attribute(&img, Method::Saliency, Some(0));
        let c2 = oracle.attribute(&img, Method::Saliency, Some(2));
        assert_ne!(c0.relevance, c2.relevance);
        // methods disagree on relevance, agree on the forward pass
        let sal = oracle.attribute(&img, Method::Saliency, None);
        let dec = oracle.attribute(&img, Method::Deconvnet, None);
        assert_ne!(sal.relevance, dec.relevance);
        assert_eq!(sal.logits, dec.logits);
    }

    #[test]
    fn conv_adjoint_is_consistent() {
        // <conv(x), g> == <x, conv_input_grad(g)> — the defining
        // property of the adjoint, checked on random tensors
        let mut rng = Pcg32::seeded(7);
        let (ic, h, w, oc, k, pad) = (2, 6, 6, 3, 3, 1);
        let x: Vec<f32> = (0..ic * h * w).map(|_| rng.normal()).collect();
        let wt: Vec<f32> = (0..oc * ic * k * k).map(|_| rng.normal()).collect();
        let bias = vec![0f32; oc];
        let y = conv_forward(&x, (ic, h, w), &wt, &bias, oc, k, pad);
        let g: Vec<f32> = (0..y.len()).map(|_| rng.normal()).collect();
        let gx = conv_input_grad(&g, (ic, h, w), &wt, oc, k, pad);
        let lhs: f64 = y.iter().zip(&g).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = x.iter().zip(&gx).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }
}
