//! Miniature property-testing harness (no proptest offline).
//!
//! `run_prop` draws N random cases from a generator, checks a property,
//! and on failure re-runs a bounded greedy shrink loop using a
//! user-supplied shrinker. Failures report the seed so a case can be
//! replayed deterministically.
//!
//! Used by the rust test suite for coordinator/scheduler/fx invariants
//! (see rust/tests/).

use super::rng::Pcg32;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrinks: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, seed: 0xa77a_c5ee_d, max_shrinks: 200 }
    }
}

/// Check `prop` over `cases` random inputs from `gen`.
/// On failure, greedily shrink with `shrink` (returns candidate smaller
/// inputs) and panic with the minimal failing case found.
pub fn run_prop_shrink<T, G, P, S>(cfg: PropConfig, mut gen: G, mut prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg32) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Pcg32::seeded(case_seed);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // greedy shrink
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut budget = cfg.max_shrinks;
            'outer: loop {
                for cand in shrink(&best) {
                    if budget == 0 {
                        break 'outer;
                    }
                    budget -= 1;
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x})\n  minimal input: {best:?}\n  reason: {best_msg}"
            );
        }
    }
}

/// run_prop without shrinking.
pub fn run_prop<T, G, P>(cfg: PropConfig, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg32) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    run_prop_shrink(cfg, gen, prop, |_| Vec::new());
}

/// Standard shrinker for a vec: halve it, drop single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 12 {
        for i in 0..v.len() {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

/// Standard shrinker for a usize: move toward zero.
pub fn shrink_usize(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if n > 0 {
        out.push(n / 2);
        out.push(n - 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run_prop(
            PropConfig { cases: 64, ..Default::default() },
            |r| r.below(100),
            |&x| if x < 100 { Ok(()) } else { Err("out of range".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        run_prop(
            PropConfig { cases: 64, ..Default::default() },
            |r| r.below(100),
            |&x| if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) },
        );
    }

    #[test]
    fn shrinker_minimizes() {
        // property: no vec contains a value >= 10; shrinker should find a
        // small counterexample (len 1 after shrinking).
        let result = std::panic::catch_unwind(|| {
            run_prop_shrink(
                PropConfig { cases: 16, ..Default::default() },
                |r| (0..8).map(|_| r.below(20)).collect::<Vec<u32>>(),
                |v| {
                    if v.iter().all(|&x| x < 10) {
                        Ok(())
                    } else {
                        Err("contains >= 10".into())
                    }
                },
                |v| shrink_vec(v),
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("expected failure"),
        };
        // minimal failing vec should have shrunk below the original 8 elems
        let list_part = msg.split("minimal input: ").nth(1).unwrap();
        let commas = list_part.split('\n').next().unwrap().matches(',').count();
        assert!(commas < 7, "shrinker did not reduce: {msg}");
    }
}
