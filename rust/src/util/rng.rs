//! Deterministic PCG32 PRNG.
//!
//! The offline sandbox has no `rand` crate; this is the reference
//! PCG-XSH-RR 64/32 generator (O'Neill 2014). Used by the shapes-32
//! generator, the property-test harness and workload generators —
//! everything that needs reproducible randomness.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with an arbitrary (seed, stream) pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-seed constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, bound) without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-9 {
                let u2 = self.f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * core::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vector() {
        // PCG reference implementation, seed=42, stream=54:
        let mut r = Pcg32::new(42, 54);
        let got: Vec<u32> = (0..6).map(|_| r.next_u32()).collect();
        assert_eq!(got, vec![0xa15c02b7, 0x7b47f409, 0xba1d3330, 0x83d2f293, 0xbfa4784b, 0xcbed606e]);
    }

    #[test]
    fn determinism_and_streams() {
        let a: Vec<u32> = (0..16).scan(Pcg32::seeded(7), |r, _| Some(r.next_u32())).collect();
        let b: Vec<u32> = (0..16).scan(Pcg32::seeded(7), |r, _| Some(r.next_u32())).collect();
        let c: Vec<u32> = (0..16).scan(Pcg32::seeded(8), |r, _| Some(r.next_u32())).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Pcg32::seeded(2);
        let mut lo = 1.0f32;
        let mut hi = 0.0f32;
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
            lo = lo.min(v);
            hi = hi.max(v);
        }
        assert!(lo < 0.01 && hi > 0.99, "spread: lo={lo} hi={hi}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(3);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(4);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }
}
