//! From-scratch utility substrates (S10 in DESIGN.md).
//!
//! The offline sandbox ships only the `xla` crate's dependency tree —
//! no tokio / clap / serde / rand / criterion / proptest — so every
//! support capability the coordinator needs is implemented here.

pub mod bench;
pub mod cli;
pub mod crc;
pub mod json;
pub mod log;
pub mod ppm;
pub mod prop;
pub mod rng;
pub mod stats;
