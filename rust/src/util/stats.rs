//! Online statistics + percentile summaries for metrics and benches.

/// Streaming mean/variance (Welford) with min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Buffered sampler for exact percentiles (serving-latency scale: fine).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Samples { xs: Vec::new() }
    }
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Exact percentile by linear interpolation; q in [0,1].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.percentile(0.50),
            self.percentile(0.95),
            self.percentile(0.99),
            self.percentile(1.0),
        )
    }
}

/// Pearson correlation between two equal-length slices.
///
/// Degenerate-input contract (the shadow verifier gates on this value,
/// so the edges are defined explicitly rather than left to float
/// accident):
///
/// * any NaN in either input → `NaN` (propagated, never masked as
///   agreement);
/// * fewer than two samples → `1.0` (nothing to disagree about);
/// * both inputs constant → `1.0` iff they are elementwise identical,
///   else `0.0` (two *different* flat heatmaps are not "perfectly
///   correlated" — the seed returned 1.0 for any pair of constants
///   because both variances were 0.0 and `va == vb` held vacuously);
/// * exactly one input constant → `0.0` (mathematically undefined;
///   reported as no correlation).
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.iter().chain(b.iter()).any(|v| v.is_nan()) {
        return f64::NAN;
    }
    let n = a.len() as f64;
    if n < 2.0 {
        return 1.0;
    }
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for i in 0..a.len() {
        let da = a[i] as f64 - ma;
        let db = b[i] as f64 - mb;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va == 0.0 && vb == 0.0 {
        return if a == b { 1.0 } else { 0.0 };
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Spearman rank correlation — the attribution-fidelity metric used by
/// the precision sweep and `xeval` (heatmaps are rank-ordered
/// relevance). Ties receive the average of the ranks they span.
///
/// Degenerate-input contract, mirroring [`pearson`]:
///
/// * any NaN in either input → `NaN` (the seed's rank sort would have
///   panicked on NaN instead of propagating it);
/// * either input constant → the *value-level* [`pearson`] rules apply
///   (both constant → `1.0` iff elementwise identical else `0.0`; one
///   constant → `0.0`). A constant input has a degenerate rank vector
///   — every element ties at the same average rank — so ranking it
///   would report vacuous perfect agreement between two heatmaps that
///   share no ordering information at all.
pub fn spearman(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.iter().chain(b.iter()).any(|v| v.is_nan()) {
        return f64::NAN;
    }
    let const_a = a.windows(2).all(|w| w[0] == w[1]);
    let const_b = b.windows(2).all(|w| w[0] == w[1]);
    if const_a || const_b {
        return pearson(a, b);
    }
    pearson(&ranks(a), &ranks(b))
}

/// Trapezoidal area under the curve `ys` sampled at `xs` — the
/// deletion/insertion faithfulness scalar (`xeval::faithfulness`).
///
/// Degenerate contract (documented and tested, like [`pearson`]):
///
/// * fewer than two points → `NaN` (a curve with no extent has no
///   area; returning 0.0 would read as a perfect deletion score);
/// * `xs` must be non-decreasing — the function **panics** on a
///   descending step (a shuffled domain is a caller bug; silently
///   sorting would pair ys with the wrong xs);
/// * NaN anywhere in either slice propagates to the result.
pub fn auc(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "auc: domain/range length mismatch");
    if xs.len() < 2 {
        return f64::NAN;
    }
    let mut area = 0.0;
    for i in 1..xs.len() {
        let dx = xs[i] - xs[i - 1];
        // a NaN dx is let through the assert and propagates via the sum
        assert!(
            dx >= 0.0 || dx.is_nan(),
            "auc: xs must be non-decreasing (xs[{}]={} after xs[{}]={})",
            i,
            xs[i],
            i - 1,
            xs[i - 1]
        );
        area += dx * 0.5 * (ys[i] + ys[i - 1]);
    }
    area
}

fn ranks(xs: &[f32]) -> Vec<f32> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
    let mut out = vec![0f32; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f32 / 2.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let mut r = Running::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 3.0).abs() < 1e-12);
        assert!((r.var() - 2.5).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 5.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(1.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(0.5) - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.95) - 95.05).abs() < 0.1);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        let c = [8.0f32, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_monotone_invariant() {
        // spearman only sees ranks: x vs x^3 is exactly 1
        let a: Vec<f32> = (-10..10).map(|i| i as f32).collect();
        let b: Vec<f32> = a.iter().map(|x| x * x * x).collect();
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_ties() {
        let a = [1.0f32, 1.0, 2.0, 3.0];
        let b = [1.0f32, 1.0, 2.0, 3.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_tied_ranks_are_averaged() {
        // a ranks: [0.5, 0.5, 2]; b ranks: [0, 1.5, 1.5]
        // pearson of those rank vectors is exactly 0.5 — only true when
        // ties get the average rank (min- or max-ranking gives 0.655/0.18)
        let a = [1.0f32, 1.0, 2.0];
        let b = [1.0f32, 2.0, 2.0];
        assert!((spearman(&a, &b) - 0.5).abs() < 1e-9, "{}", spearman(&a, &b));
        // tie-heavy but identically-ordered inputs agree
        let c = [5.0f32, 5.0, 5.0, 7.0, 7.0, 9.0];
        let d = [1.0f32, 1.0, 1.0, 2.0, 2.0, 3.0];
        assert!((spearman(&c, &d) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_constant_inputs_mirror_pearson_contract() {
        let k = [2.0f32, 2.0, 2.0];
        let v = [1.0f32, 2.0, 3.0];
        // constant vs varying: no ordering information, not agreement
        assert_eq!(spearman(&k, &v), 0.0);
        assert_eq!(spearman(&v, &k), 0.0);
        // identical constants agree; different constants do not
        assert_eq!(spearman(&k, &k), 1.0);
        let k2 = [3.0f32, 3.0, 3.0];
        assert_eq!(spearman(&k, &k2), 0.0);
        // zero-filled heatmaps on both sides agree
        let z = [0.0f32, 0.0, 0.0];
        assert_eq!(spearman(&z, &z), 1.0);
    }

    #[test]
    fn spearman_nan_propagates() {
        let a = [1.0f32, f32::NAN, 3.0];
        let b = [1.0f32, 2.0, 3.0];
        // the seed's rank sort panicked on NaN; now it propagates like
        // pearson's contract demands
        assert!(spearman(&a, &b).is_nan());
        assert!(spearman(&b, &a).is_nan());
        assert!(spearman(&a, &a).is_nan());
        assert!(spearman(&[f32::NAN], &[1.0]).is_nan());
    }

    #[test]
    fn auc_trapezoid_closed_forms() {
        // flat line: area = height * width
        assert!((auc(&[0.0, 1.0], &[3.0, 3.0]) - 3.0).abs() < 1e-12);
        // triangle under y = x on [0, 1]
        let xs: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
        let ys = xs.clone();
        assert!((auc(&xs, &ys) - 0.5).abs() < 1e-12);
        // uneven spacing is weighted by dx
        assert!((auc(&[0.0, 0.5, 2.0], &[1.0, 1.0, 1.0]) - 2.0).abs() < 1e-12);
        // repeated x (zero-width step) contributes nothing
        assert!((auc(&[0.0, 1.0, 1.0], &[1.0, 1.0, 5.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_contract() {
        assert!(auc(&[], &[]).is_nan());
        assert!(auc(&[0.5], &[2.0]).is_nan());
        assert!(auc(&[0.0, 1.0], &[f64::NAN, 1.0]).is_nan());
        assert!(auc(&[0.0, f64::NAN], &[1.0, 1.0]).is_nan());
    }

    #[test]
    #[should_panic(expected = "non-decreasing")]
    fn auc_panics_on_unsorted_domain() {
        auc(&[0.0, 2.0, 1.0], &[1.0, 1.0, 1.0]);
    }

    #[test]
    fn constant_input_degenerate() {
        let a = [1.0f32, 1.0, 1.0];
        let b = [1.0f32, 2.0, 3.0];
        // identical constants: perfect agreement
        assert_eq!(pearson(&a, &a), 1.0);
        // constant vs varying: undefined, reported as no correlation
        assert_eq!(pearson(&a, &b), 0.0);
        assert_eq!(pearson(&b, &a), 0.0);
        // two DIFFERENT constants must not read as perfect agreement
        let c = [2.0f32, 2.0, 2.0];
        assert_eq!(pearson(&a, &c), 0.0);
        // zero-filled heatmaps on both sides agree
        let z = [0.0f32, 0.0, 0.0];
        assert_eq!(pearson(&z, &z), 1.0);
    }

    #[test]
    fn short_inputs_trivially_correlated() {
        assert_eq!(pearson(&[], &[]), 1.0);
        assert_eq!(pearson(&[3.0], &[7.0]), 1.0);
    }

    #[test]
    fn nan_propagates() {
        let a = [1.0f32, f32::NAN, 3.0];
        let b = [1.0f32, 2.0, 3.0];
        assert!(pearson(&a, &b).is_nan());
        assert!(pearson(&b, &a).is_nan());
        assert!(pearson(&a, &a).is_nan());
        // NaN beats the short-input and constant rules
        assert!(pearson(&[f32::NAN], &[1.0]).is_nan());
    }
}
