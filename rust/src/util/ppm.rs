//! PPM/PGM image writers + a diverging colormap for heatmaps (Fig. 3).
//!
//! Binary P6 (RGB) / P5 (gray). No image crates offline; these formats
//! are 15 lines each and viewable everywhere.

use std::io::Write;
use std::path::Path;

/// Write an RGB image; `rgb` is row-major [h*w*3] in [0,1].
pub fn write_ppm(path: &Path, rgb: &[f32], w: usize, h: usize) -> std::io::Result<()> {
    assert_eq!(rgb.len(), w * h * 3);
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = rgb.iter().map(|&v| (v.clamp(0.0, 1.0) * 255.0 + 0.5) as u8).collect();
    f.write_all(&bytes)
}

/// Write a grayscale image; `g` is row-major [h*w] in [0,1].
pub fn write_pgm(path: &Path, g: &[f32], w: usize, h: usize) -> std::io::Result<()> {
    assert_eq!(g.len(), w * h);
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = g.iter().map(|&v| (v.clamp(0.0, 1.0) * 255.0 + 0.5) as u8).collect();
    f.write_all(&bytes)
}

/// Map a signed relevance value in [-1,1] to a blue-white-red diverging
/// color (negative = blue, positive = red) — the convention attribution
/// papers use for signed heatmaps.
pub fn diverging(v: f32) -> [f32; 3] {
    let v = v.clamp(-1.0, 1.0);
    if v >= 0.0 {
        [1.0, 1.0 - v, 1.0 - v]
    } else {
        [1.0 + v, 1.0 + v, 1.0]
    }
}

/// Normalize a relevance map to [-1,1] by its max |value| and render it.
pub fn relevance_to_rgb(rel: &[f32]) -> Vec<f32> {
    let maxabs = rel.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-12);
    let mut out = Vec::with_capacity(rel.len() * 3);
    for &v in rel {
        out.extend_from_slice(&diverging(v / maxabs));
    }
    out
}

/// Channel-major [3,H,W] image tensor -> row-major RGB for write_ppm.
pub fn chw_to_rgb(chw: &[f32], h: usize, w: usize) -> Vec<f32> {
    assert_eq!(chw.len(), 3 * h * w);
    let mut out = vec![0f32; h * w * 3];
    for c in 0..3 {
        for y in 0..h {
            for x in 0..w {
                out[(y * w + x) * 3 + c] = chw[c * h * w + y * w + x];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diverging_endpoints() {
        assert_eq!(diverging(0.0), [1.0, 1.0, 1.0]);
        assert_eq!(diverging(1.0), [1.0, 0.0, 0.0]);
        assert_eq!(diverging(-1.0), [0.0, 0.0, 1.0]);
    }

    #[test]
    fn ppm_header_and_size() {
        let dir = std::env::temp_dir().join("attrax_ppm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ppm");
        write_ppm(&p, &vec![0.5; 4 * 2 * 3], 4, 2).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n4 2\n255\n"));
        assert_eq!(bytes.len(), "P6\n4 2\n255\n".len() + 24);
    }

    #[test]
    fn chw_transpose() {
        // 1x2 image: pixel0 = (r0,g0,b0) = (1,3,5), pixel1 = (2,4,6)
        let chw = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let rgb = chw_to_rgb(&chw, 1, 2);
        assert_eq!(rgb, vec![1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn relevance_normalization() {
        let rgb = relevance_to_rgb(&[2.0, -2.0, 0.0]);
        assert_eq!(&rgb[0..3], &[1.0, 0.0, 0.0]); // +max -> red
        assert_eq!(&rgb[3..6], &[0.0, 0.0, 1.0]); // -max -> blue
        assert_eq!(&rgb[6..9], &[1.0, 1.0, 1.0]); // zero -> white
    }
}
