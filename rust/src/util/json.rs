//! Minimal JSON parser/serializer (no serde in the offline sandbox).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bool, null). Used to read `artifacts/manifest.json` /
//! `golden.json` and to emit metrics reports. Numbers are stored as f64
//! plus the raw text so exact integers round-trip.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) ---------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a", "b")` == obj["a"]["b"], None anywhere it breaks.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad \\u escape"))?);
                            self.pos -= 1; // compensate the +1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let txt = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

/// Append `s` as a quoted, backslash-escaped string literal. Shared
/// with the stats exposition endpoint (`obs::export`), whose label
/// values follow the same quoting grammar as JSON strings.
pub fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    /// Serialize into `out` (compact, no whitespace). `to_string()`
    /// (via `Display`) is the allocating convenience. Integers with
    /// |n| < 9e15 print without a fraction so they re-parse exactly;
    /// other finite numbers use Rust's shortest round-trip `f64`
    /// formatting, so `parse(write(v)) == v` for every finite value
    /// (property-tested below). Non-finite numbers are not
    /// representable in JSON and serialize as `null` — emitting the
    /// bare tokens `NaN`/`inf` would make the whole document
    /// unparseable, which is strictly worse than one absent value.
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for emitting metrics/reports.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\n\t\"\\ bAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ bAé");
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":{"d":true}}"#).unwrap();
        assert_eq!(v.path(&["c", "d"]).unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "{\"a\" 1}", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"nested":{"b":false},"s":"q\"uote"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn nonfinite_numbers_serialize_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = obj(vec![("x", num(bad)), ("y", num(1.5))]).to_string();
            assert_eq!(doc, r#"{"x":null,"y":1.5}"#);
            // the emitted document must stay parseable
            assert!(Json::parse(&doc).is_ok());
        }
    }

    #[test]
    fn integers_roundtrip_exact() {
        let v = Json::parse("[0, 591274, 2365096, -7]").unwrap();
        assert_eq!(v.to_string(), "[0,591274,2365096,-7]");
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" :  [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn write_public_api() {
        let mut out = String::new();
        Json::Arr(vec![Json::Num(1.0), Json::Str("x".into())]).write(&mut out);
        assert_eq!(out, r#"[1,"x"]"#);
    }

    #[test]
    fn float_edge_cases_roundtrip() {
        let cases = [
            -0.0,
            0.1,
            0.1 + 0.2,
            1e-308,
            5e-324, // smallest subnormal
            1.5e300,
            -2.5,
            f32::MAX as f64,
            f32::MIN_POSITIVE as f64,
            9_007_199_254_740_992.0, // 2^53
            -9_007_199_254_740_992.0,
            123456789.12345679,
        ];
        for x in cases {
            let v = Json::Num(x);
            let text = v.to_string();
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, v, "float {x:e} failed to round-trip via {text:?}");
        }
    }

    #[test]
    fn escape_edge_cases_roundtrip() {
        // every C0 control char, plus the escapes and some unicode
        let mut hard = String::new();
        for b in 0u32..0x20 {
            hard.push(char::from_u32(b).unwrap());
        }
        hard.push_str("\"\\/ é😀\u{7f}\u{2028}");
        let v = Json::Str(hard);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    // -- property tests: parse ∘ write == id --------------------------------

    fn gen_string(rng: &mut crate::util::rng::Pcg32) -> String {
        const POOL: &[char] = &[
            'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{8}', '\u{c}', '\u{1}',
            '\u{1f}', 'é', 'ß', '中', '😀', '\u{7f}',
        ];
        let len = rng.below(8) as usize;
        (0..len).map(|_| POOL[rng.below(POOL.len() as u32) as usize]).collect()
    }

    fn gen_num(rng: &mut crate::util::rng::Pcg32) -> f64 {
        match rng.below(5) {
            0 => rng.below(2001) as f64 - 1000.0,
            1 => {
                let mag = (rng.next_u64() % (1u64 << 53)) as f64;
                if rng.below(2) == 0 { mag } else { -mag }
            }
            2 => rng.f32() as f64,
            // wide magnitude sweep, always finite
            3 => (rng.f32() as f64 - 0.5) * 10f64.powi(rng.below(601) as i32 - 300),
            _ => 0.0,
        }
    }

    fn gen_json(rng: &mut crate::util::rng::Pcg32, depth: usize) -> Json {
        let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num(gen_num(rng)),
            3 => Json::Str(gen_string(rng)),
            4 => {
                let n = rng.below(4) as usize;
                Json::Arr((0..n).map(|_| gen_json(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.below(4) as usize;
                Json::Obj((0..n).map(|_| (gen_string(rng), gen_json(rng, depth - 1))).collect())
            }
        }
    }

    #[test]
    fn prop_parse_write_roundtrip() {
        use crate::util::prop::{run_prop, PropConfig};
        run_prop(
            PropConfig { cases: 512, ..Default::default() },
            |rng| gen_json(rng, 3),
            |v| {
                let text = v.to_string();
                let back = Json::parse(&text)
                    .map_err(|e| format!("writer emitted unparsable {text:?}: {e}"))?;
                if &back == v {
                    Ok(())
                } else {
                    Err(format!("{back:?} != {v:?} via {text:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_write_is_stable() {
        // write ∘ parse ∘ write == write (serialization is canonical)
        use crate::util::prop::{run_prop, PropConfig};
        run_prop(
            PropConfig { cases: 256, ..Default::default() },
            |rng| gen_json(rng, 3),
            |v| {
                let once = v.to_string();
                let twice = Json::parse(&once).map_err(|e| e.to_string())?.to_string();
                if once == twice {
                    Ok(())
                } else {
                    Err(format!("unstable: {once:?} vs {twice:?}"))
                }
            },
        );
    }
}
