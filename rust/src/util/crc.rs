//! IEEE CRC-32 (reflected polynomial 0xEDB88320 — the zlib/Ethernet
//! one), table-driven and std-only. Shared by the wire protocol (the
//! optional per-frame payload `"crc"` field) and the plan integrity
//! manifest (weight-slab checksums that catch SEU bit flips before a
//! corrupted model ships a plausible-looking heatmap).

/// 256-entry lookup table, built at compile time.
const TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Streaming CRC-32 state, for checksumming without materializing a
/// contiguous byte buffer.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: !0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = TABLE[((self.state ^ b as u32) & 0xff) as usize] ^ (self.state >> 8);
        }
    }

    pub fn finish(self) -> u32 {
        !self.state
    }
}

impl Default for Crc32 {
    fn default() -> Crc32 {
        Crc32::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// CRC-32 of an `i32` slab, each word as its little-endian bytes —
/// the representation the plan's quantized weight slabs checksum
/// under, allocation-free.
pub fn crc32_i32s(words: &[i32]) -> u32 {
    let mut c = Crc32::new();
    for &w in words {
        c.update(&w.to_le_bytes());
    }
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_check_value() {
        // The standard CRC-32/IEEE check vector.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn i32_slab_matches_le_bytes() {
        let words = [0i32, -1, 42, i32::MIN, i32::MAX];
        let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
        assert_eq!(crc32_i32s(&words), crc32(&bytes));
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let mut words = vec![7i32; 64];
        let before = crc32_i32s(&words);
        words[13] ^= 1 << 5;
        assert_ne!(crc32_i32s(&words), before);
    }
}
