//! Leveled, target-tagged stderr logger with an env switch
//! (`ATTRAX_LOG=debug|info|warn|error|off`).
//!
//! Library code logs through this — never raw `eprintln!` — so the
//! serving stack is silent by default: the level starts at
//! [`Level::Off`] and `init_from_env` keeps it off unless the env var
//! asks for output. `emitted()` counts lines actually written, which
//! is what lets a test pin "level=off emits nothing" without capturing
//! stderr.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    /// Sentinel threshold above every real level: nothing emits.
    Off = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Off as u8);
static EMITTED: AtomicU64 = AtomicU64::new(0);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn init_from_env() {
    let lvl = match std::env::var("ATTRAX_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("info") => Level::Info,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Off,
    };
    set_level(lvl);
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
    START.get_or_init(Instant::now);
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Total lines actually written to stderr since process start.
pub fn emitted() -> u64 {
    EMITTED.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if l == Level::Off || !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
        Level::Off => unreachable!(),
    };
    EMITTED.fetch_add(1, Ordering::Relaxed);
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test (not several) so the global level is never mutated by
    // two parallel test threads at once.
    #[test]
    fn level_gating_and_off_emits_nothing() {
        // default: off — every level gated, nothing written
        set_level(Level::Off);
        let before = emitted();
        for l in [Level::Debug, Level::Info, Level::Warn, Level::Error] {
            assert!(!enabled(l), "{l:?} must be gated when level=off");
            log(l, "test", format_args!("must not emit"));
        }
        assert_eq!(emitted(), before, "level=off must emit nothing");

        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        let before = emitted();
        log(Level::Error, "test", format_args!("one line"));
        assert_eq!(emitted(), before + 1);

        set_level(Level::Off); // restore default for other tests
    }
}
