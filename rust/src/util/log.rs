//! Leveled stderr logger with an env switch (`ATTRAX_LOG=debug|info|warn`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1); // Info
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn init_from_env() {
    let lvl = match std::env::var("ATTRAX_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(lvl);
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
    START.get_or_init(Instant::now);
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info); // restore default for other tests
    }
}
