//! Bench harness (no criterion offline): wall-clock timing with warmup
//! + repetitions, and aligned table printing for the paper-table
//! reproduction benches (`cargo bench` runs each `harness = false`
//! bench binary; they print the same rows the paper reports).

use std::time::Instant;

use super::stats::Running;

/// Time `f` with `warmup` discarded runs and `reps` measured runs.
/// Returns (mean_ms, std_ms, min_ms).
pub fn time_ms<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut r = Running::new();
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        r.push(t.elapsed().as_secs_f64() * 1e3);
    }
    (r.mean(), r.std(), r.min())
}

/// Simple fixed-width table printer.
pub struct Table {
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        let widths = headers.iter().map(|h| h.len()).collect();
        Table { widths, rows: vec![headers.iter().map(|s| s.to_string()).collect()] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.widths.len(), "column count mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn rows_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn print(&self) {
        for (i, row) in self.rows.iter().enumerate() {
            let line: Vec<String> = row
                .iter()
                .zip(&self.widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("  {}", line.join("  "));
            if i == 0 {
                let total: usize = self.widths.iter().sum::<usize>() + 2 * (self.widths.len() - 1);
                println!("  {}", "-".repeat(total));
            }
        }
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===\n");
}

/// Format a count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_positive() {
        let (mean, _std, min) = time_ms(1, 3, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(mean > 0.0 && min > 0.0 && min <= mean * 1.5);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["a", "board"]);
        t.rows_str(&["1", "Pynq-Z2"]);
        t.row(&vec!["100".to_string(), "x".to_string()]);
        assert_eq!(t.rows.len(), 3);
        t.print(); // should not panic
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(25_003_264), "25,003,264");
    }
}
