//! Tiny declarative CLI argument parser (no clap in the offline sandbox).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands. Generates usage text from the declared options.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn parse_num<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{name} expects a number, got {v:?}");
                std::process::exit(2);
            }),
            None => default,
        }
    }
}

pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = o
                .default
                .filter(|d| !d.is_empty())
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<26}{}{def}\n", o.help));
        }
        s
    }

    /// Parse a token stream. Returns Err(message) on unknown/invalid input.
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                if !d.is_empty() {
                    args.values.insert(o.name.to_string(), d.to_string());
                }
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{name} is a flag, it takes no value"));
                    }
                    args.flags.push(name);
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} expects a value"))?,
                    };
                    args.values.insert(name, val);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test")
            .opt("device", "pynq-z2", "target board")
            .opt("n", "4", "count")
            .flag("verbose", "chatty")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(sv(&[])).unwrap();
        assert_eq!(a.get("device"), Some("pynq-z2"));
        assert_eq!(a.parse_num::<u32>("n", 0), 4);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let a = cmd().parse(sv(&["--device", "zcu104", "--n=9", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get("device"), Some("zcu104"));
        assert_eq!(a.parse_num::<u32>("n", 0), 9);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(sv(&["--bogus"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(sv(&["--verbose=yes"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(sv(&["--device"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cmd().parse(sv(&["--help"])).unwrap_err();
        assert!(err.contains("--device"));
        assert!(err.contains("target board"));
    }
}
